"""Halo transport suite: per-peer packed p2p vs all-gather broadcast.

Two layers of coverage:

- pure-numpy layout properties of the per-peer packed send blocks the
  ``transport="p2p"`` runtime ships (every consumed gid appears exactly
  once in exactly one peer block, block row counts equal the paper's
  per-(vertex, consumer) accounting);
- subprocess parity runs on 8 forced host devices
  (``transport_parity_script.py``): p2p vs allgather logits/grads <= 1e-5
  for every aggregation backend, single- and multi-pod meshes, the bf16
  compressed wire, pipelined-step equivalence, exact measured-row
  accounting, and no donation warnings from the donated jitted steps.
"""
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

_SCRIPT = os.path.join(os.path.dirname(__file__), "transport_parity_script.py")
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, _SCRIPT, *args],
                          capture_output=True, text=True, timeout=900,
                          env=env)


@pytest.mark.parametrize(
    "flags",
    [("--backend", "edges"), ("--backend", "ell"), ("--backend", "hybrid"),
     ("--multi-pod",), ("--bf16",)],
    ids=["edges", "ell", "hybrid", "multi_pod", "bf16"])
def test_p2p_matches_allgather(flags):
    res = _run(*flags)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "OK" in res.stdout
    assert "donated buffers were not usable" not in res.stderr


# --------------------------------------------------------- layout properties

def _xplan(n, m, parts, seed, c_gpu, c_cpu):
    from repro.core import CacheCapacity, build_cache_plan
    from repro.dist import build_exchange_plan
    from repro.graph import build_partition, rmat
    from repro.graph.partition import random_partition

    g = rmat(n, m, seed=seed)
    assign = random_partition(g, parts, seed=seed)
    for p in range(parts):       # every part non-empty
        assign[p % n] = p
    ps = build_partition(g, assign, hops=1)
    plan = build_cache_plan(ps, CacheCapacity(c_gpu=[c_gpu] * parts,
                                              c_cpu=c_cpu),
                            refresh_every=2)
    return ps, build_exchange_plan(ps, plan), plan


@pytest.mark.parametrize("seed,parts,c_gpu,c_cpu",
                         [(0, 2, 0, 0), (1, 3, 5, 10), (2, 4, 12, 7),
                          (3, 4, 1000, 1000), (4, 4, 3, 0)])
def test_peer_pack_partitions_consumed_gids(seed, parts, c_gpu, c_cpu):
    """For each tier and consumer, the union of that consumer's peer
    blocks is exactly its tier gid set — every consumed gid in exactly one
    block of exactly one owner, exactly once."""
    ps, xplan, plan = _xplan(60, 240, parts, seed, c_gpu, c_cpu)
    tiers = {"uncached": [w.uncached_gids for w in plan.workers],
             "local": [w.local_gids for w in plan.workers]}
    for name, gids_per_part in tiers.items():
        t = xplan.uncached if name == "uncached" else xplan.local
        assert t.n_peer_rows == t.n_rows
        for q in range(parts):
            got = []
            for o in range(parts):
                block = t.peer_send_row[o][q][t.peer_send_valid[o][q]]
                gid = ps.parts[o].inner_nodes[block]
                got.append(gid)
                # block rows must be owned by o
                assert np.all(ps.assign[gid] == o)
            got = np.concatenate(got) if got else np.zeros(0, np.int64)
            want = np.asarray(gids_per_part[q])
            assert got.size == want.size
            assert np.array_equal(np.sort(got), np.sort(want))
            # no gid twice across this consumer's blocks
            assert np.unique(got).size == got.size


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_recv_peer_slot_addresses_own_gid(seed):
    """Each consumer's (src_part, peer_slot) pair addresses exactly the row
    of its own tier gid inside the (owner -> consumer) block."""
    ps, xplan, plan = _xplan(50, 200, 3, seed, 6, 8)
    for t, gids_per_part in ((xplan.uncached,
                              [w.uncached_gids for w in plan.workers]),
                             (xplan.local,
                              [w.local_gids for w in plan.workers])):
        for q in range(3):
            n = gids_per_part[q].size
            for k in range(n):
                o = int(t.recv_src_part[q, k])
                s = int(t.recv_peer_slot[q, k])
                assert bool(t.peer_send_valid[o, q, s])
                row = int(t.peer_send_row[o, q, s])
                assert int(ps.parts[o].inner_nodes[row]) == \
                    int(gids_per_part[q][k])


def test_transport_rows_accounting():
    """p2p originated rows == the paper accounting bytes_per_step counts;
    allgather moves ~P x; padded counts dominate valid counts."""
    _, xplan, plan = _xplan(60, 300, 4, 0, 8, 12)
    for refresh in (False, True):
        p2p = xplan.transport_rows("p2p", refresh)
        want = xplan.uncached.n_rows
        if refresh:
            want += xplan.local.n_rows + xplan.glob.n_unique
        assert p2p["total"] == want
        d, bt = 16, 2
        assert xplan.bytes_per_step(d, refresh, dtype_bytes=bt) == \
            p2p["total"] * d * bt
        ag = xplan.transport_rows("allgather", refresh)
        assert ag["uncached"] == 4 * xplan.uncached.n_send_rows
        assert xplan.transport_rows("p2p", refresh, padded=True)["total"] \
            >= p2p["total"]
    with pytest.raises(ValueError, match="nope"):
        xplan.transport_rows("nope", True)


def test_comm_bytes_dtype_threading():
    """ExchangePlan.bytes_per_step and jaca.comm_bytes_per_step agree for
    every payload width, not just the f32 default."""
    from repro.core import comm_bytes_per_step
    _, xplan, plan = _xplan(60, 300, 4, 1, 8, 12)
    for bt in (4, 2):
        cb = comm_bytes_per_step(plan, feat_dim=32, dtype_bytes=bt)
        assert xplan.bytes_per_step(32, refresh=False, dtype_bytes=bt) \
            == cb["cached_step_bytes"]
        assert xplan.bytes_per_step(32, refresh=True, dtype_bytes=bt) \
            == cb["refresh_step_bytes"]
    cb4 = comm_bytes_per_step(plan, feat_dim=32, dtype_bytes=4)
    cb2 = comm_bytes_per_step(plan, feat_dim=32, dtype_bytes=2)
    assert cb2["refresh_step_bytes"] * 2 == cb4["refresh_step_bytes"]


# ------------------------------------------------------------- donation

def test_sim_steps_donate_without_warnings():
    """The sim runtime's donated steps chain cleanly (steady-state buffers
    rewritten in place) and emit no donation warnings; donated arguments
    are actually consumed."""
    import jax
    import jax.numpy as jnp
    from repro.core import PROFILES, build_cache_plan, cal_capacity
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.dist import (build_exchange_plan, init_caches,
                            make_sim_runtime, stack_partitions)
    from repro.graph import (build_partition, metis_partition, rmat,
                             symmetric_normalize, synth_features)
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import adam

    g = rmat(200, 1000, seed=5)
    feats, labels = synth_features(g, 8, 4, seed=5)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=5)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=4)
    ps = build_partition(gn, metis_partition(gn, 2, seed=5), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=8, hidden_dim=8, out_dim=4,
                    num_layers=2)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * 2)
    xplan = build_exchange_plan(ps, build_cache_plan(ps, cap,
                                                     refresh_every=2))
    sp = stack_partitions(ps, task)
    opt = adam(1e-2)
    rt = make_sim_runtime(cfg, sp, xplan, opt)   # donate=True default
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    caches = init_caches(cfg, xplan, 2)
    first_opt_leaf = next(a for a in jax.tree.leaves(opt_state) if a.size)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for i in range(3):
            fn = (rt.step_refresh, rt.step_cached, rt.step_pipelined)[i]
            params, opt_state, caches, m = fn(params, opt_state, caches)
        jax.block_until_ready(m["loss"])
        bad = [str(x.message) for x in w if "donat" in str(x.message).lower()]
    assert not bad, bad
    assert np.isfinite(float(m["loss"]))
    # donation really happened: the original opt-state buffer is consumed
    with pytest.raises(RuntimeError, match="deleted|donated"):
        _ = first_opt_leaf + 1
