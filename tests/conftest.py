import os
import sys

# Make src/ importable without installation.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests need hypothesis; when it isn't installed (hermetic
# containers), fall back to the minimal vendored stand-in.  Appended behind
# the import check so a real installation always takes precedence.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.append(os.path.join(os.path.dirname(__file__), "_compat"))
