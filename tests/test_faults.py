"""repro.faults: seeded fault injection + graceful degradation.

The two contracts under test (ISSUE acceptance):

- **zero overhead disabled** — with no ``FaultPlan``/guard attached (or a
  disabled one), training is bit-identical to a run that never heard of
  ``repro.faults``; a defense-armed but fault-free run is also
  bit-identical (the guards change control flow only on failure).
- **injected == defended, exactly** — under each fault class at a fixed
  seed, training completes with a finite loss and every injector firing
  is matched by exactly one counted defense event (fetch_drop ->
  fetch_errors, fetch_delay -> slow_fetches, halo_corrupt ->
  corruptions_detected, grad_nan -> rollbacks, mem_pressure ->
  mem_backoffs).
"""
import os
import sys

import numpy as np
import pytest

from repro.core import (PROFILES, AdaptivePlanner, CacheCapacity,
                        StalenessController, build_cache_plan, cal_capacity)
from repro.data.gnn_data import FullBatchTask, split_masks
from repro.dist import (build_exchange_plan, make_sim_runtime,
                        stack_partitions, train_capgnn)
from repro.faults import (FAULT_KINDS, DefenseEvents, FaultPlan, FetchError,
                          FetchGuard, GuardConfig, NULL_FAULTS)
from repro.graph import (build_partition, metis_partition, rmat,
                         symmetric_normalize, synth_features)
from repro.models.gnn import GNNConfig
from repro.optim import adam

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

PARTS = 2
EPOCHS = 8
REFRESH_EVERY = 2


def _base(policy=None):
    g = rmat(260, 1500, seed=5)
    feats, labels = synth_features(g, 12, 4, seed=5)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=5)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=4)
    ps = build_partition(gn, metis_partition(gn, PARTS, seed=5), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=12, hidden_dim=16, out_dim=4,
                    num_layers=2)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * PARTS,
                       m_cpu_gib=1.0)
    planner = None
    if policy:
        planner = AdaptivePlanner(ps, cap, refresh_every=REFRESH_EVERY,
                                  policy=policy, seed=5)
        xplan = planner.exchange_plan()
    else:
        plan = build_cache_plan(ps, cap, refresh_every=REFRESH_EVERY)
        xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    return task, ps, cfg, sp, xplan, planner


def _train(features="host", spec=None, guard=None, policy=None,
           tracer=None, faults=None):
    task, ps, cfg, sp, xplan, planner = _base(policy)
    opt = adam(0.01)
    rt = make_sim_runtime(cfg, sp, xplan, opt, features=features)
    ctl = StalenessController(refresh_every=REFRESH_EVERY)
    if spec:
        faults = FaultPlan.parse(spec, seed=0)
    return train_capgnn(cfg, rt, xplan, PARTS, opt, epochs=EPOCHS,
                        controller=ctl, seed=0, planner=planner,
                        faults=faults, guard=guard, tracer=tracer)


# ------------------------------------------------------------ plan parsing

def test_parse_roundtrip_and_errors():
    fp = FaultPlan.parse("fetch_drop@3,7;grad_nan@5;halo_corrupt@4:rows=8",
                         seed=3)
    assert fp.enabled and fp.seed == 3
    assert fp.spec_string() == "fetch_drop@3,7;grad_nan@5;halo_corrupt@4"
    assert fp._by_kind["halo_corrupt"].rows == 8
    assert fp._by_kind["fetch_drop"].steps == (3, 7)
    # reparsing the roundtripped string yields the same step addressing
    fp2 = FaultPlan.parse(fp.spec_string(), seed=3)
    assert {k: s.steps for k, s in fp2._by_kind.items()} \
        == {k: s.steps for k, s in fp._by_kind.items()}

    assert not FaultPlan.parse("").enabled
    assert not FaultPlan.parse(None).enabled
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("cosmic_ray@3")
    with pytest.raises(ValueError, match="kind@step"):
        FaultPlan.parse("grad_nan")
    with pytest.raises(ValueError, match="unknown fault option"):
        FaultPlan.parse("grad_nan@3:zap=1")
    with pytest.raises(ValueError, match="duplicate"):
        FaultPlan.parse("grad_nan@3;grad_nan@5")
    assert set(FAULT_KINDS) >= {"fetch_drop", "grad_nan", "ckpt_truncate"}


def test_injectors_noop_outside_step_window():
    """Setup/eval are never faulted: injectors only fire between
    begin_step and end_run, and only on marked steps."""
    fp = FaultPlan.parse("fetch_drop@2;grad_nan@2;mem_pressure@2")
    fp.on_fetch()                       # no begin_step -> no-op
    assert fp.corrupt_params({"w": None}) == {"w": None}
    assert not fp.mem_pressure()
    fp.begin_step(1)                    # unmarked step -> no-op
    fp.on_fetch()
    assert not fp.mem_pressure()
    fp.begin_step(2)
    with pytest.raises(FetchError):
        fp.on_fetch()
    assert fp.mem_pressure()
    fp.end_run()
    fp.on_fetch()                       # window closed again
    assert fp.injected["fetch_drop"] == 1
    assert fp.injected["mem_pressure"] == 1
    assert NULL_FAULTS.total_injected() == 0


# -------------------------------------------------- disabled == untouched

def test_clean_run_bit_identical_with_disabled_plan():
    """faults=None, faults=disabled-plan, and a defense-armed fault-free
    run all produce bit-identical losses (zero-overhead contract; the
    guards alter numerics only when something actually fails)."""
    _, plain = _train(features="host")
    _, nullfp = _train(features="host", faults=FaultPlan(()))
    assert plain.losses == nullfp.losses
    assert plain.fault_events is None and plain.faults_injected is None
    assert nullfp.fault_events is None and nullfp.faults_injected is None

    _, guarded = _train(features="host",
                        guard=GuardConfig(guard_every=2, fetch_retries=2,
                                          checksums=True,
                                          fetch_timeout_s=10.0))
    assert guarded.losses == plain.losses
    assert all(v == 0 for v in guarded.fault_events.values())


# ------------------------------------------------------ injected==defended

@pytest.mark.parametrize("spec,defense,guard_kw,policy", [
    ("fetch_drop@3,5", "fetch_errors", dict(fetch_retries=2), None),
    ("fetch_delay@2:delay_s=0.12", "slow_fetches",
     dict(fetch_timeout_s=0.05), None),
    ("halo_corrupt@3", "corruptions_detected", dict(checksums=True), None),
    ("grad_nan@3", "rollbacks", dict(guard_every=2), None),
    ("mem_pressure@4", "mem_backoffs", dict(), "lru"),
])
def test_fault_class_defended_exactly(spec, defense, guard_kw, policy):
    kind = spec.split("@")[0]
    _, rep = _train(features="host", spec=spec,
                    guard=GuardConfig(**guard_kw), policy=policy)
    assert len(rep.losses) == EPOCHS and np.isfinite(rep.losses[-1])
    assert rep.faults_injected[kind] > 0
    assert rep.faults_injected[kind] == rep.fault_events[defense], \
        (rep.faults_injected, rep.fault_events)


def test_rollback_resumes_clean_trajectory():
    """After the NaN step's rollback + forced refresh, training replays
    the clean loss trajectory exactly (the snapshot restore is
    bit-faithful and the plain refresh rewrites every poisoned tier)."""
    _, clean = _train(features="host")
    _, rep = _train(features="host", spec="grad_nan@3",
                    guard=GuardConfig(guard_every=2))
    assert not np.isfinite(rep.losses[3])            # the injected step
    # snapshot was taken after step 1; the rollback replays from there
    np.testing.assert_allclose(rep.losses[4:], clean.losses[2:EPOCHS - 2],
                               rtol=1e-6, atol=1e-7)
    assert rep.fault_events["rollbacks"] == 1
    assert rep.fault_events["forced_refreshes"] == 1


def test_injection_deterministic_across_runs():
    """Same spec + seed -> the same per-step events and the same final
    loss, bit for bit (what lets the suite assert exact accounting)."""
    _, a = _train(features="host", spec="fetch_drop@3;halo_corrupt@4",
                  guard=GuardConfig(fetch_retries=1, checksums=True))
    _, b = _train(features="host", spec="fetch_drop@3;halo_corrupt@4",
                  guard=GuardConfig(fetch_retries=1, checksums=True))
    assert a.losses == b.losses
    assert a.faults_injected == b.faults_injected
    assert a.fault_events == b.fault_events


def test_tracer_counters_sum_to_report_ledgers():
    from repro.obs import Tracer
    tr = Tracer()
    _, rep = _train(features="host", spec="fetch_drop@3;grad_nan@5",
                    guard=GuardConfig(guard_every=2, fetch_retries=1),
                    tracer=tr)
    tot = tr.totals()
    for k, v in rep.fault_events.items():
        assert tot[k] == v, (k, tot[k], v)
    assert tot["faults_injected"] == sum(rep.faults_injected.values())
    kinds = {s.kind for s in tr.spans}
    assert {"rollback", "divergence_check"} <= kinds


# --------------------------------------------------------- guard unit tests

def test_fetch_guard_stale_reuse_and_exhaustion():
    ev = DefenseEvents()
    g = FetchGuard(GuardConfig(fetch_retries=2, fetch_backoff_s=0.0), ev)

    class _Store:
        from repro.obs.tracer import NULL_TRACER as tracer

    def always_fails():
        raise FetchError("down")

    # no previously consumed rows -> clean terminal error
    with pytest.raises(FetchError, match="no previously consumed rows"):
        g.fetch_sync(always_fails, _Store, "l0")
    assert ev.fetch_errors == 3 and ev.fetch_retries == 2
    # once rows were consumed, exhaustion degrades to stale reuse
    g.last_good["l0"] = np.ones(3)
    out = g.fetch_sync(always_fails, _Store, "l0")
    np.testing.assert_array_equal(out, np.ones(3))
    assert ev.fetch_stale_reuse == 1
    assert ev.fetch_errors == 6


def test_prefetch_degradation_window():
    ev = DefenseEvents()
    g = FetchGuard(GuardConfig(degrade_steps=2), ev)
    assert g.prefetch_ok()
    g._degraded = 2
    assert not g.prefetch_ok() and not g.prefetch_ok()
    assert g.prefetch_ok()                 # window over
    assert ev.prefetch_degraded_steps == 2


# --------------------------------------------------- planner memory backoff

def _xshapes(xp):
    """The exchange plan's slot-stable shape signature."""
    return tuple(a.shape for a in (
        xp.uncached.send_row, xp.uncached.recv_valid,
        xp.uncached.peer_send_row, xp.local.send_row, xp.local.recv_valid,
        xp.local.peer_send_row, xp.glob.send_row, xp.glob.src_part,
        xp.glob.read_pos))


def _pressure_planner(policy):
    g = rmat(260, 1500, seed=5)
    ps = build_partition(symmetric_normalize(g),
                         metis_partition(g, PARTS, seed=5), hops=1)
    # small enough that every budget binds (shrinking must change plans)
    cap = CacheCapacity(c_gpu=[max(2, pt.n_halo // 2) for pt in ps.parts],
                        c_cpu=max(2, ps.halo_union().size // 2))
    return ps, AdaptivePlanner(ps, cap, refresh_every=REFRESH_EVERY,
                               policy=policy, seed=5)


def test_shrink_capacity_is_slot_stable():
    """Shrinking under memory pressure halves the budgets but pins the
    exchange padding at the pre-shrink capacity, so post-shrink plans
    keep the original shape signature (no retrace on swap)."""
    ps, planner = _pressure_planner("lru")
    shapes = _xshapes(planner.exchange_plan())
    cap_before = planner.capacity
    planner.shrink_capacity(0.5)
    assert planner.capacity.c_cpu == int(cap_before.c_cpu * 0.5)
    assert planner.capacity.c_gpu == [int(c * 0.5)
                                      for c in cap_before.c_gpu]
    new_plan = planner.replan()
    assert _xshapes(planner.exchange_plan(new_plan)) == shapes
    # the shrunk budgets actually bound the new plan's residency
    for i, w in enumerate(new_plan.workers):
        assert w.local_gids.size <= planner.capacity.c_gpu[i]
    with pytest.raises(ValueError, match="shrink factor"):
        planner.shrink_capacity(0.0)


def test_shrink_capacity_static_rebuilds_plan():
    """static replan() returns the installed plan unchanged, so the
    shrink itself must rebuild it under the smaller budget."""
    ps, planner = _pressure_planner("static")
    shapes = _xshapes(planner.exchange_plan())
    rows_before = sum(w.local_gids.size for w in planner.plan.workers)
    planner.shrink_capacity(0.5)
    rows_after = sum(w.local_gids.size for w in planner.plan.workers)
    assert rows_after < rows_before
    assert planner.replan() is planner.plan
    assert _xshapes(planner.exchange_plan()) == shapes


# ------------------------------------------------------ checkpoint integrity

def test_checkpoint_truncation_detected_and_skipped(tmp_path):
    import warnings

    from repro.checkpoint import (CheckpointCorruptError, latest_step,
                                  load_checkpoint, save_checkpoint,
                                  verify_checkpoint)

    d = str(tmp_path)
    tree = {"w": np.arange(20, dtype=np.float32).reshape(4, 5)}
    save_checkpoint(d, 2, tree)
    save_checkpoint(d, 4, tree)
    assert latest_step(d) == 4
    meta = verify_checkpoint(d, 4)
    assert meta["payload_crc32"] is not None and meta["payload_bytes"] > 0

    FaultPlan.parse("ckpt_truncate@0:frac=0.3").truncate_checkpoint(
        os.path.join(d, "ckpt_00000004.npz"))
    with pytest.raises(CheckpointCorruptError, match="truncated"):
        verify_checkpoint(d, 4)
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint(d, 4, tree)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert latest_step(d) == 2
    assert any("corrupt" in str(x.message) for x in w)
    got = load_checkpoint(d, 2, tree)
    np.testing.assert_array_equal(got["w"], tree["w"])


def test_checkpoint_bitflip_detected(tmp_path):
    from repro.checkpoint import CheckpointCorruptError, verify_checkpoint
    from repro.checkpoint import save_checkpoint

    d = str(tmp_path)
    save_checkpoint(d, 1, {"w": np.zeros(8, np.float32)})
    path = os.path.join(d, "ckpt_00000001.npz")
    raw = bytearray(open(path, "rb").read())
    raw[-1] ^= 0xFF                      # same length, different bytes
    open(path, "wb").write(bytes(raw))
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        verify_checkpoint(d, 1)


def test_checkpoint_pre_checksum_meta_still_loads(tmp_path):
    import json

    from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint

    d = str(tmp_path)
    tree = {"w": np.ones(6, np.float32)}
    save_checkpoint(d, 3, tree)
    mp = os.path.join(d, "ckpt_00000003.json")
    meta = json.load(open(mp))
    del meta["payload_crc32"], meta["payload_bytes"]
    json.dump(meta, open(mp, "w"))
    assert latest_step(d) == 3
    got = load_checkpoint(d, 3, tree)
    np.testing.assert_array_equal(got["w"], tree["w"])


# ------------------------------------------------- regression gate key diff

def test_check_regression_reports_keys_both_directions():
    from benchmarks.check_regression import compare, new_keys

    baseline = {"s": {"a": 1, "b": True}}
    current = {"s": {"a": 1, "c": 2.0}, "t": {"x": 1}}
    problems = compare(baseline, current, 1e-3, 25.0)
    assert any("s.b" in p and "missing" in p for p in problems)
    extra = new_keys(baseline, current)
    assert any(e.startswith("s.c") for e in extra)
    assert any(e.startswith("t:") for e in extra)
    # SKIP_KEYS never reported in either direction
    assert not new_keys({"s": {}}, {"s": {"_mtime": "now"}})
