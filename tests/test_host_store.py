"""Out-of-core host feature store: HostTier plan invariants, the staged
fetch/writeback machinery, host-RAM capacity detection, and the
``features="host"`` runtimes.

Two layers of coverage:

- in-process unit/property tests: HostTier membership (= uncached ∪
  global reads, disjoint from the device-resident local cache), exact
  consumption-driven accounting, ``halo_dtype`` staging casts, the
  double-buffer ring under re-plans (``set_plan`` / ``step_transition``)
  on ragged uneven partitions — parity with the device-resident oracle at
  every step proves no staged buffer is ever served stale or mis-rowed;
- subprocess parity runs on 8 forced host devices
  (``host_parity_script.py``): host vs device training <= 1e-5
  (logits + sgd(1.0)-pinned grads) for every aggregation backend and
  both halo transports, exact fetch accounting, no donation warnings.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

_SCRIPT = os.path.join(os.path.dirname(__file__), "host_parity_script.py")
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, _SCRIPT, *args],
                          capture_output=True, text=True, timeout=900,
                          env=env)


@pytest.mark.parametrize(
    "flags",
    [("--backend", "edges"), ("--backend", "edges", "--transport", "p2p"),
     ("--backend", "ell"), ("--backend", "hybrid"), ("--bf16",)],
    ids=["edges", "edges_p2p", "ell", "hybrid", "bf16"])
def test_host_matches_device(flags):
    res = _run(*flags)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "OK" in res.stdout
    assert "donated buffers were not usable" not in res.stderr


# --------------------------------------------------- HostTier plan invariants

def _xplan(n, m, parts, seed, c_gpu, c_cpu, pad_cap=None):
    from repro.core import CacheCapacity, build_cache_plan
    from repro.dist import build_exchange_plan, exchange_capacity
    from repro.graph import build_partition, rmat
    from repro.graph.partition import random_partition

    g = rmat(n, m, seed=seed)
    assign = random_partition(g, parts, seed=seed)
    for p in range(parts):       # every part non-empty
        assign[p % n] = p
    ps = build_partition(g, assign, hops=1)
    cap = CacheCapacity(c_gpu=[c_gpu] * parts, c_cpu=c_cpu)
    plan = build_cache_plan(ps, cap, refresh_every=2)
    pad = exchange_capacity(ps, pad_cap) if pad_cap is not None else None
    return ps, build_exchange_plan(ps, plan, pad_to=pad), plan


@pytest.mark.parametrize("seed,parts,c_gpu,c_cpu",
                         [(0, 2, 0, 0), (1, 3, 5, 10), (2, 4, 12, 7),
                          (3, 4, 1000, 1000), (4, 4, 3, 0)])
def test_host_tier_membership(seed, parts, c_gpu, c_cpu):
    """Per worker, the host tier's valid positions are exactly
    uncached_pos ∪ global_pos — every halo row NOT in the device-resident
    local cache, each exactly once, none overlapping local_pos."""
    ps, xplan, plan = _xplan(60, 240, parts, seed, c_gpu, c_cpu)
    h = xplan.host
    assert h is not None
    total = 0
    for q, w in enumerate(plan.workers):
        got = np.sort(h.feat_pos[q][h.feat_valid[q]])
        want = np.sort(np.concatenate([w.uncached_pos, w.global_pos]))
        assert np.array_equal(got, want.astype(got.dtype))
        assert np.unique(got).size == got.size
        assert np.intersect1d(got, w.local_pos).size == 0
        total += got.size
    assert h.n_fetch_rows == total
    assert h.width == h.feat_pos.shape[1]


def test_host_tier_slot_stable_width():
    """Under a capacity-padded plan the host width is un_recv + glob_read,
    so re-planned memberships swap as data without a shape change."""
    from repro.core import CacheCapacity
    from repro.dist import exchange_capacity
    cap = CacheCapacity(c_gpu=[6] * 3, c_cpu=12)
    ps, xp_a, _ = _xplan(60, 240, 3, 1, 6, 12, pad_cap=cap)
    _, xp_b, _ = _xplan(60, 240, 3, 1, 3, 12, pad_cap=cap)
    ec = exchange_capacity(ps, cap)
    assert xp_a.host.width == ec.un_recv + ec.glob_read
    assert xp_a.host.feat_pos.shape == xp_b.host.feat_pos.shape


def test_host_fetch_accounting_methods():
    """host_fetch_rows / host_bytes_per_step / host_writeback_bytes agree
    with the tier index sets for every payload width."""
    _, xplan, plan = _xplan(60, 300, 4, 0, 8, 12)
    l0 = xplan.host.n_fetch_rows
    g = xplan.glob.n_unique
    assert xplan.host_fetch_rows(False, 2) == \
        {"l0": l0, "global": 0, "total": l0}
    assert xplan.host_fetch_rows(True, 2) == \
        {"l0": l0, "global": 2 * g, "total": l0 + 2 * g}
    for bt in (4, 2):
        assert xplan.host_bytes_per_step(16, (8, 8), False, bt) \
            == l0 * 16 * bt
        assert xplan.host_bytes_per_step(16, (8, 4), True, bt) \
            == (l0 * 16 + g * 12) * bt
    assert xplan.host_writeback_bytes((8, 4)) == g * 12 * 4
    bare = dataclasses.replace(xplan, host=None)
    with pytest.raises(ValueError, match="host tier"):
        bare.host_fetch_rows(True, 2)
    with pytest.raises(ValueError, match="host tier"):
        bare.host_bytes_per_step(16, (8,), True)


# ------------------------------------------------------- store unit tests

def test_stage_rows_masks_and_accounts_on_consumption():
    import jax
    from repro.dist.host_store import HostFeatureStore
    feat = np.arange(3 * 5 * 4, dtype=np.float32).reshape(3, 5, 4)
    store = HostFeatureStore(feat)
    pos = np.array([[0, 2, 0], [4, 1, 0], [3, 3, 0]])
    valid = np.array([[True, True, False],
                      [True, False, False],
                      [True, True, True]])
    staged = store.stage_rows((np.arange(3)[:, None], pos), valid=valid)
    assert staged.rows == int(valid.sum())
    assert staged.nbytes == staged.rows * 4 * 4
    got = np.asarray(jax.block_until_ready(staged.array))
    want = np.where(valid[..., None], feat[np.arange(3)[:, None], pos], 0.0)
    np.testing.assert_array_equal(got, want)
    # nothing accounted until the consuming step dispatches
    assert store.stats["fetch_rows"] == 0
    store.account_fetch(staged)
    assert store.stats["fetch_rows"] == staged.rows
    assert store.stats["fetch_bytes"] == staged.nbytes
    assert store.stats["fetches"] == 1


def test_fetch_rows_sync_path():
    from repro.dist.host_store import HostFeatureStore
    feat = np.random.default_rng(0).normal(size=(20, 6)).astype(np.float32)
    store = HostFeatureStore(feat)
    idx = np.array([3, 17, 3, 0])
    out = store.fetch_rows(idx)
    np.testing.assert_array_equal(out, feat[idx])
    assert store.stats["fetch_rows"] == 4      # accounted immediately
    assert store.delta(store.snapshot()) == \
        {k: 0 for k in store.stats}


def test_bf16_staging_halves_bytes():
    import jax
    import jax.numpy as jnp
    from repro.dist.host_store import HostFeatureStore, halo_dtype_info
    assert halo_dtype_info(None) == (None, 4)
    assert halo_dtype_info("bf16") == (jnp.bfloat16, 2)
    with pytest.raises(ValueError, match="halo_dtype"):
        halo_dtype_info("f8")
    feat = np.random.default_rng(1).normal(size=(10, 8)).astype(np.float32)
    s32 = HostFeatureStore(feat)
    s16 = HostFeatureStore(feat, halo_dtype="bf16")
    idx = np.arange(10)
    a = s32.stage_rows(idx)
    b = s16.stage_rows(idx)
    assert b.nbytes * 2 == a.nbytes
    got = np.asarray(jax.block_until_ready(b.array).astype(jnp.float32))
    np.testing.assert_allclose(got, feat, rtol=1e-2, atol=1e-2)


def test_global_buffer_roundtrip():
    import jax
    from repro.dist.host_store import HostFeatureStore
    store = HostFeatureStore(np.zeros((4, 4), np.float32))
    with pytest.raises(KeyError, match="never written back"):
        store.stage_buf(0)
    store.init_buf(0, (6, 3), n_valid=5)
    assert store.has_buf(0) and not store.has_buf(1)
    z = store.stage_buf(0)
    assert z.rows == 5
    np.testing.assert_array_equal(
        np.asarray(jax.block_until_ready(z.array)), np.zeros((6, 3)))
    buf = np.random.default_rng(2).normal(size=(6, 3)).astype(np.float32)
    store.write_buf(0, buf, n_valid=5)
    assert store.stats["writeback_bytes"] == 5 * 3 * 4
    back = store.stage_buf(0)
    np.testing.assert_array_equal(
        np.asarray(jax.block_until_ready(back.array)), buf)
    assert store.resident_bytes() == 4 * 4 * 4 + 6 * 3 * 4


def test_ring_backpressure_skips_consumed_handles():
    """The in-flight bound must not block on handles a donated step has
    already consumed (deleted buffers cannot be waited on)."""
    from repro.dist.host_store import HostFeatureStore
    feat = np.ones((8, 4), np.float32)
    store = HostFeatureStore(feat, prefetch_depth=1)
    staged = [store.stage_rows(np.arange(4)) for _ in range(3)]
    staged[0].array.delete()           # simulate donation into a step
    store.stage_rows(np.arange(4))     # must not raise
    assert len(store._inflight) <= 2


def test_suggest_prefetch_depth():
    from repro.dist.host_store import suggest_prefetch_depth
    assert suggest_prefetch_depth(0, 1.0, 10.0) == 2      # degenerate
    assert suggest_prefetch_depth(1 << 20, 0.0, 10.0) == 2
    slow = suggest_prefetch_depth(1 << 30, 1e-3, 1.0)
    assert slow == 8                                      # clamped
    assert suggest_prefetch_depth(1 << 20, 1.0, 100.0) == 1


# ------------------------------------------- host-RAM capacity detection

def test_detect_host_mem_gib():
    from repro.core.device_profile import detect_host_mem_gib
    got = detect_host_mem_gib()
    assert 0.1 < got < 1 << 20


def test_cal_capacity_host_ram_default():
    """m_cpu_gib=None resolves to the profiles' host_mem_gib floor (the
    declared Table 1 profiles keep the paper's 16 GiB assumption), and to
    the detected machine RAM when no profile declares one."""
    from repro.core import PROFILES, cal_capacity
    from repro.graph import build_partition, rmat
    from repro.graph.partition import random_partition
    g = rmat(80, 400, seed=0)
    ps = build_partition(g, random_partition(g, 2, seed=0), hops=1)
    profiles = [PROFILES["rtx3090"]] * 2
    default = cal_capacity(ps, [16, 8, 4], profiles)
    explicit = cal_capacity(ps, [16, 8, 4], profiles, m_cpu_gib=16.0)
    assert default.c_cpu == explicit.c_cpu
    assert default.c_gpu == explicit.c_gpu
    blank = [dataclasses.replace(p, host_mem_gib=0.0) for p in profiles]
    detected = cal_capacity(ps, [16, 8, 4], blank)
    assert detected.c_cpu >= 0


def test_measured_profile_reports_host_mem():
    from repro.core.device_profile import measure_profile
    prof = measure_profile(size=64, repeats=1)
    assert prof.host_mem_gib > 0.1


# ------------------------- double buffer under re-plans (property test)

def test_double_buffer_never_serves_stale_rows_under_replans():
    """Ragged uneven partitions + live re-planning: the host-backed
    runtime is stepped through refreshes, cached steps, ``set_plan``
    swaps and pipelined ``step_transition``s in lockstep with the
    device-resident oracle.  Param parity <= 1e-5 at every step proves
    the staged ring never serves a stale or wrong row (flushed prefetches
    are discarded); the store's consumed rows must equal the plan-counted
    fetches exactly, including the transition's l0loc restage."""
    import jax
    from repro.core import AdaptivePlanner, CacheCapacity
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.dist import init_caches, make_sim_runtime, stack_partitions
    from repro.graph import (build_partition, rmat, symmetric_normalize,
                             synth_features)
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import sgd

    g = rmat(240, 1500, seed=11)
    feats, labels = synth_features(g, 10, 4, seed=11)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=11)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=4)
    # deliberately skewed partition sizes (resource-aware style raggedness)
    rng = np.random.default_rng(11)
    assign = rng.choice(3, size=g.num_nodes, p=[0.6, 0.25, 0.15])
    for p in range(3):
        assign[p] = p
    ps = build_partition(gn, assign, hops=1, parts=3)
    sizes = [pt.n_halo for pt in ps.parts]
    assert max(sizes) > min(sizes)          # genuinely ragged
    cfg = GNNConfig(model="gcn", in_dim=10, hidden_dim=12, out_dim=4,
                    num_layers=3)
    cap = CacheCapacity(c_gpu=[max(1, max(sizes) // 4)] * 3,
                        c_cpu=max(1, ps.halo_union().size // 4))
    planner = AdaptivePlanner(ps, cap, refresh_every=2, policy="lru",
                              seed=11)
    xp = planner.exchange_plan()
    sp = stack_partitions(ps, task)
    opt = sgd(1.0)
    dev = make_sim_runtime(cfg, sp, xp, opt, donate=False)
    host = make_sim_runtime(cfg, sp, xp, opt, donate=False,
                            features="host", prefetch_depth=3)
    store = host.host_store
    snap = store.snapshot()

    params = init_gnn(jax.random.PRNGKey(1), cfg)
    sd = (params, opt.init(params), init_caches(cfg, xp, 3))
    sh = (params, opt.init(params),
          init_caches(cfg, xp, 3, features="host"))
    ex_layers = cfg.num_layers - 1
    expected = 0
    # schedule mixes every flavour with two re-plan mechanisms: pipelined
    # step_transition (stale tiers consumed on the OLD plan, caches
    # emitted for the NEW) and a cold set_plan + refresh
    schedule = ["refresh", "cached", "transition", "cached", "pipelined",
                "set_plan", "refresh", "cached", "transition", "cached"]
    for step, kind in enumerate(schedule):
        cur = host.xplan
        per = cur.host_fetch_rows(True, ex_layers)
        if kind == "transition":
            nxt = planner.exchange_plan(planner.replan())
            sd = dev.step_transition(*sd, nxt)[:3]
            sh = host.step_transition(*sh, nxt)[:3]
            # old plan's stale tiers consumed + the new plan's layer-0
            # local block restaged (accounted at install)
            expected += per["total"] + int(nxt.local.n_rows)
        elif kind == "set_plan":
            nxt = planner.exchange_plan(planner.replan())
            dev.set_plan(nxt)
            host.set_plan(nxt)          # flushes the ring unaccounted
            expected += int(nxt.local.n_rows)
            continue
        else:
            sd = getattr(dev, f"step_{kind}")(*sd)[:3]
            sh = getattr(host, f"step_{kind}")(*sh)[:3]
            expected += (per["l0"] if kind == "refresh" else per["total"])
        diffs = [float(np.abs(np.asarray(a) - np.asarray(b)).max())
                 for a, b in zip(jax.tree.leaves(sd[0]),
                                 jax.tree.leaves(sh[0]))]
        assert max(diffs) < 1e-5, f"param drift at step {step} ({kind})"
    d = store.delta(snap)
    assert d["fetch_rows"] == expected, (d["fetch_rows"], expected)


# ------------------------------------------------------ serve host tier

def test_serve_engine_uses_host_store():
    """The serve engine's host-tier misses go through the shared
    HostFeatureStore staged fetch (accounted + timed), not a bare numpy
    gather."""
    import jax
    from repro.core import CacheCapacity, build_cache_plan
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.dist import build_exchange_plan, stack_partitions
    from repro.graph import (build_partition, metis_partition, rmat,
                             symmetric_normalize, synth_features)
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.serve import (GNNServeEngine, precompute_embeddings,
                             rank_hot_nodes)

    g = rmat(120, 700, seed=9)
    feats, labels = synth_features(g, 8, 4, seed=9)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=9)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=4)
    ps = build_partition(gn, metis_partition(gn, 2, seed=9), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=8, hidden_dim=8, out_dim=4,
                    num_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    xplan = build_exchange_plan(
        ps, build_cache_plan(ps, CacheCapacity(c_gpu=[4] * 2, c_cpu=10),
                             refresh_every=2))
    sp = stack_partitions(ps, task)
    emb = precompute_embeddings(cfg, ps, sp, xplan, params)
    hot = rank_hot_nodes(gn, 10, ps=ps, policy="degree")
    engine = GNNServeEngine(emb, params, gn, hot, features=task.features)
    cold = np.setdiff1d(np.arange(g.num_nodes), hot)[:16]
    out = engine.lookup(cold)
    np.testing.assert_allclose(out, emb.logits[cold], rtol=1e-6, atol=1e-6)
    assert engine.host_store.stats["fetch_rows"] >= cold.size
    assert engine.stats["host_fetch_s"] > 0.0
    assert engine.stats["host_hits"] >= cold.size
