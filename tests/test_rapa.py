"""RAPA unit tests: cost model (Eqs. 13-14), influence score (Eq. 16),
adjustment loop (Algs. 2-3), memory constraint (Eq. 15)."""
import numpy as np
import pytest

from repro.core import (do_partition, RapaConfig, comm_cost, comp_cost,
                        influence_scores, memory_bytes, PROFILES, make_group)
from repro.core.rapa import _make_states, _lambda
from repro.graph import rmat, build_partition, metis_partition


@pytest.fixture(scope="module")
def ps():
    g = rmat(1000, 7000, seed=1)
    return build_partition(g, metis_partition(g, 4, seed=1), hops=1)


def test_comm_cost_weaker_device_costs_more():
    profs = make_group(["rtx3090", "gtx1650"])
    c_fast = comm_cost(100, profs[0], profs, 2)
    c_slow = comm_cost(100, profs[1], profs, 2)
    assert c_slow >= c_fast
    # zero outer edges -> zero comm cost
    assert comm_cost(0, profs[0], profs, 2) == 0.0


def test_comp_cost_alpha_extremes():
    profs = make_group(["rtx3090", "rtx3060"])
    # alpha=1: pure SpMM term (edges only)
    assert comp_cost(100, 999, profs[0], profs, alpha=1.0) == \
        pytest.approx(100 * profs[0].spmm / min(p.spmm for p in profs))
    # alpha=0: pure MM term (inner vertices only)
    assert comp_cost(999, 100, profs[0], profs, alpha=0.0) == \
        pytest.approx(100 * profs[0].mm / min(p.mm for p in profs))


def test_influence_scores_shape_and_sign(ps):
    for part in ps.parts:
        s = influence_scores(ps, part)
        assert s.shape == (part.n_halo,)
        assert np.all(s >= 0)
        # a halo with local edges must score > 0 (replication count >= 1)
        lsrc, _ = part.local_graph.edges()
        deg = np.bincount(lsrc[lsrc >= part.n_inner] - part.n_inner,
                          minlength=part.n_halo)
        assert np.all(s[deg > 0] > 0)


def test_do_partition_balances_heterogeneous(ps):
    profiles = make_group(["rtx3090", "a40", "rtx3060", "gtx1660ti"])
    res = do_partition(ps, profiles, RapaConfig(feat_dim=32))
    lam0 = res.history[0]["lambda"]
    lamN = res.history[-1]["lambda"]
    # imbalance must not get worse; normally improves a lot (Fig. 20)
    assert lamN.std() <= lam0.std() + 1e-9
    assert lamN.max() <= lam0.max() + 1e-9
    # weak devices shed halos; total removals positive under heterogeneity
    assert sum(res.removed_per_part) > 0


def test_do_partition_homogeneous_near_noop(ps):
    """With identical devices and METIS-balanced parts, RAPA should remove
    few (possibly zero) replicas."""
    profiles = [PROFILES["rtx3090"]] * 4
    res = do_partition(ps, profiles, RapaConfig(feat_dim=32))
    removed = sum(res.removed_per_part)
    assert removed <= 0.5 * ps.total_halo()


def test_pruned_partitions_are_structurally_valid(ps):
    profiles = make_group(["rtx3090", "rtx3090", "rtx3060", "gtx1650"])
    res = do_partition(ps, profiles, RapaConfig(feat_dim=32))
    for old, new in zip(ps.parts, res.partition_set.parts):
        assert np.array_equal(old.inner_nodes, new.inner_nodes)
        assert set(new.halo_nodes).issubset(set(old.halo_nodes))
        # local graph edges reference valid local ids only
        src, dst = new.local_graph.edges()
        assert src.max(initial=0) < new.n_local
        assert dst.max(initial=0) < new.n_inner  # dst always inner
        # global_to_local is a consistent bijection over local vertices
        assert len(new.global_to_local) == new.n_local


def test_lambda_decreases_when_halos_removed(ps):
    profiles = make_group(["rtx3090"] * 4)
    states = _make_states(ps)
    cfg = RapaConfig()
    st = states[0]
    lam_before = _lambda(st, profiles[0], profiles, cfg, 4)
    # remove the 10 lowest-influence halos
    order = np.argsort(st.scores)
    st.removed[order[:10]] = True
    lam_after = _lambda(st, profiles[0], profiles, cfg, 4)
    assert lam_after <= lam_before


def test_memory_bytes_monotone():
    cfg = RapaConfig(feat_dim=64)
    assert memory_bytes(100, 500, cfg) < memory_bytes(200, 500, cfg)
    assert memory_bytes(100, 500, cfg) < memory_bytes(100, 900, cfg)


def test_history_records_fig20_series(ps):
    profiles = make_group(["rtx3090", "a40", "rtx3060", "gtx1660ti"])
    res = do_partition(ps, profiles, RapaConfig(feat_dim=32))
    assert len(res.history) >= 2
    for snap in res.history:
        assert len(snap["nodes"]) == 4
        assert len(snap["edges"]) == 4
        assert snap["lambda"].shape == (4,)
        assert snap["std"] >= 0
