"""RAPA unit tests: cost model (Eqs. 13-14), influence score (Eq. 16),
adjustment loop (Algs. 2-3), memory constraint (Eq. 15)."""
import numpy as np
import pytest

from repro.core import (do_partition, RapaConfig, comm_cost, comp_cost,
                        influence_scores, memory_bytes, PROFILES, make_group)
from repro.core.rapa import _make_states, _lambda
from repro.graph import rmat, build_partition, metis_partition


@pytest.fixture(scope="module")
def ps():
    g = rmat(1000, 7000, seed=1)
    return build_partition(g, metis_partition(g, 4, seed=1), hops=1)


def test_comm_cost_weaker_device_costs_more():
    profs = make_group(["rtx3090", "gtx1650"])
    c_fast = comm_cost(100, profs[0], profs, 2)
    c_slow = comm_cost(100, profs[1], profs, 2)
    assert c_slow >= c_fast
    # zero outer edges -> zero comm cost
    assert comm_cost(0, profs[0], profs, 2) == 0.0


def test_comp_cost_alpha_extremes():
    profs = make_group(["rtx3090", "rtx3060"])
    # alpha=1: pure SpMM term (edges only)
    assert comp_cost(100, 999, profs[0], profs, alpha=1.0) == \
        pytest.approx(100 * profs[0].spmm / min(p.spmm for p in profs))
    # alpha=0: pure MM term (inner vertices only)
    assert comp_cost(999, 100, profs[0], profs, alpha=0.0) == \
        pytest.approx(100 * profs[0].mm / min(p.mm for p in profs))


def test_influence_scores_shape_and_sign(ps):
    for part in ps.parts:
        s = influence_scores(ps, part)
        assert s.shape == (part.n_halo,)
        assert np.all(s >= 0)
        # a halo with local edges must score > 0 (replication count >= 1)
        lsrc, _ = part.local_graph.edges()
        deg = np.bincount(lsrc[lsrc >= part.n_inner] - part.n_inner,
                          minlength=part.n_halo)
        assert np.all(s[deg > 0] > 0)


def test_do_partition_balances_heterogeneous(ps):
    profiles = make_group(["rtx3090", "a40", "rtx3060", "gtx1660ti"])
    res = do_partition(ps, profiles, RapaConfig(feat_dim=32))
    lam0 = res.history[0]["lambda"]
    lamN = res.history[-1]["lambda"]
    # imbalance must not get worse; normally improves a lot (Fig. 20)
    assert lamN.std() <= lam0.std() + 1e-9
    assert lamN.max() <= lam0.max() + 1e-9
    # weak devices shed halos; total removals positive under heterogeneity
    assert sum(res.removed_per_part) > 0


def test_do_partition_homogeneous_near_noop(ps):
    """With identical devices and METIS-balanced parts, RAPA should remove
    few (possibly zero) replicas."""
    profiles = [PROFILES["rtx3090"]] * 4
    res = do_partition(ps, profiles, RapaConfig(feat_dim=32))
    removed = sum(res.removed_per_part)
    assert removed <= 0.5 * ps.total_halo()


def test_pruned_partitions_are_structurally_valid(ps):
    profiles = make_group(["rtx3090", "rtx3090", "rtx3060", "gtx1650"])
    res = do_partition(ps, profiles, RapaConfig(feat_dim=32))
    for old, new in zip(ps.parts, res.partition_set.parts):
        assert np.array_equal(old.inner_nodes, new.inner_nodes)
        assert set(new.halo_nodes).issubset(set(old.halo_nodes))
        # local graph edges reference valid local ids only
        src, dst = new.local_graph.edges()
        assert src.max(initial=0) < new.n_local
        assert dst.max(initial=0) < new.n_inner  # dst always inner
        # global_to_local is a consistent bijection over local vertices
        assert len(new.global_to_local) == new.n_local


def test_lambda_decreases_when_halos_removed(ps):
    profiles = make_group(["rtx3090"] * 4)
    states = _make_states(ps)
    cfg = RapaConfig()
    st = states[0]
    lam_before = _lambda(st, profiles[0], profiles, cfg, 4)
    # remove the 10 lowest-influence halos
    order = np.argsort(st.scores)
    st.removed[order[:10]] = True
    lam_after = _lambda(st, profiles[0], profiles, cfg, 4)
    assert lam_after <= lam_before


def test_memory_bytes_monotone():
    cfg = RapaConfig(feat_dim=64)
    assert memory_bytes(100, 500, cfg) < memory_bytes(200, 500, cfg)
    assert memory_bytes(100, 500, cfg) < memory_bytes(100, 900, cfg)


def test_history_records_fig20_series(ps):
    profiles = make_group(["rtx3090", "a40", "rtx3060", "gtx1660ti"])
    res = do_partition(ps, profiles, RapaConfig(feat_dim=32))
    assert len(res.history) >= 2
    for snap in res.history:
        assert len(snap["nodes"]) == 4
        assert len(snap["edges"]) == 4
        assert snap["lambda"].shape == (4,)
        assert snap["std"] >= 0


def test_adjust_subgraph_respects_tight_memory_bound(ps):
    """Eq. 15: with ``mem_gib`` set between the inner-only and the full
    footprint, one adjustment sweep must shed halo until every partition
    fits its device."""
    import dataclasses as dc
    from repro.core import adjust_subgraph
    cfg = RapaConfig(feat_dim=32)
    states = _make_states(ps)
    profiles = []
    for st in states:
        lo = memory_bytes(st.part.n_inner, st.e_inner, cfg)
        hi = memory_bytes(st.v_local, st.e_all, cfg)
        assert hi > lo    # the bound below really forces pruning
        mem = (lo + 0.25 * (hi - lo)) / 1024 ** 3
        profiles.append(dc.replace(PROFILES["rtx3090"], mem_gib=mem))
    adjust_subgraph(states, profiles, cfg)
    for st, prof in zip(states, profiles):
        assert memory_bytes(st.v_local, st.e_all, cfg) \
            <= prof.mem_gib * 1024 ** 3


def test_influence_scores_on_weighted_graph(ps):
    """Eq. 16 on a weighted (symmetric-normalised) graph: finite,
    non-negative, positive wherever the replica has local edges."""
    from repro.graph import symmetric_normalize
    gw = symmetric_normalize(ps.graph)
    psw = build_partition(gw, ps.assign, hops=1, parts=ps.num_parts)
    for part in psw.parts:
        s = influence_scores(psw, part)
        assert s.shape == (part.n_halo,)
        assert np.all(np.isfinite(s))
        assert np.all(s >= 0)
        lsrc, _ = part.local_graph.edges()
        deg = np.bincount(lsrc[lsrc >= part.n_inner] - part.n_inner,
                          minlength=part.n_halo)
        assert np.all(s[deg > 0] > 0)


def test_uneven_stacks_match_uniform_logits():
    """Resource-aware uneven partitions change shapes, not math: the sim
    runtime's fresh forward on skew-weighted partitions matches uniform
    partitioning vertex-for-vertex, and ``pad_to`` makes the two stacked
    layouts shape-identical (the slot-stable stacking contract)."""
    import jax
    from repro.core import cal_capacity, build_cache_plan
    from repro.data import make_task
    from repro.dist import build_exchange_plan, stack_partitions, \
        make_sim_runtime
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import adam

    task = make_task("flickr", scale=0.01, feat_dim=16, seed=0)
    g = task.graph
    cfg = GNNConfig(model="gcn", in_dim=16, hidden_dim=32,
                    out_dim=task.num_classes, num_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    profiles = [PROFILES["rtx3090"]] * 4

    logits = {}
    stacked = {}
    for name, w in (("uniform", None),
                    ("uneven", [0.4, 0.3, 0.2, 0.1])):
        ps = build_partition(g, metis_partition(g, 4, seed=0, weights=w),
                             hops=1, parts=4)
        cap = cal_capacity(ps, cfg.feat_dims, profiles)
        plan = build_cache_plan(ps, cap, refresh_every=1)
        xplan = build_exchange_plan(ps, plan)
        sp = stack_partitions(ps, task)
        rt = make_sim_runtime(cfg, sp, xplan, adam(1e-2))
        out = np.asarray(rt.forward_fresh(params))
        full = np.zeros((g.num_nodes, task.num_classes), np.float32)
        for i, part in enumerate(ps.parts):
            full[part.inner_nodes] = out[i, :part.n_inner]
        logits[name] = full
        stacked[name] = sp
    np.testing.assert_allclose(logits["uneven"], logits["uniform"],
                               atol=1e-5, rtol=0)

    # pad_to: both partitionings stacked to common widths are
    # shape-identical while the valid masks keep the accounting exact
    ni = max(s.n_inner_max for s in stacked.values())
    nh = max(s.n_halo_max for s in stacked.values())
    shapes = []
    for name in ("uniform", "uneven"):
        sp2 = stack_partitions(
            build_partition(g, metis_partition(
                g, 4, seed=0,
                weights=None if name == "uniform" else [0.4, 0.3, 0.2, 0.1]),
                hops=1, parts=4),
            task, pad_to=(ni, nh))
        shapes.append((sp2.feats.shape, sp2.halo_feats.shape))
        assert int(sp2.inner_valid.sum()) == g.num_nodes
    assert shapes[0] == shapes[1]
