"""DistStrategy dispatch, the capability matrix, and halo_1d-through-the-
interface purity: building via ``get_strategy("halo_1d")`` must be
bit-identical (same layout arrays, same lowered step HLO, same losses
and byte accounting) to calling the pre-existing constructors directly.
"""
import numpy as np
import pytest

from repro.dist import (DistStrategy, Halo1DStrategy, StrategyCaps,
                        StrategyCapabilityError, TrainSpec, get_strategy,
                        build_exchange_plan, stack_partitions,
                        make_sim_runtime, train_capgnn)
from repro.dist.strategy_15d import Spmm15DStrategy

from test_spec import _tiny_problem


def test_registry_dispatch():
    h = get_strategy("halo_1d")
    s = get_strategy("spmm_15d")
    assert isinstance(h, Halo1DStrategy) and isinstance(s, Spmm15DStrategy)
    assert get_strategy("halo_1d") is h          # singleton
    assert isinstance(h, DistStrategy) and isinstance(s, DistStrategy)
    with pytest.raises(ValueError) as ei:
        get_strategy("ring")
    assert "halo_1d" in str(ei.value) and "spmm_15d" in str(ei.value)


def test_capability_matrix():
    h, s = get_strategy("halo_1d").caps, get_strategy("spmm_15d").caps
    assert isinstance(h, StrategyCaps) and isinstance(s, StrategyCaps)
    # halo_1d owns the paper's machinery; spmm_15d is exact + replicated
    assert h.jaca_tiers and h.pipeline and h.host_features and h.sim_runtime
    assert h.adaptive_cache and h.fault_guard and not h.replicated
    assert not (s.jaca_tiers or s.pipeline or s.host_features
                or s.adaptive_cache or s.fault_guard or s.sim_runtime)
    assert s.replicated and s.backends == ("edges",)
    assert set(h.transports) == {"allgather", "p2p"}


def test_spmm15d_denies_sim_runtime():
    spec = TrainSpec(strategy="spmm_15d", replication=2)
    with pytest.raises(StrategyCapabilityError, match="sim"):
        get_strategy("spmm_15d").make_sim_runtime(None, None, None, spec)


def test_halo1d_interface_is_pure_refactor():
    """Layout arrays, lowered refresh-step HLO, losses and byte accounting
    are bit-identical between the strategy interface and the direct
    constructor path (acceptance criterion: pure refactor)."""
    import jax
    import jax.numpy as jnp
    from repro.models.gnn import init_gnn
    from repro.optim import adam

    ps, task, cfg, plan = _tiny_problem()
    strat = get_strategy("halo_1d")
    spec = TrainSpec(refresh_every=2, donate=False)

    layout = strat.build_layout(ps, task, spec, plan=plan)
    sp = stack_partitions(ps, task)
    xplan = build_exchange_plan(ps, plan)
    np.testing.assert_array_equal(layout.sp.feats, sp.feats)
    np.testing.assert_array_equal(layout.sp.e_src, sp.e_src)
    np.testing.assert_array_equal(layout.xplan.uncached.send_row,
                                  xplan.uncached.send_row)
    assert layout.num_parts == ps.num_parts

    opt = adam(1e-2)
    rt_s = strat.make_sim_runtime(cfg, layout, opt, spec)
    rt_d = make_sim_runtime(cfg, sp, xplan, opt, spec=spec)

    # same compiled-step cache key: the lowered HLO text is identical
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    o0 = opt.init(params)
    c_s = jax.tree.map(jnp.asarray, rt_s.caches0)
    c_d = jax.tree.map(jnp.asarray, rt_d.caches0)
    hlo_s = rt_s.lower_step("refresh", params, o0, c_s).as_text()
    hlo_d = rt_d.lower_step("refresh", params, o0, c_d).as_text()
    assert hlo_s == hlo_d

    _, rep_s = strat.train(cfg, rt_s, layout, opt, spec, epochs=4)
    _, rep_d = train_capgnn(cfg, rt_d, xplan, ps.num_parts, opt, epochs=4,
                            spec=spec)
    assert rep_s.losses == rep_d.losses          # bit-identical
    assert rep_s.comm_bytes == rep_d.comm_bytes
    assert rep_s.comm_bytes_vanilla == rep_d.comm_bytes_vanilla
    assert rep_s.refresh_steps == rep_d.refresh_steps

    # the strategy's modeled step_bytes is the plan-counted refresh figure
    assert strat.step_bytes(layout, cfg, spec) == sum(
        xplan.bytes_per_step(d, refresh=True, dtype_bytes=4)
        for d in cfg.feat_dims[:cfg.num_layers])
