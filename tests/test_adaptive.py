"""Online cache adaptation suite.

Three layers of coverage:

- **slot-stable layout properties** (randomized membership churn): every
  capacity-padded exchange plan over the same (partitioning, capacity)
  pair has identical array shapes, and each one individually preserves
  the exchange invariants — every consumed gid in exactly one tier and
  exactly one peer block, valid-mask row counts equal to the plan's tier
  sizes, scatter positions in range, and exact halo reconstruction;
- **live eviction == trace simulator**: an :class:`AdaptivePlanner`
  configured as a single shared cache reproduces
  ``simulate_policy_hit_rate``'s FIFO/LRU hit sequence exactly on the
  same epoch stream;
- **no-retrace + parity**: the jitted sim steps keep a compiled-call
  cache of size 1 across re-plan events (plan swap is data, not shape),
  an adaptive run with a membership-preserving policy matches the frozen
  static runtime's numerics, and the byte accounting stays exact
  (plan-counted rows == valid-mask rows of the consumed arrays) across
  transitions.  The SPMD equivalent runs in a subprocess on forced host
  devices (``adaptive_parity_script.py``) for both transports.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AdaptivePlanner, CacheCapacity, StalenessController,
                        build_cache_plan, plan_from_membership,
                        simulate_policy_hit_rate)
from repro.dist import build_exchange_plan, exchange_capacity
from repro.graph import build_partition, rmat
from repro.graph.partition import random_partition

_SCRIPT = os.path.join(os.path.dirname(__file__), "adaptive_parity_script.py")
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _ps(n, m, parts, seed):
    g = rmat(n, m, seed=seed)
    assign = random_partition(g, parts, seed=seed)
    for p in range(parts):       # every part non-empty
        assign[p % n] = p
    return build_partition(g, assign, hops=1)


def _random_membership(ps, cap, rng):
    """Arbitrary capacity-respecting tier membership (worst-case churn —
    no policy structure at all)."""
    local_sets = []
    for i, pt in enumerate(ps.parts):
        hi = min(cap.c_gpu[i], pt.n_halo)
        k = int(rng.integers(0, hi + 1))
        sel = rng.choice(pt.halo_nodes, size=k, replace=False) if k else []
        local_sets.append(set(int(v) for v in sel))
    union = ps.halo_union()
    kc = int(rng.integers(0, min(cap.c_cpu, union.size) + 1))
    glob = (set(int(v) for v in rng.choice(union, size=kc, replace=False))
            if kc else set())
    return local_sets, glob


def _check_invariants(ps, plan, xplan):
    """The exchange invariants a re-ranked plan must preserve."""
    parts = ps.num_parts
    tiers = {"uncached": ([w.uncached_gids for w in plan.workers],
                          xplan.uncached),
             "local": ([w.local_gids for w in plan.workers], xplan.local)}
    for name, (gids_per_part, t) in tiers.items():
        # valid-mask rows == plan rows
        want_rows = sum(g.size for g in gids_per_part)
        assert int(t.recv_valid.sum()) == want_rows, name
        assert t.n_peer_rows == want_rows, name
        for q in range(parts):
            got = []
            for o in range(parts):
                block = t.peer_send_row[o][q][t.peer_send_valid[o][q]]
                gid = ps.parts[o].inner_nodes[block]
                got.append(gid)
                assert np.all(ps.assign[gid] == o)
            got = np.concatenate(got) if got else np.zeros(0, np.int64)
            want = np.asarray(gids_per_part[q])
            # every consumed gid in exactly one peer block, exactly once
            assert np.array_equal(np.sort(got), np.sort(want))
            assert np.unique(got).size == got.size
            # scatter positions in range and valid-masked
            nh = ps.parts[q].n_halo
            v = t.recv_valid[q]
            assert np.all(t.recv_halo_pos[q][v] < max(nh, 1))
    # the three tiers partition each worker's halo positions
    for w, part in zip(plan.workers, ps.parts):
        pos = np.concatenate([w.local_pos, w.global_pos, w.uncached_pos])
        assert np.array_equal(np.sort(pos), np.arange(part.n_halo))
    # global buffer: one valid row per unique consumed gid, reads in range
    used = [w.global_gids for w in plan.workers if w.global_gids.size]
    n_used = int(np.unique(np.concatenate(used)).size) if used else 0
    g = xplan.glob
    assert g.n_unique == n_used
    assert int(g.read_valid.sum()) == sum(w.global_pos.size
                                          for w in plan.workers)
    for q in range(parts):
        v = g.read_valid[q]
        assert np.all(g.read_buf_idx[q][v] < max(g.buf_size, 1))
        if v.any():
            assert bool(g.buf_valid[g.read_buf_idx[q][v]].all())
        assert np.all(g.read_pos[q][v] < max(ps.parts[q].n_halo, 1))


@st.composite
def churn_case(draw):
    n = draw(st.integers(20, 70))
    m = draw(st.integers(n, 5 * n))
    parts = draw(st.integers(2, 4))
    seed = draw(st.integers(0, 2 ** 16))
    c_gpu = draw(st.integers(0, 25))
    c_cpu = draw(st.integers(0, 25))
    return n, m, parts, seed, c_gpu, c_cpu


@given(churn_case())
@settings(max_examples=25, deadline=None)
def test_slot_stable_replanning_preserves_invariants(case):
    """Randomized membership churn: shapes frozen, invariants intact."""
    n, m, parts, seed, c_gpu, c_cpu = case
    ps = _ps(n, m, parts, seed)
    cap = CacheCapacity(c_gpu=[c_gpu] * parts, c_cpu=c_cpu)
    pad = exchange_capacity(ps, cap)
    rng = np.random.default_rng(seed)
    ref_shapes = None
    plans = [build_cache_plan(ps, cap, refresh_every=2)]
    for _ in range(3):
        loc, glob = _random_membership(ps, cap, rng)
        plans.append(plan_from_membership(ps, loc, glob, cap,
                                          refresh_every=2))
    for plan in plans:
        xplan = build_exchange_plan(ps, plan, pad_to=pad)
        shapes = tuple(a.shape for a in (
            xplan.uncached.send_row, xplan.uncached.recv_valid,
            xplan.uncached.peer_send_row, xplan.local.send_row,
            xplan.local.recv_valid, xplan.local.peer_send_row,
            xplan.glob.send_row, xplan.glob.src_part, xplan.glob.read_pos))
        if ref_shapes is None:
            ref_shapes = shapes
        assert shapes == ref_shapes     # slot stability: shapes are data-free
        _check_invariants(ps, plan, xplan)


@given(churn_case())
@settings(max_examples=15, deadline=None)
def test_padded_exchange_reconstructs_halo_exactly(case):
    """A capacity-padded, randomly re-ranked plan still reconstructs the
    exact halo feature matrix (padding rows never leak)."""
    import jax.numpy as jnp
    from repro.dist.capgnn_sim import (_build_global, _glob_dict, _pull,
                                       _read_global, _scatter, _tier_dict)
    n, m, parts, seed, c_gpu, c_cpu = case
    ps = _ps(n, m, parts, seed)
    cap = CacheCapacity(c_gpu=[c_gpu] * parts, c_cpu=c_cpu)
    rng = np.random.default_rng(seed + 1)
    loc, glob_set = _random_membership(ps, cap, rng)
    plan = plan_from_membership(ps, loc, glob_set, cap, refresh_every=1)
    xplan = build_exchange_plan(ps, plan, pad_to=exchange_capacity(ps, cap))

    d = 3
    feats = rng.normal(size=(ps.graph.num_nodes, d)).astype(np.float32)
    ni = max(pt.n_inner for pt in ps.parts)
    nh = max(max(pt.n_halo for pt in ps.parts), 1)
    h = np.zeros((parts, ni, d), np.float32)
    for i, pt in enumerate(ps.parts):
        h[i, :pt.n_inner] = feats[pt.inner_nodes]
    hj = jnp.asarray(h)
    un = _tier_dict(xplan.uncached)
    loc_d = _tier_dict(xplan.local)
    gl = _glob_dict(xplan.glob)
    halo = jnp.zeros((parts, nh, d))
    halo = _scatter(halo, un["recv_halo_pos"], _pull(un, hj),
                    un["recv_valid"])
    halo = _scatter(halo, loc_d["recv_halo_pos"], _pull(loc_d, hj),
                    loc_d["recv_valid"])
    halo = _read_global(gl, _build_global(gl, hj), halo)
    halo = np.asarray(halo)
    for i, pt in enumerate(ps.parts):
        np.testing.assert_allclose(halo[i, :pt.n_halo],
                                   feats[pt.halo_nodes],
                                   rtol=1e-6, atol=1e-6)


# ------------------------------------------------- live eviction == simulator

@pytest.mark.parametrize("policy", ["fifo", "lru"])
@pytest.mark.parametrize("cap_frac", [0.3, 0.6, 0.9])
def test_live_eviction_matches_trace_simulator(policy, cap_frac):
    """Planner as a single shared cache (local tiers disabled) reproduces
    the trace simulator's hit sequence exactly on the epoch stream."""
    ps = _ps(80, 400, 3, seed=4)
    k = max(1, int(cap_frac * ps.halo_union().size))
    layers, epochs = 3, 4
    pl = AdaptivePlanner(ps, CacheCapacity(c_gpu=[0] * 3, c_cpu=k),
                         policy=policy)
    for _ in range(epochs):
        pl.observe_step(layers=layers)
    want = simulate_policy_hit_rate(ps, k, policy, layers=layers,
                                    epochs=epochs)
    assert pl.hit_rate() == pytest.approx(want, abs=1e-12)


def test_planner_replan_respects_capacities_and_partitions_halo():
    ps = _ps(80, 400, 3, seed=5)
    cap = CacheCapacity(c_gpu=[6, 3, 9], c_cpu=11)
    for policy in ("lru", "fifo", "drift", "overlap"):
        pl = AdaptivePlanner(ps, cap, policy=policy)
        for _ in range(3):
            pl.observe_step(layers=2)
        plan = pl.replan()
        for i, (w, part) in enumerate(zip(plan.workers, ps.parts)):
            assert w.local_pos.size <= cap.c_gpu[i]
            pos = np.concatenate([w.local_pos, w.global_pos,
                                  w.uncached_pos])
            assert np.array_equal(np.sort(pos), np.arange(part.n_halo))
        assert plan.global_gids.size <= cap.c_cpu
        # padded exchange plans share one shape signature
        xa, xb = pl.exchange_plan(plan), pl.exchange_plan(pl._initial)
        assert xa.uncached.recv_valid.shape == xb.uncached.recv_valid.shape
        assert xa.glob.src_part.shape == xb.glob.src_part.shape


def test_staleness_controller_replan_schedule():
    ctl = StalenessController(refresh_every=2, replan_every=2)
    picks = []
    for _ in range(9):
        picks.append((ctl.should_refresh(), ctl.should_replan()))
        ctl.observe()
    refreshes = [r for r, _ in picks]
    replans = [p for _, p in picks]
    assert refreshes == [True, False] * 4 + [True]
    assert replans[0] is False          # warm-up refresh never replans
    assert any(replans)
    # replans only at refresh boundaries, thinned 2x
    assert all(r for r, p in picks if p)
    assert sum(replans) == 2


# ---------------------------------------------- no-retrace + parity (sim)

def _task_setup(seed=6, parts=3):
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.graph import metis_partition, symmetric_normalize, synth_features
    g = rmat(200, 1000, seed=seed)
    feats, labels = synth_features(g, 8, 4, seed=seed)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=seed)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=4)
    ps = build_partition(gn, metis_partition(gn, parts, seed=seed), hops=1)
    return task, ps


def test_sim_adaptive_matches_static_and_never_retraces():
    """An adaptive run whose re-plans preserve membership (policy
    'overlap' on a static graph) is numerically the static runtime; the
    jitted steps compile exactly once across every re-plan event."""
    import jax
    from repro.core import PROFILES, cal_capacity
    from repro.dist import make_sim_runtime, stack_partitions, train_capgnn
    from repro.models.gnn import GNNConfig

    task, ps = _task_setup()
    cfg = GNNConfig(model="gcn", in_dim=8, hidden_dim=8, out_dim=4,
                    num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * 3,
                       m_cpu_gib=0.001)
    plan = build_cache_plan(ps, cap, refresh_every=2)
    sp = stack_partitions(ps, task)
    epochs, tau = 8, 2

    def run(adaptive: bool):
        from repro.optim import adam
        opt = adam(1e-2)
        planner = None
        if adaptive:
            planner = AdaptivePlanner(ps, cap, refresh_every=tau,
                                      policy="overlap")
            xp = planner.exchange_plan(plan)
        else:
            xp = build_exchange_plan(ps, plan)
        rt = make_sim_runtime(cfg, sp, xp, opt)
        ctl = StalenessController(refresh_every=tau)
        params, rep = train_capgnn(cfg, rt, xp, 3, opt, epochs=epochs,
                                   controller=ctl, pipeline=True,
                                   seed=0, planner=planner)
        return params, rep, rt

    p_static, rep_static, _ = run(False)
    p_adapt, rep_adapt, rt = run(True)
    assert rep_adapt.replan_events > 0
    # membership-preserving re-plans change nothing: exact loss trajectory
    np.testing.assert_allclose(rep_adapt.losses, rep_static.losses,
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(p_adapt), jax.tree.leaves(p_static)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    # byte accounting identical to the frozen plan's (same membership)
    assert rep_adapt.comm_bytes == rep_static.comm_bytes
    # no retraces: one compiled call per step flavour across all re-plans
    for name in ("refresh", "cached", "pipelined"):
        assert rt.jit_steps[name]._cache_size() <= 1, name


def test_sim_lru_replan_rows_exact_and_no_retrace():
    """Membership-churning LRU re-plans: plan-counted rows == valid-mask
    rows of the arrays each step actually consumed, across transitions."""
    import jax
    from repro.dist import (init_caches, make_sim_runtime, stack_partitions)
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import adam

    task, ps = _task_setup(seed=7)
    cfg = GNNConfig(model="gcn", in_dim=8, hidden_dim=8, out_dim=4,
                    num_layers=3)
    max_halo = max(pt.n_halo for pt in ps.parts)
    cap = CacheCapacity(c_gpu=[max(1, max_halo // 3)] * 3,
                        c_cpu=max(1, ps.halo_union().size // 4))
    planner = AdaptivePlanner(ps, cap, refresh_every=2, policy="lru")
    xp = planner.exchange_plan(planner.plan)
    opt = adam(1e-2)
    rt = make_sim_runtime(cfg, stack_partitions(ps, task), xp, opt)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    caches = init_caches(cfg, xp, 3)
    ctl = StalenessController(refresh_every=2)
    memberships = set()
    plan_rows = measured = 0
    for e in range(8):
        refresh = ctl.should_refresh()
        x_read = rt.xplan
        if ctl.should_replan():
            x_next = planner.exchange_plan(planner.replan())
            xr_arr = rt._state["xarr"]
            params, opt_state, caches, m = rt.step_transition(
                params, opt_state, caches, x_next)
            xe_arr = rt._state["xarr"]
            plan_rows += (x_read.uncached.n_rows + x_next.local.n_rows
                          + x_next.glob.n_unique)
            measured += (int(np.asarray(xr_arr["un"]["recv_valid"]).sum())
                         + int(np.asarray(xe_arr["loc"]["recv_valid"]).sum())
                         + int(np.asarray(xe_arr["gl"]["buf_valid"]).sum()))
        else:
            fn = rt.step_refresh if refresh else rt.step_cached
            params, opt_state, caches, m = fn(params, opt_state, caches)
            xa = rt._state["xarr"]
            plan_rows += x_read.uncached.n_rows
            measured += int(np.asarray(xa["un"]["recv_valid"]).sum())
            if refresh:
                plan_rows += x_read.local.n_rows + x_read.glob.n_unique
                measured += (int(np.asarray(xa["loc"]["recv_valid"]).sum())
                             + int(np.asarray(xa["gl"]["buf_valid"]).sum()))
        memberships.add(tuple(sorted(
            int(v) for w in planner.plan.workers for v in w.local_gids)))
        planner.observe_step(layers=2)
        ctl.observe(None, refreshed=refresh)
        assert np.isfinite(float(m["loss"]))
    assert plan_rows == measured
    assert len(memberships) >= 2        # the re-plans really changed tiers
    for name in ("refresh", "cached", "pipelined"):
        assert rt.jit_steps[name]._cache_size() <= 1, name


# --------------------------------------------------- SPMD subprocess parity

@pytest.mark.parametrize("transport", ["p2p", "allgather"])
def test_spmd_adaptive_parity_and_no_retrace(transport):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, _SCRIPT, "--transport", transport],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "OK" in res.stdout
