"""Hypothesis property tests on system invariants.

The central one: for ANY graph/partitioning/capacity, the exchange plan
reconstructs the exact halo feature matrix each worker needs — i.e. the
static communication plan is information-losslessly equivalent to a direct
gather from the global feature table.
"""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import cal_capacity, build_cache_plan, CacheCapacity
from repro.core.jaca import plan_hit_rate
from repro.dist import build_exchange_plan
from repro.dist.capgnn_sim import (_pull, _scatter, _build_global,
                                   _read_global, _tier_dict, _glob_dict)
from repro.graph import csr_from_edges, build_partition
from repro.graph.partition import random_partition
from repro.kernels.ops import ell_pack


@st.composite
def graph_and_parts(draw):
    n = draw(st.integers(8, 60))
    m = draw(st.integers(n, 5 * n))
    parts = draw(st.integers(2, 4))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    g = csr_from_edges(src[keep], dst[keep], n, dedup=True)
    assign = random_partition(g, parts, seed=seed)
    # ensure every part non-empty (stacked layout assumes it)
    for p in range(parts):
        assign[p % n] = p
    return g, build_partition(g, assign, hops=1)


@st.composite
def caps(draw):
    return (draw(st.integers(0, 30)), draw(st.integers(0, 30)))


@given(graph_and_parts(), caps())
@settings(max_examples=40, deadline=None)
def test_exchange_plan_reconstructs_halo_exactly(gp, cc):
    """scatter(pull) over all three tiers == direct feature gather."""
    g, ps = gp
    c_gpu, c_cpu = cc
    p = ps.num_parts
    plan = build_cache_plan(ps, CacheCapacity(c_gpu=[c_gpu] * p, c_cpu=c_cpu),
                            refresh_every=1)
    xplan = build_exchange_plan(ps, plan)

    d = 3
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(g.num_nodes, d)).astype(np.float32)
    ni = max(pt.n_inner for pt in ps.parts)
    nh = max(max(pt.n_halo for pt in ps.parts), 1)
    h = np.zeros((p, ni, d), np.float32)
    for i, pt in enumerate(ps.parts):
        h[i, :pt.n_inner] = feats[pt.inner_nodes]
    hj = jnp.asarray(h)

    un = _tier_dict(xplan.uncached)
    loc = _tier_dict(xplan.local)
    glob = _glob_dict(xplan.glob)
    halo = jnp.zeros((p, nh, d))
    halo = _scatter(halo, un["recv_halo_pos"], _pull(un, hj), un["recv_valid"])
    halo = _scatter(halo, loc["recv_halo_pos"], _pull(loc, hj), loc["recv_valid"])
    buf = _build_global(glob, hj)
    halo = _read_global(glob, buf, halo)
    halo = np.asarray(halo)
    for i, pt in enumerate(ps.parts):
        np.testing.assert_allclose(halo[i, :pt.n_halo], feats[pt.halo_nodes],
                                   rtol=1e-6, atol=1e-6)


@given(graph_and_parts(), caps())
@settings(max_examples=40, deadline=None)
def test_cache_plan_partitions_halo(gp, cc):
    """Tiers form an exact partition of each worker's halo positions, and
    row accounting matches."""
    g, ps = gp
    c_gpu, c_cpu = cc
    p = ps.num_parts
    plan = build_cache_plan(ps, CacheCapacity(c_gpu=[c_gpu] * p, c_cpu=c_cpu))
    for w, part in zip(plan.workers, ps.parts):
        pos = np.concatenate([w.local_pos, w.global_pos, w.uncached_pos])
        assert np.array_equal(np.sort(pos), np.arange(part.n_halo))
        # gid arrays are consistent with pos arrays
        assert np.array_equal(w.local_gids, part.halo_nodes[w.local_pos])
        assert np.array_equal(w.global_gids, part.halo_nodes[w.global_pos])
        assert np.array_equal(w.uncached_gids, part.halo_nodes[w.uncached_pos])
    hr = plan_hit_rate(plan)
    assert 0.0 <= hr["hit"] <= 1.0


@given(graph_and_parts())
@settings(max_examples=30, deadline=None)
def test_overlap_ratio_counts_memberships(gp):
    g, ps = gp
    r = ps.overlap_ratio()
    manual = np.zeros(g.num_nodes, dtype=int)
    for part in ps.parts:
        for v in part.halo_nodes:
            manual[v] += 1
    assert np.array_equal(r, manual)
    # a vertex is never halo of its own partition
    for part in ps.parts:
        assert not np.any(ps.assign[part.halo_nodes] == part.part_id)


@given(st.integers(2, 50), st.integers(2, 60), st.integers(1, 300),
       st.integers(0, 2 ** 16))
@settings(max_examples=40, deadline=None)
def test_ell_pack_preserves_edges(n_rows, n_cols, m, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_cols, m).astype(np.int32)
    dst = rng.integers(0, n_rows, m).astype(np.int32)
    w = rng.normal(size=m).astype(np.float32)
    w[w == 0] = 1.0
    cols, vals = ell_pack(src, dst, w, n_rows)
    # multiset of (dst, src, w) survives the packing
    got = sorted((r, int(c), float(v))
                 for r in range(n_rows)
                 for c, v in zip(cols[r], vals[r]) if v != 0)
    want = sorted((int(d_), int(s_), float(w_))
                  for s_, d_, w_ in zip(src, dst, w))
    assert got == want


@given(graph_and_parts())
@settings(max_examples=20, deadline=None)
def test_capacity_algorithm_bounds(gp):
    """Alg. 1 outputs are within [0, n_halo] / [0, |halo union|]."""
    from repro.core.device_profile import PROFILES
    g, ps = gp
    profiles = [PROFILES["rtx3090"]] * ps.num_parts
    cap = cal_capacity(ps, [8, 8], profiles, m_cpu_gib=0.5)
    for c, part in zip(cap.c_gpu, ps.parts):
        assert 0 <= c <= part.n_halo
    assert 0 <= cap.c_cpu <= len(ps.halo_union())
