"""Subprocess helper for test_transport: runs the SPMD CaPGNN runtime on 8
forced host devices with both halo transports and checks that

- ``transport="p2p"`` (per-peer packed ppermute ring) and
  ``transport="allgather"`` produce identical logits and gradients
  (gradients pinned through an sgd(1.0) step, whose update *is* the
  gradient — adam's scale-invariant first step cannot mask factor errors);
- both match the single-device stacked oracle;
- ``step_pipelined`` (double-buffered rings) matches ``step_cached``'s
  loss exactly and emits the same fresh cache rows as the non-deferred
  pipelined step;
- the p2p transport's originated wire rows equal the exchange plan's tier
  row counts exactly (no P x broadcast replication);
- the donated jitted steps emit no donation warnings.

Invoked as:  python tests/transport_parity_script.py
                 [--backend edges|ell|hybrid] [--multi-pod] [--bf16]
Exits non-zero on any mismatch.
"""
import os
import sys
import warnings

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402

TOL = 1e-5


def leafdiff(t1, t2):
    import jax.numpy as jnp
    diffs = [float(jnp.abs(a - b).max()) for a, b in
             zip(jax.tree.leaves(t1), jax.tree.leaves(t2)) if a.size]
    return max(diffs) if diffs else 0.0


def main():
    multi_pod = "--multi-pod" in sys.argv
    bf16 = "--bf16" in sys.argv
    backend = (sys.argv[sys.argv.index("--backend") + 1]
               if "--backend" in sys.argv else "edges")
    import jax.numpy as jnp
    from repro.core import PROFILES, build_cache_plan, cal_capacity
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.dist import (TrainSpec, build_exchange_plan, init_caches,
                            make_sim_runtime, stack_partitions)
    from repro.dist.capgnn_spmd import make_spmd_runtime
    from repro.graph import (build_partition, metis_partition, rmat,
                             symmetric_normalize, synth_features)
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import sgd

    parts = 4
    g = rmat(360, 2200, seed=3)
    feats, labels = synth_features(g, 12, 5, seed=3)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=3)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=5)
    ps = build_partition(gn, metis_partition(gn, parts, seed=3), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=12, hidden_dim=16, out_dim=5,
                    num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * parts)
    plan = build_cache_plan(ps, cap, refresh_every=2)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task, backend=backend)
    opt = sgd(1.0)   # update == -grad: parity below IS gradient parity
    halo_dtype = "bf16" if bf16 else "f32"
    # bf16 rounds both transports' payloads identically (forward logits
    # stay <= 1e-5), but backward cotangents ALSO round through the wire
    # cast, and the ring's transpose accumulates them in a different order
    # than the all-gather's -> gradient comparisons carry the bf16 ulp
    sim_tol = 5e-3 if bf16 else TOL
    grad_tol = 1e-3 if bf16 else TOL

    if multi_pod:
        mesh = jax.make_mesh((2, 2), ("pod", "data"))
        axis = ("pod", "data")
    else:
        mesh = jax.make_mesh((4,), ("data",))
        axis = "data"

    spec = TrainSpec(backend=backend, halo_dtype=halo_dtype, donate=False)
    sim = make_sim_runtime(cfg, sp, xplan, opt, spec=spec)
    rts = {t: make_spmd_runtime(cfg, sp, xplan, opt, mesh, axis=axis,
                                spec=spec.replace(transport=t))
           for t in ("allgather", "p2p")}
    params = init_gnn(jax.random.PRNGKey(7), cfg)

    # ---- measured wire rows: p2p originates exactly the plan's row counts
    assert xplan.uncached.n_peer_rows == xplan.uncached.n_rows
    assert xplan.local.n_peer_rows == xplan.local.n_rows
    rows = xplan.transport_rows("p2p", refresh=True)
    assert rows["uncached"] == xplan.uncached.n_rows
    assert rows["local"] == xplan.local.n_rows
    assert rows["global"] == xplan.glob.n_unique
    rows_ag = xplan.transport_rows("allgather", refresh=True)
    assert rows_ag["total"] > rows["total"], (rows_ag, rows)

    # ---- fresh-forward logits parity
    lf = {t: np.asarray(rt.forward_fresh(params), np.float32)
          for t, rt in rts.items()}
    np.testing.assert_allclose(lf["p2p"], lf["allgather"],
                               rtol=TOL, atol=TOL)
    np.testing.assert_allclose(lf["p2p"], np.asarray(sim.forward_fresh(params)),
                               rtol=sim_tol, atol=sim_tol)

    # ---- gradient parity: refresh step (local/global ring transposes),
    # then a cached step (uncached ring transpose + stale cache reads)
    state = {}
    for t, rt in rts.items():
        p1, o1, c1, m1 = rt.step_refresh(params, opt.init(params),
                                         init_caches(cfg, xplan, parts))
        state[t] = (p1, o1, c1, float(m1["loss"]))
    assert abs(state["p2p"][3] - state["allgather"][3]) < TOL
    assert leafdiff(state["p2p"][0], state["allgather"][0]) < grad_tol
    ps1, _, _, ms = sim.step_refresh(params, opt.init(params),
                                     init_caches(cfg, xplan, parts))
    assert abs(state["p2p"][3] - float(ms["loss"])) < sim_tol
    assert leafdiff(state["p2p"][0], ps1) < sim_tol

    cached = {}
    for t, rt in rts.items():
        p1, o1, c1, _ = state[t]
        p2, _, _, m2 = rt.step_cached(p1, o1, c1)
        cached[t] = (p2, float(m2["loss"]))
    assert abs(cached["p2p"][1] - cached["allgather"][1]) < TOL
    assert leafdiff(cached["p2p"][0], cached["allgather"][0]) < grad_tol

    # ---- pipelined: same loss as cached; fresh caches match the
    # non-deferred (allgather) pipelined step's
    pipe = {}
    for t, rt in rts.items():
        p1, o1, c1, _ = state[t]
        _, _, cP, mP = rt.step_pipelined(p1, o1, c1)
        pipe[t] = (cP, float(mP["loss"]))
    assert abs(pipe["p2p"][1] - cached["p2p"][1]) < 1e-6
    # each transport pipelines from its own post-refresh state, which has
    # already diverged by grad_tol under bf16
    assert leafdiff(pipe["p2p"][0], pipe["allgather"][0]) < sim_tol

    # ---- donation: chained donated steps run clean, no donation warnings
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rt_d = make_spmd_runtime(cfg, sp, xplan, opt, mesh, axis=axis,
                                 spec=spec.replace(transport="p2p",
                                                   donate=True))
        pp = jax.tree.map(jnp.copy, params)
        oo, cc = opt.init(pp), init_caches(cfg, xplan, parts)
        for i in range(3):
            fn = (rt_d.step_refresh, rt_d.step_cached, rt_d.step_pipelined)[i]
            pp, oo, cc, mm = fn(pp, oo, cc)
        jax.block_until_ready(mm["loss"])
        bad = [str(x.message) for x in w
               if "donat" in str(x.message).lower()]
        assert not bad, bad

    print(f"OK multi_pod={multi_pod} backend={backend} bf16={bf16} "
          f"loss_refresh={state['p2p'][3]:.5f} "
          f"loss_cached={cached['p2p'][1]:.5f} "
          f"p2p_rows={rows['total']} allgather_rows={rows_ag['total']}")


if __name__ == "__main__":
    main()
