"""Pluggable aggregation backends: edge-list vs Pallas blocked-ELL vs
hybrid ELL+COO through the Adjacency protocol, the stacked layout, and the
sim runtime.  (SPMD-side backend parity lives in test_spmd_runtime.py.)"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import PROFILES, build_cache_plan, cal_capacity
from repro.data.gnn_data import FullBatchTask, split_masks
from repro.dist import (build_exchange_plan, init_caches, make_sim_runtime,
                        stack_partitions, train_capgnn)
from repro.graph import (build_partition, metis_partition, rmat,
                        symmetric_normalize, synth_features)
from repro.models.gnn import (DenseAdj, EdgeListAdj, EllAdj, GNNConfig,
                              HybridAdj, gnn_forward, init_gnn,
                              make_local_adj)
from repro.optim import adam, sgd


def _task_and_parts(n=320, m=2000, parts=4, seed=2, feat=12, classes=5):
    g = rmat(n, m, seed=seed)
    feats, labels = synth_features(g, feat, classes, seed=seed)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=seed)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=classes)
    ps = build_partition(gn, metis_partition(gn, parts, seed=seed), hops=1)
    return task, ps


# ---------------------------------------------------------------- protocol

def test_local_adj_backends_agree():
    """spmm and degree() agree across all four make_local_adj backends."""
    task, ps = _task_and_parts()
    part = ps.parts[0]
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(part.n_local, 16)).astype(np.float32))
    adjs = {b: make_local_adj(part.local_graph, part.n_inner, backend=b)
            for b in ("edges", "dense", "ell", "hybrid")}
    ref = np.asarray(adjs["edges"].spmm(h))
    deg_ref = np.asarray(adjs["edges"].degree())
    for name, adj in adjs.items():
        np.testing.assert_allclose(np.asarray(adj.spmm(h)), ref,
                                   rtol=1e-5, atol=1e-5, err_msg=name)
        np.testing.assert_allclose(np.asarray(adj.degree()), deg_ref,
                                   rtol=1e-5, atol=1e-5, err_msg=name)


def test_make_local_adj_types_and_unknown_backend():
    task, ps = _task_and_parts()
    part = ps.parts[0]
    assert isinstance(make_local_adj(part.local_graph, part.n_inner,
                                     backend="ell"), EllAdj)
    assert isinstance(make_local_adj(part.local_graph, part.n_inner,
                                     backend="hybrid"), HybridAdj)
    with pytest.raises(ValueError, match="nope"):
        make_local_adj(part.local_graph, part.n_inner, backend="nope")


def test_spmm_at_capabilities():
    """EdgeListAdj/EllAdj support spmm_at; DenseAdj/HybridAdj raise a
    precise capability error naming the backend and the edges fallback."""
    task, ps = _task_and_parts()
    part = ps.parts[0]
    rng = np.random.default_rng(1)
    h = jnp.asarray(rng.normal(size=(part.n_local, 8)).astype(np.float32))

    edges = make_local_adj(part.local_graph, part.n_inner, backend="edges")
    ell = make_local_adj(part.local_graph, part.n_inner, backend="ell")
    # scaled per-edge values: spmm_at(2w) == 2 * spmm on both backends
    np.testing.assert_allclose(
        np.asarray(edges.spmm_at(2.0 * edges.weight, h)),
        2.0 * np.asarray(edges.spmm(h)), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ell.spmm_at(2.0 * ell.vals, h)),
        2.0 * np.asarray(ell.spmm(h)), rtol=1e-5, atol=1e-5)

    for backend, cls in (("dense", DenseAdj), ("hybrid", HybridAdj)):
        adj = make_local_adj(part.local_graph, part.n_inner, backend=backend)
        with pytest.raises(NotImplementedError) as ei:
            adj.spmm_at(jnp.ones(3), h)
        assert cls.__name__ in str(ei.value)
        assert "edges" in str(ei.value)


def test_gat_requires_edge_list_backend():
    task, ps = _task_and_parts()
    cfg = GNNConfig(model="gat", in_dim=task.features.shape[1],
                    hidden_dim=16, out_dim=task.num_classes, num_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    adj = make_local_adj(task.graph, task.graph.num_nodes, backend="ell")
    with pytest.raises(NotImplementedError, match="EllAdj"):
        gnn_forward(cfg, params, adj, jnp.asarray(task.features), None)


# ------------------------------------------------------- stacked pack

def test_stacked_ell_pack_layout():
    task, ps = _task_and_parts()
    sp_ell = stack_partitions(ps, task, backend="ell")
    sp_hyb = stack_partitions(ps, task, backend="hybrid")
    p, ni = sp_ell.num_parts, sp_ell.n_inner_max
    assert sp_ell.ell is not None and sp_ell.ell.backend == "ell"
    assert sp_ell.ell.cols.shape[:2] == (p, ni)
    assert sp_ell.ell.tail_width == 0
    # hybrid caps the regular width and spills overflow to the tail
    assert sp_hyb.ell.max_deg <= sp_ell.ell.max_deg
    # nnz conservation: ELL slots + tail entries == stacked edge count
    nnz_edges = int((sp_ell.e_w != 0).sum())
    assert int((sp_ell.ell.vals != 0).sum()) == nnz_edges
    assert (int((sp_hyb.ell.vals != 0).sum())
            + int((sp_hyb.ell.tail_w != 0).sum())) == nnz_edges
    # padded tail rows are routed to the dropped row NI
    pad = sp_hyb.ell.tail_w == 0
    assert np.all(sp_hyb.ell.tail_dst[pad] == ni)
    with pytest.raises(ValueError, match="nope"):
        stack_partitions(ps, task, backend="nope")


def test_runtime_rejects_mismatched_pack():
    task, ps = _task_and_parts()
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=16, out_dim=task.num_classes, num_layers=2)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * ps.num_parts)
    xplan = build_exchange_plan(ps, build_cache_plan(ps, cap, refresh_every=2))
    sp = stack_partitions(ps, task)                       # no pack
    with pytest.raises(ValueError, match="stack_partitions"):
        make_sim_runtime(cfg, sp, xplan, adam(1e-2), backend="ell")
    sp_ell = stack_partitions(ps, task, backend="ell")    # wrong pack kind
    with pytest.raises(ValueError, match="hybrid"):
        make_sim_runtime(cfg, sp_ell, xplan, adam(1e-2), backend="hybrid")


# ------------------------------------------------------- runtime parity

def _sim_fixture(model="gcn", refresh_every=2):
    task, ps = _task_and_parts()
    cfg = GNNConfig(model=model, in_dim=task.features.shape[1],
                    hidden_dim=16, out_dim=task.num_classes, num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * ps.num_parts)
    plan = build_cache_plan(ps, cap, refresh_every=refresh_every)
    xplan = build_exchange_plan(ps, plan)
    return task, ps, cfg, xplan


@pytest.mark.parametrize("backend", ["ell", "hybrid"])
@pytest.mark.parametrize("model", ["gcn", "sage", "gin"])
def test_sim_runtime_backend_parity(model, backend):
    """Stacked runtime logits match the edges backend to ~1e-5, and a full
    refresh step produces identical loss and near-identical parameters."""
    task, ps, cfg, xplan = _sim_fixture(model=model)
    opt = sgd(1e-2)
    params = init_gnn(jax.random.PRNGKey(3), cfg)

    # donate=False: both runtimes step from the same params pytree
    rt_e = make_sim_runtime(cfg, stack_partitions(ps, task), xplan, opt,
                            donate=False)
    rt_b = make_sim_runtime(cfg, stack_partitions(ps, task, backend=backend),
                            xplan, opt, backend=backend, donate=False)
    le = np.asarray(rt_e.forward_fresh(params))
    lb = np.asarray(rt_b.forward_fresh(params))
    np.testing.assert_allclose(lb, le, rtol=1e-5, atol=1e-5)

    o1, o2 = opt.init(params), opt.init(params)
    c1 = init_caches(cfg, xplan, ps.num_parts)
    c2 = init_caches(cfg, xplan, ps.num_parts)
    p1, _, _, m1 = rt_e.step_refresh(params, o1, c1)
    p2, _, _, m2 = rt_b.step_refresh(params, o2, c2)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", ["ell", "hybrid"])
def test_train_capgnn_backend_comm_bytes_identical(backend):
    """Swapping the aggregation backend must not change the exchange byte
    accounting — communication is a plan property, not a kernel property."""
    task, ps, cfg, xplan = _sim_fixture()
    opt = adam(1e-2)
    rt_e = make_sim_runtime(cfg, stack_partitions(ps, task), xplan, opt)
    rt_b = make_sim_runtime(cfg, stack_partitions(ps, task, backend=backend),
                            xplan, opt, backend=backend)
    _, rep_e = train_capgnn(cfg, rt_e, xplan, ps.num_parts, opt, epochs=6)
    _, rep_b = train_capgnn(cfg, rt_b, xplan, ps.num_parts, opt, epochs=6)
    assert rep_b.comm_bytes == rep_e.comm_bytes
    assert rep_b.comm_bytes_vanilla == rep_e.comm_bytes_vanilla
    assert rep_b.refresh_steps == rep_e.refresh_steps
    np.testing.assert_allclose(rep_b.losses, rep_e.losses,
                               rtol=1e-4, atol=1e-4)
