"""TrainSpec: validation, serialisation round-trips, CLI construction,
and the CLI-args -> spec -> runtime -> TrainReport.spec provenance chain.
"""
import argparse
import dataclasses

import numpy as np
import pytest

from repro.dist import (TrainSpec, StrategyCapabilityError, get_strategy,
                        build_exchange_plan, stack_partitions,
                        make_sim_runtime, train_capgnn)


def test_defaults_valid_and_frozen():
    s = TrainSpec()
    assert s.strategy == "halo_1d" and s.replication == 1
    with pytest.raises(dataclasses.FrozenInstanceError):
        s.backend = "ell"
    assert s.replace(backend="ell").backend == "ell"


@pytest.mark.parametrize("kw", [
    {"backend": "csr"}, {"transport": "nccl"}, {"features": "disk"},
    {"halo_dtype": "fp8"}, {"cache_policy": "mru"},
    {"replication": 0}, {"refresh_every": 0}, {"prefetch_depth": 0},
])
def test_validation_rejects(kw):
    with pytest.raises(ValueError):
        TrainSpec(**kw)


def test_strategy_capability_validation():
    # halo_1d owns no replication axis
    with pytest.raises(StrategyCapabilityError):
        TrainSpec(replication=2)
    # these knobs are halo_1d machinery, denied under spmm_15d
    for kw in ({"pipeline": True}, {"features": "host"},
               {"cache_policy": "lru"}, {"refresh_every": 4},
               {"backend": "ell"}, {"faults": "fetch_drop:p=0.5"},
               {"guard_every": 5}, {"pallas_pack": True}):
        with pytest.raises(StrategyCapabilityError):
            TrainSpec(strategy="spmm_15d", replication=2, **kw)
    # ...but the exact subset is fine
    assert TrainSpec(strategy="spmm_15d", replication=2).replication == 2


def test_unknown_strategy_names_valid_options():
    with pytest.raises(ValueError, match="halo_1d, spmm_15d"):
        TrainSpec(strategy="2d")
    with pytest.raises(ValueError, match="halo_1d, spmm_15d"):
        get_strategy("spmm_2d")


def test_dict_round_trip():
    s = TrainSpec(backend="ell", transport="p2p", halo_dtype="bf16",
                  pipeline=True, refresh_every=4, cache_policy="lru",
                  faults="grad_nan:at=3", guard_every=2, seed=11)
    d = s.to_dict()
    assert d["transport"] == "p2p" and d["refresh_every"] == 4
    assert TrainSpec.from_dict(d) == s
    with pytest.raises(ValueError, match="unknown TrainSpec fields"):
        TrainSpec.from_dict({**d, "wire_dtype": "bf16"})


def test_from_cli_args():
    # launch.train-style flags; jaca=True means exchange_layer0=False
    ns = argparse.Namespace(backend="hybrid", halo_dtype="bf16",
                            features="host", jaca=True, pipeline=True,
                            refresh_every=6, cache_policy="drift",
                            replan_every=2, cpu_cache_gib=1.5,
                            faults="fetch_drop:p=0.2", guard_every=3,
                            seed=9)
    s = TrainSpec.from_cli_args(ns)
    assert (s.backend, s.halo_dtype, s.features) == ("hybrid", "bf16",
                                                     "host")
    assert s.exchange_layer0 is False and s.pipeline and s.refresh_every == 6
    assert s.cache_policy == "drift" and s.cpu_cache_gib == 1.5
    # missing attributes fall back to the CLI defaults
    s2 = TrainSpec.from_cli_args(argparse.Namespace())
    assert s2 == TrainSpec(exchange_layer0=False)
    # spmm_15d normalises the halo-only staleness defaults away instead
    # of tripping capability validation on the CLI's refresh_every=4
    s3 = TrainSpec.from_cli_args(argparse.Namespace(
        strategy="spmm_15d", replication=2, refresh_every=4,
        pipeline=True, jaca=False))
    assert s3.strategy == "spmm_15d" and s3.refresh_every == 1
    assert not s3.pipeline and s3.exchange_layer0


def _tiny_problem(parts=2):
    from repro.core import PROFILES, build_cache_plan, cal_capacity
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.graph import (build_partition, metis_partition, rmat,
                             symmetric_normalize, synth_features)
    from repro.models.gnn import GNNConfig

    g = rmat(120, 480, seed=5)
    feats, labels = synth_features(g, 6, 3, seed=5)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=5)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=3)
    ps = build_partition(gn, metis_partition(gn, parts, seed=5), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=6, hidden_dim=8, out_dim=3,
                    num_layers=2)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * parts)
    plan = build_cache_plan(ps, cap, refresh_every=2)
    return ps, task, cfg, plan


def test_spec_round_trip_into_report():
    """CLI args -> TrainSpec -> runtime -> TrainReport.spec: every run
    records the exact configuration that produced it."""
    from repro.optim import adam

    ps, task, cfg, plan = _tiny_problem()
    ns = argparse.Namespace(refresh_every=2, seed=3, jaca=False)
    spec = TrainSpec.from_cli_args(ns)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(1e-2)
    rt = make_sim_runtime(cfg, sp, xplan, opt, spec=spec)
    assert rt.spec is spec
    _, report = train_capgnn(cfg, rt, xplan, ps.num_parts, opt, epochs=3,
                             spec=spec)
    assert report.spec == spec.to_dict()
    assert TrainSpec.from_dict(report.spec) == spec
    assert report.spec["seed"] == 3 and report.spec["refresh_every"] == 2
    assert np.isfinite(report.losses).all()


def test_loose_kwargs_deprecated_but_equivalent():
    """The legacy loose-kwarg constructors warn once and synthesise the
    same spec the explicit path passes — bit-identical training."""
    from repro.optim import adam

    ps, task, cfg, plan = _tiny_problem()
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(1e-2)
    with pytest.warns(DeprecationWarning, match="make_sim_runtime"):
        rt_old = make_sim_runtime(cfg, sp, xplan, opt, halo_dtype="bf16")
    spec = TrainSpec(halo_dtype="bf16")
    rt_new = make_sim_runtime(cfg, sp, xplan, opt, spec=spec)
    assert rt_old.spec == spec == rt_new.spec
    with pytest.warns(DeprecationWarning, match="train_capgnn"):
        _, rep_old = train_capgnn(cfg, rt_old, xplan, ps.num_parts, opt,
                                  epochs=4, seed=1)
    _, rep_new = train_capgnn(cfg, rt_new, xplan, ps.num_parts, opt,
                              epochs=4, spec=spec.replace(seed=1))
    assert rep_old.losses == rep_new.losses      # bit-identical
    assert rep_old.comm_bytes == rep_new.comm_bytes
    assert rep_old.spec == rep_new.spec
