"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as its REDUCED family-preserving
variant (<=2 layers, d_model<=512, <=4 experts) and runs one forward and one
train step on CPU, asserting output shapes and finiteness; decode-capable
archs additionally run a one-token serve_step against a KV cache.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced, canonical
from repro.models.transformer import (init_model, forward, loss_fn,
                                      train_step_fn, init_decode_cache,
                                      serve_step, param_count)
from repro.optim import adam

B, S = 2, 32


def _batch(cfg, rng):
    s_text = S
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_text)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_text)),
                              jnp.int32),
    }
    if cfg.vision_tokens:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    rng = np.random.default_rng(0)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    s_total = S + (cfg.vision_tokens or 0)
    assert logits.shape == (B, s_total, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    rng = np.random.default_rng(1)
    params = init_model(jax.random.PRNGKey(1), cfg)
    opt = adam(1e-3)
    opt_state = opt.init(params)
    step = jax.jit(train_step_fn(cfg, opt))
    batch = _batch(cfg, rng)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    loss1 = float(metrics["loss"])
    assert np.isfinite(loss1)
    # params actually moved
    moved = any(not np.allclose(np.asarray(a, np.float32),
                                np.asarray(b, np.float32))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert moved
    # a second step on the same batch reduces loss (sanity, not strict)
    _, _, metrics2 = step(params2, opt_state2, batch)
    assert float(metrics2["loss"]) < loss1 + 0.5


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = get_reduced(arch)
    rng = np.random.default_rng(2)
    params = init_model(jax.random.PRNGKey(2), cfg)
    caches = init_decode_cache(cfg, batch=B, max_len=64)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    step = jax.jit(lambda p, c, t, pos: serve_step(cfg, p, c, t, pos))
    logits, caches = step(params, caches, tok, jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # decode a few more tokens; cache state must keep logits finite
    for pos in range(1, 4):
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32) % cfg.vocab_size
        logits, caches = step(params, caches, tok, jnp.asarray(pos, jnp.int32))
        assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    """Token-by-token decode logits == full-sequence forward logits."""
    cfg = get_reduced(arch)
    if cfg.vision_tokens:
        pytest.skip("VLM prefix handled in prefill path only")
    rng = np.random.default_rng(3)
    params = init_model(jax.random.PRNGKey(3), cfg)
    T = 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, T)), jnp.int32)
    full_logits, _ = forward(cfg, params, {"tokens": toks})
    caches = init_decode_cache(cfg, batch=1, max_len=T)
    step = jax.jit(lambda p, c, t, pos: serve_step(cfg, p, c, t, pos))
    outs = []
    for t in range(T):
        lg, caches = step(params, caches, toks[:, t:t + 1],
                          jnp.asarray(t, jnp.int32))
        outs.append(np.asarray(lg, np.float32)[0])
    dec = np.stack(outs)
    ful = np.asarray(full_logits, np.float32)[0]
    # bf16 models accumulate small divergence; compare top-1 agreement and
    # a loose numeric tolerance
    top_full = ful.argmax(-1)
    top_dec = dec.argmax(-1)
    agree = (top_full == top_dec).mean()
    assert agree >= 0.75, (arch, agree)
    np.testing.assert_allclose(dec, ful, rtol=0.12, atol=0.12)


def test_full_configs_match_brief():
    """The FULL configs carry the exact published numbers from the brief."""
    expect = {
        "qwen3-14b": dict(num_layers=40, d_model=5120, n_heads=40,
                          n_kv_heads=8, d_ff=17408, vocab_size=151936),
        "qwen2-1.5b": dict(num_layers=28, d_model=1536, n_heads=12,
                           n_kv_heads=2, d_ff=8960, vocab_size=151936),
        "xlstm-350m": dict(num_layers=24, d_model=1024, n_heads=4,
                           vocab_size=50304),
        "musicgen-large": dict(num_layers=48, d_model=2048, n_heads=32,
                               d_ff=8192, vocab_size=2048),
        "qwen3-1.7b": dict(num_layers=28, d_model=2048, n_heads=16,
                           n_kv_heads=8, d_ff=6144, vocab_size=151936),
        "phi-3-vision-4.2b": dict(num_layers=32, d_model=3072, n_heads=32,
                                  d_ff=8192, vocab_size=32064),
        "mixtral-8x7b": dict(num_layers=32, d_model=4096, n_heads=32,
                             n_kv_heads=8, vocab_size=32000, n_experts=8,
                             top_k=2),
        "deepseek-v3-671b": dict(num_layers=61, d_model=7168, n_heads=128,
                                 vocab_size=129280, n_experts=256, top_k=8,
                                 moe_d_ff=2048, use_mla=True),
        "hymba-1.5b": dict(num_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab_size=32001,
                           ssm_state=16),
        "codeqwen1.5-7b": dict(num_layers=32, d_model=4096, n_heads=32,
                               d_ff=13440, vocab_size=92416),
    }
    for name, fields in expect.items():
        cfg = get_config(name)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)
        assert cfg.source


def test_qwen3_features():
    cfg = get_config("qwen3-14b")
    assert cfg.qk_norm
    cfg2 = get_config("qwen2-1.5b")
    assert cfg2.qkv_bias


def test_param_counts_plausible():
    """Shape-evaluated parameter counts sit near the published sizes."""
    approx = {"qwen2-1.5b": 1.5e9, "qwen3-1.7b": 1.7e9, "xlstm-350m": 0.35e9,
              "hymba-1.5b": 1.5e9, "codeqwen1.5-7b": 7e9,
              "mixtral-8x7b": 47e9, "deepseek-v3-671b": 671e9}
    for name, n in approx.items():
        cfg = get_config(name)
        got = param_count(cfg)
        assert 0.5 * n < got < 1.9 * n, (name, got, n)


def test_analytic_param_count_close_to_exact():
    for name in ("qwen2-1.5b", "mixtral-8x7b", "xlstm-350m"):
        cfg = get_config(name)
        exact = param_count(cfg)
        analytic = cfg.param_count()
        assert abs(analytic - exact) / exact < 0.15, (name, analytic, exact)


def test_moe_active_params_less_than_total():
    for name in ("mixtral-8x7b", "deepseek-v3-671b"):
        cfg = get_config(name)
        assert cfg.active_param_count() < cfg.param_count()
