"""Strategy objects for the vendored hypothesis stand-in (see __init__)."""
from __future__ import annotations

import hashlib

import numpy as np

__all__ = ["integers", "composite", "SearchStrategy"]


def _rng_for_example(test_name: str, index: int) -> np.random.Generator:
    """Deterministic per-(test, example) stream, stable across runs."""
    h = hashlib.sha256(f"{test_name}:{index}".encode()).digest()
    return np.random.default_rng(int.from_bytes(h[:8], "little"))


class SearchStrategy:
    def sample(self, rng: np.random.Generator):
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int):
        self.min_value = min_value
        self.max_value = max_value

    def sample(self, rng):
        return int(rng.integers(self.min_value, self.max_value + 1))


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return _Integers(min_value, max_value)


class _Composite(SearchStrategy):
    def __init__(self, fn, args, kwargs):
        self.fn = fn
        self.args = args
        self.kwargs = kwargs

    def sample(self, rng):
        def draw(strategy: SearchStrategy):
            return strategy.sample(rng)
        return self.fn(draw, *self.args, **self.kwargs)


def composite(fn):
    def make(*args, **kwargs):
        return _Composite(fn, args, kwargs)
    return make
