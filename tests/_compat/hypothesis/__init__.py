"""Minimal stand-in for the `hypothesis` API used by this test suite.

Loaded by ``tests/conftest.py`` ONLY when the real hypothesis package is not
installed (this path is appended to ``sys.path`` behind an import check, so
a real installation always wins).  It implements just what the suite needs —
``given``, ``settings``, ``strategies.integers`` and
``strategies.composite`` — as deterministic seeded random sampling with no
shrinking.  On a failing example it re-raises the original assertion with
the example index noted.
"""
from __future__ import annotations

import functools

from . import strategies

__all__ = ["given", "settings", "strategies"]

_DEFAULT_MAX_EXAMPLES = 25


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Records ``max_examples`` on the test function; other knobs ignored."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats, **kw_strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            for i in range(n):
                rng = strategies._rng_for_example(fn.__qualname__, i)
                vals = [s.sample(rng) for s in strats]
                kvals = {k: s.sample(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, *vals, **kwargs, **kvals)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__name__}: "
                        f"{e!r}") from e
        # Hide the wrapped signature: pytest must not mistake the strategy
        # parameters for fixtures.
        del wrapper.__wrapped__
        return wrapper
    return deco
