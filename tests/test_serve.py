"""repro.serve: precompute parity, the two-tier query engine, the
micro-batcher, and the workload generators.

The load-bearing claim (ISSUE acceptance): served logits equal the training
runtime's ``forward_fresh`` oracle to <=1e-5 for every aggregation backend,
on cached-tier hits and host-tier misses alike; the fresh=k recompute path
is exact against the single-worker full-graph forward when k >= num_layers.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import PROFILES, build_cache_plan, cal_capacity
from repro.data.gnn_data import FullBatchTask, split_masks
from repro.dist import build_exchange_plan, make_sim_runtime, stack_partitions
from repro.graph import (build_partition, metis_partition, rmat,
                         symmetric_normalize, synth_features)
from repro.models.gnn import GNNConfig, gnn_forward, init_gnn, make_local_adj
from repro.optim import adam
from repro.serve import (BatchConfig, GNNServeEngine, load_store,
                         make_stream, plan_batches, precompute_embeddings,
                         rank_hot_nodes, save_store, serve_stream,
                         zipf_stream, WORKLOAD_KINDS)

BACKENDS = ("edges", "ell", "hybrid")
_CACHE: dict = {}


def _base():
    """Shared tiny task/partitioning (backend-independent pieces)."""
    if "base" not in _CACHE:
        g = rmat(240, 1400, seed=3)
        feats, labels = synth_features(g, 8, 4, seed=3)
        gn = symmetric_normalize(g)
        tr, va, te = split_masks(g.num_nodes, seed=3)
        task = FullBatchTask(graph=gn, features=feats, labels=labels,
                             train_mask=tr, val_mask=va, test_mask=te,
                             num_classes=4)
        ps = build_partition(gn, metis_partition(gn, 3, seed=3), hops=1)
        cfg = GNNConfig(model="gcn", in_dim=8, hidden_dim=16, out_dim=4,
                        num_layers=3)
        params = init_gnn(jax.random.PRNGKey(0), cfg)
        cap = cal_capacity(ps, cfg.feat_dims,
                           [PROFILES["rtx3090"]] * ps.num_parts)
        xplan = build_exchange_plan(ps, build_cache_plan(ps, cap,
                                                         refresh_every=2))
        _CACHE["base"] = (task, ps, cfg, params, xplan)
    return _CACHE["base"]


def _bundle(backend):
    """Per-backend stacked layout, runtime oracle, and embedding store."""
    if backend not in _CACHE:
        task, ps, cfg, params, xplan = _base()
        sp = stack_partitions(ps, task, backend=backend)
        rt = make_sim_runtime(cfg, sp, xplan, adam(1e-2), backend=backend)
        store = precompute_embeddings(cfg, ps, sp, xplan, params,
                                      backend=backend)
        stacked = np.asarray(rt.forward_fresh(params))
        ref = np.zeros((task.graph.num_nodes, cfg.out_dim), np.float32)
        for i, part in enumerate(ps.parts):
            ref[part.inner_nodes] = stacked[i, : part.n_inner]
        _CACHE[backend] = (store, ref)
    return _CACHE[backend]


# ------------------------------------------------------------- precompute

@pytest.mark.parametrize("backend", BACKENDS)
def test_precompute_matches_forward_fresh(backend):
    """The final table is the training oracle's fresh logits, per backend."""
    _, _, _, _, _ = _base()
    store, ref = _bundle(backend)
    np.testing.assert_allclose(store.logits, ref, rtol=1e-5, atol=1e-5)


def test_store_roundtrip(tmp_path):
    store, _ = _bundle("edges")
    save_store(str(tmp_path), store)
    got = load_store(str(tmp_path))
    assert got.backend == store.backend
    assert got.cfg == store.cfg
    for a, b in zip(store.tables, got.tables):
        np.testing.assert_array_equal(a, b)
    with pytest.raises(FileNotFoundError):
        load_store(str(tmp_path / "empty"))


# ----------------------------------------------------------------- engine

@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_parity_hot_and_host(backend):
    """Tiered lookups == forward_fresh oracle, with both tiers exercised."""
    task, ps, cfg, params, _ = _base()
    store, ref = _bundle(backend)
    g = task.graph
    hot = rank_hot_nodes(g, 40, ps=ps, policy="degree")
    engine = GNNServeEngine(store, params, g, hot, features=task.features)
    q = np.arange(0, g.num_nodes, 3)
    out = engine.lookup(q)
    assert engine.stats["hot_hits"] > 0, "hot tier never hit"
    assert engine.stats["host_hits"] > 0, "host tier never hit"
    assert engine.stats["hot_hits"] + engine.stats["host_hits"] == q.size
    np.testing.assert_allclose(out, ref[q], rtol=1e-5, atol=1e-5)


def test_rank_hot_nodes_policies():
    task, ps, _, _, _ = _base()
    g = task.graph
    _, dst = g.edges()
    deg = np.bincount(dst, minlength=g.num_nodes)
    hot = rank_hot_nodes(g, 10, policy="degree")
    assert deg[hot].min() >= np.sort(deg)[-10]      # the top-degree nodes
    ov = rank_hot_nodes(g, 10, ps=ps, policy="overlap")
    assert ov.size == 10
    with pytest.raises(ValueError, match="PartitionSet"):
        rank_hot_nodes(g, 10, policy="overlap")
    with pytest.raises(ValueError, match="nope"):
        rank_hot_nodes(g, 10, policy="nope")


def test_fresh_recompute_is_exact():
    """fresh=num_layers recompute == full-graph forward on updated features;
    clean queries keep coming from the cache tiers."""
    task, ps, cfg, params, _ = _base()
    store, _ = _bundle("edges")
    g = task.graph
    engine = GNNServeEngine(store, params, g,
                            rank_hot_nodes(g, 40, policy="degree"),
                            features=task.features)
    upd = np.array([5, 77])
    newf = task.features.copy()
    newf[upd] += 1.5
    engine.update_features(upd, newf[upd])
    assert engine.stale[upd].all()

    q = np.arange(0, g.num_nodes, 3)
    out = engine.query(q)
    adj = make_local_adj(g, g.num_nodes, backend="edges")
    oracle = np.asarray(gnn_forward(cfg, params, adj, jnp.asarray(newf),
                                    None))
    np.testing.assert_allclose(out, oracle[q], rtol=1e-5, atol=1e-5)
    n_stale = int(engine.stale[q].sum())
    assert engine.stats["fresh_recomputes"] == n_stale
    assert engine.stats["hot_hits"] + engine.stats["host_hits"] \
        == q.size - n_stale


def test_stale_marking_is_forward_cone():
    """Only nodes reachable within num_layers forward hops go stale."""
    task, _, cfg, params, _ = _base()
    store, _ = _bundle("edges")
    g = task.graph
    engine = GNNServeEngine(store, params, g, np.zeros(0, np.int64),
                            features=task.features)
    upd = np.array([0])
    engine.update_features(upd, task.features[upd] + 1.0)
    src, dst = g.edges()
    seen = np.zeros(g.num_nodes, bool)
    seen[0] = True
    for _ in range(cfg.num_layers):
        seen[dst[seen[src]]] = True
    np.testing.assert_array_equal(engine.stale, seen)


def test_serve_stream_report():
    task, _, _, params, _ = _base()
    store, ref = _bundle("edges")
    g = task.graph
    engine = GNNServeEngine(store, params, g,
                            rank_hot_nodes(g, 40, policy="degree"),
                            features=task.features)
    stream = zipf_stream(g.num_nodes, 300, qps=3000.0, alpha=1.2, seed=0,
                         rank_to_node=rank_hot_nodes(g, g.num_nodes,
                                                     policy="degree"))
    rep = serve_stream(engine, stream, BatchConfig(max_batch=32,
                                                   deadline_ms=2.0))
    assert rep["queries"] == 300
    assert rep["qps"] > 0 and rep["busy_s"] > 0
    assert rep["p99_ms"] >= rep["p50_ms"] >= 0
    assert rep["hot_hit_rate"] + rep["host_hit_rate"] \
        + rep["fresh_rate"] == pytest.approx(1.0)
    assert rep["hot_hit_rate"] > 0.3   # zipf head aligned with the hot tier


# ----------------------------------------------------- micro-batcher props

@st.composite
def batcher_case(draw):
    n = draw(st.integers(1, 120))
    seed = draw(st.integers(0, 2 ** 16))
    max_batch = draw(st.integers(1, 12))
    deadline_ms = draw(st.integers(1, 40))
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(0.004, n))
    return times, BatchConfig(max_batch=max_batch,
                              deadline_ms=float(deadline_ms))


@given(batcher_case())
@settings(max_examples=60, deadline=None)
def test_microbatcher_invariants(case):
    """No query dropped or duplicated, order kept, size and deadline
    bounds respected, seal times monotone."""
    times, cfg = case
    batches = plan_batches(times, cfg)
    got = np.concatenate([b.idx for b in batches])
    np.testing.assert_array_equal(got, np.arange(times.size))
    prev_close = -np.inf
    for b in batches:
        assert 1 <= b.idx.size <= cfg.max_batch
        assert b.close_time - times[b.idx[0]] <= cfg.deadline_s + 1e-9
        assert b.close_time >= times[b.idx].max() - 1e-9
        assert b.close_time >= prev_close - 1e-9
        prev_close = b.close_time


def test_plan_batches_empty():
    assert plan_batches(np.zeros(0), BatchConfig()) == []


# --------------------------------------------------------- workload props

def test_streams_deterministic_and_valid():
    for kind in WORKLOAD_KINDS:
        a = make_stream(kind, 500, 300, qps=800.0, alpha=1.2, seed=7)
        b = make_stream(kind, 500, 300, qps=800.0, alpha=1.2, seed=7)
        np.testing.assert_array_equal(a.t, b.t)
        np.testing.assert_array_equal(a.node, b.node)
        assert a.kind == kind and a.num_queries == 300
        assert np.all(np.diff(a.t) >= 0) and a.t[0] >= 0
        assert a.node.min() >= 0 and a.node.max() < 500
    c = make_stream("zipf", 500, 300, qps=800.0, alpha=1.2, seed=7)
    d = make_stream("zipf", 500, 300, qps=800.0, alpha=1.2, seed=8)
    assert not np.array_equal(c.node, d.node)   # the seed actually matters
    with pytest.raises(ValueError, match="workload"):
        make_stream("nope", 10, 10)


@st.composite
def zipf_case(draw):
    n_nodes = draw(st.integers(50, 400))
    q = draw(st.integers(100, 400))
    seed = draw(st.integers(0, 2 ** 16))
    lo_q = draw(st.integers(0, 8))
    d_q = draw(st.integers(1, 8))
    a_lo = 0.25 + lo_q * 0.25
    return n_nodes, q, seed, a_lo, a_lo + d_q * 0.25


@given(zipf_case())
@settings(max_examples=25, deadline=None)
def test_zipf_skew_monotone_in_alpha(case):
    """Inverse-CDF sampling: under a fixed seed, raising the exponent never
    raises any sampled rank, so head concentration is monotone."""
    n, q, seed, a_lo, a_hi = case
    ident = np.arange(n)
    lo = zipf_stream(n, q, alpha=a_lo, seed=seed, rank_to_node=ident)
    hi = zipf_stream(n, q, alpha=a_hi, seed=seed, rank_to_node=ident)
    assert np.all(hi.node <= lo.node)            # pointwise, same uniforms
    m = max(1, n // 20)
    assert np.mean(hi.node < m) >= np.mean(lo.node < m)


def test_engine_rejects_out_of_range_ids():
    """Malformed query batches fail with a clean ValueError (not a numpy
    fancy-index surprise) and are counted in ``rejected_queries``; valid
    queries afterwards are unaffected."""
    task, ps, cfg, params, _ = _base()
    store, ref = _bundle("edges")
    g = task.graph
    hot = rank_hot_nodes(g, 40, ps=ps, policy="degree")
    engine = GNNServeEngine(store, params, g, hot, features=task.features)

    with pytest.raises(ValueError, match="out-of-range"):
        engine.lookup(np.array([0, -1, 3]))
    with pytest.raises(ValueError, match="out-of-range"):
        engine.query(np.array([g.num_nodes, 2, g.num_nodes + 7]))
    assert engine.stats["rejected_queries"] == 3
    with pytest.raises(ValueError, match="1-D"):
        engine.lookup(np.zeros((2, 2), np.int64))
    with pytest.raises(ValueError, match="integer"):
        engine.lookup(np.array([0.5, 1.0]))
    # nothing was served by the rejected batches
    assert engine.stats["queries"] == 0

    q = np.arange(0, g.num_nodes, 7)
    out = engine.lookup(q)
    np.testing.assert_allclose(out, ref[q], rtol=1e-5, atol=1e-5)
    assert engine.stats["queries"] == q.size
