"""Subprocess helper for test_obs: runs the SPMD CaPGNN runtime on 4
forced host devices under an enabled ``repro.obs.Tracer`` and checks that

- the traced per-step counter totals equal ``TrainReport.comm_bytes`` /
  ``comm_bytes_vanilla`` / ``host_fetch_rows`` / ``host_fetch_bytes`` /
  ``host_writeback_bytes`` *exactly*, for the requested halo transport;
- every scheduled step kind got a depth-0 span, spans nest strictly, and
  the exported Chrome trace validates against the trace_event schema.

Invoked as:  python tests/obs_trace_script.py
                 [--transport allgather|p2p] [--features device|host]
Prints OK and exits zero on success.
"""
import json
import os
import sys
import tempfile

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402


def main():
    transport = (sys.argv[sys.argv.index("--transport") + 1]
                 if "--transport" in sys.argv else "allgather")
    features = (sys.argv[sys.argv.index("--features") + 1]
                if "--features" in sys.argv else "device")
    jax.devices()           # lock the forced host device count first
    from repro.core import (CacheCapacity, StalenessController,
                            build_cache_plan)
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.dist import (TrainSpec, build_exchange_plan,
                            stack_partitions, train_capgnn)
    from repro.dist.capgnn_spmd import make_spmd_runtime
    from repro.graph import (build_partition, metis_partition, rmat,
                             symmetric_normalize, synth_features)
    from repro.models.gnn import GNNConfig
    from repro.obs import SPAN_KINDS, Tracer, validate_chrome_trace
    from repro.optim import adam

    parts = 4
    g = rmat(240, 1400, seed=7)
    feats, labels = synth_features(g, 8, 4, seed=7)
    gn = symmetric_normalize(g)
    trm, va, te = split_masks(g.num_nodes, seed=7)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=trm, val_mask=va, test_mask=te,
                         num_classes=4)
    ps = build_partition(gn, metis_partition(gn, parts, seed=7), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=8, hidden_dim=8, out_dim=4,
                    num_layers=3)
    # all three tiers non-empty so refresh/cached/host traffic all flow
    max_halo = max(pt.n_halo for pt in ps.parts)
    cap = CacheCapacity(c_gpu=[max(1, max_halo // 3)] * parts,
                        c_cpu=max(1, max_halo))
    plan = build_cache_plan(ps, cap, refresh_every=2)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(1e-2)
    mesh = jax.make_mesh((parts,), ("data",))
    spec = TrainSpec(transport=transport, features=features,
                     refresh_every=2, pipeline=True)
    rt = make_spmd_runtime(cfg, sp, xplan, opt, mesh, spec=spec)

    epochs = 6
    tr = Tracer()
    ctl = StalenessController(refresh_every=2)
    _, rep = train_capgnn(cfg, rt, xplan, parts, opt, epochs=epochs,
                          controller=ctl, spec=spec, eval_every=0,
                          tracer=tr)

    tot = tr.totals()
    assert tot["steps"] == epochs, (tot["steps"], epochs)
    assert tot["wire_bytes"] == rep.comm_bytes, \
        (transport, tot["wire_bytes"], rep.comm_bytes)
    assert tot["wire_bytes_vanilla"] == rep.comm_bytes_vanilla
    assert tot["host_fetch_rows"] == rep.host_fetch_rows, \
        (transport, features, tot["host_fetch_rows"], rep.host_fetch_rows)
    assert tot["host_fetch_bytes"] == rep.host_fetch_bytes
    assert tot["host_writeback_bytes"] == rep.host_writeback_bytes
    if features == "host":
        assert rep.host_fetch_rows > 0, "host mode staged nothing"

    # schedule refresh_every=2 over 6 epochs: refresh @0, pipelined @2,4
    kinds = [c.kind for c in tr.counters]
    assert kinds[0] == "refresh" and "pipelined" in kinds \
        and "cached" in kinds, kinds
    depth0 = [s for s in tr.spans if s.depth == 0]
    assert [s.kind for s in depth0 if s.kind != "eval"] == kinds
    assert all(s.kind in SPAN_KINDS or s.kind in ("h2d_put",)
               for s in tr.spans), {s.kind for s in tr.spans}
    assert rep.compile_s > 0 and rep.phase_stats

    with tempfile.TemporaryDirectory() as d:
        paths = tr.export(d, prefix="spmd")
        with open(paths["trace"]) as f:
            stats = validate_chrome_trace(json.load(f))
    for k in ("refresh", "pipelined", "cached"):
        assert stats["spans_by_cat"].get(k, 0) > 0, stats["spans_by_cat"]
    assert stats["n_counters"] > 0
    print(f"OK transport={transport} features={features} "
          f"wire_bytes={rep.comm_bytes} host_rows={rep.host_fetch_rows}")


if __name__ == "__main__":
    main()
