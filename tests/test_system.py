"""End-to-end behaviour of the CaPGNN system (paper §4-§5).

The key correctness claim: the partition-parallel runtime with a fully
synchronous schedule (every step is a refresh step) computes *exactly* the
same logits/gradients as single-worker full-graph training.  Caching/staleness
then trades bounded error for communication, which we also verify.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (cal_capacity, build_cache_plan, CacheCapacity,
                        do_partition, RapaConfig, PROFILES, make_group,
                        StalenessController)
from repro.dist import (build_exchange_plan, stack_partitions,
                        make_sim_runtime, train_capgnn, init_caches)
from repro.graph import metis_partition, build_partition, symmetric_normalize, rmat
from repro.models.gnn import GNNConfig, init_gnn, gnn_forward, make_local_adj
from repro.optim import adam, sgd


def _small_task(n=400, m=2400, parts=4, seed=0, feat=16, classes=5):
    g = rmat(n, m, seed=seed)
    from repro.graph import synth_features
    feats, labels = synth_features(g, feat, classes, seed=seed)
    gn = symmetric_normalize(g)
    from repro.data.gnn_data import FullBatchTask, split_masks
    tr, va, te = split_masks(g.num_nodes, seed=seed)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=classes)
    assign = metis_partition(gn, parts, seed=seed)
    ps = build_partition(gn, assign, hops=1)
    return task, ps


def _full_graph_logits(cfg, params, task):
    """Single-worker reference: whole graph is 'inner', no halo."""
    adj = make_local_adj(task.graph, task.graph.num_nodes, backend="edges")
    return gnn_forward(cfg, params, adj, jnp.asarray(task.features), None)


@pytest.mark.parametrize("model", ["gcn", "sage", "gin"])
def test_partitioned_equals_fullgraph(model):
    """Refresh-every-step partitioned forward == full-graph forward."""
    task, ps = _small_task()
    cfg = GNNConfig(model=model, in_dim=task.features.shape[1],
                    hidden_dim=32, out_dim=task.num_classes, num_layers=3)
    params = init_gnn(jax.random.PRNGKey(0), cfg)

    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * ps.num_parts)
    plan = build_cache_plan(ps, cap, refresh_every=1)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    rt = make_sim_runtime(cfg, sp, xplan, adam(1e-2))

    logits_p = np.asarray(rt.forward_fresh(params))   # [P, NI, C]
    logits_f = np.asarray(_full_graph_logits(cfg, params, task))
    for i, part in enumerate(ps.parts):
        np.testing.assert_allclose(logits_p[i, :part.n_inner],
                                   logits_f[part.inner_nodes],
                                   rtol=2e-4, atol=2e-4)


def test_cache_tiering_is_exhaustive_and_disjoint():
    task, ps = _small_task()
    cap = CacheCapacity(c_gpu=[10] * ps.num_parts, c_cpu=25)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    for w, part in zip(plan.workers, ps.parts):
        pos = np.concatenate([w.local_pos, w.global_pos, w.uncached_pos])
        assert np.array_equal(np.sort(pos), np.arange(part.n_halo))
        assert w.local_pos.size <= 10


def test_training_converges_and_saves_communication():
    task, ps = _small_task()
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=32, out_dim=task.num_classes, num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * ps.num_parts,
                       m_cpu_gib=1.0)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    rt = make_sim_runtime(cfg, sp, xplan, adam(1e-2))
    params, rep = train_capgnn(cfg, rt, xplan, ps.num_parts, adam(1e-2),
                               epochs=40, eval_every=20,
                               controller=StalenessController(refresh_every=4))
    assert rep.losses[-1] < rep.losses[0] * 0.7
    # caching must reduce bytes vs vanilla (all-halo-every-step)
    assert rep.comm_bytes < rep.comm_bytes_vanilla
    assert rep.comm_reduction > 0.0
    assert rep.refresh_steps == 10
    # accuracy sanity: better than chance on the homophilous synthetic task
    _, acc = rt.evaluate(params, "val")
    assert acc > 1.5 / task.num_classes


def test_stale_steps_bounded_deviation():
    """Cached-step loss deviates from a fresh step's by a bounded amount
    (Lemma 2's epsilon_H-driven bound, qualitatively)."""
    task, ps = _small_task()
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=32, out_dim=task.num_classes, num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * ps.num_parts)
    plan = build_cache_plan(ps, cap, refresh_every=2)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = sgd(1e-3)
    # donate=False: this test deliberately re-runs two step flavours from
    # the same (params, opt_state, caches), which donation would consume
    rt = make_sim_runtime(cfg, sp, xplan, opt, donate=False)

    params = init_gnn(jax.random.PRNGKey(1), cfg)
    opt_state = opt.init(params)
    caches = init_caches(cfg, xplan, ps.num_parts)
    # one refresh step -> caches hold step-0 embeddings
    params, opt_state, caches, m0 = rt.step_refresh(params, opt_state, caches)
    # one cached step: loss must stay finite and close to a fresh step's
    p_stale, _, _, m_stale = rt.step_cached(params, opt_state, caches)
    p_fresh, _, _, m_fresh = rt.step_refresh(params, opt_state, caches)
    assert np.isfinite(float(m_stale["loss"]))
    assert abs(float(m_stale["loss"]) - float(m_fresh["loss"])) < 0.5
    # with a tiny LR after one step, parameters should be near-identical
    for a, b in zip(jax.tree.leaves(p_stale), jax.tree.leaves(p_fresh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_rapa_plus_jaca_end_to_end():
    """Full CaPGNN composition: RAPA prune -> JACA plan -> train."""
    task, ps = _small_task(parts=4)
    profiles = make_group(["rtx3090", "rtx3090", "rtx3060", "gtx1660ti"])
    res = do_partition(ps, profiles, RapaConfig(feat_dim=16))
    ps2 = res.partition_set
    # RAPA never drops inner vertices
    for a, b in zip(ps.parts, ps2.parts):
        assert np.array_equal(a.inner_nodes, b.inner_nodes)
        assert b.n_halo <= a.n_halo
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=32, out_dim=task.num_classes, num_layers=2)
    cap = cal_capacity(ps2, cfg.feat_dims, profiles, m_cpu_gib=1.0)
    plan = build_cache_plan(ps2, cap, refresh_every=4)
    xplan = build_exchange_plan(ps2, plan)
    sp = stack_partitions(ps2, task)
    rt = make_sim_runtime(cfg, sp, xplan, adam(1e-2))
    params, rep = train_capgnn(cfg, rt, xplan, ps2.num_parts, adam(1e-2),
                               epochs=20, eval_every=0)
    assert np.isfinite(rep.losses[-1])
    assert rep.losses[-1] < rep.losses[0]


def test_pipelined_mode_matches_cached_numerics():
    """step_pipelined consumes the same stale tiers as step_cached; its loss
    must be identical — it only *additionally* emits fresh cache rows."""
    task, ps = _small_task()
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=32, out_dim=task.num_classes, num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * ps.num_parts)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = sgd(1e-2)
    # donate=False: cached and pipelined branch from the same state
    rt = make_sim_runtime(cfg, sp, xplan, opt, donate=False)
    params = init_gnn(jax.random.PRNGKey(2), cfg)
    opt_state = opt.init(params)
    caches = init_caches(cfg, xplan, ps.num_parts)
    params, opt_state, caches, _ = rt.step_refresh(params, opt_state, caches)
    _, _, cA, mA = rt.step_cached(params, opt_state, caches)
    _, _, cB, mB = rt.step_pipelined(params, opt_state, caches)
    assert float(mA["loss"]) == pytest.approx(float(mB["loss"]), rel=1e-6)
    # pipelined must have refreshed its cache tiers (different from stale)
    stale = np.asarray(cA["local"][0])
    fresh = np.asarray(cB["local"][0])
    assert not np.allclose(stale, fresh)


def test_comm_bytes_accounting_consistent():
    task, ps = _small_task()
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=32, out_dim=task.num_classes, num_layers=3)
    cap = CacheCapacity(c_gpu=[20] * ps.num_parts, c_cpu=40)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    xplan = build_exchange_plan(ps, plan)
    # tier row counts must add up to the total halo count
    total_halo = ps.total_halo()
    assert (xplan.uncached.n_rows + xplan.local.n_rows
            + int(xplan.glob.read_valid.sum())) == total_halo
    d = cfg.hidden_dim
    b_ref = xplan.bytes_per_step(d, refresh=True)
    b_cac = xplan.bytes_per_step(d, refresh=False)
    assert b_cac < b_ref
    # dedup saving: refresh moves one row per unique global vertex, not per
    # consumer replica
    n_global_reads = int(xplan.glob.read_valid.sum())
    assert xplan.glob.n_unique <= n_global_reads


def test_zero_capacity_plan_is_vanilla():
    """c=0 everywhere -> everything uncached -> bytes equal vanilla."""
    task, ps = _small_task()
    plan = build_cache_plan(ps, CacheCapacity(c_gpu=[0] * ps.num_parts,
                                              c_cpu=0), refresh_every=1)
    xplan = build_exchange_plan(ps, plan)
    assert xplan.local.n_rows == 0
    assert xplan.glob.n_unique == 0
    assert xplan.uncached.n_rows == ps.total_halo()


def test_train_resume_roundtrip(tmp_path):
    """launch.train gnn --resume: two 4-epoch runs through a checkpoint
    reproduce one straight 8-epoch run exactly (params, opt state and the
    refresh schedule all round-trip; pipeline off so the refresh-step
    numerics are schedule-independent)."""
    import argparse
    from repro.checkpoint import latest_step
    from repro.launch.train import run_gnn

    base = dict(dataset="flickr", scale=0.008, feat_dim=16, model="gcn",
                backend="edges", hidden=16, layers=2, parts=2,
                partitioner="metis", epochs=8, lr=0.01, jaca=True,
                rapa=False, pipeline=False, refresh_every=4,
                adaptive_staleness=False, cpu_cache_gib=1.0, seed=0,
                ckpt_dir="", resume=False)
    straight = run_gnn(argparse.Namespace(**base))

    d = str(tmp_path / "ck")
    first = run_gnn(argparse.Namespace(**{**base, "epochs": 4,
                                          "ckpt_dir": d}))
    assert first["resumed_from"] == 0
    assert latest_step(d) == 4
    second = run_gnn(argparse.Namespace(**{**base, "ckpt_dir": d,
                                           "resume": True}))
    assert second["resumed_from"] == 4
    assert latest_step(d) == 8
    np.testing.assert_allclose(second["final_loss"], straight["final_loss"],
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(second["test_acc"], straight["test_acc"],
                               rtol=1e-6, atol=1e-7)
    # resuming past the budget is a no-op that keeps the checkpoint intact
    third = run_gnn(argparse.Namespace(**{**base, "ckpt_dir": d,
                                          "resume": True}))
    assert third["resumed_from"] == 8 and third["final_loss"] is None
    assert latest_step(d) == 8


def test_kill_and_resume_parity(tmp_path):
    """Simulated mid-run crash: a later checkpoint left truncated mid-write
    (plus a stray .tmp from the interrupted save) must not derail
    ``--resume`` — ``latest_step`` skips the damaged entry, resumes from
    the newest *valid* checkpoint, and the completed run matches the
    uninterrupted one exactly."""
    import argparse
    import shutil
    import warnings
    from repro.checkpoint import latest_step
    from repro.faults import FaultPlan
    from repro.launch.train import run_gnn

    base = dict(dataset="flickr", scale=0.008, feat_dim=16, model="gcn",
                backend="edges", hidden=16, layers=2, parts=2,
                partitioner="metis", epochs=8, lr=0.01, jaca=True,
                rapa=False, pipeline=False, refresh_every=4,
                adaptive_staleness=False, cpu_cache_gib=1.0, seed=0,
                ckpt_dir="", resume=False)
    straight = run_gnn(argparse.Namespace(**base))

    d = str(tmp_path / "ck")
    run_gnn(argparse.Namespace(**{**base, "epochs": 4, "ckpt_dir": d}))
    assert latest_step(d) == 4

    # fake the crash: a step-6 checkpoint whose payload write was cut
    # short (valid sidecar meta, truncated npz) plus the stray tmp file
    # an interrupted atomic save leaves behind
    shutil.copy(f"{d}/ckpt_00000004.npz", f"{d}/ckpt_00000006.npz")
    shutil.copy(f"{d}/ckpt_00000004.json", f"{d}/ckpt_00000006.json")
    FaultPlan.parse("ckpt_truncate@0:frac=0.5").truncate_checkpoint(
        f"{d}/ckpt_00000006.npz")
    open(f"{d}/ckpt_00000006.npz.tmp", "wb").write(b"partial")

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        assert latest_step(d) == 4          # corrupt 6 skipped
        resumed = run_gnn(argparse.Namespace(**{**base, "ckpt_dir": d,
                                                "resume": True}))
    assert resumed["resumed_from"] == 4
    np.testing.assert_allclose(resumed["final_loss"],
                               straight["final_loss"],
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(resumed["test_acc"], straight["test_acc"],
                               rtol=1e-6, atol=1e-7)
