"""Graph substrate: CSR invariants, partitioners, halo construction."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import (csr_from_edges, symmetric_normalize, rmat, sbm,
                         random_partition, fennel_partition, metis_partition,
                         build_partition, edge_cut, bfs_order)


@st.composite
def small_graph(draw):
    n = draw(st.integers(4, 40))
    m = draw(st.integers(n, 6 * n))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    return csr_from_edges(src[keep], dst[keep], n, dedup=True)


@given(small_graph())
@settings(max_examples=30, deadline=None)
def test_csr_roundtrip(g):
    src, dst = g.edges()
    g2 = csr_from_edges(src, dst, g.num_nodes)
    assert np.array_equal(g.indptr, g2.indptr)
    assert np.array_equal(g.indices, g2.indices)
    assert g.out_degree().sum() == g.num_edges
    assert g.in_degree().sum() == g.num_edges


@given(small_graph(), st.integers(2, 5), st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_partition_invariants(g, parts, hops):
    assign = random_partition(g, parts, seed=0)
    ps = build_partition(g, assign, hops=hops)
    # every vertex is inner in exactly one partition
    counts = np.zeros(g.num_nodes, dtype=int)
    for p in ps.parts:
        counts[p.inner_nodes] += 1
        # halo sets are disjoint from inner, owners are correct
        assert not set(p.inner_nodes) & set(p.halo_nodes)
        assert np.all(assign[p.halo_nodes] == p.halo_owner)
        assert np.all(p.halo_owner != p.part_id)
    assert np.all(counts == 1)
    # edge conservation: every edge into an inner vertex whose src is within
    # `hops` appears in exactly one local graph (hops=1 covers all edges)
    if hops >= 1:
        total_local = sum(p.local_graph.num_edges for p in ps.parts)
        assert total_local == g.num_edges


def test_partitioners_cut_quality():
    g = rmat(1500, 9000, seed=3)
    cut_r = edge_cut(g, random_partition(g, 4, seed=0))
    cut_f = edge_cut(g, fennel_partition(g, 4, seed=0))
    cut_m = edge_cut(g, metis_partition(g, 4, seed=0))
    # structure-aware partitioners must beat random
    assert cut_f < cut_r
    assert cut_m < cut_r


def test_weighted_partition_sizes():
    g = rmat(2000, 10000, seed=1)
    w = [0.4, 0.4, 0.1, 0.1]
    a = fennel_partition(g, 4, seed=0, weights=w)
    sizes = np.bincount(a, minlength=4) / g.num_nodes
    assert sizes[0] > sizes[2]
    assert sizes[1] > sizes[3]


def test_symmetric_normalize_weights():
    g = rmat(300, 2000, seed=0)
    gn = symmetric_normalize(g)
    assert gn.edge_weight is not None
    assert np.all(gn.edge_weight > 0)
    assert np.all(np.isfinite(gn.edge_weight))


def test_bfs_order_is_permutation():
    g = rmat(500, 2500, seed=2)
    order = bfs_order(g)
    assert np.array_equal(np.sort(order), np.arange(g.num_nodes))


def test_halo_observation1():
    """Paper Obs. 1: total halo >= inner for power-law graphs at P>=4."""
    g = rmat(3000, 24000, seed=0)
    ps = build_partition(g, random_partition(g, 8, seed=0), hops=1)
    assert ps.total_halo() >= 0.8 * ps.total_inner()


def test_halo_grows_with_hops_and_parts():
    g = rmat(2000, 12000, seed=0)
    a = metis_partition(g, 4, seed=0)
    h1 = build_partition(g, a, hops=1).total_halo()
    h2 = build_partition(g, a, hops=2).total_halo()
    assert h2 >= h1
    a8 = metis_partition(g, 8, seed=0)
    h8 = build_partition(g, a8, hops=1).total_halo()
    assert h8 >= h1


def test_build_partition_empty_part_and_empty_assign():
    """Regression: inferring ``assign.max() + 1`` dropped trailing empty
    parts (breaking the ``len(profiles) == ps.num_parts`` contract) and
    crashed on an empty assignment."""
    g = rmat(600, 3000, seed=0)
    assign = random_partition(g, 2, seed=0)
    ps = build_partition(g, assign, hops=1, parts=3)   # part 2 never used
    assert ps.num_parts == 3
    empty = ps.parts[2]
    assert empty.n_inner == 0 and empty.n_halo == 0
    assert empty.local_graph.num_edges == 0
    # a fleet-sized profile list now lines up with the partition count
    from repro.core import PROFILES, RapaConfig, do_partition
    res = do_partition(ps, [PROFILES["rtx3090"]] * 3, RapaConfig(feat_dim=8))
    assert res.partition_set.num_parts == 3

    none = np.zeros(0, np.int64)
    g0 = csr_from_edges(none, none, 0)
    assert build_partition(g0, none, hops=1).num_parts == 0
    assert build_partition(g0, none, hops=1, parts=2).num_parts == 2
    with pytest.raises(ValueError):
        build_partition(g, assign, hops=1, parts=int(assign.max()))


def _halo_reference(g, assign, pid, hops):
    """Per-vertex BFS the vectorised ``_k_hop_halo`` replaced."""
    g_rev = g.reverse()
    inner = np.where(assign == pid)[0]
    seen = {int(v) for v in inner}
    frontier = sorted(seen)
    halo = set()
    for _ in range(hops):
        nxt = []
        for v in frontier:
            for u in g_rev.neighbors(v):
                u = int(u)
                if u not in seen:
                    seen.add(u)
                    halo.add(u)
                    nxt.append(u)
        frontier = nxt
    return halo


def test_k_hop_halo_matches_slow_reference():
    g = rmat(700, 5000, seed=4)
    assign = random_partition(g, 3, seed=1)
    for hops in (1, 2, 3):
        ps = build_partition(g, assign, hops=hops)
        for pt in ps.parts:
            assert {int(v) for v in pt.halo_nodes} == \
                _halo_reference(g, assign, pt.part_id, hops)


def test_partitioners_track_capability_weights():
    """The rebalance pass keeps part sizes near the per-part targets —
    the property resource-aware uneven partitioning depends on."""
    g = rmat(2000, 12000, seed=2)
    w = np.array([0.4, 0.3, 0.2, 0.1])
    for fn in (metis_partition, fennel_partition):
        sizes = np.bincount(fn(g, 4, seed=0, weights=w), minlength=4)
        assert np.all(sizes <= 1.12 * w * g.num_nodes + 1)
        assert sizes[0] > sizes[2] > sizes[3]
