"""SPMD (shard_map) CaPGNN runtime parity vs the stacked oracle.

The collectives-based runtime needs >1 device, and XLA locks the host
device count at first jax init — so the check runs in a subprocess with
``--xla_force_host_platform_device_count=8`` (single-pod 4-worker mesh and
the §5.11-style multi-pod (2 pods x 2 workers) mesh).
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = os.path.join(os.path.dirname(__file__), "spmd_parity_script.py")
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, _SCRIPT, *args],
                          capture_output=True, text=True, timeout=900,
                          env=env)


@pytest.mark.parametrize(
    "flags",
    [(), ("--multi-pod",), ("--backend", "ell"), ("--backend", "hybrid")],
    ids=["single_pod", "multi_pod", "ell_backend", "hybrid_backend"])
def test_spmd_matches_oracle(flags):
    """The collectives runtime matches the edge-list stacked oracle — for
    the reference backend and for the Pallas ell/hybrid aggregation
    backends (oracle stays on edges, so this also cross-checks kernels)."""
    res = _run(*flags)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "OK" in res.stdout
