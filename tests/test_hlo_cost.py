"""Unit tests for the trip-count-aware HLO cost roll-up (launch/hlo_cost)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyse_hlo, xla_cost_analysis


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_counted_per_iteration():
    """grad of scan-of-matmul: 12 iterations x (1 fwd + 2 bwd) dots."""
    def f(params, x):
        def body(c, p):
            return jnp.tanh(c @ p), None
        out, _ = jax.lax.scan(body, x, params)
        return out.sum()

    params = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    comp = _compile(jax.grad(f, argnums=0), params, x)
    c = analyse_hlo(comp.as_text())
    expect = 12 * 3 * (2 * 8 * 64 * 64)
    assert c.flops == pytest.approx(expect, rel=0.01)
    # XLA's own analysis counts the body once — ours must exceed it
    assert c.flops > xla_cost_analysis(comp)["flops"] * 5
    assert c.unresolved_loops == 0


def test_dot_flops_no_loop():
    comp = _compile(lambda a, b: a @ b,
                    jax.ShapeDtypeStruct((32, 48), jnp.float32),
                    jax.ShapeDtypeStruct((48, 16), jnp.float32))
    c = analyse_hlo(comp.as_text())
    assert c.flops == pytest.approx(2 * 32 * 48 * 16, rel=0.01)


def test_windowed_bytes_not_charged_full_operand():
    """A scan that dynamic-slices a big stacked tensor must charge the
    slices (~N x slice), not N x the whole stack."""
    big = jax.ShapeDtypeStruct((64, 128, 128), jnp.float32)  # 4 MiB

    def f(stack):
        def body(c, p):
            return c + p[0, :8], None
        out, _ = jax.lax.scan(body, jnp.zeros((8,)), stack)
        return out

    comp = _compile(f, big)
    c = analyse_hlo(comp.as_text())
    full_bytes = 64 * 128 * 128 * 4
    # 64 iterations x full stack would be 256 MiB; windowed must be far less
    assert c.bytes_accessed < 0.5 * 64 * full_bytes
    assert c.bytes_accessed > 0


def test_collectives_multiplied_by_trip_count():
    mesh = jax.make_mesh((jax.device_count(),), ("d",))
    from jax.sharding import PartitionSpec as P
    n = jax.device_count()

    def g(x):
        def body(c, xs):
            return c + jax.lax.psum(xs, "d"), None
        out, _ = jax.lax.scan(body, jnp.zeros((64,)), x)
        return out

    sm = jax.shard_map(g, mesh=mesh, in_specs=P(None, "d"), out_specs=P("d"))
    comp = _compile(sm, jax.ShapeDtypeStruct((10, 64 * n), jnp.float32))
    c = analyse_hlo(comp.as_text())
    assert c.collective_counts["all-reduce"] == 10
    assert c.collective_bytes["all-reduce"] == 10 * 64 * 4


def test_no_loops_graph_has_zero_unresolved():
    comp = _compile(lambda x: jnp.tanh(x).sum(),
                    jax.ShapeDtypeStruct((128, 128), jnp.float32))
    c = analyse_hlo(comp.as_text())
    assert c.unresolved_loops == 0
    assert c.flops == 0.0  # no dots
    assert c.bytes_accessed > 128 * 128 * 4
