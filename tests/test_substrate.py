"""Substrate units: optimizers, checkpointing, staleness controller,
device profiles, GNN model backends, reordering."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import save_checkpoint, load_checkpoint, latest_step
from repro.core import StalenessController, theorem1_bound, measure_profile
from repro.core.device_profile import (PROFILES, PAPER_GROUPS, make_group,
                                       TPU_V5E, capability_weights)
from repro.graph import rmat, symmetric_normalize, reorder_partition_arrays, build_partition
from repro.graph.partition import metis_partition
from repro.models.gnn import (GNNConfig, init_gnn, gnn_forward,
                              make_local_adj, cross_entropy_loss, accuracy)
from repro.optim import sgd, adam, adamw, clip_by_global_norm


# --------------------------------------------------------------------- optim

def _quad_min(opt, steps=200):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return opt.update(grads, state, params)

    for _ in range(steps):
        params, state = step(params, state)
    return float(jnp.abs(params["w"] - target).max())


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.05, momentum=0.9),
                                 adam(0.1), adamw(0.1, weight_decay=0.0)])
def test_optimizers_minimize_quadratic(opt):
    assert _quad_min(opt) < 1e-2


def test_adamw_decays_weights():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.ones(4) * 10.0}
    state = opt.init(params)
    zero_grads = {"w": jnp.zeros(4)}
    params2, _ = opt.update(zero_grads, state, params)
    assert float(params2["w"][0]) < 10.0


def test_clip_by_global_norm():
    grads = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    leaves = jax.tree.leaves(clipped)
    got = float(jnp.sqrt(sum(jnp.sum(g ** 2) for g in leaves)))
    assert got == pytest.approx(1.0, rel=1e-5)
    assert float(norm) > 1.0


# ---------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"layers": [jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                       {"b": jnp.ones(4, jnp.bfloat16)}],
            "step": jnp.asarray(7)}
    d = str(tmp_path)
    save_checkpoint(d, 3, tree)
    save_checkpoint(d, 10, tree)
    assert latest_step(d) == 10
    got = load_checkpoint(d, 10, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_checkpoint(d, 1, {"w": jnp.zeros((3, 3))})


# ----------------------------------------------------------------- staleness

def test_fixed_staleness_schedule():
    ctl = StalenessController(refresh_every=4)
    pattern = []
    for _ in range(8):
        pattern.append(ctl.should_refresh())
        ctl.observe()
    assert pattern == [True, False, False, False, True, False, False, False]


def test_adaptive_staleness_shrinks_on_drift():
    ctl = StalenessController(refresh_every=8, adaptive=True, eps_h=0.5)
    for _ in range(4):
        ctl.observe(drift_inf_norm=2.0)   # way over the bound
    assert ctl.period < 8
    for _ in range(20):
        ctl.observe(drift_inf_norm=0.01)  # well under
    assert ctl.period >= 8


def test_theorem1_bound_decays():
    b10 = theorem1_bound(5.0, rho=1.0, alpha=2.0, t=10)
    b1000 = theorem1_bound(5.0, rho=1.0, alpha=2.0, t=1000)
    assert b1000 < b10
    assert b1000 == pytest.approx(
        2 * 5.0 / np.sqrt(1000) + 1.0 * 2.0 / (2 * np.sqrt(1000)))


# ------------------------------------------------------------ device profile

def test_paper_groups_match_table4():
    for k, names in PAPER_GROUPS.items():
        assert len(names) == int(k[1:])
        profs = make_group(names)
        assert all(p.mm > 0 and p.mem_gib > 0 for p in profs)
    # Table 1 ordering: 3090 faster than 1650 at MM
    assert PROFILES["rtx3090"].mm < PROFILES["gtx1650"].mm


def test_measure_profile_runs():
    prof = measure_profile(size=128, repeats=1)
    assert prof.mm > 0 and prof.spmm > 0 and prof.h2d > 0
    assert TPU_V5E.mm < PROFILES["rtx3090"].mm  # 197 TF/s beats a 3090


# --------------------------------------------------------------- GNN models

@pytest.fixture(scope="module")
def tiny():
    g = symmetric_normalize(rmat(120, 700, seed=4))
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(g.num_nodes, 12)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 4, g.num_nodes).astype(np.int32))
    return g, feats, labels


@pytest.mark.parametrize("model", ["gcn", "sage", "gat", "gin"])
def test_gnn_forward_and_grads(tiny, model):
    g, feats, labels = tiny
    cfg = GNNConfig(model=model, in_dim=12, hidden_dim=16, out_dim=4,
                    num_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    adj = make_local_adj(g, g.num_nodes, backend="edges")
    logits = gnn_forward(cfg, params, adj, feats, None)
    assert logits.shape == (g.num_nodes, 4)
    grads = jax.grad(lambda p: cross_entropy_loss(
        gnn_forward(cfg, p, adj, feats, None), labels))(params)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("model", ["gcn", "gin"])
def test_adjacency_backends_agree(tiny, model):
    g, feats, _ = tiny
    cfg = GNNConfig(model=model, in_dim=12, hidden_dim=16, out_dim=4,
                    num_layers=2)
    params = init_gnn(jax.random.PRNGKey(1), cfg)
    outs = {}
    for backend in ("dense", "edges", "ell"):
        adj = make_local_adj(g, g.num_nodes, backend=backend)
        outs[backend] = np.asarray(gnn_forward(cfg, params, adj, feats, None))
    np.testing.assert_allclose(outs["edges"], outs["dense"], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs["edges"], outs["ell"], rtol=2e-4, atol=2e-4)


def test_accuracy_metric():
    logits = jnp.asarray([[2.0, 0.0], [0.0, 2.0], [2.0, 0.0]])
    labels = jnp.asarray([0, 1, 1])
    assert float(accuracy(logits, labels)) == pytest.approx(2 / 3)
    mask = jnp.asarray([1.0, 1.0, 0.0])
    assert float(accuracy(logits, labels, mask)) == pytest.approx(1.0)


# ----------------------------------------------------------------- reorder

def test_reorder_preserves_graph_semantics():
    g = symmetric_normalize(rmat(200, 1200, seed=6))
    ps = build_partition(g, metis_partition(g, 2, seed=0), hops=1)
    part = ps.parts[0]
    pri = np.random.default_rng(0).random(part.n_halo)
    new_g, perm = reorder_partition_arrays(part.local_graph, part.n_inner, pri)
    assert np.array_equal(np.sort(perm), np.arange(part.n_local))
    # inner ids stay in the inner range, halo in the halo range
    assert np.all(perm[:part.n_inner] < part.n_inner)
    assert np.all(perm[part.n_inner:] >= part.n_inner)
    # edge multiset is preserved under the permutation
    src, dst = part.local_graph.edges()
    inv = np.empty(part.n_local, dtype=np.int64)
    inv[perm] = np.arange(part.n_local)
    ns, nd = new_g.edges()
    assert sorted(zip(inv[src].tolist(), inv[dst].tolist())) == \
        sorted(zip(ns.tolist(), nd.tolist()))


def test_measure_profile_d2h_not_cache_hit():
    """Regression: the d2h loop re-converted the same committed array, so
    JAX served the memoised host copy and d2h measured ~0 (hundreds of
    times faster than h2d), poisoning RAPA's Eq. 13 comm ratios.  Real
    same-size transfers land within an order of magnitude of each other."""
    prof = measure_profile(size=512, repeats=3)
    assert prof.d2h > 0
    assert prof.d2h <= prof.h2d * 10
    assert prof.h2d <= prof.d2h * 10
    assert prof.mem_gib > 0


def test_capability_weights_order_and_normalisation():
    profs = make_group(["rtx3090", "a40", "rtx3060", "gtx1650"])
    w = capability_weights(profs)
    assert w.shape == (4,)
    assert w.sum() == pytest.approx(1.0)
    assert np.all(w > 0)
    # stronger device (smaller matmul times) gets the larger share
    assert w[0] > w[2] > w[3]
