"""repro.obs: structured tracing, per-phase timing, Perfetto export.

Coverage layers:

- tracer unit properties: strict LIFO span nesting, step kinds never
  interleave, the disabled tracer is a true no-op (one shared context
  manager, no fences, no records);
- exact accounting: a traced sim train's per-step counter totals equal
  ``TrainReport.comm_bytes`` / ``host_fetch_*`` *exactly* (device and
  host feature modes, static and adaptive/replanning schedules); the
  SPMD runtime over both halo transports is covered by the forced-mesh
  subprocess (``obs_trace_script.py``);
- export: the Chrome trace round-trips through JSON and validates
  against the trace_event schema (spans as "X", counters as "C",
  per-worker counter tracks), the JSONL metrics stream reconstructs the
  counter records;
- zero overhead: a run without a tracer issues no
  ``jax.block_until_ready`` beyond the untraced baseline and the donated
  steps stay warning-free.
"""
import dataclasses
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.obs import (NULL_TRACER, STEP_KINDS, StepCounters, Tracer,
                       chrome_trace_events, validate_chrome_trace,
                       write_chrome_trace, write_metrics_jsonl)

_SCRIPT = os.path.join(os.path.dirname(__file__), "obs_trace_script.py")
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ------------------------------------------------------------- unit: spans

def test_spans_nest_strictly():
    tr = Tracer(fence=False)
    with tr.step_span("refresh", 0):
        with tr.span("l0_stage"):
            with tr.span("h2d_put", nbytes=128):
                pass
        with tr.span("writeback"):
            pass
    with tr.step_span("cached", 1):
        pass
    assert [s.name for s in tr.spans] == \
        ["h2d_put", "l0_stage", "writeback", "refresh", "cached"]
    by = {s.name: s for s in tr.spans}
    assert by["refresh"].depth == 0 and by["cached"].depth == 0
    assert by["l0_stage"].depth == 1 and by["h2d_put"].depth == 2
    assert by["h2d_put"].args == {"nbytes": 128}
    assert by["refresh"].step == 0 and by["cached"].step == 1
    # children lie inside their parent's interval
    for child, parent in (("h2d_put", "l0_stage"), ("l0_stage", "refresh")):
        c, p = by[child], by[parent]
        assert c.t0 >= p.t0 and c.t0 + c.dur <= p.t0 + p.dur + 1e-9


def test_step_kinds_never_interleave():
    tr = Tracer(fence=False)
    span = tr.step_span("refresh", 0)
    with span:
        with pytest.raises(RuntimeError, match="interleave"):
            with tr.step_span("cached", 1):
                pass
    # sub-spans must close LIFO
    a, b = tr.span("l0_stage"), tr.span("writeback")
    a.__enter__()
    b.__enter__()
    with pytest.raises(RuntimeError, match="nest strictly"):
        a.__exit__(None, None, None)


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    s1 = tr.span("anything", rows=3)
    s2 = tr.step_span("refresh", 0)
    assert s1 is s2 is NULL_TRACER.span("x")   # one shared no-op CM
    with s1:
        pass
    tr.count(StepCounters(step=0, kind="refresh"))
    assert tr.spans == [] and tr.counters == []
    assert tr.phase_stats() == {}
    assert tr.totals()["steps"] == 0


def test_disabled_fence_never_syncs(monkeypatch):
    import jax
    calls = []
    monkeypatch.setattr(jax, "block_until_ready",
                        lambda x: calls.append(1) or x)
    Tracer(enabled=False).fence(object())
    NULL_TRACER.fence(object())
    assert not calls
    Tracer().fence(object())
    assert len(calls) == 1
    Tracer(fence=False).fence(object())   # timing on, fencing opted out
    assert len(calls) == 1


def test_phase_stats_percentiles():
    tr = Tracer(fence=False)
    for e in range(10):
        with tr.step_span("cached", e):
            pass
    st = tr.phase_stats()
    assert set(st) == {"cached"}
    assert st["cached"]["count"] == 10
    assert 0 <= st["cached"]["p50_ms"] <= st["cached"]["p99_ms"]
    assert st["cached"]["total_s"] >= 0


# ------------------------------------------------------------ unit: export

def _fake_traced():
    tr = Tracer(fence=False)
    for e, kind in enumerate(("refresh", "cached", "pipelined")):
        with tr.step_span(kind, e):
            with tr.span("l0_stage"):
                pass
        tr.count(StepCounters(step=e, kind=kind, wire_rows_uncached=5 + e,
                              wire_bytes=100 * (e + 1),
                              wire_bytes_vanilla=400,
                              cache_hit_rate=None if e == 0 else 0.5,
                              wire_rows_by_worker=[2 + e, 3]))
    return tr


def test_chrome_trace_roundtrip(tmp_path):
    tr = _fake_traced()
    path = write_chrome_trace(tr, str(tmp_path / "trace.json"))
    with open(path) as f:
        payload = json.load(f)
    assert payload["displayTimeUnit"] == "ms"
    stats = validate_chrome_trace(payload)
    assert stats["spans_by_cat"] == {"refresh": 1, "cached": 1,
                                     "pipelined": 1, "l0_stage": 3}
    # per-worker counter tracks: 2 workers x 3 steps on pids 1, 2
    pids = {ev["pid"] for ev in payload["traceEvents"] if ev["ph"] == "C"}
    assert pids == {0, 1, 2}
    names = {ev["args"]["name"] for ev in payload["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "process_name"}
    assert {"train host", "worker0", "worker1"} <= names
    # ts are non-negative relative microsecond ints, spans ordered
    xs = [ev for ev in payload["traceEvents"] if ev["ph"] == "X"]
    assert all(ev["ts"] >= 0 and ev["dur"] >= 1 for ev in xs)
    steps = [ev["args"]["step"] for ev in xs if ev["cat"] in STEP_KINDS]
    assert steps == sorted(steps)


def test_counter_events_skip_none_fields():
    tr = _fake_traced()
    evs = chrome_trace_events(tr)
    hits = [ev for ev in evs if ev["ph"] == "C"
            and ev["name"] == "cache_hit_rate"]
    assert len(hits) == 2          # None on the refresh record -> skipped
    assert not any(ev["name"] in ("queries", "hot_hits") for ev in evs
                   if ev["ph"] == "C")   # serve fields absent on train recs


def test_metrics_jsonl_roundtrip(tmp_path):
    tr = _fake_traced()
    path = write_metrics_jsonl(tr, str(tmp_path / "metrics.jsonl"))
    rows = [json.loads(line) for line in open(path)]
    assert len(rows) == 3
    want = [dataclasses.asdict(c) for c in tr.counters]
    assert rows == want


def test_validate_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"foo": []})
    bad = {"traceEvents": [{"ph": "X", "name": "a", "ts": 0, "pid": 0}]}
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace(bad)
    bad = {"traceEvents": [{"ph": "C", "name": "c", "ts": 0, "pid": 0,
                            "args": {"v": "nan-string"}}]}
    with pytest.raises(ValueError, match="numeric"):
        validate_chrome_trace(bad)
    with pytest.raises(ValueError, match="phase"):
        validate_chrome_trace({"traceEvents": [{"ph": "Z"}]})


# ----------------------------------------------- traced training (sim)

_CACHE: dict = {}


def _tiny(features="device", adaptive=False):
    import jax
    from repro.core import (AdaptivePlanner, CacheCapacity,
                            StalenessController, build_cache_plan)
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.dist import (build_exchange_plan, make_sim_runtime,
                            stack_partitions)
    from repro.graph import (build_partition, metis_partition, rmat,
                             symmetric_normalize, synth_features)
    from repro.models.gnn import GNNConfig
    from repro.optim import adam

    g = rmat(200, 1100, seed=9)
    feats, labels = synth_features(g, 8, 4, seed=9)
    gn = symmetric_normalize(g)
    trm, va, te = split_masks(g.num_nodes, seed=9)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=trm, val_mask=va, test_mask=te,
                         num_classes=4)
    parts = 2
    ps = build_partition(gn, metis_partition(gn, parts, seed=9), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=8, hidden_dim=8, out_dim=4,
                    num_layers=2)
    max_halo = max(pt.n_halo for pt in ps.parts)
    cap = CacheCapacity(c_gpu=[max(1, max_halo // 3)] * parts,
                        c_cpu=max(1, max_halo))
    planner = None
    if adaptive:
        planner = AdaptivePlanner(ps, cap, refresh_every=2, policy="lru",
                                  seed=0)
        xplan = planner.exchange_plan()
    else:
        xplan = build_exchange_plan(
            ps, build_cache_plan(ps, cap, refresh_every=2))
    sp = stack_partitions(ps, task)
    opt = adam(1e-2)
    rt = make_sim_runtime(cfg, sp, xplan, opt, features=features)
    ctl = StalenessController(refresh_every=2)
    return cfg, rt, xplan, parts, opt, ctl, planner


def _traced_run(features="device", adaptive=False, epochs=6, tracer=...,
                eval_every=0):
    from repro.dist import train_capgnn
    cfg, rt, xplan, parts, opt, ctl, planner = _tiny(features, adaptive)
    tr = Tracer() if tracer is ... else tracer
    _, rep = train_capgnn(cfg, rt, xplan, parts, opt, epochs=epochs,
                          controller=ctl, pipeline=True,
                          eval_every=eval_every, planner=planner, tracer=tr)
    return tr, rep, rt


@pytest.mark.parametrize("features", ["device", "host"])
def test_traced_totals_match_report_sim(features):
    """The per-step counter stream is the report's accounting, pre-sum:
    totals equal comm_bytes / host_fetch_* exactly."""
    tr, rep, rt = _traced_run(features=features)
    tot = tr.totals()
    assert tot["wire_bytes"] == rep.comm_bytes
    assert tot["wire_bytes_vanilla"] == rep.comm_bytes_vanilla
    assert tot["host_fetch_rows"] == rep.host_fetch_rows
    assert tot["host_fetch_bytes"] == rep.host_fetch_bytes
    assert tot["host_writeback_bytes"] == rep.host_writeback_bytes
    if features == "host":
        assert rep.host_fetch_rows > 0
        # host mode stages h2d inside the staging/prefetch sub-spans
        kinds = {s.kind for s in tr.spans}
        assert {"l0_stage", "h2d_prefetch", "h2d_put"} <= kinds
    # wire rows on the counters re-derive wire_bytes per step
    dimb = sum(d * rt.halo_dtype_bytes for d in rt.comm_dims)
    for c in tr.counters:
        rows = (c.wire_rows_uncached + c.wire_rows_local
                + c.wire_rows_global)
        assert c.wire_bytes == rows * dimb


def test_traced_step_kind_schedule():
    """refresh_every=2, pipeline: refresh @0, pipelined @2,4, cached else;
    exactly one depth-0 span per step, in step order."""
    tr, rep, _ = _traced_run(epochs=6)
    kinds = [c.kind for c in tr.counters]
    assert kinds == ["refresh", "cached", "pipelined", "cached",
                     "pipelined", "cached"]
    depth0 = [s for s in tr.spans if s.depth == 0]
    assert [s.kind for s in depth0] == kinds
    assert [s.step for s in depth0] == list(range(6))
    # counters are monotone in step and stamp time
    assert [c.step for c in tr.counters] == list(range(6))
    ts = [c.t for c in tr.counters]
    assert ts == sorted(ts)
    assert all(c.wire_bytes >= 0 and c.wire_bytes <= c.wire_bytes_vanilla
               for c in tr.counters)
    # steady-state/compile split: both positive, wall excludes step 0
    assert rep.compile_s > 0 and rep.wall_time_s > 0
    assert set(rep.phase_stats) == {"refresh", "cached", "pipelined"}
    assert sum(p["count"] for p in rep.phase_stats.values()) == 6


def test_traced_adaptive_replan_exact():
    """Replanning schedules: transition steps traced with a nested replan
    span, and the totals stay exact across plan swaps."""
    tr, rep, _ = _traced_run(adaptive=True, epochs=7)
    assert rep.replan_events > 0
    kinds = [c.kind for c in tr.counters]
    assert "transition" in kinds
    assert tr.totals()["wire_bytes"] == rep.comm_bytes
    replans = [s for s in tr.spans if s.kind == "replan"]
    assert len(replans) == rep.replan_events
    assert all(s.depth == 1 for s in replans)
    trans = {s.step for s in tr.spans
             if s.depth == 0 and s.kind == "transition"}
    assert {s.step for s in replans} <= trans | {0}


def test_eval_spans_depth0():
    tr, rep, _ = _traced_run(epochs=4, eval_every=2)
    evals = [s for s in tr.spans if s.kind == "eval"]
    assert len(evals) == 2 and all(s.depth == 0 for s in evals)
    assert "eval" in rep.phase_stats


def test_traced_export_validates(tmp_path):
    tr, _, _ = _traced_run(features="host", epochs=4)
    paths = tr.export(str(tmp_path), prefix="t")
    with open(paths["trace"]) as f:
        stats = validate_chrome_trace(json.load(f))
    assert stats["n_spans"] == len(tr.spans)
    assert stats["spans_by_cat"].get("refresh", 0) > 0
    rows = [json.loads(line) for line in open(paths["metrics"])]
    assert len(rows) == len(tr.counters)


def test_untraced_run_adds_no_sync(monkeypatch):
    """tracer=None and a disabled tracer issue zero block_until_ready
    calls from the training loop (the per-step float() is the only sync),
    and donation stays clean."""
    import jax
    real = jax.block_until_ready
    calls = []
    monkeypatch.setattr(jax, "block_until_ready",
                        lambda x: calls.append(1) or real(x))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        _, rep_none, _ = _traced_run(tracer=None, epochs=4)
        n_none = len(calls)
        _, rep_off, _ = _traced_run(tracer=Tracer(enabled=False),
                                    epochs=4)
        n_off = len(calls) - n_none
    assert n_none == 0 and n_off == 0
    bad = [str(x.message) for x in w if "donat" in str(x.message).lower()]
    assert not bad, bad
    assert rep_none.phase_stats is None and rep_off.phase_stats is None
    np.testing.assert_allclose(rep_none.losses, rep_off.losses)
    # ... and an enabled tracer fences once per step
    calls.clear()
    tr, _, _ = _traced_run(epochs=4)
    assert len(calls) == 4


# ------------------------------------------- SPMD runtimes (forced mesh)

def _run_script(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, _SCRIPT, *args],
                          capture_output=True, text=True, timeout=900,
                          env=env)


@pytest.mark.parametrize("transport,features",
                         [("allgather", "device"), ("allgather", "host"),
                          ("p2p", "device"), ("p2p", "host")])
def test_spmd_traced_totals_match_report(transport, features):
    """Plan rows == traced rows == report totals on the real SPMD runtime,
    both transports, device- and host-resident features."""
    res = _run_script("--transport", transport, "--features", features)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "OK" in res.stdout
    assert "donated buffers were not usable" not in res.stderr


# --------------------------------------------------------------- serving

def test_serve_stream_traced_counters():
    import jax
    from repro.core import build_cache_plan, cal_capacity, PROFILES
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.dist import build_exchange_plan, stack_partitions
    from repro.graph import (build_partition, metis_partition, rmat,
                             symmetric_normalize, synth_features)
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.serve import (BatchConfig, GNNServeEngine, make_stream,
                             precompute_embeddings, rank_hot_nodes,
                             serve_stream)

    g = rmat(160, 800, seed=4)
    feats, labels = synth_features(g, 8, 4, seed=4)
    gn = symmetric_normalize(g)
    trm, va, te = split_masks(g.num_nodes, seed=4)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=trm, val_mask=va, test_mask=te,
                         num_classes=4)
    ps = build_partition(gn, metis_partition(gn, 2, seed=4), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=8, hidden_dim=8, out_dim=4,
                    num_layers=2)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * 2)
    xplan = build_exchange_plan(ps, build_cache_plan(ps, cap,
                                                     refresh_every=2))
    sp = stack_partitions(ps, task)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    store = precompute_embeddings(cfg, ps, sp, xplan, params)
    hot = rank_hot_nodes(gn, 40, ps=ps)
    engine = GNNServeEngine(store, params, gn, hot, features=feats)
    stream = make_stream("zipf", gn.num_nodes, 96, qps=1e9, seed=4)
    tr = Tracer(fence=False)
    report = serve_stream(engine, stream, BatchConfig(max_batch=32),
                          tracer=tr)
    assert tr.counters and all(c.kind == "serve" for c in tr.counters)
    tot_q = sum(c.queries for c in tr.counters)
    assert tot_q == engine.stats["queries"] == 96
    assert sum(c.hot_hits for c in tr.counters) == engine.stats["hot_hits"]
    assert sum(c.host_hits for c in tr.counters) == \
        engine.stats["host_hits"]
    batch_spans = [s for s in tr.spans if s.kind == "serve_batch"]
    assert len(batch_spans) == len(tr.counters) == engine.stats["batches"]
    # sub-phase spans nest inside batch spans
    subs = [s for s in tr.spans if s.kind in ("hot_gather", "host_fetch",
                                              "fresh_recompute")]
    assert subs and all(s.depth >= 1 for s in subs)
    # wire counters absent on serve records -> no zero-valued train tracks
    evs = chrome_trace_events(tr)
    cnames = {ev["name"] for ev in evs if ev["ph"] == "C"}
    assert "queries" in cnames and "wire_bytes" not in cnames
