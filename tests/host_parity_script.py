"""Subprocess helper for test_host_store: runs the out-of-core
``features="host"`` runtimes on 8 forced host devices and checks that

- the host-backed sim runtime matches the device-resident sim runtime
  exactly: fresh-forward logits, and params through a full staleness
  schedule (refresh -> cached -> pipelined), pinned through sgd(1.0)
  steps so the comparison IS gradient parity;
- the host-backed SPMD runtime matches the device-resident SPMD runtime
  under the requested halo transport, and the sim host runtime;
- the host stores' consumed staged rows equal the plan's
  ``host_fetch_rows`` accounting exactly (sim and SPMD);
- the donated host-mode jitted steps emit no donation warnings.

Invoked as:  python tests/host_parity_script.py
                 [--backend edges|ell|hybrid] [--transport allgather|p2p]
                 [--bf16]
Exits non-zero on any mismatch.
"""
import os
import sys
import warnings

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402

TOL = 1e-5
EPOCHS = 6          # refresh @0, pipelined @3, cached elsewhere


def leafdiff(t1, t2):
    import jax.numpy as jnp
    diffs = [float(jnp.abs(a - b).max()) for a, b in
             zip(jax.tree.leaves(t1), jax.tree.leaves(t2)) if a.size]
    return max(diffs) if diffs else 0.0


def main():
    bf16 = "--bf16" in sys.argv
    backend = (sys.argv[sys.argv.index("--backend") + 1]
               if "--backend" in sys.argv else "edges")
    transport = (sys.argv[sys.argv.index("--transport") + 1]
                 if "--transport" in sys.argv else "allgather")
    import jax.numpy as jnp
    from repro.core import CacheCapacity, build_cache_plan
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.dist import (TrainSpec, build_exchange_plan, init_caches,
                            make_sim_runtime, stack_partitions)
    from repro.dist.capgnn_spmd import make_spmd_runtime
    from repro.graph import (build_partition, metis_partition, rmat,
                             symmetric_normalize, synth_features)
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import sgd

    parts = 4
    g = rmat(360, 2200, seed=3)
    feats, labels = synth_features(g, 12, 5, seed=3)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=3)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=5)
    ps = build_partition(gn, metis_partition(gn, parts, seed=3), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=12, hidden_dim=16, out_dim=5,
                    num_layers=3)
    # forced small capacity: cal_capacity at this scale caches every halo
    # row locally, which would leave the host tier empty and the test
    # vacuous — this keeps all three tiers populated
    plan = build_cache_plan(ps, CacheCapacity(c_gpu=[8] * parts, c_cpu=30),
                            refresh_every=3)
    xplan = build_exchange_plan(ps, plan)
    assert xplan.host is not None and xplan.host.n_fetch_rows > 0
    assert xplan.local.n_rows > 0 and xplan.glob.n_unique > 0
    sp = stack_partitions(ps, task, backend=backend)
    opt = sgd(1.0)   # update == -grad: parity below IS gradient parity
    halo_dtype = "bf16" if bf16 else "f32"
    # bf16: device mode reads layer-0 local-tier rows from the resident
    # f32 table while host mode stages them through the bf16 PCIe cast —
    # an expected one-quantisation gap; f32 must be exact
    tol = 5e-3 if bf16 else TOL

    mesh = jax.make_mesh((parts,), ("data",))
    spec_dev = TrainSpec(backend=backend, transport=transport,
                         halo_dtype=halo_dtype, donate=False)
    spec_host = spec_dev.replace(features="host", prefetch_depth=2)
    sim_dev = make_sim_runtime(cfg, sp, xplan, opt, spec=spec_dev)
    sim_host = make_sim_runtime(cfg, sp, xplan, opt, spec=spec_host)
    spmd_dev = make_spmd_runtime(cfg, sp, xplan, opt, mesh, spec=spec_dev)
    spmd_host = make_spmd_runtime(cfg, sp, xplan, opt, mesh, spec=spec_host)
    params = init_gnn(jax.random.PRNGKey(7), cfg)

    # ---- fresh-forward logits parity
    lsd = np.asarray(sim_dev.forward_fresh(params), np.float32)
    lsh = np.asarray(sim_host.forward_fresh(params), np.float32)
    np.testing.assert_allclose(lsh, lsd, rtol=tol, atol=tol)
    lph = np.asarray(spmd_host.forward_fresh(params), np.float32)
    np.testing.assert_allclose(lph, np.asarray(spmd_dev.forward_fresh(params),
                                               np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(lph, lsh, rtol=TOL, atol=TOL)

    # ---- full schedule parity, all four runtimes in lockstep
    snap_sim = sim_host.host_store.snapshot()
    snap_spmd = spmd_host.host_store.snapshot()
    state = {}
    for name, rt in (("sim_dev", sim_dev), ("sim_host", sim_host),
                     ("spmd_dev", spmd_dev), ("spmd_host", spmd_host)):
        state[name] = (params, opt.init(params),
                       init_caches(cfg, xplan, parts,
                                   features="host" if "host" in name
                                   else "device"))
    losses = {k: [] for k in state}
    for step in range(EPOCHS):
        flavor = ("refresh" if step == 0
                  else "pipelined" if step % 3 == 0 else "cached")
        for name, rt in (("sim_dev", sim_dev), ("sim_host", sim_host),
                         ("spmd_dev", spmd_dev), ("spmd_host", spmd_host)):
            fn = getattr(rt, f"step_{flavor}")
            p, o, c, m = fn(*state[name])
            state[name] = (p, o, c)
            losses[name].append(float(m["loss"]))
        assert leafdiff(state["sim_host"][0], state["sim_dev"][0]) < tol, \
            f"sim host/device param drift at step {step} ({flavor})"
        assert leafdiff(state["spmd_host"][0], state["spmd_dev"][0]) < tol, \
            f"spmd host/device param drift at step {step} ({flavor})"
        # sim-vs-spmd under bf16 carries the bf16 ulp in gradients (the
        # runtimes quantise the wire payload at different boundaries) —
        # same looser bound as transport_parity_script; f32 stays strict
        assert leafdiff(state["spmd_host"][0], state["sim_host"][0]) < tol, \
            f"spmd/sim host param drift at step {step} ({flavor})"

    # ---- exact consumption-driven fetch accounting (plan == store).
    # step 0 is a plain refresh (fresh global built on-wire); every later
    # step stages the host-resident global buffers alongside layer 0
    ex_layers = cfg.num_layers - 1
    per = xplan.host_fetch_rows(True, ex_layers)
    expected = EPOCHS * per["l0"] + (EPOCHS - 1) * per["global"]
    for store, snap, label in ((sim_host.host_store, snap_sim, "sim"),
                               (spmd_host.host_store, snap_spmd, "spmd")):
        d = store.delta(snap)
        assert d["fetch_rows"] == expected, \
            (label, d["fetch_rows"], expected)

    # ---- donation: chained donated host-mode steps run clean
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        spec_don = spec_host.replace(donate=True)
        for mk in (lambda: make_sim_runtime(cfg, sp, xplan, opt,
                                            spec=spec_don),
                   lambda: make_spmd_runtime(cfg, sp, xplan, opt, mesh,
                                             spec=spec_don)):
            rt_d = mk()
            pp = jax.tree.map(jnp.copy, params)
            oo = opt.init(pp)
            cc = init_caches(cfg, xplan, parts, features="host")
            for i in range(3):
                fn = (rt_d.step_refresh, rt_d.step_cached,
                      rt_d.step_pipelined)[i]
                pp, oo, cc, mm = fn(pp, oo, cc)
            jax.block_until_ready(mm["loss"])
        bad = [str(x.message) for x in w
               if "donat" in str(x.message).lower()]
        assert not bad, bad

    print(f"OK backend={backend} transport={transport} bf16={bf16} "
          f"host_rows={xplan.host.n_fetch_rows} fetched={expected} "
          f"loss_last={losses['spmd_host'][-1]:.5f}")


if __name__ == "__main__":
    main()
