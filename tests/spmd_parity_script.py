"""Subprocess helper for test_spmd_runtime: runs the shard_map SPMD CaPGNN
runtime on 8 forced host devices and checks numeric parity with the
single-device stacked oracle.  Exits non-zero on any mismatch.

Invoked as:  python tests/spmd_parity_script.py [--multi-pod]
                 [--backend edges|ell|hybrid]

``--backend`` swaps the SPMD runtime's local aggregation operator while the
oracle keeps the edge-list reference — the parity check then covers both
the collectives lowering and the Pallas kernel backends.
"""
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402


def main():
    multi_pod = "--multi-pod" in sys.argv
    backend = (sys.argv[sys.argv.index("--backend") + 1]
               if "--backend" in sys.argv else "edges")
    import jax.numpy as jnp
    from repro.core import (PROFILES, StalenessController, build_cache_plan,
                            cal_capacity)
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.dist import (TrainSpec, build_exchange_plan, make_sim_runtime,
                            stack_partitions)
    from repro.dist.capgnn_spmd import make_spmd_runtime
    from repro.graph import (build_partition, metis_partition, rmat,
                             symmetric_normalize, synth_features)
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import adam

    parts = 4
    g = rmat(360, 2200, seed=3)
    feats, labels = synth_features(g, 12, 5, seed=3)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=3)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=5)
    ps = build_partition(gn, metis_partition(gn, parts, seed=3), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=12, hidden_dim=16, out_dim=5,
                    num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * parts)
    plan = build_cache_plan(ps, cap, refresh_every=2)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(1e-2)

    # donate=False: the parity check re-uses (params, caches) across the
    # sim and SPMD runtimes' step calls
    sim = make_sim_runtime(cfg, sp, xplan, opt,
                           spec=TrainSpec(donate=False))

    if multi_pod:
        mesh = jax.make_mesh((2, 2), ("pod", "data"))
        axis = ("pod", "data")
    else:
        mesh = jax.make_mesh((4,), ("data",))
        axis = "data"
    sp_b = (sp if backend == "edges"
            else stack_partitions(ps, task, backend=backend))
    spmd = make_spmd_runtime(cfg, sp_b, xplan, opt, mesh, axis=axis,
                             spec=TrainSpec(backend=backend, donate=False))

    params = init_gnn(jax.random.PRNGKey(7), cfg)

    # ---- fresh forward parity
    lf_sim = np.asarray(sim.forward_fresh(params), np.float32)
    lf_spmd = np.asarray(spmd.forward_fresh(params), np.float32)
    np.testing.assert_allclose(lf_spmd, lf_sim, rtol=2e-4, atol=2e-4)

    # ---- refresh-step parity (loss + updated params)
    o1 = opt.init(params)
    o2 = opt.init(params)
    c_sim = sim_caches(sim, cfg, xplan, parts)
    c_spmd = jax.tree.map(jnp.asarray, spmd.caches0)
    p1, o1, c_sim, m1 = sim.step_refresh(params, o1, c_sim)
    p2, o2, c_spmd, m2 = spmd.step_refresh(params, o2, c_spmd)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-4, \
        (float(m1["loss"]), float(m2["loss"]))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)

    # ---- cached step runs and stays finite
    p2b, o2, c_spmd, m3 = spmd.step_cached(p2, o2, c_spmd)
    assert np.isfinite(float(m3["loss"]))
    print(f"OK multi_pod={multi_pod} backend={backend} "
          f"loss_refresh={float(m2['loss']):.5f} "
          f"loss_cached={float(m3['loss']):.5f}")


def sim_caches(sim, cfg, xplan, parts):
    from repro.dist.capgnn_sim import init_caches
    return init_caches(cfg, xplan, parts)


if __name__ == "__main__":
    main()
