"""Subprocess helper for test_adaptive: online cache adaptation on the
SPMD runtime over forced host devices.  Checks, per transport:

- an adaptive run whose re-plans preserve membership (slot-stable padded
  layout, 'overlap' re-ranks on a static graph) matches the frozen static
  runtime's losses and params to <= 1e-5, with ``step_transition`` taking
  the place of the static run's pipelined refresh;
- a membership-churning re-plan (random re-ranked plan) executes through
  ``step_transition`` + subsequent cached steps with finite loss, exact
  plan-counted == valid-mask row accounting, and **zero retraces**: every
  jitted step flavour reports a compiled-call cache of size <= 1 at exit.

Invoked as:  python tests/adaptive_parity_script.py
                 [--transport p2p|allgather]
Exits non-zero on any mismatch.
"""
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402

TOL = 1e-5


def main():
    transport = (sys.argv[sys.argv.index("--transport") + 1]
                 if "--transport" in sys.argv else "p2p")
    from repro.core import (AdaptivePlanner, CacheCapacity,
                            build_cache_plan)
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.dist import (TrainSpec, build_exchange_plan,
                            exchange_capacity, init_caches,
                            stack_partitions)
    from repro.dist.capgnn_spmd import make_spmd_runtime
    from repro.graph import (build_partition, metis_partition, rmat,
                             symmetric_normalize, synth_features)
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import sgd

    parts = 4
    g = rmat(300, 1800, seed=11)
    feats, labels = synth_features(g, 12, 5, seed=11)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=11)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=5)
    ps = build_partition(gn, metis_partition(gn, parts, seed=11), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=12, hidden_dim=16, out_dim=5,
                    num_layers=3)
    max_halo = max(pt.n_halo for pt in ps.parts)
    cap = CacheCapacity(c_gpu=[max(1, max_halo // 3)] * parts,
                        c_cpu=max(1, ps.halo_union().size // 4))
    plan = build_cache_plan(ps, cap, refresh_every=2)
    pad = exchange_capacity(ps, cap)
    sp = stack_partitions(ps, task)
    opt = sgd(1.0)   # update == -grad: parity below IS gradient parity
    mesh = jax.make_mesh((parts,), ("data",))

    def make(xp):
        return make_spmd_runtime(cfg, sp, xp, opt, mesh, axis="data",
                                 spec=TrainSpec(transport=transport,
                                                donate=False))

    params0 = init_gnn(jax.random.PRNGKey(3), cfg)

    # ---- static reference: refresh, cached, pipelined-refresh, cached
    rt_s = make(build_exchange_plan(ps, plan))
    p, o, c = params0, opt.init(params0), init_caches(cfg, rt_s.xplan, parts)
    losses_s = []
    for fn in (rt_s.step_refresh, rt_s.step_cached, rt_s.step_pipelined,
               rt_s.step_cached):
        p, o, c, m = fn(p, o, c)
        losses_s.append(float(m["loss"]))
    p_static = p

    # ---- adaptive with membership-preserving re-plan at the same step
    planner = AdaptivePlanner(ps, cap, refresh_every=2, policy="overlap")
    rt = make(planner.exchange_plan(plan))
    p, o, c = params0, opt.init(params0), init_caches(cfg, rt.xplan, parts)
    losses_a = []
    p, o, c, m = rt.step_refresh(p, o, c)
    losses_a.append(float(m["loss"]))
    p, o, c, m = rt.step_cached(p, o, c)
    losses_a.append(float(m["loss"]))
    planner.observe_step(layers=2)
    x_next = planner.exchange_plan(planner.replan())   # same membership
    p, o, c, m = rt.step_transition(p, o, c, x_next)
    losses_a.append(float(m["loss"]))
    p, o, c, m = rt.step_cached(p, o, c)
    losses_a.append(float(m["loss"]))
    np.testing.assert_allclose(losses_a, losses_s, rtol=TOL, atol=TOL)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p_static)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=TOL, atol=TOL)

    # ---- membership-churning re-plan: rows exact, loss finite, no retrace
    rng = np.random.default_rng(5)
    from repro.core import plan_from_membership
    local_sets = []
    for i, pt in enumerate(ps.parts):
        k = min(cap.c_gpu[i], pt.n_halo)
        sel = rng.choice(pt.halo_nodes, size=k, replace=False)
        local_sets.append(set(int(v) for v in sel))
    union = ps.halo_union()
    glob = set(int(v) for v in rng.choice(
        union, size=min(cap.c_cpu, union.size), replace=False))
    churned = plan_from_membership(ps, local_sets, glob, cap,
                                   refresh_every=2)
    x_read = rt.xplan
    x_next = build_exchange_plan(ps, churned, pad_to=pad)
    xr_arr = rt._state["xarr"]
    p, o, c, m = rt.step_transition(p, o, c, x_next)
    xe_arr = rt._state["xarr"]
    assert np.isfinite(float(m["loss"]))
    plan_rows = (x_read.uncached.n_rows + x_next.local.n_rows
                 + x_next.glob.n_unique)
    measured = (int(np.asarray(xr_arr["sh"]["un"]["recv_valid"]).sum())
                + int(np.asarray(xe_arr["sh"]["loc"]["recv_valid"]).sum())
                + int(np.asarray(xe_arr["rep"]["g_buf_valid"]).sum()))
    assert plan_rows == measured, (plan_rows, measured)
    p, o, c, m = rt.step_cached(p, o, c)   # consume the prefetched caches
    assert np.isfinite(float(m["loss"]))

    # ---- zero retraces across every re-plan event above
    sizes = {k: rt.jit_steps[k]._cache_size()
             for k in ("refresh", "cached", "pipelined")}
    assert all(v <= 1 for v in sizes.values()), sizes

    print(f"OK transport={transport} losses={losses_a} "
          f"jit_cache_sizes={sizes} rows={plan_rows}")


if __name__ == "__main__":
    main()
