"""Subprocess helper for test_spmm15d: runs the 1.5D replicated-row SpMM
strategy on 4 forced host devices and checks it against the halo_1d sim
oracle at refresh_every=1 (the exact single-worker reference).  Exits
non-zero on any mismatch.

Invoked as:  python tests/spmm15d_parity_script.py [--eight]

``--eight`` forces 8 host devices instead and runs the ``c=2, pr=4``
(g=2) case — permute, gather and allreduce all live in one step.

Covers, per ISSUE 10's acceptance criteria:

- ``c=2`` (pr=2, g=1 — the permute + allreduce path) and ``c=1`` (pr=4,
  g=4 — the degenerate dense-1D all_gather path) on the same graph;
- logits parity <= 1e-5 vs the oracle's fresh forward (valid rows);
- explicit grads parity <= 1e-5 (one sgd(1.0) step: the param delta IS
  the gradient — this would expose the classic uniform-c / c**2
  replication-cotangent bugs exactly);
- loss-trajectory parity <= 1e-5 over 6 adam epochs;
- modeled forward collective bytes == HLO-measured
  (:func:`repro.launch.dryrun.collective_bytes` over the compiled
  forward), including the ``exchange_layer0=False`` pre-replicated
  variant.
"""
import os
import sys

NDEV = 8 if "--eight" in sys.argv else 4
os.environ["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={NDEV} "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402


def build_problem(parts):
    from repro.core import PROFILES, build_cache_plan, cal_capacity
    from repro.data.gnn_data import FullBatchTask, split_masks
    from repro.dist import build_exchange_plan, stack_partitions
    from repro.graph import (build_partition, metis_partition, rmat,
                             symmetric_normalize, synth_features)
    from repro.models.gnn import GNNConfig

    g = rmat(360, 2200, seed=3)
    feats, labels = synth_features(g, 12, 5, seed=3)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=3)
    task = FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=5)
    ps = build_partition(gn, metis_partition(gn, parts, seed=3), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=12, hidden_dim=16, out_dim=5,
                    num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * parts)
    plan = build_cache_plan(ps, cap, refresh_every=1)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    return ps, task, cfg, sp, xplan


def check_case(c, pr, exchange_layer0=True):
    import jax.numpy as jnp
    from repro.dist import TrainSpec, get_strategy
    from repro.dist.capgnn_sim import make_sim_runtime, train_capgnn
    from repro.dist.strategy_15d import (build_spmm15d_layout,
                                         make_spmm15d_runtime,
                                         train_spmm15d)
    from repro.launch.dryrun import collective_bytes
    from repro.models.gnn import init_gnn
    from repro.optim import adam, sgd

    ps, task, cfg, sp, xplan = build_problem(pr)
    spec15 = TrainSpec(strategy="spmm_15d", replication=c,
                       exchange_layer0=exchange_layer0, donate=False)
    layout = build_spmm15d_layout(ps, task, spec15)
    assert layout.edges_total == sum(
        int((np.asarray(pt.local_graph.edges()[1]) < pt.n_inner).sum())
        for pt in ps.parts), "replica edge chunks must partition the edges"

    # --- oracle: halo_1d sim at refresh_every=1, identical spec knobs
    spec1d = TrainSpec(strategy="halo_1d", donate=False,
                       exchange_layer0=exchange_layer0)
    opt = adam(1e-2)
    sim = make_sim_runtime(cfg, sp, xplan, opt, spec=spec1d)
    rt = make_spmm15d_runtime(cfg, layout, opt, spec15)

    params = init_gnn(jax.random.PRNGKey(7), cfg)
    valid = np.asarray(sp.inner_valid)                      # [pr, NI]

    # ---- logits parity (every replica against its block row)
    lo_sim = np.asarray(sim.forward_fresh(params), np.float64)
    lo_15 = np.asarray(rt.forward_fresh(params), np.float64)
    for i in range(pr):
        for j in range(c):
            d = np.abs(lo_15[i * c + j][valid[i]] - lo_sim[i][valid[i]])
            assert d.max() <= 1e-5, (c, pr, i, j, d.max())

    # ---- explicit grads parity: one sgd(1.0) step, param delta == -grad
    s1 = sgd(1.0)
    sim_s = make_sim_runtime(cfg, sp, xplan, s1, spec=spec1d)
    rt_s = make_spmm15d_runtime(cfg, layout, s1, spec15)
    p_sim, _, _, m_sim = sim_s.step_refresh(params, s1.init(params),
                                            jax.tree.map(jnp.asarray,
                                                         sim_s.caches0))
    p_15, _, m_15 = rt_s.step(params, s1.init(params))
    assert abs(float(m_sim["loss"]) - float(m_15["loss"])) <= 1e-5
    for a, b in zip(jax.tree.leaves(p_sim), jax.tree.leaves(p_15)):
        d = np.abs(np.asarray(a, np.float64) - np.asarray(b, np.float64))
        assert d.max() <= 1e-5, (c, pr, d.max())

    # ---- loss trajectory over 6 adam epochs
    _, rep_sim = train_capgnn(cfg, sim, xplan, pr, opt, epochs=6,
                              spec=spec1d)
    _, rep_15 = train_spmm15d(cfg, rt, opt, spec15, epochs=6)
    traj = np.abs(np.asarray(rep_sim.losses) - np.asarray(rep_15.losses))
    assert traj.max() <= 1e-5, (c, pr, rep_sim.losses, rep_15.losses)
    assert rep_15.spec["strategy"] == "spmm_15d"
    assert rep_15.spec["replication"] == c

    # ---- byte-accounting contract: modeled == HLO-measured forward
    hlo = rt.lower_forward(params).compile().as_text()
    measured = collective_bytes(hlo)["total"]
    assert measured == rt.forward_bytes_per_device, (
        c, pr, measured, rt.forward_bytes_per_device,
        collective_bytes(hlo))
    strat = get_strategy("spmm_15d")
    assert strat.step_bytes(layout, cfg, spec15) == \
        rt.forward_bytes_per_device * layout.n_devices
    print(f"OK c={c} pr={pr} g={layout.g} xl0={exchange_layer0} "
          f"loss0={rep_15.losses[0]:.5f} "
          f"fwd_bytes/dev={rt.forward_bytes_per_device} (== HLO)")
    return float(traj.max()), measured


def main():
    if NDEV == 8:
        check_case(c=2, pr=4)                      # permute+gather+psum
    else:
        check_case(c=2, pr=2)                      # permute + psum path
        check_case(c=1, pr=4)                      # dense-1D gather path
        check_case(c=2, pr=2, exchange_layer0=False)  # pre-replicated
    print("OK spmm15d parity")


if __name__ == "__main__":
    main()
