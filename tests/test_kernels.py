"""Pallas kernel sweeps: every kernel vs its pure-jnp oracle across
shapes/dtypes (interpret mode — faithful CPU execution of the kernel body)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ops import ell_pack, ell_spmm, ell_stats, gather_rows, cache_combine
from repro.kernels import ref as R
from repro.kernels.ell_spmm import ell_spmm_pallas
from repro.kernels.cache_gather import gather_rows_pallas


def _rand_ell(rng, n_rows, max_deg, n_cols, dtype):
    cols = rng.integers(0, n_cols, size=(n_rows, max_deg)).astype(np.int32)
    vals = rng.normal(size=(n_rows, max_deg)).astype(np.float32)
    # randomly zero ~30% as padding
    vals[rng.random((n_rows, max_deg)) < 0.3] = 0.0
    h = rng.normal(size=(n_cols, 0)).astype(dtype)  # placeholder
    return cols, vals


SHAPES = [
    (128, 4, 256, 128),     # minimal aligned tile
    (256, 9, 300, 128),     # odd max_deg, unaligned n_cols
    (384, 16, 512, 256),    # multi-tile rows and feats
    (128, 1, 64, 128),      # degenerate degree-1
]


@pytest.mark.parametrize("n_rows,max_deg,n_cols,d", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ell_spmm_matches_oracle(n_rows, max_deg, n_cols, d, dtype):
    rng = np.random.default_rng(n_rows + max_deg)
    cols, vals = _rand_ell(rng, n_rows, max_deg, n_cols, np.float32)
    h = rng.normal(size=(n_cols, d)).astype(np.float32)
    hj = jnp.asarray(h, dtype)
    out = ell_spmm_pallas(jnp.asarray(cols), jnp.asarray(vals), hj,
                          interpret=True)
    want = R.ell_spmm_ref(jnp.asarray(cols), jnp.asarray(vals), hj)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("col_chunk", [64, 128])
def test_ell_spmm_column_chunked(col_chunk):
    """Chunked accumulation (VMEM-bounded path) must equal monolithic."""
    rng = np.random.default_rng(7)
    n_rows, max_deg, n_cols, d = 128, 8, 256, 128
    cols, vals = _rand_ell(rng, n_rows, max_deg, n_cols, np.float32)
    h = jnp.asarray(rng.normal(size=(n_cols, d)).astype(np.float32))
    mono = ell_spmm_pallas(jnp.asarray(cols), jnp.asarray(vals), h,
                           interpret=True)
    chunked = ell_spmm_pallas(jnp.asarray(cols), jnp.asarray(vals), h,
                              col_chunk=col_chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(mono),
                               rtol=1e-5, atol=1e-5)


def test_ell_spmm_wrapper_pads_ragged():
    """Public wrapper handles n_rows/d not multiples of the block sizes."""
    rng = np.random.default_rng(11)
    n_rows, max_deg, n_cols, d = 70, 5, 90, 48
    cols = rng.integers(0, n_cols, size=(n_rows, max_deg)).astype(np.int32)
    vals = rng.normal(size=(n_rows, max_deg)).astype(np.float32)
    h = jnp.asarray(rng.normal(size=(n_cols, d)).astype(np.float32))
    out = ell_spmm(jnp.asarray(cols), jnp.asarray(vals), h, interpret=True)
    want = R.ell_spmm_ref(jnp.asarray(cols), jnp.asarray(vals), h)
    assert out.shape == (n_rows, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ell_pack_roundtrip_spmm():
    """COO -> ELL pack -> kernel == segment-sum SpMM on the COO form."""
    rng = np.random.default_rng(3)
    n_rows, n_cols, m = 100, 150, 600
    src = rng.integers(0, n_cols, m).astype(np.int32)
    dst = rng.integers(0, n_rows, m).astype(np.int32)
    w = rng.normal(size=m).astype(np.float32)
    cols, vals = ell_pack(src, dst, w, n_rows)
    assert (vals != 0).sum() <= m
    h = rng.normal(size=(n_cols, 32)).astype(np.float32)
    out = ell_spmm(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(h),
                   interpret=True)[:n_rows]
    want = jax.ops.segment_sum(jnp.asarray(h)[src] * w[:, None],
                               jnp.asarray(dst), num_segments=n_rows)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    stats = ell_stats(cols, vals)
    assert 0.0 <= stats["pad_waste"] <= 1.0


@pytest.mark.parametrize("n_out,n_src,d", [(128, 64, 128), (256, 512, 256),
                                           (128, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_rows_matches_oracle(n_out, n_src, d, dtype):
    rng = np.random.default_rng(n_out + d)
    src = jnp.asarray(rng.normal(size=(n_src, d)).astype(np.float32), dtype)
    idx = jnp.asarray(rng.integers(0, n_src, n_out).astype(np.int32))
    out = gather_rows_pallas(src, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(src)[np.asarray(idx)])


def test_gather_rows_wrapper_ragged_and_empty():
    rng = np.random.default_rng(5)
    src = jnp.asarray(rng.normal(size=(40, 20)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 40, 33).astype(np.int32))
    out = gather_rows(src, idx, interpret=True)
    assert out.shape == (33, 20)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(src)[np.asarray(idx)])
    empty = gather_rows(src, jnp.zeros((0,), jnp.int32), interpret=True)
    assert empty.shape == (0, 20)


def test_cache_combine_three_tiers():
    """Disjoint positions from 3 sources fill the halo buffer exactly."""
    rng = np.random.default_rng(9)
    n_halo, d = 30, 8
    pos = rng.permutation(n_halo)
    lp, gp, rp = pos[:10], pos[10:18], pos[18:]
    lr = rng.normal(size=(10, d)).astype(np.float32)
    gr = rng.normal(size=(8, d)).astype(np.float32)
    rr = rng.normal(size=(12, d)).astype(np.float32)
    out = np.asarray(cache_combine(jnp.asarray(lr), jnp.asarray(lp),
                                   jnp.asarray(gr), jnp.asarray(gp),
                                   jnp.asarray(rr), jnp.asarray(rp), n_halo))
    np.testing.assert_array_equal(out[lp], lr)
    np.testing.assert_array_equal(out[gp], gr)
    np.testing.assert_array_equal(out[rp], rr)


def test_cache_combine_empty_tier():
    out = cache_combine(jnp.zeros((0, 4)), jnp.zeros((0,), jnp.int32),
                        jnp.zeros((0, 4)), jnp.zeros((0,), jnp.int32),
                        jnp.ones((3, 4)), jnp.asarray([0, 1, 2]), 5)
    assert out.shape == (5, 4)
    np.testing.assert_array_equal(np.asarray(out)[:3], np.ones((3, 4)))
    np.testing.assert_array_equal(np.asarray(out)[3:], np.zeros((2, 4)))


def test_ell_spmm_gradients_flow():
    """vjp through the kernel (interpret mode) matches the oracle's vjp."""
    rng = np.random.default_rng(13)
    cols = jnp.asarray(rng.integers(0, 64, (128, 4)).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(128, 4)).astype(np.float32))
    h = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))

    g_k = jax.grad(lambda x: ell_spmm_pallas(cols, vals, x,
                                             interpret=True).sum())(h)
    g_r = jax.grad(lambda x: R.ell_spmm_ref(cols, vals, x).sum())(h)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r),
                               rtol=1e-4, atol=1e-4)


def test_ell_spmm_custom_vjp_matches_ref_vjp():
    """Full VJP parity of the kernel's custom rule against autodiff of the
    jnp oracle: the cols cotangent is float0 (int input), and the vals/h
    cotangents agree for a random (non-ones) output cotangent."""
    rng = np.random.default_rng(17)
    n_rows, max_deg, n_cols, d = 128, 6, 96, 128
    cols = jnp.asarray(rng.integers(0, n_cols,
                                    (n_rows, max_deg)).astype(np.int32))
    vals = np.random.default_rng(18).normal(
        size=(n_rows, max_deg)).astype(np.float32)
    vals[rng.random((n_rows, max_deg)) < 0.3] = 0.0
    vals = jnp.asarray(vals)
    h = jnp.asarray(rng.normal(size=(n_cols, d)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(n_rows, d)).astype(np.float32))

    out_k, vjp_k = jax.vjp(
        lambda c, v, x: ell_spmm_pallas(c, v, x, interpret=True),
        cols, vals, h)
    out_r, vjp_r = jax.vjp(R.ell_spmm_ref, cols, vals, h)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)

    ct_cols_k, ct_vals_k, ct_h_k = vjp_k(g)
    ct_cols_r, ct_vals_r, ct_h_r = vjp_r(g)
    assert ct_cols_k.dtype == jax.dtypes.float0
    assert ct_cols_k.shape == cols.shape
    assert ct_cols_r.dtype == jax.dtypes.float0
    np.testing.assert_allclose(np.asarray(ct_vals_k), np.asarray(ct_vals_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ct_h_k), np.asarray(ct_h_r),
                               rtol=1e-4, atol=1e-4)

    # jax.grad of the oracle wrt vals as well (satellite spec): agree with
    # the kernel's grad under a scalar loss too.
    g_v_k = jax.grad(lambda v: (ell_spmm_pallas(cols, v, h, interpret=True)
                                * g).sum())(vals)
    g_v_r = jax.grad(lambda v: (R.ell_spmm_ref(cols, v, h) * g).sum())(vals)
    np.testing.assert_allclose(np.asarray(g_v_k), np.asarray(g_v_r),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------- hybrid ELL+COO pack

def test_hybrid_pack_matches_plain_spmm():
    """ELL(quantile) + COO tail == plain full-width ELL == segment-sum."""
    from repro.kernels.ops import ell_pack_hybrid, hybrid_spmm
    rng = np.random.default_rng(5)
    n_rows, n_cols, m = 200, 200, 3000
    # power-law-ish dst distribution (heavy rows)
    dst = (rng.pareto(1.3, m) * 10).astype(np.int64) % n_rows
    src = rng.integers(0, n_cols, m)
    w = rng.normal(size=m).astype(np.float32)
    h = jnp.asarray(rng.normal(size=(n_cols, 32)).astype(np.float32))

    cols, vals, ts, td, tw = ell_pack_hybrid(src, dst, w, n_rows,
                                             quantile=0.9)
    got = hybrid_spmm(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(ts),
                      jnp.asarray(td), jnp.asarray(tw), h)
    # oracle: plain segment-sum over all edges
    msgs = h[jnp.asarray(src)] * jnp.asarray(w)[:, None]
    want = jax.ops.segment_sum(msgs, jnp.asarray(dst), num_segments=n_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_hybrid_pack_reduces_padding():
    from repro.kernels.ops import ell_pack, ell_pack_hybrid, ell_stats
    rng = np.random.default_rng(6)
    n_rows, m = 300, 4000
    dst = (rng.pareto(1.2, m) * 8).astype(np.int64) % n_rows
    src = rng.integers(0, n_rows, m)
    w = np.ones(m, np.float32)
    cols_p, vals_p = ell_pack(src, dst, w, n_rows)
    cols_h, vals_h, ts, td, tw = ell_pack_hybrid(src, dst, w, n_rows)
    waste_plain = ell_stats(cols_p, vals_p)["pad_waste"]
    waste_hyb = ell_stats(cols_h, vals_h)["pad_waste"]
    assert waste_hyb < waste_plain
    # heavy-tailed degree => much of the edge MASS can be tail, but the
    # tail stays a minority and the regular part is dense
    assert ts.shape[0] < m * 0.5
