"""The spmm_15d strategy needs a real multi-device mesh, and JAX pins the
device count at first init — so the parity/accounting checks run in a
subprocess with ``--xla_force_host_platform_device_count`` (the forced
4-device c=2 / c=1 cases and the 8-device c=2, g=2 case where permute,
gather and allreduce all live in one step).  The script asserts logits,
explicit grads and loss-trajectory parity <= 1e-5 against the halo_1d sim
oracle and modeled == HLO-measured forward collective bytes.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = os.path.join(os.path.dirname(__file__), "spmm15d_parity_script.py")
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, _SCRIPT, *args],
                          capture_output=True, text=True, timeout=900,
                          env=env)


@pytest.mark.parametrize("flags", [(), ("--eight",)],
                         ids=["four_devices", "eight_devices"])
def test_spmm15d_matches_oracle(flags):
    """1.5D replicated-row SpMM matches the refresh_every=1 sim oracle
    and its byte model matches the compiled HLO exactly."""
    res = _run(*flags)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "OK spmm15d parity" in res.stdout
