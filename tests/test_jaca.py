"""JACA unit tests: capacity (Alg. 1), plan tiering (Eq. 2 priority),
hit-rate claims (Figs. 14-15), byte accounting."""
import numpy as np
import pytest

from repro.core import (cal_capacity, build_cache_plan, CacheCapacity,
                        plan_hit_rate, simulate_policy_hit_rate,
                        comm_bytes_per_step, PROFILES)
from repro.graph import rmat, build_partition, metis_partition


@pytest.fixture(scope="module")
def ps():
    g = rmat(800, 5000, seed=0)
    return build_partition(g, metis_partition(g, 4, seed=0), hops=1)


def test_cal_capacity_respects_memory(ps):
    profiles = [PROFILES["rtx3090"]] * 4
    cap = cal_capacity(ps, [64, 32, 32], profiles, m_cpu_gib=0.001,
                       reserved_cpu_mib=0.0)
    # tiny CPU budget => tiny global capacity
    bytes_per_vertex = (64 + 32 + 32) * 4
    assert cap.c_cpu <= int(0.001 * 1024 ** 3 / bytes_per_vertex)
    for c, part in zip(cap.c_gpu, ps.parts):
        assert 0 <= c <= part.n_halo


def test_cal_capacity_caps_at_halo_count(ps):
    profiles = [PROFILES["a40"]] * 4   # 48 GiB: plenty
    cap = cal_capacity(ps, [16], profiles, m_cpu_gib=64.0)
    for c, part in zip(cap.c_gpu, ps.parts):
        assert c == part.n_halo     # never exceeds the candidate set


def test_overlap_priority_orders_local_tier(ps):
    """Local tier must contain the highest-overlap halos (Eq. 2)."""
    overlap = ps.overlap_ratio()
    cap = CacheCapacity(c_gpu=[15] * 4, c_cpu=0)
    plan = build_cache_plan(ps, cap, policy="overlap_high")
    for w in plan.workers:
        if w.local_gids.size and w.uncached_gids.size:
            assert overlap[w.local_gids].min() >= overlap[w.uncached_gids].max() - 1


def test_high_beats_low_priority_hit_rate(ps):
    """Fig. 14: overlap_high >= overlap_low at equal capacity."""
    for capacity in (10, 40, 120):
        hi = simulate_policy_hit_rate(ps, capacity, policy="overlap_high")
        lo = simulate_policy_hit_rate(ps, capacity, policy="overlap_low")
        assert hi >= lo


def test_jaca_beats_fifo_lru_at_small_capacity(ps):
    """Fig. 15: static overlap-ranked cache beats FIFO/LRU for the
    full-batch sweep access pattern at sub-working-set capacities."""
    capacity = 60
    jaca = simulate_policy_hit_rate(ps, capacity, policy="overlap_high")
    fifo = simulate_policy_hit_rate(ps, capacity, policy="fifo")
    lru = simulate_policy_hit_rate(ps, capacity, policy="lru")
    assert jaca > fifo
    assert jaca > lru


def test_hit_rate_monotone_in_capacity(ps):
    rates = [simulate_policy_hit_rate(ps, c, policy="overlap_high")
             for c in (5, 20, 80, 320, 100000)]
    assert all(b >= a - 1e-9 for a, b in zip(rates, rates[1:]))
    assert rates[-1] == pytest.approx(1.0)


def test_plan_hit_rate_accounting(ps):
    cap = CacheCapacity(c_gpu=[25] * 4, c_cpu=50)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    hr = plan_hit_rate(plan)
    assert 0.0 <= hr["hit"] <= 1.0
    assert hr["hit"] == pytest.approx(hr["local_hit"] + hr["global_hit"])
    assert hr["miss"] == pytest.approx(1.0 - hr["hit"])
    # amortisation: refresh steps re-send the cached tiers
    assert hr["amortised_hit"] == pytest.approx(hr["hit"] * 0.75)


def test_comm_bytes_math(ps):
    cap = CacheCapacity(c_gpu=[25] * 4, c_cpu=50)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    cb = comm_bytes_per_step(plan, feat_dim=64)
    assert cb["cached_step_bytes"] < cb["refresh_step_bytes"]
    assert cb["cached_step_bytes"] <= cb["amortised_bytes"] <= cb["refresh_step_bytes"]
    assert 0.0 <= cb["reduction"] <= 1.0
    # more aggressive staleness -> more saving
    plan8 = build_cache_plan(ps, cap, refresh_every=8)
    cb8 = comm_bytes_per_step(plan8, feat_dim=64)
    assert cb8["amortised_bytes"] <= cb["amortised_bytes"]


def test_plan_hit_rate_beats_fifo_lru_trace(ps):
    """Regression for the JACA policy-quality claim (paper Fig. 15): the
    overlap-ranked static plan's *exact* hit rate beats the FIFO/LRU trace
    simulation at the same total capacity on an r-mat graph."""
    cap_per_worker = 15
    plan = build_cache_plan(ps, CacheCapacity(c_gpu=[cap_per_worker] * 4,
                                              c_cpu=0), refresh_every=4)
    exact_hit = plan_hit_rate(plan)["hit"]
    total_cap = cap_per_worker * 4
    fifo = simulate_policy_hit_rate(ps, total_cap, policy="fifo")
    lru = simulate_policy_hit_rate(ps, total_cap, policy="lru")
    assert exact_hit > fifo
    assert exact_hit > lru


def test_comm_bytes_match_exchange_plan(ps):
    """comm_bytes_per_step must equal the point-to-point rows the compiled
    exchange plan enumerates — i.e. the valid rows in its static index
    sets (the paper's transport model)."""
    from repro.dist import build_exchange_plan
    cap = CacheCapacity(c_gpu=[25] * 4, c_cpu=50)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    xplan = build_exchange_plan(ps, plan)
    d = 64
    cb = comm_bytes_per_step(plan, feat_dim=d)
    assert xplan.bytes_per_step(d, refresh=False) == cb["cached_step_bytes"]
    assert xplan.bytes_per_step(d, refresh=True) == cb["refresh_step_bytes"]
    # and both equal a direct count of the plan's valid index rows
    row = d * 4
    moved_cached = int(xplan.uncached.recv_valid.sum()) * row
    moved_refresh = moved_cached + row * (
        int(xplan.local.recv_valid.sum()) + xplan.glob.n_unique)
    assert moved_cached == cb["cached_step_bytes"]
    assert moved_refresh == cb["refresh_step_bytes"]
    # global dedup really deduplicates: buffer rows <= per-consumer reads
    assert xplan.glob.n_unique <= int(xplan.glob.read_valid.sum())


def test_global_tier_requires_membership(ps):
    """A halo only lands in a worker's global tier if it is in the shared
    global cache's gid set."""
    cap = CacheCapacity(c_gpu=[5] * 4, c_cpu=30)
    plan = build_cache_plan(ps, cap)
    gset = set(int(v) for v in plan.global_gids)
    for w in plan.workers:
        assert all(int(v) in gset for v in w.global_gids)
    assert plan.global_gids.size <= 30


def test_cal_capacity_reserves_partition_residents(ps):
    """Joint budgeting (§4.3): the resident subgraph is charged against
    device memory before the cache claims the remainder, so a device
    whose memory barely fits its partition gets (almost) no cache."""
    import dataclasses as dc
    feat_dims = [64, 32, 32]
    bpv = sum(feat_dims) * 4
    base = [PROFILES["rtx3090"]] * 4
    free = cal_capacity(ps, feat_dims, base, reserve_partition=False)
    joint = cal_capacity(ps, feat_dims, base)
    assert all(j <= f for j, f in zip(joint.c_gpu, free.c_gpu))

    tight = []
    for part in ps.parts:
        resident = part.n_local * bpv + part.local_graph.num_edges * 8.0
        gib = (resident + 512 * 1024 ** 2 + 10 * bpv) / 1024 ** 3
        tight.append(dc.replace(PROFILES["rtx3090"], mem_gib=gib))
    reserved = cal_capacity(ps, feat_dims, tight)
    assert all(c <= 10 for c in reserved.c_gpu)
    unreserved = cal_capacity(ps, feat_dims, tight,
                              reserve_partition=False)
    assert any(c > 10 for c in unreserved.c_gpu)
