"""Fault injection + graceful degradation (``repro.faults``): under each
fault class, training must complete, every injected fault must be matched
by exactly one counted defense event, and the final loss must land within
tolerance of the clean run.

Four sections:

- **fault matrix** — the sim runtime over both feature modes
  (``features="device"|"host"``), one cell per applicable fault class:
  ``fetch_drop`` (bounded retry -> stale-tier reuse), ``fetch_delay``
  (slow-fetch detection -> prefetch degraded to synchronous),
  ``halo_corrupt`` (per-tier checksums -> forced plain refresh),
  ``grad_nan`` (divergence guard -> rollback to the last good snapshot)
  and ``mem_pressure`` (capacity shrink + slot-stable replan through the
  ``AdaptivePlanner``).  Per cell: run completes with a finite loss,
  ``injected[kind] == events[defense]`` *exactly*, loss gap vs the clean
  run under ``LOSS_TOL``.
- **event accounting** — a combined-fault run under the ``repro.obs``
  tracer: the per-step ``StepCounters`` fault deltas must sum to the
  report's ``fault_events`` exactly (the trace is the same ledger,
  before summation).  With ``REPRO_BENCH_TRACE=1`` the Perfetto timeline
  is exported for the CI schema gate (rollback/integrity/fetch_retry
  spans visible).
- **checkpoint integrity** — ``ckpt_truncate`` against the checksummed
  checkpoint format: the truncated file is detected
  (``CheckpointCorruptError``), ``latest_step`` falls back to the newest
  valid checkpoint, and the restored state matches the values saved
  there bit-for-bit.
- **SPMD transports** — re-execs this module with
  ``--xla_force_host_platform_device_count=4`` and runs the shard_map
  runtime in host mode over both halo transports (``p2p`` ring /
  ``allgather``) under a combined fault spec, asserting the same
  injected==defended accounting on each.

``REPRO_BENCH_TINY=1`` shrinks everything for CI smoke runs.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import numpy as np

from ._util import BENCH_SCALE, DEFAULT_OUT, save

EPOCHS = 8
REFRESH_EVERY = 2
# |final loss - clean final loss| budget: a rollback legitimately loses
# the faulted step, so the faulted run trails the clean one by ~one step
LOSS_TOL = 0.25

# one row per fault class: spec, the defense counter it must equal, the
# guard knobs that arm the defense, and the feature modes it applies to
FAULT_MATRIX = (
    {"kind": "fetch_drop", "spec": "fetch_drop@3,5",
     "defense": "fetch_errors", "guard": {"fetch_retries": 2},
     "modes": ("host",)},
    {"kind": "fetch_delay", "spec": "fetch_delay@2:delay_s=0.12",
     "defense": "slow_fetches", "guard": {"fetch_timeout_s": 0.05},
     "modes": ("host",)},
    {"kind": "halo_corrupt", "spec": "halo_corrupt@3",
     "defense": "corruptions_detected", "guard": {"checksums": True},
     "modes": ("device", "host")},
    {"kind": "grad_nan", "spec": "grad_nan@3",
     "defense": "rollbacks", "guard": {"guard_every": 2},
     "modes": ("device", "host")},
    {"kind": "mem_pressure", "spec": "mem_pressure@4",
     "defense": "mem_backoffs", "guard": {}, "policy": "lru",
     "modes": ("device", "host")},
)


def _build(tiny: bool, features: str = "host", policy: str | None = None,
           parts: int = 2):
    """Fresh task/plan/runtime (donated state — never reuse across runs)."""
    from repro.core import (PROFILES, AdaptivePlanner, StalenessController,
                            build_cache_plan, cal_capacity)
    from repro.data import make_task
    from repro.dist import (TrainSpec, build_exchange_plan, make_sim_runtime,
                            stack_partitions)
    from repro.graph import build_partition, metis_partition
    from repro.models.gnn import GNNConfig
    from repro.optim import adam

    scale = BENCH_SCALE["flickr"] / (16 if tiny else 4)
    task = make_task("flickr", scale=scale, feat_dim=16, seed=0)
    ps = build_partition(task.graph,
                         metis_partition(task.graph, parts, seed=0), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=16, out_dim=task.num_classes, num_layers=2)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * parts,
                       m_cpu_gib=1.0)
    planner = None
    if policy:
        planner = AdaptivePlanner(ps, cap, refresh_every=REFRESH_EVERY,
                                  policy=policy, seed=0)
        xplan = planner.exchange_plan()
    else:
        plan = build_cache_plan(ps, cap, refresh_every=REFRESH_EVERY)
        xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    spec = TrainSpec(features=features, refresh_every=REFRESH_EVERY,
                     cache_policy=policy or "static")
    rt = make_sim_runtime(cfg, sp, xplan, opt, spec=spec)
    ctl = StalenessController(refresh_every=REFRESH_EVERY)
    return cfg, rt, xplan, parts, opt, planner, ctl


def _train(tiny: bool, spec: str | None = None, guard_kw: dict | None = None,
           features: str = "host", policy: str | None = None, tracer=None):
    from repro.dist import train_capgnn
    from repro.faults import FaultPlan, GuardConfig

    cfg, rt, xplan, parts, opt, planner, ctl = _build(tiny, features, policy)
    faults = FaultPlan.parse(spec, seed=0) if spec else None
    guard = GuardConfig(**guard_kw) if guard_kw is not None else None
    _, rep = train_capgnn(cfg, rt, xplan, parts, opt, epochs=EPOCHS,
                          controller=ctl, spec=rt.spec, planner=planner,
                          tracer=tracer, faults=faults, guard=guard)
    return rep


def fault_matrix_section(tiny: bool) -> list[dict]:
    """One cell per (fault class, feature mode): completes, exact
    accounting, loss within tolerance of the clean run."""
    clean: dict = {}        # (features, policy) -> clean losses
    rows = []
    for row in FAULT_MATRIX:
        policy = row.get("policy")
        for features in row["modes"]:
            key = (features, policy)
            if key not in clean:
                clean[key] = _train(tiny, features=features,
                                    policy=policy).losses
            rep = _train(tiny, spec=row["spec"], guard_kw=row["guard"],
                         features=features, policy=policy)
            injected = rep.faults_injected[row["kind"]]
            defended = rep.fault_events[row["defense"]]
            gap = abs(rep.losses[-1] - clean[key][-1])
            rows.append({
                "kind": row["kind"], "features": features,
                "injected": int(injected), "defended": int(defended),
                "accounting_exact": bool(injected == defended
                                         and injected > 0),
                "completed": bool(len(rep.losses) == EPOCHS
                                  and np.isfinite(rep.losses[-1])),
                "loss_clean": float(clean[key][-1]),
                "loss_faulted": float(rep.losses[-1]),
                "loss_gap": float(gap),
                "loss_within_tol": bool(gap <= LOSS_TOL),
                "events": {k: v for k, v in rep.fault_events.items() if v},
            })
    return rows


def accounting_section(tiny: bool, out_dir: str) -> dict:
    """Combined-fault traced run: per-step counter deltas must sum to the
    report's ledgers exactly; exports the Perfetto timeline when
    ``REPRO_BENCH_TRACE=1`` (CI gates its span kinds)."""
    from repro.obs import Tracer

    tr = Tracer()
    rep = _train(tiny, spec="fetch_drop@3;halo_corrupt@4;grad_nan@5",
                 guard_kw={"guard_every": 2, "fetch_retries": 1,
                           "checksums": True},
                 features="host", tracer=tr)
    tot = tr.totals()
    events_match = all(tot[k] == v for k, v in rep.fault_events.items())
    injected_match = tot["faults_injected"] == sum(
        rep.faults_injected.values())
    out = {
        "trace_events_match_report": bool(events_match),
        "trace_injected_match_report": bool(injected_match),
        "injected": {k: v for k, v in rep.faults_injected.items() if v},
        "events": {k: v for k, v in rep.fault_events.items() if v},
    }
    if bool(int(os.environ.get("REPRO_BENCH_TRACE", "0"))):
        out["trace_file"] = tr.export(out_dir,
                                      prefix="fault_tolerance")["trace"]
    return out


def checkpoint_section(tiny: bool) -> dict:
    """``ckpt_truncate`` vs the checksummed checkpoint format: detect,
    fall back, restore bit-for-bit."""
    import tempfile
    import warnings

    import jax

    from repro.checkpoint import (CheckpointCorruptError, latest_step,
                                  load_checkpoint, save_checkpoint,
                                  verify_checkpoint)
    from repro.dist import train_capgnn
    from repro.faults import FaultPlan

    cfg, rt, xplan, parts, opt, planner, ctl = _build(tiny)
    half = EPOCHS // 2
    params, rep = train_capgnn(cfg, rt, xplan, parts, opt, epochs=half,
                               controller=ctl, spec=rt.spec)
    mid = {"params": params, "opt_state": rep.final_opt_state}
    mid_host = jax.tree.map(np.asarray, mid)
    out: dict = {}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, half, mid)
        params, rep = train_capgnn(cfg, rt, xplan, parts, opt,
                                   epochs=EPOCHS - half, controller=ctl,
                                   spec=rt.spec, params0=params,
                                   opt_state0=rep.final_opt_state)
        save_checkpoint(d, EPOCHS,
                        {"params": params,
                         "opt_state": rep.final_opt_state})
        assert latest_step(d) == EPOCHS
        fp = FaultPlan.parse("ckpt_truncate@0:frac=0.4", seed=0)
        fp.truncate_checkpoint(os.path.join(d, f"ckpt_{EPOCHS:08d}.npz"))
        try:
            verify_checkpoint(d, EPOCHS)
            detected = False
        except CheckpointCorruptError:
            detected = True
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            fallback = latest_step(d)
        restored = load_checkpoint(d, half, mid)
        flat_r = jax.tree.leaves(jax.tree.map(np.asarray, restored))
        flat_m = jax.tree.leaves(mid_host)
        exact = all(np.array_equal(a, b) for a, b in zip(flat_r, flat_m))
        out = {
            "injected": int(fp.injected["ckpt_truncate"]),
            "truncation_detected": bool(detected),
            "fallback_step": fallback,
            "fallback_ok": bool(fallback == half),
            "restore_bit_exact": bool(exact),
        }
    return out


# ---------------------------------------------------- forced-mesh transports

def spmd_sweep(tiny: bool, transports=("allgather", "p2p")) -> dict:
    """Runs in the forced-4-device child: SPMD host mode over both halo
    transports under a combined fault spec, exact accounting per
    transport."""
    import jax
    jax.devices()           # lock the forced host device count first
    from repro.core import (PROFILES, StalenessController, build_cache_plan,
                            cal_capacity)
    from repro.data import make_task
    from repro.dist import (TrainSpec, build_exchange_plan, stack_partitions,
                            train_capgnn)
    from repro.dist.capgnn_spmd import make_spmd_runtime
    from repro.faults import FaultPlan, GuardConfig
    from repro.graph import build_partition, metis_partition
    from repro.models.gnn import GNNConfig
    from repro.optim import adam

    parts = 4
    scale = BENCH_SCALE["flickr"] / (16 if tiny else 4)
    task = make_task("flickr", scale=scale, feat_dim=16, seed=0)
    ps = build_partition(task.graph,
                         metis_partition(task.graph, parts, seed=0), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=16, out_dim=task.num_classes, num_layers=2)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * parts,
                       m_cpu_gib=1.0)
    plan = build_cache_plan(ps, cap, refresh_every=REFRESH_EVERY)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    mesh = jax.make_mesh((parts,), ("data",))

    def run(transport, spec=None, guard=None):
        tspec = TrainSpec(transport=transport, features="host",
                          refresh_every=REFRESH_EVERY)
        rt = make_spmd_runtime(cfg, sp, xplan, opt, mesh, spec=tspec)
        ctl = StalenessController(refresh_every=REFRESH_EVERY)
        faults = FaultPlan.parse(spec, seed=0) if spec else None
        _, rep = train_capgnn(cfg, rt, xplan, parts, opt, epochs=EPOCHS,
                              controller=ctl, spec=tspec, faults=faults,
                              guard=guard)
        return rep

    spec = "fetch_drop@3;grad_nan@5"
    out = {"transports": {}}
    for transport in transports:
        clean = run(transport)
        rep = run(transport, spec,
                  GuardConfig(guard_every=2, fetch_retries=1))
        exact = (rep.fault_events["fetch_errors"] > 0
                 and rep.faults_injected["fetch_drop"]
                 == rep.fault_events["fetch_errors"]
                 and rep.fault_events["rollbacks"] > 0
                 and rep.faults_injected["grad_nan"]
                 == rep.fault_events["rollbacks"])
        gap = abs(rep.losses[-1] - clean.losses[-1])
        out["transports"][transport] = {
            "completed": bool(len(rep.losses) == EPOCHS
                              and np.isfinite(rep.losses[-1])),
            "accounting_exact": bool(exact),
            "loss_clean": float(clean.losses[-1]),
            "loss_faulted": float(rep.losses[-1]),
            "loss_within_tol": bool(gap <= LOSS_TOL),
            "injected": {k: v for k, v in rep.faults_injected.items()
                         if v},
            "events": {k: v for k, v in rep.fault_events.items() if v},
        }
    out["exact_all"] = bool(all(
        r["completed"] and r["accounting_exact"] and r["loss_within_tol"]
        for r in out["transports"].values()))
    return out


def _spmd_subprocess(tiny: bool, transports=("allgather", "p2p")) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["REPRO_BENCH_TINY"] = "1" if tiny else "0"
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.fault_tolerance",
         "--spmd-child", "--transport", *transports],
        capture_output=True, text=True, timeout=3600, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if res.returncode != 0:
        raise RuntimeError("fault_tolerance spmd child failed:\n"
                           + res.stdout[-2000:] + res.stderr[-2000:])
    return json.loads(res.stdout.splitlines()[-1])


def run(out_dir: str = DEFAULT_OUT, tiny: bool | None = None,
        transports=("allgather", "p2p")) -> dict:
    if tiny is None:
        tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
    matrix = fault_matrix_section(tiny)
    acct = accounting_section(tiny, out_dir)
    ckpt = checkpoint_section(tiny)
    spmd = _spmd_subprocess(tiny, transports)

    out = {
        "tiny": bool(tiny),
        "classes": len(matrix),
        "completed_all": bool(all(r["completed"] for r in matrix)),
        "accounting_exact_all": bool(all(r["accounting_exact"]
                                         for r in matrix)),
        "loss_within_tol_all": bool(all(r["loss_within_tol"]
                                        for r in matrix)),
        "trace_accounting_match": bool(
            acct["trace_events_match_report"]
            and acct["trace_injected_match_report"]),
        "ckpt_truncation_detected": ckpt["truncation_detected"],
        "ckpt_fallback_ok": ckpt["fallback_ok"],
        "ckpt_restore_bit_exact": ckpt["restore_bit_exact"],
        "spmd_exact_both_transports": spmd["exact_all"],
        "matrix": matrix,
        "accounting": acct,
        "checkpoint": ckpt,
        "spmd": spmd,
    }
    if "trace_file" in acct:
        # "trace_file" is in the regression gate's SKIP_KEYS: attached,
        # never gated
        out["trace_file"] = acct["trace_file"]
    save(out_dir, "fault_tolerance", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spmd-child", action="store_true",
                    help="internal: run only the SPMD fault sweep in this "
                         "(forced multi-device) process, JSON on stdout")
    ap.add_argument("--transport", nargs="*",
                    default=["allgather", "p2p"],
                    choices=["allgather", "p2p"])
    # parse_known_args: tolerate the benchmarks.run orchestrator's flags
    args, _ = ap.parse_known_args(argv)
    if args.spmd_child:
        tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
        print(json.dumps(spmd_sweep(tiny, tuple(args.transport))))
        return
    out = run(transports=tuple(args.transport))
    print(f"fault_tolerance: {out['classes']} fault cells")
    for r in out["matrix"]:
        print(f"  {r['kind']:13s} [{r['features']:6s}]: injected "
              f"{r['injected']} == defended {r['defended']}, loss "
              f"{r['loss_clean']:.4f} -> {r['loss_faulted']:.4f} "
              f"(gap {r['loss_gap']:.4f})")
    c = out["checkpoint"]
    print(f"  ckpt_truncate: detected={c['truncation_detected']}, "
          f"fallback -> step {c['fallback_step']}, "
          f"bit-exact restore={c['restore_bit_exact']}")
    for t, r in out["spmd"]["transports"].items():
        print(f"  spmd {t:9s}: exact={r['accounting_exact']}, loss "
              f"{r['loss_clean']:.4f} -> {r['loss_faulted']:.4f}")
    assert out["completed_all"], "a faulted run did not complete"
    assert out["accounting_exact_all"], \
        "injected fault counts != counted defense events"
    assert out["loss_within_tol_all"], \
        f"a faulted run's final loss drifted beyond {LOSS_TOL}"
    assert out["trace_accounting_match"], \
        "per-step trace counters disagree with the report ledgers"
    assert (out["ckpt_truncation_detected"] and out["ckpt_fallback_ok"]
            and out["ckpt_restore_bit_exact"]), \
        "checkpoint integrity defense broken"
    assert out["spmd_exact_both_transports"], \
        "SPMD fault accounting drifted on a transport"


if __name__ == "__main__":
    main()
