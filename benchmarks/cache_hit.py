"""Paper Figs. 14-15: cache hit rate vs priority policy / capacity / policy.

Fig. 14 — prioritising HIGH-overlap halo vertices beats LOW-overlap priority
at equal capacity (JACA's Eq. 2 ranking).
Fig. 15 — hit rate vs cache capacity for JACA (static overlap plan) vs FIFO
and LRU trace simulation; JACA dominates at small capacity and saturates.
"""
from __future__ import annotations

import numpy as np

from repro.core import (CacheCapacity, build_cache_plan, plan_hit_rate,
                        simulate_policy_hit_rate)
from repro.graph import build_partition, metis_partition
from ._util import DEFAULT_OUT, bench_task, save


def _plan_hit(ps, cap_per_worker: int, policy: str) -> float:
    cap = CacheCapacity(c_gpu=[cap_per_worker] * ps.num_parts,
                        c_cpu=cap_per_worker)
    plan = build_cache_plan(ps, cap, policy=policy)
    return plan_hit_rate(plan)["hit"]


def run(out_dir: str = DEFAULT_OUT) -> dict:
    task = bench_task("reddit")
    g = task.graph

    # ---- Fig. 14: high vs low overlap priority, parts 2..8, 20% capacity.
    # Hit rate over the epoch halo-access stream with a shared cache of
    # fixed capacity: residency chosen by priority, a vertex with overlap
    # R(v) serves R(v) accesses per layer when resident — which is exactly
    # why the high-overlap ranking wins (Eq. 2).
    fig14 = []
    for p in (2, 4, 8):
        ps = build_partition(g, metis_partition(g, p, seed=0), hops=1)
        cap20 = max(1, int(0.2 * ps.halo_union().size))
        fig14.append({
            "parts": p, "capacity": cap20,
            "hit_high": simulate_policy_hit_rate(ps, cap20, "overlap_high"),
            "hit_low": simulate_policy_hit_rate(ps, cap20, "overlap_low"),
            "hit_random": simulate_policy_hit_rate(ps, cap20, "random"),
        })
    high_wins = all(r["hit_high"] >= r["hit_low"] for r in fig14)

    # ---- Fig. 15: capacity sweep, JACA vs FIFO vs LRU
    fig15 = []
    for p in (2, 4):
        ps = build_partition(g, metis_partition(g, p, seed=0), hops=1)
        max_halo = max(pt.n_halo for pt in ps.parts)
        for frac in (0.05, 0.1, 0.2, 0.4, 0.7, 1.0):
            cap = max(1, int(frac * max_halo))
            fig15.append({
                "parts": p, "capacity": cap, "frac": frac,
                "jaca": _plan_hit(ps, cap, "overlap_high"),
                "fifo": simulate_policy_hit_rate(ps, cap * p, "fifo"),
                "lru": simulate_policy_hit_rate(ps, cap * p, "lru"),
            })
    jaca_beats = np.mean([r["jaca"] >= max(r["fifo"], r["lru"]) - 0.02
                          for r in fig15])
    out = {"fig14": fig14, "fig14_high_priority_wins": bool(high_wins),
           "fig15": fig15, "fig15_jaca_wins_frac": float(jaca_beats)}
    save(out_dir, "cache_hit", out)
    return out


def main():
    out = run()
    print("cache_hit: high-overlap priority wins =",
          out["fig14_high_priority_wins"])
    for r in out["fig14"]:
        print(f"  p={r['parts']} hit(high)={r['hit_high']:.3f} "
              f"hit(low)={r['hit_low']:.3f}")
    print(f"  JACA >= best(FIFO,LRU) on {out['fig15_jaca_wins_frac']:.0%} "
          "of capacity points")


if __name__ == "__main__":
    main()
