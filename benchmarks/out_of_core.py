"""Out-of-core host feature store: step-time overhead of host-resident vs
device-resident features (``features="host"`` vs ``"device"``), a section
training a graph whose stacked features exceed a simulated device budget,
and an exact host-fetch accounting harness on a forced multi-device mesh.

Three sections:

- **overhead sweep** — steady-state pipelined step time with the halo
  feature table device-resident vs host-resident, across feature dims and
  host-tier fractions (the share of halo rows served from the host store
  instead of the local device cache).  The double-buffered prefetch ring
  should keep the host-backed step within ~1.5x of device-resident at the
  flickr benchmark scale (asserted by ``main``).
- **out-of-core budget** — device/host persistent feature residency under
  a simulated device byte budget set *between* the two: the stacked
  device-mode table exceeds it, the host-mode device footprint (the
  layer-0 local-tier block only) fits, and training still converges.
  Transient staging bytes (the in-flight prefetch buffers) are reported
  separately — they bound the peak, not the persistent residency.
- **accounting** — re-execs this module with
  ``--xla_force_host_platform_device_count=4`` and runs the SPMD runtime
  in host mode over both halo transports, asserting plan-counted host
  fetch rows/bytes == the store's consumed staged rows/bytes exactly
  (the identity :meth:`~repro.dist.ExchangePlan.host_fetch_rows`
  promises), plus the d2h writeback bytes of every emit step.

``REPRO_BENCH_TINY=1`` shrinks everything for CI smoke runs.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

from ._util import BENCH_SCALE, DEFAULT_OUT, save

EPOCHS = 9          # with refresh_every=4: plain refresh @0, pipelined @4,8
REFRESH_EVERY = 4


def _forced_cap(ps, host_frac: float, parts: int):
    """Capacity forcing all three tiers non-empty with ``host_frac`` of the
    widest worker's halo rows host-resident at layer 0 (uncached + global);
    the plan's actual tier sizes are what the sweep records."""
    from repro.core import CacheCapacity
    max_halo = max(pt.n_halo for pt in ps.parts)
    local = max(1, int(round((1.0 - host_frac) * max_halo)))
    # split the host share between the deduplicated global tier and
    # per-step uncached rows — both stage h2d at layer 0 in host mode
    c_cpu = max(1, int(round(0.5 * host_frac * max_halo * parts)))
    return CacheCapacity(c_gpu=[local] * parts, c_cpu=c_cpu)


def _time_step(fn, params, opt, cfg, xplan, parts, features: str = "device",
               repeats: int = 5, inner: int = 2) -> float:
    """Best-of-``repeats`` per-step seconds, chaining the returned state
    (steady-state loop; host mode includes the staging/prefetch work the
    wrapper does on the host thread)."""
    import jax
    import jax.numpy as jnp
    from repro.dist import init_caches

    pp = jax.tree.map(jnp.copy, params)
    oo = opt.init(pp)
    cc = init_caches(cfg, xplan, parts, features=features)
    pp, oo, cc, m = fn(pp, oo, cc)          # compile + warm-up
    jax.block_until_ready(m["loss"])
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            pp, oo, cc, m = fn(pp, oo, cc)
        jax.block_until_ready(m["loss"])
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


def overhead_sweep(tiny: bool) -> list[dict]:
    """Pipelined step time, device- vs host-resident features, across
    feature dims and host-tier fractions at flickr benchmark scale."""
    import jax
    from repro.core import build_cache_plan
    from repro.data import make_task
    from repro.dist import (build_exchange_plan, make_sim_runtime,
                            stack_partitions)
    from repro.graph import build_partition, metis_partition
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import adam

    parts = 4
    scale = BENCH_SCALE["flickr"] / (8 if tiny else 1)
    dims = (32, 64) if tiny else (64, 256)
    fracs = (0.3, 0.7) if tiny else (0.2, 0.5, 0.8)

    rows = []
    for feat_dim in dims:
        task = make_task("flickr", scale=scale, feat_dim=feat_dim)
        ps = build_partition(task.graph,
                             metis_partition(task.graph, parts, seed=0),
                             hops=1)
        cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                        hidden_dim=64, out_dim=task.num_classes,
                        num_layers=3)
        sp = stack_partitions(ps, task)
        opt = adam(0.01)
        params = init_gnn(jax.random.PRNGKey(0), cfg)
        for frac in fracs:
            plan = build_cache_plan(ps, _forced_cap(ps, frac, parts),
                                    refresh_every=REFRESH_EVERY)
            xplan = build_exchange_plan(ps, plan)
            rt_dev = make_sim_runtime(cfg, sp, xplan, opt)
            rt_host = make_sim_runtime(cfg, sp, xplan, opt,
                                       features="host", prefetch_depth=2)
            dev_s = _time_step(rt_dev.step_pipelined, params, opt, cfg,
                               xplan, parts)
            host_s = _time_step(rt_host.step_pipelined, params, opt, cfg,
                                xplan, parts, features="host")
            rows.append({
                "feat_dim": feat_dim, "host_frac": frac,
                "host_rows_l0": int(xplan.host.n_fetch_rows),
                "local_rows_l0": int(xplan.local.n_rows),
                "global_unique": int(xplan.glob.n_unique),
                "device_ms": dev_s * 1e3, "host_ms": host_s * 1e3,
                "overhead": host_s / max(dev_s, 1e-12),
            })
    return rows


def ooc_budget_section(tiny: bool, tracer=None) -> dict:
    """Train with the stacked halo feature table exceeding a simulated
    device budget: host mode keeps only the layer-0 local-tier block
    persistent on device; the full table plus the device-mode global
    caches would not fit."""
    import jax
    from repro.core import StalenessController, build_cache_plan
    from repro.data import make_task
    from repro.dist import (build_exchange_plan, init_caches,
                            make_sim_runtime, stack_partitions, train_capgnn)
    from repro.graph import build_partition, metis_partition
    from repro.models.gnn import GNNConfig
    from repro.optim import adam

    parts = 4
    scale = BENCH_SCALE["flickr"] / (8 if tiny else 1)
    task = make_task("flickr", scale=scale, feat_dim=64)
    ps = build_partition(task.graph,
                         metis_partition(task.graph, parts, seed=0), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=64, out_dim=task.num_classes, num_layers=3)
    plan = build_cache_plan(ps, _forced_cap(ps, 0.7, parts),
                            refresh_every=REFRESH_EVERY)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    rt = make_sim_runtime(cfg, sp, xplan, opt, features="host",
                          prefetch_depth=2)
    store = rt.host_store

    # persistent residency: device mode keeps the whole stacked halo table
    # plus the per-layer global cache buffers on device for the entire
    # run; host mode keeps only the staged layer-0 local-tier block
    cc_dev = init_caches(cfg, xplan, parts)
    device_bytes = int(sp.halo_feats.nbytes
                       + sum(int(np.prod(g.shape)) * 4
                             for g in cc_dev["global"]))
    host_bytes = int(rt._state["l0loc"].nbytes)
    budget = (device_bytes + host_bytes) // 2   # simulated device budget
    ex_dims = cfg.feat_dims[1:cfg.num_layers]
    staging_bytes = int(store.prefetch_depth * parts * xplan.host.width
                        * cfg.feat_dims[0] * store.dtype_bytes
                        + sum(xplan.glob.n_unique * d * store.dtype_bytes
                              for d in ex_dims))

    ctl = StalenessController(refresh_every=REFRESH_EVERY)
    params, rep = train_capgnn(cfg, rt, xplan, parts, opt, epochs=EPOCHS,
                               controller=ctl, pipeline=True, eval_every=0,
                               tracer=tracer)
    # schedule: plain refresh @0 (no stale global staged), pipelined
    # refreshes + cached steps stage the global buffers every other step
    per = xplan.host_fetch_rows(True, len(ex_dims))
    expected_rows = EPOCHS * per["l0"] + (EPOCHS - 1) * per["global"]
    _, test_acc = rt.evaluate(params, "test")
    return {
        "nodes": int(task.graph.num_nodes),
        "device_feature_bytes": device_bytes,
        "host_device_feature_bytes": host_bytes,
        "sim_device_budget_bytes": int(budget),
        "peak_staging_bytes": staging_bytes,
        "host_store_resident_bytes": int(store.resident_bytes()),
        "exceeds_device_budget": bool(device_bytes > budget),
        "host_fits_budget": bool(host_bytes <= budget),
        "loss_first": rep.losses[0], "loss_last": rep.losses[-1],
        "loss_decreased": bool(rep.losses[-1] < rep.losses[0]),
        "test_acc": float(test_acc),
        "host_fetch_rows": int(rep.host_fetch_rows),
        "host_fetch_rows_expected": int(expected_rows),
        "rows_match": bool(rep.host_fetch_rows == expected_rows),
        "host_fetch_bytes": int(rep.host_fetch_bytes),
        "host_writeback_bytes": int(rep.host_writeback_bytes),
    }


# ------------------------------------------------- forced-mesh accounting

def accounting_sweep(tiny: bool, transports=("allgather", "p2p")) -> dict:
    """Runs in the forced-4-device child: SPMD host mode over both halo
    transports with exact plan-vs-store fetch accounting."""
    import jax
    jax.devices()           # lock the forced host device count first
    import jax.numpy as jnp
    from repro.core import build_cache_plan
    from repro.data import make_task
    from repro.dist import (build_exchange_plan, init_caches,
                            stack_partitions)
    from repro.dist.capgnn_spmd import make_spmd_runtime
    from repro.graph import build_partition, metis_partition
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import adam

    parts = 4
    scale = BENCH_SCALE["flickr"] / (16 if tiny else 4)
    task = make_task("flickr", scale=scale, feat_dim=32)
    ps = build_partition(task.graph,
                         metis_partition(task.graph, parts, seed=0), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=32, out_dim=task.num_classes, num_layers=3)
    plan = build_cache_plan(ps, _forced_cap(ps, 0.7, parts),
                            refresh_every=REFRESH_EVERY)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    mesh = jax.make_mesh((parts,), ("data",))
    params = init_gnn(jax.random.PRNGKey(0), cfg)

    ex_dims = cfg.feat_dims[1:cfg.num_layers]
    per = xplan.host_fetch_rows(True, len(ex_dims))
    # step 0 is a plain refresh (fresh global built on-wire, nothing
    # staged); every later step — cached or pipelined — stages the
    # host-resident global buffers alongside the layer-0 rows
    expected_rows = EPOCHS * per["l0"] + (EPOCHS - 1) * per["global"]
    refresh_b = xplan.host_bytes_per_step(cfg.feat_dims[0], ex_dims, False)
    stale_b = xplan.host_bytes_per_step(cfg.feat_dims[0], ex_dims, True)
    expected_bytes = refresh_b + (EPOCHS - 1) * stale_b
    n_emit = 1 + (EPOCHS - 1) // REFRESH_EVERY       # steps 0, 4, 8
    expected_wb = n_emit * xplan.host_writeback_bytes(ex_dims)

    out = {"parts": parts, "tiny": bool(tiny),
           "nodes": int(task.graph.num_nodes),
           "host_rows_l0": int(xplan.host.n_fetch_rows),
           "global_unique": int(xplan.glob.n_unique),
           "transports": {}}
    losses = {}
    for transport in transports:
        rt = make_spmd_runtime(cfg, sp, xplan, opt, mesh,
                               transport=transport, features="host")
        store = rt.host_store
        snap = store.snapshot()
        pp = jax.tree.map(jnp.copy, params)
        oo = opt.init(pp)
        cc = init_caches(cfg, xplan, parts, features="host")
        hist = []
        for step in range(EPOCHS):
            if step == 0:
                fn = rt.step_refresh
            elif step % REFRESH_EVERY == 0:
                fn = rt.step_pipelined
            else:
                fn = rt.step_cached
            pp, oo, cc, m = fn(pp, oo, cc)
            hist.append(float(m["loss"]))
        d = store.delta(snap)
        losses[transport] = hist
        out["transports"][transport] = {
            "fetch_rows": d["fetch_rows"],
            "expected_rows": expected_rows,
            "fetch_bytes": d["fetch_bytes"],
            "expected_bytes": expected_bytes,
            "writeback_bytes": d["writeback_bytes"],
            "expected_writeback_bytes": expected_wb,
            "rows_match": bool(d["fetch_rows"] == expected_rows),
            "bytes_match": bool(d["fetch_bytes"] == expected_bytes
                                and d["writeback_bytes"] == expected_wb),
            "loss_last": hist[-1],
        }
    out["rows_match_all"] = bool(all(
        r["rows_match"] and r["bytes_match"]
        for r in out["transports"].values()))
    if len(losses) == 2:
        a, b = (np.array(losses[t]) for t in transports)
        out["transport_losses_agree"] = bool(np.abs(a - b).max() <= 1e-5)
    return out


def _accounting_subprocess(tiny: bool,
                           transports=("allgather", "p2p")) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["REPRO_BENCH_TINY"] = "1" if tiny else "0"
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.out_of_core",
         "--accounting-child", "--transport", *transports],
        capture_output=True, text=True, timeout=3600, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if res.returncode != 0:
        raise RuntimeError("out_of_core accounting child failed:\n"
                           + res.stdout[-2000:] + res.stderr[-2000:])
    return json.loads(res.stdout.splitlines()[-1])


def run(out_dir: str = DEFAULT_OUT, tiny: bool | None = None,
        transports=("allgather", "p2p")) -> dict:
    if tiny is None:
        tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
    tracer = None
    if bool(int(os.environ.get("REPRO_BENCH_TRACE", "0"))):
        from repro.obs import Tracer
        tracer = Tracer()
    sweep = overhead_sweep(tiny)
    ooc = ooc_budget_section(tiny, tracer=tracer)
    acct = _accounting_subprocess(tiny, transports)

    overheads = np.array([r["overhead"] for r in sweep])
    out = {
        "tiny": bool(tiny),
        "nodes": ooc["nodes"],
        # geometric mean across (feat_dim, host_frac) cells; max is the
        # worst cell.  "_leq_" marks the bool as timing-derived so the
        # regression gate skips it (it is asserted by main() instead).
        "host_overhead_pipelined": float(np.exp(np.log(overheads).mean())),
        "host_overhead_max": float(overheads.max()),
        "host_overhead_leq_1p5": bool(
            np.exp(np.log(overheads).mean()) <= 1.5),
        "exceeds_device_budget": ooc["exceeds_device_budget"],
        "host_fits_budget": ooc["host_fits_budget"],
        "ooc_loss_decreased": ooc["loss_decreased"],
        "sim_host_rows_match": ooc["rows_match"],
        "host_fetch_rows": ooc["host_fetch_rows"],
        "host_fetch_bytes": ooc["host_fetch_bytes"],
        "accounting_rows_match_both_transports": acct["rows_match_all"],
        "transport_losses_agree": acct.get("transport_losses_agree", True),
        "overhead_sweep": sweep,
        "out_of_core": ooc,
        "accounting": acct,
    }
    if tracer is not None:
        # "trace_file" is in the regression gate's SKIP_KEYS: attached,
        # never gated
        out["trace_file"] = tracer.export(out_dir,
                                          prefix="out_of_core")["trace"]
    save(out_dir, "out_of_core", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--accounting-child", action="store_true",
                    help="internal: run only the SPMD accounting sweep in "
                         "this (forced multi-device) process, JSON on stdout")
    ap.add_argument("--transport", nargs="*",
                    default=["allgather", "p2p"],
                    choices=["allgather", "p2p"])
    # parse_known_args: tolerate the benchmarks.run orchestrator's flags
    args, _ = ap.parse_known_args(argv)
    if args.accounting_child:
        tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
        print(json.dumps(accounting_sweep(tiny, tuple(args.transport))))
        return
    out = run(transports=tuple(args.transport))
    print(f"out_of_core: {out['nodes']} nodes, host/device pipelined step "
          f"overhead {out['host_overhead_pipelined']:.2f}x (max "
          f"{out['host_overhead_max']:.2f}x)")
    for r in out["overhead_sweep"]:
        print(f"  F={r['feat_dim']:4d} host_frac={r['host_frac']:.1f}: "
              f"device {r['device_ms']:7.2f} ms, host {r['host_ms']:7.2f} ms"
              f" ({r['overhead']:.2f}x), l0 host rows {r['host_rows_l0']}")
    o = out["out_of_core"]
    print(f"  budget: device-resident {o['device_feature_bytes']:.3e} B > "
          f"budget {o['sim_device_budget_bytes']:.3e} B >= host-resident "
          f"{o['host_device_feature_bytes']:.3e} B; "
          f"loss {o['loss_first']:.3f} -> {o['loss_last']:.3f}, "
          f"acc {o['test_acc']:.2%}")
    for t, r in out["accounting"]["transports"].items():
        print(f"  accounting {t:9s}: fetched {r['fetch_rows']} rows "
              f"(plan {r['expected_rows']}), {r['fetch_bytes']} B "
              f"(plan {r['expected_bytes']}), writeback "
              f"{r['writeback_bytes']} B — match={r['rows_match']}/"
              f"{r['bytes_match']}")
    assert out["exceeds_device_budget"] and out["host_fits_budget"], \
        "out-of-core budget demonstration broken"
    assert out["ooc_loss_decreased"], "host-mode training failed to learn"
    assert out["sim_host_rows_match"], "sim host-fetch accounting drifted"
    assert out["accounting_rows_match_both_transports"], \
        "SPMD host-fetch accounting drifted from the plan"
    assert out["host_overhead_pipelined"] <= 1.5, \
        (f"host-backed pipelined step {out['host_overhead_pipelined']:.2f}x "
         "device-resident (> 1.5x budget)")


if __name__ == "__main__":
    main()
