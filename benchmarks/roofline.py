"""§Roofline (deliverable g): three-term roofline per (arch x shape x mesh)
from the dry-run artifacts in experiments/dryrun/.

  compute term    = HLO_FLOPs / peak_FLOP/s          (per device)
  memory term     = HLO_bytes / HBM_bw               (per device)
  collective term = collective_bytes / link_bw       (per device)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Also reports MODEL_FLOPS = 6*N*D (train) or 2*N_active*D (inference) and
the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * devices).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import INPUT_SHAPES, get_config, canonical
from ._util import DEFAULT_OUT, save

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(canonical(arch.split("+")[0]))  # strip +swa variant tag
    seq, batch, kind = INPUT_SHAPES[shape]
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * seq * batch
    if kind == "prefill":
        return 2.0 * n * seq * batch
    return 2.0 * n * batch           # decode: one token per sequence


def analyse(rec: dict) -> dict:
    t_comp = rec["hlo_flops_per_device"] / PEAK_FLOPS
    t_mem = rec["hlo_bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_per_device"] / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    total_hlo = rec["hlo_flops_per_device"] * rec["devices"]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **{k: float(f"{v:.6g}") for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": mf / total_hlo if total_hlo else 0.0,
        "step_time_lb_s": max(terms.values()),
        "mfu_bound": (mf / rec["devices"] / PEAK_FLOPS)
        / max(max(terms.values()), 1e-12),
        "temp_bytes_per_device": rec["memory"]["temp_size"],
    }


def run(out_dir: str = DEFAULT_OUT) -> dict:
    rows, perf_rows = [], []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyse(rec)
        row["act_mode"] = rec.get("act_mode", "baseline")
        (rows if row["act_mode"] == "baseline" else perf_rows).append(row)
    by_dominant = {}
    for r in rows:
        by_dominant.setdefault(r["dominant"], []).append(
            f"{r['arch']}/{r['shape']}/{r['mesh']}")
    out = {"rows": rows, "perf_rows": perf_rows, "count": len(rows),
           "dominant_histogram": {k: len(v) for k, v in by_dominant.items()},
           "by_dominant": by_dominant}
    save(out_dir, "roofline", out)
    return out


def table(rows, mesh="16x16") -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful | MFU-bound |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['mfu_bound']:.2f} |")
    return "\n".join(lines)


def main():
    out = run()
    print(f"roofline: {out['count']} (arch x shape x mesh) rows, "
          f"dominant-term histogram {out['dominant_histogram']}")
    print(table(out["rows"]))
    if out["perf_rows"]:
        print("\nblock_sp (§Perf hillclimb) rows:")
        print(table(out["perf_rows"]))


if __name__ == "__main__":
    main()
