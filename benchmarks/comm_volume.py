"""Paper Figs. 16-19: epoch time & communication volume vs cache capacity,
plus the overhead / benefit-to-overhead ratios of the caching machinery.

Byte counts are exact (plan properties); wall time is CPU wall time of the
compiled stacked runtime.  The paper's check_cache/pick_cache bookkeeping
maps here to (a) the host-side plan build and (b) the cache scatter/gather
ops inside the step; (a) is measured directly, (b) rides in the step time.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (CacheCapacity, StalenessController, build_cache_plan,
                        comm_bytes_per_step)
from repro.dist import build_exchange_plan, make_sim_runtime, stack_partitions, train_capgnn
from repro.graph import build_partition, metis_partition
from repro.models.gnn import GNNConfig
from repro.optim import adam
from ._util import DEFAULT_OUT, Timer, bench_task, save

EPOCHS = 12


def _one(task, ps, cap_frac: float, parts: int, refresh_every: int = 4):
    max_halo = max(pt.n_halo for pt in ps.parts)
    cap = max(0, int(cap_frac * max_halo))
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=128, out_dim=task.num_classes, num_layers=3)
    with Timer() as t_plan:
        capc = CacheCapacity(c_gpu=[cap] * parts, c_cpu=cap * parts)
        plan = build_cache_plan(ps, capc, refresh_every=refresh_every)
        xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    runtime = make_sim_runtime(cfg, sp, xplan, opt)
    ctl = StalenessController(refresh_every=refresh_every)
    with Timer() as t_train:
        _, rep = train_capgnn(cfg, runtime, xplan, parts, opt, epochs=EPOCHS,
                              controller=ctl, eval_every=0)
    vol = comm_bytes_per_step(plan, cfg.hidden_dim)
    return {
        "cap_frac": cap_frac, "capacity": cap,
        "epoch_time_s": t_train.seconds / EPOCHS,
        "plan_build_s": t_plan.seconds,
        "comm_bytes": rep.comm_bytes,
        "comm_bytes_vanilla": rep.comm_bytes_vanilla,
        "comm_reduction": rep.comm_reduction,
        "amortised_bytes_per_step": vol["amortised_bytes"],
    }


def run(out_dir: str = DEFAULT_OUT) -> dict:
    task = bench_task("reddit")
    g = task.graph
    sweeps = {}
    for parts in (2, 4):
        ps = build_partition(g, metis_partition(g, parts, seed=0), hops=1)
        rows = [_one(task, ps, f, parts) for f in (0.0, 0.1, 0.3, 0.6, 1.0)]
        sweeps[f"{parts}p"] = rows

    # Fig. 19 ratios at the 4-partition full-capacity point
    base = sweeps["4p"][0]          # no cache
    best = sweeps["4p"][-1]         # full cache
    overhead_s = best["plan_build_s"] / EPOCHS
    saved_s = base["epoch_time_s"] - best["epoch_time_s"]
    out = {
        "sweeps": sweeps,
        # any non-zero cache beats no cache; the sweep is NOT monotone in
        # capacity because mid-size caches route more vertices through the
        # deduplicated global tier (one broadcast row per unique vertex)
        # while an all-local plan refreshes per-(vertex,consumer) pair —
        # the same "more cache is not always better" shape as paper Fig. 18.
        "cache_beats_no_cache": bool(all(
            r["comm_bytes"] < rows[0]["comm_bytes"]
            for rows in sweeps.values() for r in rows[1:])),
        "overhead_ratio": overhead_s / max(best["epoch_time_s"], 1e-9),
        "benefit_to_overhead": saved_s / max(overhead_s, 1e-9),
        "max_comm_reduction": max(r["comm_reduction"]
                                  for rows in sweeps.values() for r in rows),
    }
    save(out_dir, "comm_volume", out)
    return out


def main():
    out = run()
    print(f"comm_volume: cache beats no cache = {out['cache_beats_no_cache']},"
          f" max reduction = {out['max_comm_reduction']:.1%}")
    for k, rows in out["sweeps"].items():
        line = ", ".join(f"{r['cap_frac']:.1f}:{r['comm_reduction']:.0%}"
                         for r in rows)
        print(f"  {k}: reduction by cap frac {line}")
    print(f"  overhead ratio {out['overhead_ratio']:.4f}, "
          f"benefit/overhead {out['benefit_to_overhead']:.1f}")


if __name__ == "__main__":
    main()
