"""Paper Figs. 16-19: epoch time & communication volume vs cache capacity,
plus the overhead / benefit-to-overhead ratios of the caching machinery —
and the halo-transport sweep: modeled vs HLO-measured wire bytes and
pipelined vs unpipelined step time for ``transport="allgather" | "p2p"``.

Byte counts are exact (plan properties); wall time is CPU wall time of the
compiled stacked runtime.  The paper's check_cache/pick_cache bookkeeping
maps here to (a) the host-side plan build and (b) the cache scatter/gather
ops inside the step; (a) is measured directly, (b) rides in the step time.

The transport sweep needs a multi-device mesh, so it re-execs this module
in a subprocess with ``--xla_force_host_platform_device_count=4`` and
merges the child's JSON into ``experiments/comm_volume.json``.
``REPRO_BENCH_TINY=1`` shrinks both parts for CI smoke runs.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.core import (CacheCapacity, StalenessController, build_cache_plan,
                        comm_bytes_per_step)
from repro.dist import (TrainSpec, build_exchange_plan, make_sim_runtime,
                        stack_partitions, train_capgnn)
from repro.graph import build_partition, metis_partition
from repro.models.gnn import GNNConfig
from repro.optim import adam
from ._util import BENCH_SCALE, DEFAULT_OUT, Timer, bench_task, save

EPOCHS = 12


def _one(task, ps, cap_frac: float, parts: int, refresh_every: int = 4,
         epochs: int = EPOCHS):
    max_halo = max(pt.n_halo for pt in ps.parts)
    cap = max(0, int(cap_frac * max_halo))
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=128, out_dim=task.num_classes, num_layers=3)
    with Timer() as t_plan:
        capc = CacheCapacity(c_gpu=[cap] * parts, c_cpu=cap * parts)
        plan = build_cache_plan(ps, capc, refresh_every=refresh_every)
        xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    spec = TrainSpec(refresh_every=refresh_every)
    runtime = make_sim_runtime(cfg, sp, xplan, opt, spec=spec)
    ctl = StalenessController(refresh_every=refresh_every)
    with Timer() as t_train:
        _, rep = train_capgnn(cfg, runtime, xplan, parts, opt, epochs=epochs,
                              controller=ctl, eval_every=0, spec=spec)
    vol = comm_bytes_per_step(plan, cfg.hidden_dim,
                              dtype_bytes=runtime.halo_dtype_bytes)
    return {
        "cap_frac": cap_frac, "capacity": cap,
        "epoch_time_s": t_train.seconds / epochs,
        "plan_build_s": t_plan.seconds,
        "comm_bytes": rep.comm_bytes,
        "comm_bytes_vanilla": rep.comm_bytes_vanilla,
        "comm_reduction": rep.comm_reduction,
        "amortised_bytes_per_step": vol["amortised_bytes"],
    }


# ------------------------------------------------------- transport sweep

def _time_step(fn, params, opt, cfg, xplan, parts, repeats: int = 5,
               inner: int = 2) -> float:
    """Best-of-``repeats`` per-step seconds of a donated jitted step,
    chaining the returned state (steady-state loop)."""
    import jax
    import jax.numpy as jnp
    from repro.dist import init_caches

    pp = jax.tree.map(jnp.copy, params)
    oo = opt.init(pp)
    cc = init_caches(cfg, xplan, parts)
    pp, oo, cc, m = fn(pp, oo, cc)          # compile + warm-up
    jax.block_until_ready(m["loss"])
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            pp, oo, cc, m = fn(pp, oo, cc)
        jax.block_until_ready(m["loss"])
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


def transport_sweep(tiny: bool, transports=("allgather", "p2p")) -> dict:
    """Runs in the forced-4-device child process: modeled vs HLO-measured
    wire bytes and pipelined vs unpipelined step time per transport on the
    flickr-scale benchmark config."""
    import jax
    jax.devices()           # lock the forced host device count first
    import jax.numpy as jnp
    from repro.core import PROFILES, cal_capacity
    from repro.data import make_task
    from repro.dist import TrainSpec, init_caches
    from repro.dist.capgnn_spmd import make_spmd_runtime
    from repro.launch.dryrun import collective_bytes
    from repro.models.gnn import init_gnn
    from repro.optim import adam as mk_adam

    parts = 4
    scale = BENCH_SCALE["flickr"] / (8 if tiny else 1)
    task = make_task("flickr", scale=scale, feat_dim=64)
    ps = build_partition(task.graph,
                         metis_partition(task.graph, parts, seed=0), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=128, out_dim=task.num_classes, num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * parts)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = mk_adam(0.01)
    mesh = jax.make_mesh((parts,), ("data",))
    params = init_gnn(jax.random.PRNGKey(0), cfg)

    out = {"parts": parts, "num_nodes": int(task.graph.num_nodes),
           "tiny": bool(tiny), "transports": {}}
    for transport in transports:
        rt = make_spmd_runtime(cfg, sp, xplan, opt, mesh,
                               spec=TrainSpec(transport=transport))
        row = {}
        for refresh, key in ((False, "cached"), (True, "refresh")):
            row[f"modeled_{key}_bytes"] = sum(
                xplan.bytes_per_step(d, refresh=refresh,
                                     dtype_bytes=rt.halo_dtype_bytes)
                for d in rt.comm_dims)
            row[f"{key}_rows"] = rt.wire_rows(refresh)
            row[f"{key}_rows_padded"] = rt.wire_rows(refresh, padded=True)
        # HLO-measured per-device collective bytes of one compiled step
        # (includes static-shape padding and grad-transpose collectives)
        pp = jax.tree.map(jnp.copy, params)
        oo = opt.init(pp)
        cc = init_caches(cfg, xplan, parts)
        for name in ("cached", "refresh", "pipelined"):
            hlo = rt.lower_step(name, pp, oo, cc).compile().as_text()
            cb = collective_bytes(hlo)
            row[f"hlo_{name}_collective_bytes_per_device"] = cb["total"]
            row[f"hlo_{name}_collective_counts"] = cb["counts"]
        row["cached_ms"] = _time_step(rt.step_cached, params, opt, cfg,
                                      xplan, parts) * 1e3
        row["refresh_unpipelined_ms"] = _time_step(
            rt.step_refresh, params, opt, cfg, xplan, parts) * 1e3
        row["pipelined_ms"] = _time_step(rt.step_pipelined, params, opt,
                                         cfg, xplan, parts) * 1e3
        out["transports"][transport] = row

    if "p2p" in out["transports"]:
        p2p = out["transports"]["p2p"]
        refresh_rows = p2p["refresh_rows"]
        out["p2p_rows_match_plan"] = bool(
            refresh_rows["uncached"] == xplan.uncached.n_rows
            and refresh_rows["local"] == xplan.local.n_rows
            and refresh_rows["global"] == xplan.glob.n_unique)
        out["pipelined_leq_unpipelined_p2p"] = bool(
            p2p["pipelined_ms"] <= p2p["refresh_unpipelined_ms"])
        out["p2p_pipeline_speedup"] = (
            p2p["refresh_unpipelined_ms"] / max(p2p["pipelined_ms"], 1e-9))
        if "allgather" in out["transports"]:
            ag = out["transports"]["allgather"]
            out["p2p_vs_allgather_row_ratio"] = (
                refresh_rows["total"]
                / max(1, ag["refresh_rows"]["total"]))
    return out


# ------------------------------------------------------- strategy sweep

def strategy_sweep(tiny: bool) -> dict:
    """Runs in the forced-4-device child: the spmm_15d strategy measured
    for real — c=2 (pr=2) and c=1 (pr=4, the dense-1D degenerate) on the
    flickr-scale config — asserting the byte-accounting contract
    (modeled forward collective bytes == HLO-measured) and loss parity
    vs the halo_1d sim oracle at refresh_every=1."""
    import jax
    jax.devices()           # lock the forced host device count first
    import numpy as np
    from repro.core import PROFILES, cal_capacity
    from repro.data import make_task
    from repro.dist import TrainSpec, make_sim_runtime, train_capgnn
    from repro.dist.strategy_15d import (build_spmm15d_layout,
                                         make_spmm15d_runtime,
                                         train_spmm15d)
    from repro.launch.dryrun import collective_bytes
    from repro.models.gnn import init_gnn
    from repro.optim import adam as mk_adam

    devices = 4
    epochs = 4 if tiny else 8
    scale = BENCH_SCALE["flickr"] / (8 if tiny else 1)
    task = make_task("flickr", scale=scale, feat_dim=64)
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=128, out_dim=task.num_classes, num_layers=3)
    opt = mk_adam(0.01)
    out = {"devices": devices, "tiny": bool(tiny),
           "num_nodes": int(task.graph.num_nodes)}
    for c in (1, 2):
        pr = devices // c
        ps = build_partition(task.graph,
                             metis_partition(task.graph, pr, seed=0), hops=1)
        spec = TrainSpec(strategy="spmm_15d", replication=c, donate=False)
        layout = build_spmm15d_layout(ps, task, spec)
        rt = make_spmm15d_runtime(cfg, layout, opt, spec)
        params = init_gnn(jax.random.PRNGKey(0), cfg)
        hlo = rt.lower_forward(params).compile().as_text()
        measured = collective_bytes(hlo)["total"]
        row = {"block_rows": pr, "group_size": layout.g,
               "modeled_fwd_bytes_per_device": rt.forward_bytes_per_device,
               "hlo_fwd_bytes_per_device": measured,
               "hlo_matches_model": bool(
                   measured == rt.forward_bytes_per_device),
               "step_bytes_total": rt.step_bytes,
               "vanilla_bytes_total": rt.vanilla_bytes}
        assert row["hlo_matches_model"], (
            f"spmm_15d c={c}: modeled {rt.forward_bytes_per_device} != "
            f"HLO {measured} ({collective_bytes(hlo)['counts']})")
        if c == 2:
            # loss parity vs the halo_1d sim oracle at refresh_every=1
            # over the same pr-block partition
            cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * pr)
            plan = build_cache_plan(ps, cap, refresh_every=1)
            xplan = build_exchange_plan(ps, plan)
            sp = stack_partitions(ps, task)
            spec1d = TrainSpec(strategy="halo_1d", donate=False)
            sim = make_sim_runtime(cfg, sp, xplan, opt, spec=spec1d)
            _, rep_sim = train_capgnn(cfg, sim, xplan, pr, opt,
                                      epochs=epochs, spec=spec1d)
            _, rep_15 = train_spmm15d(cfg, rt, opt, spec, epochs=epochs)
            row["parity_max_err"] = float(np.abs(
                np.asarray(rep_sim.losses)
                - np.asarray(rep_15.losses)).max())
            row["step_ms"] = rep_15.wall_time_s / max(1, epochs - 1) * 1e3
        out[f"c{c}"] = row
    return out


def strategy_model_sweep(task, parts_list=(2, 4, 8, 16)) -> dict:
    """Pure byte-model head-to-head over P and c on one graph (no devices
    needed): the halo_1d exact-mode wire bytes (zero-capacity plan — every
    halo row every step, the cut-bounded figure) vs the spmm_15d model at
    every replication factor with P % c**2 == 0.  This is where the
    1D-vs-1.5D crossover trend lives: for group size g = P/c**2 > 1 the
    per-layer total is ~4*n*(P/c + 2c) bytes, so the c=2/c=1 ratio is
    1/2 + 4/P — decreasing in P, with c=2 winning outright by P=16 (at
    P=c**2 the gather axis is size 1 and drops, so small P sits near
    break-even modulo partition padding).  The halo figure tracks the
    partition cut instead and stays below both at these scales."""
    from repro.dist import TrainSpec
    from repro.dist.strategy_15d import build_spmm15d_layout, step_bytes_total

    g = task.graph
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=128, out_dim=task.num_classes, num_layers=3)
    dims = cfg.feat_dims[:cfg.num_layers]
    parts_cache: dict[int, object] = {}

    def parted(pr):
        if pr not in parts_cache:
            parts_cache[pr] = build_partition(
                g, metis_partition(g, pr, seed=0), hops=1)
        return parts_cache[pr]

    rows = {}
    for p in parts_list:
        ps = parted(p)
        plan0 = build_cache_plan(ps, CacheCapacity(c_gpu=[0] * p, c_cpu=0),
                                 refresh_every=1)
        xplan = build_exchange_plan(ps, plan0)
        halo = sum(xplan.bytes_per_step(d, refresh=True, dtype_bytes=4)
                   for d in dims)
        row = {"halo_exact_bytes": int(halo), "spmm15d": {}}
        for c in (1, 2, 4):
            if p % (c * c):
                continue
            spec = TrainSpec(strategy="spmm_15d", replication=c)
            layout = build_spmm15d_layout(parted(p // c), task, spec)
            row["spmm15d"][f"c{c}"] = int(step_bytes_total(layout, cfg, spec))
        rows[f"p{p}"] = row
    return rows


def _strategy_sweep_subprocess(tiny: bool) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["REPRO_BENCH_TINY"] = "1" if tiny else "0"
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.comm_volume",
         "--strategy-sweep-child"],
        capture_output=True, text=True, timeout=3600, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if res.returncode != 0:
        raise RuntimeError("strategy sweep child failed:\n"
                           + res.stdout[-2000:] + res.stderr[-2000:])
    return json.loads(res.stdout.splitlines()[-1])


def _transport_sweep_subprocess(tiny: bool,
                                transports=("allgather", "p2p")) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["REPRO_BENCH_TINY"] = "1" if tiny else "0"
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.comm_volume",
         "--transport-sweep-child", "--transport", *transports],
        capture_output=True, text=True, timeout=3600, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if res.returncode != 0:
        raise RuntimeError("transport sweep child failed:\n"
                           + res.stdout[-2000:] + res.stderr[-2000:])
    return json.loads(res.stdout.splitlines()[-1])


def run(out_dir: str = DEFAULT_OUT, tiny: bool | None = None,
        transports=("allgather", "p2p")) -> dict:
    if tiny is None:
        tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
    if tiny:
        from repro.data import make_task
        task = make_task("reddit", scale=BENCH_SCALE["reddit"] / 4,
                         feat_dim=64)
        part_counts, fracs, epochs = (2, 4), (0.0, 0.3, 1.0), 4
    else:
        task = bench_task("reddit")
        part_counts, fracs, epochs = (2, 4), (0.0, 0.1, 0.3, 0.6, 1.0), EPOCHS
    g = task.graph
    sweeps = {}
    for parts in part_counts:
        ps = build_partition(g, metis_partition(g, parts, seed=0), hops=1)
        rows = [_one(task, ps, f, parts, epochs=epochs) for f in fracs]
        sweeps[f"{parts}p"] = rows

    # Fig. 19 ratios at the 4-partition full-capacity point
    base = sweeps["4p"][0]          # no cache
    best = sweeps["4p"][-1]         # full cache
    overhead_s = best["plan_build_s"] / epochs
    saved_s = base["epoch_time_s"] - best["epoch_time_s"]

    # strategy head-to-head: byte-model sweep over P and c (in-process),
    # plus the forced-4-device measured child (HLO == model + parity)
    sm = strategy_model_sweep(task)
    ratio = {p: (sm[f"p{p}"]["spmm15d"]["c2"]
                 / max(1, sm[f"p{p}"]["spmm15d"]["c1"]))
             for p in (4, 8, 16)}
    p16 = sm["p16"]["spmm15d"]
    ss = _strategy_sweep_subprocess(tiny)
    out = {
        "tiny": bool(tiny),
        "sweeps": sweeps,
        "strategy_model_sweep": sm,
        "strategy_sweep": ss,
        # byte-accounting contract, measured: modeled forward collective
        # bytes equal the HLO-measured figure for both c=1 and c=2
        "spmm15d_hlo_matches_model": bool(
            ss["c1"]["hlo_matches_model"] and ss["c2"]["hlo_matches_model"]),
        "spmm15d_parity_max_err": float(ss["c2"]["parity_max_err"]),
        # the 1D-vs-1.5D crossover trend: for g = P/c**2 > 1 the c=2/c=1
        # ratio falls as 1/2 + 4/P, so P=4/8 hover near break-even (the
        # model's partition padding wobbles them either side of 1.0) and
        # the P=16 tail is decisive: c=2 beats c=1, c=4 beats both.
        # Gated as exact ints + the tail bools + rtol'd ratios.
        "spmm15d_bytes_p4_c1": int(sm["p4"]["spmm15d"]["c1"]),
        "spmm15d_bytes_p4_c2": int(sm["p4"]["spmm15d"]["c2"]),
        "spmm15d_bytes_p16_c1": int(p16["c1"]),
        "spmm15d_bytes_p16_c2": int(p16["c2"]),
        "spmm15d_bytes_p16_c4": int(p16["c4"]),
        "halo_exact_bytes_p4": int(sm["p4"]["halo_exact_bytes"]),
        "spmm15d_ratio_c2_c1_p4": float(ratio[4]),
        "spmm15d_ratio_c2_c1_p8": float(ratio[8]),
        "spmm15d_ratio_c2_c1_p16": float(ratio[16]),
        "spmm15d_crossover_at_p16": bool(
            ratio[16] < min(ratio[4], ratio[8], 1.0)),
        "spmm15d_c2_beats_c1_at_p16": bool(ratio[16] < 1.0),
        "spmm15d_c4_best_at_p16": bool(
            p16["c4"] < p16["c2"] and p16["c4"] < p16["c1"]),
        # any non-zero cache beats no cache; the sweep is NOT monotone in
        # capacity because mid-size caches route more vertices through the
        # deduplicated global tier (one broadcast row per unique vertex)
        # while an all-local plan refreshes per-(vertex,consumer) pair —
        # the same "more cache is not always better" shape as paper Fig. 18.
        "cache_beats_no_cache": bool(all(
            r["comm_bytes"] < rows[0]["comm_bytes"]
            for rows in sweeps.values() for r in rows[1:])),
        "overhead_ratio": overhead_s / max(best["epoch_time_s"], 1e-9),
        "benefit_to_overhead": saved_s / max(overhead_s, 1e-9),
        "max_comm_reduction": max(r["comm_reduction"]
                                  for rows in sweeps.values() for r in rows),
        "transport_sweep": _transport_sweep_subprocess(tiny, transports),
    }
    save(out_dir, "comm_volume", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--transport-sweep-child", action="store_true",
                    help="internal: run only the transport sweep in this "
                         "(forced multi-device) process, JSON on stdout")
    ap.add_argument("--strategy-sweep-child", action="store_true",
                    help="internal: run only the spmm_15d strategy sweep "
                         "in this (forced multi-device) process, JSON on "
                         "stdout")
    ap.add_argument("--transport", nargs="*",
                    default=["allgather", "p2p"],
                    choices=["allgather", "p2p"],
                    help="which halo transports the sweep times/records")
    # parse_known_args: tolerate the benchmarks.run orchestrator's flags
    args, _ = ap.parse_known_args(argv)
    if args.transport_sweep_child:
        tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
        print(json.dumps(transport_sweep(tiny, tuple(args.transport))))
        return
    if args.strategy_sweep_child:
        tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
        print(json.dumps(strategy_sweep(tiny)))
        return
    out = run(transports=tuple(args.transport))
    print(f"comm_volume: cache beats no cache = {out['cache_beats_no_cache']},"
          f" max reduction = {out['max_comm_reduction']:.1%}")
    for k, rows in out["sweeps"].items():
        line = ", ".join(f"{r['cap_frac']:.1f}:{r['comm_reduction']:.0%}"
                         for r in rows)
        print(f"  {k}: reduction by cap frac {line}")
    print(f"  overhead ratio {out['overhead_ratio']:.4f}, "
          f"benefit/overhead {out['benefit_to_overhead']:.1f}")
    ts = out["transport_sweep"]
    for t, row in ts["transports"].items():
        print(f"  transport {t:9s}: refresh rows "
              f"{row['refresh_rows']['total']:7d} "
              f"(padded {row['refresh_rows_padded']['total']:7d}), "
              f"hlo refresh coll {row['hlo_refresh_collective_bytes_per_device']:.2e} B/dev, "
              f"cached {row['cached_ms']:.1f} ms, "
              f"refresh {row['refresh_unpipelined_ms']:.1f} ms, "
              f"pipelined {row['pipelined_ms']:.1f} ms")
    if "p2p_rows_match_plan" in ts:
        print(f"  p2p rows match plan = {ts['p2p_rows_match_plan']}, "
              f"p2p/allgather rows = "
              f"{ts.get('p2p_vs_allgather_row_ratio', float('nan')):.2f}, "
              f"pipelined<=unpipelined(p2p) = "
              f"{ts['pipelined_leq_unpipelined_p2p']}"
              f" (speedup {ts['p2p_pipeline_speedup']:.2f}x)")
    # strategy head-to-head: the 1D-vs-1.5D crossover as P grows
    print(f"  spmm_15d: HLO == model = {out['spmm15d_hlo_matches_model']}, "
          f"parity vs halo_1d oracle = "
          f"{out['spmm15d_parity_max_err']:.2e}")
    for p, row in out["strategy_model_sweep"].items():
        ks = ", ".join(f"{c}={b:.2e}" for c, b in row["spmm15d"].items())
        print(f"  strategy {p:4s}: halo exact {row['halo_exact_bytes']:.2e} B"
              f" | spmm15d {ks}")
    print(f"  crossover: c2/c1 ratio "
          f"P4 {out['spmm15d_ratio_c2_c1_p4']:.2f} -> "
          f"P8 {out['spmm15d_ratio_c2_c1_p8']:.2f} -> "
          f"P16 {out['spmm15d_ratio_c2_c1_p16']:.2f}; "
          f"c2 beats c1 at P=16 = {out['spmm15d_c2_beats_c1_at_p16']}, "
          f"c4 best at P=16 = {out['spmm15d_c4_best_at_p16']}")


if __name__ == "__main__":
    main()
