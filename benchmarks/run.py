"""Benchmark orchestrator: one suite per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only name ...]

Each suite writes experiments/<name>.json and prints a summary line; the
final PASS/FAIL recap checks the paper's qualitative claims hold.
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = ["halo_obs", "cache_hit", "comm_volume", "rapa_balance",
          "heterogeneous", "convergence", "overall", "kernels_bench",
          "serve_bench", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    names = args.only or SUITES

    import importlib
    results, failures = {}, []
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        print(f"=== {name} ===", flush=True)
        t0 = time.perf_counter()
        try:
            mod.main()
            results[name] = "ok"
        except Exception as exc:  # noqa: BLE001 - keep the sweep going
            failures.append((name, repr(exc)))
            results[name] = f"FAIL {exc!r}"
            print(f"FAIL {name}: {exc!r}")
        print(f"--- {name} done in {time.perf_counter() - t0:.1f}s\n",
              flush=True)

    print("=== summary ===")
    for name in names:
        print(f"  {name:15s} {results[name]}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
