"""Benchmark orchestrator: one suite per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only name[,name...] ...]

``--only`` accepts space- and/or comma-separated suite names and rejects
unknown ones up front.  Each suite writes experiments/<name>.json and
prints a summary line; the final PASS/FAIL recap checks the paper's
qualitative claims hold.  After every invocation (even a --only subset)
the orchestrator folds the top-level scalars of ALL experiments/*.json
into a single experiments/bench_summary.json, so the perf trajectory
stays trackable across PRs from one artifact.  A suite that raises marks
its summary entry with ``_failed`` (so a stale JSON from an earlier run
can't masquerade as green — ``benchmarks.check_regression`` treats it as
a regression) and the process exits non-zero.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

SUITES = ["halo_obs", "cache_hit", "comm_volume", "rapa_balance",
          "heterogeneous", "convergence", "overall", "kernels_bench",
          "serve_bench", "adaptive_cache", "out_of_core",
          "fault_tolerance", "roofline"]

_SUMMARY = "bench_summary"
# not suite outputs: the folded summary itself and the regression baseline
_NON_SUITE = {_SUMMARY + ".json", "baseline.json"}
# trace artifacts (repro.obs exports) live beside the suite JSONs but are
# timelines, not headline scalars — never fold them into the summary
_TRACE_PREFIXES = ("trace_", "metrics_")


def provenance() -> dict:
    """Environment stamp folded into bench_summary.json so every archived
    summary records what produced it."""
    prov: dict = {}
    try:
        import jax
        devs = jax.devices()
        prov.update(jax_version=jax.__version__,
                    platform=devs[0].platform,
                    device_kind=devs[0].device_kind,
                    device_count=len(devs))
    except Exception as exc:  # noqa: BLE001 - stamp what we can
        prov["jax_error"] = repr(exc)
    import platform as _pl
    prov["python"] = _pl.python_version()
    prov["machine"] = _pl.machine()
    return prov


def summarize(out_dir: str, failed: dict | None = None) -> dict:
    """Fold every experiments/*.json into one summary: per file, the
    top-level scalar fields (the headline numbers each suite promotes)
    plus the file's mtime.  Nested sweeps stay in their own files."""
    summary = {}
    for fname in sorted(os.listdir(out_dir)):
        if (not fname.endswith(".json") or fname in _NON_SUITE
                or fname.startswith(_TRACE_PREFIXES)):
            continue
        path = os.path.join(out_dir, fname)
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError) as exc:
            summary[fname[:-5]] = {"unreadable": repr(exc)}
            continue
        scalars = {k: v for k, v in payload.items()
                   if isinstance(v, (int, float, bool, str))}
        # transport sweep headline numbers live one level down
        ts = payload.get("transport_sweep")
        if isinstance(ts, dict):
            scalars.update({f"transport_{k}": v for k, v in ts.items()
                            if isinstance(v, (int, float, bool))})
        scalars["_mtime"] = time.strftime(
            "%Y-%m-%dT%H:%M:%S", time.gmtime(os.path.getmtime(path)))
        summary[fname[:-5]] = scalars
    # a suite that raised this invocation may have left a stale (or no)
    # JSON behind — mark it so downstream gates see red, not stale green
    for name, err in (failed or {}).items():
        summary.setdefault(name, {})["_failed"] = err
    return summary


def write_summary(out_dir: str | None = None,
                  failed: dict | None = None,
                  walls: dict | None = None) -> str:
    if out_dir is None:
        out_dir = os.path.join(os.path.dirname(__file__), "..",
                               "experiments")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, _SUMMARY + ".json")
    summary = summarize(out_dir, failed=failed)
    # per-suite orchestrator wall time; "_wall_s" is in the regression
    # gate's SKIP_KEYS so it is recorded but never gated.  CI runs one
    # suite per invocation, so carry stamps for suites not in this run
    # forward from the previous summary instead of re-folding them away.
    try:
        with open(path) as f:
            prev = json.load(f)
    except (OSError, ValueError):
        prev = {}
    for name, fields in summary.items():
        old = prev.get(name)
        if (isinstance(fields, dict) and isinstance(old, dict)
                and "_wall_s" in old and name not in (walls or {})):
            fields["_wall_s"] = old["_wall_s"]
    for name, wall in (walls or {}).items():
        summary.setdefault(name, {})["_wall_s"] = round(wall, 2)
    # per-suite provenance: suites executed this invocation are stamped
    # with the current environment; entries folded from stale JSONs carry
    # their stamp forward from the previous summary.  When no previous
    # summary exists (fresh checkout + --only single-suite), every entry
    # still gets the current stamp instead of silently losing provenance.
    prov = provenance()
    for name, fields in summary.items():
        if not isinstance(fields, dict):
            continue
        old = prev.get(name)
        if (name in (walls or {}) or not isinstance(old, dict)
                or "_prov" not in old):
            fields["_prov"] = prov
        else:
            fields["_prov"] = old["_prov"]
    summary["_provenance"] = prov
    with open(path, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="suite names, space- and/or comma-separated")
    ap.add_argument("--trace", action="store_true",
                    help="run the training suites under the repro.obs "
                         "tracer and attach Perfetto trace artifacts "
                         "(experiments/trace_<suite>.json) per suite")
    args = ap.parse_args()
    if args.trace:
        os.environ["REPRO_BENCH_TRACE"] = "1"
    names: list[str] = []
    for chunk in (args.only or []):
        names.extend(n for n in chunk.split(",") if n)
    names = names or SUITES
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        print(f"unknown suite(s) {unknown}; available: {SUITES}",
              file=sys.stderr)
        sys.exit(2)

    import importlib
    results, failures, walls = {}, [], {}
    for name in names:
        print(f"=== {name} ===", flush=True)
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main()
            results[name] = "ok"
        except Exception as exc:  # noqa: BLE001 - keep the sweep going
            failures.append((name, repr(exc)))
            results[name] = f"FAIL {exc!r}"
            print(f"FAIL {name}: {exc!r}")
        walls[name] = time.perf_counter() - t0
        print(f"--- {name} done in {walls[name]:.1f}s\n", flush=True)

    path = write_summary(failed=dict(failures), walls=walls)
    print(f"=== summary (aggregated -> {os.path.relpath(path)}) ===")
    for name in names:
        print(f"  {name:15s} {results[name]}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
