"""Paper Fig. 21 + §4.3: heterogeneous device groups, cost model and
straggler end-to-end.

Two sections:

1. **Cost model** (Fig. 21): per-device lambda (Eq. 13+14) before/after
   RAPA for uniform-split (DistGCN-style) vs RAPA partitions, across the
   paper's Table 4 groups.  Variance explodes for uniform splits as
   heterogeneity grows; RAPA keeps it flat.
2. **Straggler end-to-end**: on the skewed x4/x8 groups, the full
   resource-aware path — capability-weighted uneven partitions
   (``capability_weights``) + Alg. 2/3 halo adjustment + jointly-set
   cache budgets (``cal_capacity`` sees the same profiles) — against the
   uniform-split baseline, judged on the modeled straggler step time
   (``lambda_max``), the padded-row waste of the stacked ``[P, ...]``
   layout the runtimes compile, and exact byte accounting
   (plan-counted rows == stacked valid-mask rows == p2p packed rows).
   ``rapa_even`` (adjustment on even partitions) rides along as the
   ablation separating the two RAPA stages.

The straggler section runs on the flickr-scale benchmark graph: its
sparsity keeps halo sizes proportional to part sizes.  (At the reddit
benchmark density — avg degree ~350 — every part's halo saturates to
nearly the whole remainder of the graph, which blunts partition-shape
effects; the cost-model section keeps reddit for continuity.)

A subprocess with ``--xla_force_host_platform_device_count=4`` (same
pattern as ``benchmarks.comm_volume``) drives the compiled SPMD step for
the uneven partitions over BOTH halo transports and checks the wire-row
accounting and cross-transport loss agreement.  ``REPRO_BENCH_TINY=1``
shrinks every graph for CI smoke runs.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import numpy as np

from repro.core import (PAPER_GROUPS, RapaConfig, build_cache_plan,
                        cal_capacity, capability_weights, do_partition,
                        make_group, partition_lambdas)
from repro.dist import build_exchange_plan, stack_partitions
from repro.dist.exchange import exchange_capacity
from repro.graph import build_partition, metis_partition
from ._util import BENCH_SCALE, DEFAULT_OUT, save

STRAGGLER_GROUPS = ("x4", "x8")


def _flickr_task(tiny: bool):
    from repro.data import make_task
    scale = BENCH_SCALE["flickr"] / (4 if tiny else 1)
    return make_task("flickr", scale=scale, feat_dim=64, seed=0)


# ------------------------------------------------------------ cost model

def cost_model_rows(tiny: bool) -> list[dict]:
    from repro.data import make_task
    scale = BENCH_SCALE["reddit"] / (4 if tiny else 1)
    task = make_task("reddit", scale=scale, feat_dim=64, seed=0)
    g = task.graph
    cfg = RapaConfig(feat_dim=task.features.shape[1])
    rows = []
    for grp in ("x2", "x4", "x6", "x8"):
        profiles = make_group(PAPER_GROUPS[grp])
        p = len(profiles)
        ps = build_partition(g, metis_partition(g, p, seed=0), hops=1,
                             parts=p)
        lam_uniform = partition_lambdas(ps, profiles, cfg)
        res = do_partition(ps, profiles, cfg)
        lam_rapa = res.lambda_final
        rows.append({
            "group": grp, "parts": p,
            "uniform_max": float(lam_uniform.max()),
            "uniform_rel_std": float(lam_uniform.std() / lam_uniform.mean()),
            "rapa_max": float(np.max(lam_rapa)),
            "rapa_rel_std": float(np.std(lam_rapa) / np.mean(lam_rapa)),
            "heterogeneity": float(max(pr.mm for pr in profiles)
                                   / min(pr.mm for pr in profiles)),
        })
    return rows


# ------------------------------------------------- straggler end-to-end

def _build_variants(g, profiles, cfg, seed: int = 0) -> dict:
    """uniform (even split, no adjustment — the DistGCN-style baseline),
    rapa_even (adjustment only), rapa_uneven (the full §4.3 pipeline)."""
    p = len(profiles)
    w = capability_weights(profiles)
    ps_even = build_partition(g, metis_partition(g, p, seed=seed),
                              hops=1, parts=p)
    ps_wtd = build_partition(g, metis_partition(g, p, seed=seed, weights=w),
                             hops=1, parts=p)
    return {
        "uniform": ps_even,
        "rapa_even": do_partition(ps_even, profiles, cfg).partition_set,
        "rapa_uneven": do_partition(ps_wtd, profiles, cfg).partition_set,
    }


def _variant_stats(task, ps, profiles, cfg) -> dict:
    """Cost model + padding + cache budgets + row accounting for one
    (partitioning, device group) pair."""
    lam = partition_lambdas(ps, profiles, cfg)
    sp = stack_partitions(ps, task)
    stats = sp.padding_stats()
    feat_dims = (task.features.shape[1], 128, 128)
    # cache budgets from the SAME profiles that shaped the partitions:
    # big-memory devices absorb more residents (per-part c_gpu)
    cap = cal_capacity(ps, feat_dims, profiles)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    xplan = build_exchange_plan(ps, plan)
    xcap = exchange_capacity(ps, cap)

    # every halo position is served by exactly one tier; the p2p packed
    # blocks re-ship exactly the plan rows (one slot per (row, consumer),
    # one per unique global row) — three independent data structures
    halo_valid = int(sp.halo_valid.sum())
    served = (xplan.uncached.n_rows + xplan.local.n_rows
              + int(xplan.glob.read_valid.sum()))
    plan_rows = (xplan.uncached.n_rows + xplan.local.n_rows
                 + xplan.glob.n_unique)
    p2p_rows = xplan.transport_rows("p2p", refresh=True)["total"]
    padded_total = (int(stats["inner_padded_rows"])
                    + int(stats["halo_padded_rows"])
                    + int(stats["edges_padded_rows"]))
    return {
        "inner_sizes": [int(pt.n_inner) for pt in ps.parts],
        "halo_sizes": [int(pt.n_halo) for pt in ps.parts],
        "c_gpu": [int(c) for c in cap.c_gpu],
        "mem_gib": [float(pr.mem_gib) for pr in profiles],
        "lambda_max": float(lam.max()),
        "lambda_rel_std": float(lam.std() / max(lam.mean(), 1e-12)),
        "halo_valid_rows": int(stats["halo_valid_rows"]),
        "halo_padded_rows": int(stats["halo_padded_rows"]),
        "inner_padded_rows": int(stats["inner_padded_rows"]),
        "edges_padded_rows": int(stats["edges_padded_rows"]),
        "padded_rows_total": padded_total,
        "stack_waste_frac": float(stats["waste_frac"]),
        "capacity_waste_frac": float(xcap.padding_waste()["waste_frac"]),
        "plan_recv_rows": int(plan_rows),
        "p2p_packed_rows": int(p2p_rows),
        "halo_rows_served": int(served),
        "accounting_exact": bool(served == halo_valid
                                 and p2p_rows == plan_rows),
    }


def _sim_uneven_run(task, ps, profiles, tiny: bool) -> dict:
    """Drive the ragged masked stacks through the sim runtime end-to-end
    (the compiled step the launcher runs) on the most skewed group."""
    from repro.core import StalenessController
    from repro.dist import make_sim_runtime, train_capgnn
    from repro.models.gnn import GNNConfig
    from repro.optim import adam

    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=64, out_dim=task.num_classes, num_layers=3)
    p = ps.num_parts
    cap = cal_capacity(ps, cfg.feat_dims, profiles)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    rt = make_sim_runtime(cfg, sp, xplan, opt)
    ctl = StalenessController(refresh_every=4)
    epochs = 2 if tiny else 6
    params, rep = train_capgnn(cfg, rt, xplan, p, opt, epochs=epochs,
                               controller=ctl, eval_every=0)
    _, acc = rt.evaluate(params, "test")
    return {
        "epochs": epochs,
        "final_loss": float(rep.losses[-1]),
        "loss_finite": bool(np.isfinite(rep.losses).all()),
        "test_acc": float(acc),
        "comm_bytes": int(rep.comm_bytes),
        "comm_reduction": float(rep.comm_reduction),
        "stack_waste_frac": float(rt.padding_stats()["waste_frac"]),
    }


def straggler_section(tiny: bool) -> dict:
    task = _flickr_task(tiny)
    g = task.graph
    cfg = RapaConfig(feat_dim=task.features.shape[1])
    groups = {}
    for grp in STRAGGLER_GROUPS:
        profiles = make_group(PAPER_GROUPS[grp])
        variants = _build_variants(g, profiles, cfg)
        stats = {name: _variant_stats(task, ps, profiles, cfg)
                 for name, ps in variants.items()}
        uni, unv = stats["uniform"], stats["rapa_uneven"]
        groups[grp] = {
            "parts": len(profiles),
            "capability_weights":
                [float(x) for x in capability_weights(profiles)],
            "variants": stats,
            "uneven_cuts_lambda_max": bool(
                unv["lambda_max"] < uni["lambda_max"]),
            # total padded rows of the [P, ...] stack (inner+halo+edges):
            # uniform splits look tight on halos alone but pay for the
            # straggler part's inner/edge overshoot; uneven partitions
            # trade halo spread for a much smaller total allocation
            "uneven_cuts_padded_rows": bool(
                unv["padded_rows_total"] < uni["padded_rows_total"]),
            "uneven_cuts_stack_waste": bool(
                unv["stack_waste_frac"] < uni["stack_waste_frac"]),
            "lambda_max_reduction": float(
                1.0 - unv["lambda_max"] / max(uni["lambda_max"], 1e-12)),
        }
    # end-to-end: the x8 uneven partitions through the compiled sim step
    profiles8 = make_group(PAPER_GROUPS["x8"])
    ps8 = _build_variants(g, profiles8, cfg)["rapa_uneven"]
    sim = _sim_uneven_run(task, ps8, profiles8, tiny)
    return {"num_nodes": int(g.num_nodes), "num_edges": int(g.num_edges),
            "groups": groups, "sim_uneven_x8": sim}


# --------------------------------------- SPMD transport child (4 devices)

def straggler_transport_child(tiny: bool) -> dict:
    """Runs in the forced-4-device subprocess: the x4-group uneven
    partitions through the compiled shard_map step over both halo
    transports — wire-row accounting + cross-transport loss agreement."""
    import jax
    jax.devices()           # lock the forced host device count first
    import jax.numpy as jnp
    from repro.dist import init_caches
    from repro.dist.capgnn_spmd import make_spmd_runtime
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import adam

    task = _flickr_task(tiny)
    g = task.graph
    parts = 4
    profiles = make_group(PAPER_GROUPS["x4"])
    rcfg = RapaConfig(feat_dim=task.features.shape[1])
    w = capability_weights(profiles)
    ps = build_partition(g, metis_partition(g, parts, seed=0, weights=w),
                         hops=1, parts=parts)
    ps = do_partition(ps, profiles, rcfg).partition_set
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=64, out_dim=task.num_classes, num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims, profiles)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    mesh = jax.make_mesh((parts,), ("data",))
    params = init_gnn(jax.random.PRNGKey(0), cfg)

    plan_rows = {"uncached": xplan.uncached.n_rows,
                 "local": xplan.local.n_rows,
                 "global": xplan.glob.n_unique}
    out = {"parts": parts, "tiny": bool(tiny),
           "inner_sizes": [int(pt.n_inner) for pt in ps.parts],
           "plan_rows": plan_rows, "transports": {}}
    losses = {}
    for transport in ("allgather", "p2p"):
        rt = make_spmd_runtime(cfg, sp, xplan, opt, mesh,
                               transport=transport)
        pp = jax.tree.map(jnp.copy, params)
        oo = opt.init(pp)
        cc = init_caches(cfg, xplan, parts)
        step_loss = {}
        for name, fn in (("cached", rt.step_cached),
                         ("refresh", rt.step_refresh),
                         ("pipelined", rt.step_pipelined)):
            pp, oo, cc, m = fn(pp, oo, cc)
            step_loss[name] = float(np.asarray(m["loss"]).ravel()[0])
        losses[transport] = step_loss
        out["transports"][transport] = {
            "refresh_rows": rt.wire_rows(True),
            "step_losses": step_loss,
            "losses_finite": bool(
                np.isfinite(list(step_loss.values())).all()),
        }

    p2p = out["transports"]["p2p"]["refresh_rows"]
    ag = out["transports"]["allgather"]["refresh_rows"]
    p2p_ok = (p2p["uncached"] == plan_rows["uncached"]
              and p2p["local"] == plan_rows["local"]
              and p2p["global"] == plan_rows["global"])
    # allgather replicates every owner's dedup send buffer to all P devices
    ag_ok = (ag["uncached"] == parts * xplan.uncached.n_send_rows
             and ag["local"] == parts * xplan.local.n_send_rows
             and ag["global"] == parts * int(xplan.glob.send_valid.sum()))
    out["rows_match_plan_both_transports"] = bool(p2p_ok and ag_ok)
    out["transport_losses_agree"] = bool(all(
        abs(losses["allgather"][k] - losses["p2p"][k]) <= 1e-5
        for k in ("cached", "refresh", "pipelined")))
    return out


def _transport_child_subprocess(tiny: bool) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["REPRO_BENCH_TINY"] = "1" if tiny else "0"
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.heterogeneous",
         "--straggler-child"],
        capture_output=True, text=True, timeout=3600, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if res.returncode != 0:
        raise RuntimeError("straggler transport child failed:\n"
                           + res.stdout[-2000:] + res.stderr[-2000:])
    return json.loads(res.stdout.splitlines()[-1])


# ------------------------------------------------------------------ run

def run(out_dir: str = DEFAULT_OUT, tiny: bool | None = None) -> dict:
    if tiny is None:
        tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
    rows = cost_model_rows(tiny)
    straggler = straggler_section(tiny)
    child = _transport_child_subprocess(tiny)

    # Eq. 15 objective is max(lambda) + Std(lambda): the max term is the
    # step-time bound, which is what heterogeneity blows up for uniform
    # splits.  (rel-std alone is misleading once lambda is near zero.)
    improved = all(r["rapa_max"] <= r["uniform_max"] * 1.001 for r in rows)
    grp = straggler["groups"]
    x8 = grp["x8"]
    out = {
        "tiny": bool(tiny),
        "rows": rows,
        "rapa_reduces_max_cost": bool(improved),
        "max_cost_reduction": max(1 - r["rapa_max"] / r["uniform_max"]
                                  for r in rows),
        "straggler": straggler,
        "straggler_transport": child,
        # gated headline claims (deterministic; see check_regression.py)
        "uneven_cuts_lambda_max": bool(all(
            g["uneven_cuts_lambda_max"] for g in grp.values())),
        "uneven_cuts_padded_rows_x8": bool(x8["uneven_cuts_padded_rows"]),
        "uneven_cuts_stack_waste_x8": bool(x8["uneven_cuts_stack_waste"]),
        "x8_lambda_max_reduction": float(x8["lambda_max_reduction"]),
        "x8_uniform_padded_rows":
            int(x8["variants"]["uniform"]["padded_rows_total"]),
        "x8_uneven_padded_rows":
            int(x8["variants"]["rapa_uneven"]["padded_rows_total"]),
        "straggler_accounting_exact": bool(all(
            v["accounting_exact"]
            for g in grp.values() for v in g["variants"].values())),
        "rows_match_plan_both_transports":
            bool(child["rows_match_plan_both_transports"]),
        "transport_losses_agree": bool(child["transport_losses_agree"]),
        "sim_uneven_loss_finite":
            bool(straggler["sim_uneven_x8"]["loss_finite"]),
    }
    save(out_dir, "heterogeneous", out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--straggler-child", action="store_true",
                    help="internal: run only the SPMD transport check in "
                         "this (forced multi-device) process, JSON on "
                         "stdout")
    # parse_known_args: tolerate the benchmarks.run orchestrator's flags
    args, _ = ap.parse_known_args(argv)
    if args.straggler_child:
        tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
        print(json.dumps(straggler_transport_child(tiny)))
        return
    out = run()
    print("heterogeneous: RAPA reduces max cost =",
          out["rapa_reduces_max_cost"],
          f"(best reduction {out['max_cost_reduction']:.1%})")
    for r in out["rows"]:
        print(f"  {r['group']} (het {r['heterogeneity']:.1f}x): max "
              f"{r['uniform_max']:.2e} -> {r['rapa_max']:.2e}, rel-std "
              f"{r['uniform_rel_std']:.3f} -> {r['rapa_rel_std']:.3f}")
    for grp, g in out["straggler"]["groups"].items():
        uni = g["variants"]["uniform"]
        unv = g["variants"]["rapa_uneven"]
        print(f"  straggler {grp}: lambda_max {uni['lambda_max']:.2e} -> "
              f"{unv['lambda_max']:.2e} ({g['lambda_max_reduction']:.1%}), "
              f"stack padded rows {uni['padded_rows_total']} -> "
              f"{unv['padded_rows_total']} (waste "
              f"{uni['stack_waste_frac']:.3f} -> "
              f"{unv['stack_waste_frac']:.3f})")
    sim = out["straggler"]["sim_uneven_x8"]
    print(f"  sim x8 uneven: loss {sim['final_loss']:.4f}, acc "
          f"{sim['test_acc']:.3f}, comm saved {sim['comm_reduction']:.1%}")
    print(f"  accounting exact = {out['straggler_accounting_exact']}, "
          f"wire rows match plan (both transports) = "
          f"{out['rows_match_plan_both_transports']}, "
          f"transport losses agree = {out['transport_losses_agree']}")


if __name__ == "__main__":
    main()
