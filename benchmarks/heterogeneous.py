"""Paper Fig. 21: robustness under heterogeneous device groups.

Cost-model evaluation: per-device lambda (Eq. 13+14) before/after RAPA for
uniform-split (DistGCN-style) vs RAPA partitions, across paper Table 4
groups.  The paper's claim — variance explodes for uniform splits as
heterogeneity grows, RAPA keeps it flat — is checked on the model the
runtime actually schedules with.
"""
from __future__ import annotations

import numpy as np

from repro.core import (PAPER_GROUPS, RapaConfig, comm_cost, comp_cost,
                        do_partition, make_group)
from repro.core.rapa import _make_states, _lambda
from repro.graph import build_partition, metis_partition
from ._util import DEFAULT_OUT, bench_task, save


def _lambdas(ps, profiles, cfg):
    states = _make_states(ps)
    return np.array([_lambda(st, profiles[i], profiles, cfg, ps.num_parts)
                     for i, st in enumerate(states)])


def run(out_dir: str = DEFAULT_OUT) -> dict:
    task = bench_task("reddit")
    g = task.graph
    cfg = RapaConfig(feat_dim=task.features.shape[1])
    rows = []
    for grp in ("x2", "x4", "x6", "x8"):
        profiles = make_group(PAPER_GROUPS[grp])
        p = len(profiles)
        ps = build_partition(g, metis_partition(g, p, seed=0), hops=1)
        lam_uniform = _lambdas(ps, profiles, cfg)
        res = do_partition(ps, profiles, cfg)
        lam_rapa = res.lambda_final
        rows.append({
            "group": grp, "parts": p,
            "uniform_max": float(lam_uniform.max()),
            "uniform_rel_std": float(lam_uniform.std() / lam_uniform.mean()),
            "rapa_max": float(np.max(lam_rapa)),
            "rapa_rel_std": float(np.std(lam_rapa) / np.mean(lam_rapa)),
            "heterogeneity": float(max(pr.mm for pr in profiles)
                                   / min(pr.mm for pr in profiles)),
        })
    # Eq. 15 objective is max(lambda) + Std(lambda): the max term is the
    # step-time bound, which is what heterogeneity blows up for uniform
    # splits.  (rel-std alone is misleading once lambda is near zero.)
    improved = all(r["rapa_max"] <= r["uniform_max"] * 1.001 for r in rows)
    out = {"rows": rows, "rapa_reduces_max_cost": bool(improved),
           "max_cost_reduction": max(1 - r["rapa_max"] / r["uniform_max"]
                                     for r in rows)}
    save(out_dir, "heterogeneous", out)
    return out


def main():
    out = run()
    print("heterogeneous: RAPA reduces max cost =",
          out["rapa_reduces_max_cost"],
          f"(best reduction {out['max_cost_reduction']:.1%})")
    for r in out["rows"]:
        print(f"  {r['group']} (het {r['heterogeneity']:.1f}x): max "
              f"{r['uniform_max']:.2e} -> {r['rapa_max']:.2e}, rel-std "
              f"{r['uniform_rel_std']:.3f} -> {r['rapa_rel_std']:.3f}")


if __name__ == "__main__":
    main()
