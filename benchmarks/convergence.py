"""Paper Fig. 22 + Theorem 1: convergence of cached (stale) training.

Trains the same model four ways — single-worker full graph (oracle),
partitioned fully-synchronous (tau=1), CaPGNN cached (tau=4), CaPGNN
pipelined — and checks (a) losses track the oracle, (b) accuracy within
tolerance, (c) gradient-norm trajectory sits under the Theorem-1 envelope.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (CacheCapacity, PROFILES, StalenessController,
                        build_cache_plan, cal_capacity, theorem1_bound)
from repro.dist import (build_exchange_plan, make_sim_runtime,
                        stack_partitions, train_capgnn)
from repro.graph import build_partition, metis_partition
from repro.models.gnn import (GNNConfig, cross_entropy_loss, gnn_forward,
                              init_gnn, make_local_adj)
from repro.optim import adam
from ._util import DEFAULT_OUT, bench_task, save

EPOCHS = 60


def _full_graph_curve(cfg, task, seed=0):
    adj = make_local_adj(task.graph, task.graph.num_nodes, backend="edges")
    params = init_gnn(jax.random.PRNGKey(seed), cfg)
    opt = adam(0.01)
    state = opt.init(params)
    feats = jnp.asarray(task.features)
    labels = jnp.asarray(task.labels)
    mask = jnp.asarray(task.train_mask.astype(np.float32))

    @jax.jit
    def step(params, state):
        def lf(p):
            return cross_entropy_loss(gnn_forward(cfg, p, adj, feats, None),
                                      labels, mask)
        loss, grads = jax.value_and_grad(lf)(params)
        gnorm = jnp.sqrt(sum(jnp.sum(g ** 2) for g in jax.tree.leaves(grads)))
        params, state = opt.update(grads, state, params)
        return params, state, loss, gnorm

    losses, gnorms = [], []
    for _ in range(EPOCHS):
        params, state, loss, gn = step(params, state)
        losses.append(float(loss))
        gnorms.append(float(gn))
    return losses, gnorms


def _capgnn_curve(cfg, task, ps, refresh_every, pipeline=False, seed=0):
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * ps.num_parts)
    plan = build_cache_plan(ps, cap, refresh_every=refresh_every)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    runtime = make_sim_runtime(cfg, sp, xplan, opt)
    ctl = StalenessController(refresh_every=refresh_every)
    _, rep = train_capgnn(cfg, runtime, xplan, ps.num_parts, opt,
                          epochs=EPOCHS, controller=ctl, eval_every=EPOCHS,
                          pipeline=pipeline, seed=seed)
    return rep.losses, (rep.val_acc[-1] if rep.val_acc else None), rep


def run(out_dir: str = DEFAULT_OUT) -> dict:
    task = bench_task("flickr")
    g = task.graph
    ps = build_partition(g, metis_partition(g, 4, seed=0), hops=1)
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=128, out_dim=task.num_classes, num_layers=3)

    oracle_losses, gnorms = _full_graph_curve(cfg, task)
    sync_losses, sync_acc, _ = _capgnn_curve(cfg, task, ps, refresh_every=1)
    stale_losses, stale_acc, stale_rep = _capgnn_curve(cfg, task, ps,
                                                       refresh_every=4)
    pipe_losses, pipe_acc, _ = _capgnn_curve(cfg, task, ps, refresh_every=4,
                                             pipeline=True)

    # Theorem 1 envelope over the measured gradient norms (rho, alpha fitted
    # loosely from the trajectory: rho ~ smoothness proxy, alpha ~ gamma^2)
    loss_gap = oracle_losses[0] - min(oracle_losses)
    env = [theorem1_bound(loss_gap, rho=2.0, alpha=4.0 * max(gnorms) ** 2,
                          t=t + 1) for t in range(EPOCHS)]
    mean_sq = np.cumsum(np.array(gnorms) ** 2) / np.arange(1, EPOCHS + 1)
    under_env = bool(np.all(mean_sq[5:] <= np.array(env[5:]) * 10))

    out = {
        "oracle_final": oracle_losses[-1],
        "sync_final": sync_losses[-1],
        "stale_final": stale_losses[-1],
        "pipelined_final": pipe_losses[-1],
        "sync_tracks_oracle": bool(abs(sync_losses[-1] - oracle_losses[-1])
                                   < 0.3 * max(1e-6, oracle_losses[-1]) + 0.2),
        "stale_within_tolerance": bool(
            stale_losses[-1] < oracle_losses[-1] + 0.35),
        "val_acc": {"sync": sync_acc, "stale": stale_acc, "pipe": pipe_acc},
        "stale_comm_reduction": stale_rep.comm_reduction,
        "grad_mean_sq_under_envelope": under_env,
        "curves": {"oracle": oracle_losses, "sync": sync_losses,
                   "stale": stale_losses, "pipe": pipe_losses,
                   "theorem1_envelope": env},
    }
    save(out_dir, "convergence", out)
    return out


def main():
    out = run()
    print(f"convergence: oracle {out['oracle_final']:.4f} "
          f"sync {out['sync_final']:.4f} stale {out['stale_final']:.4f} "
          f"pipe {out['pipelined_final']:.4f}")
    print(f"  acc sync/stale/pipe = {out['val_acc']}")
    print(f"  stale comm reduction = {out['stale_comm_reduction']:.1%}, "
          f"grad envelope ok = {out['grad_mean_sq_under_envelope']}")


if __name__ == "__main__":
    main()
