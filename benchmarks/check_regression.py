"""Benchmark regression gate: diff experiments/bench_summary.json against
the committed experiments/baseline.json and exit non-zero on regression.

    PYTHONPATH=src python -m benchmarks.check_regression           # check
    PYTHONPATH=src python -m benchmarks.check_regression --update  # refresh

The baseline holds, per gated suite, the summary's headline fields.  Field
classes:

- **bools / ints / strings** — compared exactly.  This covers the
  deterministic invariants the gate exists for: parity flags, plan-counted
  bytes and wire rows, replan/event counts, padding shapes.
- **parity/error floats** (key contains ``err``) — one-sided: current
  must stay under ``baseline + --err-atol`` (default 1e-5, the repo's
  parity tolerance).  Getting *more* exact never fails the gate.
- **non-timing floats** (hit rates, reductions, ratios) — relative
  tolerance ``--float-rtol`` (default 1e-3; these are numpy-deterministic
  but may carry last-ulp noise across BLAS/XLA builds).
- **timing floats** (key matches ``_ms``/``_s``/``time``/``qps``/
  ``speedup``/``overhead``/...) — only a catastrophic slowdown fails:
  current must stay under baseline x ``--timing-factor`` (default 25; CI
  machines are noisy).  Speedups pass.  Timing-derived *bools* (e.g.
  pipelined-faster-than-unpipelined orderings) are skipped entirely.

A suite present in the baseline but missing (or unreadable/failed) in the
current summary is a regression — a crashed suite can no longer leave a
stale green JSON behind.  The reverse direction — summary keys the
baseline doesn't know about — is printed as an explicit named diff so a
renamed field can't silently escape the gate; it stays non-fatal unless
``--strict-keys`` is passed.

Refreshing the baseline (after an intentional perf/accounting change):
run the gated suites with ``REPRO_BENCH_TINY=1`` exactly as CI does, then
``--update`` and commit the new ``experiments/baseline.json``:

    REPRO_BENCH_TINY=1 PYTHONPATH=src python -m benchmarks.run \
        --only kernels_bench,comm_volume,serve_bench,adaptive_cache,\
heterogeneous,out_of_core,fault_tolerance
    PYTHONPATH=src python -m benchmarks.check_regression --update
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")
# suites CI re-runs (REPRO_BENCH_TINY=1) before invoking this gate
GATED_SUITES = ["kernels_bench", "comm_volume", "serve_bench",
                "adaptive_cache", "heterogeneous", "out_of_core",
                "fault_tolerance"]
TIMING_SUFFIXES = ("_ms", "_s", "_seconds")
TIMING_MARKERS = ("time", "qps", "tok", "wall", "p50", "p99", "speedup",
                  "overhead", "benefit", "_leq_")
SKIP_KEYS = ("_mtime", "_wall_s", "_prov", "trace_file")


def is_timing(key: str) -> bool:
    # unit tokens may sit mid-key when a distribution suffix follows
    # (host_fetch_ms_zipf), so match them anywhere, not just at the end
    k = key.lower()
    return (k.endswith(TIMING_SUFFIXES)
            or any(t in ("ms", "s", "seconds") for t in k.split("_"))
            or any(m in k for m in TIMING_MARKERS))


def compare(baseline: dict, current: dict, float_rtol: float,
            timing_factor: float, err_atol: float = 1e-5) -> list[str]:
    """Return a list of human-readable regressions (empty = green)."""
    problems: list[str] = []
    for suite, fields in baseline.items():
        cur = current.get(suite)
        if not isinstance(cur, dict):
            problems.append(f"{suite}: missing from current summary")
            continue
        if "_failed" in cur or "unreadable" in cur:
            problems.append(f"{suite}: suite failed/unreadable: "
                            f"{cur.get('_failed') or cur.get('unreadable')}")
            continue
        for key, base in fields.items():
            if key in SKIP_KEYS:
                continue
            if key not in cur:
                problems.append(f"{suite}.{key}: missing (baseline {base!r})")
                continue
            val = cur[key]
            if is_timing(key):
                # wall-clock-derived: bools (orderings) skipped, floats
                # only gate a catastrophic slowdown
                if (isinstance(base, (int, float)) and not isinstance(base, bool)
                        and isinstance(val, (int, float))
                        and val > base * timing_factor):
                    problems.append(
                        f"{suite}.{key}: {val:.4g} > {timing_factor}x "
                        f"baseline {base:.4g}")
            elif isinstance(base, bool) or isinstance(val, bool):
                if bool(val) != bool(base):
                    problems.append(f"{suite}.{key}: {val!r} != baseline "
                                    f"{base!r}")
            elif isinstance(base, (int, float)) and isinstance(val, (int, float)):
                if "err" in key.lower():
                    if val > base + err_atol:
                        problems.append(
                            f"{suite}.{key}: {val:.4g} > baseline "
                            f"{base:.4g} + {err_atol}")
                elif isinstance(base, int) and isinstance(val, int):
                    if val != base:
                        problems.append(f"{suite}.{key}: {val} != baseline "
                                        f"{base}")
                else:
                    tol = float_rtol * max(abs(base), 1e-12)
                    if abs(val - base) > tol:
                        problems.append(
                            f"{suite}.{key}: {val:.6g} != baseline "
                            f"{base:.6g} (rtol {float_rtol})")
            elif val != base:
                problems.append(f"{suite}.{key}: {val!r} != baseline "
                                f"{base!r}")
    return problems


def new_keys(baseline: dict, current: dict) -> list[str]:
    """The reverse key diff: ``suite.key`` entries present in the current
    summary but absent from the baseline (new suites count whole).  These
    are fields the gate silently ignores — surfaced as an explicit named
    diff so a renamed key can't slip through as "baseline side missing +
    current side unchecked"; ``--strict-keys`` turns them into failures."""
    out: list[str] = []
    for suite, fields in current.items():
        if not isinstance(fields, dict):
            continue
        base = baseline.get(suite)
        if not isinstance(base, dict):
            out.append(f"{suite}: suite not in baseline")
            continue
        for key in fields:
            if key in SKIP_KEYS:
                continue
            if key not in base:
                out.append(f"{suite}.{key}: not in baseline "
                           f"(current {fields[key]!r})")
    return out


def make_baseline(summary: dict, suites: list[str]) -> dict:
    out = {}
    for suite in suites:
        fields = summary.get(suite)
        if not isinstance(fields, dict):
            raise SystemExit(f"cannot baseline {suite!r}: not in summary — "
                             f"run the suite first (see module docstring)")
        if "_failed" in fields or "unreadable" in fields:
            raise SystemExit(f"cannot baseline {suite!r}: suite failed")
        out[suite] = {k: v for k, v in fields.items() if k not in SKIP_KEYS}
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--summary", default=os.path.join(DEFAULT_DIR,
                                                      "bench_summary.json"))
    ap.add_argument("--baseline", default=os.path.join(DEFAULT_DIR,
                                                       "baseline.json"))
    ap.add_argument("--suites", default=",".join(GATED_SUITES),
                    help="comma-separated suites to gate/baseline")
    ap.add_argument("--float-rtol", type=float, default=1e-3)
    ap.add_argument("--err-atol", type=float, default=1e-5)
    ap.add_argument("--timing-factor", type=float, default=float(
        os.environ.get("REPRO_REGRESSION_TIMING_FACTOR", "25")))
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current summary")
    ap.add_argument("--strict-keys", action="store_true",
                    help="also fail on summary keys absent from the "
                         "baseline (default: report them, stay green)")
    args = ap.parse_args(argv)
    suites = [s for s in args.suites.split(",") if s]

    with open(args.summary) as f:
        summary = json.load(f)

    if args.update:
        baseline = make_baseline(summary, suites)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"baseline refreshed -> {os.path.relpath(args.baseline)} "
              f"({sum(len(v) for v in baseline.values())} fields over "
              f"{len(baseline)} suites)")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    baseline = {k: v for k, v in baseline.items() if k in suites}
    problems = compare(baseline, summary, args.float_rtol,
                       args.timing_factor, err_atol=args.err_atol)
    extra = new_keys(baseline, {k: v for k, v in summary.items()
                                if k in suites})
    if extra:
        print("KEYS NOT IN BASELINE (unchecked by the gate):")
        for e in extra:
            print(f"  {e}")
        if args.strict_keys:
            problems.extend(f"[strict-keys] {e}" for e in extra)
        else:
            print("  (refresh with --update to start gating them, or pass "
                  "--strict-keys to fail on this)")
    if problems:
        print("REGRESSIONS:")
        for p in problems:
            print(f"  {p}")
        print(f"{len(problems)} regression(s) vs "
              f"{os.path.relpath(args.baseline)}; if intentional, refresh "
              "with --update (see benchmarks/check_regression.py docstring)")
        return 1
    n = sum(len(v) for v in baseline.values())
    print(f"regression gate green: {n} fields over {len(baseline)} suites "
          "match baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
