"""Online cache adaptation under a drifting access pattern.

Full-batch training touches every halo vertex every step, which is exactly
the regime where the paper's static overlap ranking is optimal.  Real
deployments drift: sampled mini-batches, partial activity, evolving
queries (BGL/CDFGNN motivation).  This sweep replays a *drifting* halo
access stream — a rotating hot window per partition plus background
noise — through the frozen static plan and the live
:class:`repro.core.jaca.AdaptivePlanner` policies, and reports per-policy
cache hit rate and plan-counted exchange rows/bytes.  The adaptive
policies re-rank at refresh boundaries; the paper-qualitative claim the
recap checks is that ``lru`` and ``drift`` strictly beat the frozen plan
on both metrics under drift.

A second, live section runs the stacked sim runtime through actual
re-plan events (slot-stable capacity-padded layout) and asserts the two
online-adaptation contracts: the jitted steps are never retraced across
plan swaps, and plan-counted rows equal the valid-mask rows of the arrays
the steps actually consumed.

``REPRO_BENCH_TINY=1`` shrinks the task for CI smoke runs.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import (AdaptivePlanner, CacheCapacity, StalenessController,
                        build_cache_plan)
from repro.dist import (build_exchange_plan, exchange_capacity, init_caches,
                        make_sim_runtime, stack_partitions)
from repro.graph import build_partition, metis_partition
from repro.models.gnn import GNNConfig, init_gnn
from repro.optim import adam
from ._util import DEFAULT_OUT, bench_task, save

POLICIES = ("static", "overlap", "fifo", "lru", "drift")
FEAT_DIM = 64


def drifting_accesses(ps, epoch: int, hot_frac: float = 0.25,
                      noise_frac: float = 0.05, shift_frac: float = 0.15,
                      seed: int = 0) -> list:
    """Per-partition accessed halo gids for one epoch: a hot window over
    the partition's halo, sliding by ``shift_frac`` of its width per epoch
    (gradual drift a boundary-replanning policy can track), plus uniform
    noise."""
    out = []
    rng = np.random.default_rng((seed + 1) * 1_000_003 + epoch)
    for pt in ps.parts:
        nh = pt.n_halo
        if nh == 0:
            out.append(np.zeros(0, np.int64))
            continue
        w = max(1, int(hot_frac * nh))
        start = int(epoch * max(1, int(shift_frac * w))) % nh
        idx = (start + np.arange(w)) % nh
        noise = rng.choice(nh, size=max(1, int(noise_frac * nh)),
                           replace=False)
        out.append(pt.halo_nodes[np.unique(np.concatenate([idx, noise]))])
    return out


def _plan_tier_sets(plan):
    loc = [set(int(v) for v in w.local_gids) for w in plan.workers]
    glob = set()
    for w in plan.workers:
        glob.update(int(v) for v in w.global_gids)
    return loc, glob


def _refresh_rows(plan) -> int:
    """Refresh-step cached-tier rows: one per (vertex, consumer) local row
    plus one per unique consumed global vertex (the dedup broadcast)."""
    n_local = sum(w.local_pos.size for w in plan.workers)
    used = [w.global_gids for w in plan.workers if w.global_gids.size]
    n_glob = int(np.unique(np.concatenate(used)).size) if used else 0
    return n_local + n_glob


def replay_policy(ps, capc, policy: str, epochs: int, tau: int,
                  layers: int, seed: int = 0) -> dict:
    """Replay the drifting stream; hits/bytes are counted against the
    *installed* plan (what the runtime would actually serve from cache),
    for every policy uniformly."""
    planner = AdaptivePlanner(ps, capc, refresh_every=tau, policy=policy,
                              seed=seed)
    ctl = StalenessController(refresh_every=tau)
    plan = planner.plan
    loc_sets, glob_set = _plan_tier_sets(plan)
    hits = accesses = rows = replans = 0
    for e in range(epochs):
        refresh = ctl.should_refresh()
        if policy != "static" and ctl.should_replan():
            plan = planner.replan()
            loc_sets, glob_set = _plan_tier_sets(plan)
            replans += 1
        acc = drifting_accesses(ps, e, seed=seed)
        for i, gids in enumerate(acc):
            accesses += layers * gids.size
            n_hit = sum(1 for v in gids
                        if int(v) in loc_sets[i] or int(v) in glob_set)
            hits += layers * n_hit
            rows += layers * (gids.size - n_hit)   # uncached accesses move
        if refresh:
            rows += layers * _refresh_rows(plan)
        planner.observe_step(accessed=acc, layers=layers)
        ctl.observe(None, refreshed=refresh)
    return {"policy": policy, "hit_rate": hits / max(1, accesses),
            "plan_rows": rows, "plan_bytes": rows * FEAT_DIM * 4,
            "replan_events": replans}


def live_adaptation(task, ps, capc, tau: int = 3, epochs: int = 9,
                    policy: str = "lru") -> dict:
    """Drive the jitted sim runtime through real re-plan events and check
    the slot-stability contracts: no retraces, and plan-counted rows ==
    the valid-mask rows of the exchange arrays the steps consumed."""
    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=32, out_dim=task.num_classes, num_layers=3)
    planner = AdaptivePlanner(ps, capc, refresh_every=tau, policy=policy)
    pad = exchange_capacity(ps, capc)
    xplan = build_exchange_plan(ps, planner.plan, pad_to=pad)
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    rt = make_sim_runtime(cfg, sp, xplan, opt)
    import jax
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    caches = init_caches(cfg, xplan, ps.num_parts)
    ctl = StalenessController(refresh_every=tau)
    dims = rt.comm_dims
    plan_rows = measured_rows = replans = 0
    for e in range(epochs):
        refresh = ctl.should_refresh()
        x_read = rt.xplan
        if planner is not None and ctl.should_replan():
            x_next = planner.exchange_plan(planner.replan())
            xr_arr = rt._state["xarr"]
            params, opt_state, caches, m = rt.step_transition(
                params, opt_state, caches, x_next)
            xe_arr = rt._state["xarr"]
            replans += 1
            plan_rows += len(dims) * (
                x_read.uncached.n_rows + x_next.local.n_rows
                + x_next.glob.n_unique)
            measured_rows += len(dims) * (
                int(np.asarray(xr_arr["un"]["recv_valid"]).sum())
                + int(np.asarray(xe_arr["loc"]["recv_valid"]).sum())
                + int(np.asarray(xe_arr["gl"]["buf_valid"]).sum()))
        else:
            fn = rt.step_refresh if refresh else rt.step_cached
            params, opt_state, caches, m = fn(params, opt_state, caches)
            xa = rt._state["xarr"]
            n = x_read.uncached.n_rows
            nm = int(np.asarray(xa["un"]["recv_valid"]).sum())
            if refresh:
                n += x_read.local.n_rows + x_read.glob.n_unique
                nm += (int(np.asarray(xa["loc"]["recv_valid"]).sum())
                       + int(np.asarray(xa["gl"]["buf_valid"]).sum()))
            plan_rows += len(dims) * n
            measured_rows += len(dims) * nm
        planner.observe_step(layers=len(dims))
        ctl.observe(None, refreshed=refresh)
    sizes = {k: rt.jit_steps[k]._cache_size()
             for k in ("refresh", "cached", "pipelined")}
    return {"replan_events": replans,
            "plan_rows": plan_rows, "measured_rows": measured_rows,
            "rows_exact": plan_rows == measured_rows,
            "jit_cache_sizes": sizes,
            "no_retrace": all(v <= 1 for v in sizes.values()),
            "final_loss": float(m["loss"])}


def run(out_dir: str = DEFAULT_OUT) -> dict:
    tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
    task = bench_task("flickr" if tiny else "reddit")
    parts = 3 if tiny else 4
    epochs = 16 if tiny else 48
    tau, layers = 4, 2
    ps = build_partition(task.graph,
                         metis_partition(task.graph, parts, seed=0), hops=1)
    max_halo = max(pt.n_halo for pt in ps.parts)
    union = ps.halo_union().size
    capc = CacheCapacity(c_gpu=[max(1, int(0.3 * max_halo))] * parts,
                         c_cpu=max(1, int(0.2 * union)))

    sweep = [replay_policy(ps, capc, pol, epochs, tau, layers)
             for pol in POLICIES]
    by = {r["policy"]: r for r in sweep}
    adaptive_beats_static = bool(
        by["lru"]["hit_rate"] > by["static"]["hit_rate"]
        and by["drift"]["hit_rate"] > by["static"]["hit_rate"]
        and by["lru"]["plan_bytes"] < by["static"]["plan_bytes"]
        and by["drift"]["plan_bytes"] < by["static"]["plan_bytes"])

    live = live_adaptation(task, ps, capc)

    out = {
        "parts": parts, "epochs": epochs, "tau": tau,
        "c_gpu": capc.c_gpu[0], "c_cpu": capc.c_cpu,
        "sweep": sweep,
        "hit_static": by["static"]["hit_rate"],
        "hit_lru": by["lru"]["hit_rate"],
        "hit_drift": by["drift"]["hit_rate"],
        "bytes_static": by["static"]["plan_bytes"],
        "bytes_lru": by["lru"]["plan_bytes"],
        "bytes_drift": by["drift"]["plan_bytes"],
        "adaptive_beats_static": adaptive_beats_static,
        "live": live,
        "live_no_retrace": live["no_retrace"],
        "live_rows_exact": live["rows_exact"],
        "live_replan_events": live["replan_events"],
    }
    save(out_dir, "adaptive_cache", out)
    return out


def main():
    out = run()
    for r in out["sweep"]:
        print(f"  {r['policy']:8s} hit={r['hit_rate']:.3f} "
              f"rows={r['plan_rows']} replans={r['replan_events']}")
    print(f"adaptive_cache: lru/drift beat static = "
          f"{out['adaptive_beats_static']}, live no-retrace = "
          f"{out['live_no_retrace']}, live rows exact = "
          f"{out['live_rows_exact']}")
    assert out["adaptive_beats_static"], \
        "adaptive policies must beat the frozen plan under drift"
    assert out["live_no_retrace"] and out["live_rows_exact"]


if __name__ == "__main__":
    main()
