"""Shared benchmark plumbing: scaled task setup, timing, json output.

Every benchmark module exposes ``run(out_dir) -> dict`` and can be invoked
standalone (``python -m benchmarks.<name>``).  Results land in
``experiments/<name>.json`` so EXPERIMENTS.md can cite exact numbers.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")

# CPU-friendly scales per dataset (fraction of paper Table 5 node counts).
BENCH_SCALE = {
    "corafull": 0.25, "flickr": 0.06, "coauthor-physics": 0.15,
    "reddit": 0.02, "yelp": 0.008, "amazon-products": 0.004,
    "ogbn-products": 0.0025,
}


def bench_task(name: str = "reddit", feat_dim: int = 64, seed: int = 0):
    from repro.data import make_task
    return make_task(name, scale=BENCH_SCALE.get(name, 0.02),
                     feat_dim=feat_dim, seed=seed)


def save(out_dir: str, name: str, payload: dict) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_np_default)
    return path


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
