"""Paper Fig. 20: RAPA iteration dynamics — per-subgraph node/edge counts and
cost scores converge to a tight band across heterogeneous device groups.
"""
from __future__ import annotations

import numpy as np

from repro.core import PAPER_GROUPS, RapaConfig, do_partition, make_group
from repro.graph import build_partition, metis_partition
from ._util import DEFAULT_OUT, bench_task, save


def run(out_dir: str = DEFAULT_OUT) -> dict:
    task = bench_task("flickr")
    g = task.graph
    results = {}
    for grp in ("x2", "x3", "x4", "x5"):
        profiles = make_group(PAPER_GROUPS[grp])
        p = len(profiles)
        ps = build_partition(g, metis_partition(g, p, seed=0), hops=1)
        res = do_partition(ps, profiles,
                           RapaConfig(feat_dim=task.features.shape[1]))
        hist = res.history
        std0 = hist[0]["std"] / max(np.mean(hist[0]["lambda"]), 1e-9)
        stdN = hist[-1]["std"] / max(np.mean(hist[-1]["lambda"]), 1e-9)
        results[grp] = {
            "iters": len(hist) - 1,
            "rel_std_initial": float(std0),
            "rel_std_final": float(stdN),
            "lambda_initial": hist[0]["lambda"].tolist(),
            "lambda_final": hist[-1]["lambda"].tolist(),
            "nodes_final": hist[-1]["nodes"],
            "edges_final": hist[-1]["edges"],
            "removed_per_part": res.removed_per_part,
            "balanced_improved": bool(stdN <= std0 + 1e-12),
        }
    out = {"groups": results,
           "all_improved": bool(all(r["balanced_improved"]
                                    for r in results.values()))}
    save(out_dir, "rapa_balance", out)
    return out


def main():
    out = run()
    print("rapa_balance: all groups improved =", out["all_improved"])
    for grp, r in out["groups"].items():
        print(f"  {grp}: rel-std {r['rel_std_initial']:.3f} -> "
              f"{r['rel_std_final']:.3f} in {r['iters']} iters, "
              f"removed {r['removed_per_part']}")


if __name__ == "__main__":
    main()
