"""Paper §3.4 Observations 1-2 (Figs. 4-6): halo growth, edge-cut
correlation, and duplicate-halo overlap vs partitions/hops/method.
"""
from __future__ import annotations

import numpy as np

from repro.core import halo_stats, overlap_histogram, duplicate_count
from repro.graph import (build_partition, edge_cut, fennel_partition,
                         metis_partition, random_partition)
from ._util import DEFAULT_OUT, bench_task, save

DATASETS = ("corafull", "flickr", "reddit")
PARTS = (2, 4, 8)
HOPS = (1, 2)


def run(out_dir: str = DEFAULT_OUT) -> dict:
    rows = []
    for ds in DATASETS:
        task = bench_task(ds)
        g = task.graph
        for method, fn in (("metis", metis_partition),
                           ("fennel", fennel_partition),
                           ("random", random_partition)):
            for p in PARTS:
                # re-partition at each p so METIS quality holds
                a = fn(g, p, seed=0)
                cut = edge_cut(g, a)
                for h in HOPS:
                    ps = build_partition(g, a, hops=h)
                    st = halo_stats(ps)
                    rows.append({
                        "dataset": ds, "method": method, "parts": p,
                        "hops": h, "inner": st.total_inner,
                        "halo": st.total_halo,
                        "halo_over_inner": st.halo_inner_ratio,
                        "unique_halo": st.unique_halo,
                        "duplicates": duplicate_count(ps),
                        "edge_cut": cut if h == 1 else None,
                        "overlap_hist": overlap_histogram(ps).tolist()[:8],
                    })
    # Observation 1: halo/inner grows with parts & hops (check monotone trend)
    obs1 = {}
    for ds in DATASETS:
        r = [x["halo_over_inner"] for x in rows
             if x["dataset"] == ds and x["method"] == "metis" and x["hops"] == 1]
        obs1[ds] = {"ratio_by_parts": dict(zip(PARTS, r)),
                    "grows_with_parts": bool(all(b >= a * 0.9 for a, b
                                                 in zip(r, r[1:])))}
    # Fig. 5: edge-cut vs 1-hop halo correlation across all (ds, method, p)
    cuts = np.array([x["edge_cut"] for x in rows if x["hops"] == 1],
                    dtype=float)
    halos = np.array([x["halo"] for x in rows if x["hops"] == 1], dtype=float)
    corr = float(np.corrcoef(cuts, halos)[0, 1]) if cuts.size > 2 else None
    out = {"rows": rows, "observation1": obs1,
           "edgecut_halo_corr": corr}
    save(out_dir, "halo_obs", out)
    return out


def main():
    out = run()
    print("halo_obs: edge-cut/halo corr = %.3f" % out["edgecut_halo_corr"])
    for ds, o in out["observation1"].items():
        print(f"  {ds}: halo/inner by parts {o['ratio_by_parts']}")


if __name__ == "__main__":
    main()
