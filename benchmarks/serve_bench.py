"""Serving benchmark: precompute cost, then QPS / latency / per-tier hit
rates of the query engine across the three workload shapes (uniform, zipf,
bursty) and a fresh-recompute scenario with updated features.

Also asserts the load-bearing parity claim: tiered lookups equal the
training runtime's ``forward_fresh`` logits.

``REPRO_BENCH_TINY=1`` shrinks the task for CI smoke runs (the Pallas
gather hot path is exercised either way).
"""
from __future__ import annotations

import os
import time

import numpy as np

from ._util import BENCH_SCALE, DEFAULT_OUT, bench_task, save

WORKLOADS = ("uniform", "zipf", "bursty")


def run(out_dir: str = DEFAULT_OUT, tiny: bool | None = None) -> dict:
    import jax
    from repro.core import PROFILES, build_cache_plan, cal_capacity
    from repro.dist import build_exchange_plan, stack_partitions, \
        make_sim_runtime
    from repro.graph import build_partition, metis_partition
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import adam
    from repro.serve import (BatchConfig, GNNServeEngine, make_stream,
                             precompute_embeddings, rank_hot_nodes,
                             serve_stream)

    if tiny is None:
        tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
    if tiny:
        from repro.data import make_task
        task = make_task("flickr", scale=BENCH_SCALE["flickr"] / 8,
                         feat_dim=64)
        n_queries, max_batch = 512, 32
    else:
        task = bench_task("flickr")
        n_queries, max_batch = 4096, 64
    g = task.graph
    ps = build_partition(g, metis_partition(g, 4, seed=0), hops=1)

    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=64, out_dim=task.num_classes, num_layers=3)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * 4)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    rt = make_sim_runtime(cfg, sp, xplan, adam(1e-2))

    t0 = time.perf_counter()
    store = precompute_embeddings(cfg, ps, sp, xplan, params)
    precompute_s = time.perf_counter() - t0

    # parity anchor: tables vs the training runtime's fresh logits
    stacked = np.asarray(rt.forward_fresh(params))
    ref = np.zeros_like(store.logits)
    for i, part in enumerate(ps.parts):
        ref[part.inner_nodes] = stacked[i, : part.n_inner]
    parity = float(np.abs(store.logits - ref).max())

    hot_capacity = max(1, g.num_nodes // 10)
    hot = rank_hot_nodes(g, hot_capacity, ps=ps, policy="degree")
    by_degree = rank_hot_nodes(g, g.num_nodes, policy="degree")
    bcfg = BatchConfig(max_batch=max_batch, deadline_ms=2.0)

    rows = {}
    for kind in WORKLOADS:
        engine = GNNServeEngine(store, params, g, hot,
                                features=task.features)
        stream = make_stream(kind, g.num_nodes, n_queries, qps=500.0,
                             alpha=1.1, seed=0, rank_to_node=by_degree)
        rows[kind] = serve_stream(engine, stream, bcfg)

    # fresh-recompute scenario: 1% of nodes get new features.  On these
    # small dense benchmark graphs the L-hop influence cone of even a few
    # updates covers most nodes, so nearly every query takes the recompute
    # path — a shorter stream keeps the (deliberately expensive) row bounded.
    engine = GNNServeEngine(store, params, g, hot, features=task.features)
    rng = np.random.default_rng(0)
    upd = rng.choice(g.num_nodes, max(1, g.num_nodes // 100), replace=False)
    engine.update_features(
        upd, task.features[upd]
        + rng.normal(scale=0.5, size=(upd.size, task.features.shape[1])))
    stream = make_stream("zipf", g.num_nodes, max(64, n_queries // 8),
                         qps=500.0, alpha=1.1, seed=0, rank_to_node=by_degree)
    rows["zipf_fresh"] = {**serve_stream(engine, stream, bcfg),
                          "stale_nodes": int(engine.stale.sum())}

    out = {"tiny": bool(tiny), "nodes": g.num_nodes,
           "hot_capacity": hot_capacity, "queries": n_queries,
           "max_batch": max_batch, "precompute_s": precompute_s,
           "lookup_parity_max_err": parity,
           # host-tier miss service through the HostFeatureStore staged
           # fetch, timed separately from hot-tier Pallas gathers (gated
           # as timing fields; the nested workload rows carry the rest)
           "host_fetch_ms_zipf": rows["zipf"]["host_fetch_ms"],
           "host_fetch_per_row_ms_zipf": rows["zipf"]["host_fetch_per_row_ms"],
           "workloads": rows}
    save(out_dir, "serve_bench", out)
    return out


def main():
    out = run()
    print(f"serve: {out['nodes']} nodes, precompute {out['precompute_s']:.2f}s, "
          f"lookup parity {out['lookup_parity_max_err']:.2e}")
    for kind, row in out["workloads"].items():
        print(f"  {kind:11s}: {row['qps']:8.0f} qps, "
              f"p50 {row['p50_ms']:6.2f} ms, p99 {row['p99_ms']:6.2f} ms, "
              f"hot {row['hot_hit_rate']:.2%} / host {row['host_hit_rate']:.2%}"
              f" / fresh {row['fresh_rate']:.2%}, "
              f"host fetch {row['host_fetch_per_row_ms']*1e3:.1f} us/row")
    assert out["lookup_parity_max_err"] <= 1e-5, "serving parity broken"


if __name__ == "__main__":
    main()
