"""Operator-level benchmark: ELL padding waste + kernel-vs-oracle parity on
partition-shaped workloads (the paper's SpMM hot spot, Table 1's compute
side), plus ELL pack statistics before/after RAPA pruning and an
end-to-end aggregation-backend sweep (edges vs Pallas ell/hybrid through
the stacked runtime — logit parity + per-step wall time).

``REPRO_BENCH_TINY=1`` shrinks the task for CI smoke runs (the Pallas
interpret path is exercised either way).
"""
from __future__ import annotations

import os
import time

import numpy as np
import jax.numpy as jnp

from repro.core import PAPER_GROUPS, RapaConfig, do_partition, make_group
from repro.graph import build_partition, metis_partition
from repro.kernels.ops import (ell_pack, ell_pack_hybrid, ell_spmm,
                               ell_stats, hybrid_spmm)
from repro.kernels import ref as R
from ._util import BENCH_SCALE, DEFAULT_OUT, bench_task, save


def _pack_partition(part):
    src, dst = part.local_graph.edges()
    keep = dst < part.n_inner
    w = part.local_graph.edge_weight
    w = w[keep] if w is not None else np.ones(keep.sum(), np.float32)
    return ell_pack(src[keep], dst[keep], w, part.n_inner)


def _backend_sweep(task, ps, epochs: int = 2) -> dict:
    """Same exchange plan + caches through every runtime backend: logit
    parity vs the edge-list reference and per-refresh-step wall time."""
    import jax
    from repro.core import PROFILES, build_cache_plan, cal_capacity
    from repro.dist import (build_exchange_plan, init_caches,
                            make_sim_runtime, stack_partitions)
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import adam

    cfg = GNNConfig(model="gcn", in_dim=task.features.shape[1],
                    hidden_dim=64, out_dim=task.num_classes, num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims,
                       [PROFILES["rtx3090"]] * ps.num_parts)
    plan = build_cache_plan(ps, cap, refresh_every=2)
    xplan = build_exchange_plan(ps, plan)
    opt = adam(1e-2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)

    sweep = {}
    logits_ref = None
    for backend in ("edges", "ell", "hybrid"):
        sp = stack_partitions(ps, task, backend=backend)
        rt = make_sim_runtime(cfg, sp, xplan, opt, backend=backend)
        logits = np.asarray(rt.forward_fresh(params))
        if logits_ref is None:
            logits_ref = logits
        # the jitted steps donate their inputs: chain the returned state
        # (the realistic steady-state loop) instead of re-using arguments
        p_b = jax.tree.map(jnp.copy, params)
        opt_state = opt.init(p_b)
        caches = init_caches(cfg, xplan, ps.num_parts)
        p_b, opt_state, caches, m = rt.step_refresh(p_b, opt_state, caches)
        jax.block_until_ready(m["loss"])            # compile + run warm-up
        t0 = time.perf_counter()
        for _ in range(epochs):
            p_b, opt_state, caches, m = rt.step_refresh(p_b, opt_state,
                                                        caches)
        jax.block_until_ready(m["loss"])
        row = {"step_ms": (time.perf_counter() - t0) / epochs * 1e3,
               "logit_max_diff": float(np.abs(logits - logits_ref).max())}
        if sp.ell is not None:
            row["max_deg"] = sp.ell.max_deg
            row["tail_edges"] = int((sp.ell.tail_w != 0).sum())
        sweep[backend] = row
    return sweep


def run(out_dir: str = DEFAULT_OUT, tiny: bool | None = None) -> dict:
    if tiny is None:
        tiny = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
    if tiny:
        from repro.data import make_task
        task = make_task("flickr", scale=BENCH_SCALE["flickr"] / 8,
                         feat_dim=64)
    else:
        task = bench_task("flickr")
    g = task.graph
    profiles = make_group(PAPER_GROUPS["x4"])
    ps = build_partition(g, metis_partition(g, 4, seed=0), hops=1)
    res = do_partition(ps, profiles, RapaConfig(feat_dim=64))

    rows = []
    for tag, pset in (("metis", ps), ("rapa", res.partition_set)):
        for part in pset.parts:
            cols, vals = _pack_partition(part)
            st = ell_stats(cols, vals)
            # kernel parity on the real partition shape
            h = np.random.default_rng(0).normal(
                size=(part.n_local, 64)).astype(np.float32)
            out = ell_spmm(jnp.asarray(cols), jnp.asarray(vals),
                           jnp.asarray(h), interpret=True)
            want = R.ell_spmm_ref(jnp.asarray(cols), jnp.asarray(vals),
                                  jnp.asarray(h))
            err = float(np.abs(np.asarray(out) - np.asarray(want)).max())
            # hybrid ELL+COO pack (beyond-paper): quantile-capped width
            src, dst = part.local_graph.edges()
            keep = dst < part.n_inner
            w = part.local_graph.edge_weight
            w = (w[keep] if w is not None
                 else np.ones(keep.sum(), np.float32))
            hc, hv, ts, td, tw = ell_pack_hybrid(src[keep], dst[keep], w,
                                                 part.n_inner)
            hyb = hybrid_spmm(jnp.asarray(hc), jnp.asarray(hv),
                              jnp.asarray(ts), jnp.asarray(td),
                              jnp.asarray(tw), jnp.asarray(h))
            err_h = float(np.abs(np.asarray(hyb) - np.asarray(want)).max())
            st_h = ell_stats(hc, hv)
            rows.append({"partitioner": tag, "part": part.part_id, **st,
                         "kernel_max_err": err,
                         "hybrid_pad_waste": st_h["pad_waste"],
                         "hybrid_tail_edges": int(ts.shape[0]),
                         "hybrid_max_err": err_h})
    waste_metis = np.mean([r["pad_waste"] for r in rows
                           if r["partitioner"] == "metis"])
    waste_rapa = np.mean([r["pad_waste"] for r in rows
                          if r["partitioner"] == "rapa"])
    out = {"tiny": bool(tiny), "rows": rows,
           "pad_waste_metis": float(waste_metis),
           "pad_waste_rapa": float(waste_rapa),
           "pad_waste_hybrid": float(np.mean([r["hybrid_pad_waste"]
                                              for r in rows])),
           "max_kernel_err": max(r["kernel_max_err"] for r in rows),
           "max_hybrid_err": max(r["hybrid_max_err"] for r in rows),
           "backend_sweep": _backend_sweep(task, ps)}
    save(out_dir, "kernels_bench", out)
    return out


def main():
    out = run()
    print(f"kernels: pad waste metis {out['pad_waste_metis']:.2%} -> "
          f"rapa {out['pad_waste_rapa']:.2%} -> hybrid ELL+COO "
          f"{out['pad_waste_hybrid']:.2%}; "
          f"max |kernel - oracle| = {out['max_kernel_err']:.2e}, "
          f"hybrid {out['max_hybrid_err']:.2e}")
    for be, row in out["backend_sweep"].items():
        print(f"  backend {be:7s}: {row['step_ms']:.1f} ms/refresh-step, "
              f"logit max diff {row['logit_max_diff']:.2e}")


if __name__ == "__main__":
    main()
