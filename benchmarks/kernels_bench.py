"""Operator-level benchmark: ELL padding waste + kernel-vs-oracle parity on
partition-shaped workloads (the paper's SpMM hot spot, Table 1's compute
side), plus ELL pack statistics before/after RAPA pruning.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import PAPER_GROUPS, RapaConfig, do_partition, make_group
from repro.graph import build_partition, metis_partition
from repro.kernels.ops import (ell_pack, ell_pack_hybrid, ell_spmm,
                               ell_stats, hybrid_spmm)
from repro.kernels import ref as R
from ._util import DEFAULT_OUT, bench_task, save


def _pack_partition(part):
    src, dst = part.local_graph.edges()
    keep = dst < part.n_inner
    w = part.local_graph.edge_weight
    w = w[keep] if w is not None else np.ones(keep.sum(), np.float32)
    return ell_pack(src[keep], dst[keep], w, part.n_inner)


def run(out_dir: str = DEFAULT_OUT) -> dict:
    task = bench_task("flickr")
    g = task.graph
    profiles = make_group(PAPER_GROUPS["x4"])
    ps = build_partition(g, metis_partition(g, 4, seed=0), hops=1)
    res = do_partition(ps, profiles, RapaConfig(feat_dim=64))

    rows = []
    for tag, pset in (("metis", ps), ("rapa", res.partition_set)):
        for part in pset.parts:
            cols, vals = _pack_partition(part)
            st = ell_stats(cols, vals)
            # kernel parity on the real partition shape
            h = np.random.default_rng(0).normal(
                size=(part.n_local, 64)).astype(np.float32)
            out = ell_spmm(jnp.asarray(cols), jnp.asarray(vals),
                           jnp.asarray(h), interpret=True)
            want = R.ell_spmm_ref(jnp.asarray(cols), jnp.asarray(vals),
                                  jnp.asarray(h))
            err = float(np.abs(np.asarray(out) - np.asarray(want)).max())
            # hybrid ELL+COO pack (beyond-paper): quantile-capped width
            src, dst = part.local_graph.edges()
            keep = dst < part.n_inner
            w = part.local_graph.edge_weight
            w = (w[keep] if w is not None
                 else np.ones(keep.sum(), np.float32))
            hc, hv, ts, td, tw = ell_pack_hybrid(src[keep], dst[keep], w,
                                                 part.n_inner)
            hyb = hybrid_spmm(jnp.asarray(hc), jnp.asarray(hv),
                              jnp.asarray(ts), jnp.asarray(td),
                              jnp.asarray(tw), jnp.asarray(h))
            err_h = float(np.abs(np.asarray(hyb) - np.asarray(want)).max())
            st_h = ell_stats(hc, hv)
            rows.append({"partitioner": tag, "part": part.part_id, **st,
                         "kernel_max_err": err,
                         "hybrid_pad_waste": st_h["pad_waste"],
                         "hybrid_tail_edges": int(ts.shape[0]),
                         "hybrid_max_err": err_h})
    waste_metis = np.mean([r["pad_waste"] for r in rows
                           if r["partitioner"] == "metis"])
    waste_rapa = np.mean([r["pad_waste"] for r in rows
                          if r["partitioner"] == "rapa"])
    out = {"rows": rows,
           "pad_waste_metis": float(waste_metis),
           "pad_waste_rapa": float(waste_rapa),
           "pad_waste_hybrid": float(np.mean([r["hybrid_pad_waste"]
                                              for r in rows])),
           "max_kernel_err": max(r["kernel_max_err"] for r in rows),
           "max_hybrid_err": max(r["hybrid_max_err"] for r in rows)}
    save(out_dir, "kernels_bench", out)
    return out


def main():
    out = run()
    print(f"kernels: pad waste metis {out['pad_waste_metis']:.2%} -> "
          f"rapa {out['pad_waste_rapa']:.2%} -> hybrid ELL+COO "
          f"{out['pad_waste_hybrid']:.2%}; "
          f"max |kernel - oracle| = {out['max_kernel_err']:.2e}, "
          f"hybrid {out['max_hybrid_err']:.2e}")


if __name__ == "__main__":
    main()
