"""Paper Tables 7-8: overall performance + ablation.

Configurations (Table 8 rows): Vanilla (all-halo exchange every step),
+JACA, +RAPA, +JACA+RAPA, +JACA+RAPA+Pipe — per dataset x {GCN, SAGE},
heterogeneous x4 group.  Reports epoch time, exact communication bytes,
and final validation accuracy; Table 7's cross-method comparison columns
are the Vanilla vs full-CaPGNN pair.

``--backend edges|ell|hybrid`` swaps the local aggregation operator (the
Pallas SpMM backends run in interpret mode on CPU); results land in
``experiments/overall.json`` for ``edges`` and ``overall_<backend>.json``
otherwise, so a sweep keeps every variant side by side.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from repro.core import (CacheCapacity, PAPER_GROUPS, RapaConfig,
                        StalenessController, build_cache_plan, cal_capacity,
                        do_partition, make_group)
from repro.dist import (TrainSpec, build_exchange_plan, make_sim_runtime,
                        stack_partitions, train_capgnn)
from repro.graph import build_partition, metis_partition
from repro.models.gnn import GNNConfig
from repro.optim import adam
from ._util import DEFAULT_OUT, bench_task, save

EPOCHS = 40
DATASETS = ("flickr", "reddit")
MODELS = ("gcn", "sage")


def _maybe_tracer():
    """One shared tracer for the whole suite when ``benchmarks.run
    --trace`` (REPRO_BENCH_TRACE=1) is on; spans/counters from every
    variant land on one timeline."""
    if not bool(int(os.environ.get("REPRO_BENCH_TRACE", "0"))):
        return None
    from repro.obs import Tracer
    return Tracer()


def _variant(task, ps_base, profiles, model, jaca: bool, rapa: bool,
             pipe: bool, backend: str = "edges", tracer=None):
    cfg = GNNConfig(model=model, in_dim=task.features.shape[1],
                    hidden_dim=128, out_dim=task.num_classes, num_layers=3)
    ps = ps_base
    if rapa:
        ps = do_partition(ps_base, profiles,
                          RapaConfig(feat_dim=task.features.shape[1])
                          ).partition_set
    if jaca:
        cap = cal_capacity(ps, cfg.feat_dims, profiles)
        refresh = 4
    else:
        cap = CacheCapacity(c_gpu=[0] * ps.num_parts, c_cpu=0)
        refresh = 1
    plan = build_cache_plan(ps, cap, refresh_every=refresh)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task, backend=backend)
    opt = adam(0.01)
    spec = TrainSpec(backend=backend, refresh_every=refresh, pipeline=pipe)
    runtime = make_sim_runtime(cfg, sp, xplan, opt, spec=spec)
    ctl = StalenessController(refresh_every=refresh)
    params, rep = train_capgnn(cfg, runtime, xplan, ps.num_parts, opt,
                               epochs=EPOCHS, controller=ctl,
                               eval_every=0, spec=spec, tracer=tracer)
    _, acc = runtime.evaluate(params, "test")
    return {
        # steady-state epoch time: wall_time_s excludes the fenced
        # first step, which compile_s reports separately
        "epoch_s": rep.wall_time_s / max(1, EPOCHS - 1),
        "compile_s": rep.compile_s,
        "comm_mb": rep.comm_bytes / 2 ** 20,
        "comm_reduction": rep.comm_reduction,
        "test_acc": acc,
    }


VARIANTS = [("vanilla", False, False, False),
            ("+JACA", True, False, False),
            ("+RAPA", False, True, False),
            ("+JACA+RAPA", True, True, False),
            ("+JACA+RAPA+Pipe", True, True, True)]


def run(out_dir: str = DEFAULT_OUT, backend: str = "edges") -> dict:
    profiles = make_group(PAPER_GROUPS["x4"])
    tracer = _maybe_tracer()
    table = {}
    for ds in DATASETS:
        task = bench_task(ds)
        ps = build_partition(task.graph,
                             metis_partition(task.graph, 4, seed=0), hops=1)
        for model in MODELS:
            rows = {}
            for name, jaca, rapa, pipe in VARIANTS:
                rows[name] = _variant(task, ps, profiles, model, jaca, rapa,
                                      pipe, backend=backend, tracer=tracer)
            table[f"{ds}/{model}"] = rows

    # headline claims
    claims = {}
    for key, rows in table.items():
        van, full = rows["vanilla"], rows["+JACA+RAPA+Pipe"]
        claims[key] = {
            "comm_reduction_full": full["comm_reduction"],
            "acc_delta": full["test_acc"] - van["test_acc"],
            "comm_mb_vanilla": van["comm_mb"],
            "comm_mb_full": full["comm_mb"],
        }
    out = {"backend": backend, "table8": table, "claims": claims,
           "max_comm_reduction": max(c["comm_reduction_full"]
                                     for c in claims.values()),
           "min_acc_delta": min(c["acc_delta"] for c in claims.values())}
    name = "overall" if backend == "edges" else f"overall_{backend}"
    if tracer is not None:
        out["trace_file"] = tracer.export(out_dir, prefix=name)["trace"]
    save(out_dir, name, out)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="edges",
                    choices=("edges", "ell", "hybrid"),
                    help="local aggregation backend for the runtime")
    # parse_known_args: tolerate the benchmarks.run orchestrator's own flags
    args, _ = ap.parse_known_args(argv)
    out = run(backend=args.backend)
    print(f"overall[{args.backend}]: "
          f"max comm reduction {out['max_comm_reduction']:.1%}, "
          f"worst acc delta {out['min_acc_delta']:+.3f}")
    for key, rows in out["table8"].items():
        cells = "  ".join(
            f"{n}: {r['epoch_s']*1e3:.0f}ms/{r['comm_mb']:.1f}MB/"
            f"{r['test_acc']:.3f}" for n, r in rows.items())
        print(f"  {key}: {cells}")


if __name__ == "__main__":
    main()
