"""Production mesh construction.

Single pod: 256 TPU v5e chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the ``pod``
axis extends data parallelism (or sequence sharding for long-context).

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "dp_axes", "HW"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "the dry-run entry point must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import")
    import numpy as np
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes that shard tokens (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


class HW:
    """TPU v5e per-chip hardware constants (roofline)."""
    PEAK_BF16_FLOPS = 197e12     # FLOP/s
    HBM_BW = 819e9               # B/s
    ICI_BW = 50e9                # B/s per link (per brief)
    HBM_GIB = 16.0
