import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # Workaround for an XLA-CPU crash: AllReducePromotion's CloneAllReduce
    # check-fails ("Invalid binary instruction opcode copy") on variadic
    # all-reduces produced by SPMD-partitioned MoE graphs.  The pass is a
    # CPU-only bf16->f32 promotion; the TPU target never runs it.
    "--xla_disable_hlo_passes=all-reduce-promotion "
    + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry run: lower + compile every (architecture x input shape)
on the production meshes and extract memory / cost / collective stats.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all

The XLA_FLAGS line above MUST execute before any jax import (jax locks the
device count at first init); do not move it.
"""
import argparse
import dataclasses
import json
import re
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, INPUT_SHAPES, get_config, canonical,
                           long_context_variant)
from repro.models.transformer import (ModelConfig, use_spmd, loss_fn,
                                      train_step_fn, serve_step, forward)
from repro.optim import adam
from repro.launch.mesh import make_production_mesh, dp_axes, HW
from repro.launch import sharding as shd

__all__ = ["run_one", "collective_bytes", "main"]

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type, e.g. 'bf16[8,128]' or a tuple of them."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in compiled HLO (per device),
    bucketed by collective kind."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(\S+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+(\S+)\(", line)
        if not m:
            continue
        op = m.group(3)
        base = op.split(".")[0]
        # match e.g. all-gather, all-gather-start, all-reduce-start
        for kind in _COLLECTIVES:
            if base == kind or base.startswith(kind + "-"):
                if base.endswith("-done"):
                    break
                out[kind] += _shape_bytes(m.group(2))
                counts[kind] += 1
                break
    out_total = sum(out.values())
    return {"per_kind": out, "counts": counts, "total": out_total}


def build_step(cfg: ModelConfig, shape_name: str, mesh,
               act_mode: str = "baseline"):
    """Returns (jitted_fn, example_args_shape_structs, ctx, meta)."""
    seq_len, batch, kind = INPUT_SHAPES[shape_name]
    seq_shard = (batch == 1)
    if shape_name == "long_500k":
        cfg = long_context_variant(cfg)
    if kind == "train" and not cfg.remat:
        # block-level activation checkpointing is mandatory at these shapes
        cfg = dataclasses.replace(cfg, remat=True)
    ctx = shd.make_spmd_ctx(mesh, cfg, kind, seq_shard, act_mode=act_mode)
    p_shapes = shd.abstract_params(cfg)
    p_structs = shd.attach(p_shapes, shd.param_shardings(mesh, cfg, p_shapes))

    if kind == "train":
        opt = adam(3e-4)
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_structs = shd.attach(o_shapes,
                               shd.param_shardings(mesh, cfg, o_shapes,
                                                   zero_data=True))
        batch_structs = shd.batch_specs(mesh, cfg, seq_len, batch, kind,
                                        seq_shard)
        step = train_step_fn(cfg, opt)
        fn = jax.jit(step, donate_argnums=(0, 1))
        args = (p_structs, o_structs, batch_structs)
    elif kind == "prefill":
        batch_structs = shd.batch_specs(mesh, cfg, seq_len, batch, kind,
                                        seq_shard)

        def prefill(params, b):
            logits, _ = forward(cfg, params, b)
            return logits[:, -1]        # next-token logits only

        fn = jax.jit(prefill)
        args = (p_structs, batch_structs)
    else:  # decode
        cache_structs = shd.decode_state_specs(mesh, cfg, batch, seq_len,
                                               seq_shard)
        dp = dp_axes(mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        tok = jax.ShapeDtypeStruct(
            (batch, 1), jnp.int32,
            sharding=NamedSharding(mesh, P(None if seq_shard else dp, None)))
        pos = jax.ShapeDtypeStruct((), jnp.int32)

        def decode(params, caches, tokens, position):
            return serve_step(cfg, params, caches, tokens, position)

        fn = jax.jit(decode, donate_argnums=(1,))
        args = (p_structs, cache_structs, tok, pos)
    return fn, args, ctx, {"cfg": cfg, "seq_len": seq_len, "batch": batch,
                           "kind": kind}


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            cfg_override: ModelConfig | None = None,
            ctx_override=None, act_mode: str = "baseline") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg_override or get_config(arch)
    fn, args, ctx, meta = build_step(cfg, shape_name, mesh, act_mode=act_mode)
    if ctx_override is not None:
        ctx = ctx_override(mesh, meta)
    n_dev = mesh.devices.size
    t0 = time.perf_counter()
    with use_spmd(ctx):
        with jax.set_mesh(mesh):
            lowered = fn.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    # Trip-count-aware roll-up: XLA's cost_analysis charges while (scan)
    # bodies once; analyse_hlo multiplies by the recovered trip counts so
    # scanned layers / flash-attention chunks are fully counted.
    from repro.launch.hlo_cost import analyse_hlo
    acc = analyse_hlo(hlo_text)
    result = {
        "arch": meta["cfg"].name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "act_mode": act_mode,
        "devices": int(n_dev),
        "seq_len": meta["seq_len"], "batch": meta["batch"],
        "kind": meta["kind"],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_flops_per_device": acc.flops,
        "hlo_bytes_per_device": acc.bytes_accessed,
        "collective_bytes_per_device": acc.collective_total,
        "collectives": acc.collective_bytes,
        "collective_counts": acc.collective_counts,
        "unresolved_loops": acc.unresolved_loops,
        "xla_raw": {  # once-per-body numbers, kept as a cross-check
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collective_bytes": collective_bytes(hlo_text)["total"],
        },
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes",
                                           None),
        },
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--act-mode", default="baseline",
                    choices=["baseline", "block_sp"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [canonical(args.arch)]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
                if args.act_mode != "baseline":
                    tag += f"_{args.act_mode}"
                try:
                    res = run_one(arch, shape, multi_pod=mp,
                                  act_mode=args.act_mode)
                except Exception as exc:  # noqa: BLE001 - report and continue
                    failures.append((tag, str(exc)[:200]))
                    print(f"FAIL {tag}: {exc}")
                    continue
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                print(f"OK   {tag}  flops/dev={res['hlo_flops_per_device']:.3e} "
                      f"coll/dev={res['collective_bytes_per_device']:.3e} "
                      f"temp={res['memory']['temp_size']}")
    if failures:
        print(f"{len(failures)} FAILURES")
        for tag, msg in failures:
            print(" ", tag, msg)
        raise SystemExit(1)
    print("all dry-runs passed")


if __name__ == "__main__":
    main()
