"""Serving drivers.

Two entry points:

- ``python -m repro.launch.serve gnn ...`` — partitioned GNN query serving:
  precompute per-layer embeddings through the CaPGNN exchange machinery,
  stand up the two-tier cache engine, and drive a synthetic query stream
  through the micro-batcher; prints QPS, latency percentiles and per-tier
  hit rates.
- ``python -m repro.launch.serve lm ...`` — batched transformer decode
  against the KV cache (the architecture-zoo serve path).

Both are host-scale drivers; full shapes are exercised via
``repro.launch.dryrun`` decode lowering.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def run_lm(args) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.models.transformer import (init_model, init_decode_cache,
                                          serve_step)

    cfg = get_reduced(args.arch)
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    caches = init_decode_cache(cfg, args.batch, args.cache_len)
    step = jax.jit(lambda p, c, t, pos: serve_step(cfg, p, c, t, pos))

    rng = np.random.default_rng(args.seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, 1)),
                         jnp.int32)
    # warm up / compile — sync before starting the clock so compile and
    # first-step dispatch don't bleed into the timed loop
    logits, caches = step(params, caches, tokens, jnp.int32(0))
    jax.block_until_ready((logits, caches))
    t0 = time.perf_counter()
    for i in range(1, args.steps):
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        nxt = jnp.where(nxt >= cfg.vocab_size, 0, nxt)
        logits, caches = step(params, caches, nxt, jnp.int32(i))
    logits.block_until_ready()
    wall = time.perf_counter() - t0
    out = {
        "arch": cfg.name, "batch": args.batch, "steps": args.steps,
        "tokens_per_s": round(args.batch * (args.steps - 1) / wall, 1),
        "logits_finite": bool(jnp.isfinite(logits).all()),
    }
    print(json.dumps(out, indent=1))
    return out


def run_gnn(args) -> dict:
    import jax
    from repro.core import (PROFILES, PAPER_GROUPS, make_group, cal_capacity,
                            build_cache_plan)
    from repro.data import make_task
    from repro.dist import build_exchange_plan, stack_partitions
    from repro.graph import metis_partition, random_partition, build_partition
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.serve import (BatchConfig, GNNServeEngine, load_store,
                             make_stream, precompute_embeddings,
                             rank_hot_nodes, save_store, serve_stream)

    task = make_task(args.dataset, scale=args.scale, feat_dim=args.feat_dim,
                     seed=args.seed)
    g = task.graph
    p = args.parts
    part_fn = {"metis": metis_partition,
               "random": random_partition}[args.partitioner]
    ps = build_partition(g, part_fn(g, p, seed=args.seed), hops=1)
    profiles = make_group(PAPER_GROUPS[f"x{p}"]) if f"x{p}" in PAPER_GROUPS \
        else [PROFILES["rtx3090"]] * p

    # a loaded store fixes the model config and backend (it was precomputed
    # with them); otherwise they come from the CLI
    store = None
    if args.load_store:
        if not args.store_dir:
            raise SystemExit("--load-store requires --store-dir")
        store = load_store(args.store_dir)
        if store.num_nodes != g.num_nodes:
            raise SystemExit(
                f"store in {args.store_dir} was precomputed over "
                f"{store.num_nodes} nodes but this task has {g.num_nodes}; "
                "re-run precompute (drop --load-store)")
        cfg, backend = store.cfg, store.backend
    else:
        cfg = GNNConfig(model=args.model, in_dim=task.features.shape[1],
                        hidden_dim=args.hidden, out_dim=task.num_classes,
                        num_layers=args.layers)
        backend = args.backend
    params = init_gnn(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt_dir:
        # restore weights trained by `repro.launch.train gnn --ckpt-dir ...`
        from repro.checkpoint import latest_step, load_checkpoint
        from repro.optim import adam
        step = latest_step(args.ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {args.ckpt_dir}")
        like = {"params": params, "opt_state": adam(1e-2).init(params)}
        params = load_checkpoint(args.ckpt_dir, step, like)["params"]

    t0 = time.perf_counter()
    if store is None:
        cap = cal_capacity(ps, cfg.feat_dims, profiles,
                           m_cpu_gib=args.cpu_cache_gib)
        plan = build_cache_plan(ps, cap, refresh_every=args.refresh_every)
        xplan = build_exchange_plan(ps, plan)
        sp = stack_partitions(ps, task, backend=backend)
        store = precompute_embeddings(cfg, ps, sp, xplan, params,
                                      backend=backend)
        if args.store_dir:
            save_store(args.store_dir, store)
    precompute_s = time.perf_counter() - t0

    hot_capacity = int(round(args.hot_frac * g.num_nodes))
    hot = rank_hot_nodes(g, hot_capacity, ps=ps, policy=args.hot_rank)
    engine = GNNServeEngine(store, params, g, hot, features=task.features,
                            fresh_hops=args.fresh_hops)

    rng = np.random.default_rng(args.seed)
    if args.update_frac > 0:
        upd = rng.choice(g.num_nodes,
                         max(1, int(args.update_frac * g.num_nodes)),
                         replace=False)
        engine.update_features(
            upd, task.features[upd]
            + rng.normal(scale=0.5, size=(upd.size,
                                          task.features.shape[1])))

    if args.popularity == "degree":
        # popularity rank == hot-tier degree rank: the zipf head hits HBM
        rank_to_node = rank_hot_nodes(g, g.num_nodes, policy="degree")
    else:
        rank_to_node = None
    stream = make_stream(args.workload, g.num_nodes, args.queries,
                         qps=args.qps, alpha=args.alpha, seed=args.seed,
                         rank_to_node=rank_to_node)
    tracer = None
    if getattr(args, "trace", False):
        from repro.obs import Tracer
        tracer = Tracer()
    report = serve_stream(engine, stream,
                          BatchConfig(max_batch=args.max_batch,
                                      deadline_ms=args.deadline_ms),
                          tracer=tracer)
    out = {
        "dataset": args.dataset, "model": cfg.model,
        "backend": backend, "parts": p,
        "nodes": g.num_nodes, "layers": cfg.num_layers,
        "hot_capacity": hot_capacity, "hot_rank": args.hot_rank,
        "stale_nodes": int(engine.stale.sum()),
        "precompute_s": round(precompute_s, 3),
        **{k: (round(v, 4) if isinstance(v, float) else v)
           for k, v in report.items()},
    }
    if tracer is not None:
        paths = tracer.export(args.trace_dir, prefix="serve")
        out["trace_file"] = paths["trace"]
        out["metrics_file"] = paths["metrics"]
    print(json.dumps(out, indent=1))
    return out


def main():
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)

    g = sub.add_parser("gnn")
    g.add_argument("--dataset", default="flickr")
    g.add_argument("--scale", type=float, default=0.02)
    g.add_argument("--feat-dim", type=int, default=64)
    g.add_argument("--model", default="gcn",
                   choices=["gcn", "sage", "gat", "gin"])
    g.add_argument("--backend", default="edges",
                   choices=["edges", "ell", "hybrid"],
                   help="aggregation backend for the precompute pass "
                        "(ell/hybrid run the Pallas SpMM; interpret on CPU)")
    g.add_argument("--hidden", type=int, default=64)
    g.add_argument("--layers", type=int, default=3)
    g.add_argument("--parts", type=int, default=4)
    g.add_argument("--partitioner", default="metis",
                   choices=["metis", "random"])
    g.add_argument("--refresh-every", type=int, default=4)
    g.add_argument("--cpu-cache-gib", type=float, default=4.0)
    g.add_argument("--ckpt-dir", default="",
                   help="load trained params from repro.launch.train gnn")
    g.add_argument("--store-dir", default="",
                   help="persist the precomputed embedding store here")
    g.add_argument("--load-store", action="store_true",
                   help="skip precompute; load the store from --store-dir")
    g.add_argument("--hot-frac", type=float, default=0.1,
                   help="fraction of nodes resident in the device hot tier")
    g.add_argument("--hot-rank", default="degree",
                   choices=["degree", "overlap"])
    g.add_argument("--workload", default="zipf",
                   choices=["uniform", "zipf", "bursty"])
    g.add_argument("--queries", type=int, default=2048)
    g.add_argument("--qps", type=float, default=500.0,
                   help="mean simulated arrival rate (keep below the "
                        "engine's service QPS to measure latency rather "
                        "than queue backlog)")
    g.add_argument("--alpha", type=float, default=1.1,
                   help="zipf popularity exponent")
    g.add_argument("--popularity", default="degree",
                   choices=["degree", "random"],
                   help="map popularity ranks to node ids by degree "
                        "(aligned with the hot tier) or a random permutation")
    g.add_argument("--max-batch", type=int, default=64)
    g.add_argument("--deadline-ms", type=float, default=2.0)
    g.add_argument("--update-frac", type=float, default=0.0,
                   help="perturb this fraction of node features before "
                        "serving (exercises the fresh=k recompute path)")
    g.add_argument("--fresh-hops", type=int, default=None,
                   help="k for the fresh recompute (default: num layers, "
                        "which is exact)")
    g.add_argument("--trace", action="store_true",
                   help="enable the repro.obs tracer over the serve loop: "
                        "per-batch spans + hit/miss counters, exported as "
                        "a Perfetto-loadable Chrome trace")
    g.add_argument("--trace-dir", default="experiments",
                   help="directory for trace_serve.json / "
                        "metrics_serve.jsonl (with --trace)")
    g.add_argument("--seed", type=int, default=0)
    g.set_defaults(fn=run_gnn)

    l = sub.add_parser("lm")
    l.add_argument("--arch", default="qwen3-1.7b")
    l.add_argument("--batch", type=int, default=4)
    l.add_argument("--steps", type=int, default=32)
    l.add_argument("--cache-len", type=int, default=256)
    l.add_argument("--seed", type=int, default=0)
    l.set_defaults(fn=run_lm)

    args = ap.parse_args()
    args.fn(args)


if __name__ == "__main__":
    main()
