"""Batched serving driver: prefill-free decode loop over the KV cache.

Host-scale demo of the serve path (reduced configs on CPU); the full
shapes are exercised via ``repro.launch.dryrun`` decode lowering.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.models.transformer import (init_model, init_decode_cache,
                                          serve_step)

    cfg = get_reduced(args.arch)
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    caches = init_decode_cache(cfg, args.batch, args.cache_len)
    step = jax.jit(lambda p, c, t, pos: serve_step(cfg, p, c, t, pos))

    rng = np.random.default_rng(args.seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, 1)),
                         jnp.int32)
    # warm up / compile
    logits, caches = step(params, caches, tokens, jnp.int32(0))
    t0 = time.perf_counter()
    for i in range(1, args.steps):
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        nxt = jnp.where(nxt >= cfg.vocab_size, 0, nxt)
        logits, caches = step(params, caches, nxt, jnp.int32(i))
    logits.block_until_ready()
    wall = time.perf_counter() - t0
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch, "steps": args.steps,
        "tokens_per_s": round(args.batch * (args.steps - 1) / wall, 1),
        "logits_finite": bool(jnp.isfinite(logits).all()),
    }, indent=1))


if __name__ == "__main__":
    main()
