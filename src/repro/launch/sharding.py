"""Sharding rules: parameter specs, activation specs, input specs.

Rules are path-name based over the param pytree; GSPMD pads non-divisible
dims (e.g. 40 q-heads or 2 kv-heads over a 16-way model axis), which the
roofline accounting treats as measured waste rather than hiding it.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import ModelConfig, SpmdCtx
from repro.models.transformer.model import init_model, init_decode_cache
from .mesh import dp_axes

__all__ = ["param_specs", "param_shardings", "make_spmd_ctx", "batch_specs",
           "decode_state_specs", "abstract_params", "attach"]

M = "model"


def _leaf_spec(path: tuple[str, ...], leaf, ep_experts: bool) -> P:
    """PartitionSpec for one (unstacked) param leaf, by name rules.

    ``ep_experts``: expert count divides the model axis -> expert-parallel
    layout [E/model, D/data, F]; otherwise tensor-parallel experts
    [E, D, F/model].
    """
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    nd = leaf.ndim

    def spec(*axes):
        return P(*(list(axes) + [None] * nd)[:nd])

    if name == "embed":
        return spec(M, None)
    if name == "lm_head":
        return spec(None, M)
    if name in ("scale", "bias", "eps"):
        return spec(None)
    # attention
    if name in ("wq", "wk", "wv") and parent == "attn":
        return spec(None, M)
    if name in ("bq", "bk", "bv"):
        return spec(M)
    if name == "wo":
        return spec(M, None)
    if name in ("wdq", "wuq", "wukv"):
        return spec(None, M)
    if name == "wdkv":
        return spec(None, None)
    # ffn / shared expert
    if name in ("wg", "wu", "wi") and nd == 2:
        return spec(None, M)
    if name == "wd" and nd == 2:
        return spec(M, None)
    if name == "bi":
        return spec(M)
    # moe experts [E, D, F] / [E, F, D]
    if nd == 3 and name in ("wg", "wu"):
        return spec(M, "data", None) if ep_experts else spec(None, None, M)
    if nd == 3 and name == "wd":
        return spec(M, None, "data") if ep_experts else spec(None, M, None)
    if name == "router":
        return spec(None, None)
    # mlstm
    if name in ("wz",):
        return spec(None, M)
    if name == "wif":
        return spec(None, None)
    # slstm
    if name == "r":
        return spec(None)
    # mamba
    if name == "win":
        return spec(None, M)
    if name == "wout":
        return spec(M, None)
    if name in ("wbc", "wdt1", "a_log"):
        return spec(M, None)
    if name in ("conv", "conv_b", "wdt2", "dt_b", "d_skip"):
        return spec(None)
    if name == "wg" and parent != "attn":  # slstm gates [D, 4D]
        return spec(None, M)
    return spec(None)


def _path_names(path) -> tuple[str, ...]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(f"[{e.idx}]")
        elif hasattr(e, "name"):
            names.append(str(e.name))
    return tuple(names)


def param_specs(cfg: ModelConfig, params_shape, *, model_size: int = 16,
                data_size: int = 16, zero_data: bool = False) -> Any:
    """PartitionSpec tree matching the param pytree (handles the stacked
    run axis: anything under 'runs' gets a leading None).

    ``zero_data``: additionally shard the largest still-unsharded,
    data-divisible dim over 'data' (ZeRO-1 — used for optimizer moments).
    Every chosen axis is validated against the dim size (input shardings
    must divide evenly) and dropped if it does not fit.
    """
    ep_experts = cfg.n_experts > 0 and cfg.n_experts % model_size == 0

    def one(path, leaf):
        names = _path_names(path)
        stacked = "runs" in names
        base_names = tuple(n for n in names if not n.startswith("["))

        class V:
            ndim = leaf.ndim - (1 if stacked else 0)
        sp = _leaf_spec(base_names if base_names else names, V, ep_experts)
        if stacked:
            sp = P(*([None] + list(sp)))
        # validate divisibility; drop axes that do not fit
        sizes = {"model": model_size, "data": data_size}
        ent = []
        for dim, ax in zip(leaf.shape, tuple(sp) + (None,) * leaf.ndim):
            if ax is not None and dim % sizes.get(ax, 1) != 0:
                ax = None
            ent.append(ax)
        if zero_data and "data" not in ent:
            start = 1 if stacked else 0
            cands = [i for i in range(start, leaf.ndim)
                     if ent[i] is None and leaf.shape[i] % data_size == 0]
            if cands:
                big = max(cands, key=lambda i: leaf.shape[i])
                ent[big] = "data"
        return P(*ent)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(mesh: Mesh, cfg: ModelConfig, params_shape,
                    zero_data: bool = False):
    specs = param_specs(cfg, params_shape,
                        model_size=mesh.shape.get("model", 1),
                        data_size=mesh.shape.get("data", 1),
                        zero_data=zero_data)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_model(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def attach(shape_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shape_tree, sharding_tree)


def make_spmd_ctx(mesh: Mesh, cfg: ModelConfig, shape_kind: str,
                  seq_shard: bool, act_mode: str = "baseline") -> SpmdCtx:
    """Activation sharding policy.

    train/prefill: batch over dp; hidden sequence dim sharded over 'model'
    between blocks (Megatron-style sequence parallelism — keeps the saved
    residuals at 1/16 size, which is what lets 14B x 4k x 256 fit HBM).
    long-context (seq_shard): sequence over dp instead of batch.
    decode: batch over dp.

    ``act_mode='block_sp'`` (§Perf) keeps the same between-block residual
    layout but adds per-block constraints that gather the sequence once and
    shard heads / SSM channels over 'model' inside attention and recurrent
    scans — removing the per-chunk / per-timestep collectives GSPMD
    otherwise inserts.
    """
    dp = dp_axes(mesh)
    if seq_shard:
        act = P(None, dp, None)
        logits = P(None, dp, None)
    elif shape_kind in ("train", "prefill"):
        act = P(dp, M, None)
        logits = P(dp, None, M)
    else:
        act = P(dp, None, None)
        logits = P(dp, None, M)
    return SpmdCtx(mesh=mesh, dp_axes=dp, act_spec=act, logits_spec=logits,
                   block_sp=(act_mode == "block_sp"
                             and shape_kind in ("train", "prefill")
                             and not seq_shard))


def batch_specs(mesh: Mesh, cfg: ModelConfig, seq_len: int, batch: int,
                shape_kind: str, seq_shard: bool):
    """ShapeDtypeStructs (with shardings) for the input batch."""
    dp = dp_axes(mesh)
    tok_spec = P(None, dp) if seq_shard else P(dp, None)
    tok_sh = NamedSharding(mesh, tok_spec)
    s_text = seq_len - (cfg.vision_tokens or 0)
    batch_tree = {
        "tokens": jax.ShapeDtypeStruct((batch, s_text), jnp.int32,
                                       sharding=tok_sh),
        "labels": jax.ShapeDtypeStruct((batch, s_text), jnp.int32,
                                       sharding=tok_sh),
    }
    if cfg.vision_tokens:
        batch_tree["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, P(dp, None, None)))
    return batch_tree


def _cache_leaf_spec(path_names, leaf_shape, cfg, seq_shard, dp,
                     sizes) -> P:
    """Sharding for decode-cache leaves (divisibility-checked; for k/v the
    model axis lands on kv-heads when divisible, else on head_dim)."""
    name = path_names[-1] if path_names else ""
    nd = len(leaf_shape)
    m_size = sizes.get("model", 1)

    def fit(sp):
        ent = []
        for dim, ax in zip(leaf_shape, tuple(sp) + (None,) * nd):
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= sizes.get(a, 1)
                if dim % n != 0:
                    ax = None
            ent.append(ax)
        return P(*ent)

    if name in ("k", "v"):          # [L, B, W, nkv, hd]
        head_ax = M if leaf_shape[3] % m_size == 0 else None
        dim_ax = None if head_ax else (M if leaf_shape[4] % m_size == 0 else None)
        if seq_shard:
            return fit(P(None, None, dp, head_ax, dim_ax))
        return fit(P(None, dp, None, head_ax, dim_ax))
    if name == "pos":               # [L, B, W]
        return fit(P(None, None, dp) if seq_shard else P(None, dp, None))
    if name == "ckv":               # [L, B, S, kvl]
        return fit(P(None, None, dp, M) if seq_shard
                   else P(None, dp, None, M))
    if name == "kr":                # [L, B, S, rdim]
        return fit(P(None, None, dp, None) if seq_shard
                   else P(None, dp, None, None))
    if name == "conv":              # [L, B, CW-1, DI]
        return fit(P(None, None if seq_shard else dp, None, M))
    if name == "h" and nd == 4:     # mamba state [L, B, DI, N]
        return fit(P(None, None if seq_shard else dp, M, None))
    # mlstm state c [L,B,H,dk,dv] / n [L,B,H,dk]; slstm states [L,B,D]
    specs = [None, None if seq_shard else dp] + [None] * (nd - 2)
    return fit(P(*specs))


def decode_state_specs(mesh: Mesh, cfg: ModelConfig, batch: int,
                       max_len: int, seq_shard: bool):
    """ShapeDtypeStructs for the stacked decode caches."""
    dp = dp_axes(mesh)
    sizes = dict(mesh.shape)
    cache_shape = jax.eval_shape(
        lambda: init_decode_cache(cfg, batch, max_len))

    def one(path, leaf):
        names = _path_names(path)
        sp = _cache_leaf_spec(names, leaf.shape, cfg, seq_shard, dp, sizes)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, sp))

    return jax.tree_util.tree_map_with_path(one, cache_shape)
