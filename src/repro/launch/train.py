"""Training launchers.

Two entry points:

- ``python -m repro.launch.train gnn ...``  — CaPGNN full-batch GNN
  training (the paper's workload): partitions, JACA plan, RAPA balance,
  staleness schedule, byte accounting.
- ``python -m repro.launch.train lm --arch <id> ...`` — token-LM training
  for the architecture-zoo configs (reduced or full), single host.

Both are host-scale drivers; the production mesh path is exercised by
``repro.launch.dryrun`` (this container has one real device).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def run_gnn(args) -> dict:
    import jax
    from repro.core import (PROFILES, PAPER_GROUPS, make_group, cal_capacity,
                            build_cache_plan, do_partition, RapaConfig,
                            CacheCapacity, StalenessController,
                            AdaptivePlanner, capability_weights)
    from repro.data import make_task
    from repro.dist import (build_exchange_plan, stack_partitions,
                            make_sim_runtime, train_capgnn)
    from repro.graph import metis_partition, random_partition, build_partition
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.optim import adam

    from repro.dist.spec import TrainSpec
    from repro.dist.strategy import StrategyCapabilityError, get_strategy

    task = make_task(args.dataset, scale=args.scale, feat_dim=args.feat_dim,
                     seed=args.seed)
    g = task.graph
    p = args.parts

    # one constructor path for the whole config surface: CLI flags ->
    # TrainSpec (validated, including strategy capability checks)
    try:
        spec = TrainSpec.from_cli_args(args)
        strat = get_strategy(spec.strategy)
    except ValueError as e:       # includes StrategyCapabilityError
        raise SystemExit(str(e))
    is_15d = spec.strategy == "spmm_15d"
    c = spec.replication
    if is_15d and p % (c * c):
        raise SystemExit(
            f"spmm_15d needs --parts divisible by replication**2 "
            f"(P % c**2 == 0): got --parts={p} --replication={c}")
    # under spmm_15d --parts is the total device count P; the graph is
    # partitioned into the pr = P / c block rows
    n_parts = p // c if is_15d else p

    # device group first: with --uneven the profile shapes the partition
    # sizes (RAPA's resource-aware pre-partition), not just the pruning
    group = getattr(args, "group", "auto")
    if group == "auto":
        group = f"x{n_parts}" if f"x{n_parts}" in PAPER_GROUPS else "uniform"
    profiles = ([PROFILES["rtx3090"]] * n_parts if group == "uniform"
                else make_group(PAPER_GROUPS[group]))
    if len(profiles) != n_parts:
        raise SystemExit(f"device group {group!r} has {len(profiles)} "
                         f"devices but the run needs {n_parts} partitions")

    uneven = getattr(args, "uneven", True)
    weights = capability_weights(profiles) if uneven else None
    part_fn = {"metis": metis_partition, "random": random_partition}[args.partitioner]
    assign = part_fn(g, n_parts, seed=args.seed, weights=weights)
    ps = build_partition(g, assign, hops=1, parts=n_parts)
    if args.rapa:
        res = do_partition(ps, profiles, RapaConfig(feat_dim=args.feat_dim))
        ps = res.partition_set

    cfg = GNNConfig(model=args.model, in_dim=task.features.shape[1],
                    hidden_dim=args.hidden, out_dim=task.num_classes,
                    num_layers=args.layers)
    if is_15d:
        try:
            return _run_gnn_15d(args, spec, strat, task, ps, cfg, group,
                                uneven)
        except StrategyCapabilityError as e:
            raise SystemExit(str(e))
    if args.jaca:
        cap = cal_capacity(ps, cfg.feat_dims, profiles,
                           m_cpu_gib=args.cpu_cache_gib)
    else:
        cap = CacheCapacity(c_gpu=[0] * p, c_cpu=0)
    cache_policy = getattr(args, "cache_policy", "static")
    planner = None
    if cache_policy != "static":
        # online adaptation: the planner owns the initial plan AND the
        # slot-stable capacity padding, so the runtime's installed plan and
        # the planner's hit/drift accounting can never desync
        planner = AdaptivePlanner(ps, cap, refresh_every=args.refresh_every,
                                  policy=cache_policy, seed=args.seed)
        xplan = planner.exchange_plan()
    else:
        plan = build_cache_plan(ps, cap, refresh_every=args.refresh_every)
        xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task, backend=args.backend)
    opt = adam(args.lr)
    halo_dtype = spec.halo_dtype
    features = spec.features
    prefetch_depth = spec.prefetch_depth
    runtime = make_sim_runtime(cfg, sp, xplan, opt, spec=spec)
    ctl = StalenessController(refresh_every=args.refresh_every,
                              adaptive=args.adaptive_staleness,
                              replan_every=getattr(args, "replan_every", 1))

    # --resume: restore (params, opt_state, epoch) and run the remaining
    # epochs; --epochs is the *total* budget across runs.
    start_epoch, params0, opt_state0 = 0, None, None
    if args.resume and args.ckpt_dir:
        from repro.checkpoint import latest_step, load_checkpoint
        step = latest_step(args.ckpt_dir)
        if step is not None:
            like = init_gnn(jax.random.PRNGKey(args.seed), cfg)
            state = load_checkpoint(args.ckpt_dir, step,
                                    {"params": like,
                                     "opt_state": opt.init(like)})
            params0, opt_state0 = state["params"], state["opt_state"]
            start_epoch = step
    run_epochs = max(0, args.epochs - start_epoch)

    # fault injection + graceful degradation (repro.faults): a --faults
    # spec enables seeded injectors; any of the defense knobs builds a
    # TrainGuard even without injected faults (defense-only runs)
    faults_spec = getattr(args, "faults", "")
    guard_every = int(getattr(args, "guard_every", 0) or 0)
    fetch_retries = getattr(args, "fetch_retries", None)
    checksums = bool(getattr(args, "checksums", False))
    faults = guard = None
    if faults_spec:
        from repro.faults import FaultPlan
        faults = FaultPlan.parse(faults_spec, seed=args.seed)
    if (faults is not None or guard_every or checksums
            or fetch_retries is not None):
        from repro.faults import GuardConfig
        guard = GuardConfig(
            guard_every=guard_every,
            fetch_retries=(2 if fetch_retries is None
                           else int(fetch_retries)),
            checksums=checksums)

    tracer = None
    if getattr(args, "trace", False):
        from repro.obs import Tracer
        tracer = Tracer()
    device_trace_dir = getattr(args, "device_trace_dir", "")
    from repro.obs import device_trace
    with device_trace(device_trace_dir):
        params, report = train_capgnn(cfg, runtime, xplan, p, opt,
                                      epochs=run_epochs, controller=ctl,
                                      spec=spec,
                                      params0=params0, opt_state0=opt_state0,
                                      planner=planner, tracer=tracer,
                                      faults=faults, guard=guard)
    _, test_acc = runtime.evaluate(params, "test")
    out = {
        "dataset": args.dataset, "model": args.model, "parts": p,
        "strategy": spec.strategy, "replication": spec.replication,
        "group": group, "uneven": bool(uneven),
        "inner_sizes": [pt.n_inner for pt in ps.parts],
        "stack_waste_frac": runtime.padding_stats().get("waste_frac"),
        "epochs": args.epochs, "resumed_from": start_epoch,
        "final_loss": report.losses[-1] if report.losses else None,
        "halo_dtype": halo_dtype,
        "features": features, "prefetch_depth": prefetch_depth,
        "host_fetch_rows": report.host_fetch_rows,
        "host_fetch_bytes": report.host_fetch_bytes,
        "host_writeback_bytes": report.host_writeback_bytes,
        "cache_policy": cache_policy,
        "replan_events": report.replan_events,
        "planner_hit_rate": report.hit_rate,
        "test_acc": test_acc, "comm_bytes": report.comm_bytes,
        "comm_reduction_vs_vanilla": report.comm_reduction,
        "refresh_steps": report.refresh_steps,
        "cached_steps": report.cached_steps,
        # compile_s is the fenced step-0 time; wall_time_s is steady state
        "compile_s": round(report.compile_s, 3),
        "wall_time_s": round(report.wall_time_s, 2),
    }
    if report.fault_events is not None:
        out["faults"] = (faults.spec_string() if faults is not None else "")
        out["faults_injected"] = report.faults_injected
        out["fault_events"] = report.fault_events
    if tracer is not None:
        paths = tracer.export(args.trace_dir, prefix="train")
        out["phase_stats"] = report.phase_stats
        out["trace_file"] = paths["trace"]
        out["metrics_file"] = paths["metrics"]
    print(json.dumps(out, indent=1))
    if args.ckpt_dir:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt_dir, start_epoch + run_epochs,
                        {"params": params,
                         "opt_state": report.final_opt_state})
    return out


def _run_gnn_15d(args, spec, strat, task, ps, cfg, group, uneven) -> dict:
    """The ``--strategy spmm_15d`` branch of ``run_gnn``: 1.5D replicated-
    row block SpMM over a real ``(grp, sub, repl)`` device mesh.  Needs
    ``--parts`` visible devices (force host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=P`` on CPU).
    Every step is exact (refresh-equivalent), so the staleness/caching
    flags do not apply — ``TrainSpec.from_cli_args`` normalises them away
    and the capability validation rejects explicit halo-only requests."""
    import jax
    from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
    from repro.models.gnn import init_gnn
    from repro.optim import adam

    p = args.parts
    if len(jax.devices()) < p:
        raise SystemExit(
            f"spmm_15d with --parts={p} needs {p} devices but only "
            f"{len(jax.devices())} are visible; on CPU force host devices "
            f"with XLA_FLAGS=--xla_force_host_platform_device_count={p}")
    layout = strat.build_layout(ps, task, spec)
    opt = adam(args.lr)
    runtime = strat.make_spmd_runtime(cfg, layout, opt, spec)

    start_epoch, params0, opt_state0 = 0, None, None
    if args.resume and args.ckpt_dir:
        step = latest_step(args.ckpt_dir)
        if step is not None:
            like = init_gnn(jax.random.PRNGKey(args.seed), cfg)
            state = load_checkpoint(args.ckpt_dir, step,
                                    {"params": like,
                                     "opt_state": opt.init(like)})
            params0, opt_state0 = state["params"], state["opt_state"]
            start_epoch = step
    run_epochs = max(0, args.epochs - start_epoch)

    params, report = strat.train(cfg, runtime, layout, opt, spec,
                                 epochs=run_epochs, seed=args.seed,
                                 params0=params0, opt_state0=opt_state0)
    _, test_acc = runtime.evaluate(params, "test")
    out = {
        "dataset": args.dataset, "model": args.model, "parts": p,
        "strategy": spec.strategy, "replication": spec.replication,
        "block_rows": layout.pr, "group_size": layout.g,
        "group": group, "uneven": bool(uneven),
        "inner_sizes": [pt.n_inner for pt in ps.parts],
        "epochs": args.epochs, "resumed_from": start_epoch,
        "final_loss": report.losses[-1] if report.losses else None,
        "halo_dtype": spec.halo_dtype,
        "test_acc": test_acc, "comm_bytes": report.comm_bytes,
        # vanilla = dense 1D full-H all-gather on the same block rows, so
        # the reduction isolates the replication benefit
        "comm_reduction_vs_vanilla": report.comm_reduction,
        "fwd_collective_bytes_per_device": runtime.forward_bytes_per_device,
        "refresh_steps": report.refresh_steps,
        "cached_steps": report.cached_steps,
        "compile_s": round(report.compile_s, 3),
        "wall_time_s": round(report.wall_time_s, 2),
    }
    print(json.dumps(out, indent=1))
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, start_epoch + run_epochs,
                        {"params": params,
                         "opt_state": report.final_opt_state})
    return out


def run_lm(args) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, get_reduced
    from repro.data import synthetic_token_batches
    from repro.models.transformer import init_model, train_step_fn
    from repro.optim import adamw

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw(args.lr)
    opt_state = opt.init(params)
    start_step = 0
    if args.resume and args.ckpt_dir:
        from repro.checkpoint import latest_step, load_checkpoint
        s = latest_step(args.ckpt_dir)
        if s is not None:
            state = load_checkpoint(args.ckpt_dir, s,
                                    {"params": params, "opt_state": opt_state})
            params, opt_state = state["params"], state["opt_state"]
            start_step = s
    run_steps = max(0, args.steps - start_step)
    step = jax.jit(train_step_fn(cfg, opt))
    gen = synthetic_token_batches(cfg.vocab_size, args.seq_len, args.batch,
                                  seed=args.seed)
    for _ in range(start_step):   # resume the data stream where we left off
        next(gen)
    losses = []
    t0 = time.perf_counter()
    for i, host_batch in zip(range(run_steps), gen):
        batch = {"tokens": jnp.asarray(host_batch["tokens"]),
                 "labels": jnp.asarray(host_batch["labels"])}
        if cfg.vision_tokens:
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    wall = time.perf_counter() - t0
    out = {"arch": cfg.name, "steps": args.steps,
           "resumed_from": start_step,
           "loss_first": losses[0] if losses else None,
           "loss_last": losses[-1] if losses else None,
           "tokens_per_s":
           round(run_steps * args.batch * args.seq_len / max(wall, 1e-9), 1)}
    print(json.dumps(out, indent=1))
    if args.ckpt_dir:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt_dir, start_step + run_steps,
                        {"params": params, "opt_state": opt_state})
    return out


def main():
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)

    g = sub.add_parser("gnn")
    g.add_argument("--dataset", default="flickr")
    g.add_argument("--scale", type=float, default=0.02)
    g.add_argument("--feat-dim", type=int, default=64)
    g.add_argument("--model", default="gcn",
                   choices=["gcn", "sage", "gat", "gin"])
    g.add_argument("--strategy", default="halo_1d",
                   choices=["halo_1d", "spmm_15d"],
                   help="distribution model (repro.dist.strategy): "
                        "'halo_1d' is the paper's 1D vertex partition + "
                        "halo exchange (JACA/staleness/host-store "
                        "capable); 'spmm_15d' is communication-avoiding "
                        "1.5D replicated-row block SpMM over a real "
                        "device mesh — --parts is then the total device "
                        "count P, partitioned into P/c block rows")
    g.add_argument("--replication", type=int, default=1,
                   help="1.5D row-replication factor c (spmm_15d only; "
                        "needs P %% c**2 == 0). c=1 degenerates to dense "
                        "1D all-gather")
    g.add_argument("--backend", default="edges",
                   choices=["edges", "ell", "hybrid"],
                   help="local aggregation backend (ell/hybrid run the "
                        "Pallas SpMM; interpret mode on CPU)")
    g.add_argument("--halo-dtype", default="f32", choices=["f32", "bf16"],
                   help="halo payload dtype on the wire: bf16 halves every "
                        "tier's exchange bytes (dequantised on scatter)")
    g.add_argument("--features", default="device",
                   choices=["device", "host"],
                   help="'host' keeps the halo feature/embedding table in a "
                        "host-resident store (out-of-core): layer-0 rows "
                        "arrive via double-buffered h2d prefetch, global-tier "
                        "buffers live on the host between steps")
    g.add_argument("--prefetch-depth", type=int, default=2,
                   help="host-store double-buffer depth (in-flight h2d "
                        "fetches; 2 = classic double buffering)")
    g.add_argument("--hidden", type=int, default=256)
    g.add_argument("--layers", type=int, default=3)
    g.add_argument("--parts", type=int, default=4)
    g.add_argument("--partitioner", default="metis",
                   choices=["metis", "random"])
    g.add_argument("--epochs", type=int, default=200)
    g.add_argument("--lr", type=float, default=0.01)
    g.add_argument("--jaca", action="store_true", default=True)
    g.add_argument("--no-jaca", dest="jaca", action="store_false")
    g.add_argument("--rapa", action="store_true", default=True)
    g.add_argument("--no-rapa", dest="rapa", action="store_false")
    g.add_argument("--uneven", action="store_true", default=True,
                   help="profile-weighted uneven partition sizes (RAPA "
                        "resource-aware pre-partition; weakest device gets "
                        "the smallest inner set)")
    g.add_argument("--even", dest="uneven", action="store_false",
                   help="uniform partition targets regardless of profile")
    from repro.core.device_profile import PAPER_GROUPS
    g.add_argument("--group", default="auto",
                   choices=["auto", "uniform"] + sorted(PAPER_GROUPS),
                   help="device group: a paper Table 4 group (x2..x8), "
                        "'uniform' (all rtx3090), or 'auto' (x<parts> if "
                        "defined, else uniform)")
    g.add_argument("--pipeline", action="store_true", default=True)
    g.add_argument("--no-pipeline", dest="pipeline", action="store_false")
    g.add_argument("--refresh-every", type=int, default=4)
    g.add_argument("--cache-policy", default="static",
                   choices=["static", "overlap", "lru", "fifo", "drift"],
                   help="online cache adaptation: 'static' freezes the "
                        "JACA overlap plan; the others re-rank tiers at "
                        "refresh boundaries (slot-stable swap, no retrace)")
    g.add_argument("--replan-every", type=int, default=1,
                   help="re-rank every k-th refresh (adaptive policies)")
    g.add_argument("--adaptive-staleness", action="store_true")
    g.add_argument("--cpu-cache-gib", type=float, default=4.0)
    g.add_argument("--trace", action="store_true",
                   help="enable the repro.obs tracer: per-step spans + "
                        "typed counters, exported as a Perfetto-loadable "
                        "Chrome trace and a JSONL metrics stream")
    g.add_argument("--trace-dir", default="experiments",
                   help="directory for trace_train.json / "
                        "metrics_train.jsonl (with --trace)")
    g.add_argument("--device-trace-dir", default="",
                   help="opt-in jax.profiler.trace capture directory for "
                        "device-side timelines (XPlane; open in "
                        "TensorBoard/Perfetto)")
    g.add_argument("--faults", default="",
                   help="fault-injection spec, e.g. "
                        "'grad_nan@3;fetch_drop@2,5:rows=4' — clauses "
                        "kind@step,step[:key=val,...] joined by ';' "
                        "(kinds: fetch_drop fetch_delay halo_corrupt "
                        "grad_nan mem_pressure ckpt_truncate); seeded "
                        "by --seed, deterministic")
    g.add_argument("--guard-every", type=int, default=0,
                   help="divergence guard cadence: check param finiteness "
                        "and snapshot a rollback point every k steps "
                        "(0 = guard off; non-finite losses are checked "
                        "every step when on)")
    g.add_argument("--fetch-retries", type=int, default=None,
                   help="bounded retries for failed host-store fetches "
                        "before degrading to stale-tier reuse (enables "
                        "the fetch guard; default 2 when any fault/guard "
                        "flag is set)")
    g.add_argument("--checksums", action="store_true",
                   help="per-tier payload checksums on exchange/cache "
                        "buffers: verify before each step, force a plain "
                        "refresh of corrupted tiers (opt-in: adds a fenced "
                        "d2h digest per step)")
    g.add_argument("--seed", type=int, default=0)
    g.add_argument("--ckpt-dir", default="")
    g.add_argument("--resume", action="store_true",
                   help="restore (params, opt_state, epoch) from the latest "
                        "checkpoint in --ckpt-dir and train the remaining "
                        "epochs up to --epochs")
    g.set_defaults(fn=run_gnn)

    l = sub.add_parser("lm")
    l.add_argument("--arch", required=True)
    l.add_argument("--reduced", action="store_true", default=True)
    l.add_argument("--full", dest="reduced", action="store_false")
    l.add_argument("--steps", type=int, default=20)
    l.add_argument("--batch", type=int, default=4)
    l.add_argument("--seq-len", type=int, default=128)
    l.add_argument("--lr", type=float, default=3e-4)
    l.add_argument("--seed", type=int, default=0)
    l.add_argument("--ckpt-dir", default="")
    l.add_argument("--resume", action="store_true",
                   help="restore (params, opt_state, step) from the latest "
                        "checkpoint in --ckpt-dir and run the remaining "
                        "steps up to --steps")
    l.set_defaults(fn=run_lm)

    args = ap.parse_args()
    args.fn(args)


if __name__ == "__main__":
    main()
