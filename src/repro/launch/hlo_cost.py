"""Call-graph-aware cost roll-up over compiled HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a ``while``
loop body (every ``lax.scan``: the layer stack, flash-attention KV chunks)
is charged a single iteration, so FLOPs / bytes / collective bytes of
scanned models are undercounted by roughly the trip count.  This module
re-derives the three roofline inputs from ``compiled.as_text()`` with the
call graph walked explicitly:

- ``while``    -> body and condition costs x trip count (trip count
                  recovered from the loop-bound constant in the condition
                  computation — lax.scan always lowers to a counted loop);
- ``fusion``   -> FLOPs of the fused computation count, but only the
                  fusion's *surface* operands/results count as bytes
                  (fused intermediates never touch HBM);
- ``call``     -> costs x 1.

FLOPs: ``dot`` = 2 * prod(result_dims) * prod(lhs contracting dims)
(batch dims included in the result product).  Elementwise FLOPs are
ignored — they ride on the byte traffic in the memory term.

Bytes: sum of (result + operand) sizes of every materialising top-level
instruction (parameters, constants, tuples, GTEs, bitcasts are free).
This approximates post-fusion HBM traffic.

Collectives: result bytes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (and their ``-start`` forms), times the
path multiplier.
"""
from __future__ import annotations

import dataclasses
import math
import re

__all__ = ["analyse_hlo", "HloCost", "xla_cost_analysis"]

# --- version-compat shims -------------------------------------------------
# `jax.shard_map` graduated from `jax.experimental.shard_map` in newer
# releases; callers (tests, benchmarks) use the top-level name, so backfill
# it on older installs.
try:
    import functools as _functools

    import jax as _jax
    if not hasattr(_jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        @_functools.wraps(_shard_map)
        def _shard_map_compat(*args, **kwargs):
            # The experimental version's replication checker predates the
            # scan-carry fix (it rejects psum-in-scan bodies); the graduated
            # API does not have that failure mode, so default the check off.
            kwargs.setdefault("check_rep", False)
            return _shard_map(*args, **kwargs)

        _jax.shard_map = _shard_map_compat
except ImportError:          # HLO text analysis itself needs no jax
    pass


def xla_cost_analysis(compiled) -> dict:
    """XLA's own per-module cost analysis as a plain dict.

    ``Compiled.cost_analysis()`` returned a one-element list of dicts before
    jax 0.5 and a bare dict after; normalise so callers can index by key.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "iota", "partition-id", "replica-id", "reshape",
             # control surfaces account their bodies via call edges; the
             # loop carry stays resident, it is not re-streamed per step
             "while", "conditional", "call"}

# Ops that touch only a window of their big operand: charged by the window,
# not by the operand's full size.
_SLICING_OPS = {"dynamic-slice", "slice", "gather"}
_PASSTHRU_OPS = {"bitcast", "reshape"}


def _instr_bytes(ins: "_Instr", ttable: dict[str, str],
                 comps: dict[str, list["_Instr"]]) -> float:
    """HBM traffic estimate for one top-level instruction.

    Windowed ops (dynamic-slice / gather / dynamic-update-slice / scatter)
    are charged by the touched window, not the resident operand — a scan
    that dynamic-slices its stacked parameters per iteration reads one
    layer, not the whole stack.  Fusions charge their surface operands,
    except parameters that the fused computation only ever slices/gathers,
    which are charged by the slice results (and a root dynamic-update-slice
    writes only its update window).
    """
    res_b = _type_bytes(ins.type_str)
    ops = _operands(ins.rest)

    def opnd_b(i: int) -> float:
        return _type_bytes(ttable.get(ops[i], "")) if i < len(ops) else 0.0

    if ins.op in _SLICING_OPS:
        extra = sum(opnd_b(i) for i in range(1, len(ops)))  # indices
        return 2.0 * res_b + extra                          # read win + write
    if ins.op == "dynamic-update-slice":
        upd = opnd_b(1)
        return 2.0 * upd + sum(opnd_b(i) for i in range(2, len(ops)))
    if ins.op == "scatter":
        upd = opnd_b(2) if len(ops) > 2 else res_b
        idx = opnd_b(1)
        return 3.0 * upd + idx                              # rmw + indices
    if ins.op == "fusion":
        callee = _attr(ins.rest, "calls")
        instrs = comps.get(callee or "", [])
        ftable = ttable_of(instrs)
        by_name = {fi.name: fi for fi in instrs}
        params: dict[int, str] = {}
        users: dict[str, list["_Instr"]] = {}
        for fi in instrs:
            if fi.op == "parameter":
                m = re.match(r"(\d+)\)", fi.rest)
                if m:
                    params[int(m.group(1))] = fi.name
            for o in _operands(fi.rest):
                users.setdefault(o, []).append(fi)

        def touched(name: str, depth: int = 0) -> float | None:
            """Bytes read from a param if only sliced / in-place updated."""
            if depth > 8:
                return None
            total = 0.0
            for u in users.get(name, []):
                u_ops = _operands(u.rest)
                if u.op in _SLICING_OPS:
                    total += _type_bytes(u.type_str)
                elif (u.op == "dynamic-update-slice" and u_ops
                      and u_ops[0] == name):
                    # in-place window write: read nothing but the window
                    total += _type_bytes(ftable.get(u_ops[1], "")) \
                        if len(u_ops) > 1 else 0.0
                elif u.op in _PASSTHRU_OPS or u.op == "get-tuple-element":
                    sub = touched(u.name, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                else:
                    return None
            return total

        total = 0.0
        for i in range(len(ops)):
            full = opnd_b(i)
            pname = params.get(i)
            win = touched(pname) if pname else None
            total += min(win, full) if win is not None else full

        def write_bytes(name: str, full: float, depth: int = 0) -> float:
            """Written bytes for one root value: a dynamic-update-slice
            (possibly behind bitcast/reshape) writes only its window."""
            fi = by_name.get(name)
            if fi is None or depth > 8:
                return full
            if fi.op == "dynamic-update-slice":
                f_ops = _operands(fi.rest)
                upd = _type_bytes(ftable.get(f_ops[1], "")) \
                    if len(f_ops) > 1 else 0.0
                return upd or full
            if fi.op in _PASSTHRU_OPS:
                f_ops = _operands(fi.rest)
                if f_ops:
                    return write_bytes(f_ops[0], full, depth + 1)
            return full

        root = next((fi for fi in instrs if fi.is_root),
                    instrs[-1] if instrs else None)
        if root is None:
            total += res_b
        elif root.op == "tuple":
            for o in _operands(root.rest):
                total += write_bytes(o, _type_bytes(ftable.get(o, "")))
        else:
            total += write_bytes(root.name, res_b)
        return total
    return res_b + sum(opnd_b(i) for i in range(len(ops)))


def ttable_of(instrs: list["_Instr"]) -> dict[str, str]:
    return {i.name: i.type_str for i in instrs}

# Result type may be a tuple containing `/*index=N*/` comments; match it
# non-greedily up to the ` opcode(` that follows.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*((?:\(.*?\))|(?:\S+))\s+"
    r"([\w\-]+)\((.*)$")
# Header like `%name (args...) -> type {` — args may contain nested parens
# (tuple-typed params), so just grab the leading %name and require `->`/`{`.
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    rest: str           # raw text after the opening '('
    is_root: bool = False


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_accessed: float
    collective_bytes: dict[str, float]
    collective_counts: dict[str, float]
    unresolved_loops: int = 0

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


def _parse_computations(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    for line in text.splitlines():
        if cur is None:
            stripped0 = line.strip()
            m = _COMP_RE.match(stripped0)
            if (m and line.rstrip().endswith("{") and "->" in stripped0):
                comps[m.group(1)] = cur = []
            continue
        stripped = line.strip()
        if stripped == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.append(_Instr(m.group(1), m.group(2), m.group(3), m.group(4),
                              is_root=stripped.startswith("ROOT ")))
    return comps


def _operands(rest: str) -> list[str]:
    """Operand %names from the call-paren contents (first paren group)."""
    depth = 1
    out, cur = [], []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            cur.append(ch)
    arglist = "".join(cur)
    return re.findall(r"%[\w.\-]+", arglist)


def _attr(rest: str, key: str) -> str | None:
    m = re.search(key + r"=(%[\w.\-]+)", rest)
    return m.group(1) if m else None


def _dims_attr(rest: str, key: str) -> list[int]:
    m = re.search(key + r"=\{([\d,]*)\}", rest)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


def _trip_count(cond_instrs: list[_Instr]) -> int | None:
    """Loop bound = the largest integer constant in the condition (lax.scan
    lowers to `i < C`; any auxiliary constants are smaller indices)."""
    best = None
    for ins in cond_instrs:
        if ins.op == "constant" and ins.type_str.startswith(("s32", "s64",
                                                             "u32", "u64")):
            m = re.match(r"([\-\d]+)\)", ins.rest)
            if m:
                v = int(m.group(1))
                if best is None or v > best:
                    best = v
    return best


def analyse_hlo(text: str) -> HloCost:
    comps = _parse_computations(text)
    types: dict[str, dict[str, str]] = {
        cname: {i.name: i.type_str for i in instrs}
        for cname, instrs in comps.items()}

    # entry = computation never referenced as callee; fall back to the one
    # whose name starts with %main.
    callees: set[str] = set()
    for instrs in comps.values():
        for ins in instrs:
            for key in ("condition", "body", "calls", "to_apply",
                        "branch_computations"):
                for ref in re.findall(key + r"=\{?([%\w.\-, ]+)\}?",
                                      ins.rest):
                    callees.update(re.findall(r"%[\w.\-]+", ref))
    entry = None
    for name in comps:
        if name not in callees and name.startswith("%main"):
            entry = name
            break
    if entry is None:
        cands = [n for n in comps if n not in callees]
        entry = cands[0] if cands else next(iter(comps))

    memo: dict[tuple[str, bool], HloCost] = {}
    unresolved = [0]

    def visit(cname: str, in_fusion: bool) -> HloCost:
        key = (cname, in_fusion)
        if key in memo:
            return memo[key]
        flops = 0.0
        byts = 0.0
        coll = {k: 0.0 for k in _COLLECTIVES}
        counts = {k: 0.0 for k in _COLLECTIVES}
        ttable = types.get(cname, {})
        for ins in comps.get(cname, []):
            ops = None
            # --- flops
            if ins.op == "dot":
                k = 1
                lhs_dims = _dims_attr(ins.rest, "lhs_contracting_dims")
                ops = _operands(ins.rest)
                if ops:
                    lhs_shape = _shape_dims(ttable.get(ops[0], ""))
                    for d in lhs_dims:
                        if d < len(lhs_shape):
                            k *= lhs_shape[d]
                flops += 2.0 * k * math.prod(_shape_dims(ins.type_str))
            # --- collectives
            base = ins.op
            for kind in _COLLECTIVES:
                if base == kind or base.startswith(kind + "-"):
                    if not base.endswith("-done"):
                        coll[kind] += _type_bytes(ins.type_str)
                        counts[kind] += 1
                    break
            # --- bytes (only outside fusions; collective payloads belong to
            # the collective term, not the HBM term)
            if (not in_fusion and ins.op not in _FREE_OPS
                    and not any(ins.op == k or ins.op.startswith(k + "-")
                                for k in _COLLECTIVES)):
                byts += _instr_bytes(ins, ttable, comps)
            # --- call edges
            if ins.op == "while":
                body = _attr(ins.rest, "body")
                cond = _attr(ins.rest, "condition")
                trip = _trip_count(comps.get(cond, [])) if cond else None
                if trip is None:
                    trip = 1
                    unresolved[0] += 1
                for callee in (body, cond):
                    if callee and callee in comps:
                        sub = visit(callee, in_fusion)
                        flops += trip * sub.flops
                        byts += trip * sub.bytes_accessed
                        for k2 in _COLLECTIVES:
                            coll[k2] += trip * sub.collective_bytes[k2]
                            counts[k2] += trip * sub.collective_counts[k2]
            elif ins.op == "fusion":
                callee = _attr(ins.rest, "calls")
                if callee and callee in comps:
                    sub = visit(callee, True)
                    flops += sub.flops
                    for k2 in _COLLECTIVES:
                        coll[k2] += sub.collective_bytes[k2]
                        counts[k2] += sub.collective_counts[k2]
            elif ins.op in ("call", "async-start", "custom-call"):
                callee = (_attr(ins.rest, "to_apply")
                          or _attr(ins.rest, "calls"))
                if callee and callee in comps:
                    sub = visit(callee, in_fusion)
                    flops += sub.flops
                    byts += sub.bytes_accessed
                    for k2 in _COLLECTIVES:
                        coll[k2] += sub.collective_bytes[k2]
                        counts[k2] += sub.collective_counts[k2]
            elif ins.op == "conditional":
                branches = re.search(r"branch_computations=\{([^}]*)\}",
                                     ins.rest)
                names = (re.findall(r"%[\w.\-]+", branches.group(1))
                         if branches else
                         [x for x in (_attr(ins.rest, "true_computation"),
                                      _attr(ins.rest, "false_computation"))
                          if x])
                subs = [visit(n, in_fusion) for n in names if n in comps]
                if subs:  # charge the most expensive branch
                    big = max(subs, key=lambda s: s.flops + s.bytes_accessed)
                    flops += big.flops
                    byts += big.bytes_accessed
                    for k2 in _COLLECTIVES:
                        coll[k2] += big.collective_bytes[k2]
                        counts[k2] += big.collective_counts[k2]
        res = HloCost(flops=flops, bytes_accessed=byts, collective_bytes=coll,
                      collective_counts=counts)
        memo[key] = res
        return res

    out = visit(entry, False)
    out.unresolved_loops = unresolved[0]
    return out
