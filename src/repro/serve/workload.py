"""Synthetic query-stream generators for the serving benchmarks.

A :class:`QueryStream` is a time-stamped sequence of node-id queries.  Three
arrival/popularity shapes cover the workloads the serving literature
benchmarks against (BGL, arXiv 2112.08541: cache hit rate under power-law
popularity dominates GNN serving throughput):

- ``uniform`` — Poisson arrivals, uniformly popular nodes;
- ``zipf``    — Poisson arrivals, Zipf(``alpha``) node popularity (the
  skew knob; sampled by inverse CDF so the skew is *pointwise* monotone in
  ``alpha`` under a fixed seed — the property tests rely on this);
- ``bursty``  — Zipf popularity with arrivals alternating between short
  high-rate bursts and low-rate idle stretches (tail-latency stressor).

All generators are deterministic functions of their seed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["QueryStream", "uniform_stream", "zipf_stream", "bursty_stream",
           "make_stream", "WORKLOAD_KINDS"]

WORKLOAD_KINDS = ("uniform", "zipf", "bursty")


@dataclasses.dataclass(frozen=True)
class QueryStream:
    kind: str
    t: np.ndarray      # [Q] float64 arrival seconds, nondecreasing, t[0]>=0
    node: np.ndarray   # [Q] int64 queried node ids

    @property
    def num_queries(self) -> int:
        return int(self.node.shape[0])

    @property
    def duration_s(self) -> float:
        return float(self.t[-1]) if self.t.size else 0.0


def _poisson_times(n_queries: int, qps: float,
                   rng: np.random.Generator) -> np.ndarray:
    gaps = rng.exponential(1.0 / max(qps, 1e-9), n_queries)
    return np.cumsum(gaps)


def _zipf_ranks(n_nodes: int, n_queries: int, alpha: float,
                rng: np.random.Generator) -> np.ndarray:
    """Inverse-CDF Zipf sampling.

    For a fixed uniform draw ``u``, the sampled rank is nonincreasing in
    ``alpha`` (higher exponent → CDF mass shifts to low ranks), so any
    top-m query share is monotone nondecreasing in ``alpha``.
    """
    w = np.arange(1, n_nodes + 1, dtype=np.float64) ** (-float(alpha))
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    u = rng.random(n_queries)
    return np.searchsorted(cdf, u, side="left").clip(0, n_nodes - 1)


def uniform_stream(n_nodes: int, n_queries: int, qps: float = 1000.0,
                   seed: int = 0) -> QueryStream:
    rng = np.random.default_rng(seed)
    t = _poisson_times(n_queries, qps, rng)
    node = rng.integers(0, n_nodes, n_queries).astype(np.int64)
    return QueryStream(kind="uniform", t=t, node=node)


def zipf_stream(n_nodes: int, n_queries: int, qps: float = 1000.0,
                alpha: float = 1.1, seed: int = 0,
                rank_to_node: np.ndarray | None = None) -> QueryStream:
    """Zipf(``alpha``) popularity.  ``rank_to_node`` maps popularity rank →
    node id (e.g. a degree ordering, so the head of the distribution lands
    on the engine's degree-ranked hot tier); default is a seeded
    permutation, decorrelating popularity from node id."""
    rng = np.random.default_rng(seed)
    t = _poisson_times(n_queries, qps, rng)
    ranks = _zipf_ranks(n_nodes, n_queries, alpha, rng)
    if rank_to_node is None:
        rank_to_node = np.random.default_rng(seed + 1).permutation(n_nodes)
    node = np.asarray(rank_to_node, np.int64)[ranks]
    return QueryStream(kind="zipf", t=t, node=node)


def bursty_stream(n_nodes: int, n_queries: int, qps: float = 1000.0,
                  alpha: float = 1.1, burst_len: int = 32,
                  burst_factor: float = 16.0, seed: int = 0,
                  rank_to_node: np.ndarray | None = None) -> QueryStream:
    """Bursts of ``burst_len`` queries at ``qps * burst_factor`` separated
    by idle stretches at ``qps / burst_factor`` (mean rate stays ~``qps``
    for burst_factor >> 1 with equal on/off query counts)."""
    rng = np.random.default_rng(seed)
    in_burst = (np.arange(n_queries) // max(1, burst_len)) % 2 == 0
    rate = np.where(in_burst, qps * burst_factor, qps / burst_factor)
    gaps = rng.exponential(1.0, n_queries) / np.maximum(rate, 1e-9)
    t = np.cumsum(gaps)
    ranks = _zipf_ranks(n_nodes, n_queries, alpha, rng)
    if rank_to_node is None:
        rank_to_node = np.random.default_rng(seed + 1).permutation(n_nodes)
    node = np.asarray(rank_to_node, np.int64)[ranks]
    return QueryStream(kind="bursty", t=t, node=node)


def make_stream(kind: str, n_nodes: int, n_queries: int, qps: float = 1000.0,
                alpha: float = 1.1, seed: int = 0,
                rank_to_node: np.ndarray | None = None) -> QueryStream:
    """Dispatcher used by the CLI / benchmarks."""
    if kind == "uniform":
        return uniform_stream(n_nodes, n_queries, qps, seed)
    if kind == "zipf":
        return zipf_stream(n_nodes, n_queries, qps, alpha, seed, rank_to_node)
    if kind == "bursty":
        return bursty_stream(n_nodes, n_queries, qps, alpha, seed=seed,
                             rank_to_node=rank_to_node)
    raise ValueError(f"unknown workload kind {kind!r}; "
                     f"expected one of {WORKLOAD_KINDS}")
