"""Online node-query engine over precomputed embeddings.

Serving counterpart of the JACA training cache (paper §4.2): the halo
insight — a small overlap-ranked subset of vertices absorbs most reads —
applies directly to inference, where queries follow skewed popularity.  The
engine answers ``logits(v)`` queries from a two-tier embedding cache:

- **hot tier** — device-resident rows of the top-``capacity`` nodes under a
  ``build_cache_plan``-compatible static ranking (overlap ratio or degree,
  stable-argsort priority).  Row fetch goes through the Pallas
  :func:`~repro.kernels.ops.gather_rows` kernel — the JACA ``pick_cache``
  hot path, load-bearing at last.
- **host tier** — the full precomputed table behind it, held in a
  :class:`~repro.dist.host_store.HostFeatureStore` (the same host-resident
  store the out-of-core training runtimes use); every query the hot tier
  misses is served through the store's staged fetch, and its latency is
  accounted separately (``host_fetch_s``) from hot-tier service.

Queries arrive through a deadline/size **micro-batcher**: a batch closes
when it reaches ``max_batch`` or when its oldest query has waited
``deadline_ms``, whichever comes first — the standard throughput/latency
knob for online inference.

**Freshness** (``fresh_hops=k``): features may change after precompute.
``update_features`` marks every node within ``num_layers`` forward hops of
an update as stale; a stale query is answered by recomputing its k-hop
in-neighbourhood subgraph with current features, substituting precomputed
layer tables at the subgraph frontier.  With ``k >= num_layers`` this is
*exact* (the influence radius of L layers is L hops — parity-tested);
smaller ``k`` trades accuracy for a smaller recompute.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.dist.host_store import HostFeatureStore
from repro.graph.graph import Graph
from repro.graph.partition import PartitionSet
from repro.kernels.ops import gather_rows
from repro.models.gnn import EdgeListAdj, gnn_forward
from repro.obs.tracer import NULL_TRACER, StepCounters, device_peak_bytes

from .precompute import EmbeddingStore
from .workload import QueryStream

__all__ = ["rank_hot_nodes", "BatchConfig", "Batch", "MicroBatcher",
           "plan_batches", "GNNServeEngine", "serve_stream",
           "HOT_RANK_POLICIES"]

HOT_RANK_POLICIES = ("degree", "overlap")


# ---------------------------------------------------------------------------
# Hot-tier planning (JACA-style static ranking)
# ---------------------------------------------------------------------------

def rank_hot_nodes(graph: Graph, capacity: int,
                   ps: PartitionSet | None = None,
                   policy: str = "degree") -> np.ndarray:
    """Top-``capacity`` node ids under a static priority ranking.

    Same idiom as :func:`repro.core.jaca.build_cache_plan`: a per-node
    priority, stable descending argsort, truncate to capacity.  ``degree``
    ranks by in-degree (popular aggregation sources; needs only the graph),
    ``overlap`` by the paper's Eq. 2 overlap ratio (needs the partition
    set; vertices read by many partitions are also the ones many queries'
    neighbourhoods share).
    """
    if policy == "degree":
        _, dst = graph.edges()
        pri = np.bincount(dst, minlength=graph.num_nodes)
    elif policy == "overlap":
        if ps is None:
            raise ValueError("policy='overlap' needs the PartitionSet")
        pri = ps.overlap_ratio()
    else:
        raise ValueError(f"unknown hot-rank policy {policy!r}; "
                         f"expected one of {HOT_RANK_POLICIES}")
    order = np.argsort(-pri.astype(np.float64), kind="stable")
    return order[: max(0, int(capacity))].astype(np.int64)


# ---------------------------------------------------------------------------
# Deadline/size micro-batcher
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchConfig:
    max_batch: int = 64
    deadline_ms: float = 2.0

    @property
    def deadline_s(self) -> float:
        return self.deadline_ms * 1e-3


@dataclasses.dataclass(frozen=True)
class Batch:
    idx: np.ndarray      # positions into the source stream, arrival order
    close_time: float    # when the batch was sealed (same clock as offers)


class MicroBatcher:
    """Accumulate queries; seal on size or deadline.

    Invariants (property-tested): every offered query lands in exactly one
    batch, batches preserve arrival order, ``len(batch) <= max_batch``, and
    ``close_time - first_arrival <= deadline`` for every batch.
    """

    def __init__(self, cfg: BatchConfig):
        self.cfg = cfg
        self._idx: list[int] = []
        self._t0 = 0.0

    def _seal(self, close_time: float) -> Batch:
        b = Batch(idx=np.asarray(self._idx, np.int64), close_time=close_time)
        self._idx = []
        return b

    def offer(self, i: int, t: float) -> list[Batch]:
        """Register query ``i`` arriving at time ``t`` (nondecreasing).
        Returns the batches sealed by this arrival (0, 1, or — when
        ``max_batch == 1`` forces an immediate seal after a deadline seal —
        2)."""
        out: list[Batch] = []
        if self._idx and t - self._t0 >= self.cfg.deadline_s:
            # the deadline timer fired before this arrival
            out.append(self._seal(self._t0 + self.cfg.deadline_s))
        if not self._idx:
            self._t0 = t
        self._idx.append(i)
        if len(self._idx) >= self.cfg.max_batch:
            out.append(self._seal(t))
        return out

    def flush(self) -> Batch | None:
        """Seal whatever is pending (end of stream) at its deadline."""
        if not self._idx:
            return None
        return self._seal(self._t0 + self.cfg.deadline_s)


def plan_batches(times: np.ndarray, cfg: BatchConfig) -> list[Batch]:
    """Run the whole (time-sorted) arrival sequence through a batcher."""
    mb = MicroBatcher(cfg)
    batches: list[Batch] = []
    for i, t in enumerate(np.asarray(times, np.float64)):
        batches.extend(mb.offer(i, float(t)))
    tail = mb.flush()
    if tail is not None:
        batches.append(tail)
    return batches


# ---------------------------------------------------------------------------
# BFS helpers (vectorised over the edge list)
# ---------------------------------------------------------------------------

def _bfs_mask(src: np.ndarray, dst: np.ndarray, seeds: np.ndarray,
              hops: int, n: int) -> np.ndarray:
    """Nodes within ``hops`` steps of ``seeds`` along src→dst edges
    (seeds included)."""
    seen = np.zeros(n, dtype=bool)
    seen[seeds] = True
    frontier = seen.copy()
    for _ in range(hops):
        nxt = dst[frontier[src]]
        frontier = np.zeros(n, dtype=bool)
        frontier[nxt[~seen[nxt]]] = True
        if not frontier.any():
            break
        seen |= frontier
    return seen


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class GNNServeEngine:
    """Two-tier embedding cache + k-hop fresh recompute over one store."""

    def __init__(self, store: EmbeddingStore, params, graph: Graph,
                 hot_ids: np.ndarray, features: np.ndarray | None = None,
                 fresh_hops: int | None = None, interpret: bool = True,
                 host_store: HostFeatureStore | None = None):
        self.store = store
        self.cfg = store.cfg
        self.params = params
        self.graph = graph
        self.interpret = interpret
        self.fresh_hops = (self.cfg.num_layers if fresh_hops is None
                           else int(fresh_hops))
        n = store.num_nodes
        if graph.num_nodes != n:
            raise ValueError(f"graph has {graph.num_nodes} nodes but the "
                             f"store was precomputed over {n}")
        # current input features (fresh-path layer 0); default = the
        # features the store was precomputed from
        self.features = np.array(features if features is not None
                                 else store.tables[0], np.float32)
        self._src, self._dst = graph.edges()
        self._w = (graph.edge_weight if graph.edge_weight is not None
                   else np.ones(self._src.shape[0], np.float32))
        # tiers
        self.hot_ids = np.asarray(hot_ids, np.int64)
        self.hot_slot = np.full(n, -1, np.int32)
        self.hot_slot[self.hot_ids] = np.arange(self.hot_ids.size,
                                                dtype=np.int32)
        self.hot_buf = jnp.asarray(store.logits[self.hot_ids])  # device tier
        # host tier: the full table lives in a HostFeatureStore (misses
        # go through its staged fetch, not a raw fancy-index); a shared
        # store may be injected (e.g. one built over training features)
        self.host_store = (host_store if host_store is not None
                           else HostFeatureStore(store.logits))
        # staleness
        self.stale = np.zeros(n, dtype=bool)
        self.stats = {"queries": 0, "hot_hits": 0, "host_hits": 0,
                      "fresh_recomputes": 0, "batches": 0,
                      "rejected_queries": 0, "host_fetch_s": 0.0}
        self.tracer = NULL_TRACER

    def set_tracer(self, tracer) -> None:
        """Attach a :class:`repro.obs.Tracer`: the query paths record
        ``hot_gather`` / ``host_fetch`` / ``fresh_recompute`` sub-spans
        (and the host store its ``h2d_put`` dispatches) nested inside the
        caller's per-batch span."""
        self.tracer = tracer
        self.host_store.set_tracer(tracer)

    # -- input validation ----------------------------------------------------

    def _validate_ids(self, nodes) -> np.ndarray:
        """Reject malformed query batches before they reach the tiers: a
        negative or out-of-range id would fancy-index garbage (or wrap
        around) instead of failing.  Rejected ids are counted in
        ``stats["rejected_queries"]`` and surfaced as a clean
        ``ValueError`` naming the offenders."""
        nodes = np.asarray(nodes)
        if nodes.ndim != 1:
            raise ValueError(f"query batch must be 1-D node ids, "
                             f"got shape {nodes.shape}")
        if not np.issubdtype(nodes.dtype, np.integer):
            raise ValueError(f"query batch must be integer node ids, "
                             f"got dtype {nodes.dtype}")
        nodes = nodes.astype(np.int64, copy=False)
        n = self.graph.num_nodes
        bad = (nodes < 0) | (nodes >= n)
        if bad.any():
            k = int(bad.sum())
            self.stats["rejected_queries"] += k
            sample = nodes[bad][:5].tolist()
            raise ValueError(
                f"query contains {k} out-of-range node id(s) "
                f"(valid range [0, {n})): {sample}")
        return nodes

    # -- freshness ---------------------------------------------------------

    def update_features(self, nodes: np.ndarray, new_feats: np.ndarray):
        """Overwrite input features; mark the forward influence cone stale.

        An L-layer GNN propagates a feature change at most L hops along
        src→dst edges, so exactly the nodes within ``num_layers`` forward
        hops of an update can have stale precomputed logits.  Stale nodes
        bypass both cache tiers until recomputed (the hot tier keeps its
        rows — they are simply never served while stale).
        """
        nodes = np.asarray(nodes, np.int64)
        self.features[nodes] = np.asarray(new_feats, np.float32)
        affected = _bfs_mask(self._src, self._dst, nodes,
                             self.cfg.num_layers, self.graph.num_nodes)
        self.stale |= affected

    def _recompute(self, nodes: np.ndarray) -> np.ndarray:
        """Exact-on-the-inside k-hop recompute for ``nodes``.

        Builds the ``fresh_hops``-hop *in*-neighbourhood subgraph of the
        batch, runs all layers over it with current features, and feeds
        frontier neighbours from the precomputed layer tables (layer 0:
        current features).  The subgraph aggregation runs the edge-list
        backend regardless of the precompute backend — logits are
        backend-invariant, and a ragged one-off subgraph is exactly the
        shape Pallas packs are worst at.
        """
        n = self.graph.num_nodes
        src, dst, w = self._src, self._dst, self._w
        seen = _bfs_mask(dst, src, nodes, self.fresh_hops, n)  # reverse BFS
        inner = np.where(seen)[0]
        keep = seen[dst]                      # every edge into the subgraph
        hsrc = src[keep]
        halo = np.unique(hsrc[~seen[hsrc]])
        loc = np.full(n, -1, np.int64)
        loc[inner] = np.arange(inner.size)
        loc[halo] = inner.size + np.arange(halo.size)
        adj = EdgeListAdj(jnp.asarray(loc[src[keep]], jnp.int32),
                          jnp.asarray(loc[dst[keep]], jnp.int32),
                          jnp.asarray(w[keep], jnp.float32),
                          inner.size, inner.size + halo.size)
        halo_embeds = [jnp.asarray(self.features[halo])]
        for l in range(1, self.cfg.num_layers):
            halo_embeds.append(jnp.asarray(self.store.tables[l][halo]))
        logits = gnn_forward(self.cfg, self.params, adj,
                             jnp.asarray(self.features[inner]), halo_embeds)
        return np.asarray(logits)[np.searchsorted(inner, nodes)]

    # -- query paths -------------------------------------------------------

    def lookup(self, nodes: np.ndarray) -> np.ndarray:
        """Pure tiered fetch (no staleness check): hot tier via the Pallas
        gather kernel, host-store staged fetch for the rest (timed
        separately into ``host_fetch_s``)."""
        nodes = self._validate_ids(nodes)
        out = np.empty((nodes.size, self.cfg.out_dim), np.float32)
        slots = self.hot_slot[nodes]
        hit = slots >= 0
        if hit.any():
            with self.tracer.span("hot_gather", rows=int(hit.sum())):
                rows = gather_rows(self.hot_buf, jnp.asarray(slots[hit]),
                                   interpret=self.interpret)
                out[hit] = np.asarray(rows)
        if (~hit).any():
            t0 = time.perf_counter()
            with self.tracer.span("host_fetch", rows=int((~hit).sum())):
                out[~hit] = self.host_store.fetch_rows(nodes[~hit])
            self.stats["host_fetch_s"] += time.perf_counter() - t0
        self.stats["queries"] += int(nodes.size)
        self.stats["hot_hits"] += int(hit.sum())
        self.stats["host_hits"] += int((~hit).sum())
        self.stats["batches"] += 1
        return out

    def query(self, nodes: np.ndarray) -> np.ndarray:
        """Serve one micro-batch: cached tiers for clean nodes, k-hop
        fresh recompute for stale ones."""
        nodes = self._validate_ids(nodes)
        st = self.stale[nodes]
        if not st.any():
            return self.lookup(nodes)
        out = np.empty((nodes.size, self.cfg.out_dim), np.float32)
        if (~st).any():
            out[~st] = self.lookup(nodes[~st])
            self.stats["batches"] -= 1   # one logical batch, not two
        with self.tracer.span("fresh_recompute", rows=int(st.sum())):
            out[st] = self._recompute(nodes[st])
        self.stats["queries"] += int(st.sum())
        self.stats["fresh_recomputes"] += int(st.sum())
        self.stats["batches"] += 1
        return out

    def warmup(self, batch_size: int):
        """Compile the gather kernel at the serving batch shape before any
        timed work (same sync discipline as the benchmark drivers)."""
        nodes = self.hot_ids[:batch_size] if self.hot_ids.size else \
            np.arange(min(batch_size, self.graph.num_nodes))
        saved = dict(self.stats)
        self.lookup(np.resize(nodes, batch_size))
        self.stats = saved


# ---------------------------------------------------------------------------
# Stream serving (simulated arrival clock, measured service times)
# ---------------------------------------------------------------------------

def serve_stream(engine: GNNServeEngine, stream: QueryStream,
                 bcfg: BatchConfig, fresh: bool = True,
                 warmup: bool = True, tracer=None) -> dict:
    """Micro-batch ``stream`` through the engine and report throughput,
    latency and per-tier hit rates.

    Arrivals follow the stream's (simulated) clock; service times are
    measured wall clock on this host.  Per-query latency = queueing in the
    batcher (bounded by the deadline) + queueing behind earlier batches +
    measured service time.  QPS is service throughput
    (``queries / busy_seconds``).

    ``tracer`` records one ``serve_batch`` span per micro-batch (with the
    engine's ``hot_gather``/``host_fetch``/``fresh_recompute`` sub-spans
    nested inside) and one ``kind="serve"`` counter record per batch from
    the engine's stat deltas.
    """
    tr = tracer if tracer is not None else NULL_TRACER
    if tr.enabled:
        engine.set_tracer(tr)
    batches = plan_batches(stream.t, bcfg)
    if warmup:
        # own depth-0 span: warmup gathers (compile) are not batch work
        with tr.span("warmup", n=int(bcfg.max_batch)):
            engine.warmup(bcfg.max_batch)
    before = dict(engine.stats)
    latency = np.zeros(stream.num_queries)
    free = 0.0
    busy = 0.0
    for bi, b in enumerate(batches):
        nodes = stream.node[b.idx]
        snap = dict(engine.stats) if tr.enabled else None
        t0 = time.perf_counter()
        with tr.span("serve_batch", step=bi, n=int(nodes.size)):
            out = engine.query(nodes) if fresh else engine.lookup(nodes)
        service = time.perf_counter() - t0
        assert out.shape == (nodes.size, engine.cfg.out_dim)
        begin = max(b.close_time, free)
        free = begin + service
        busy += service
        latency[b.idx] = free - stream.t[b.idx]
        if tr.enabled:
            db = {k: engine.stats[k] - snap[k] for k in engine.stats}
            tr.count(StepCounters(
                step=bi, kind="serve",
                queries=int(db["queries"]),
                hot_hits=int(db["hot_hits"]),
                host_hits=int(db["host_hits"]),
                fresh_recomputes=int(db["fresh_recomputes"]),
                device_peak_bytes=device_peak_bytes()))
    q = stream.num_queries
    d = {k: engine.stats[k] - before[k] for k in engine.stats}
    served = max(1, d["queries"])
    return {
        "workload": stream.kind,
        "queries": q,
        "batches": len(batches),
        "mean_batch": q / max(1, len(batches)),
        "qps": q / max(busy, 1e-9),
        "p50_ms": float(np.percentile(latency, 50) * 1e3) if q else 0.0,
        "p99_ms": float(np.percentile(latency, 99) * 1e3) if q else 0.0,
        "hot_hit_rate": d["hot_hits"] / served,
        "host_hit_rate": d["host_hits"] / served,
        "fresh_rate": d["fresh_recomputes"] / served,
        # host-tier staged-fetch latency, separated from hot-tier service
        "host_fetch_ms": d["host_fetch_s"] * 1e3,
        "host_fetch_per_row_ms": (d["host_fetch_s"] / d["host_hits"] * 1e3
                                  if d["host_hits"] else 0.0),
        "busy_s": busy,
    }
