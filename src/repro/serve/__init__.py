"""GNN inference serving (the ROADMAP's query-traffic axis).

- :mod:`repro.serve.precompute` — partitioned layer-wise full-graph
  inference through the training runtime's exchange machinery; per-layer
  global embedding tables, persisted via :mod:`repro.checkpoint`.
- :mod:`repro.serve.engine` — two-tier (device hot / host) embedding cache
  with a JACA-style static ranking, a deadline/size micro-batcher, the
  Pallas row-gather hot path, and a k-hop fresh-recompute mode for updated
  features.
- :mod:`repro.serve.workload` — deterministic uniform / zipf / bursty
  query-stream generators for throughput and latency benchmarks.
"""
from .precompute import (EmbeddingStore, load_store, precompute_embeddings,
                         save_store)
from .engine import (Batch, BatchConfig, GNNServeEngine, MicroBatcher,
                     plan_batches, rank_hot_nodes, serve_stream)
from .workload import (QueryStream, bursty_stream, make_stream,
                       uniform_stream, zipf_stream, WORKLOAD_KINDS)

__all__ = [
    "EmbeddingStore", "precompute_embeddings", "save_store", "load_store",
    "Batch", "BatchConfig", "MicroBatcher", "plan_batches",
    "GNNServeEngine", "rank_hot_nodes", "serve_stream",
    "QueryStream", "uniform_stream", "zipf_stream", "bursty_stream",
    "make_stream", "WORKLOAD_KINDS",
]
