"""Partitioned layer-wise full-graph inference → per-layer embedding tables.

Offline half of the serving subsystem: run the trained model once over the
whole graph through the *same* partition-parallel machinery as training
(`ExchangePlan` tiers, `StackedParts` layout, any aggregation ``backend``),
and scatter every layer's stacked ``[P, NI, d]`` activations back to global
``[N, d]`` tables.  The online engine (`repro.serve.engine`) then answers
node queries by row lookup instead of neighbourhood aggregation — the
standard layer-wise inference trick (one full-graph pass costs the same as
a single refresh training step, then each query is O(1)).

``tables[l]`` holds the *input* of layer ``l`` for ``l < L`` (layer 0 = the
raw input features, layers ``1..L-1`` = post-activation hidden states) and
``tables[L]`` the final logits.  The intermediate layers are what the
engine's ``fresh=k`` mode consumes as frontier boundary values when it
recomputes a k-hop neighbourhood for updated nodes.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.dist.capgnn_sim import (_build_global, _glob_dict, _pull,
                                   _read_global, _scatter, _tier_dict,
                                   make_adj_builder)
from repro.dist.exchange import ExchangePlan, StackedParts
from repro.graph.partition import PartitionSet
from repro.models.gnn import GNNConfig, _layer_apply

__all__ = ["EmbeddingStore", "precompute_embeddings", "save_store",
           "load_store"]

_META_NAME = "store_meta.json"


@dataclasses.dataclass
class EmbeddingStore:
    """Per-layer global embedding tables of one precompute pass.

    ``tables`` has ``num_layers + 1`` entries; entry ``l`` is ``[N, d_l]``
    with ``d_l = cfg.feat_dims[l]`` (input features, hidden states, logits).
    """
    cfg: GNNConfig
    backend: str
    tables: list[np.ndarray]

    @property
    def num_nodes(self) -> int:
        return int(self.tables[0].shape[0])

    @property
    def logits(self) -> np.ndarray:
        return self.tables[-1]

    @property
    def dims(self) -> list[int]:
        return [int(t.shape[1]) for t in self.tables]


def precompute_embeddings(cfg: GNNConfig, ps: PartitionSet, sp: StackedParts,
                          xplan: ExchangePlan, params,
                          backend: str = "edges",
                          interpret: bool = True) -> EmbeddingStore:
    """One fresh partition-parallel forward pass, keeping every layer.

    Numerically identical to ``SimRuntime.forward_fresh`` (same tier pulls,
    same vmapped per-partition layer apply, same backend packs), so the
    final table equals the training runtime's fresh logits — asserted by
    the serving parity tests.
    """
    p, ni, nh = sp.num_parts, sp.n_inner_max, sp.n_halo_max
    layers = cfg.num_layers
    feats = jnp.asarray(sp.feats)
    halo_feats = jnp.asarray(sp.halo_feats)
    adj_leaves, build_adj = make_adj_builder(sp, backend, interpret)
    un_d = _tier_dict(xplan.uncached)
    loc_d = _tier_dict(xplan.local)
    glob_d = _glob_dict(xplan.glob)

    def layer_all(lp, h, halo, is_last):
        def one(lv, hi, hhi):
            adj = build_adj(lv)
            h_local = jnp.concatenate([hi, hhi], axis=0)
            return _layer_apply(cfg, lp, adj, h_local, ni, is_last)
        return jax.vmap(one)(adj_leaves, h, halo)

    @jax.jit
    def run(params):
        h = feats
        outs = [h]
        for li, lp in enumerate(params):
            if li == 0:
                halo = halo_feats
            else:
                d = h.shape[-1]
                halo = jnp.zeros((p, nh, d), h.dtype)
                halo = _scatter(halo, un_d["recv_halo_pos"], _pull(un_d, h),
                                un_d["recv_valid"])
                halo = _scatter(halo, loc_d["recv_halo_pos"], _pull(loc_d, h),
                                loc_d["recv_valid"])
                halo = _read_global(glob_d, _build_global(glob_d, h), halo)
            h = layer_all(lp, h, halo, is_last=(li == layers - 1))
            outs.append(h)
        return outs

    outs = [np.asarray(o) for o in run(params)]
    n = ps.graph.num_nodes
    tables = []
    for o in outs:
        table = np.zeros((n, o.shape[-1]), np.float32)
        for i, part in enumerate(ps.parts):
            table[part.inner_nodes] = o[i, : part.n_inner]
        tables.append(table)
    return EmbeddingStore(cfg=cfg, backend=backend, tables=tables)


# ---------------------------------------------------------------------------
# Persistence (rides on repro.checkpoint: atomic npz + json meta)
# ---------------------------------------------------------------------------

def save_store(store_dir: str, store: EmbeddingStore, step: int = 0) -> str:
    """Persist the tables via :mod:`repro.checkpoint` plus a meta sidecar
    describing the model config, so :func:`load_store` is self-contained."""
    path = save_checkpoint(store_dir, step, store.tables)
    meta = {"backend": store.backend,
            "num_nodes": store.num_nodes,
            "dims": store.dims,
            "cfg": dataclasses.asdict(store.cfg)}
    meta_path = os.path.join(store_dir, _META_NAME)
    with open(meta_path + ".tmp", "w") as f:
        json.dump(meta, f)
    os.replace(meta_path + ".tmp", meta_path)
    return path


def load_store(store_dir: str, step: int | None = None) -> EmbeddingStore:
    with open(os.path.join(store_dir, _META_NAME)) as f:
        meta = json.load(f)
    if step is None:
        step = latest_step(store_dir)
        if step is None:
            raise FileNotFoundError(f"no embedding checkpoint in {store_dir}")
    like = [np.zeros((meta["num_nodes"], d), np.float32)
            for d in meta["dims"]]
    tables = [np.asarray(t) for t in load_checkpoint(store_dir, step, like)]
    return EmbeddingStore(cfg=GNNConfig(**meta["cfg"]),
                          backend=meta["backend"], tables=tables)
