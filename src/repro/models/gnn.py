"""GNN models (GCN, GraphSAGE, GAT, GIN) over pluggable aggregation backends.

Layer contract (partition-parallel form): a layer maps
``h_local = concat([h_inner, h_halo])  [n_local, d_in]`` to new inner
embeddings ``[n_inner, d_out]`` via an :class:`Adjacency` whose rows are the
partition's inner vertices and whose columns are local ids.  On a single
worker with no partitioning, n_halo = 0 and this reduces to the textbook
model — that equivalence is what the correctness tests assert.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import glorot, zeros_init
from repro.graph.graph import Graph

__all__ = ["Adjacency", "DenseAdj", "EdgeListAdj", "EllAdj", "HybridAdj",
           "BACKENDS", "GNNConfig", "init_gnn", "gnn_forward",
           "make_local_adj", "cross_entropy_loss", "bce_loss", "accuracy"]

BACKENDS = ("edges", "dense", "ell", "hybrid")


# ---------------------------------------------------------------------------
# Aggregation backends
# ---------------------------------------------------------------------------

class Adjacency:
    """Abstract aggregation operator: rows = inner vertices, cols = local.

    Every backend provides ``spmm`` and ``degree``; ``spmm_at`` (per-edge
    values, the GAT edge-softmax path) is a capability — backends that can't
    express it raise a :class:`NotImplementedError` naming themselves and
    the ``backend="edges"`` fallback.
    """

    n_rows: int
    n_cols: int

    def spmm(self, h: jnp.ndarray) -> jnp.ndarray:   # [n_cols, d] -> [n_rows, d]
        raise NotImplementedError

    def degree(self) -> jnp.ndarray:
        """Weighted in-degree per inner row.

        Default: ``spmm`` against a ones column — exact for every backend
        since padding entries carry zero weight.  Backends with a cheaper
        closed form override this.
        """
        return self.spmm(jnp.ones((self.n_cols, 1), jnp.float32))[:, 0]

    def spmm_at(self, e_vals: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
        """SpMM with externally supplied per-edge values (GAT attention)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support per-edge-value "
            "aggregation (spmm_at); GAT's edge softmax needs flat edge ids "
            "— build the adjacency with backend='edges'.")


@dataclasses.dataclass(frozen=True)
class DenseAdj(Adjacency):
    """Dense normalized adjacency (tests / tiny graphs)."""
    mat: jnp.ndarray   # [n_rows, n_cols]

    @property
    def n_rows(self):
        return self.mat.shape[0]

    @property
    def n_cols(self):
        return self.mat.shape[1]

    def spmm(self, h):
        return self.mat @ h


@dataclasses.dataclass(frozen=True)
class EdgeListAdj(Adjacency):
    """COO edge list + segment-sum aggregation (jnp reference backend)."""
    src: jnp.ndarray      # [m] local col ids
    dst: jnp.ndarray      # [m] inner row ids
    weight: jnp.ndarray   # [m]
    n_rows_: int
    n_cols_: int

    @property
    def n_rows(self):
        return self.n_rows_

    @property
    def n_cols(self):
        return self.n_cols_

    def spmm(self, h):
        msgs = h[self.src] * self.weight[:, None]
        return jax.ops.segment_sum(msgs, self.dst, num_segments=self.n_rows_)

    def spmm_at(self, e_vals, h):
        msgs = h[self.src] * e_vals[:, None]
        return jax.ops.segment_sum(msgs, self.dst, num_segments=self.n_rows_)

    def degree(self):
        # weighted in-degree — consistent with the spmm(ones) fallback of the
        # dense/ELL backends and with the stacked worker layer (SAGE mean is
        # the ew-weighted mean on the normalized graph).
        return jax.ops.segment_sum(self.weight, self.dst,
                                   num_segments=self.n_rows_)


@dataclasses.dataclass(frozen=True)
class EllAdj(Adjacency):
    """Blocked-ELL adjacency backed by the Pallas SpMM kernel."""
    cols: jnp.ndarray     # [n_rows, max_deg] local col ids (padded)
    vals: jnp.ndarray     # [n_rows, max_deg] weights (0 at padding)
    n_cols_: int
    interpret: bool = True

    @property
    def n_rows(self):
        return self.cols.shape[0]

    @property
    def n_cols(self):
        return self.n_cols_

    def spmm(self, h):
        from repro.kernels.ops import ell_spmm
        return ell_spmm(self.cols, self.vals, h, interpret=self.interpret)

    def spmm_at(self, e_vals, h):
        """SpMM with ELL-shaped per-edge values ``[n_rows, max_deg]``.

        Padding slots (``vals == 0``) are masked out, so callers may pass
        unmasked attention scores in the same ELL layout.
        """
        from repro.kernels.ops import ell_spmm
        v = jnp.where(self.vals != 0, e_vals, 0.0)
        return ell_spmm(self.cols, v, h, interpret=self.interpret)


@dataclasses.dataclass(frozen=True)
class HybridAdj(Adjacency):
    """Hybrid blocked-ELL + COO-tail adjacency (Pallas kernel + segment-sum).

    The regular part is packed to the degree quantile; overflow edges of
    heavy rows live in a COO tail aggregated by segment-sum.  Padded tail
    entries carry ``tail_dst == n_rows`` and are dropped by the scatter, so
    the tail arrays may be padded to a static width (stacked runtimes).
    """
    cols: jnp.ndarray      # [n_rows, max_deg] local col ids (padded)
    vals: jnp.ndarray      # [n_rows, max_deg] weights (0 at padding)
    tail_src: jnp.ndarray  # [mt] local col ids
    tail_dst: jnp.ndarray  # [mt] inner row ids (n_rows = padding)
    tail_w: jnp.ndarray    # [mt] weights (0 at padding)
    n_cols_: int
    interpret: bool = True

    @property
    def n_rows(self):
        return self.cols.shape[0]

    @property
    def n_cols(self):
        return self.n_cols_

    def spmm(self, h):
        from repro.kernels.ops import hybrid_spmm
        return hybrid_spmm(self.cols, self.vals, self.tail_src,
                           self.tail_dst, self.tail_w, h,
                           interpret=self.interpret)


def make_local_adj(local_graph: Graph, n_inner: int, backend: str = "edges",
                   interpret: bool = True) -> Adjacency:
    """Build an Adjacency for a partition-local graph (rows = inner)."""
    src, dst = local_graph.edges()
    keep = dst < n_inner
    src, dst = src[keep], dst[keep]
    w = (local_graph.edge_weight[keep] if local_graph.edge_weight is not None
         else np.ones(src.shape[0], np.float32))
    n_cols = local_graph.num_nodes
    if backend == "dense":
        mat = np.zeros((n_inner, n_cols), np.float32)
        mat[dst, src] = w
        return DenseAdj(jnp.asarray(mat))
    if backend == "edges":
        return EdgeListAdj(jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
                           jnp.asarray(w, jnp.float32), n_inner, n_cols)
    if backend == "ell":
        from repro.kernels.ops import ell_pack
        cols, vals = ell_pack(src, dst, w, n_inner)
        return EllAdj(jnp.asarray(cols), jnp.asarray(vals), n_cols,
                      interpret=interpret)
    if backend == "hybrid":
        from repro.kernels.ops import ell_pack_hybrid
        cols, vals, ts, td, tw = ell_pack_hybrid(src, dst, w, n_inner)
        return HybridAdj(jnp.asarray(cols), jnp.asarray(vals),
                         jnp.asarray(ts), jnp.asarray(td), jnp.asarray(tw),
                         n_cols, interpret=interpret)
    raise ValueError(f"unknown aggregation backend {backend!r}; "
                     f"expected one of {BACKENDS}")


# ---------------------------------------------------------------------------
# Models
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GNNConfig:
    model: str = "gcn"            # gcn | sage | gat | gin
    in_dim: int = 64
    hidden_dim: int = 256         # paper: 256
    out_dim: int = 16
    num_layers: int = 3           # paper: 3
    num_heads: int = 4            # GAT
    residual: bool = False

    @property
    def layer_dims(self) -> list[tuple[int, int]]:
        dims = [self.in_dim] + [self.hidden_dim] * (self.num_layers - 1) + [self.out_dim]
        return list(zip(dims[:-1], dims[1:]))

    @property
    def feat_dims(self) -> list[int]:
        """Per-tier cached row widths: input features + each layer output."""
        return [self.in_dim] + [self.hidden_dim] * (self.num_layers - 1) + [self.out_dim]


def init_gnn(key, cfg: GNNConfig) -> list[dict]:
    params = []
    for li, (din, dout) in enumerate(cfg.layer_dims):
        key, k1, k2, k3 = jax.random.split(key, 4)
        if cfg.model == "gcn":
            p = {"w": glorot(k1, (din, dout)), "b": zeros_init(k2, (dout,))}
        elif cfg.model == "sage":
            p = {"w_self": glorot(k1, (din, dout)),
                 "w_neigh": glorot(k2, (din, dout)),
                 "b": zeros_init(k3, (dout,))}
        elif cfg.model == "gat":
            h = cfg.num_heads
            dh = max(1, dout // h)
            p = {"w": glorot(k1, (din, h * dh)),
                 "a_src": glorot(k2, (h, dh)),
                 "a_dst": glorot(k3, (h, dh)),
                 "proj": glorot(key, (h * dh, dout))}
        elif cfg.model == "gin":
            p = {"w1": glorot(k1, (din, dout)), "b1": zeros_init(k2, (dout,)),
                 "w2": glorot(k3, (dout, dout)), "b2": zeros_init(key, (dout,)),
                 "eps": jnp.zeros(())}
        else:
            raise ValueError(cfg.model)
        params.append(p)
    return params


def _layer_apply(cfg: GNNConfig, p: dict, adj: Adjacency,
                 h_local: jnp.ndarray, n_inner: int, is_last: bool) -> jnp.ndarray:
    if cfg.model == "gcn":
        z = adj.spmm(h_local) @ p["w"] + p["b"]
    elif cfg.model == "sage":
        agg = adj.spmm(h_local)
        agg = agg / jnp.maximum(adj.degree()[:, None], 1.0)
        z = h_local[:n_inner] @ p["w_self"] + agg @ p["w_neigh"] + p["b"]
    elif cfg.model == "gat":
        if not isinstance(adj, EdgeListAdj):
            raise NotImplementedError(
                f"GAT's edge softmax needs flat edge ids, which the "
                f"{type(adj).__name__} backend does not expose — build the "
                "adjacency/runtime with backend='edges' for GAT.")
        h_heads = (h_local @ p["w"]).reshape(h_local.shape[0], p["a_src"].shape[0], -1)
        e_src = jnp.einsum("nhd,hd->nh", h_heads, p["a_src"])
        e_dst = jnp.einsum("nhd,hd->nh", h_heads, p["a_dst"])
        logits = jax.nn.leaky_relu(e_src[adj.src] + e_dst[adj.dst], 0.2)
        # segment softmax over incoming edges of each inner vertex
        seg_max = jax.ops.segment_max(logits, adj.dst, num_segments=adj.n_rows)
        ex = jnp.exp(logits - seg_max[adj.dst])
        denom = jax.ops.segment_sum(ex, adj.dst, num_segments=adj.n_rows)
        att = ex / jnp.maximum(denom[adj.dst], 1e-9)
        outs = []
        for hh in range(att.shape[1]):
            outs.append(adj.spmm_at(att[:, hh], h_heads[:, hh, :]))
        z = jnp.concatenate(outs, axis=-1) @ p["proj"]
    elif cfg.model == "gin":
        agg = adj.spmm(h_local)
        z = (1.0 + p["eps"]) * h_local[:n_inner] + agg
        z = jax.nn.relu(z @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
    else:
        raise ValueError(cfg.model)
    if not is_last:
        z = jax.nn.relu(z)
    return z


def gnn_forward(cfg: GNNConfig, params: list[dict], adj: Adjacency,
                h_inner: jnp.ndarray,
                halo_embeds: Sequence[jnp.ndarray] | None) -> jnp.ndarray:
    """Partition-local forward.

    ``halo_embeds[l]`` are the halo embeddings consumed by layer ``l``
    (layer 0: halo input features; layer l>0: remote layer-(l) inputs).
    ``None`` means no halo (single-worker full graph).
    Returns inner-vertex logits.
    """
    n_inner = h_inner.shape[0]
    h = h_inner
    for li, p in enumerate(params):
        if halo_embeds is not None:
            h_local = jnp.concatenate([h, halo_embeds[li]], axis=0)
        else:
            h_local = h
        h = _layer_apply(cfg, p, adj, h_local, n_inner,
                         is_last=(li == len(params) - 1))
    return h


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------

def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), -1)[:, 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def bce_loss(logits: jnp.ndarray, targets: jnp.ndarray,
             mask: jnp.ndarray | None = None) -> jnp.ndarray:
    per = jnp.maximum(logits, 0) - logits * targets + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    per = per.mean(-1)
    if mask is not None:
        return jnp.sum(per * mask) / jnp.maximum(mask.sum(), 1.0)
    return per.mean()


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
             mask: jnp.ndarray | None = None) -> jnp.ndarray:
    correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    if mask is not None:
        return jnp.sum(correct * mask) / jnp.maximum(mask.sum(), 1.0)
    return correct.mean()
