"""Model assembly: embedding -> block runs (lax.scan) -> LM head.

Entry points:
- ``init_model(key, cfg)``            parameter pytree
- ``forward(cfg, params, batch)``     train/prefill logits
- ``train_step_fn(cfg, opt)``         jit-able (params, opt_state, batch) step
- ``init_decode_cache(cfg, B, S)``    stacked per-run caches
- ``serve_step(cfg, params, cache, tokens, pos)``  one-token decode

Layers of the same kind are stacked and executed with ``lax.scan`` so the
61-layer DeepSeek config lowers as a handful of loops, not 61 inlined
blocks.  ``cfg.remat`` wraps the scan body in ``jax.checkpoint``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.nn.init import normal_init
from .blocks import init_block, apply_block, init_block_cache, norm_apply
from .config import ModelConfig
from .spmd import constrain

__all__ = ["init_model", "forward", "loss_fn", "train_step_fn",
           "init_decode_cache", "serve_step", "param_count"]


def _stack_trees(trees: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_model(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.num_layers + 3)
    params: dict[str, Any] = {
        "embed": normal_init(keys[0], (cfg.padded_vocab, cfg.d_model),
                             dtype=dt),
        "final_norm": {"scale": jnp.ones((cfg.d_model,), jnp.float32)},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(keys[1], (cfg.d_model,
                                                  cfg.padded_vocab), dtype=dt)
    runs = []
    for kind, start, length in cfg.block_runs():
        layers = [init_block(keys[3 + start + i], cfg, kind)
                  for i in range(length)]
        runs.append(_stack_trees(layers))
    params["runs"] = runs
    return params


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count via shape-only evaluation (no allocation)."""
    shapes = jax.eval_shape(lambda k: init_model(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    import numpy as np
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))


def _embed(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    tokens = batch["tokens"]
    x = params["embed"][tokens] * jnp.sqrt(float(cfg.d_model)).astype(
        jnp.dtype(cfg.dtype))
    if cfg.vision_tokens and "patches" in batch:
        # VLM stub carve-out: pre-computed patch embeddings are prepended.
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    return x


def forward(cfg: ModelConfig, params, batch) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (logits [B,S,Vpad], aux_loss)."""
    x = _embed(cfg, params, batch)
    x = constrain(x)
    s = x.shape[1]
    positions = jnp.arange(s)
    aux_total = jnp.zeros((), jnp.float32)
    for run_params, (kind, start, length) in zip(params["runs"],
                                                 cfg.block_runs()):
        def body(carry, p_layer, _kind=kind):
            h, aux = carry
            h2, _, aux_l = apply_block(cfg, _kind, p_layer, h, positions)
            h2 = constrain(h2)
            return (h2, aux + aux_l), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), run_params)
    x = norm_apply(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    logits = constrain(logits, "logits")
    return logits, aux_total


def loss_fn(cfg: ModelConfig, params, batch) -> tuple[jnp.ndarray, dict]:
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.vision_tokens and "patches" in batch:
        logits = logits[:, batch["patches"].shape[1]:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32),
                               -1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        loss = nll.mean()
    else:
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux}


def train_step_fn(cfg: ModelConfig, opt):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step(params, opt_state, batch):
        (total, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        params2, opt_state2 = opt.update(grads, opt_state, params)
        return params2, opt_state2, metrics

    return step


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> list:
    """Per-run stacked caches."""
    caches = []
    for kind, start, length in cfg.block_runs():
        layer_caches = [init_block_cache(cfg, kind, batch, max_len)
                        for _ in range(length)]
        caches.append(_stack_trees(layer_caches))
    return caches


def serve_step(cfg: ModelConfig, params, caches: list, tokens: jnp.ndarray,
               pos: jnp.ndarray):
    """Decode one token.  tokens [B,1] int32, pos scalar int32 (current
    position = number of tokens already in the cache).
    Returns (logits [B, Vpad], new caches)."""
    x = params["embed"][tokens] * jnp.sqrt(float(cfg.d_model)).astype(
        jnp.dtype(cfg.dtype))
    x = constrain(x)
    new_caches = []
    for run_params, run_cache, (kind, start, length) in zip(
            params["runs"], caches, cfg.block_runs()):
        def body(h, layer, _kind=kind):
            p_layer, c_layer = layer
            h2, c2, _ = apply_block(cfg, _kind, p_layer, h, None,
                                    cache=c_layer, pos=pos)
            return constrain(h2), c2

        x, updated = jax.lax.scan(body, x, (run_params, run_cache))
        new_caches.append(updated)
    x = norm_apply(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head)[:, 0]
    return logits, new_caches
