"""SPMD context for the transformer zoo.

The model code is mesh-agnostic; the launcher installs an :class:`SpmdCtx`
that tells it (a) how activations are sharded (so it can place
``with_sharding_constraint`` hints) and (b) which axes are data-parallel
(so the MoE dispatch can run in a partial-manual ``shard_map`` group —
GShard-style per-group capacity instead of an infeasible global dispatch
tensor).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["SpmdCtx", "use_spmd", "current_spmd", "constrain"]

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class SpmdCtx:
    mesh: Mesh
    dp_axes: tuple[str, ...]            # axes sharding tokens (batch or seq)
    act_spec: P                         # PartitionSpec for [B, S, D] hiddens
    logits_spec: Optional[P] = None     # for [B, S, V] logits
    moe_group: bool = True              # run MoE dispatch per dp group
    # Block-level sequence parallelism (§Perf): residuals stay seq-sharded
    # between blocks, but q/k/v (and SSM internals) are constrained to a
    # seq-FULL, head-(or channel-)sharded layout ONCE per block, so the
    # flash-attention chunk loops and recurrent scans run with zero
    # per-iteration collectives (one all-gather in, one reduce-scatter out).
    block_sp: bool = False


def use_spmd(ctx: Optional[SpmdCtx]):
    @contextlib.contextmanager
    def cm():
        prev = getattr(_state, "ctx", None)
        _state.ctx = ctx
        try:
            yield ctx
        finally:
            _state.ctx = prev
    return cm()


def current_spmd() -> Optional[SpmdCtx]:
    return getattr(_state, "ctx", None)


def constrain(x, spec_name: str = "act"):
    """Apply a sharding constraint if a ctx is installed (no-op otherwise)."""
    ctx = current_spmd()
    if ctx is None:
        return x
    spec = ctx.act_spec if spec_name == "act" else ctx.logits_spec
    if spec is None:
        return x
    # trim spec to rank
    spec = P(*(list(spec) + [None] * x.ndim)[: x.ndim])
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_to(x, spec: P):
    """Explicit-spec constraint (no-op without a ctx)."""
    if current_spmd() is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def block_sp_active() -> bool:
    ctx = current_spmd()
    return bool(ctx is not None and ctx.block_sp)


def block_sp_dp() -> tuple[str, ...]:
    ctx = current_spmd()
    return ctx.dp_axes if ctx is not None else ()
