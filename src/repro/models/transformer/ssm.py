"""SSM / recurrent primitives: chunked mLSTM, Mamba selective scan, sLSTM.

TPU adaptation notes (DESIGN.md §2):

- **mLSTM** (xLSTM): decay is a *scalar per head per step*, so the
  chunkwise-parallel dual form applies — intra-chunk work is a masked,
  decay-weighted q@k^T (MXU-friendly [c, c] tiles), inter-chunk state
  ``C [H, dh, dh]`` is carried by a short ``lax.scan`` over chunks.
  Compute O(S*c + S*dh) per head-dim, sub-quadratic in S for fixed c.
- **Mamba** selective scan: decay is per-channel x per-state (rank-full),
  so the dual form would need [c, c, d_inner] temporaries; we use the
  sequential ``lax.scan`` over time (one XLA while-loop, small carried
  state [B, d_inner, N]) — the TPU analogue of the CUDA selective-scan
  kernel's recurrence, chosen over associative_scan whose [B,S,d,N]
  materialisation cannot fit HBM at the assigned shapes.
- **sLSTM** has head-block recurrent matrices R (h_{t-1} feeds the gates),
  which is inherently sequential — faithful ``lax.scan``.

All scans are causal and expose a (state-in, state-out) interface so decode
reuses the same cell code with a 1-step scan.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["MlstmState", "mlstm_chunked", "mlstm_step", "selective_scan",
           "selective_scan_step", "SlstmState", "slstm_scan", "slstm_step"]


# ---------------------------------------------------------------------------
# mLSTM (chunkwise-parallel linear attention with scalar per-head gates)
# ---------------------------------------------------------------------------

class MlstmState(NamedTuple):
    c: jnp.ndarray   # [B, H, dk, dv] matrix memory
    n: jnp.ndarray   # [B, H, dk]     normalizer


def mlstm_chunked(q, k, v, i_gate, f_gate, state: MlstmState | None = None,
                  chunk: int = 128):
    """q/k/v [B,S,H,dh]; i_gate/f_gate [B,S,H] (pre-activations).

    f = sigmoid(f_gate) (log-decay <= 0), i = exp(clip(i_gate)) per the
    xLSTM exponential input gate (clipped for stability; the |q.n|
    denominator provides the scale normalisation).
    Returns (y [B,S,H,dh], final MlstmState).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, z4)
        k = jnp.pad(k, z4)
        v = jnp.pad(v, z4)
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)))
        f_gate = jnp.pad(f_gate, ((0, 0), (0, pad), (0, 0)),
                         constant_values=30.0)  # f=1 for padding
    sp = q.shape[1]
    n_chunks = sp // c
    scale = 1.0 / jnp.sqrt(dk).astype(jnp.float32)

    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))    # [B,S,H] <= 0
    li = jnp.clip(i_gate.astype(jnp.float32), -20.0, 10.0)

    def r(x, width):
        return x.reshape(b, n_chunks, c, *x.shape[2:]).transpose(
            1, 0, *range(2, x.ndim + 1))

    q_r = q.reshape(b, n_chunks, c, h, dk).transpose(1, 0, 3, 2, 4)  # [n,B,H,c,dk]
    k_r = k.reshape(b, n_chunks, c, h, dk).transpose(1, 0, 3, 2, 4)
    v_r = v.reshape(b, n_chunks, c, h, dv).transpose(1, 0, 3, 2, 4)
    lf_r = lf.reshape(b, n_chunks, c, h).transpose(1, 0, 3, 2)       # [n,B,H,c]
    li_r = li.reshape(b, n_chunks, c, h).transpose(1, 0, 3, 2)

    if state is None:
        state = MlstmState(
            c=jnp.zeros((b, h, dk, dv), jnp.float32),
            n=jnp.zeros((b, h, dk), jnp.float32))

    def chunk_step(carry, inp):
        c0, n0 = carry
        qb, kb, vb, lfb, lib = inp            # [B,H,c,*]
        lf_cum = jnp.cumsum(lfb, axis=-1)     # [B,H,c] log prod f up to t
        # intra-chunk: D[t,i] = exp(lf_cum[t] - lf_cum[i]) for i <= t
        dmat = lf_cum[..., :, None] - lf_cum[..., None, :]
        causal = jnp.tril(jnp.ones((c, c), bool))
        wts = jnp.where(causal, jnp.exp(dmat + lib[..., None, :]), 0.0)
        sc = jnp.einsum("bhtd,bhid->bhti", qb.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale
        sc = sc * wts
        y_intra = jnp.einsum("bhti,bhiv->bhtv", sc, vb.astype(jnp.float32))
        n_intra = jnp.einsum("bhti,bhid->bhtd", wts,
                             kb.astype(jnp.float32)) * scale
        # inter-chunk: decayed read of carried state
        decay_t = jnp.exp(lf_cum)             # [B,H,c]
        y_inter = jnp.einsum("bhtd,bhdv->bhtv", qb.astype(jnp.float32),
                             c0) * decay_t[..., None] * scale
        n_inter = jnp.einsum("bhtd,bhd->bht", qb.astype(jnp.float32),
                             n0) * decay_t * scale
        denom_intra = jnp.einsum("bhtd,bhtd->bht", qb.astype(jnp.float32),
                                 n_intra)
        denom = jnp.abs(denom_intra + n_inter)
        y = (y_intra + y_inter) / jnp.maximum(denom, 1.0)[..., None]
        # state update
        total = lf_cum[..., -1]               # [B,H]
        wts_end = jnp.exp(total[..., None] - lf_cum + lib)   # [B,H,c]
        kv = jnp.einsum("bhid,bhiv->bhdv",
                        kb.astype(jnp.float32) * wts_end[..., None],
                        vb.astype(jnp.float32))
        c1 = c0 * jnp.exp(total)[..., None, None] + kv
        n1 = n0 * jnp.exp(total)[..., None] + jnp.einsum(
            "bhid,bhi->bhd", kb.astype(jnp.float32), wts_end)
        return (c1, n1), y

    (c_f, n_f), ys = jax.lax.scan(chunk_step, (state.c, state.n),
                                  (q_r, k_r, v_r, lf_r, li_r))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, sp, h, dv)[:, :s]
    return y.astype(q.dtype), MlstmState(c=c_f, n=n_f)


def mlstm_step(state: MlstmState, q, k, v, i_gate, f_gate):
    """Single decode step.  q/k/v [B,1,H,dh], gates [B,1,H]."""
    y, st = mlstm_chunked(q, k, v, i_gate, f_gate, state=state, chunk=1)
    return y, st


# ---------------------------------------------------------------------------
# Mamba selective scan
# ---------------------------------------------------------------------------

def selective_scan(x, delta, a_log, b_in, c_in, d_skip,
                   h0: jnp.ndarray | None = None):
    """Mamba S4D-real selective scan (sequential lax.scan over time).

    x      [B,S,DI]      input (post conv+silu)
    delta  [B,S,DI]      softplus'd step sizes
    a_log  [DI,N]        A = -exp(a_log)
    b_in   [B,S,N]
    c_in   [B,S,N]
    d_skip [DI]
    h0     [B,DI,N] carried state (zeros if None)
    Returns (y [B,S,DI], h_final).
    """
    bsz, s, di = x.shape
    n = a_log.shape[1]
    a = -jnp.exp(a_log.astype(jnp.float32))           # [DI,N]
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)

    xs = x.astype(jnp.float32).transpose(1, 0, 2)     # [S,B,DI]
    ds = delta.astype(jnp.float32).transpose(1, 0, 2)
    bs = b_in.astype(jnp.float32).transpose(1, 0, 2)  # [S,B,N]
    cs = c_in.astype(jnp.float32).transpose(1, 0, 2)

    def step(h, inp):
        xt, dt, bt, ct = inp
        decay = jnp.exp(dt[..., None] * a)            # [B,DI,N]
        h = h * decay + (dt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h_f, ys = jax.lax.scan(step, h0, (xs, ds, bs, cs))
    y = ys.transpose(1, 0, 2) + x.astype(jnp.float32) * d_skip
    return y.astype(x.dtype), h_f


def selective_scan_step(h, xt, dt, a_log, bt, ct, d_skip):
    """One decode step: xt/dt [B,DI], bt/ct [B,N], h [B,DI,N]."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32)[..., None] * a)
    h = h * decay + (dt * xt).astype(jnp.float32)[..., None] * bt[:, None, :].astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, ct.astype(jnp.float32))
    y = y + xt.astype(jnp.float32) * d_skip
    return y.astype(xt.dtype), h


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, exponential gating, block-diagonal recurrence)
# ---------------------------------------------------------------------------

class SlstmState(NamedTuple):
    c: jnp.ndarray   # [B, D]
    n: jnp.ndarray   # [B, D]
    h: jnp.ndarray   # [B, D]
    m: jnp.ndarray   # [B, D] stabilizer


def _slstm_cell(state: SlstmState, gates_x, r_blocks, n_heads):
    """gates_x [B, 4D] = W x_t + b (z,i,f,o pre-acts before recurrence)."""
    c0, n0, h0, m0 = state
    bsz, d = c0.shape
    dh = d // n_heads
    h_heads = h0.reshape(bsz, n_heads, dh)
    rec = jnp.einsum("bhd,hgde->bhge", h_heads, r_blocks)   # [B,H,4,dh]
    rec = rec.transpose(0, 2, 1, 3).reshape(bsz, 4 * d)
    z_, i_, f_, o_ = jnp.split(gates_x + rec, 4, axis=-1)
    z = jnp.tanh(z_)
    o = jax.nn.sigmoid(o_)
    # stabilized exponential gating (xLSTM eq. 15-17)
    log_f = jax.nn.log_sigmoid(f_)
    m1 = jnp.maximum(log_f + m0, i_)
    i = jnp.exp(i_ - m1)
    f = jnp.exp(log_f + m0 - m1)
    c1 = f * c0 + i * z
    n1 = f * n0 + i
    h1 = o * (c1 / jnp.maximum(n1, 1.0))
    return SlstmState(c=c1, n=n1, h=h1, m=m1)


def slstm_scan(gates_x, r_blocks, n_heads: int,
               state: SlstmState | None = None):
    """gates_x [B,S,4D]; r_blocks [H,4,dh,dh].  Returns (h [B,S,D], state)."""
    bsz, s, d4 = gates_x.shape
    d = d4 // 4
    if state is None:
        z = jnp.zeros((bsz, d), jnp.float32)
        state = SlstmState(c=z, n=z, h=z, m=z)

    def step(st, gx):
        st1 = _slstm_cell(st, gx, r_blocks, n_heads)
        return st1, st1.h

    state_f, hs = jax.lax.scan(step, state,
                               gates_x.astype(jnp.float32).transpose(1, 0, 2))
    return hs.transpose(1, 0, 2).astype(gates_x.dtype), state_f


def slstm_step(state: SlstmState, gates_x, r_blocks, n_heads: int):
    """One decode step: gates_x [B, 4D]."""
    st = _slstm_cell(state, gates_x.astype(jnp.float32), r_blocks, n_heads)
    return st.h.astype(gates_x.dtype), st
