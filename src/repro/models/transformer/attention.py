"""Attention: GQA (+RoPE, qk-norm, bias, sliding window) and MLA.

Training/prefill attention is **blocked** (flash-style running-softmax over
KV chunks, O(chunk^2) memory) so 32k-sequence prefill lowers without an
S x S temporary; with a sliding window the KV iteration is **banded**
(only window//chunk + 1 chunks per query chunk => O(S*W) compute), which is
what makes dense archs eligible for the long_500k shape.

Decode attends one query against the cache; MLA decode runs in the
compressed (kv_lora) space via weight absorption, so the cache holds
``kv_lora + rope_dim`` per token instead of ``2 * n_heads * head_dim``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["rope", "rope_at", "blocked_attention", "decode_attention",
           "banded_attention"]

_NEG_INF = -1e30


def _rope_angles(positions: jnp.ndarray, dim: int, theta: float):
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., dim/2]
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x [..., S, H, D], positions [S] (or broadcastable)."""
    d = x.shape[-1]
    cos, sin = _rope_angles(positions, d, theta)       # [S, D/2]
    cos = cos[:, None, :]                              # [S, 1, D/2]
    sin = sin[:, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)


def rope_at(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """RoPE for a single decode position. x [B, 1, H, D], pos scalar."""
    return rope(x, jnp.reshape(pos, (1,)), theta)


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B, S, nkv, D] -> [B, S, nq, D] by group repeat."""
    nkv = k.shape[2]
    if nkv == n_heads:
        return k
    rep = n_heads // nkv
    return jnp.repeat(k, rep, axis=2)


def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, q_chunk: int = 512,
                      k_chunk: int = 512) -> jnp.ndarray:
    """Flash-style causal attention.  q [B,S,Hq,D], k/v [B,S,Hkv,D].

    Memory per step: O(B * Hq * q_chunk * k_chunk).  Query chunks via
    lax.map, KV chunks via lax.scan carrying (m, l, acc).
    """
    b, s, hq, d = q.shape
    dv = v.shape[-1]
    k = _expand_kv(k, hq)
    v = _expand_kv(v, hq)
    qc = min(q_chunk, s)
    kc = min(k_chunk, s)
    pad_q = (-s) % qc
    pad_k = (-s) % kc
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq, sk = q.shape[1], k.shape[1]
    nq, nk = sq // qc, sk // kc
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    q_r = q.reshape(b, nq, qc, hq, d).transpose(1, 0, 3, 2, 4)   # [nq,B,H,qc,D]
    k_r = k.reshape(b, nk, kc, hq, d).transpose(1, 0, 3, 2, 4)
    v_r = v.reshape(b, nk, kc, hq, dv).transpose(1, 0, 3, 2, 4)
    kv_valid = (jnp.arange(sk) < s).reshape(nk, kc)

    def per_q_chunk(args):
        qi, q_blk = args                         # q_blk [B,H,qc,D]
        q_pos = qi * qc + jnp.arange(qc)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_blk, v_blk, valid = inp
            sc = jnp.einsum("bhqd,bhkd->bhqk", q_blk.astype(jnp.float32),
                            k_blk.astype(jnp.float32)) * scale
            k_pos = ki * kc + jnp.arange(kc)
            mask = valid[None, None, None, :]
            if causal:
                mask = mask & (k_pos[None, None, None, :]
                               <= q_pos[None, None, :, None])
            sc = jnp.where(mask, sc, _NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, qc), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, qc), jnp.float32)
        a0 = jnp.zeros((b, hq, qc, dv), jnp.float32)
        # checkpoint each KV step: backward recomputes the [qc, kc] score
        # tile instead of stashing it (flash-attention memory behaviour)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0),
            (jnp.arange(nk), k_r, v_r, kv_valid))
        return acc / jnp.maximum(l, 1e-20)[..., None]

    out = jax.lax.map(per_q_chunk, (jnp.arange(nq), q_r))  # [nq,B,H,qc,Dv]
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, sq, hq, dv)
    return out[:, :s].astype(q.dtype)


def banded_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     window: int, *, chunk: int = 512) -> jnp.ndarray:
    """Sliding-window causal attention with O(S * window) compute.

    For query chunk i, only KV chunks in [i - window//chunk, i] are touched
    (dynamic_slice), so compute and memory scale with the band, not S^2.
    """
    b, s, hq, d = q.shape
    k = _expand_kv(k, hq)
    v = _expand_kv(v, hq)
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = q.shape[1]
    n = sp // c
    n_band = min(n - 1, (window + c - 1) // c)    # trailing chunks + diagonal
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    q_r = q.reshape(b, n, c, hq, d).transpose(1, 0, 3, 2, 4)  # [n,B,H,c,D]
    k_t = k.transpose(0, 2, 1, 3)                             # [B,H,S,D]
    v_t = v.transpose(0, 2, 1, 3)

    def per_q_chunk(args):
        qi, q_blk = args
        q_pos = qi * c + jnp.arange(c)

        def band_step(carry, off):
            m, l, acc = carry
            ki = qi - n_band + off                 # chunk index (may be < 0)
            start = jnp.maximum(ki, 0) * c
            k_blk = jax.lax.dynamic_slice_in_dim(k_t, start, c, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(v_t, start, c, axis=2)
            sc = jnp.einsum("bhqd,bhkd->bhqk", q_blk.astype(jnp.float32),
                            k_blk.astype(jnp.float32)) * scale
            k_pos = start + jnp.arange(c)
            mask = (k_pos[None, None, None, :] <= q_pos[None, None, :, None])
            mask &= (q_pos[None, None, :, None] - k_pos[None, None, None, :]
                     < window)
            mask &= (ki >= 0)
            mask &= (k_pos[None, None, None, :] < s)
            sc = jnp.where(mask, sc, _NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, c), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, c), jnp.float32)
        a0 = jnp.zeros((b, hq, c, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(band_step), (m0, l0, a0),
                                      jnp.arange(n_band + 1))
        return acc / jnp.maximum(l, 1e-20)[..., None]

    out = jax.lax.map(per_q_chunk, (jnp.arange(n), q_r))
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, sp, hq, d)
    return out[:, :s].astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, length: jnp.ndarray, *,
                     window: Optional[int] = None) -> jnp.ndarray:
    """One-token attention.  q [B,1,Hq,D], caches [B,S,Hkv,D].

    ``length`` = number of valid cache entries (new token's position).
    The softmax runs in f32; with a window only the last ``window``
    positions score (the cache itself may be a ring buffer upstream).
    """
    b, _, hq, d = q.shape
    s = k_cache.shape[1]
    k_cache = _expand_kv(k_cache, hq)
    v_cache = _expand_kv(v_cache, hq)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    sc = jnp.einsum("bohd,bshd->bhos", q.astype(jnp.float32),
                    k_cache.astype(jnp.float32)) * scale    # [B,H,1,S]
    pos = jnp.arange(s)
    mask = pos[None, None, None, :] <= length
    if window is not None:
        mask &= pos[None, None, None, :] > (length - window)
    sc = jnp.where(mask, sc, _NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhos,bshd->bohd", w, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)
