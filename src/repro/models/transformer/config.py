"""Unified transformer-family model configuration.

One dataclass covers the 10 assigned architectures (dense GQA, MoE, MLA,
xLSTM, Mamba-hybrid, VLM/audio backbones).  Each ``src/repro/configs/<id>.py``
instantiates it with the published numbers (source cited there).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

__all__ = ["ModelConfig", "BlockKind"]

BlockKind = Literal["attn_dense", "attn_moe", "mlstm", "slstm", "hybrid"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 => d_model // n_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    attn_window: Optional[int] = None    # sliding-window size (None = full)
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    ffn_act: str = "swiglu"              # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                    # per-expert FFN width (0 = d_ff)
    n_dense_layers: int = 0              # leading dense layers (deepseek)
    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    slstm_every: int = 0                 # xLSTM: every k-th block is sLSTM
    # frontends (stubs per brief)
    vision_tokens: int = 0               # VLM: patch embeddings prepended
    audio_frontend: bool = False
    # numerics / training
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    remat: bool = False                  # activation checkpoint per block
    # citation for the numbers above
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (shardable over 16-way model
        axis with lane-aligned 128-multiples per shard)."""
        return ((self.vocab_size + 255) // 256) * 256

    def block_kinds(self) -> list[str]:
        kinds: list[str] = []
        for i in range(self.num_layers):
            if self.arch_type == "ssm":
                if self.slstm_every and i % self.slstm_every == 0:
                    kinds.append("slstm")
                else:
                    kinds.append("mlstm")
            elif self.arch_type == "hybrid":
                kinds.append("hybrid")
            elif self.n_experts > 0 and i >= self.n_dense_layers:
                kinds.append("attn_moe")
            else:
                kinds.append("attn_dense")
        return kinds

    def block_runs(self) -> list[tuple[str, int, int]]:
        """Contiguous (kind, start, length) runs — each run is one scan."""
        kinds = self.block_kinds()
        runs: list[tuple[str, int, int]] = []
        i = 0
        while i < len(kinds):
            j = i
            while j < len(kinds) and kinds[j] == kinds[i]:
                j += 1
            runs.append((kinds[i], i, j - i))
            i = j
        return runs

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        for kind in self.block_kinds():
            if kind in ("attn_dense", "attn_moe"):
                if self.use_mla:
                    ql = self.q_lora_rank or d
                    attn = d * ql + ql * nq * (self.qk_nope_dim + self.qk_rope_dim)
                    attn += d * (self.kv_lora_rank + self.qk_rope_dim)
                    attn += self.kv_lora_rank * nq * (self.qk_nope_dim + self.v_head_dim)
                    attn += nq * self.v_head_dim * d
                else:
                    attn = d * (nq + 2 * nkv) * hd + nq * hd * d
                total += attn
                if kind == "attn_dense":
                    ff = self.d_ff
                    total += d * ff * (3 if self.ffn_act == "swiglu" else 2)
                else:
                    fe = self.moe_d_ff or self.d_ff
                    total += self.n_experts * d * fe * 3
                    total += self.n_shared_experts * d * fe * 3
                    total += d * self.n_experts  # router
            elif kind == "mlstm":
                di = self.d_model * self.ssm_expand
                # wq,wk,wv,wz [d,di] + wd [di,d] + if-gates [d,2H]
                total += 5 * d * di + 2 * d * self.n_heads
            elif kind == "slstm":
                dh = d // max(1, self.n_heads)
                total += 4 * d * d + 4 * self.n_heads * dh * dh
            elif kind == "hybrid":
                attn = d * (nq + 2 * nkv) * hd + nq * hd * d
                di = d * self.ssm_expand
                ssm = d * di * 2 + di * d + di * (2 * self.ssm_state + 1)
                total += attn + ssm + d * self.d_ff * 3
            total += 2 * d  # norms
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k instead of all experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        fe = self.moe_d_ff or self.d_ff
        inactive = (self.n_experts - self.top_k) * d * fe * 3
        n_moe = sum(1 for k in self.block_kinds() if k == "attn_moe")
        return int(self.param_count() - n_moe * inactive)
