"""Decoder blocks for the architecture zoo: init + apply (train & decode).

Block kinds (config.block_kinds):
- ``attn_dense``  — [qk-norm|bias|SWA] GQA or MLA attention + dense FFN
- ``attn_moe``    — attention + top-k MoE FFN (capacity-based dispatch,
                    optional shared experts)
- ``mlstm``       — xLSTM matrix-memory block (chunked linear attention)
- ``slstm``       — xLSTM scalar-memory block (sequential recurrence)
- ``hybrid``      — Hymba-style parallel attention + Mamba heads, then FFN

Params are plain dicts; each init_* takes (key, cfg) and returns one
layer's params, the model stacks them per run for ``lax.scan``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.nn import rms_norm, layer_norm
from repro.nn.init import normal_init, zeros_init
from .attention import (rope, blocked_attention, banded_attention,
                        decode_attention)
from .config import ModelConfig
from .spmd import (block_sp_active as _bsp_active, block_sp_dp as _bsp_dp,
                   constrain_to as _constrain_to)
from .ssm import (MlstmState, mlstm_chunked, selective_scan,
                  selective_scan_step, SlstmState, slstm_scan, slstm_step)

__all__ = ["init_block", "apply_block", "init_block_cache", "norm_apply"]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def norm_apply(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(p, x)
    return rms_norm(p, x)


def _norm_params(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# Attention sub-block
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    if cfg.use_mla:
        ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
        nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        p = {
            "wdq": normal_init(ks[0], (d, ql), dtype=dt),
            "q_norm": _norm_params(ql),
            "wuq": normal_init(ks[1], (ql, nq * (nope + rdim)), dtype=dt),
            "wdkv": normal_init(ks[2], (d, kvl + rdim), dtype=dt),
            "kv_norm": _norm_params(kvl),
            "wukv": normal_init(ks[3], (kvl, nq * (nope + vdim)), dtype=dt),
            "wo": normal_init(ks[4], (nq * vdim, d), dtype=dt),
        }
        return p
    p = {
        "wq": normal_init(ks[0], (d, nq * hd), dtype=dt),
        "wk": normal_init(ks[1], (d, nkv * hd), dtype=dt),
        "wv": normal_init(ks[2], (d, nkv * hd), dtype=dt),
        "wo": normal_init(ks[3], (nq * hd, d), dtype=dt),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init(ks[4], (nq * hd,), dt)
        p["bk"] = zeros_init(ks[5], (nkv * hd,), dt)
        p["bv"] = zeros_init(ks[6], (nkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = _norm_params(hd)
        p["k_norm"] = _norm_params(hd)
    return p


def _apply_attn(cfg: ModelConfig, p: dict, x, positions, cache, pos):
    """x [B,S,D].  Train when cache is None, else one-token decode."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    if cfg.use_mla:
        return _apply_mla(cfg, p, x, positions, cache, pos)
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, nq, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)
    if cache is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if _bsp_active():
            # block-SP: gather the sequence ONCE here (residuals between
            # blocks stay seq-sharded) and shard heads over 'model' (GSPMD
            # pads non-divisible head counts), so the chunked-attention
            # loops below execute with zero per-chunk collectives.  KV is
            # expanded to the full query-head count FIRST — a post-shard
            # jnp.repeat over a head-sharded dim would reshard per chunk.
            # (A context-parallel variant — q seq-sharded, KV replicated —
            # was tried and REFUTED: GSPMD replicated the q-chunk compute,
            # 2x flops and more collectives; see EXPERIMENTS.md §Perf.)
            from .attention import _expand_kv
            from jax.sharding import PartitionSpec as P
            k = _expand_kv(k, nq)
            v = _expand_kv(v, nq)
            hspec = P(_bsp_dp(), None, "model", None)
            q = _constrain_to(q, hspec)
            k = _constrain_to(k, hspec)
            v = _constrain_to(v, hspec)
        if cfg.attn_window and cfg.attn_window < s:
            o = banded_attention(q, k, v, cfg.attn_window)
        else:
            o = blocked_attention(q, k, v, causal=True)
        new_cache = None
    else:
        q = rope(q, pos[None], cfg.rope_theta)
        k = rope(k, pos[None], cfg.rope_theta)
        w_len = cache["k"].shape[1]
        slot = jnp.where(w_len < 10 ** 9, pos % w_len, pos)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.full((b, 1), pos, cache["pos"].dtype), slot, axis=1)
        sc_mask_low = pos - (cfg.attn_window or 10 ** 9)
        # cpos == -1 marks a never-written slot; it must stay masked or the
        # zero keys dilute the softmax (decode != prefill).
        valid = (cpos >= 0) & (cpos <= pos) & (cpos > sc_mask_low)
        o = _decode_attn_ring(q, ck, cv, valid)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    o = o.reshape(b, s, nq * hd)
    return (o @ p["wo"]).astype(x.dtype), new_cache


def _decode_attn_ring(q, ck, cv, valid):
    """Decode attention over a (possibly ring-buffer) cache with an explicit
    per-slot validity mask.  q [B,1,Hq,hd], ck/cv [B,W,Hkv,hd]."""
    b, _, hq, hd = q.shape
    nkv = ck.shape[2]
    if nkv != hq:
        rep = hq // nkv
        ck = jnp.repeat(ck, rep, axis=2)
        cv = jnp.repeat(cv, rep, axis=2)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    sc = jnp.einsum("bohd,bshd->bhos", q.astype(jnp.float32),
                    ck.astype(jnp.float32)) * scale
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhos,bshd->bohd", w, cv.astype(jnp.float32))
    return o.astype(q.dtype)


def _apply_mla(cfg: ModelConfig, p: dict, x, positions, cache, pos):
    """DeepSeek MLA.  Train: expanded form.  Decode: absorbed/compressed."""
    b, s, d = x.shape
    nq = cfg.n_heads
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    cq = rms_norm(p["q_norm"], x @ p["wdq"])
    qall = (cq @ p["wuq"]).reshape(b, s, nq, nope + rdim)
    q_nope, q_rope = qall[..., :nope], qall[..., nope:]
    dkv = x @ p["wdkv"]                       # [B,S,kvl+rdim]
    ckv = rms_norm(p["kv_norm"], dkv[..., :kvl])
    k_rope = dkv[..., kvl:].reshape(b, s, 1, rdim)
    if cache is None:
        q_rope = rope(q_rope, positions, cfg.rope_theta)
        k_rope_r = rope(k_rope, positions, cfg.rope_theta)
        kv = (ckv @ p["wukv"]).reshape(b, s, nq, nope + vdim)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope_r, (b, s, nq, rdim))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        if _bsp_active():
            # block-SP (see _apply_attn): seq gathered once, heads sharded
            # over 'model' (MLA nq=128 divides a 16-way axis exactly).
            from jax.sharding import PartitionSpec as P
            hspec = P(_bsp_dp(), None, "model", None)
            q = _constrain_to(q, hspec)
            k = _constrain_to(k, hspec)
            v = _constrain_to(v, hspec)
        o = blocked_attention(q, k, v, causal=True)
        o = o.reshape(b, s, nq * vdim)
        return (o @ p["wo"]).astype(x.dtype), None
    # --- absorbed decode: cache (ckv, k_rope), score in compressed space ---
    q_rope = rope(q_rope, pos[None], cfg.rope_theta)
    k_rope_r = rope(k_rope, pos[None], cfg.rope_theta)
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv.astype(cache["ckv"].dtype), pos, axis=1)
    r_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["kr"], k_rope_r[:, :, 0].astype(cache["kr"].dtype), pos, axis=1)
    wukv = p["wukv"].reshape(kvl, nq, nope + vdim)
    w_uk = wukv[..., :nope]                   # [kvl, nq, nope]
    w_uv = wukv[..., nope:]                   # [kvl, nq, vdim]
    q_abs = jnp.einsum("bohn,lhn->bohl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))          # [B,1,nq,kvl]
    sc = jnp.einsum("bohl,bsl->bhos", q_abs,
                    c_cache.astype(jnp.float32))
    sc += jnp.einsum("bohr,bsr->bhos", q_rope.astype(jnp.float32),
                     r_cache.astype(jnp.float32))
    sc *= 1.0 / jnp.sqrt(nope + rdim)
    slen = c_cache.shape[1]
    spos = jnp.arange(slen)[None, None, None, :]
    mask = spos <= pos
    if cfg.attn_window:  # +swa long-context variant
        mask &= spos > (pos - cfg.attn_window)
    sc = jnp.where(mask, sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    ctx_c = jnp.einsum("bhos,bsl->bohl", w, c_cache.astype(jnp.float32))
    o = jnp.einsum("bohl,lhv->bohv", ctx_c, w_uv.astype(jnp.float32))
    o = o.reshape(b, s, nq * vdim).astype(x.dtype)
    return o @ p["wo"], {"ckv": c_cache, "kr": r_cache}


# ---------------------------------------------------------------------------
# FFN sub-blocks
# ---------------------------------------------------------------------------

def _init_ffn(key, cfg: ModelConfig, d_ff: int) -> dict:
    d, dt = cfg.d_model, _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.ffn_act == "swiglu":
        return {"wg": normal_init(k1, (d, d_ff), dtype=dt),
                "wu": normal_init(k2, (d, d_ff), dtype=dt),
                "wd": normal_init(k3, (d_ff, d), dtype=dt)}
    return {"wi": normal_init(k1, (d, d_ff), dtype=dt),
            "bi": zeros_init(k2, (d_ff,), dt),
            "wd": normal_init(k3, (d_ff, d), dtype=dt)}


def _apply_ffn(cfg: ModelConfig, p: dict, x):
    if cfg.ffn_act == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    return jax.nn.gelu(x @ p["wi"] + p["bi"]) @ p["wd"]


def _init_moe(key, cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, _dtype(cfg)
    e = cfg.n_experts
    fe = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {"router": normal_init(ks[0], (d, e), stddev=0.02, dtype=jnp.float32),
         "wg": normal_init(ks[1], (e, d, fe), dtype=dt),
         "wu": normal_init(ks[2], (e, d, fe), dtype=dt),
         "wd": normal_init(ks[3], (e, fe, d), dtype=dt)}
    if cfg.n_shared_experts:
        p["shared"] = _init_ffn(ks[4], cfg, fe * cfg.n_shared_experts)
    return p


def _apply_moe(cfg: ModelConfig, p: dict, x, capacity_factor: float = 1.25):
    """Top-k MoE with sort-based capacity dispatch.

    Tokens are routed to ``[E, cap]`` expert buffers via a stable sort on
    expert id (no [T, E, cap] one-hot — that is infeasible at E=256);
    overflowing tokens are dropped (residual path keeps them).  Runs on
    the *local* token block when wrapped in partial-manual shard_map (see
    ``_moe_dispatch``); the expert-dim einsums stay GSPMD-sharded over the
    'model' axis (expert parallelism).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    gates = jax.nn.softmax(logits, -1)                   # [T,E]
    topv, topi = jax.lax.top_k(gates, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    cap = max(1, int(t * k / e * capacity_factor))

    eid = topi.reshape(-1)                               # [T*k]
    gate = topv.reshape(-1)
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(eid, stable=True)
    eid_s, tok_s, gate_s = eid[order], tok[order], gate[order]
    counts = jnp.bincount(eid, length=e)                 # [E]
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[eid_s].astype(jnp.int32)
    keep = rank < cap
    slot = jnp.where(keep, eid_s * cap + rank, e * cap)  # sentinel row

    rows = jnp.where(keep[:, None], xf[tok_s], 0)
    buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].add(rows)
    buf3 = buf[: e * cap].reshape(e, cap, d)
    hg = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf3, p["wg"]))
    hu = jnp.einsum("ecd,edf->ecf", buf3, p["wu"])
    ho = jnp.einsum("ecf,efd->ecd", hg * hu, p["wd"])    # [E,cap,D]
    out_rows = jnp.concatenate(
        [ho.reshape(e * cap, d), jnp.zeros((1, d), ho.dtype)], axis=0)
    vals = jnp.where(keep, gate_s, 0.0)[:, None].astype(x.dtype) * out_rows[slot]
    out = jnp.zeros((t, d), x.dtype).at[tok_s].add(vals)
    if cfg.n_shared_experts:
        out = out + _apply_ffn(cfg, p["shared"], xf)
    # Switch-style load-balance aux loss
    me = gates.mean(0)
    frac = counts.astype(jnp.float32) / max(1, t * k)
    aux = (me * frac).sum() * e
    return out.reshape(b, s, d).astype(x.dtype), aux


def _dp_only_spec(act_spec, dp: tuple[str, ...], rank: int = 3):
    """Strip non-dp axes from an activation spec (partial-manual shard_map
    in_specs may only name manual axes)."""
    from jax.sharding import PartitionSpec as P
    entries = (list(act_spec) + [None] * rank)[:rank]

    def keep(e):
        if e is None:
            return None
        axes = e if isinstance(e, tuple) else (e,)
        return e if set(axes) <= set(dp) else None

    return P(*(keep(e) for e in entries))


def _moe_dispatch(cfg: ModelConfig, p: dict, h):
    """MoE entry point.

    - No SpmdCtx (single-device tests): plain whole-batch dispatch.
    - E % model_axis == 0 (deepseek-class): fully-manual **expert
      parallelism** — tokens all_to_all to expert owners over 'model',
      expert weights sharded [E/model, D/data, F] (gathered over 'data'
      per layer, FSDP-style).
    - otherwise (mixtral-class): per-dp-group dispatch under
      partial-manual shard_map; expert FFN dims stay GSPMD-sharded over
      'model' (tensor-parallel experts).
    """
    from .spmd import current_spmd
    from jax.sharding import PartitionSpec as P

    ctx = current_spmd()
    if ctx is None or not ctx.moe_group:
        return _apply_moe(cfg, p, h)

    # Manual dispatch needs the token axes to split evenly over the mesh
    # axes named in the activation spec; a 1-token decode step against a
    # sequence-sharded spec (long_500k serve_step) cannot, so it falls back
    # to whole-batch dispatch under GSPMD (expert weights stay sharded).
    sizes = dict(ctx.mesh.shape)
    for dim, ax in zip(h.shape, tuple(ctx.act_spec) + (None,) * h.ndim):
        axes = ax if isinstance(ax, tuple) else (ax,) if ax else ()
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        if n > 1 and dim % n != 0:
            return _apply_moe(cfg, p, h)

    dp = ctx.dp_axes
    m_size = ctx.mesh.shape.get("model", 1)
    if m_size > 1 and cfg.n_experts % m_size == 0:
        return _apply_moe_ep(cfg, p, h, ctx)

    act_spec = _dp_only_spec(ctx.act_spec, dp)

    def local(h_blk, p_moe):
        out, aux = _apply_moe(cfg, p_moe, h_blk)
        return out, jax.lax.pmean(aux, dp)

    fn = jax.shard_map(local, mesh=ctx.mesh,
                       in_specs=(act_spec, P()),
                       out_specs=(act_spec, P()),
                       axis_names=set(dp))
    return fn(h, p)


def _apply_moe_ep(cfg: ModelConfig, p: dict, x, ctx,
                  capacity_factor: float = 1.25):
    """Fully-manual expert-parallel MoE (GShard-style 2D: DP x EP).

    Every device routes its local tokens to expert owners with one
    ``all_to_all`` over 'model', computes its E/model experts on the
    received rows (weights gathered over 'data'), and returns results
    with the reverse ``all_to_all``.
    """
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    m_size = mesh.shape["model"]
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // m_size
    act_spec = P(*(list(ctx.act_spec) + [None] * 3)[:3])
    data_axes = tuple(a for a in ("data",) if a in mesh.axis_names)

    def local(x_blk, p_moe):
        b, s, d = x_blk.shape
        t = b * s
        xf = x_blk.reshape(t, d)
        logits = xf.astype(jnp.float32) @ p_moe["router"]
        gates = jax.nn.softmax(logits, -1)
        topv, topi = jax.lax.top_k(gates, k)
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        cap = max(1, int(t * k / e * capacity_factor))

        eid = topi.reshape(-1)
        gate = topv.reshape(-1)
        tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
        order = jnp.argsort(eid, stable=True)
        eid_s, tok_s, gate_s = eid[order], tok[order], gate[order]
        counts = jnp.bincount(eid, length=e)
        starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        rank = (jnp.arange(t * k, dtype=jnp.int32)
                - starts[eid_s].astype(jnp.int32))
        keep = rank < cap
        slot = jnp.where(keep, eid_s * cap + rank, e * cap)
        rows = jnp.where(keep[:, None], xf[tok_s], 0)
        buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].add(rows)

        # ship rows to expert owners: [m, E_loc*cap, D] over 'model'
        send = buf[: e * cap].reshape(m_size, e_loc * cap, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        rows_by_e = recv.reshape(m_size, e_loc, cap, d).transpose(
            1, 0, 2, 3).reshape(e_loc, m_size * cap, d)

        # FSDP gather of this group's expert weights over 'data'
        wg = p_moe["wg"]
        wu = p_moe["wu"]
        wd = p_moe["wd"]
        if data_axes:
            wg = jax.lax.all_gather(wg, data_axes, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, data_axes, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, data_axes, axis=2, tiled=True)
        hg = jax.nn.silu(jnp.einsum("egd,edf->egf", rows_by_e, wg))
        hu = jnp.einsum("egd,edf->egf", rows_by_e, wu)
        ho = jnp.einsum("egf,efd->egd", hg * hu, wd)

        back = ho.reshape(e_loc, m_size, cap, d).transpose(1, 0, 2, 3)
        back = back.reshape(m_size, e_loc * cap, d)
        got = jax.lax.all_to_all(back, "model", split_axis=0,
                                 concat_axis=0, tiled=True)
        out_rows = jnp.concatenate(
            [got.reshape(e * cap, d), jnp.zeros((1, d), got.dtype)], axis=0)
        vals = (jnp.where(keep, gate_s, 0.0)[:, None].astype(x_blk.dtype)
                * out_rows[slot])
        out = jnp.zeros((t, d), x_blk.dtype).at[tok_s].add(vals)

        if cfg.n_shared_experts:
            # hand-written tensor-parallel shared expert (F over 'model')
            sh = p_moe["shared"]
            hg_s = jax.nn.silu(xf @ sh["wg"]) * (xf @ sh["wu"])
            out = out + jax.lax.psum(hg_s @ sh["wd"], "model")

        me = gates.mean(0)
        frac = counts.astype(jnp.float32) / max(1, t * k)
        aux = (me * frac).sum() * e
        # aux only varies over the axes named in act_spec (it is a pure
        # function of x_blk and the replicated router); pvary the rest so
        # the full-mesh pmean type-checks under shard_map's VMA rules.
        named = set()
        for ax in tuple(act_spec):
            if ax is not None:
                named.update(ax if isinstance(ax, tuple) else (ax,))
        missing = tuple(a for a in mesh.axis_names if a not in named)
        if missing:
            aux = jax.lax.pvary(aux, missing)
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return out.reshape(b, s, d), aux

    router_spec = P()
    w_in_spec = P("model", "data" if data_axes else None, None)
    wd_spec = P("model", None, "data" if data_axes else None)
    shared_spec = {"wg": P(None, "model"), "wu": P(None, "model"),
                   "wd": P("model", None)} if cfg.n_shared_experts else None
    p_specs = {"router": router_spec, "wg": w_in_spec, "wu": w_in_spec,
               "wd": wd_spec}
    if shared_spec:
        p_specs["shared"] = shared_spec
    # When act_spec leaves some mesh axis unused (decode: tokens are
    # replicated over 'model'), every rank along that axis computes the
    # identical dispatch, so the output IS replicated — but the VMA system
    # cannot infer that through all_to_all; disable the static check then.
    named = set()
    for ax in tuple(act_spec):
        if ax is not None:
            named.update(ax if isinstance(ax, tuple) else (ax,))
    covers_mesh = named >= set(mesh.axis_names)
    fn = jax.shard_map(local, mesh=mesh,
                       in_specs=(act_spec, p_specs),
                       out_specs=(act_spec, P()),
                       axis_names=set(mesh.axis_names),
                       check_vma=covers_mesh)
    return fn(x, p)


# ---------------------------------------------------------------------------
# mLSTM / sLSTM / hybrid sub-blocks
# ---------------------------------------------------------------------------

def _init_mlstm(key, cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, _dtype(cfg)
    di = d * cfg.ssm_expand
    ks = jax.random.split(key, 6)
    return {"wq": normal_init(ks[0], (d, di), dtype=dt),
            "wk": normal_init(ks[1], (d, di), dtype=dt),
            "wv": normal_init(ks[2], (d, di), dtype=dt),
            "wz": normal_init(ks[3], (d, di), dtype=dt),
            "wif": normal_init(ks[4], (d, 2 * cfg.n_heads), dtype=jnp.float32),
            "wd": normal_init(ks[5], (di, d), dtype=dt)}


def _apply_mlstm(cfg: ModelConfig, p: dict, x, cache):
    b, s, d = x.shape
    h = cfg.n_heads
    di = d * cfg.ssm_expand
    dh = di // h
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, h, dh)
    v = (x @ p["wv"]).reshape(b, s, h, dh)
    gif = x.astype(jnp.float32) @ p["wif"]
    ig, fg = gif[..., :h], gif[..., h:]
    state = cache if cache is not None else None
    chunk = 1 if (cache is not None and s == 1) else 128
    y, st = mlstm_chunked(q, k, v, ig, fg, state=state, chunk=chunk)
    y = y.reshape(b, s, di) * jax.nn.silu(x @ p["wz"])
    out = (y @ p["wd"]).astype(x.dtype)
    return out, (st if cache is not None else None)


def _init_slstm(key, cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, _dtype(cfg)
    h = cfg.n_heads
    dh = d // h
    k1, k2 = jax.random.split(key)
    return {"wg": normal_init(k1, (d, 4 * d), dtype=jnp.float32),
            "r": normal_init(k2, (h, 4, dh, dh), stddev=0.05,
                             dtype=jnp.float32)}


def _apply_slstm(cfg: ModelConfig, p: dict, x, cache):
    b, s, d = x.shape
    gx = x.astype(jnp.float32) @ p["wg"]
    if cache is not None and s == 1:
        y, st = slstm_step(cache, gx[:, 0], p["r"], cfg.n_heads)
        return y[:, None].astype(x.dtype), st
    y, st = slstm_scan(gx, p["r"], cfg.n_heads, state=cache)
    return y.astype(x.dtype), (st if cache is not None else None)


def _init_mamba(key, cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, _dtype(cfg)
    di = d * cfg.ssm_expand
    n = cfg.ssm_state
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 7)
    a_init = jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None],
                              (di, 1)))
    return {"win": normal_init(ks[0], (d, 2 * di), dtype=dt),
            "conv": normal_init(ks[1], (cfg.ssm_conv, di), dtype=dt),
            "conv_b": zeros_init(ks[2], (di,), dt),
            "wbc": normal_init(ks[3], (di, 2 * n), dtype=dt),
            "wdt1": normal_init(ks[4], (di, dt_rank), dtype=dt),
            "wdt2": normal_init(ks[5], (dt_rank, di), dtype=dt),
            "dt_b": jnp.full((di,), -4.6, jnp.float32),   # softplus ~ 0.01
            "a_log": a_init,
            "d_skip": jnp.ones((di,), jnp.float32),
            "wout": normal_init(ks[6], (di, d), dtype=dt)}


def _causal_conv(x, kernel, bias, conv_state=None):
    """Depthwise causal conv1d.  x [B,S,DI], kernel [CW,DI]."""
    cw = kernel.shape[0]
    if conv_state is not None:
        ctx = jnp.concatenate([conv_state, x], axis=1)    # [B,CW-1+S,DI]
    else:
        ctx = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(ctx[:, i:i + x.shape[1]] * kernel[i] for i in range(cw))
    new_state = ctx[:, -(cw - 1):] if cw > 1 else ctx[:, :0]
    return out + bias, new_state


def _apply_mamba(cfg: ModelConfig, p: dict, x, cache):
    b, s, d = x.shape
    di = d * cfg.ssm_expand
    n = cfg.ssm_state
    xz = x @ p["win"]
    xi, z = xz[..., :di], xz[..., di:]
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xi, p["conv"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    if _bsp_active() and s > 1:
        # block-SP: the selective scan's recurrence is elementwise in DI, so
        # pin every scan input to a seq-FULL, DI-over-'model' layout — the
        # 4096-step time loop then runs with zero per-step collectives.
        from jax.sharding import PartitionSpec as P
        dspec = P(_bsp_dp(), None, "model")
        xc = _constrain_to(xc, dspec)
        z = _constrain_to(z, dspec)
    bc = xc @ p["wbc"]
    b_in, c_in = bc[..., :n], bc[..., n:]
    delta = jax.nn.softplus((xc @ p["wdt1"]) @ p["wdt2"]
                            + p["dt_b"]).astype(jnp.float32)
    if _bsp_active() and s > 1:
        from jax.sharding import PartitionSpec as P
        dspec = P(_bsp_dp(), None, "model")
        rspec = P(_bsp_dp(), None, None)
        delta = _constrain_to(delta, dspec)
        b_in = _constrain_to(b_in, rspec)
        c_in = _constrain_to(c_in, rspec)
    if cache is not None and s == 1:
        y, h = selective_scan_step(cache["h"], xc[:, 0], delta[:, 0],
                                   p["a_log"], b_in[:, 0], c_in[:, 0],
                                   p["d_skip"])
        y = y[:, None]
    else:
        h0 = cache["h"] if cache is not None else None
        y, h = selective_scan(xc, delta, p["a_log"], b_in, c_in,
                              p["d_skip"], h0=h0)
    y = y * jax.nn.silu(z)
    out = (y @ p["wout"]).astype(x.dtype)
    new_cache = ({"h": h, "conv": new_conv} if cache is not None else None)
    return out, new_cache


# ---------------------------------------------------------------------------
# Block assembly
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind == "attn_dense":
        return {"norm1": _norm_params(d), "attn": _init_attn(ks[0], cfg),
                "norm2": _norm_params(d),
                "ffn": _init_ffn(ks[1], cfg, cfg.d_ff)}
    if kind == "attn_moe":
        return {"norm1": _norm_params(d), "attn": _init_attn(ks[0], cfg),
                "norm2": _norm_params(d), "moe": _init_moe(ks[1], cfg)}
    if kind == "mlstm":
        return {"norm1": _norm_params(d), "mlstm": _init_mlstm(ks[0], cfg)}
    if kind == "slstm":
        return {"norm1": _norm_params(d), "slstm": _init_slstm(ks[0], cfg)}
    if kind == "hybrid":
        return {"norm1": _norm_params(d), "attn": _init_attn(ks[0], cfg),
                "mamba": _init_mamba(ks[1], cfg),
                "norm2": _norm_params(d),
                "ffn": _init_ffn(ks[2], cfg, cfg.d_ff)}
    raise ValueError(kind)


def apply_block(cfg: ModelConfig, kind: str, p: dict, x, positions,
                cache=None, pos=None):
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn_dense", "attn_moe"):
        a, c_attn = _apply_attn(cfg, p["attn"], norm_apply(cfg, p["norm1"], x),
                                positions, cache, pos)
        x = x + a
        h = norm_apply(cfg, p["norm2"], x)
        if kind == "attn_dense":
            x = x + _apply_ffn(cfg, p["ffn"], h)
        else:
            mo, aux = _moe_dispatch(cfg, p["moe"], h)
            x = x + mo
        return x, c_attn, aux
    if kind == "mlstm":
        y, st = _apply_mlstm(cfg, p["mlstm"], norm_apply(cfg, p["norm1"], x),
                             cache)
        return x + y, st, aux
    if kind == "slstm":
        y, st = _apply_slstm(cfg, p["slstm"], norm_apply(cfg, p["norm1"], x),
                             cache)
        return x + y, st, aux
    if kind == "hybrid":
        h = norm_apply(cfg, p["norm1"], x)
        c_attn = cache["attn"] if cache is not None else None
        c_ssm = cache["ssm"] if cache is not None else None
        a, c_attn2 = _apply_attn(cfg, p["attn"], h, positions, c_attn, pos)
        m, c_ssm2 = _apply_mamba(cfg, p["mamba"], h, c_ssm)
        x = x + 0.5 * (a + m)
        x = x + _apply_ffn(cfg, p["ffn"], norm_apply(cfg, p["norm2"], x))
        new_cache = ({"attn": c_attn2, "ssm": c_ssm2}
                     if cache is not None else None)
        return x, new_cache, aux
    raise ValueError(kind)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    """Zeroed decode cache for one block."""
    dt = _dtype(cfg)
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    di = d * cfg.ssm_expand

    def attn_cache():
        if cfg.use_mla:
            return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
                    "kr": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dt)}
        w = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
        return {"k": jnp.zeros((batch, w, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, w, cfg.n_kv_heads, hd), dt),
                "pos": jnp.full((batch, w), -1, jnp.int32)}

    if kind in ("attn_dense", "attn_moe"):
        return attn_cache()
    if kind == "mlstm":
        h = cfg.n_heads
        dh = di // h
        return MlstmState(c=jnp.zeros((batch, h, dh, dh), jnp.float32),
                          n=jnp.zeros((batch, h, dh), jnp.float32))
    if kind == "slstm":
        z = jnp.zeros((batch, d), jnp.float32)
        return SlstmState(c=z, n=z, h=z, m=z)
    if kind == "hybrid":
        return {"attn": attn_cache(),
                "ssm": {"h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
                        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dt)}}
    raise ValueError(kind)
