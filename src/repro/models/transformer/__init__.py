from .config import ModelConfig
from .model import (init_model, forward, loss_fn, train_step_fn,
                    init_decode_cache, serve_step, param_count)
from .spmd import SpmdCtx, use_spmd, current_spmd

__all__ = ["ModelConfig", "init_model", "forward", "loss_fn",
           "train_step_fn", "init_decode_cache", "serve_step", "param_count",
           "SpmdCtx", "use_spmd", "current_spmd"]
