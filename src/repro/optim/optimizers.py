"""Hand-rolled pytree optimizers (SGD / Adam / AdamW) + grad utilities."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "adam", "adamw", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """(init, update) pair; update returns (new_params, new_state)."""
    init: Callable
    update: Callable


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, step=None):
        del step
        if momentum == 0.0:
            new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new_params, ()
        new_vel = jax.tree.map(lambda v, g: momentum * v + g, state, grads)
        new_params = jax.tree.map(lambda p, v: p - lr * v, params, new_vel)
        return new_params, new_vel

    return Optimizer(init, update)


class _AdamState(NamedTuple):
    mu: object
    nu: object
    count: jnp.ndarray


def _adam_like(lr, b1, b2, eps, weight_decay) -> Optimizer:
    def init(params):
        return _AdamState(mu=jax.tree.map(jnp.zeros_like, params),
                          nu=jax.tree.map(jnp.zeros_like, params),
                          count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, step=None):
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(p, m, v):
            step_ = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step_ = step_ + lr * weight_decay * p
            return p - step_

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, _AdamState(mu, nu, count)

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
         ) -> Optimizer:
    return _adam_like(lr, b1, b2, eps, weight_decay=0.0)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    return _adam_like(lr, b1, b2, eps, weight_decay)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm
