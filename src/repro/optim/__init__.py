from .optimizers import Optimizer, sgd, adam, adamw, clip_by_global_norm

__all__ = ["Optimizer", "sgd", "adam", "adamw", "clip_by_global_norm"]
