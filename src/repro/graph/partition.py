"""Graph partitioners and halo construction.

Three partitioners, mirroring the paper's experimental setup (§3.4 uses
METIS and Random; §2.4 also discusses streaming partitioners):

- ``random_partition``  — uniform random vertex assignment (paper baseline)
- ``fennel_partition``  — single-pass streaming with locality-balance objective
- ``metis_partition``   — METIS-like multilevel: heavy-edge-matching
  coarsening, greedy initial partition, boundary Kernighan-Lin refinement.

``build_partition`` then materialises, per part: inner vertices, k-hop halo
sets, local CSR (inner rows x (inner+halo) cols) and the ownership maps the
distributed runtime needs.  Vertex-centric (edge-cut) partitioning with halo
retention, as in paper Fig. 2.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .graph import Graph, csr_from_edges

__all__ = [
    "Partition", "PartitionSet", "random_partition", "fennel_partition",
    "metis_partition", "build_partition", "edge_cut",
]


def random_partition(g: Graph, parts: int, seed: int = 0,
                     weights: Sequence[float] | None = None) -> np.ndarray:
    """Random assignment, optionally with target fractions per part."""
    rng = np.random.default_rng(seed)
    if weights is None:
        return rng.integers(0, parts, size=g.num_nodes).astype(np.int32)
    w = np.asarray(weights, dtype=np.float64)
    w = w / w.sum()
    return rng.choice(parts, size=g.num_nodes, p=w).astype(np.int32)


def fennel_partition(g: Graph, parts: int, seed: int = 0, gamma: float = 1.5,
                     weights: Sequence[float] | None = None) -> np.ndarray:
    """Fennel streaming partitioner (Tsourakakis et al., 2014).

    Greedy per-vertex placement maximising |neighbours in part| - penalty,
    with the balance penalty alpha * gamma * (size)^(gamma-1), optionally
    scaled by per-part capacity weights (used by RAPA's capability-aware
    pre-partition).
    """
    rng = np.random.default_rng(seed)
    n, m = g.num_nodes, g.num_edges
    w = np.ones(parts) / parts if weights is None else np.asarray(weights, float) / np.sum(weights)
    alpha = np.sqrt(parts) * m / max(1.0, n ** gamma)
    assign = -np.ones(n, dtype=np.int32)
    sizes = np.zeros(parts, dtype=np.int64)
    order = rng.permutation(n)
    cap = w * n
    for v in order:
        nbr = g.neighbors(v)
        nb_assign = assign[nbr]
        gain = np.zeros(parts)
        valid = nb_assign[nb_assign >= 0]
        if valid.size:
            np.add.at(gain, valid, 1.0)
        # capacity-normalised balance penalty
        rel = sizes / np.maximum(cap, 1.0)
        penalty = alpha * gamma * rel ** (gamma - 1.0)
        p = int(np.argmax(gain - penalty))
        assign[v] = p
        sizes[p] += 1
    if weights is None:
        return assign
    return _rebalance(g, assign, parts, w * n)


def _heavy_edge_matching(g: Graph, rng: np.random.Generator) -> np.ndarray:
    """Return coarse-node id per node via randomized heavy-edge matching."""
    n = g.num_nodes
    match = -np.ones(n, dtype=np.int64)
    order = rng.permutation(n)
    for v in order:
        if match[v] >= 0:
            continue
        best = -1
        for u in g.neighbors(v):
            if match[u] < 0 and u != v:
                best = u
                break
        if best >= 0:
            match[v] = best
            match[best] = v
        else:
            match[v] = v
    # assign coarse ids
    coarse = -np.ones(n, dtype=np.int64)
    nxt = 0
    for v in range(n):
        if coarse[v] < 0:
            coarse[v] = nxt
            coarse[match[v]] = nxt
            nxt += 1
    return coarse


def _coarsen(g: Graph, coarse: np.ndarray) -> Graph:
    src, dst = g.edges()
    cs, cd = coarse[src], coarse[dst]
    keep = cs != cd
    nc = int(coarse.max()) + 1
    return csr_from_edges(cs[keep], cd[keep], nc, dedup=True)


def _greedy_grow(g: Graph, parts: int, rng: np.random.Generator,
                 weights: np.ndarray) -> np.ndarray:
    """Greedy BFS region growing for the initial (coarsest) partition."""
    n = g.num_nodes
    assign = -np.ones(n, dtype=np.int32)
    target = weights * n
    sizes = np.zeros(parts)
    seeds = rng.choice(n, size=min(parts, n), replace=False)
    from collections import deque
    queues = [deque([s]) for s in seeds]
    for p, s in enumerate(seeds):
        assign[s] = p
        sizes[p] += 1
    active = True
    while active:
        active = False
        for p in range(min(parts, n)):
            if sizes[p] >= target[p]:
                continue
            q = queues[p]
            while q:
                v = q.popleft()
                placed = False
                for u in g.neighbors(v):
                    if assign[u] < 0:
                        assign[u] = p
                        sizes[p] += 1
                        q.append(u)
                        placed = True
                        active = True
                        break
                if placed:
                    break
    # orphans -> least loaded (relative to target)
    for v in np.where(assign < 0)[0]:
        p = int(np.argmin(sizes / np.maximum(target, 1e-9)))
        assign[v] = p
        sizes[p] += 1
    return assign


def _refine(g: Graph, assign: np.ndarray, parts: int, weights: np.ndarray,
            passes: int = 3, imbalance: float = 1.05) -> np.ndarray:
    """Boundary refinement (KL/FM-style single-vertex moves)."""
    assign = assign.copy()
    n = g.num_nodes
    target = weights * n
    sizes = np.bincount(assign, minlength=parts).astype(np.float64)
    for _ in range(passes):
        moved = 0
        src, dst = g.edges()
        boundary = np.unique(src[assign[src] != assign[dst]])
        for v in boundary:
            nbr = g.neighbors(v)
            if nbr.size == 0:
                continue
            counts = np.bincount(assign[nbr], minlength=parts)
            cur = assign[v]
            best = int(np.argmax(counts))
            if best == cur or counts[best] <= counts[cur]:
                continue
            if sizes[best] + 1 > imbalance * target[best]:
                continue
            assign[v] = best
            sizes[cur] -= 1
            sizes[best] += 1
            moved += 1
        if moved == 0:
            break
    return assign


def _rebalance(g: Graph, assign: np.ndarray, parts: int, target: np.ndarray,
               imbalance: float = 1.05, passes: int = 8) -> np.ndarray:
    """Enforce per-part size caps ``imbalance * target`` by migrating the
    least internally-connected vertices of overfull parts into the
    highest-affinity part with room.

    Greedy growth and KL refinement only *avoid* overfilling a part — they
    never shrink one that already overshot, so without this pass the
    partitioners track capacity ``weights`` loosely (one part can absorb
    half the graph), which defeats resource-aware uneven partitioning.

    Runs only on the explicitly-weighted path: ``weights=None`` callers
    keep the historical (balanced) partitioner output unchanged.
    """
    assign = assign.copy()
    n = g.num_nodes
    cap = np.maximum(imbalance * target, 1.0)
    for _ in range(passes):
        sizes = np.bincount(assign, minlength=parts).astype(np.float64)
        over = np.where(sizes > cap)[0]
        if over.size == 0:
            break
        # vertex -> part affinity (undirected edge counts), one snapshot
        # per pass: stale within the pass, rebuilt between passes
        src, dst = g.edges()
        cnt = np.zeros((n, parts), np.float64)
        np.add.at(cnt, (src, assign[dst]), 1.0)
        np.add.at(cnt, (dst, assign[src]), 1.0)
        moved = 0
        for po in over:
            members = np.where(assign == po)[0]
            order = members[np.argsort(cnt[members, po], kind="stable")]
            for v in order:
                if sizes[po] <= cap[po]:
                    break
                room = np.where(sizes + 1.0 <= cap)[0]
                room = room[room != po]
                if room.size == 0:
                    break
                dest = room[np.argmax(cnt[v, room])]
                assign[v] = dest
                sizes[po] -= 1.0
                sizes[dest] += 1.0
                moved += 1
        if moved == 0:
            break
    return assign.astype(np.int32)


def metis_partition(g: Graph, parts: int, seed: int = 0,
                    weights: Sequence[float] | None = None,
                    coarsen_to: int = 256) -> np.ndarray:
    """METIS-like multilevel partitioner (coarsen -> initial -> uncoarsen+refine)."""
    rng = np.random.default_rng(seed)
    w = np.ones(parts) / parts if weights is None else np.asarray(weights, float) / np.sum(weights)
    levels: list[tuple[Graph, np.ndarray]] = []
    cur = g
    while cur.num_nodes > max(coarsen_to, parts * 8):
        coarse = _heavy_edge_matching(cur, rng)
        nxt = _coarsen(cur, coarse)
        if nxt.num_nodes >= cur.num_nodes * 0.95:  # matching stalled
            break
        levels.append((cur, coarse))
        cur = nxt
    assign = _greedy_grow(cur, parts, rng, w)
    assign = _refine(cur, assign, parts, w)
    for fine, coarse in reversed(levels):
        assign = assign[coarse].astype(np.int32)
        assign = _refine(fine, assign, parts, w)
    if weights is None:
        return assign.astype(np.int32)
    assign = _rebalance(g, assign, parts, w * g.num_nodes)
    return _refine(g, assign, parts, w).astype(np.int32)


def edge_cut(g: Graph, assign: np.ndarray) -> int:
    """Unique inter-partition edges; each bidirectional pair counted once
    (paper Fig. 5 definition)."""
    src, dst = g.edges()
    cut = assign[src] != assign[dst]
    a = np.minimum(src[cut], dst[cut])
    b = np.maximum(src[cut], dst[cut])
    return int(np.unique(a.astype(np.int64) * g.num_nodes + b).shape[0])


# ---------------------------------------------------------------------------
# Partition materialisation with halo vertices
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Partition:
    """One worker's subgraph.

    Local vertex ids: ``[0, n_inner)`` are inner vertices, ``[n_inner,
    n_inner+n_halo)`` are halo vertices.  ``local_graph`` stores edges whose
    *destination* is an inner vertex (all information needed to aggregate
    into inner vertices); sources may be inner or halo.
    """
    part_id: int
    inner_nodes: np.ndarray       # [n_inner] global ids
    halo_nodes: np.ndarray        # [n_halo]  global ids (sorted)
    halo_owner: np.ndarray        # [n_halo]  owning part per halo vertex
    local_graph: Graph            # CSR over n_inner+n_halo nodes
    global_to_local: dict         # global id -> local id

    @property
    def n_inner(self) -> int:
        return int(self.inner_nodes.shape[0])

    @property
    def n_halo(self) -> int:
        return int(self.halo_nodes.shape[0])

    @property
    def n_local(self) -> int:
        return self.n_inner + self.n_halo

    def local_ids(self, global_ids: np.ndarray) -> np.ndarray:
        return np.array([self.global_to_local[int(v)] for v in global_ids],
                        dtype=np.int64)


@dataclasses.dataclass
class PartitionSet:
    """All partitions of a graph plus global bookkeeping."""
    graph: Graph
    assign: np.ndarray            # [n] part id per vertex
    parts: list[Partition]
    hops: int

    @property
    def num_parts(self) -> int:
        return len(self.parts)

    def halo_union(self) -> np.ndarray:
        """H = union of all partitions' halo sets (global ids)."""
        if not self.parts:
            return np.zeros(0, np.int64)
        return np.unique(np.concatenate([p.halo_nodes for p in self.parts]))

    def overlap_ratio(self) -> np.ndarray:
        """Paper Eq. 2: R(v) = #partitions whose halo set contains v, for all v."""
        r = np.zeros(self.graph.num_nodes, dtype=np.int32)
        for p in self.parts:
            r[p.halo_nodes] += 1
        return r

    def total_halo(self) -> int:
        return int(sum(p.n_halo for p in self.parts))

    def total_inner(self) -> int:
        return int(sum(p.n_inner for p in self.parts))


def _k_hop_halo(g_rev: Graph, inner: np.ndarray, inner_mask: np.ndarray,
                hops: int) -> np.ndarray:
    """Vertices within `hops` reverse-hops of `inner` that are not inner.

    Aggregation at an inner vertex needs its in-neighbours; stacking L layers
    needs the L-hop in-neighbourhood (paper Obs. 1 varies `hops`).
    """
    indptr, indices = g_rev.indptr, g_rev.indices
    frontier = np.asarray(inner, dtype=np.int64)
    seen = inner_mask.copy()
    halo: list[np.ndarray] = []
    for _ in range(hops):
        if frontier.size == 0:
            break
        starts = indptr[frontier]
        counts = indptr[frontier + 1] - starts
        total = int(counts.sum())
        if total == 0:
            break
        # gather every frontier vertex's neighbour list in one shot:
        # idx[k] walks starts[j] .. starts[j]+counts[j]-1 for each j
        excl = np.cumsum(counts) - counts
        idx = np.repeat(starts - excl, counts) + np.arange(total)
        nbr = indices[idx].astype(np.int64)
        new = np.unique(nbr[~seen[nbr]])
        if new.size == 0:
            break
        seen[new] = True
        halo.append(new)
        frontier = new
    if not halo:
        return np.zeros(0, np.int64)
    return np.unique(np.concatenate(halo)).astype(np.int64)


def build_partition(g: Graph, assign: np.ndarray, hops: int = 1,
                    parts: int | None = None) -> PartitionSet:
    """Materialise vertex-centric partitions with k-hop halos.

    Edges kept in partition i: every edge (u -> v) with v inner to i and u in
    (inner U halo).  This is exactly what L-layer aggregation into inner
    vertices requires when halo embeddings for layers >0 are *communicated*
    (hops=1) or replicated deeper (hops=L).

    ``parts`` fixes the number of partitions explicitly; without it the
    count is inferred as ``assign.max() + 1``, which drops trailing empty
    parts (and crashes on an empty assignment) — callers that promised a
    fleet size (e.g. ``rapa.do_partition``'s ``len(profiles) ==
    ps.num_parts`` contract) must pass it.  Empty parts materialise with
    zero inner vertices, an empty halo and an empty local graph.
    """
    assign = np.asarray(assign)
    if parts is None:
        num_parts = int(assign.max()) + 1 if assign.size else 0
    else:
        num_parts = int(parts)
        if assign.size and int(assign.max()) >= num_parts:
            raise ValueError(f"assign references part {int(assign.max())} "
                             f">= parts={num_parts}")
    g_rev = g.reverse()
    src, dst = g.edges()
    w = g.edge_weight
    parts: list[Partition] = []
    for p in range(num_parts):
        inner = np.where(assign == p)[0].astype(np.int64)
        inner_mask = np.zeros(g.num_nodes, dtype=bool)
        inner_mask[inner] = True
        halo = _k_hop_halo(g_rev, inner, inner_mask, hops)
        halo_owner = assign[halo].astype(np.int32)
        local_of = -np.ones(g.num_nodes, dtype=np.int64)
        local_of[inner] = np.arange(inner.shape[0])
        local_of[halo] = inner.shape[0] + np.arange(halo.shape[0])
        # keep edges into inner vertices whose src is local (inner or halo)
        keep = inner_mask[dst] & (local_of[src] >= 0) & (assign[dst] == p)
        lsrc, ldst = local_of[src[keep]], local_of[dst[keep]]
        lw = w[keep] if w is not None else None
        n_local = inner.shape[0] + halo.shape[0]
        lg = csr_from_edges(lsrc, ldst, n_local, weight=lw)
        g2l = {int(v): int(local_of[v]) for v in np.concatenate([inner, halo])}
        parts.append(Partition(part_id=p, inner_nodes=inner, halo_nodes=halo,
                               halo_owner=halo_owner, local_graph=lg,
                               global_to_local=g2l))
    return PartitionSet(graph=g, assign=assign.astype(np.int32), parts=parts,
                        hops=hops)
