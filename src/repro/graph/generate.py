"""Synthetic graph dataset generators.

The container is offline so the paper's DGL/OGB datasets (CoraFull, Flickr,
Reddit, Yelp, AmazonProducts, ogbn-products, ...) are reproduced *in shape*:
we generate graphs whose degree distribution, clustering and scale knobs
mirror each dataset's published statistics (Table 5 of the paper), at a
configurable scale factor so tests stay fast and benchmarks stay faithful in
structure (power-law skew is what drives halo behaviour).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .graph import Graph, csr_from_edges

__all__ = ["DatasetSpec", "PAPER_DATASETS", "rmat", "sbm", "erdos_renyi",
           "make_dataset", "synth_features"]


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Shape statistics of a node-classification dataset (paper Table 5)."""
    name: str
    num_nodes: int
    num_edges: int
    feat_dim: int
    num_classes: int
    multilabel: bool = False
    generator: str = "rmat"   # rmat | sbm


# Paper Table 5 (full-scale stats; benchmarks use scale=... to shrink).
PAPER_DATASETS: dict[str, DatasetSpec] = {
    "corafull": DatasetSpec("corafull", 19_793, 126_842, 8_710, 70),
    "flickr": DatasetSpec("flickr", 89_250, 899_756, 500, 7),
    "coauthor-physics": DatasetSpec("coauthor-physics", 34_493, 495_924, 8_415, 5, generator="sbm"),
    "reddit": DatasetSpec("reddit", 232_965, 114_615_892, 602, 41),
    "yelp": DatasetSpec("yelp", 716_847, 13_954_819, 300, 100, multilabel=True),
    "amazon-products": DatasetSpec("amazon-products", 1_569_960, 264_339_468, 200, 107, multilabel=True),
    "ogbn-products": DatasetSpec("ogbn-products", 2_449_029, 61_859_140, 100, 47),
}


def rmat(num_nodes: int, num_edges: int, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> Graph:
    """R-MAT power-law generator (Graph500 parameters by default)."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(num_nodes, 2))))
    n = 1 << scale
    # Draw quadrant choices for every bit level at once.
    probs = np.array([a, b, c, 1.0 - a - b - c])
    src = np.zeros(num_edges, dtype=np.int64)
    dst = np.zeros(num_edges, dtype=np.int64)
    for level in range(scale):
        q = rng.choice(4, size=num_edges, p=probs)
        src |= ((q >> 1) & 1) << level
        dst |= (q & 1) << level
    # Permute ids to decorrelate bit structure, fold into [0, num_nodes).
    perm = rng.permutation(n)
    src, dst = perm[src] % num_nodes, perm[dst] % num_nodes
    keep = src != dst
    g = csr_from_edges(src[keep], dst[keep], num_nodes, dedup=True)
    return g.to_undirected()


def sbm(num_nodes: int, num_blocks: int, p_in: float, p_out: float,
        seed: int = 0) -> Graph:
    """Stochastic block model (clustered graphs, e.g. coauthor networks)."""
    rng = np.random.default_rng(seed)
    block = rng.integers(0, num_blocks, size=num_nodes)
    # Sample edges block-pair-wise to keep memory bounded.
    srcs, dsts = [], []
    idx_by_block = [np.where(block == b)[0] for b in range(num_blocks)]
    for bi in range(num_blocks):
        for bj in range(bi, num_blocks):
            p = p_in if bi == bj else p_out
            ni, nj = len(idx_by_block[bi]), len(idx_by_block[bj])
            if ni == 0 or nj == 0:
                continue
            m = rng.binomial(ni * nj, p)
            if m == 0:
                continue
            srcs.append(idx_by_block[bi][rng.integers(0, ni, m)])
            dsts.append(idx_by_block[bj][rng.integers(0, nj, m)])
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    keep = src != dst
    g = csr_from_edges(src[keep], dst[keep], num_nodes, dedup=True)
    return g.to_undirected()


def erdos_renyi(num_nodes: int, num_edges: int, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_nodes, num_edges)
    dst = rng.integers(0, num_nodes, num_edges)
    keep = src != dst
    return csr_from_edges(src[keep], dst[keep], num_nodes, dedup=True).to_undirected()


def make_dataset(name: str, scale: float = 1.0, seed: int = 0
                 ) -> tuple[Graph, DatasetSpec]:
    """Generate a (possibly down-scaled) synthetic replica of a paper dataset."""
    spec = PAPER_DATASETS[name]
    n = max(64, int(spec.num_nodes * scale))
    m = max(4 * n, int(spec.num_edges * scale))
    if spec.generator == "sbm":
        g = sbm(n, num_blocks=max(4, spec.num_classes), p_in=min(0.5, 4 * m / max(1, n * n)),
                p_out=min(0.1, 0.2 * m / max(1, n * n)), seed=seed)
    else:
        g = rmat(n, m, seed=seed)
    eff = DatasetSpec(spec.name, g.num_nodes, g.num_edges, spec.feat_dim,
                      spec.num_classes, spec.multilabel, spec.generator)
    return g, eff


def synth_features(g: Graph, feat_dim: int, num_classes: int, seed: int = 0,
                   class_sep: float = 1.0
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Class-conditioned gaussian features with graph-smoothed labels.

    Labels are made graph-correlated (homophily) by label-propagating random
    seeds so GNNs genuinely beat MLPs on the synthetic task — needed for the
    accuracy-preservation experiments to be meaningful.
    """
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    labels = rng.integers(0, num_classes, size=n)
    # 3 rounds of majority-ish propagation for homophily.
    src, dst = g.edges()
    for _ in range(3):
        # each node adopts label of a random in-neighbour with prob 0.7
        perm = rng.permutation(len(src))
        lab_new = labels.copy()
        lab_new[dst[perm]] = labels[src[perm]]
        take = rng.random(n) < 0.7
        labels = np.where(take, lab_new, labels)
    centers = rng.normal(0, class_sep, size=(num_classes, feat_dim))
    feats = centers[labels] + rng.normal(0, 1.0, size=(n, feat_dim))
    return feats.astype(np.float32), labels.astype(np.int32)
