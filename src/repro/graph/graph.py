"""Core graph data structures (CSR) used throughout the framework.

All host-side graph manipulation (partitioning, halo analysis, cache
planning) is done with numpy on CSR structures; device-side aggregation uses
either dense normalized adjacency (tiny graphs / tests) or blocked-ELL
packing (see :mod:`repro.kernels.ell_spmm`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["Graph", "csr_from_edges", "symmetric_normalize", "subgraph"]


@dataclasses.dataclass(frozen=True)
class Graph:
    """A directed graph in CSR format.

    ``indptr[i]:indptr[i+1]`` indexes the out-neighbours of vertex ``i`` in
    ``indices``.  ``edge_weight`` is optional (defaults to 1.0).
    """

    indptr: np.ndarray          # [n+1] int64
    indices: np.ndarray         # [m] int32 column (destination) ids
    num_nodes: int
    edge_weight: Optional[np.ndarray] = None  # [m] float32 or None

    def __post_init__(self):
        assert self.indptr.ndim == 1 and self.indptr.shape[0] == self.num_nodes + 1
        assert self.indices.ndim == 1
        assert int(self.indptr[-1]) == self.indices.shape[0]

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def in_degree(self) -> np.ndarray:
        return np.bincount(self.indices, minlength=self.num_nodes).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def edges(self) -> tuple[np.ndarray, np.ndarray]:
        """Return (src, dst) arrays."""
        src = np.repeat(np.arange(self.num_nodes, dtype=np.int32), self.out_degree())
        return src, self.indices.astype(np.int32)

    def reverse(self) -> "Graph":
        src, dst = self.edges()
        return csr_from_edges(dst, src, self.num_nodes)

    def to_undirected(self) -> "Graph":
        src, dst = self.edges()
        s = np.concatenate([src, dst])
        d = np.concatenate([dst, src])
        return csr_from_edges(s, d, self.num_nodes, dedup=True)

    def has_edge_weights(self) -> bool:
        return self.edge_weight is not None


def csr_from_edges(src: np.ndarray, dst: np.ndarray, num_nodes: int,
                   weight: Optional[np.ndarray] = None,
                   dedup: bool = False) -> Graph:
    """Build a CSR graph from an edge list (duplicates optionally removed)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    assert src.shape == dst.shape
    if dedup:
        key = src * num_nodes + dst
        _, uniq = np.unique(key, return_index=True)
        src, dst = src[uniq], dst[uniq]
        if weight is not None:
            weight = weight[uniq]
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    if weight is not None:
        weight = np.asarray(weight, dtype=np.float32)[order]
    counts = np.bincount(src, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return Graph(indptr=indptr, indices=dst.astype(np.int32),
                 num_nodes=num_nodes, edge_weight=weight)


def symmetric_normalize(g: Graph, add_self_loops: bool = True) -> Graph:
    """GCN-style symmetric normalization: A_hat = D^-1/2 (A [+ I]) D^-1/2.

    Returns a new Graph whose ``edge_weight`` carries the normalized values.
    """
    src, dst = g.edges()
    if add_self_loops:
        loop = np.arange(g.num_nodes, dtype=np.int32)
        src = np.concatenate([src, loop])
        dst = np.concatenate([dst, loop])
    deg = np.bincount(src, minlength=g.num_nodes) + np.bincount(dst, minlength=g.num_nodes)
    deg = deg.astype(np.float64) / 2.0  # undirected-ish degree estimate
    # Use in/out degree product for directed graphs (standard GCN uses
    # undirected degree; for our symmetric generators these coincide).
    deg_out = np.bincount(src, minlength=g.num_nodes).astype(np.float64)
    deg_in = np.bincount(dst, minlength=g.num_nodes).astype(np.float64)
    d_out = np.where(deg_out > 0, deg_out, 1.0) ** -0.5
    d_in = np.where(deg_in > 0, deg_in, 1.0) ** -0.5
    w = (d_out[src] * d_in[dst]).astype(np.float32)
    return csr_from_edges(src, dst, g.num_nodes, weight=w)


def subgraph(g: Graph, nodes: np.ndarray) -> tuple[Graph, np.ndarray]:
    """Node-induced subgraph.

    Returns (sub, mapping) where ``mapping[local] = global`` and edges are
    kept only if both endpoints are in ``nodes``.
    """
    nodes = np.asarray(nodes, dtype=np.int64)
    lut = -np.ones(g.num_nodes, dtype=np.int64)
    lut[nodes] = np.arange(nodes.shape[0])
    src, dst = g.edges()
    keep = (lut[src] >= 0) & (lut[dst] >= 0)
    w = g.edge_weight[keep] if g.edge_weight is not None else None
    sub = csr_from_edges(lut[src[keep]], lut[dst[keep]], nodes.shape[0], weight=w)
    return sub, nodes
