"""Vertex reordering for memory-access locality (paper Fig. 13).

After RAPA adjustment each subgraph is reordered so that frequently
co-accessed vertices are contiguous: inner vertices by BFS (RCM-like) order,
halo vertices by descending overlap ratio (so the JACA cache prefix is a
contiguous slice — this is what makes the TPU cache gather a dense
``dynamic_slice`` instead of a random gather for the hot tier).
"""
from __future__ import annotations

from collections import deque

import numpy as np

from .graph import Graph, csr_from_edges

__all__ = ["bfs_order", "reorder_partition_arrays"]


def bfs_order(g: Graph, start: int = 0) -> np.ndarray:
    """BFS (Cuthill-McKee style) permutation: order[new_id] = old_id."""
    n = g.num_nodes
    seen = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    k = 0
    deg = g.out_degree()
    for seed in np.argsort(deg):  # low-degree seeds first, RCM heuristic
        if seen[seed]:
            continue
        q = deque([int(seed)])
        seen[seed] = True
        while q:
            v = q.popleft()
            order[k] = v
            k += 1
            nbr = g.neighbors(v)
            nbr = nbr[~seen[nbr]]
            # visit neighbours in increasing degree order
            for u in nbr[np.argsort(deg[nbr])]:
                if not seen[u]:
                    seen[u] = True
                    q.append(int(u))
    assert k == n
    return order


def reorder_partition_arrays(local_graph: Graph, n_inner: int,
                             halo_priority: np.ndarray
                             ) -> tuple[Graph, np.ndarray]:
    """Reorder a partition-local graph.

    Inner ids get BFS order over the inner-inner subgraph; halo ids are
    sorted by descending ``halo_priority`` (overlap ratio).  Returns the
    permuted graph and ``perm`` with ``perm[new_local] = old_local``.
    """
    n_local = local_graph.num_nodes
    n_halo = n_local - n_inner
    # BFS over inner-induced subgraph
    src, dst = local_graph.edges()
    keep = (src < n_inner) & (dst < n_inner)
    inner_g = csr_from_edges(src[keep], dst[keep], n_inner)
    inner_perm = bfs_order(inner_g)
    halo_perm = n_inner + np.argsort(-halo_priority, kind="stable")
    perm = np.concatenate([inner_perm, halo_perm])
    inv = np.empty(n_local, dtype=np.int64)
    inv[perm] = np.arange(n_local)
    new_g = csr_from_edges(inv[src], inv[dst], n_local,
                           weight=local_graph.edge_weight)
    return new_g, perm
