from .graph import Graph, csr_from_edges, symmetric_normalize, subgraph
from .generate import (DatasetSpec, PAPER_DATASETS, rmat, sbm, erdos_renyi,
                       make_dataset, synth_features)
from .partition import (Partition, PartitionSet, random_partition,
                        fennel_partition, metis_partition, build_partition,
                        edge_cut)
from .reorder import bfs_order, reorder_partition_arrays

__all__ = [
    "Graph", "csr_from_edges", "symmetric_normalize", "subgraph",
    "DatasetSpec", "PAPER_DATASETS", "rmat", "sbm", "erdos_renyi",
    "make_dataset", "synth_features",
    "Partition", "PartitionSet", "random_partition", "fennel_partition",
    "metis_partition", "build_partition", "edge_cut",
    "bfs_order", "reorder_partition_arrays",
]
