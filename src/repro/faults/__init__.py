"""Deterministic fault injection + graceful-degradation guards.

``FaultPlan`` (:mod:`repro.faults.plan`) injects seeded faults at chosen
training steps — dropped/delayed host-store fetches, corrupted halo
payload rows, NaN gradients, simulated device-memory pressure, truncated
checkpoints.  ``GuardConfig``/``TrainGuard``/``FetchGuard``
(:mod:`repro.faults.guard`) are the runtime defenses each fault class
proves out.  Every injected fault and every defense action is counted, so
``injected == defended`` holds exactly (asserted by
``benchmarks/fault_tolerance.py`` and the tier-1 suite).

Zero-overhead contract (same as the disabled ``repro.obs.Tracer``): the
shared :data:`NULL_FAULTS` plan is a no-op — with it installed and no
guard configured, the training loop and both runtimes execute the exact
code paths they did before this package existed.
"""
from .plan import (FAULT_KINDS, FaultPlan, FaultSpec, FetchError,
                   NULL_FAULTS)
from .guard import DefenseEvents, FetchGuard, GuardConfig, TrainGuard

__all__ = ["FAULT_KINDS", "FaultPlan", "FaultSpec", "FetchError",
           "NULL_FAULTS", "DefenseEvents", "FetchGuard", "GuardConfig",
           "TrainGuard"]
