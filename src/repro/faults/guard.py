"""Runtime defenses for the :mod:`repro.faults` fault classes.

Three cooperating pieces, all sharing one :class:`DefenseEvents` counter
block so every degradation is exactly countable against the injector's
ledger:

- :class:`FetchGuard` — wraps the runtimes' host-store staging paths:
  bounded retry with exponential backoff on a failed fetch, degradation
  from prefetch-ahead to synchronous fetching after a slow/failed fetch,
  and past the retry budget *stale-tier reuse* — the consuming step is
  served the previous refresh's rows (DistGNN-style bounded staleness)
  instead of crashing, with the staleness event counted.
- :class:`TrainGuard` — train-loop defenses: a divergence guard (free
  per-step loss finiteness check + a fenced parameter finiteness check
  every ``guard_every`` steps) that rolls back to the last good in-memory
  snapshot and restages with a forced refresh; opt-in per-tier payload
  checksums over the exchange/stale buffers that detect corrupted rows
  before a step consumes them and force a refresh of the affected tier.
- :class:`GuardConfig` — the knobs, surfaced as ``launch.train gnn
  --guard-every k --fetch-retries n --checksums``.

Memory-pressure backoff lives in the loop itself (it needs the
``AdaptivePlanner``): see ``train_capgnn`` and
:meth:`repro.core.jaca.AdaptivePlanner.shrink_capacity`.
"""
from __future__ import annotations

import dataclasses
import time
import zlib

import numpy as np

from .plan import FetchError

__all__ = ["DefenseEvents", "FetchGuard", "GuardConfig", "TrainGuard"]


@dataclasses.dataclass
class GuardConfig:
    """Defense knobs (see module docstring).  ``guard_every=0`` disables
    the divergence guard; ``checksums=False`` skips tier digests."""
    guard_every: int = 0        # snapshot + fenced finiteness cadence
    fetch_retries: int = 2      # extra attempts after a failed fetch
    fetch_timeout_s: float = 0.1   # gather slower than this counts as slow
    fetch_backoff_s: float = 0.01  # base retry backoff (doubles per retry)
    degrade_steps: int = 2      # steps to run synchronous after a slow fetch
    checksums: bool = False     # per-tier payload digests + verify
    mem_backoff_factor: float = 0.5  # capacity shrink per pressure event


@dataclasses.dataclass
class DefenseEvents:
    """Monotone defense counters.  Field names match the
    :class:`repro.obs.StepCounters` fault fields one-to-one so the loop
    can attribute per-step deltas directly."""
    fetch_errors: int = 0            # failed stage attempts caught
    fetch_retries: int = 0           # retry attempts issued
    fetch_stale_reuse: int = 0       # consumptions served stale rows
    slow_fetches: int = 0            # gathers over the timeout
    prefetch_degraded_steps: int = 0  # steps run without prefetch-ahead
    corruptions_detected: int = 0    # tier digests that failed verify
    forced_refreshes: int = 0        # guard-forced refresh steps
    rollbacks: int = 0               # divergence rollbacks
    mem_backoffs: int = 0            # capacity-shrink replans

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def delta(self, before: dict) -> dict:
        now = self.as_dict()
        return {k: now[k] - before.get(k, 0) for k in now}


class FetchGuard:
    """Retry/degrade/stale-reuse wrapper around host-store staging (see
    module docstring).  Attached to a runtime via ``set_fault_guard``;
    with none attached the staging paths are byte-for-byte the original
    code."""

    def __init__(self, cfg: GuardConfig, events: DefenseEvents):
        self.cfg = cfg
        self.events = events
        self.last_good: dict = {}   # key -> last consumed device rows
        self._degraded = 0          # steps left with prefetch suspended

    # -- consumption ---------------------------------------------------------

    def consume(self, sf, store, key: str):
        """Account one successfully staged fetch, remember its rows as the
        stale fallback for ``key``, and flag slow gathers (degrading to
        synchronous staging for ``degrade_steps`` steps)."""
        store.account_fetch(sf)
        if sf.gather_s > self.cfg.fetch_timeout_s:
            self.events.slow_fetches += 1
            self._degraded = self.cfg.degrade_steps
        self.last_good[key] = sf.array
        return sf.array

    def fetch_sync(self, stage_fn, store, key: str):
        """Synchronous staged fetch with bounded retry + backoff; past the
        budget, serve the previous refresh's rows (stale-tier reuse)."""
        attempts = 1 + max(0, self.cfg.fetch_retries)
        for i in range(attempts):
            if i > 0:
                self.events.fetch_retries += 1
                with store.tracer.span("fetch_retry", attempt=i, key=key):
                    time.sleep(self.cfg.fetch_backoff_s * (2 ** (i - 1)))
            try:
                sf = stage_fn()
            except FetchError:
                self.events.fetch_errors += 1
                continue
            return self.consume(sf, store, key)
        stale = self.last_good.get(key)
        if stale is None:
            raise FetchError(
                f"host fetch {key!r} failed after {attempts} attempts and "
                "no previously consumed rows exist to reuse")
        self.events.fetch_stale_reuse += 1
        return stale

    # -- prefetch ------------------------------------------------------------

    def try_stage(self, stage_fn):
        """Prefetch-path staging: a failure is caught and counted, the
        ring stays short, and consumption degrades to the synchronous
        retry path above."""
        try:
            return stage_fn()
        except FetchError:
            self.events.fetch_errors += 1
            return None

    def prefetch_ok(self) -> bool:
        """One call per step from the prefetch refill: while degraded,
        skip refilling (synchronous mode) and count the step."""
        if self._degraded > 0:
            self._degraded -= 1
            self.events.prefetch_degraded_steps += 1
            return False
        return True


def _digest(arr) -> int:
    """Content digest of one tier payload (crc32 over the raw bytes;
    device arrays are fenced to the host — the checksum defense is
    opt-in precisely because of this sync)."""
    return zlib.crc32(np.ascontiguousarray(np.asarray(arr)).tobytes())


def tier_digests(caches: dict, store=None) -> dict:
    """Per-tier payload digests over the stale exchange buffers: device
    local/global caches plus host-resident global buffers."""
    d = {}
    for li, c in enumerate(caches["local"]):
        d[f"local{li}"] = _digest(c)
    for li, c in enumerate(caches["global"]):
        d[f"global{li}"] = _digest(c)
    if store is not None:
        for li in store.buf_layers():
            d[f"hostbuf{li}"] = _digest(store.buf_table(li))
    return d


class TrainGuard:
    """Train-loop defense state: checksum seal/verify + divergence
    snapshot/rollback.  Owns the run's :class:`DefenseEvents` and the
    :class:`FetchGuard` the runtimes consult."""

    def __init__(self, cfg: GuardConfig, store=None):
        self.cfg = cfg
        self.store = store
        self.events = DefenseEvents()
        self.fetch_guard = FetchGuard(cfg, self.events)
        self._sealed: dict | None = None
        self._snap = None           # (params, opt_state) host copies

    # -- payload checksums -----------------------------------------------

    def seal(self, caches: dict) -> None:
        """Record the post-step tier digests (the values the next
        consuming step must still observe)."""
        if self.cfg.checksums:
            self._sealed = tier_digests(caches, self.store)

    def verify(self, caches: dict) -> list[str]:
        """Compare current tier digests against the seal; returns the
        corrupted tier names (each counted as one detection)."""
        if not self.cfg.checksums or self._sealed is None:
            return []
        now = tier_digests(caches, self.store)
        bad = [k for k, v in self._sealed.items() if now.get(k) != v]
        self.events.corruptions_detected += len(bad)
        return bad

    # -- divergence guard --------------------------------------------------

    def snapshot(self, step: int, params, opt_state) -> None:
        """Fenced host copy of the training state — the rollback target."""
        import jax
        self._snap = (step, jax.tree.map(np.asarray, params),
                      jax.tree.map(np.asarray, opt_state))

    def params_finite(self, params) -> bool:
        """Fenced finiteness sweep over the parameter leaves."""
        import jax
        return all(bool(np.isfinite(np.asarray(leaf)).all())
                   for leaf in jax.tree.leaves(params))

    def rollback(self, params, opt_state):
        """Restore the last good snapshot (placed back with the live
        leaves' shardings so donation stays clean).  The caller must run
        the next step as a plain refresh: the caches emitted alongside the
        divergent update are poisoned and a refresh rewrites every tier
        without consuming any of them."""
        import jax
        if self._snap is None:
            raise RuntimeError("divergence detected before any snapshot; "
                               "guard_every must take an initial snapshot")
        _, snap_p, snap_o = self._snap

        def put(snap, like):
            return jax.tree.map(
                lambda s, l: jax.device_put(s, l.sharding), snap, like)
        self.events.rollbacks += 1
        return put(snap_p, params), put(snap_o, opt_state)

    @property
    def snap_step(self) -> int | None:
        return self._snap[0] if self._snap is not None else None
