"""Seeded, step-addressed fault injection plan.

A :class:`FaultPlan` is threaded through ``train_capgnn`` / both runtimes
/ :class:`~repro.dist.host_store.HostFeatureStore` and fires its injectors
only on the steps its spec marks.  Injection is **deterministic**: the
spec pins the fault steps, and any randomised choice (which tier to
corrupt, which rows) derives from ``(seed, step)`` — re-running the same
plan reproduces the same fault sequence bit-for-bit, which is what lets
the fault-tolerance suite assert ``injected == defended`` exactly.

Spec grammar (the ``--faults`` CLI string)::

    spec      := clause (";" clause)*
    clause    := kind "@" step ("," step)* (":" key "=" value ("," ...))?
    kind      := fetch_drop | fetch_delay | halo_corrupt | grad_nan
               | mem_pressure | ckpt_truncate

e.g. ``"fetch_drop@3,7;grad_nan@5;halo_corrupt@4,9:rows=8"``.

Every injector increments :attr:`FaultPlan.injected` so the training
report can publish exact injection counts next to the defense counters.
The disabled plan (:data:`NULL_FAULTS`, or any plan outside
``begin_step``/``end_run``) never fires — stores and runtimes consult it
with one attribute check on their hot paths.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["FAULT_KINDS", "FaultPlan", "FaultSpec", "FetchError",
           "NULL_FAULTS"]

FAULT_KINDS = ("fetch_drop", "fetch_delay", "halo_corrupt", "grad_nan",
               "mem_pressure", "ckpt_truncate")


class FetchError(RuntimeError):
    """A host-store staged fetch failed (injected drop, or a real staging
    error surfaced through the same defense path)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault clause: a kind, the steps it fires on, and knobs."""
    kind: str
    steps: tuple
    delay_s: float = 0.25     # fetch_delay: host-side stall per stage op
    rows: int = 4             # halo_corrupt: payload rows overwritten
    value: float = 1e30       # halo_corrupt: fill value (never a real row)
    frac: float = 0.5         # ckpt_truncate: fraction of the file kept

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if not self.steps or any(int(s) < 0 for s in self.steps):
            raise ValueError(f"{self.kind}: needs >=1 non-negative step, "
                             f"got {self.steps!r}")


_FLOAT_KEYS = ("delay_s", "value", "frac")
_INT_KEYS = ("rows",)


class FaultPlan:
    """Step-addressed injector set.  Hooks are consulted by the training
    loop (``corrupt_params`` / ``corrupt_caches`` / ``mem_pressure``), the
    host store (``on_fetch``) and checkpoint tooling
    (``truncate_checkpoint``); each no-ops unless the plan is enabled AND
    the current step (set via :meth:`begin_step`) is marked for that kind.
    """

    def __init__(self, specs=(), seed: int = 0, enabled: bool | None = None):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self.enabled = bool(self.specs) if enabled is None else enabled
        self.injected: dict[str, int] = {k: 0 for k in FAULT_KINDS}
        self._step: int | None = None
        self._by_kind: dict[str, FaultSpec] = {}
        for s in self.specs:
            if s.kind in self._by_kind:
                raise ValueError(f"duplicate fault clause for {s.kind!r}")
            self._by_kind[s.kind] = s

    # -- spec parsing --------------------------------------------------------

    @classmethod
    def parse(cls, spec: str | None, seed: int = 0) -> "FaultPlan":
        """Build a plan from the ``--faults`` spec string (see module
        docstring); ``None``/empty returns the disabled plan."""
        if not spec:
            return cls(())
        specs = []
        for clause in spec.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            head, _, opts = clause.partition(":")
            kind, at, steps_s = head.partition("@")
            if not at or not steps_s:
                raise ValueError(
                    f"fault clause {clause!r} must be kind@step[,step...]")
            kw: dict = {"kind": kind.strip(),
                        "steps": tuple(int(s) for s in steps_s.split(",")
                                       if s.strip())}
            for kv in (o for o in opts.split(",") if o.strip()):
                key, eq, val = kv.partition("=")
                key = key.strip()
                if not eq:
                    raise ValueError(f"fault option {kv!r} must be key=value")
                if key in _FLOAT_KEYS:
                    kw[key] = float(val)
                elif key in _INT_KEYS:
                    kw[key] = int(val)
                else:
                    raise ValueError(
                        f"unknown fault option {key!r} in {clause!r}; "
                        f"expected one of {_FLOAT_KEYS + _INT_KEYS}")
            specs.append(FaultSpec(**kw))
        return cls(specs, seed=seed)

    def spec_string(self) -> str:
        """Inverse of :meth:`parse` (step lists only, default knobs elided
        when untouched) — used for provenance stamping."""
        return ";".join(f"{s.kind}@{','.join(str(t) for t in s.steps)}"
                        for s in self.specs)

    # -- step addressing -----------------------------------------------------

    def begin_step(self, step: int) -> None:
        """Arm the plan for training step ``step``; injectors fire only
        between ``begin_step`` and :meth:`end_run` (setup and post-loop
        evaluation are never faulted)."""
        self._step = int(step)

    def end_run(self) -> None:
        self._step = None

    def _active(self, kind: str) -> FaultSpec | None:
        if not self.enabled or self._step is None:
            return None
        s = self._by_kind.get(kind)
        return s if (s is not None and self._step in s.steps) else None

    def has(self, kind: str) -> bool:
        return kind in self._by_kind

    def total_injected(self) -> int:
        return sum(self.injected.values())

    def _rng(self) -> np.random.Generator:
        return np.random.default_rng((self.seed, int(self._step or 0)))

    # -- injectors -----------------------------------------------------------

    def on_fetch(self) -> None:
        """Host-store hook, called once per stage op.  Raises
        :class:`FetchError` on a marked ``fetch_drop`` step (every stage
        attempt in that step fails — retries within the step exhaust and
        degrade to stale reuse) or stalls on a marked ``fetch_delay`` step.
        Every raise/stall is one injected event; the defenses catch each
        exactly once, so the counts match by construction."""
        s = self._active("fetch_drop")
        if s is not None:
            self.injected["fetch_drop"] += 1
            raise FetchError(
                f"injected fetch drop at step {self._step}")
        s = self._active("fetch_delay")
        if s is not None:
            import time
            self.injected["fetch_delay"] += 1
            time.sleep(s.delay_s)

    def corrupt_params(self, params):
        """``grad_nan``: poison one parameter leaf before the step — the
        step's gradients (and loss) come out non-finite, exactly what a
        bad reduction or overflowing update produces."""
        s = self._active("grad_nan")
        if s is None:
            return params
        import jax
        import jax.numpy as jnp
        self.injected["grad_nan"] += 1
        leaves, treedef = jax.tree.flatten(params)
        leaves[0] = leaves[0].at[(0,) * leaves[0].ndim].set(jnp.nan)
        return jax.tree.unflatten(treedef, leaves)

    def corrupt_caches(self, caches: dict, store=None):
        """``halo_corrupt``: overwrite ``rows`` payload rows of one
        (seed, step)-chosen stale tier — a device local/global cache
        entry, or a host-resident global buffer when ``store`` holds them.
        Returns ``(caches, tier_name | None)``."""
        s = self._active("halo_corrupt")
        if s is None:
            return caches, None
        import jax.numpy as jnp
        tiers = [("local", li) for li, c in enumerate(caches["local"])
                 if c.shape[1] > 0]
        tiers += [("global", li) for li, c in enumerate(caches["global"])
                  if c.shape[0] > 0]
        if store is not None:
            tiers += [("hostbuf", li) for li in store.buf_layers()
                      if store.buf_table(li).shape[0] > 0]
        if not tiers:
            return caches, None
        where, li = tiers[int(self._rng().integers(len(tiers)))]
        self.injected["halo_corrupt"] += 1
        val = jnp.float32(s.value)
        if where == "hostbuf":
            buf = store.buf_table(li).copy()
            buf[: max(1, min(s.rows, buf.shape[0]))] = s.value
            store.set_buf(li, buf)
            return caches, f"hostbuf{li}"
        out = dict(caches)
        out[where] = list(caches[where])
        c = caches[where][li]
        k = max(1, min(s.rows, c.shape[1] if where == "local" else c.shape[0]))
        out[where][li] = (c.at[:, :k, :].set(val) if where == "local"
                          else c.at[:k, :].set(val))
        return out, f"{where}{li}"

    def mem_pressure(self) -> bool:
        """``mem_pressure``: signal simulated device-memory pressure for
        this step (the defense shrinks the cache capacity and replans)."""
        if self._active("mem_pressure") is None:
            return False
        self.injected["mem_pressure"] += 1
        return True

    # -- file-level injector ---------------------------------------------

    def truncate_checkpoint(self, path: str) -> int:
        """``ckpt_truncate``: truncate ``path`` to ``frac`` of its size
        (step-independent — checkpoint faults address files, not steps).
        Returns the new byte length."""
        import os
        s = self._by_kind.get("ckpt_truncate")
        frac = s.frac if s is not None else 0.5
        size = os.path.getsize(path)
        keep = max(1, int(size * frac))
        with open(path, "r+b") as f:
            f.truncate(keep)
        self.injected["ckpt_truncate"] += 1
        return keep


NULL_FAULTS = FaultPlan(())
