"""Synthetic token pipeline for the transformer architecture zoo.

Deterministic, seedable, host-side generator producing sharded global
batches — the stand-in for a production data loader (the container is
offline).  For VLM/audio archs it also fabricates the stubbed frontend
embeddings (patch / codec-frame embeddings) per the brief's carve-out.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["TokenStream", "synthetic_token_batches"]


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def __iter__(self) -> Iterator[dict]:
        return synthetic_token_batches(self.vocab_size, self.seq_len,
                                       self.global_batch, self.seed)


def synthetic_token_batches(vocab_size: int, seq_len: int, global_batch: int,
                            seed: int = 0) -> Iterator[dict]:
    """Zipfian token ids (realistic embedding-gather skew) + next-token labels."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        tokens = rng.choice(vocab_size, size=(global_batch, seq_len), p=probs)
        tokens = tokens.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = 0
        yield {"tokens": tokens, "labels": labels}
