"""Full-batch GNN task assembly: features, labels, train/val/test masks,
and the per-partition slices the distributed runtime feeds to each worker.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph import (Graph, make_dataset, symmetric_normalize,
                         synth_features)
from repro.graph.partition import PartitionSet

__all__ = ["FullBatchTask", "make_task", "split_masks", "partition_task"]


@dataclasses.dataclass
class FullBatchTask:
    graph: Graph                 # symmetric-normalized (edge weights set)
    features: np.ndarray         # [n, f]
    labels: np.ndarray           # [n]
    train_mask: np.ndarray       # [n] bool
    val_mask: np.ndarray
    test_mask: np.ndarray
    num_classes: int
    name: str = "synthetic"


def split_masks(n: int, seed: int = 0, train: float = 0.6, val: float = 0.2
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_tr, n_va = int(n * train), int(n * val)
    m = np.zeros(n, dtype=bool)
    tr, va, te = m.copy(), m.copy(), m.copy()
    tr[perm[:n_tr]] = True
    va[perm[n_tr:n_tr + n_va]] = True
    te[perm[n_tr + n_va:]] = True
    return tr, va, te


def make_task(name: str = "flickr", scale: float = 0.02, feat_dim: int | None = None,
              seed: int = 0) -> FullBatchTask:
    g, spec = make_dataset(name, scale=scale, seed=seed)
    fd = feat_dim if feat_dim is not None else min(spec.feat_dim, 128)
    feats, labels = synth_features(g, fd, spec.num_classes, seed=seed)
    gn = symmetric_normalize(g)
    tr, va, te = split_masks(g.num_nodes, seed=seed)
    return FullBatchTask(graph=gn, features=feats, labels=labels,
                         train_mask=tr, val_mask=va, test_mask=te,
                         num_classes=spec.num_classes, name=name)


@dataclasses.dataclass
class WorkerData:
    """Per-worker slice of a FullBatchTask."""
    feats_inner: np.ndarray      # [n_inner, f]
    feats_halo: np.ndarray       # [n_halo, f]
    labels: np.ndarray           # [n_inner]
    train_mask: np.ndarray       # [n_inner]
    val_mask: np.ndarray
    test_mask: np.ndarray


def partition_task(task: FullBatchTask, ps: PartitionSet) -> list[WorkerData]:
    out = []
    for part in ps.parts:
        out.append(WorkerData(
            feats_inner=task.features[part.inner_nodes],
            feats_halo=task.features[part.halo_nodes],
            labels=task.labels[part.inner_nodes],
            train_mask=task.train_mask[part.inner_nodes],
            val_mask=task.val_mask[part.inner_nodes],
            test_mask=task.test_mask[part.inner_nodes],
        ))
    return out
