from .gnn_data import FullBatchTask, make_task, split_masks, partition_task
from .token_stream import TokenStream, synthetic_token_batches

__all__ = ["FullBatchTask", "make_task", "split_masks", "partition_task",
           "TokenStream", "synthetic_token_batches"]
