"""Device-side visibility helpers: ``jax.named_scope`` inside jitted
code, ``jax.profiler.TraceAnnotation`` around host dispatch sites, and an
opt-in ``jax.profiler.trace`` capture directory.

Everything degrades to a no-op when the corresponding jax API is missing,
so the runtimes never gate on profiler availability.
"""
from __future__ import annotations

import contextlib

__all__ = ["device_scope", "host_annotation", "annotate_function",
           "device_trace"]

_NULL = contextlib.nullcontext()


def device_scope(name: str):
    """Name a region *inside* jitted/traced code: the scope lands in the
    HLO op metadata, so XLA profiles attribute kernels (layer loop, tier
    pulls, refresh rings, Pallas SpMM) to it."""
    try:
        import jax
        return jax.named_scope(name)
    except Exception:
        return _NULL


def host_annotation(name: str):
    """Annotate a host-side dispatch site (step call, h2d staging) so an
    active ``jax.profiler`` capture shows it on the host track.  A cheap
    TraceMe when no capture is running; nullcontext if unavailable."""
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:
        return _NULL


def annotate_function(fn, name: str | None = None):
    """``jax.profiler.annotate_function`` with a graceful fallback."""
    try:
        from jax.profiler import annotate_function as _af
        return _af(fn, name=name)
    except Exception:
        return fn


@contextlib.contextmanager
def device_trace(trace_dir: str | None):
    """Opt-in device profiler capture: wraps the body in
    ``jax.profiler.trace(trace_dir)`` when a directory is given (the
    capture is browsable in TensorBoard/xprof); no-op otherwise."""
    if not trace_dir:
        yield
        return
    import jax
    with jax.profiler.trace(trace_dir):
        yield
