"""repro.obs — structured tracing and per-phase timing for the runtimes.

One schema unifies the counters the training loop, the adaptive planner,
the host store and the serve engine already compute but used to discard
after summing into report totals:

- :class:`Tracer` — nestable host-side spans (step kinds ``refresh`` /
  ``cached`` / ``pipelined`` / ``transition`` plus ``replan``,
  ``h2d_prefetch``, ``l0_stage``, ``writeback``, ``eval``) and typed
  per-step :class:`StepCounters` records (wire rows/bytes per tier,
  cache hit rate, drift, host fetch/writeback, device memory
  watermarks).  A disabled tracer is a shared no-op — no allocation, no
  ``block_until_ready`` — so the hot path pays nothing when tracing is
  off; span timing fences via :meth:`Tracer.fence` only when enabled.
- :mod:`repro.obs.export` — per-step JSONL metrics stream and a Chrome
  ``trace_event`` JSON (loads in Perfetto: spans as duration events,
  counters as counter tracks, one track per worker) written under
  ``experiments/``.
- device-side visibility: :func:`device_scope` (``jax.named_scope``
  inside jitted code), :func:`host_annotation`
  (``jax.profiler.TraceAnnotation`` around dispatch sites) and
  :func:`device_trace` (opt-in ``jax.profiler.trace`` capture dir).

``python -m repro.obs.check trace.json`` validates an exported timeline
(the CI smoke gate).
"""
from .tracer import (NULL_TRACER, SPAN_KINDS, STEP_KINDS, Span,
                     StepCounters, Tracer, device_peak_bytes)
from .annotations import (annotate_function, device_scope, device_trace,
                          host_annotation)
from .export import (chrome_trace_events, validate_chrome_trace,
                     write_chrome_trace, write_metrics_jsonl)

__all__ = [
    "Tracer", "Span", "StepCounters", "NULL_TRACER",
    "STEP_KINDS", "SPAN_KINDS", "device_peak_bytes",
    "device_scope", "host_annotation", "annotate_function", "device_trace",
    "chrome_trace_events", "write_chrome_trace", "write_metrics_jsonl",
    "validate_chrome_trace",
]
