"""Host-side span/counter tracer — the core of :mod:`repro.obs`.

Spans nest strictly (LIFO): a step-kind span opens at depth 0 and every
sub-phase (``l0_stage``, ``h2d_prefetch``, ``writeback``, ``replan``, …)
opens inside it, so two step kinds can never interleave.  Counters are
typed :class:`StepCounters` records, one per training step, whose totals
reproduce the report/plan accounting exactly (asserted in tests).

Zero-overhead contract: a disabled tracer (``Tracer(enabled=False)`` or
the shared :data:`NULL_TRACER`) allocates nothing per call — ``span()``
returns one shared reusable no-op context manager, ``count()`` /
``fence()`` return immediately, and no ``jax.block_until_ready`` is ever
issued.  Fencing happens only on an *enabled* tracer, so span durations
measure completed device work rather than async dispatch.
"""
from __future__ import annotations

import dataclasses
import time

__all__ = ["Tracer", "Span", "StepCounters", "NULL_TRACER",
           "STEP_KINDS", "SPAN_KINDS", "device_peak_bytes"]

# top-level step flavours of the training loop (depth-0 spans)
STEP_KINDS = ("refresh", "cached", "pipelined", "transition")
# sub-phase + out-of-loop span names; the last row are the fault/defense
# events of repro.faults (integrity digests, divergence checks, rollback,
# fetch retries, memory-pressure backoff)
SPAN_KINDS = STEP_KINDS + ("replan", "h2d_prefetch", "l0_stage",
                           "writeback", "eval",
                           "integrity", "divergence_check", "rollback",
                           "fetch_retry", "mem_backoff")


def device_peak_bytes() -> int | None:
    """Peak device memory in use, from ``Device.memory_stats()``; ``None``
    where the backend does not report it (host CPU devices)."""
    try:
        import jax
        st = jax.local_devices()[0].memory_stats()
    except Exception:
        return None
    if not st:
        return None
    v = st.get("peak_bytes_in_use", st.get("bytes_in_use"))
    return int(v) if v is not None else None


@dataclasses.dataclass
class Span:
    """One closed span: wall-clock interval + nesting context."""
    name: str
    kind: str              # one of SPAN_KINDS (or a free-form sub-span name)
    t0: float              # perf_counter seconds
    dur: float             # seconds
    depth: int             # 0 for step spans, >0 for nested sub-phases
    step: int | None = None
    args: dict | None = None


@dataclasses.dataclass
class StepCounters:
    """Typed per-step counter record — the one schema unifying the
    accounting of ``train_capgnn`` (wire rows/bytes), ``AdaptivePlanner``
    (hit rate), ``HostFeatureStore`` (fetch/writeback deltas) and the
    device memory watermark.  Row counts are per exchange layer, exactly
    the plan figures ``_step_rows`` sums; ``wire_bytes`` is this step's
    contribution to ``TrainReport.comm_bytes``."""
    step: int
    kind: str
    wire_rows_uncached: int = 0
    wire_rows_local: int = 0        # refreshed local-tier rows (0 on cached)
    wire_rows_global: int = 0       # refreshed dedup global rows (0 on cached)
    wire_bytes: int = 0
    wire_bytes_vanilla: int = 0
    cache_hit_rate: float | None = None   # halo rows served stale / total
    planner_hit_rate: float | None = None  # AdaptivePlanner cumulative
    drift: float | None = None
    host_fetch_rows: int = 0        # store deltas attributed to this step
    host_fetch_bytes: int = 0
    host_writeback_rows: int = 0
    host_writeback_bytes: int = 0
    device_peak_bytes: int | None = None
    wire_rows_by_worker: list | None = None  # per-worker uncached recv rows
    # serve-side records (kind="serve", one per micro-batch); None on
    # training records so the exporter emits no empty counter tracks
    queries: int | None = None
    hot_hits: int | None = None
    host_hits: int | None = None
    fresh_recomputes: int | None = None
    # fault/defense event deltas (repro.faults); None on clean runs so
    # the exporter emits no flat-zero tracks and totals stay unchanged.
    # Per step, each defense field counts actions taken THIS step and
    # faults_injected counts injector firings — the two streams sum to
    # equal totals per fault class (asserted by the fault suite).
    faults_injected: int | None = None
    fetch_errors: int | None = None
    fetch_retries: int | None = None
    fetch_stale_reuse: int | None = None
    slow_fetches: int | None = None
    prefetch_degraded_steps: int | None = None
    corruptions_detected: int | None = None
    forced_refreshes: int | None = None
    rollbacks: int | None = None
    mem_backoffs: int | None = None
    t: float = 0.0                  # perf_counter stamp (set by count())


class _NoopSpan:
    """Shared reusable no-op context manager (disabled tracer path)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _OpenSpan:
    """Context manager recording one span on exit (enabled path)."""
    __slots__ = ("tr", "name", "kind", "step", "args", "t0", "depth")

    def __init__(self, tr: "Tracer", name: str, kind: str,
                 step: int | None, args: dict | None):
        self.tr, self.name, self.kind = tr, name, kind
        self.step, self.args = step, args

    def __enter__(self):
        self.depth = len(self.tr._stack)
        self.tr._stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self.t0
        top = self.tr._stack.pop()
        if top is not self:            # interleaved exit — structural bug
            raise RuntimeError(
                f"span {self.name!r} closed while {top.name!r} is open; "
                "spans must nest strictly")
        self.tr.spans.append(Span(name=self.name, kind=self.kind,
                                  t0=self.t0, dur=dur, depth=self.depth,
                                  step=self.step, args=self.args))
        return False


class Tracer:
    """Span + counter collector.  Pass ``enabled=False`` (or use
    :data:`NULL_TRACER`) for the zero-overhead disabled mode."""

    def __init__(self, enabled: bool = True, fence: bool = True):
        self.enabled = enabled
        self.do_fence = fence
        self.spans: list[Span] = []
        self.counters: list[StepCounters] = []
        self._stack: list[_OpenSpan] = []

    # -- spans -------------------------------------------------------------

    def span(self, name: str, kind: str | None = None,
             step: int | None = None, **args):
        """Open a nested span; returns a context manager.  ``kind``
        defaults to ``name`` (the usual case for the named phases)."""
        if not self.enabled:
            return _NOOP
        return _OpenSpan(self, name, kind or name, step, args or None)

    def step_span(self, kind: str, step: int):
        """Depth-0 span for one training step of flavour ``kind``."""
        if not self.enabled:
            return _NOOP
        if self._stack:
            raise RuntimeError(
                f"step span {kind!r} opened inside {self._stack[-1].name!r};"
                " step kinds must not interleave")
        return _OpenSpan(self, kind, kind, step, None)

    def fence(self, x):
        """``block_until_ready`` *only when span timing is on* — the
        disabled tracer adds no sync points."""
        if self.enabled and self.do_fence:
            import jax
            jax.block_until_ready(x)
        return x

    # -- counters ----------------------------------------------------------

    def count(self, rec: StepCounters) -> None:
        if not self.enabled:
            return
        rec.t = time.perf_counter()
        self.counters.append(rec)

    # -- summaries ---------------------------------------------------------

    def phase_stats(self) -> dict:
        """Per step-kind timing summary over the depth-0 spans:
        ``{kind: {count, p50_ms, p99_ms, total_s}}``."""
        by_kind: dict[str, list[float]] = {}
        for s in self.spans:
            if s.depth == 0 and s.kind in STEP_KINDS + ("eval",):
                by_kind.setdefault(s.kind, []).append(s.dur)
        out = {}
        for kind, durs in by_kind.items():
            ds = sorted(durs)
            out[kind] = {
                "count": len(ds),
                "p50_ms": 1e3 * ds[len(ds) // 2],
                "p99_ms": 1e3 * ds[min(len(ds) - 1,
                                       int(0.99 * (len(ds) - 1) + 0.5))],
                "total_s": sum(ds),
            }
        return out

    def totals(self) -> dict:
        """Sums of the additive counter fields — must equal the report
        totals exactly (``comm_bytes``, ``host_fetch_rows``, …)."""
        keys = ("wire_bytes", "wire_bytes_vanilla", "host_fetch_rows",
                "host_fetch_bytes", "host_writeback_rows",
                "host_writeback_bytes",
                # fault/defense streams (None on clean runs -> summed as 0)
                "faults_injected", "fetch_errors", "fetch_retries",
                "fetch_stale_reuse", "slow_fetches",
                "prefetch_degraded_steps", "corruptions_detected",
                "forced_refreshes", "rollbacks", "mem_backoffs")
        tot = {k: 0 for k in keys}
        for c in self.counters:
            for k in keys:
                tot[k] += getattr(c, k) or 0
        tot["steps"] = len(self.counters)
        return tot

    # -- export ------------------------------------------------------------

    def export(self, out_dir, prefix: str = "train") -> dict:
        """Write ``trace_<prefix>.json`` (Chrome trace_event, Perfetto)
        and ``metrics_<prefix>.jsonl`` under ``out_dir``; returns the
        file paths."""
        from .export import write_chrome_trace, write_metrics_jsonl
        import os
        os.makedirs(out_dir, exist_ok=True)
        trace = os.path.join(out_dir, f"trace_{prefix}.json")
        jsonl = os.path.join(out_dir, f"metrics_{prefix}.jsonl")
        write_chrome_trace(self, trace)
        write_metrics_jsonl(self, jsonl)
        return {"trace": trace, "metrics": jsonl}


NULL_TRACER = Tracer(enabled=False)
