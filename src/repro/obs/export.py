"""Exporters for :class:`repro.obs.Tracer`: a per-step JSONL metrics
stream and a Chrome ``trace_event`` JSON that loads directly in Perfetto
(https://ui.perfetto.dev) — spans as complete duration events (``"X"``),
counters as counter tracks (``"C"``), per-worker wire rows as one counter
track per worker process.

``validate_chrome_trace`` is the schema check the tests and the CI smoke
gate (`python -m repro.obs.check`) run against every exported file.
"""
from __future__ import annotations

import dataclasses
import json

__all__ = ["chrome_trace_events", "write_chrome_trace",
           "write_metrics_jsonl", "validate_chrome_trace"]

# aggregate counter tracks emitted per StepCounters record (pid 0);
# fault/defense fields are None on clean runs, so they only render as
# tracks when a FaultPlan / guard was active
_COUNTER_FIELDS = ("wire_bytes", "wire_rows_uncached", "wire_rows_local",
                   "wire_rows_global", "host_fetch_rows",
                   "host_fetch_bytes", "host_writeback_bytes",
                   "cache_hit_rate", "planner_hit_rate", "drift",
                   "device_peak_bytes", "queries", "hot_hits", "host_hits",
                   "fresh_recomputes",
                   "faults_injected", "fetch_errors", "fetch_retries",
                   "fetch_stale_reuse", "slow_fetches",
                   "prefetch_degraded_steps", "corruptions_detected",
                   "forced_refreshes", "rollbacks", "mem_backoffs")
# serve records carry only the query-path counters — the training wire
# fields are structurally zero there and would render as flat-0 tracks
_SERVE_FIELDS = ("queries", "hot_hits", "host_hits", "fresh_recomputes",
                 "device_peak_bytes")


def chrome_trace_events(tracer) -> list[dict]:
    """Flatten a tracer into Chrome ``trace_event`` dicts.  Timestamps
    are microseconds relative to the earliest recorded event."""
    stamps = ([s.t0 for s in tracer.spans]
              + [c.t for c in tracer.counters])
    base = min(stamps) if stamps else 0.0

    def us(t: float) -> int:
        return int(round((t - base) * 1e6))

    events: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": "train host"}},
        {"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": "steps"}},
    ]
    for s in tracer.spans:
        ev = {"name": s.name, "cat": s.kind, "ph": "X",
              "ts": us(s.t0), "dur": max(1, int(round(s.dur * 1e6))),
              "pid": 0, "tid": 0}
        args = dict(s.args or {})
        if s.step is not None:
            args["step"] = s.step
        if args:
            ev["args"] = args
        events.append(ev)

    workers: set[int] = set()
    for c in tracer.counters:
        ts = us(c.t)
        fields = _SERVE_FIELDS if c.kind == "serve" else _COUNTER_FIELDS
        for field in fields:
            v = getattr(c, field)
            if v is None:
                continue
            events.append({"name": field, "ph": "C", "ts": ts,
                           "pid": 0, "tid": 0, "args": {field: v}})
        for w, rows in enumerate(c.wire_rows_by_worker or ()):
            workers.add(w)
            events.append({"name": "wire_rows_uncached", "ph": "C",
                           "ts": ts, "pid": 1 + w, "tid": 0,
                           "args": {"wire_rows_uncached": rows}})
    for w in sorted(workers):
        events.append({"name": "process_name", "ph": "M", "pid": 1 + w,
                       "tid": 0, "args": {"name": f"worker{w}"}})
    return events


def write_chrome_trace(tracer, path: str) -> str:
    payload = {"traceEvents": chrome_trace_events(tracer),
               "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(payload, f)
    return path


def write_metrics_jsonl(tracer, path: str) -> str:
    """One JSON line per step: the full :class:`StepCounters` record."""
    with open(path, "w") as f:
        for c in tracer.counters:
            f.write(json.dumps(dataclasses.asdict(c)) + "\n")
    return path


def validate_chrome_trace(payload) -> dict:
    """Validate a loaded Chrome trace against the ``trace_event`` schema
    subset we emit; raises ``ValueError`` on any malformed event.
    Returns ``{"spans_by_cat": {...}, "n_spans": n, "n_counters": n}``."""
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        raise ValueError("trace must be an object with 'traceEvents'")
    events = payload["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    spans_by_cat: dict[str, int] = {}
    n_spans = n_counters = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"event {i}: not an object with 'ph'")
        ph = ev["ph"]
        if ph not in ("X", "C", "M", "B", "E", "I"):
            raise ValueError(f"event {i}: unsupported phase {ph!r}")
        if ph in ("X", "C", "B", "E", "I"):
            if not isinstance(ev.get("name"), str):
                raise ValueError(f"event {i}: missing string 'name'")
            if not isinstance(ev.get("ts"), (int, float)):
                raise ValueError(f"event {i}: missing numeric 'ts'")
            if not isinstance(ev.get("pid"), int):
                raise ValueError(f"event {i}: missing int 'pid'")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"event {i}: 'X' needs 'dur' >= 0")
            n_spans += 1
            cat = ev.get("cat", "")
            spans_by_cat[cat] = spans_by_cat.get(cat, 0) + 1
        elif ph == "C":
            args = ev.get("args")
            if (not isinstance(args, dict) or not args
                    or not all(isinstance(v, (int, float))
                               for v in args.values())):
                raise ValueError(f"event {i}: 'C' needs numeric 'args'")
            n_counters += 1
    return {"spans_by_cat": spans_by_cat, "n_spans": n_spans,
            "n_counters": n_counters}
