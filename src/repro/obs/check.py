"""CLI gate over exported Perfetto timelines (the CI smoke step):

    python -m repro.obs.check experiments/trace_*.json \
        --kinds refresh,cached,pipelined

Parses each file, validates it against the ``trace_event`` schema subset
(:func:`repro.obs.export.validate_chrome_trace`) and asserts >0 spans per
required step kind; exits non-zero on any violation.
"""
from __future__ import annotations

import argparse
import json
import sys

from .export import validate_chrome_trace


def check_file(path: str, kinds: list[str]) -> dict:
    with open(path) as f:
        payload = json.load(f)
    stats = validate_chrome_trace(payload)
    missing = [k for k in kinds
               if stats["spans_by_cat"].get(k, 0) <= 0]
    if missing:
        raise ValueError(f"{path}: no spans for step kind(s) {missing}; "
                         f"have {stats['spans_by_cat']}")
    if stats["n_spans"] <= 0:
        raise ValueError(f"{path}: trace contains no spans")
    return stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="+", help="trace_*.json paths")
    ap.add_argument("--kinds", default="",
                    help="comma-separated step kinds that must each have "
                         ">0 spans (e.g. refresh,cached,pipelined)")
    args = ap.parse_args(argv)
    kinds = [k for k in args.kinds.split(",") if k]
    ok = True
    for path in args.files:
        try:
            stats = check_file(path, kinds)
            print(f"OK {path}: {json.dumps(stats)}")
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"FAIL {e}", file=sys.stderr)
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
