"""Pallas TPU kernel: blocked-ELL SpMM (the aggregation hot spot).

TPU-native adaptation of the paper's SpMM (CUDA CSR SpMM does per-row
dynamic gathers; TPUs want dense, tiled, MXU/VPU-friendly access):

- The partition's local graph is packed to **ELL** at partition time:
  ``cols/vals [n_rows, max_deg]`` padded per row.  After METIS/RAPA the
  degree skew *within* a partition is bounded, keeping padding waste small
  (reported by :func:`ell_stats`), and RAPA's halo pruning removes exactly
  the high-padding tail rows first.
- Grid tiles (row_block x feat_block).  Per tile we keep a ``(BR, max_deg)``
  neighbour-id tile and the full feature-column stripe ``(n_cols, BF)`` in
  VMEM, gather neighbour rows with a vectorised take, and contract the
  neighbour axis with the VPU (einsum over k).  Feature stripes of 128 keep
  lane alignment; row blocks of 8*k keep sublane alignment.
- VMEM budget per tile = n_cols*BF*4 + BR*max_deg*(4+4) + BR*BF*4 bytes; the
  wrapper asserts it under 16 MiB and splits the column stripe otherwise
  (column-chunked accumulation).

Validated against ``ref.ell_spmm_ref`` in interpret mode (this container is
CPU-only; interpret=True executes the kernel body faithfully).
"""
from __future__ import annotations

import functools

import numpy as _np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ell_spmm_pallas"]


def _kernel(cols_ref, vals_ref, h_ref, out_ref):
    cols = cols_ref[...]          # [BR, K] int32
    vals = vals_ref[...]          # [BR, K] f32
    h = h_ref[...]                # [n_cols_chunk, BF]
    gathered = jnp.take(h, cols, axis=0)         # [BR, K, BF]
    out_ref[...] += jnp.einsum(
        "rk,rkf->rf", vals, gathered, preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)


def _zero_init_kernel(cols_ref, vals_ref, h_ref, out_ref):
    # first col-chunk initialises the accumulator
    out_ref[...] = jnp.zeros_like(out_ref)
    _kernel(cols_ref, vals_ref, h_ref, out_ref)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_feat",
                                             "col_chunk", "interpret"))
def ell_spmm_pallas(cols: jnp.ndarray, vals: jnp.ndarray, h: jnp.ndarray,
                    *, block_rows: int = 128, block_feat: int = 128,
                    col_chunk: int | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """out[i] = sum_k vals[i,k] * h[cols[i,k]]  — differentiable wrapper
    (custom VJP: the pullbacks are the transposed gather/scatter, see
    ``_spmm_bwd``).  See module docstring for kernel design.
    """
    fwd = _spmm_vjp(block_rows, block_feat, col_chunk, interpret)
    return fwd(cols, vals, h)


@functools.lru_cache(maxsize=None)
def _spmm_vjp(block_rows: int, block_feat: int, col_chunk: int | None,
              interpret: bool):
    run = functools.partial(_ell_spmm_raw, block_rows=block_rows,
                            block_feat=block_feat, col_chunk=col_chunk,
                            interpret=interpret)

    @jax.custom_vjp
    def spmm(cols, vals, h):
        return run(cols, vals, h)

    def fwd(cols, vals, h):
        return run(cols, vals, h), (cols, vals, h)

    def bwd(res, g):
        cols, vals, h = res
        g32 = g.astype(jnp.float32)
        gathered = jnp.take(h.astype(jnp.float32), cols, axis=0)  # [R,K,F]
        d_vals = jnp.einsum("rf,rkf->rk", g32, gathered).astype(vals.dtype)
        # dL/dh = A^T g: scatter-add along the neighbour ids (the reverse-
        # edge aggregation; on a real TPU this is the same kernel run on the
        # transposed ELL pack — jnp scatter keeps the oracle exact here).
        contrib = vals.astype(jnp.float32)[..., None] * g32[:, None, :]
        d_h = jnp.zeros(h.shape, jnp.float32).at[cols.reshape(-1)].add(
            contrib.reshape(-1, g.shape[-1])).astype(h.dtype)
        ct_cols = _np.zeros(cols.shape, dtype=jax.dtypes.float0)
        return ct_cols, d_vals, d_h

    spmm.defvjp(fwd, bwd)
    return spmm


def _ell_spmm_raw(cols: jnp.ndarray, vals: jnp.ndarray, h: jnp.ndarray,
                  *, block_rows: int, block_feat: int,
                  col_chunk: int | None, interpret: bool) -> jnp.ndarray:
    """The pallas_call dispatch (no autodiff).

    Shapes: cols/vals [n_rows, max_deg] (n_rows % block_rows == 0 — wrapper
    pads), h [n_cols, d] (d % block_feat == 0).  ``col_chunk`` splits the
    h-rows dimension when n_cols is too large for VMEM; neighbour ids are
    bucketed per chunk by masking vals outside the chunk.
    """
    n_rows, max_deg = cols.shape
    n_cols, d = h.shape
    assert vals.shape == (n_rows, max_deg)
    assert n_rows % block_rows == 0, (n_rows, block_rows)
    assert d % block_feat == 0, (d, block_feat)

    if col_chunk is None or col_chunk >= n_cols:
        grid = (n_rows // block_rows, d // block_feat)
        return pl.pallas_call(
            _zero_init_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_rows, max_deg), lambda i, j: (i, 0)),
                pl.BlockSpec((block_rows, max_deg), lambda i, j: (i, 0)),
                pl.BlockSpec((n_cols, block_feat), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((block_rows, block_feat), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((n_rows, d), h.dtype),
            interpret=interpret,
        )(cols, vals, h)

    # Column-chunked accumulation: mask neighbour entries per chunk and use
    # a 3rd grid dim with accumulate-into-out semantics.
    assert n_cols % col_chunk == 0, (n_cols, col_chunk)
    n_chunks = n_cols // col_chunk

    def chunk_kernel(cols_ref, vals_ref, h_ref, out_ref):
        c = pl.program_id(2)

        @pl.when(c == 0)
        def _():
            out_ref[...] = jnp.zeros_like(out_ref)

        cols_g = cols_ref[...]
        vals_g = vals_ref[...]
        lo = c * col_chunk
        in_chunk = (cols_g >= lo) & (cols_g < lo + col_chunk)
        local = jnp.where(in_chunk, cols_g - lo, 0)
        v = jnp.where(in_chunk, vals_g, 0.0)
        h_blk = h_ref[...]
        gathered = jnp.take(h_blk, local, axis=0)
        out_ref[...] += jnp.einsum(
            "rk,rkf->rf", v, gathered, preferred_element_type=jnp.float32
        ).astype(out_ref.dtype)

    grid = (n_rows // block_rows, d // block_feat, n_chunks)
    return pl.pallas_call(
        chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, max_deg), lambda i, j, c: (i, 0)),
            pl.BlockSpec((block_rows, max_deg), lambda i, j, c: (i, 0)),
            pl.BlockSpec((col_chunk, block_feat), lambda i, j, c: (c, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_feat),
                               lambda i, j, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_rows, d), h.dtype),
        interpret=interpret,
    )(cols, vals, h)
