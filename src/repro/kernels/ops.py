"""Public jit'd wrappers around the Pallas kernels (padding, dispatch,
fallbacks) + the ELL packing helper.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .ell_spmm import ell_spmm_pallas
from .cache_gather import gather_rows_pallas
from . import ref as _ref

__all__ = ["ell_pack", "ell_pack_hybrid", "hybrid_spmm", "ell_stats",
           "ell_spmm", "gather_rows", "pack_rows", "cache_combine"]


def ell_pack(src: np.ndarray, dst: np.ndarray, w: np.ndarray, n_rows: int,
             max_deg: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Pack COO (src->dst) edges into ELL rows indexed by dst.

    Returns (cols, vals) of shape [n_rows, max_deg]; padding entries have
    col id 0 and val 0 (the oracle/kernel contract).  Row-count padding to
    the kernel block size happens inside :func:`ell_spmm`, so callers see
    exactly ``n_rows`` output rows.
    """
    deg = np.bincount(dst, minlength=n_rows)
    md = int(deg.max()) if max_deg is None and deg.size else (max_deg or 1)
    md = max(1, md)
    cols = np.zeros((n_rows, md), dtype=np.int32)
    vals = np.zeros((n_rows, md), dtype=np.float32)
    order = np.argsort(dst, kind="stable")
    src_s, dst_s, w_s = src[order], dst[order], w[order]
    # vectorised slot assignment: position within each dst group
    starts = np.searchsorted(dst_s, np.arange(n_rows))
    pos_in_group = np.arange(dst_s.shape[0]) - starts[dst_s]
    keep = pos_in_group < md
    cols[dst_s[keep], pos_in_group[keep]] = src_s[keep]
    vals[dst_s[keep], pos_in_group[keep]] = w_s[keep]
    return cols, vals


def ell_pack_hybrid(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                    n_rows: int, quantile: float = 0.95
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray, np.ndarray]:
    """Hybrid ELL+COO pack (beyond-paper: power-law degree skew makes plain
    ELL ~98% padding).  Rows are packed to the ``quantile`` degree; the
    overflow edges of heavy rows go to a COO tail handled by segment-sum.

    Returns (cols, vals, tail_src, tail_dst, tail_w).
    """
    deg = np.bincount(dst, minlength=n_rows)
    md = max(1, int(np.quantile(deg[deg > 0], quantile))) if deg.any() else 1
    order = np.argsort(dst, kind="stable")
    src_s, dst_s, w_s = src[order], dst[order], w[order]
    starts = np.searchsorted(dst_s, np.arange(n_rows))
    pos_in_group = np.arange(dst_s.shape[0]) - starts[dst_s]
    keep = pos_in_group < md
    cols = np.zeros((n_rows, md), dtype=np.int32)
    vals = np.zeros((n_rows, md), dtype=np.float32)
    cols[dst_s[keep], pos_in_group[keep]] = src_s[keep]
    vals[dst_s[keep], pos_in_group[keep]] = w_s[keep]
    return (cols, vals, src_s[~keep].astype(np.int32),
            dst_s[~keep].astype(np.int32), w_s[~keep].astype(np.float32))


def hybrid_spmm(cols: jnp.ndarray, vals: jnp.ndarray, tail_src: jnp.ndarray,
                tail_dst: jnp.ndarray, tail_w: jnp.ndarray, h: jnp.ndarray,
                *, interpret: bool = True) -> jnp.ndarray:
    """ELL kernel over the regular part + segment-sum over the COO tail."""
    out = ell_spmm(cols, vals, h, interpret=interpret)
    if tail_src.shape[0]:
        msgs = h[tail_src] * tail_w[:, None].astype(h.dtype)
        out = out + jax.ops.segment_sum(msgs, tail_dst,
                                        num_segments=cols.shape[0])
    return out


def ell_stats(cols: np.ndarray, vals: np.ndarray) -> dict:
    """Padding-waste report (how ELL-friendly the partition is)."""
    nnz = int((vals != 0).sum())
    total = int(vals.size)
    return {"nnz": nnz, "slots": total,
            "pad_waste": 1.0 - nnz / max(1, total),
            "max_deg": int(vals.shape[1])}


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def ell_spmm(cols: jnp.ndarray, vals: jnp.ndarray, h: jnp.ndarray, *,
             block_rows: int = 128, block_feat: int = 128,
             col_chunk: int | None = None,
             interpret: bool = True) -> jnp.ndarray:
    """Padded/dispatched ELL SpMM; returns [n_rows, d] (unpadded)."""
    n_rows = cols.shape[0]
    d = h.shape[1]
    cols_p = _pad_to(cols, block_rows, 0)
    vals_p = _pad_to(vals, block_rows, 0)
    h_p = _pad_to(h, block_feat, 1)
    out = ell_spmm_pallas(cols_p, vals_p, h_p, block_rows=block_rows,
                          block_feat=block_feat, col_chunk=col_chunk,
                          interpret=interpret)
    return out[:n_rows, :d]


def gather_rows(src: jnp.ndarray, idx: jnp.ndarray, *,
                block_rows: int = 128, block_feat: int = 128,
                interpret: bool = True) -> jnp.ndarray:
    if idx.shape[0] == 0:
        return jnp.zeros((0, src.shape[1]), src.dtype)
    n_out, d = idx.shape[0], src.shape[1]
    idx_p = _pad_to(idx, block_rows, 0)
    src_p = _pad_to(src, block_feat, 1)
    out = gather_rows_pallas(src_p, idx_p, block_rows=block_rows,
                             block_feat=block_feat, interpret=interpret)
    return out[:n_out, :d]


def pack_rows(src: jnp.ndarray, idx: jnp.ndarray, *,
              use_pallas: bool = False, interpret: bool = True
              ) -> jnp.ndarray:
    """Fused peer-pack gather: pull ``src`` rows for an arbitrarily-shaped
    index block in one pass, e.g. the ``[P, B]`` per-peer send layout of
    the p2p halo transport -> ``[P, B, d]`` payload.

    ``use_pallas=True`` routes the flattened gather through the Pallas
    :func:`gather_rows` kernel (one VMEM sweep over ``src`` per block tile
    — the TPU path); the default is a plain ``take``, which XLA fuses into
    the surrounding send-buffer pack and is faster under CPU interpret
    mode.  Both produce identical rows.
    """
    flat = idx.reshape(-1)
    if use_pallas:
        out = gather_rows(src, flat, interpret=interpret)
    else:
        out = jnp.take(src, flat, axis=0)
    return out.reshape(*idx.shape, src.shape[1])


def cache_combine(local_rows, local_pos, global_rows, global_pos,
                  recv_rows, recv_pos, n_halo: int) -> jnp.ndarray:
    """3-way tier combine into the halo buffer (scatter; jnp implementation —
    scatter of disjoint static positions fuses well under XLA, the kernel
    win is in the gathers feeding it)."""
    return _ref.cache_combine_ref(local_rows, local_pos, global_rows,
                                  global_pos, recv_rows, recv_pos, n_halo)
