"""Pallas TPU kernel: cache row gather (JACA 'pick_cache' hot path).

Gathers cached halo rows ``out[i] = src[idx[i]]`` — the inner loop of the
cache read path.  Thanks to the reordering pass (repro.graph.reorder) the
hot cache tier is *contiguous by construction*, so the common case is a
dense ``dynamic_slice``; this kernel covers the general (permuted) case
with a tiled vectorised take, VMEM-resident source stripes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gather_rows_pallas"]


def _kernel(idx_ref, src_ref, out_ref):
    idx = idx_ref[...]            # [BR, 1] int32
    src = src_ref[...]            # [n_src, BF]
    out_ref[...] = jnp.take(src, idx[:, 0], axis=0)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_feat",
                                             "interpret"))
def gather_rows_pallas(src: jnp.ndarray, idx: jnp.ndarray, *,
                       block_rows: int = 128, block_feat: int = 128,
                       interpret: bool = True) -> jnp.ndarray:
    """out[i] = src[idx[i]].  idx [n_out] int32, src [n_src, d]."""
    n_out = idx.shape[0]
    n_src, d = src.shape
    assert n_out % block_rows == 0, (n_out, block_rows)
    assert d % block_feat == 0, (d, block_feat)
    idx2 = idx.reshape(n_out, 1).astype(jnp.int32)
    grid = (n_out // block_rows, d // block_feat)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((n_src, block_feat), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_feat), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_out, d), src.dtype),
        interpret=interpret,
    )(idx2, src)
