"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ell_spmm_ref", "cache_combine_ref", "masked_mean_ref"]


def ell_spmm_ref(cols: jnp.ndarray, vals: jnp.ndarray, h: jnp.ndarray
                 ) -> jnp.ndarray:
    """out[i] = sum_k vals[i, k] * h[cols[i, k]].

    cols: [n_rows, max_deg] int32 (padding entries must have vals == 0;
    their col ids may be arbitrary valid ids).
    vals: [n_rows, max_deg] float.
    h:    [n_cols, d].
    """
    gathered = h[cols]                      # [n_rows, max_deg, d]
    return jnp.einsum("rk,rkd->rd", vals, gathered)


def cache_combine_ref(local_rows: jnp.ndarray, local_pos: jnp.ndarray,
                      global_rows: jnp.ndarray, global_pos: jnp.ndarray,
                      recv_rows: jnp.ndarray, recv_pos: jnp.ndarray,
                      n_halo: int) -> jnp.ndarray:
    """Scatter three row sources into one [n_halo, d] halo buffer.

    Position arrays index into the halo buffer; each halo slot is covered by
    exactly one source (plan property).  Empty sources are allowed
    (size-0 leading dims).
    """
    d = local_rows.shape[-1] if local_rows.size else (
        global_rows.shape[-1] if global_rows.size else recv_rows.shape[-1])
    out = jnp.zeros((n_halo, d), local_rows.dtype if local_rows.size else
                    (global_rows.dtype if global_rows.size else recv_rows.dtype))
    if local_rows.shape[0]:
        out = out.at[local_pos].set(local_rows)
    if global_rows.shape[0]:
        out = out.at[global_pos].set(global_rows)
    if recv_rows.shape[0]:
        out = out.at[recv_pos].set(recv_rows)
    return out


def masked_mean_ref(x: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Row-masked column mean: mean over rows where mask==1."""
    m = mask.astype(x.dtype)[:, None]
    return (x * m).sum(0) / jnp.maximum(m.sum(), 1.0)
