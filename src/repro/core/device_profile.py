"""Device capability profiles (paper §3.4 Observation 3, Table 1/3).

The paper measures MM, SpMM, H2D, D2H, IDT per GPU and feeds the
capability ratios into RAPA (Eq. 13/14).  We keep the same five-metric
profile.  Two sources:

- ``measure_profile()`` — microbenchmark on the current JAX backend (the
  TPU/CPU analogue of the paper's Table 1 harness).
- ``PROFILES`` — declared profiles reproducing the paper's Table 1 numbers
  (seconds for a 16384^2 fp32 workload), used for the heterogeneous-GPU
  experiments so results are reproducible without that exact hardware.

TPU note: a TPU slice is nominally homogeneous; heterogeneity enters through
declared profiles (experiments) or measured skew.  The profile structure is
what RAPA consumes — it is agnostic to where the numbers come from.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

__all__ = ["DeviceProfile", "PROFILES", "TPU_V5E", "measure_profile",
           "make_group", "capability_weights", "detect_host_mem_gib"]


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Times (seconds, lower is better) for the paper's five microbenchmarks,
    plus memory capacity in GiB.  ``host_mem_gib`` sizes the shared CPU
    cache tier (JACA's C_CPU / the out-of-core host feature store) —
    measured profiles detect it, declared Table 1 profiles keep the
    paper's 16 GiB-host assumption."""
    name: str
    mm: float        # dense matmul time
    spmm: float      # sparse matmul time
    h2d: float       # host-to-device
    d2h: float       # device-to-host
    idt: float       # intra/inter-device transfer
    mem_gib: float
    host_mem_gib: float = 16.0

    def compute_caps(self) -> tuple[float, float]:
        """Capabilities = inverse time (bigger is faster)."""
        return 1.0 / self.mm, 1.0 / self.spmm

    def comm_caps(self) -> tuple[float, float, float]:
        return 1.0 / self.h2d, 1.0 / self.d2h, 1.0 / self.idt


# Paper Table 1 (means across same-model cards).
PROFILES: dict[str, DeviceProfile] = {
    "rtx3090": DeviceProfile("rtx3090", 0.1383, 0.1063, 0.1197, 0.1213, 0.0014, 24.0),
    "a40": DeviceProfile("a40", 0.1421, 0.1198, 0.1187, 0.1189, 0.0021, 48.0),
    "rtx3060": DeviceProfile("rtx3060", 0.3439, 0.1962, 0.1220, 0.1236, 0.0038, 12.0),
    "rtx2060": DeviceProfile("rtx2060", 0.4972, 0.2955, 0.1192, 0.1195, 0.0033, 6.0),
    "gtx1660ti": DeviceProfile("gtx1660ti", 0.9938, 0.3409, 0.1238, 0.1244, 0.0057, 6.0),
    "gtx1650": DeviceProfile("gtx1650", 1.2743, 0.6323, 0.1253, 0.1253, 0.0094, 4.0),
}

# TPU v5e targets: 197 TF/s bf16, 819 GB/s HBM, ~50GB/s/link ICI.  Times are
# normalised to the same 16384^2 workload for unit consistency with Table 1.
_WORK_FLOPS = 2 * 16384 ** 3
_WORK_BYTES = 4 * 16384 ** 2
TPU_V5E = DeviceProfile(
    name="tpu-v5e",
    mm=_WORK_FLOPS / 197e12,
    spmm=_WORK_BYTES * 64 / 819e9,   # SpMM is bandwidth-bound; ~64 nnz/row
    h2d=_WORK_BYTES / 32e9,          # PCIe-class host link
    d2h=_WORK_BYTES / 32e9,
    idt=_WORK_BYTES / 50e9,          # single ICI link
    mem_gib=16.0,
)


def make_group(names: list[str]) -> list[DeviceProfile]:
    """Paper Table 4 style groups, e.g. ['rtx3090','rtx3090','a40',...]."""
    return [PROFILES[n] for n in names]


def capability_weights(profiles: list[DeviceProfile],
                       alpha: float = 0.7) -> np.ndarray:
    """Per-device partition target fractions from compute capability.

    Inverts the Eq. 14 cost mix: device i's share is proportional to
    ``1 / (alpha * spmm_i + (1 - alpha) * mm_i)`` so the weakest device
    receives the smallest inner vertex set.  ``alpha`` is the SpMM-vs-MM
    weight (same meaning as :class:`repro.core.rapa.RapaConfig.alpha`).
    Returns weights normalised to sum to 1, suitable for the ``weights=``
    argument of the partitioners in :mod:`repro.graph.partition`.
    """
    t = np.array([alpha * p.spmm + (1.0 - alpha) * p.mm for p in profiles],
                 dtype=np.float64)
    w = 1.0 / np.maximum(t, 1e-12)
    return w / w.sum()


# Paper Table 4 groups x2..x8.
PAPER_GROUPS: dict[str, list[str]] = {
    "x2": ["rtx3090"] * 2,
    "x3": ["rtx3090"] * 2 + ["a40"],
    "x4": ["rtx3090"] * 2 + ["a40"] * 2,
    "x5": ["rtx3090"] * 2 + ["a40"] * 2 + ["rtx3060"],
    "x6": ["rtx3090"] * 2 + ["a40"] * 2 + ["rtx3060"] * 2,
    "x7": ["rtx3090"] * 2 + ["a40"] * 2 + ["rtx3060"] * 2 + ["gtx1660ti"],
    "x8": ["rtx3090"] * 2 + ["a40"] * 2 + ["rtx3060"] * 2 + ["gtx1660ti"] * 2,
}


def measure_profile(size: int = 1024, sparsity: float = 0.996,
                    repeats: int = 5) -> DeviceProfile:
    """Microbenchmark the current backend (paper Table 1 harness, scaled)."""
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (size, size), jnp.float32)
    b = jax.random.normal(key, (size, size), jnp.float32)
    mask = jax.random.uniform(key, (size, size)) > sparsity
    sp = jnp.where(mask, a, 0.0)

    def timed(fn, *args):
        fn(*args).block_until_ready()  # compile+warm
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = fn(*args)
        out.block_until_ready()
        return (time.perf_counter() - t0) / repeats

    mm = timed(jax.jit(jnp.matmul), a, b)
    spmm = timed(jax.jit(jnp.matmul), sp, b)
    host = np.asarray(a)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.device_put(host).block_until_ready()
    h2d = (time.perf_counter() - t0) / repeats
    # D2H must pull a *fresh* device buffer each repeat: JAX memoises the
    # host copy of a committed array, so repeated np.asarray(a) on the same
    # buffer measures a dict lookup (~0), not the transfer.
    bufs = [(a + float(i + 1)) for i in range(repeats)]
    for buf in bufs:
        buf.block_until_ready()
    t0 = time.perf_counter()
    for buf in bufs:
        jax.device_get(buf)
    d2h = (time.perf_counter() - t0) / repeats
    idt = timed(jax.jit(lambda x: x + 0.0), a)
    mem = _backend_mem_gib(jax, default=16.0)
    return DeviceProfile("measured", mm, spmm, h2d, d2h, idt, mem,
                         host_mem_gib=detect_host_mem_gib())


def detect_host_mem_gib(default: float = 16.0) -> float:
    """Total host RAM in GiB — ``os.sysconf`` where POSIX exposes it,
    ``psutil`` as a fallback, ``default`` when neither is available.
    Feeds :func:`repro.core.jaca.cal_capacity`'s CPU-tier budget (and the
    out-of-core benchmark's host-RAM charge) so the shared CPU cache is
    sized against the actual machine instead of a hardcoded constant."""
    import os
    try:
        pages = os.sysconf("SC_PHYS_PAGES")
        page_size = os.sysconf("SC_PAGE_SIZE")
        if pages > 0 and page_size > 0:
            return pages * page_size / 1024.0 ** 3
    except (AttributeError, ValueError, OSError):
        pass
    try:
        import psutil
        return psutil.virtual_memory().total / 1024.0 ** 3
    except Exception:
        return default


def _backend_mem_gib(jax, default: float) -> float:
    """Device memory in GiB from the backend, ``default`` if unavailable
    (CPU backends typically expose no memory stats)."""
    try:
        stats = jax.local_devices()[0].memory_stats()
        limit = (stats or {}).get("bytes_limit", 0)
        if limit:
            return float(limit) / 1024.0 ** 3
    except Exception:
        pass
    return default
