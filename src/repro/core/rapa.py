"""RAPA — Resource-Aware Partitioning Algorithm (paper §4.3).

Pipeline (Fig. 11): pre-partition (METIS-like) -> assign subgraphs to
devices -> iteratively *adjust* subgraphs by pruning low-influence halo
replicas from overloaded partitions until per-device costs are balanced
(Algs. 2-3) under the memory constraint (Eq. 15).

Cost model:
- T_comm (Eq. 13): outer-edge proxy weighted by the device's H2D/D2H/IDT
  capability ratios.
- T_comp (Eq. 14): alpha * |E_all| * spmm_ratio + (1-alpha) * |V_inner| * mm_ratio.

Halo influence score (Eq. 16): degree-normalised structural weight of the
replica's incident cross edges, times its replication count C_i — replicas
that are structurally marginal *and* redundant elsewhere go first.

RAPA prunes only halo *replicas* (a vertex keeps its inner copy and its
labels); training remains full-batch, the graph just loses some
cross-partition message paths — the lossy trade evaluated in §5.10.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.graph.graph import Graph, csr_from_edges
from repro.graph.partition import Partition, PartitionSet
from .device_profile import DeviceProfile

__all__ = ["RapaConfig", "RapaResult", "comm_cost", "comp_cost",
           "influence_scores", "adjust_subgraph", "do_partition",
           "memory_bytes", "partition_lambdas"]


@dataclasses.dataclass(frozen=True)
class RapaConfig:
    alpha: float = 0.7            # SpMM vs MM weight in Eq. 14
    epsilon_frac: float = 0.01    # stop when Std(lambda) < eps_frac * mean
    max_iters: int = 50
    feat_dim: int = 256
    m_vertex: int = 4 * 256       # bytes per vertex feature row (Eq. 15)
    m_edge: int = 8               # bytes per edge (int32 src,dst)
    beta_mib: float = 100.0       # reserved memory (paper: 100MB)
    target_mode: str = "half_gap" # Alg.3 stop: lambda_hat <= (lambda_i+mean)/2


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def comm_cost(e_outer: float, profile: DeviceProfile,
              profiles: Sequence[DeviceProfile], num_parts: int) -> float:
    """Paper Eq. 13 (time-ratio form: larger time => weaker => higher cost)."""
    f_h2d = profile.h2d / min(p.h2d for p in profiles)
    f_d2h = profile.d2h / min(p.d2h for p in profiles)
    f_idt = profile.idt / min(p.idt for p in profiles)
    p_ = max(1, num_parts)
    return e_outer * ((f_h2d + f_d2h) * (1.0 - 1.0 / p_) + f_idt * (1.0 / p_))


def comp_cost(e_all: float, v_inner: float, profile: DeviceProfile,
              profiles: Sequence[DeviceProfile], alpha: float) -> float:
    """Paper Eq. 14 (SpMM scales with edges, MM with inner vertices)."""
    r_spmm = profile.spmm / min(p.spmm for p in profiles)
    r_mm = profile.mm / min(p.mm for p in profiles)
    return alpha * e_all * r_spmm + (1.0 - alpha) * v_inner * r_mm


def memory_bytes(v_local: int, e_local: int, cfg: RapaConfig) -> float:
    """Eq. 15 memory footprint of a partition."""
    return (v_local * cfg.m_vertex + e_local * cfg.m_edge
            + cfg.feat_dim * 4 + cfg.beta_mib * 1024 ** 2)


# ---------------------------------------------------------------------------
# Influence score (Eq. 16)
# ---------------------------------------------------------------------------

def influence_scores(ps: PartitionSet, part: Partition) -> np.ndarray:
    """S for each halo vertex of ``part`` (low = prune first)."""
    g = ps.graph
    d_in = np.maximum(g.in_degree(), 1).astype(np.float64)
    d_out = np.maximum(g.out_degree(), 1).astype(np.float64)
    overlap = ps.overlap_ratio().astype(np.float64)
    lg = part.local_graph
    n_inner = part.n_inner
    lsrc, ldst = lg.edges()
    scores = np.zeros(part.n_halo, dtype=np.float64)
    # halo -> inner edges (halo vertex is the src; its "outgoing" influence)
    is_halo_src = lsrc >= n_inner
    hpos = lsrc[is_halo_src] - n_inner
    dst_gid = part.inner_nodes[ldst[is_halo_src]]
    contrib = 1.0 / np.sqrt(d_in[dst_gid]) / np.sqrt(d_out[dst_gid])
    np.add.at(scores, hpos, contrib)
    # C_i = replication count across subgraphs (>=1)
    c = np.maximum(overlap[part.halo_nodes], 1.0)
    return scores * c


# ---------------------------------------------------------------------------
# Algorithms 2 & 3
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _PartState:
    """Mutable per-partition counters + halo removal mask."""
    part: Partition
    removed: np.ndarray          # bool per halo position
    halo_deg: np.ndarray         # local edges incident to each halo replica
    e_inner: int                 # edges with inner src
    scores: np.ndarray

    @property
    def e_outer(self) -> int:
        return int(self.halo_deg[~self.removed].sum())

    @property
    def e_all(self) -> int:
        return self.e_inner + self.e_outer

    @property
    def v_local(self) -> int:
        return self.part.n_inner + int((~self.removed).sum())


def _make_states(ps: PartitionSet) -> list[_PartState]:
    states = []
    for part in ps.parts:
        lsrc, _ = part.local_graph.edges()
        is_halo = lsrc >= part.n_inner
        halo_deg = np.bincount(lsrc[is_halo] - part.n_inner,
                               minlength=part.n_halo).astype(np.int64)
        states.append(_PartState(
            part=part,
            removed=np.zeros(part.n_halo, dtype=bool),
            halo_deg=halo_deg,
            e_inner=int((~is_halo).sum()),
            scores=influence_scores(ps, part),
        ))
    return states


def _lambda(st: _PartState, prof: DeviceProfile,
            profiles: Sequence[DeviceProfile], cfg: RapaConfig,
            num_parts: int) -> float:
    return (comp_cost(st.e_all, st.part.n_inner, prof, profiles, cfg.alpha)
            + comm_cost(st.e_outer, prof, profiles, num_parts))


def partition_lambdas(ps: PartitionSet, profiles: Sequence[DeviceProfile],
                      cfg: RapaConfig | None = None) -> np.ndarray:
    """Per-partition modeled step cost lambda_i (Eq. 13 + Eq. 14) of an
    existing partitioning on a device group — the public evaluation helper
    benchmarks and examples use (``max(partition_lambdas(...))`` is the
    modeled step time, the straggler's cost)."""
    cfg = cfg or RapaConfig()
    states = _make_states(ps)
    return np.array([_lambda(st, profiles[i], profiles, cfg, ps.num_parts)
                     for i, st in enumerate(states)])


def adjust_subgraph(states: list[_PartState],
                    profiles: Sequence[DeviceProfile],
                    cfg: RapaConfig) -> np.ndarray:
    """Paper Algorithm 3 — one adjustment sweep.

    Iterates partitions from the weakest device; while a partition's cost
    exceeds the mean, removes the lowest-influence not-yet-removed halo
    replica (and its incident local edges).  Returns the status vector r
    (r_i = 1 means no further improvement possible for partition i).
    """
    p = len(states)
    lam = np.array([_lambda(st, profiles[i], profiles, cfg, p)
                    for i, st in enumerate(states)])
    mean = lam.mean()
    r = np.zeros(p, dtype=np.int64)
    # weakest device first (largest mm time)
    order = np.argsort([-profiles[i].mm for i in range(p)])
    for i in order:
        st = states[i]
        lam_i = _lambda(st, profiles[i], profiles, cfg, p)
        mem_ok = memory_bytes(st.v_local, st.e_all, cfg) <= profiles[i].mem_gib * 1024 ** 3
        if lam_i <= mean and mem_ok:
            r[i] = 1
            continue
        target = 0.5 * (lam_i + mean) if cfg.target_mode == "half_gap" else mean
        cand = np.argsort(st.scores, kind="stable")
        removed_any = False
        for pos in cand:
            if st.removed[pos]:
                continue
            lam_now = _lambda(st, profiles[i], profiles, cfg, p)
            mem_ok = memory_bytes(st.v_local, st.e_all, cfg) <= profiles[i].mem_gib * 1024 ** 3
            if lam_now <= target and mem_ok:
                break
            st.removed[pos] = True
            removed_any = True
        if not removed_any:
            r[i] = 1
    return r


@dataclasses.dataclass
class RapaResult:
    partition_set: PartitionSet          # pruned partitions
    history: list[dict]                  # per-iteration stats (Fig. 20)
    removed_per_part: list[int]
    lambda_final: np.ndarray


def do_partition(ps: PartitionSet, profiles: Sequence[DeviceProfile],
                 cfg: RapaConfig | None = None) -> RapaResult:
    """Paper Algorithm 2 — iterate Alg. 3 until balanced or stuck."""
    cfg = cfg or RapaConfig()
    assert len(profiles) == ps.num_parts
    states = _make_states(ps)
    p = ps.num_parts
    history: list[dict] = []

    def snapshot() -> dict:
        lam = np.array([_lambda(st, profiles[i], profiles, cfg, p)
                        for i, st in enumerate(states)])
        return {
            "lambda": lam.copy(),
            "std": float(lam.std()),
            "max": float(lam.max()),
            "nodes": [st.v_local for st in states],
            "edges": [st.e_all for st in states],
        }

    def objective(snap: dict) -> float:
        # Eq. 15: minimise lambda_max + Std(lambda)
        return snap["max"] + snap["std"]

    history.append(snapshot())
    best = (objective(history[0]),
            [st.removed.copy() for st in states])
    for _ in range(cfg.max_iters):
        r = adjust_subgraph(states, profiles, cfg)
        snap = snapshot()
        history.append(snap)
        if objective(snap) < best[0]:
            best = (objective(snap), [st.removed.copy() for st in states])
        lam = snap["lambda"]
        if lam.std() < cfg.epsilon_frac * max(lam.mean(), 1e-12):
            break
        if np.all(r == 1):
            break

    # halo pruning is monotone and cannot be undone within a sweep, so the
    # final iterate can overshoot (paper §6 acknowledges the limitation);
    # materialise the best iterate under the Eq. 15 objective instead.
    for st, rem in zip(states, best[1]):
        st.removed = rem
    history.append(snapshot())
    pruned = _rebuild(ps, states)
    lam = history[-1]["lambda"]
    return RapaResult(partition_set=pruned, history=history,
                      removed_per_part=[int(st.removed.sum()) for st in states],
                      lambda_final=lam)


def _rebuild(ps: PartitionSet, states: list[_PartState]) -> PartitionSet:
    """Materialise pruned partitions (drop removed halo replicas + edges)."""
    new_parts: list[Partition] = []
    for st in states:
        part = st.part
        keep_halo = ~st.removed
        new_halo = part.halo_nodes[keep_halo]
        new_owner = part.halo_owner[keep_halo]
        n_inner = part.n_inner
        # old local id -> new local id
        remap = -np.ones(part.n_local, dtype=np.int64)
        remap[:n_inner] = np.arange(n_inner)
        remap[n_inner + np.where(keep_halo)[0]] = n_inner + np.arange(new_halo.shape[0])
        lsrc, ldst = part.local_graph.edges()
        w = part.local_graph.edge_weight
        keep = (remap[lsrc] >= 0) & (remap[ldst] >= 0)
        lw = w[keep] if w is not None else None
        lg = csr_from_edges(remap[lsrc[keep]], remap[ldst[keep]],
                            n_inner + new_halo.shape[0], weight=lw)
        g2l = {int(v): int(i) for i, v in enumerate(part.inner_nodes)}
        g2l.update({int(v): n_inner + j for j, v in enumerate(new_halo)})
        new_parts.append(Partition(
            part_id=part.part_id, inner_nodes=part.inner_nodes,
            halo_nodes=new_halo, halo_owner=new_owner, local_graph=lg,
            global_to_local=g2l))
    return PartitionSet(graph=ps.graph, assign=ps.assign, parts=new_parts,
                        hops=ps.hops)
