"""Halo-vertex analytics (paper §3.4 Observations 1-2, Eq. 2).

Host-side numpy analysis feeding both the motivation benchmarks (Figs. 4-6)
and the JACA cache planner.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.partition import PartitionSet

__all__ = ["HaloStats", "halo_stats", "overlap_histogram", "duplicate_count"]


@dataclasses.dataclass(frozen=True)
class HaloStats:
    total_inner: int
    total_halo: int            # sum over partitions (with duplicates)
    unique_halo: int           # |union of halo sets|
    duplicates: int            # total_halo - unique_halo (Obs. 2 redundancy)
    halo_inner_ratio: float    # Obs. 1 metric
    overlap_mean: float
    overlap_max: int
    edge_cut: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def halo_stats(ps: PartitionSet) -> HaloStats:
    from repro.graph.partition import edge_cut as _cut
    r = ps.overlap_ratio()
    halo_union = ps.halo_union()
    total_halo = ps.total_halo()
    uniq = int(halo_union.shape[0])
    overlaps = r[halo_union] if uniq else np.zeros(0)
    return HaloStats(
        total_inner=ps.total_inner(),
        total_halo=total_halo,
        unique_halo=uniq,
        duplicates=total_halo - uniq,
        halo_inner_ratio=total_halo / max(1, ps.total_inner()),
        overlap_mean=float(overlaps.mean()) if uniq else 0.0,
        overlap_max=int(overlaps.max()) if uniq else 0,
        edge_cut=_cut(ps.graph, ps.assign),
    )


def overlap_histogram(ps: PartitionSet) -> np.ndarray:
    """hist[k] = #vertices appearing in exactly k partitions' halo sets."""
    r = ps.overlap_ratio()
    return np.bincount(r[r > 0], minlength=ps.num_parts + 1)


def duplicate_count(ps: PartitionSet) -> int:
    """Number of redundant halo replicas = sum_v max(0, R(v)-1)."""
    r = ps.overlap_ratio()
    return int(np.sum(np.maximum(r - 1, 0)))
