"""Bounded-staleness control (paper §4.2 'Staleness in CaPGNN', Thm. 1).

The runtime alternates between a *refresh* step (cached halo embeddings
re-synchronised) and *cached* steps (stale values reused).  The controller
decides which step to run; the fixed-period policy is the paper's; the
adaptive policy (paper §6 'Adaptive Staleness Control' future work) shrinks
the period when the measured embedding drift approaches the epsilon_H bound
— implemented here as a beyond-paper feature.

The controller also schedules *re-planning* for the online cache
adaptation loop (``repro.core.jaca.AdaptivePlanner``): tier membership may
only change at a refresh boundary (the refresh rewrites every cache row,
so a re-ranked plan never reads rows laid out by its predecessor), and
``replan_every`` thins that further to every k-th refresh — re-ranking
costs host time, so it should pay for itself in saved exchange rows.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["StalenessController", "theorem1_bound"]


@dataclasses.dataclass
class StalenessController:
    refresh_every: int = 4          # tau; 1 => fully synchronous
    adaptive: bool = False
    eps_h: float = 1.0              # target staleness bound on ||H - H_hat||_inf
    shrink: float = 0.5
    grow: float = 1.25
    min_period: int = 1
    max_period: int = 64
    replan_every: int = 1           # re-rank tiers every k-th refresh
    _step: int = 0
    _period: float = 0.0
    _refreshes: int = 0

    def __post_init__(self):
        self._period = float(self.refresh_every)

    def should_refresh(self) -> bool:
        """True if the upcoming step must be a refresh step."""
        return self._step % max(1, int(round(self._period))) == 0

    def should_replan(self) -> bool:
        """True if the upcoming step is a refresh boundary at which the
        adaptive planner may install a re-ranked plan.  Never true on the
        warm-up step (step 0's refresh populates the initial plan's
        caches), then every ``replan_every``-th refresh."""
        return (self.should_refresh() and self._step > 0
                and self._refreshes % max(1, self.replan_every) == 0)

    def observe(self, drift_inf_norm: float | None = None,
                refreshed: bool | None = None) -> None:
        """Advance one step; with ``adaptive``, tune the period from the
        measured ||H - H_hat||_inf drift of the last refresh.
        ``refreshed`` records whether the executed step actually was a
        refresh (defaults to what ``should_refresh`` prescribed)."""
        was_refresh = (self.should_refresh() if refreshed is None
                       else refreshed)
        if was_refresh:
            self._refreshes += 1
        self._step += 1
        if self.adaptive and drift_inf_norm is not None:
            if drift_inf_norm > self.eps_h:
                self._period = max(self.min_period, self._period * self.shrink)
            else:
                self._period = min(self.max_period, self._period * self.grow)

    @property
    def period(self) -> int:
        return max(1, int(round(self._period)))

    @property
    def step(self) -> int:
        return self._step


def theorem1_bound(loss_gap: float, rho: float, alpha: float, t: int) -> float:
    """Paper Eq. 9: E_R ||grad L(W_R)||_F^2 <= 2(L(W1)-L(W*))/sqrt(T) +
    rho*alpha/(2 sqrt(T)).  Used by the convergence benchmark to check the
    measured gradient norms sit under the theoretical envelope."""
    t = max(1, t)
    return 2.0 * loss_gap / np.sqrt(t) + rho * alpha / (2.0 * np.sqrt(t))
