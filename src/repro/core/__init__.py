"""CaPGNN core: halo analytics, JACA caching, RAPA partitioning, staleness."""
from .device_profile import (DeviceProfile, PROFILES, PAPER_GROUPS, TPU_V5E,
                             measure_profile, make_group, capability_weights,
                             detect_host_mem_gib)
from .halo import HaloStats, halo_stats, overlap_histogram, duplicate_count
from .jaca import (CacheCapacity, cal_capacity, CachePlan, WorkerCachePlan,
                   build_cache_plan, plan_hit_rate, simulate_policy_hit_rate,
                   comm_bytes_per_step, AdaptivePlanner, plan_from_membership,
                   ADAPTIVE_POLICIES)
from .rapa import (RapaConfig, RapaResult, comm_cost, comp_cost,
                   influence_scores, adjust_subgraph, do_partition,
                   memory_bytes, partition_lambdas)
from .staleness import StalenessController, theorem1_bound

__all__ = [
    "DeviceProfile", "PROFILES", "PAPER_GROUPS", "TPU_V5E", "measure_profile",
    "make_group", "capability_weights", "detect_host_mem_gib",
    "HaloStats", "halo_stats", "overlap_histogram", "duplicate_count",
    "CacheCapacity", "cal_capacity", "CachePlan", "WorkerCachePlan",
    "build_cache_plan", "plan_hit_rate", "simulate_policy_hit_rate",
    "comm_bytes_per_step", "AdaptivePlanner", "plan_from_membership",
    "ADAPTIVE_POLICIES",
    "RapaConfig", "RapaResult", "comm_cost", "comp_cost", "influence_scores",
    "adjust_subgraph", "do_partition", "memory_bytes", "partition_lambdas",
    "StalenessController", "theorem1_bound",
]
