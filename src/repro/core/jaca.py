"""JACA — Joint Adaptive Caching Algorithm (paper §4.2, Alg. 1, Eq. 2).

Two-level cache for halo vertex features/embeddings:

- **local cache**  — per-worker, device (HBM) resident, capacity ``C_GPU[i]``;
- **global cache** — shared across workers (CPU shared memory in the paper;
  here a genuinely host-resident tier when the runtimes run with
  ``features="host"`` — rows live in a
  :class:`repro.dist.host_store.HostFeatureStore` and are staged
  host→device per step — and a replicated device buffer refreshed by
  collective in the legacy device-resident mode), capacity ``C_CPU``
  charged against host RAM.

Full-batch training touches every halo vertex every epoch, so the paper
ranks candidates by the *static* *vertex overlap ratio* R(v) (Eq. 2) rather
than modelling a dynamic access stream.  We compile that ranking into a
:class:`CachePlan`:

- per worker, the halo set is split into ``local``, ``global`` and
  ``uncached`` tiers (priority order: highest R first into local, then
  global),
- the distributed step exchanges only ``uncached`` halos every iteration;
  cached tiers are *refreshed* every ``refresh_every`` iterations (bounded
  staleness, §4.2 "Staleness in CaPGNN"),
- therefore per-step communication volume is exactly measurable and hit
  rates are exact (they are plan properties, reported by
  :func:`plan_hit_rate`).

FIFO/LRU baselines (paper Figs. 15-16) are provided via a trace simulator
over the epoch access stream since those policies are genuinely dynamic.

**Online adaptation** (paper §4.2 "lightweight cache update"): the static
plan above is compiled once; :class:`AdaptivePlanner` makes the tiering a
*runtime* object.  It ingests per-halo access observations (and, for the
drift-aware policy, the per-row staleness drift the runtimes measure on
refresh steps), evolves FIFO/LRU/EWMA eviction state live, and
``replan()`` materialises the current cache content as a fresh
:class:`CachePlan`.  Compiled against a capacity-padded (slot-stable)
exchange layout, the new plan drops into the already-jitted sim/SPMD steps
without retracing — see ``repro.dist.exchange``.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Literal, Sequence

import numpy as np

from repro.graph.partition import PartitionSet
from .device_profile import DeviceProfile

__all__ = ["CacheCapacity", "cal_capacity", "CachePlan", "WorkerCachePlan",
           "build_cache_plan", "plan_hit_rate", "simulate_policy_hit_rate",
           "comm_bytes_per_step", "AdaptivePlanner", "plan_from_membership",
           "ADAPTIVE_POLICIES"]

Policy = Literal["overlap_high", "overlap_low", "random", "fifo", "lru"]

# runtime (online) eviction policies the AdaptivePlanner understands;
# "static" freezes the initial overlap plan (the paper's JACA baseline)
ADAPTIVE_POLICIES = ("static", "overlap", "fifo", "lru", "drift")


# ---------------------------------------------------------------------------
# Algorithm 1: adaptive cache capacity
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CacheCapacity:
    c_gpu: list[int]    # per-worker local-cache capacity (vertices)
    c_cpu: int          # shared global-cache capacity (vertices)


def cal_capacity(ps: PartitionSet, feat_dims: Sequence[int],
                 profiles: Sequence[DeviceProfile],
                 m_cpu_gib: float | None = None,
                 reserved_gpu_mib: float = 512.0,
                 reserved_cpu_mib: float = 1024.0,
                 top_k: int = -1,
                 reserve_partition: bool = True,
                 m_edge: int = 8) -> CacheCapacity:
    """Paper Algorithm 1 (``cal_capacity``).

    A cached vertex stores one row per layer of the feature dims in
    ``feat_dims`` (input features + per-layer embeddings), fp32.
    ``top_k`` limits candidates per partition (-1 = all halo vertices).

    ``m_cpu_gib`` budgets the shared CPU tier.  ``None`` (default) uses
    the profiles' measured ``host_mem_gib`` (the minimum across workers —
    the shared tier must fit every host), falling back to live detection
    via :func:`repro.core.device_profile.detect_host_mem_gib`; pass an
    explicit number to reproduce a fixed-budget experiment.

    ``reserve_partition=True`` sets the cache budget *jointly* with the
    partition sizes (§4.3): each worker's resident subgraph — its local
    vertices' feature/embedding rows plus ``m_edge`` bytes per local edge
    — is subtracted from device memory before the cache claims the rest,
    so with resource-aware uneven partitions big-memory devices absorb
    more cache residents and small devices don't overcommit.
    """
    if m_cpu_gib is None:
        host_gibs = [getattr(pr, "host_mem_gib", 0.0) or 0.0
                     for pr in profiles]
        if host_gibs and min(host_gibs) > 0.0:
            m_cpu_gib = float(min(host_gibs))
        else:
            from .device_profile import detect_host_mem_gib
            m_cpu_gib = detect_host_mem_gib()
    bytes_per_vertex = float(sum(d * 4 for d in feat_dims))
    c_gpu: list[int] = []
    h_cpu: set[int] = set()
    for i, part in enumerate(ps.parts):
        n_cand = part.n_halo if top_k < 0 else min(top_k, part.n_halo)
        avail = max(0.0, profiles[i].mem_gib * 1024.0 - reserved_gpu_mib) * 1024.0 ** 2
        if reserve_partition:
            resident = (part.n_local * bytes_per_vertex
                        + part.local_graph.num_edges * float(m_edge))
            avail = max(0.0, avail - resident)
        cap = int(min(avail // bytes_per_vertex, n_cand))
        c_gpu.append(cap)
        # candidates contribute to the CPU tier's working set
        h_cpu.update(int(v) for v in part.halo_nodes[:n_cand])
    avail_cpu = max(0.0, m_cpu_gib * 1024.0 - reserved_cpu_mib) * 1024.0 ** 2
    c_cpu = int(min(avail_cpu // bytes_per_vertex, len(h_cpu)))
    return CacheCapacity(c_gpu=c_gpu, c_cpu=c_cpu)


# ---------------------------------------------------------------------------
# Cache plan (static tiering by overlap ratio)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkerCachePlan:
    """Tiering of one worker's halo slots.

    All index arrays are *local halo positions* in ``[0, n_halo)`` (the
    partition's halo block is local ids ``n_inner + pos``).
    """
    part_id: int
    local_pos: np.ndarray      # cached in this worker's local (HBM) cache
    global_pos: np.ndarray     # served from the shared global cache
    uncached_pos: np.ndarray   # exchanged every step
    # global ids for each tier (same order as the pos arrays)
    local_gids: np.ndarray
    global_gids: np.ndarray
    uncached_gids: np.ndarray

    @property
    def n_halo(self) -> int:
        return (self.local_pos.size + self.global_pos.size
                + self.uncached_pos.size)


@dataclasses.dataclass(frozen=True)
class CachePlan:
    workers: list[WorkerCachePlan]
    capacity: CacheCapacity
    global_gids: np.ndarray    # unique gids resident in the global cache
    refresh_every: int         # staleness period tau (1 = always fresh)

    def worker(self, i: int) -> WorkerCachePlan:
        return self.workers[i]


def build_cache_plan(ps: PartitionSet, capacity: CacheCapacity,
                     refresh_every: int = 4,
                     policy: Policy = "overlap_high",
                     seed: int = 0) -> CachePlan:
    """Split each worker's halo set into local/global/uncached tiers.

    ``overlap_high`` is JACA (paper Eq. 2 priority).  ``overlap_low`` and
    ``random`` are the ablation orderings of Fig. 14.  (FIFO/LRU are
    runtime policies — see :func:`simulate_policy_hit_rate`.)
    """
    rng = np.random.default_rng(seed)
    overlap = ps.overlap_ratio()

    # Global tier: under JACA ('overlap_high') the C_CPU vertices with the
    # highest overlap across *all* partitions — exactly the ones whose dedup
    # saves the most (a vertex with R(v)=k would otherwise be sent k times).
    # The ablation orderings apply the same (inverted/random) priority here
    # too, so Fig. 14 compares full-policy against full-policy.
    halo_union = ps.halo_union()
    if policy == "overlap_low":
        order = np.argsort(overlap[halo_union], kind="stable")
    elif policy == "random":
        order = rng.permutation(halo_union.size)
    else:
        order = np.argsort(-overlap[halo_union], kind="stable")
    global_gids = halo_union[order][: capacity.c_cpu]
    global_set = set(int(v) for v in global_gids)

    workers: list[WorkerCachePlan] = []
    for i, part in enumerate(ps.parts):
        pos = np.arange(part.n_halo)
        gids = part.halo_nodes
        pri = overlap[gids].astype(np.float64)
        if policy == "overlap_high":
            rank = np.argsort(-pri, kind="stable")
        elif policy == "overlap_low":
            rank = np.argsort(pri, kind="stable")
        elif policy == "random":
            rank = rng.permutation(part.n_halo)
        else:
            raise ValueError(f"policy {policy!r} is a runtime policy; "
                             "use simulate_policy_hit_rate for it")
        c_local = min(capacity.c_gpu[i], part.n_halo)
        local_sel = rank[:c_local]
        rest = rank[c_local:]
        in_global = np.array([int(gids[p]) in global_set for p in rest],
                             dtype=bool) if rest.size else np.zeros(0, bool)
        global_sel = rest[in_global]
        uncached_sel = rest[~in_global]
        workers.append(WorkerCachePlan(
            part_id=i,
            local_pos=np.sort(pos[local_sel]),
            global_pos=np.sort(pos[global_sel]),
            uncached_pos=np.sort(pos[uncached_sel]),
            local_gids=gids[np.sort(pos[local_sel])],
            global_gids=gids[np.sort(pos[global_sel])],
            uncached_gids=gids[np.sort(pos[uncached_sel])],
        ))
    return CachePlan(workers=workers, capacity=capacity,
                     global_gids=global_gids, refresh_every=refresh_every)


def plan_hit_rate(plan: CachePlan) -> dict:
    """Exact hit rates of a static plan over one epoch (every halo touched).

    A 'hit' = halo access served from a cache tier instead of communicated.
    On refresh steps cached tiers are also communicated; the *amortised*
    hit rate accounts for that via refresh_every.
    """
    n_local = sum(w.local_pos.size for w in plan.workers)
    n_global = sum(w.global_pos.size for w in plan.workers)
    n_un = sum(w.uncached_pos.size for w in plan.workers)
    total = max(1, n_local + n_global + n_un)
    tau = plan.refresh_every
    amortised = (n_local + n_global) * (1.0 - 1.0 / max(1, tau)) / total
    return {
        "local_hit": n_local / total,
        "global_hit": n_global / total,
        "hit": (n_local + n_global) / total,
        "amortised_hit": amortised,
        "miss": n_un / total,
    }


# ---------------------------------------------------------------------------
# Dynamic policy baselines (FIFO / LRU) over the epoch access stream
# ---------------------------------------------------------------------------

def _epoch_stream(ps: PartitionSet, layers: int, seed: int) -> np.ndarray:
    """Access stream of one epoch: per layer, every partition touches all of
    its halo vertices (vertex-id order within partition, as the aggregation
    sweep does)."""
    rng = np.random.default_rng(seed)
    chunks = []
    for _ in range(layers):
        for part in ps.parts:
            chunks.append(part.halo_nodes)
    return np.concatenate(chunks) if chunks else np.zeros(0, np.int64)


def simulate_policy_hit_rate(ps: PartitionSet, capacity: int,
                             policy: Policy = "lru", layers: int = 3,
                             epochs: int = 3, seed: int = 0) -> float:
    """Trace-simulate FIFO/LRU (and the static policies for comparison) on
    the epoch access stream; returns overall hit rate (paper Fig. 15)."""
    stream = _epoch_stream(ps, layers, seed)
    if stream.size == 0:
        return 0.0
    if policy in ("overlap_high", "overlap_low", "random"):
        overlap = ps.overlap_ratio()
        uniq = np.unique(stream)
        pri = overlap[uniq].astype(float)
        rng = np.random.default_rng(seed)
        if policy == "overlap_high":
            order = np.argsort(-pri, kind="stable")
        elif policy == "overlap_low":
            order = np.argsort(pri, kind="stable")
        else:
            order = rng.permutation(uniq.size)
        cached = set(int(v) for v in uniq[order][:capacity])
        hits = sum(1 for _ in range(epochs) for v in stream if int(v) in cached)
        return hits / (epochs * stream.size)
    hits = 0
    if policy == "fifo":
        cache: set[int] = set()
        fifo: deque[int] = deque()
        for _ in range(epochs):
            for v in stream:
                v = int(v)
                if v in cache:
                    hits += 1
                else:
                    if len(cache) >= capacity and fifo:
                        cache.discard(fifo.popleft())
                    cache.add(v)
                    fifo.append(v)
    elif policy == "lru":
        lru: OrderedDict[int, None] = OrderedDict()
        for _ in range(epochs):
            for v in stream:
                v = int(v)
                if v in lru:
                    hits += 1
                    lru.move_to_end(v)
                else:
                    if len(lru) >= capacity:
                        lru.popitem(last=False)
                    lru[v] = None
    else:
        raise ValueError(policy)
    return hits / (epochs * stream.size)


def comm_bytes_per_step(plan: CachePlan, feat_dim: int,
                        dtype_bytes: int = 4) -> dict:
    """Exact communication volume implied by a plan (per training step).

    cached step: only uncached halos move.
    refresh step: all halos move (uncached + both cache tiers refresh), but
    global-tier rows are deduplicated — one broadcast row per unique
    *consumed* vertex instead of one copy per consumer partition (resident
    rows no worker reads are never refreshed).  These figures follow the
    paper's point-to-point transport model and equal the row counts of the
    compiled exchange plan's index sets
    (``repro.dist.ExchangePlan.bytes_per_step``, asserted by the tier-1
    suite).  The SPMD runtime's ``transport="p2p"`` (per-peer packed
    ``ppermute`` blocks) ships exactly these rows on the wire; only the
    legacy ``transport="allgather"`` broadcast moves more (~P x).
    ``dtype_bytes`` must be the actual halo payload width — 4 for f32,
    2 when the runtimes run with ``halo_dtype="bf16"``.
    """
    n_un = sum(w.uncached_pos.size for w in plan.workers)
    n_local = sum(w.local_pos.size for w in plan.workers)
    used_global = [w.global_gids for w in plan.workers if w.global_gids.size]
    n_global_dedup = (int(np.unique(np.concatenate(used_global)).size)
                      if used_global else 0)
    row = feat_dim * dtype_bytes
    cached_step = n_un * row
    refresh_step = (n_un + n_local + n_global_dedup) * row
    tau = max(1, plan.refresh_every)
    amortised = (cached_step * (tau - 1) + refresh_step) / tau
    no_cache = (n_un + n_local + sum(w.global_pos.size for w in plan.workers)) * row
    return {
        "cached_step_bytes": cached_step,
        "refresh_step_bytes": refresh_step,
        "amortised_bytes": amortised,
        "no_cache_bytes": no_cache,
        "reduction": 1.0 - amortised / max(1, no_cache),
    }


# ---------------------------------------------------------------------------
# Online adaptation: live eviction state -> re-ranked cache plans
# ---------------------------------------------------------------------------

def plan_from_membership(ps: PartitionSet, local_sets: Sequence[set],
                         global_set: set, capacity: CacheCapacity,
                         refresh_every: int) -> CachePlan:
    """Assemble a :class:`CachePlan` from explicit tier membership.

    ``local_sets[i]`` is worker ``i``'s local-cache gid set (must fit
    ``c_gpu[i]``), ``global_set`` the shared residency (must fit
    ``c_cpu``).  Per worker: halo positions whose gid is locally resident
    form the local tier; of the rest, those globally resident form the
    global tier; everything else is uncached — the same local-first
    priority :func:`build_cache_plan` applies.
    """
    workers: list[WorkerCachePlan] = []
    for i, part in enumerate(ps.parts):
        if len(local_sets[i]) > capacity.c_gpu[i]:
            raise ValueError(
                f"worker {i} local membership {len(local_sets[i])} exceeds "
                f"capacity {capacity.c_gpu[i]}")
        gids = part.halo_nodes
        pos = np.arange(part.n_halo)
        in_local = np.fromiter((int(v) in local_sets[i] for v in gids),
                               bool, count=part.n_halo) \
            if part.n_halo else np.zeros(0, bool)
        in_global = np.fromiter(
            (int(v) in global_set for v in gids), bool,
            count=part.n_halo) & ~in_local if part.n_halo \
            else np.zeros(0, bool)
        un = ~(in_local | in_global)
        workers.append(WorkerCachePlan(
            part_id=i,
            local_pos=pos[in_local], global_pos=pos[in_global],
            uncached_pos=pos[un],
            local_gids=gids[in_local], global_gids=gids[in_global],
            uncached_gids=gids[un]))
    if len(global_set) > capacity.c_cpu:
        raise ValueError(f"global membership {len(global_set)} exceeds "
                         f"capacity {capacity.c_cpu}")
    global_gids = np.array(sorted(int(v) for v in global_set), np.int64)
    return CachePlan(workers=workers, capacity=capacity,
                     global_gids=global_gids, refresh_every=refresh_every)


class _StreamCache:
    """Live FIFO/LRU eviction state over a gid access stream.

    ``access`` mirrors :func:`simulate_policy_hit_rate`'s trace loop
    statement-for-statement, so a planner fed the same epoch stream
    reproduces the simulator's hit sequence exactly (asserted by the
    tier-1 suite).  Capacity 0 disables the cache (always miss, no
    insert)."""

    def __init__(self, capacity: int, policy: str):
        if policy not in ("fifo", "lru"):
            raise ValueError(policy)
        self.capacity = int(capacity)
        self.policy = policy
        self._fifo: deque[int] = deque()
        self._set: set[int] = set()
        self._lru: OrderedDict[int, None] = OrderedDict()

    def access(self, v: int) -> bool:
        if self.capacity <= 0:
            return False
        if self.policy == "fifo":
            if v in self._set:
                return True
            if len(self._set) >= self.capacity and self._fifo:
                self._set.discard(self._fifo.popleft())
            self._set.add(v)
            self._fifo.append(v)
            return False
        if v in self._lru:
            self._lru.move_to_end(v)
            return True
        if len(self._lru) >= self.capacity:
            self._lru.popitem(last=False)
        self._lru[v] = None
        return False

    def resident(self) -> set:
        return set(self._set) if self.policy == "fifo" else set(self._lru)

    def resize(self, capacity: int) -> None:
        """Shrink (or grow) the live capacity, evicting in policy order —
        the memory-pressure backoff path
        (:meth:`AdaptivePlanner.shrink_capacity`)."""
        self.capacity = max(0, int(capacity))
        if self.policy == "fifo":
            while len(self._set) > self.capacity and self._fifo:
                self._set.discard(self._fifo.popleft())
        else:
            while len(self._lru) > self.capacity:
                self._lru.popitem(last=False)


@dataclasses.dataclass
class AdaptivePlanner:
    """Online cache adaptation: turn runtime access/drift observations into
    re-ranked :class:`CachePlan`\\ s at refresh boundaries.

    Policies (``--cache-policy`` in the launcher):

    - ``static``  — never re-ranks; :meth:`replan` returns the initial
      overlap plan unchanged (the paper's frozen JACA baseline);
    - ``overlap`` — re-runs the Eq. 2 overlap ranking (a no-op re-plan on
      a static graph: exercises the slot-stable swap path end-to-end);
    - ``fifo`` / ``lru`` — live eviction state per worker-local cache plus
      the shared global cache, exactly the trace semantics of
      :func:`simulate_policy_hit_rate`; :meth:`replan` materialises the
      current residents;
    - ``drift``   — ranks by an exponentially-weighted access frequency
      damped by the measured per-row staleness drift
      (``score = ewma_freq / (1 + drift_weight * ewma_drift)``): hot rows
      whose stale values stay accurate are the cheapest to cache under
      bounded staleness.

    The planner is pure numpy/python — observation costs are off the
    jitted step path, matching the paper's "lightweight cache update"
    claim.
    """
    ps: PartitionSet
    capacity: CacheCapacity
    refresh_every: int = 4
    policy: str = "lru"
    seed: int = 0
    decay: float = 0.8          # EWMA decay for access frequency / drift
    drift_weight: float = 1.0

    def __post_init__(self):
        if self.policy not in ADAPTIVE_POLICIES:
            raise ValueError(f"unknown adaptive policy {self.policy!r}; "
                             f"expected one of {ADAPTIVE_POLICIES}")
        n = self.ps.graph.num_nodes
        union = self.ps.halo_union()
        self.plan = build_cache_plan(self.ps, self.capacity,
                                     refresh_every=self.refresh_every,
                                     policy="overlap_high", seed=self.seed)
        self._initial = self.plan
        if self.policy in ("fifo", "lru"):
            self._local = [
                _StreamCache(min(self.capacity.c_gpu[i], pt.n_halo),
                             self.policy)
                for i, pt in enumerate(self.ps.parts)]
            self._global = _StreamCache(min(self.capacity.c_cpu, union.size),
                                        self.policy)
        else:
            self._local, self._global = None, None
        self._freq = np.zeros(n, np.float64)     # EWMA access frequency
        self._vdrift = np.zeros(n, np.float64)   # EWMA per-row value drift
        self._hits = 0
        self._accesses = 0
        self._steps = 0
        self._sync_membership()

    # -- observation ------------------------------------------------------

    def _sync_membership(self) -> None:
        self._local_sets = [set(int(v) for v in w.local_gids)
                            for w in self.plan.workers]
        self._global_plan_set = set()
        for w in self.plan.workers:
            self._global_plan_set.update(int(v) for v in w.global_gids)
        # sorted arrays for vectorized membership tests in observe_step
        self._local_sorted = [np.sort(w.local_gids)
                              for w in self.plan.workers]
        self._global_sorted = np.array(
            sorted(self._global_plan_set), np.int64)

    def observe_step(self, accessed: Sequence[np.ndarray] | None = None,
                     layers: int = 1) -> dict:
        """Ingest one step's halo accesses.

        ``accessed[i]`` is the gid array worker ``i`` touched (default: its
        full halo — exact for full-batch training, where every layer sweeps
        every halo vertex).  Per layer, workers are visited in partition
        order — the same stream order :func:`_epoch_stream` replays.
        Returns this call's ``{"accesses", "hits"}`` (cumulative counters
        feed :meth:`hit_rate`).  Hits are counted against the *live*
        eviction state for fifo/lru (simulator semantics) and against the
        installed plan's tiers for the plan-ranked policies.
        """
        if accessed is None:
            accessed = [pt.halo_nodes for pt in self.ps.parts]
        hits = accesses = 0
        decay_once = True
        for _ in range(max(1, layers)):
            for i, gids in enumerate(accessed):
                gids = np.asarray(gids)
                accesses += gids.size
                if self.policy in ("fifo", "lru"):
                    # per-access loop is load-bearing: the eviction state
                    # must evolve in stream order to stay bit-exact with
                    # the trace simulator
                    loc, glob = self._local[i], self._global
                    for v in gids:
                        v = int(v)
                        if loc.access(v):
                            hits += 1
                        elif glob.access(v):
                            hits += 1
                elif gids.size:
                    hit_mask = (np.isin(gids, self._local_sorted[i])
                                | np.isin(gids, self._global_sorted))
                    hits += int(hit_mask.sum())
            if decay_once:
                # EWMA frequency update: one decay per observed step, then
                # accumulate this step's multiplicity
                self._freq *= self.decay
                decay_once = False
            for gids in accessed:
                gids = np.asarray(gids)
                if gids.size:
                    np.add.at(self._freq, gids, 1.0)
        self._hits += hits
        self._accesses += accesses
        self._steps += 1
        return {"accesses": accesses, "hits": hits}

    def observe_drift(self, local_rows: np.ndarray,
                      global_rows: np.ndarray) -> None:
        """Fold a refresh step's per-row staleness drift (the runtimes'
        ``drift_local_rows [P, R]`` / ``drift_global_rows [G]`` metrics)
        into the per-vertex EWMA the ``drift`` policy ranks by.  Row order
        follows the *installed* plan: worker ``i``'s local rows are
        ``plan.workers[i].local_gids``; buffer rows are the sorted unique
        consumed global gids."""
        local_rows = np.asarray(local_rows, np.float64)
        self._vdrift *= self.decay
        for i, w in enumerate(self.plan.workers):
            k = w.local_gids.size
            if k:
                np.maximum.at(self._vdrift, w.local_gids,
                              (1 - self.decay) * local_rows[i, :k])
        used = [w.global_gids for w in self.plan.workers
                if w.global_gids.size]
        if used:
            buf_gids = np.unique(np.concatenate(used))
            rows = np.asarray(global_rows, np.float64)[: buf_gids.size]
            np.maximum.at(self._vdrift, buf_gids, (1 - self.decay) * rows)

    # -- re-planning ------------------------------------------------------

    def _ranked_plan(self, score: np.ndarray) -> CachePlan:
        """Top-score tiering under the capacity constraints (ties broken
        by gid for determinism)."""
        union = self.ps.halo_union()
        local_sets = []
        for i, pt in enumerate(self.ps.parts):
            gids = pt.halo_nodes
            c = min(self.capacity.c_gpu[i], pt.n_halo)
            order = np.argsort(-score[gids], kind="stable")
            local_sets.append(set(int(v) for v in gids[order[:c]]))
        c_cpu = min(self.capacity.c_cpu, union.size)
        order = np.argsort(-score[union], kind="stable")
        global_set = set(int(v) for v in union[order[:c_cpu]])
        return plan_from_membership(self.ps, local_sets, global_set,
                                    self.capacity, self.refresh_every)

    def replan(self) -> CachePlan:
        """Materialise the current eviction/ranking state as a new plan
        (and install it as the planner's reference membership)."""
        if self.policy == "static":
            return self.plan
        if self.policy == "overlap":
            new = build_cache_plan(self.ps, self.capacity,
                                   refresh_every=self.refresh_every,
                                   policy="overlap_high", seed=self.seed)
        elif self.policy in ("fifo", "lru"):
            local_sets = [c.resident() for c in self._local]
            glob = self._global.resident()
            new = plan_from_membership(self.ps, local_sets, glob,
                                       self.capacity, self.refresh_every)
        else:  # drift
            score = self._freq / (1.0 + self.drift_weight * self._vdrift)
            new = self._ranked_plan(score)
        self.plan = new
        self._sync_membership()
        return new

    def exchange_plan(self, plan: CachePlan | None = None):
        """Compile ``plan`` (default: the installed one) against the
        planner's slot-stable capacity padding — every plan this planner
        emits shares one shape signature, so swaps never retrace."""
        from repro.dist.exchange import build_exchange_plan, exchange_capacity
        if not hasattr(self, "_pad"):
            self._pad = exchange_capacity(self.ps, self.capacity)
        return build_exchange_plan(self.ps, plan or self.plan,
                                   pad_to=self._pad)

    def shrink_capacity(self, factor: float) -> CacheCapacity:
        """Memory-pressure backoff (:mod:`repro.faults`): scale every
        cache budget by ``factor`` and rebuild the planner state under
        the smaller budget, so the next :meth:`replan` emits a plan that
        fits.  The exchange padding is pinned to the *pre-shrink*
        capacity first — shrunk plans keep the original slot-stable
        shape signature, so installing them never retraces the step."""
        if not 0.0 < factor <= 1.0:
            raise ValueError(f"shrink factor must be in (0, 1], "
                             f"got {factor}")
        from repro.dist.exchange import exchange_capacity
        if not hasattr(self, "_pad"):
            self._pad = exchange_capacity(self.ps, self.capacity)
        self.capacity = CacheCapacity(
            c_gpu=[int(c * factor) for c in self.capacity.c_gpu],
            c_cpu=int(self.capacity.c_cpu * factor))
        if self.policy in ("fifo", "lru"):
            union = self.ps.halo_union()
            for i, pt in enumerate(self.ps.parts):
                self._local[i].resize(min(self.capacity.c_gpu[i],
                                          pt.n_halo))
            self._global.resize(min(self.capacity.c_cpu, union.size))
        if self.policy == "static":
            # static replan() returns the installed plan unchanged, so
            # the shrink must rebuild it here to be load-bearing
            self.plan = build_cache_plan(self.ps, self.capacity,
                                         refresh_every=self.refresh_every,
                                         policy="overlap_high",
                                         seed=self.seed)
            self._sync_membership()
        return self.capacity

    def hit_rate(self) -> float:
        """Cumulative hit rate over every observed access."""
        return self._hits / max(1, self._accesses)

    def counters(self) -> dict:
        """Snapshot of the cumulative hit accounting in the
        :mod:`repro.obs` schema — what the tracer's per-step
        ``planner_hit_rate`` counter track is derived from."""
        return {"hits": int(self._hits), "accesses": int(self._accesses),
                "steps": int(self._steps), "hit_rate": self.hit_rate()}
