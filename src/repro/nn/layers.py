"""Basic NN layer functions (params are plain dict pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dense", "layer_norm", "rms_norm", "dropout"]


def dense(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def layer_norm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = x32.var(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params.get("bias", 0.0)).astype(dtype)


def rms_norm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (y * params["scale"]).astype(dtype)


def dropout(key, x: jnp.ndarray, rate: float, deterministic: bool) -> jnp.ndarray:
    if deterministic or rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)
