from .init import glorot, he, normal_init, zeros_init
from .layers import dense, layer_norm, rms_norm, dropout

__all__ = ["glorot", "he", "normal_init", "zeros_init", "dense",
           "layer_norm", "rms_norm", "dropout"]
