"""Parameter initializers (pure functions over PRNG keys)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["glorot", "he", "normal_init", "zeros_init"]


def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    scale = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def he(key, shape, dtype=jnp.float32):
    fan_in = shape[-2]
    return jax.random.normal(key, shape, dtype) * jnp.sqrt(2.0 / fan_in)


def normal_init(key, shape, stddev=0.02, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * stddev


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)
