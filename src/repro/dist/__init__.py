"""CaPGNN partition-parallel runtime (paper §4-§5).

- :mod:`repro.dist.exchange` — compile a JACA cache plan into static
  gather/scatter index sets; stack partitions into the padded ``[P, ...]``
  layout.
- :mod:`repro.dist.capgnn_sim` — single-device stacked oracle runtime and
  the `train_capgnn` loop with exact byte accounting.
- :mod:`repro.dist.capgnn_spmd` — the same step functions lowered through
  ``shard_map`` collectives over a device mesh (flat or multi-pod).
"""
from .exchange import (ExchangePlan, ExchangeTier, GlobalTier, StackedEllPack,
                       StackedParts, build_exchange_plan, stack_partitions)
from .capgnn_sim import (SimRuntime, TrainReport, init_caches,
                         make_sim_runtime, train_capgnn)
from .capgnn_spmd import SpmdRuntime, make_spmd_runtime

__all__ = [
    "ExchangePlan", "ExchangeTier", "GlobalTier", "StackedEllPack",
    "StackedParts", "build_exchange_plan", "stack_partitions",
    "SimRuntime", "TrainReport", "init_caches", "make_sim_runtime",
    "train_capgnn",
    "SpmdRuntime", "make_spmd_runtime",
]
