"""CaPGNN partition-parallel runtime (paper §4-§5).

- :mod:`repro.dist.exchange` — compile a JACA cache plan into static
  gather/scatter index sets; stack partitions into the padded ``[P, ...]``
  layout.
- :mod:`repro.dist.capgnn_sim` — single-device stacked oracle runtime and
  the `train_capgnn` loop with exact byte accounting.
- :mod:`repro.dist.capgnn_spmd` — the same step functions lowered through
  ``shard_map`` collectives over a device mesh (flat or multi-pod).
- :mod:`repro.dist.host_store` — out-of-core host feature/embedding store
  with double-buffered host→device staged fetch, behind both runtimes'
  ``features="host"`` mode and the serve engine's host tier.
"""
from .exchange import (ExchangeCapacity, ExchangePlan, ExchangeTier,
                       GlobalTier, HostTier, StackedEllPack, StackedParts,
                       build_exchange_plan, exchange_capacity,
                       stack_partitions)
from .host_store import (HostFeatureStore, StagedFetch, halo_dtype_info,
                         suggest_prefetch_depth)
from .capgnn_sim import (RUNTIME_FEATURES, SimRuntime, TrainReport,
                         exchange_arrays, init_caches, make_sim_runtime,
                         train_capgnn)
from .capgnn_spmd import SpmdRuntime, make_spmd_runtime, spmd_exchange_arrays

__all__ = [
    "ExchangeCapacity", "ExchangePlan", "ExchangeTier", "GlobalTier",
    "HostTier", "StackedEllPack", "StackedParts", "build_exchange_plan",
    "exchange_capacity", "stack_partitions",
    "HostFeatureStore", "StagedFetch", "halo_dtype_info",
    "suggest_prefetch_depth",
    "RUNTIME_FEATURES", "SimRuntime", "TrainReport", "exchange_arrays",
    "init_caches", "make_sim_runtime", "train_capgnn",
    "SpmdRuntime", "make_spmd_runtime", "spmd_exchange_arrays",
]
