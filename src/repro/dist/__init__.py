"""CaPGNN partition-parallel runtime (paper §4-§5).

- :mod:`repro.dist.exchange` — compile a JACA cache plan into static
  gather/scatter index sets; stack partitions into the padded ``[P, ...]``
  layout.
- :mod:`repro.dist.capgnn_sim` — single-device stacked oracle runtime and
  the `train_capgnn` loop with exact byte accounting.
- :mod:`repro.dist.capgnn_spmd` — the same step functions lowered through
  ``shard_map`` collectives over a device mesh (flat or multi-pod).
"""
from .exchange import (ExchangeCapacity, ExchangePlan, ExchangeTier,
                       GlobalTier, StackedEllPack, StackedParts,
                       build_exchange_plan, exchange_capacity,
                       stack_partitions)
from .capgnn_sim import (SimRuntime, TrainReport, exchange_arrays,
                         init_caches, make_sim_runtime, train_capgnn)
from .capgnn_spmd import SpmdRuntime, make_spmd_runtime, spmd_exchange_arrays

__all__ = [
    "ExchangeCapacity", "ExchangePlan", "ExchangeTier", "GlobalTier",
    "StackedEllPack", "StackedParts", "build_exchange_plan",
    "exchange_capacity", "stack_partitions",
    "SimRuntime", "TrainReport", "exchange_arrays", "init_caches",
    "make_sim_runtime", "train_capgnn",
    "SpmdRuntime", "make_spmd_runtime", "spmd_exchange_arrays",
]
