"""CaPGNN partition-parallel runtime (paper §4-§5).

- :mod:`repro.dist.spec` — :class:`TrainSpec`, the validated,
  serialisable configuration surface every runtime builds through.
- :mod:`repro.dist.strategy` — the pluggable :class:`DistStrategy`
  interface (layout construction, per-layer collective steps, byte
  accounting) with the ``halo_1d`` implementation and registry.
- :mod:`repro.dist.strategy_15d` — the ``spmm_15d`` strategy:
  communication-avoiding 1.5D replicated-row block SpMM on a
  ``(grp, sub, repl)`` mesh.
- :mod:`repro.dist.exchange` — compile a JACA cache plan into static
  gather/scatter index sets; stack partitions into the padded ``[P, ...]``
  layout.
- :mod:`repro.dist.capgnn_sim` — single-device stacked oracle runtime and
  the `train_capgnn` loop with exact byte accounting.
- :mod:`repro.dist.capgnn_spmd` — the same step functions lowered through
  ``shard_map`` collectives over a device mesh (flat or multi-pod).
- :mod:`repro.dist.host_store` — out-of-core host feature/embedding store
  with double-buffered host→device staged fetch, behind both runtimes'
  ``features="host"`` mode and the serve engine's host tier.
"""
from .spec import (BACKENDS, CACHE_POLICIES, FEATURES, HALO_DTYPES,
                   TRANSPORTS, TrainSpec)
from .strategy import (STRATEGY_NAMES, DistStrategy, Halo1DStrategy,
                       HaloLayout, StrategyCapabilityError, StrategyCaps,
                       get_strategy)
from .exchange import (ExchangeCapacity, ExchangePlan, ExchangeTier,
                       GlobalTier, HostTier, StackedEllPack, StackedParts,
                       build_exchange_plan, exchange_capacity,
                       stack_partitions)
from .host_store import (HostFeatureStore, StagedFetch, halo_dtype_info,
                         suggest_prefetch_depth)
from .capgnn_sim import (RUNTIME_FEATURES, SimRuntime, TrainReport,
                         exchange_arrays, init_caches, make_sim_runtime,
                         train_capgnn)
from .capgnn_spmd import SpmdRuntime, make_spmd_runtime, spmd_exchange_arrays
from .strategy_15d import (Spmm15dLayout, Spmm15DStrategy, Spmm15dRuntime,
                           build_spmm15d_layout, make_spmm15d_mesh,
                           make_spmm15d_runtime, train_spmm15d)

__all__ = [
    "BACKENDS", "CACHE_POLICIES", "FEATURES", "HALO_DTYPES", "TRANSPORTS",
    "TrainSpec",
    "STRATEGY_NAMES", "DistStrategy", "Halo1DStrategy", "HaloLayout",
    "StrategyCapabilityError", "StrategyCaps", "get_strategy",
    "Spmm15dLayout", "Spmm15DStrategy", "Spmm15dRuntime",
    "build_spmm15d_layout", "make_spmm15d_mesh", "make_spmm15d_runtime",
    "train_spmm15d",
    "ExchangeCapacity", "ExchangePlan", "ExchangeTier", "GlobalTier",
    "HostTier", "StackedEllPack", "StackedParts", "build_exchange_plan",
    "exchange_capacity", "stack_partitions",
    "HostFeatureStore", "StagedFetch", "halo_dtype_info",
    "suggest_prefetch_depth",
    "RUNTIME_FEATURES", "SimRuntime", "TrainReport", "exchange_arrays",
    "init_caches", "make_sim_runtime", "train_capgnn",
    "SpmdRuntime", "make_spmd_runtime", "spmd_exchange_arrays",
]
