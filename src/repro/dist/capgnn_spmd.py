"""SPMD CaPGNN runtime: the stacked-oracle step functions lowered through
``shard_map`` over a device mesh, one partition per device.

Layout: every ``[P, ...]`` stacked array is sharded on its leading axis over
the mesh axis (or axis *tuple* — the §5.11-style multi-pod mesh shards the
partition dim over ``("pod", "data")``, linearised row-major, which is
exactly the order ``all_gather`` over that tuple reconstructs).  Parameters,
optimizer state and the deduplicated global-cache buffer are replicated.

Communication: each tier's owners pack their (deduplicated) send rows into a
dense payload and a single static-shape ``all_gather`` delivers every
payload to every consumer; consumers then address rows by
``(src_part, src_slot)``.  On cached steps only the uncached tier's payload
moves — the JACA tiers replace that collective entirely.  Loss and gradient
reductions are ``psum`` over the same axis tuple, so backprop through the
exchange (the ``all_gather`` transpose) reproduces the oracle's exact
cross-partition gradient flow.

Version note: ``shard_map`` is imported from ``jax.experimental.shard_map``
for compatibility with pre-``jax.shard_map`` releases.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):            # jax >= 0.5 exports it at top level
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map

from repro.models.gnn import GNNConfig, _layer_apply, accuracy, cross_entropy_loss
from repro.optim import Optimizer

from .capgnn_sim import init_caches, make_adj_builder
from .exchange import ExchangePlan, StackedParts

__all__ = ["make_spmd_runtime", "SpmdRuntime"]


@dataclasses.dataclass
class SpmdRuntime:
    cfg: GNNConfig
    xplan: ExchangePlan
    mesh: object
    axis_names: tuple
    comm_dims: list
    forward_fresh: Callable
    step_refresh: Callable
    step_cached: Callable
    step_pipelined: Callable
    evaluate: Callable
    caches0: dict
    backend: str = "edges"


def make_spmd_runtime(cfg: GNNConfig, sp: StackedParts, xplan: ExchangePlan,
                      opt: Optimizer, mesh, axis: str | Sequence[str] = "data",
                      exchange_layer0: bool = True, backend: str = "edges",
                      interpret: bool = True) -> SpmdRuntime:
    """``backend`` mirrors :func:`make_sim_runtime`: the per-device local
    aggregation runs through the edge-list segment-sum, the Pallas
    blocked-ELL kernel, or the hybrid ELL+COO pack — the exchange
    collectives and byte accounting are identical across backends."""
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    mesh_size = int(np.prod([mesh.shape[n] for n in names]))
    p, ni, nh = sp.num_parts, sp.n_inner_max, sp.n_halo_max
    if mesh_size != p:
        raise ValueError(f"mesh axes {names} have {mesh_size} devices but "
                         f"the plan has {p} partitions")
    layers = cfg.num_layers
    total_train = float(np.maximum(sp.train_mask.sum(), 1.0))
    adj_leaves, build_adj = make_adj_builder(sp, backend, interpret)

    # Sharded batch: leading dim = partition. Tier recv/read/send sides are
    # per-partition too, so they shard the same way.
    data_sh = {
        "feats": sp.feats, "halo_feats": sp.halo_feats,
        "labels": sp.labels.astype(np.int32),
        "train_mask": sp.train_mask, "val_mask": sp.val_mask,
        "test_mask": sp.test_mask,
        "adj": adj_leaves,
        "un": {"send_row": xplan.uncached.send_row,
               "recv_src_part": xplan.uncached.recv_src_part,
               "recv_src_slot": xplan.uncached.recv_src_slot,
               "recv_halo_pos": xplan.uncached.recv_halo_pos,
               "recv_valid": xplan.uncached.recv_valid},
        "loc": {"send_row": xplan.local.send_row,
                "recv_src_part": xplan.local.recv_src_part,
                "recv_src_slot": xplan.local.recv_src_slot,
                "recv_halo_pos": xplan.local.recv_halo_pos,
                "recv_valid": xplan.local.recv_valid},
        "gl": {"send_row": xplan.glob.send_row,
               "read_pos": xplan.glob.read_pos,
               "read_buf_idx": xplan.glob.read_buf_idx,
               "read_valid": xplan.glob.read_valid},
    }
    data_sh = jax.tree.map(jnp.asarray, data_sh)
    # Replicated: the global buffer's per-unique-vertex source addressing.
    data_rep = {"g_src_part": jnp.asarray(xplan.glob.src_part),
                "g_src_slot": jnp.asarray(xplan.glob.src_slot)}

    caches_spec = {"local": P(names), "global": P()}

    def _device_forward(params, caches, dsh, drep, use_stale: bool):
        """Per-device forward. ``dsh`` leaves carry a leading dim of 1."""
        feats = dsh["feats"][0]                       # [NI, F]
        halo0 = dsh["halo_feats"][0]                  # [NH, F]
        adj = build_adj({k: v[0] for k, v in dsh["adj"].items()})

        def pull(tier):
            def run(h):
                payload = h[tier["send_row"][0]]                  # [S, d]
                gathered = jax.lax.all_gather(payload, names)     # [P, S, d]
                rows = gathered[tier["recv_src_part"][0],
                                tier["recv_src_slot"][0]]         # [R, d]
                return jnp.where(tier["recv_valid"][0][..., None], rows, 0.0)
            return run

        def scatter(halo, pos, rows, valid):
            pos_eff = jnp.where(valid, pos, nh)
            return halo.at[pos_eff].set(rows, mode="drop")

        def build_global(h):
            payload = h[dsh["gl"]["send_row"][0]]                 # [SG, d]
            gathered = jax.lax.all_gather(payload, names)         # [P, SG, d]
            return gathered[drep["g_src_part"], drep["g_src_slot"]]

        pull_un = pull(dsh["un"])
        pull_loc = pull(dsh["loc"])

        h = feats
        fresh = {"local": [], "global": []}
        for li, lp in enumerate(params):
            if li == 0:
                halo = halo0
            else:
                d = h.shape[-1]
                halo = jnp.zeros((nh, d), h.dtype)
                halo = scatter(halo, dsh["un"]["recv_halo_pos"][0],
                               pull_un(h), dsh["un"]["recv_valid"][0])
                loc_fresh = pull_loc(h)
                buf_fresh = build_global(h)
                loc_use = (caches["local"][li - 1][0] if use_stale
                           else loc_fresh)
                buf_use = caches["global"][li - 1] if use_stale else buf_fresh
                halo = scatter(halo, dsh["loc"]["recv_halo_pos"][0], loc_use,
                               dsh["loc"]["recv_valid"][0])
                gl = dsh["gl"]
                halo = scatter(halo, gl["read_pos"][0],
                               buf_use[gl["read_buf_idx"][0]],
                               gl["read_valid"][0])
                fresh["local"].append(loc_fresh[None])
                fresh["global"].append(buf_fresh)
            h_local = jnp.concatenate([h, halo], axis=0)
            h = _layer_apply(cfg, lp, adj, h_local, ni,
                             is_last=(li == layers - 1))
        return h, fresh

    def _device_loss(params, caches, dsh, drep, use_stale: bool):
        logits, fresh = _device_forward(params, caches, dsh, drep, use_stale)
        labels = dsh["labels"][0]
        mask = dsh["train_mask"][0]
        logp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
        loss = jax.lax.psum(jnp.sum(nll * mask), names) / total_train
        return loss, (logits, fresh)

    def _make_step(use_stale: bool, emit_fresh: bool):
        def device_step(params, opt_state, caches, dsh, drep):
            (loss, (logits, fresh)), grads = jax.value_and_grad(
                _device_loss, has_aux=True)(params, caches, dsh, drep,
                                            use_stale)
            grads = jax.lax.psum(grads, names)
            new_params, new_state = opt.update(grads, opt_state, params)
            labels = dsh["labels"][0]
            mask = dsh["train_mask"][0]
            correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
            acc = jax.lax.psum(jnp.sum(correct * mask), names) / total_train
            metrics = {"loss": loss, "acc": acc}
            if emit_fresh:
                drifts = [jnp.max(jnp.abs(a - b)) for a, b in
                          zip(fresh["local"] + fresh["global"],
                              caches["local"] + caches["global"])
                          if a.size]
                local_max = (jnp.max(jnp.stack(drifts)) if drifts
                             else jnp.zeros(()))
                metrics["drift"] = jax.lax.pmax(local_max, names)
            out_caches = fresh if emit_fresh else caches
            return new_params, new_state, out_caches, metrics

        sm = shard_map(
            device_step, mesh=mesh,
            in_specs=(P(), P(), caches_spec, P(names), P()),
            out_specs=(P(), P(), caches_spec, P()),
            check_rep=False)

        @jax.jit
        def step(params, opt_state, caches):
            return sm(params, opt_state, caches, data_sh, data_rep)
        return step

    def _device_fwd_fresh(params, caches, dsh, drep):
        logits, _ = _device_forward(params, caches, dsh, drep, False)
        return logits[None]

    sm_fwd = shard_map(_device_fwd_fresh, mesh=mesh,
                       in_specs=(P(), caches_spec, P(names), P()),
                       out_specs=P(names), check_rep=False)
    caches0 = init_caches(cfg, xplan, p)

    @jax.jit
    def forward_fresh(params):
        return sm_fwd(params, caches0, data_sh, data_rep)

    labels_flat = jnp.asarray(sp.labels.astype(np.int32)).reshape(-1)
    masks_flat = {"train": jnp.asarray(sp.train_mask).reshape(-1),
                  "val": jnp.asarray(sp.val_mask).reshape(-1),
                  "test": jnp.asarray(sp.test_mask).reshape(-1)}

    def evaluate(params, split: str = "val"):
        flat = forward_fresh(params).reshape(-1, cfg.out_dim)
        m = masks_flat[split]
        return (float(cross_entropy_loss(flat, labels_flat, m)),
                float(accuracy(flat, labels_flat, m)))

    comm_dims = list(cfg.feat_dims[:layers])
    if not exchange_layer0:
        comm_dims = comm_dims[1:]

    return SpmdRuntime(cfg=cfg, xplan=xplan, mesh=mesh, axis_names=names,
                       comm_dims=comm_dims, forward_fresh=forward_fresh,
                       step_refresh=_make_step(False, True),
                       step_cached=_make_step(True, False),
                       step_pipelined=_make_step(True, True),
                       evaluate=evaluate, caches0=caches0, backend=backend)
