"""SPMD CaPGNN runtime: the stacked-oracle step functions lowered through
``shard_map`` over a device mesh, one partition per device.

Layout: every ``[P, ...]`` stacked array is sharded on its leading axis over
the mesh axis (or axis *tuple* — the §5.11-style multi-pod mesh shards the
partition dim over ``("pod", "data")``, linearised row-major, which is
exactly the order ``all_gather`` / the ``ppermute`` ring index over that
tuple reconstructs).  Parameters, optimizer state and the deduplicated
global-cache buffer are replicated.

Communication — two transports, selected by ``transport=``:

- ``"allgather"``: each tier's owners pack their (deduplicated) send rows
  into a dense payload and a single static-shape ``all_gather`` delivers
  every payload to every consumer; consumers address rows by
  ``(src_part, src_slot)``.  Simple, but wire volume is ~P x the paper's
  point-to-point accounting (replicas land on devices that never read
  them).
- ``"p2p"``: each owner re-packs its rows per destination
  (``peer_send_row``) and P-1 ``ppermute`` rotations ship block (i -> j)
  directly to j — static shapes, works on flat and multi-pod meshes, and
  each tier row crosses the wire exactly once per consumer, matching
  :meth:`~repro.dist.ExchangePlan.bytes_per_step` /
  :func:`repro.core.jaca.comm_bytes_per_step` exactly.  The global tier
  is a ring *broadcast* of the deduplicated buffer (it emulates the
  paper's CPU-shared cache: each unique row originates once).

On cached steps only the uncached tier moves — the JACA tiers replace that
traffic entirely.  ``step_pipelined`` consumes stale caches like
``step_cached`` but *additionally* refreshes them with a double-buffered
ring: the per-boundary refresh pulls are issued on the previous layer's
activations and advanced one rotation per layer while the SpMM computes,
finalising only after the last layer — nothing on the loss/grad critical
path waits for them (and no backward collectives are emitted for the
refreshed tiers), which is where the paper's pipeline hides the refresh
latency.  Loss and gradient reductions are ``psum`` over the same axis
tuple, so backprop through the exchange (``all_gather`` transpose /
inverse-permutation ``ppermute``) reproduces the oracle's exact
cross-partition gradient flow.

Version note: ``shard_map`` is imported from ``jax.experimental.shard_map``
for compatibility with pre-``jax.shard_map`` releases.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):            # jax >= 0.5 exports it at top level
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map

from repro.kernels.ops import pack_rows
from repro.models.gnn import GNNConfig, _layer_apply, accuracy, cross_entropy_loss
from repro.optim import Optimizer

from .capgnn_sim import halo_dtype_info, init_caches, make_adj_builder
from .exchange import ExchangePlan, StackedParts

__all__ = ["make_spmd_runtime", "SpmdRuntime", "TRANSPORTS",
           "spmd_exchange_arrays"]

TRANSPORTS = ("allgather", "p2p")


def spmd_exchange_arrays(xplan: ExchangePlan, p2p: bool) -> dict:
    """One plan's exchange index arrays in the SPMD runtime's layout:
    ``"sh"`` leaves are ``[P, ...]`` and sharded over the partition axis,
    ``"rep"`` leaves (the global buffer's source addressing) replicated.
    The jitted steps take this pytree as a traced argument, so a
    capacity-padded re-plan swaps in without retracing."""

    def tier_arrays(t):
        d = {"send_row": t.send_row,
             "recv_src_part": t.recv_src_part,
             "recv_src_slot": t.recv_src_slot,
             "recv_halo_pos": t.recv_halo_pos,
             "recv_valid": t.recv_valid}
        if p2p:
            d.update(peer_send_row=t.peer_send_row,
                     peer_send_valid=t.peer_send_valid,
                     recv_peer_slot=t.recv_peer_slot)
        return d

    sh = {"un": tier_arrays(xplan.uncached),
          "loc": tier_arrays(xplan.local),
          "gl": {"send_row": xplan.glob.send_row,
                 "read_pos": xplan.glob.read_pos,
                 "read_buf_idx": xplan.glob.read_buf_idx,
                 "read_valid": xplan.glob.read_valid}}
    rep = {"g_src_part": xplan.glob.src_part,
           "g_src_slot": xplan.glob.src_slot,
           "g_buf_valid": xplan.glob.buf_valid}
    return jax.tree.map(jnp.asarray, {"sh": sh, "rep": rep})


def _shift_perm(p: int, r: int) -> list:
    """Static permutation delivering device i's payload to (i + r) % p."""
    return [(s, (s + r) % p) for s in range(p)]


class _PeerRing:
    """P-1 ``ppermute`` rotations over a per-peer packed payload.

    ``payload[j]`` is the block this device ships to peer ``j``; after
    ``finish()``, ``blocks[o]`` holds the block peer ``o`` shipped to this
    device (own slot stays zero — a device never consumes its own halo
    rows).  Rotation ``r`` delivers block (i -> (i + r) % p) in one hop, so
    each row crosses the wire once per consumer.  The ring is advance-able
    one rotation at a time so the pipelined step can interleave rotations
    with layer compute in program order.
    """

    def __init__(self, payload: jnp.ndarray, i_dev, p: int, names):
        self.payload = payload                      # [P, B, d]
        self.i, self.p, self.names = i_dev, p, names
        self.blocks = jnp.zeros_like(payload)       # [P, B, d] by owner
        self.r = 0

    def advance(self, rotations: int = 1) -> "_PeerRing":
        for _ in range(rotations):
            if self.r >= self.p - 1:
                break
            self.r += 1
            send = jnp.take(self.payload, (self.i + self.r) % self.p, axis=0)
            recv = jax.lax.ppermute(send, self.names,
                                    _shift_perm(self.p, self.r))
            self.blocks = self.blocks.at[(self.i - self.r) % self.p].set(recv)
        return self

    def finish(self) -> jnp.ndarray:
        return self.advance(self.p).blocks


class _BufRing:
    """Ring broadcast of the deduplicated global-tier payload ``[SG, d]``:
    each owner's buffer originates once and circulates to all peers,
    accumulating the same ``[P, SG, d]`` an ``all_gather`` would build."""

    def __init__(self, payload: jnp.ndarray, i_dev, p: int, names):
        self.payload = payload
        self.i, self.p, self.names = i_dev, p, names
        acc = jnp.zeros((p,) + payload.shape, payload.dtype)
        self.acc = acc.at[i_dev].set(payload)
        self.r = 0

    def advance(self, rotations: int = 1) -> "_BufRing":
        for _ in range(rotations):
            if self.r >= self.p - 1:
                break
            self.r += 1
            recv = jax.lax.ppermute(self.payload, self.names,
                                    _shift_perm(self.p, self.r))
            self.acc = self.acc.at[(self.i - self.r) % self.p].set(recv)
        return self

    def finish(self) -> jnp.ndarray:
        return self.advance(self.p).acc


@dataclasses.dataclass
class SpmdRuntime:
    cfg: GNNConfig
    xplan: ExchangePlan
    mesh: object
    axis_names: tuple
    comm_dims: list
    forward_fresh: Callable
    step_refresh: Callable
    step_cached: Callable
    step_pipelined: Callable
    evaluate: Callable
    caches0: dict
    backend: str = "edges"
    transport: str = "allgather"
    halo_dtype_bytes: int = 4
    jit_steps: dict | None = dataclasses.field(default=None, repr=False)
    _state: dict | None = dataclasses.field(default=None, repr=False)
    # the stacked layout this runtime was built over — kept for padded-row
    # accounting under uneven (resource-aware) partitions
    stacked: StackedParts | None = dataclasses.field(default=None, repr=False)

    def padding_stats(self) -> dict:
        """Valid vs padded stacked-row counts (see
        :meth:`repro.dist.StackedParts.padding_stats`)."""
        return self.stacked.padding_stats() if self.stacked else {}

    def wire_rows(self, refresh: bool, padded: bool = False) -> dict:
        """Rows this runtime's transport moves in one layer exchange (see
        :meth:`repro.dist.ExchangePlan.transport_rows`)."""
        return self.xplan.transport_rows(self.transport, refresh,
                                         padded=padded)

    def set_plan(self, xplan: ExchangePlan) -> None:
        """Install a re-ranked plan (slot-stable capacity-padded layout:
        no retrace).  Cache content still follows the old tiering — the
        next step must refresh, or come from :meth:`step_transition`."""
        self.xplan = xplan
        self._state["xarr"] = spmd_exchange_arrays(
            xplan, p2p=self.transport == "p2p")

    def step_transition(self, params, opt_state, caches,
                        new_xplan: ExchangePlan):
        """Pipelined plan switch: stale consumption + uncached exchange
        run on the installed plan while the refresh rings prefetch the
        **new** plan's tier rows; the emitted caches are laid out for
        ``new_xplan``, which becomes the installed plan."""
        xe = spmd_exchange_arrays(new_xplan, p2p=self.transport == "p2p")
        out = self.jit_steps["pipelined"](params, opt_state, caches,
                                          self._state["xarr"], xe)
        self.xplan = new_xplan
        self._state["xarr"] = xe
        return out

    def lower_step(self, name: str, params, opt_state, caches):
        """Lower one jitted step flavour (``"refresh" | "cached" |
        "pipelined"``) with the installed plan's exchange arrays — for HLO
        inspection/cost tooling."""
        xa = self._state["xarr"]
        return self.jit_steps[name].lower(params, opt_state, caches, xa, xa)


def make_spmd_runtime(cfg: GNNConfig, sp: StackedParts, xplan: ExchangePlan,
                      opt: Optimizer, mesh, axis: str | Sequence[str] = "data",
                      exchange_layer0: bool = True, backend: str = "edges",
                      interpret: bool = True, transport: str = "allgather",
                      halo_dtype=None, donate: bool = True,
                      pallas_pack: bool = False) -> SpmdRuntime:
    """``backend`` mirrors :func:`make_sim_runtime`: the per-device local
    aggregation runs through the edge-list segment-sum, the Pallas
    blocked-ELL kernel, or the hybrid ELL+COO pack — the exchange
    collectives and byte accounting are identical across backends.

    ``transport`` picks the halo exchange lowering (see module docstring);
    ``"p2p"`` vs ``"allgather"`` logits and gradients agree to ~1e-5
    (asserted by ``tests/test_transport.py``).  ``halo_dtype="bf16"``
    casts every payload before the wire and dequantises on scatter.
    ``donate=True`` donates ``(params, opt_state, caches)`` into the
    jitted steps — re-use the returned state, not the arguments.
    ``pallas_pack=True`` routes the per-peer payload pack through the
    Pallas :func:`~repro.kernels.ops.gather_rows` kernel (TPU path).
    """
    if transport not in TRANSPORTS:
        raise ValueError(f"unknown transport {transport!r}; "
                         f"expected one of {TRANSPORTS}")
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    mesh_size = int(np.prod([mesh.shape[n] for n in names]))
    p, ni, nh = sp.num_parts, sp.n_inner_max, sp.n_halo_max
    if mesh_size != p:
        raise ValueError(f"mesh axes {names} have {mesh_size} devices but "
                         f"the plan has {p} partitions")
    layers = cfg.num_layers
    total_train = float(np.maximum(sp.train_mask.sum(), 1.0))
    adj_leaves, build_adj = make_adj_builder(sp, backend, interpret)
    hdt, hd_bytes = halo_dtype_info(halo_dtype)
    p2p = transport == "p2p"

    # Sharded batch: leading dim = partition.  The exchange index arrays
    # are NOT baked here — they travel as step arguments (xr/xe pytrees
    # from spmd_exchange_arrays) so online re-planning swaps them without
    # retracing.
    data_sh = {
        "feats": sp.feats, "halo_feats": sp.halo_feats,
        "labels": sp.labels.astype(np.int32),
        "train_mask": sp.train_mask, "val_mask": sp.val_mask,
        "test_mask": sp.test_mask,
        "adj": adj_leaves,
    }
    data_sh = jax.tree.map(jnp.asarray, data_sh)

    caches_spec = {"local": P(names), "global": P()}
    xarr_spec = {"sh": P(names), "rep": P()}

    def _quant(x):
        return x.astype(hdt) if hdt is not None else x

    def _device_forward(params, caches, dsh, xr, xe, use_stale: bool,
                        defer_refresh: bool = False):
        """Per-device forward. ``dsh``/``x*["sh"]`` leaves carry a leading
        dim of 1.

        ``xr`` is the installed (read) plan — stale cache consumption and
        the per-step uncached exchange run on it; ``xe`` is the emit plan
        whose tier rows the refresh pulls fetch.  They are the same arrays
        except on a plan-transition step, where the refresh
        prefetches the *next* plan's rows.

        ``defer_refresh`` (pipelined step, p2p transport): the local/global
        refresh pulls are issued as advance-able rings at their layer
        boundary, rotated once per layer while the SpMM computes, and
        finalised after the last layer — the layer math itself consumes
        the stale caches, so the rings never block it.
        """
        feats = dsh["feats"][0]                       # [NI, F]
        halo0 = dsh["halo_feats"][0]                  # [NH, F]
        adj = build_adj({k: v[0] for k, v in dsh["adj"].items()})
        i_dev = jax.lax.axis_index(names) if p2p else None

        def peer_ring(tier, h):
            payload = pack_rows(h, tier["peer_send_row"][0],
                                use_pallas=pallas_pack,
                                interpret=interpret)             # [P, B, d]
            payload = jnp.where(tier["peer_send_valid"][0][..., None],
                                payload, 0.0)
            return _PeerRing(_quant(payload), i_dev, p, names)

        def peer_collect(tier, blocks, dtype):
            rows = blocks[tier["recv_src_part"][0],
                          tier["recv_peer_slot"][0]].astype(dtype)
            return jnp.where(tier["recv_valid"][0][..., None], rows, 0.0)

        def pull(tier, h):
            """Fresh tier rows [R, d], transport run to completion."""
            if p2p:
                return peer_collect(tier, peer_ring(tier, h).finish(),
                                    h.dtype)
            payload = _quant(h[tier["send_row"][0]])              # [S, d]
            gathered = jax.lax.all_gather(payload, names)         # [P, S, d]
            rows = gathered[tier["recv_src_part"][0],
                            tier["recv_src_slot"][0]].astype(h.dtype)
            return jnp.where(tier["recv_valid"][0][..., None], rows, 0.0)

        def buf_ring(xa, h):
            return _BufRing(_quant(h[xa["sh"]["gl"]["send_row"][0]]), i_dev,
                            p, names)

        def buf_collect(xa, acc, dtype):
            rows = acc[xa["rep"]["g_src_part"],
                       xa["rep"]["g_src_slot"]].astype(dtype)
            return jnp.where(xa["rep"]["g_buf_valid"][:, None], rows, 0.0)

        def build_global(xa, h):
            if p2p:
                return buf_collect(xa, buf_ring(xa, h).finish(), h.dtype)
            payload = _quant(h[xa["sh"]["gl"]["send_row"][0]])    # [SG, d]
            gathered = jax.lax.all_gather(payload, names)         # [P, SG, d]
            return buf_collect(xa, gathered, h.dtype)

        def scatter(halo, pos, rows, valid):
            pos_eff = jnp.where(valid, pos, nh)
            return halo.at[pos_eff].set(rows, mode="drop")

        def read_global(gl, buf, halo):
            return scatter(halo, gl["read_pos"][0],
                           buf[gl["read_buf_idx"][0]], gl["read_valid"][0])

        h = feats
        fresh = {"local": [], "global": []}
        pending = []   # (dtype, local _PeerRing, global _BufRing)
        for li, lp in enumerate(params):
            if li == 0:
                halo = halo0
            else:
                d = h.shape[-1]
                halo = jnp.zeros((nh, d), h.dtype)
                un = xr["sh"]["un"]
                halo = scatter(halo, un["recv_halo_pos"][0], pull(un, h),
                               un["recv_valid"][0])
                if defer_refresh and p2p:
                    # issue this boundary's refresh rings on the EMIT plan;
                    # consume stale through the READ plan
                    pending.append((h.dtype, peer_ring(xe["sh"]["loc"], h),
                                    buf_ring(xe, h)))
                    loc_use, loc_t = caches["local"][li - 1][0], xr["sh"]["loc"]
                    buf_use, gl_t = caches["global"][li - 1], xr["sh"]["gl"]
                else:
                    loc_fresh = pull(xe["sh"]["loc"], h)
                    buf_fresh = build_global(xe, h)
                    if use_stale:
                        loc_use, loc_t = (caches["local"][li - 1][0],
                                          xr["sh"]["loc"])
                        buf_use, gl_t = caches["global"][li - 1], xr["sh"]["gl"]
                    else:
                        loc_use, loc_t = loc_fresh, xe["sh"]["loc"]
                        buf_use, gl_t = buf_fresh, xe["sh"]["gl"]
                    fresh["local"].append(loc_fresh[None])
                    fresh["global"].append(buf_fresh)
                halo = scatter(halo, loc_t["recv_halo_pos"][0], loc_use,
                               loc_t["recv_valid"][0])
                halo = read_global(gl_t, buf_use, halo)
            h_local = jnp.concatenate([h, halo], axis=0)
            h = _layer_apply(cfg, lp, adj, h_local, ni,
                             is_last=(li == layers - 1))
            # one ring rotation per in-flight refresh, placed right after
            # the layer's SpMM in program order so XLA's latency-hiding
            # scheduler can run the sends under the compute
            for _, lring, bring in pending:
                lring.advance()
                bring.advance()
        for dtype, lring, bring in pending:
            fresh["local"].append(
                peer_collect(xe["sh"]["loc"], lring.finish(), dtype)[None])
            fresh["global"].append(buf_collect(xe, bring.finish(), dtype))
        return h, fresh

    def _device_loss(params, caches, dsh, xr, xe, use_stale: bool,
                     defer_refresh: bool):
        """This device's share of the global mean loss.  The cross-device
        ``psum`` stays OUTSIDE the differentiated function: under
        ``shard_map`` the transpose of an in-loss ``psum`` is another
        ``psum``, so differentiating the summed loss and then psumming the
        grads double-counts by a factor P (the oracle-parity suite pins
        this with an sgd step, where adam's scale-invariant first step
        cannot mask it)."""
        logits, fresh = _device_forward(params, caches, dsh, xr, xe,
                                        use_stale, defer_refresh)
        labels = dsh["labels"][0]
        mask = dsh["train_mask"][0]
        logp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
        return jnp.sum(nll * mask) / total_train, (logits, fresh)

    def _make_step(use_stale: bool, emit_fresh: bool,
                   defer_refresh: bool = False):
        def device_step(params, opt_state, caches, dsh, xr, xe):
            (loss, (logits, fresh)), grads = jax.value_and_grad(
                _device_loss, has_aux=True)(params, caches, dsh, xr, xe,
                                            use_stale, defer_refresh)
            loss = jax.lax.psum(loss, names)
            grads = jax.lax.psum(grads, names)
            new_params, new_state = opt.update(grads, opt_state, params)
            labels = dsh["labels"][0]
            mask = dsh["train_mask"][0]
            correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
            acc = jax.lax.psum(jnp.sum(correct * mask), names) / total_train
            metrics = {"loss": loss, "acc": acc}
            if emit_fresh:
                pairs = list(zip(fresh["local"] + fresh["global"],
                                 caches["local"] + caches["global"]))
                drifts = [jnp.max(jnp.abs(a - b)) for a, b in pairs
                          if a.size]
                local_max = (jnp.max(jnp.stack(drifts)) if drifts
                             else jnp.zeros(()))
                metrics["drift"] = jax.lax.pmax(local_max, names)
                n_ex = len(fresh["local"])
                if n_ex:
                    # per-row drift stats for the drift-aware planner
                    metrics["drift_local_rows"] = jnp.max(jnp.stack(
                        [jnp.max(jnp.abs(a - b), axis=-1)
                         for a, b in pairs[:n_ex]]), axis=0)   # [1, Rloc]
                    metrics["drift_global_rows"] = jax.lax.pmax(
                        jnp.max(jnp.stack(
                            [jnp.max(jnp.abs(a - b), axis=-1)
                             for a, b in pairs[n_ex:]]), axis=0), names)
            out_caches = fresh if emit_fresh else caches
            return new_params, new_state, out_caches, metrics

        mspec = {"loss": P(), "acc": P()}
        if emit_fresh and layers > 1:
            mspec.update(drift=P(), drift_local_rows=P(names),
                         drift_global_rows=P())
        elif emit_fresh:
            mspec["drift"] = P()
        sm = shard_map(
            device_step, mesh=mesh,
            in_specs=(P(), P(), caches_spec, P(names), xarr_spec, xarr_spec),
            out_specs=(P(), P(), caches_spec, mspec),
            check_rep=False)

        def step(params, opt_state, caches, xr, xe):
            return sm(params, opt_state, caches, data_sh, xr, xe)
        # steady-state steps rewrite (params, opt_state, caches) in place;
        # the exchange arrays (xr, xe) are reused across steps, not donated
        return jax.jit(step, donate_argnums=(0, 1, 2) if donate else ())

    def _device_fwd_fresh(params, caches, dsh, xr):
        logits, _ = _device_forward(params, caches, dsh, xr, xr, False)
        return logits[None]

    sm_fwd = shard_map(_device_fwd_fresh, mesh=mesh,
                       in_specs=(P(), caches_spec, P(names), xarr_spec),
                       out_specs=P(names), check_rep=False)
    caches0 = init_caches(cfg, xplan, p)

    jit_steps = {"refresh": _make_step(False, True),
                 "cached": _make_step(True, False),
                 "pipelined": _make_step(True, True, defer_refresh=p2p),
                 "forward": jax.jit(
                     lambda params, xa: sm_fwd(params, caches0, data_sh, xa))}
    state = {"xarr": spmd_exchange_arrays(xplan, p2p=p2p)}

    def wrap(name):
        def stepper(params, opt_state, caches):
            xa = state["xarr"]
            return jit_steps[name](params, opt_state, caches, xa, xa)
        return stepper

    def forward_fresh(params):
        return jit_steps["forward"](params, state["xarr"])

    labels_flat = jnp.asarray(sp.labels.astype(np.int32)).reshape(-1)
    masks_flat = {"train": jnp.asarray(sp.train_mask).reshape(-1),
                  "val": jnp.asarray(sp.val_mask).reshape(-1),
                  "test": jnp.asarray(sp.test_mask).reshape(-1)}

    def evaluate(params, split: str = "val"):
        flat = forward_fresh(params).reshape(-1, cfg.out_dim)
        m = masks_flat[split]
        return (float(cross_entropy_loss(flat, labels_flat, m)),
                float(accuracy(flat, labels_flat, m)))

    comm_dims = list(cfg.feat_dims[:layers])
    if not exchange_layer0:
        comm_dims = comm_dims[1:]

    return SpmdRuntime(cfg=cfg, xplan=xplan, mesh=mesh, axis_names=names,
                       comm_dims=comm_dims, forward_fresh=forward_fresh,
                       step_refresh=wrap("refresh"),
                       step_cached=wrap("cached"),
                       step_pipelined=wrap("pipelined"),
                       evaluate=evaluate, caches0=caches0, backend=backend,
                       transport=transport, halo_dtype_bytes=hd_bytes,
                       jit_steps=jit_steps, _state=state, stacked=sp)
