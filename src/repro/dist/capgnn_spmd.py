"""SPMD CaPGNN runtime: the stacked-oracle step functions lowered through
``shard_map`` over a device mesh, one partition per device.

Layout: every ``[P, ...]`` stacked array is sharded on its leading axis over
the mesh axis (or axis *tuple* — the §5.11-style multi-pod mesh shards the
partition dim over ``("pod", "data")``, linearised row-major, which is
exactly the order ``all_gather`` / the ``ppermute`` ring index over that
tuple reconstructs).  Parameters, optimizer state and the deduplicated
global-cache buffer are replicated.

Communication — two transports, selected by ``transport=``:

- ``"allgather"``: each tier's owners pack their (deduplicated) send rows
  into a dense payload and a single static-shape ``all_gather`` delivers
  every payload to every consumer; consumers address rows by
  ``(src_part, src_slot)``.  Simple, but wire volume is ~P x the paper's
  point-to-point accounting (replicas land on devices that never read
  them).
- ``"p2p"``: each owner re-packs its rows per destination
  (``peer_send_row``) and P-1 ``ppermute`` rotations ship block (i -> j)
  directly to j — static shapes, works on flat and multi-pod meshes, and
  each tier row crosses the wire exactly once per consumer, matching
  :meth:`~repro.dist.ExchangePlan.bytes_per_step` /
  :func:`repro.core.jaca.comm_bytes_per_step` exactly.  The global tier
  is a ring *broadcast* of the deduplicated buffer (it emulates the
  paper's CPU-shared cache: each unique row originates once).

On cached steps only the uncached tier moves — the JACA tiers replace that
traffic entirely.  ``step_pipelined`` consumes stale caches like
``step_cached`` but *additionally* refreshes them with a double-buffered
ring: the per-boundary refresh pulls are issued on the previous layer's
activations and advanced one rotation per layer while the SpMM computes,
finalising only after the last layer — nothing on the loss/grad critical
path waits for them (and no backward collectives are emitted for the
refreshed tiers), which is where the paper's pipeline hides the refresh
latency.  Loss and gradient reductions are ``psum`` over the same axis
tuple, so backprop through the exchange (``all_gather`` transpose /
inverse-permutation ``ppermute``) reproduces the oracle's exact
cross-partition gradient flow.

Version note: ``shard_map`` is imported from ``jax.experimental.shard_map``
for compatibility with pre-``jax.shard_map`` releases.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):            # jax >= 0.5 exports it at top level
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map

from repro.kernels.ops import pack_rows
from repro.models.gnn import GNNConfig, _layer_apply, accuracy, cross_entropy_loss
from repro.obs.annotations import device_scope, host_annotation
from repro.obs.tracer import NULL_TRACER
from repro.optim import Optimizer

from .capgnn_sim import (RUNTIME_FEATURES, halo_dtype_info, init_caches,
                         make_adj_builder)
from .exchange import ExchangePlan, StackedParts
from .host_store import HostFeatureStore
from .spec import TrainSpec, halo_dtype_name, warn_loose_kwargs

__all__ = ["make_spmd_runtime", "SpmdRuntime", "TRANSPORTS",
           "spmd_exchange_arrays"]

TRANSPORTS = ("allgather", "p2p")


def spmd_exchange_arrays(xplan: ExchangePlan, p2p: bool,
                         include_host: bool = False) -> dict:
    """One plan's exchange index arrays in the SPMD runtime's layout:
    ``"sh"`` leaves are ``[P, ...]`` and sharded over the partition axis,
    ``"rep"`` leaves (the global buffer's source addressing) replicated.
    The jitted steps take this pytree as a traced argument, so a
    capacity-padded re-plan swaps in without retracing.  ``include_host``
    adds the layer-0 host-tier scatter program (sharded like the other
    per-worker tiers) for the ``features="host"`` runtimes."""

    def tier_arrays(t):
        d = {"send_row": t.send_row,
             "recv_src_part": t.recv_src_part,
             "recv_src_slot": t.recv_src_slot,
             "recv_halo_pos": t.recv_halo_pos,
             "recv_valid": t.recv_valid}
        if p2p:
            d.update(peer_send_row=t.peer_send_row,
                     peer_send_valid=t.peer_send_valid,
                     recv_peer_slot=t.recv_peer_slot)
        return d

    sh = {"un": tier_arrays(xplan.uncached),
          "loc": tier_arrays(xplan.local),
          "gl": {"send_row": xplan.glob.send_row,
                 "read_pos": xplan.glob.read_pos,
                 "read_buf_idx": xplan.glob.read_buf_idx,
                 "read_valid": xplan.glob.read_valid}}
    if include_host:
        if xplan.host is None:
            raise ValueError("features='host' needs a plan with a host "
                             "tier (rebuild via build_exchange_plan)")
        sh["host"] = {"feat_pos": xplan.host.feat_pos.astype(np.int32),
                      "feat_valid": xplan.host.feat_valid}
    rep = {"g_src_part": xplan.glob.src_part,
           "g_src_slot": xplan.glob.src_slot,
           "g_buf_valid": xplan.glob.buf_valid}
    return jax.tree.map(jnp.asarray, {"sh": sh, "rep": rep})


def _shift_perm(p: int, r: int) -> list:
    """Static permutation delivering device i's payload to (i + r) % p."""
    return [(s, (s + r) % p) for s in range(p)]


class _PeerRing:
    """P-1 ``ppermute`` rotations over a per-peer packed payload.

    ``payload[j]`` is the block this device ships to peer ``j``; after
    ``finish()``, ``blocks[o]`` holds the block peer ``o`` shipped to this
    device (own slot stays zero — a device never consumes its own halo
    rows).  Rotation ``r`` delivers block (i -> (i + r) % p) in one hop, so
    each row crosses the wire once per consumer.  The ring is advance-able
    one rotation at a time so the pipelined step can interleave rotations
    with layer compute in program order.
    """

    def __init__(self, payload: jnp.ndarray, i_dev, p: int, names):
        self.payload = payload                      # [P, B, d]
        self.i, self.p, self.names = i_dev, p, names
        self.blocks = jnp.zeros_like(payload)       # [P, B, d] by owner
        self.r = 0

    def advance(self, rotations: int = 1) -> "_PeerRing":
        for _ in range(rotations):
            if self.r >= self.p - 1:
                break
            self.r += 1
            send = jnp.take(self.payload, (self.i + self.r) % self.p, axis=0)
            recv = jax.lax.ppermute(send, self.names,
                                    _shift_perm(self.p, self.r))
            self.blocks = self.blocks.at[(self.i - self.r) % self.p].set(recv)
        return self

    def finish(self) -> jnp.ndarray:
        return self.advance(self.p).blocks


class _BufRing:
    """Ring broadcast of the deduplicated global-tier payload ``[SG, d]``:
    each owner's buffer originates once and circulates to all peers,
    accumulating the same ``[P, SG, d]`` an ``all_gather`` would build."""

    def __init__(self, payload: jnp.ndarray, i_dev, p: int, names):
        self.payload = payload
        self.i, self.p, self.names = i_dev, p, names
        acc = jnp.zeros((p,) + payload.shape, payload.dtype)
        self.acc = acc.at[i_dev].set(payload)
        self.r = 0

    def advance(self, rotations: int = 1) -> "_BufRing":
        for _ in range(rotations):
            if self.r >= self.p - 1:
                break
            self.r += 1
            recv = jax.lax.ppermute(self.payload, self.names,
                                    _shift_perm(self.p, self.r))
            self.acc = self.acc.at[(self.i - self.r) % self.p].set(recv)
        return self

    def finish(self) -> jnp.ndarray:
        return self.advance(self.p).acc


@dataclasses.dataclass
class SpmdRuntime:
    cfg: GNNConfig
    xplan: ExchangePlan
    mesh: object
    axis_names: tuple
    comm_dims: list
    forward_fresh: Callable
    step_refresh: Callable
    step_cached: Callable
    step_pipelined: Callable
    evaluate: Callable
    caches0: dict
    backend: str = "edges"
    transport: str = "allgather"
    halo_dtype_bytes: int = 4
    # feature residency — see :func:`repro.dist.make_sim_runtime`
    features: str = "device"
    host_store: HostFeatureStore | None = dataclasses.field(default=None,
                                                            repr=False)
    jit_steps: dict | None = dataclasses.field(default=None, repr=False)
    _state: dict | None = dataclasses.field(default=None, repr=False)
    # the stacked layout this runtime was built over — kept for padded-row
    # accounting under uneven (resource-aware) partitions
    stacked: StackedParts | None = dataclasses.field(default=None, repr=False)
    # the TrainSpec this runtime was configured from (always set — the
    # loose-kwarg shim synthesises one), recorded into TrainReport.spec
    spec: TrainSpec | None = dataclasses.field(default=None, repr=False)

    def padding_stats(self) -> dict:
        """Valid vs padded stacked-row counts (see
        :meth:`repro.dist.StackedParts.padding_stats`)."""
        return self.stacked.padding_stats() if self.stacked else {}

    def set_tracer(self, tracer) -> None:
        """Attach a :class:`repro.obs.Tracer` (see
        :meth:`repro.dist.SimRuntime.set_tracer`)."""
        if self._state is not None:
            self._state["tracer"] = tracer
        if self.host_store is not None:
            self.host_store.set_tracer(tracer)

    def set_fault_guard(self, guard) -> None:
        """Attach a :class:`repro.faults.FetchGuard` (see
        :meth:`repro.dist.SimRuntime.set_fault_guard`)."""
        if self._state is not None:
            self._state["fetch_guard"] = guard
            if guard is not None and "l0loc" in self._state:
                guard.last_good.setdefault("l0loc", self._state["l0loc"])

    def wire_rows(self, refresh: bool, padded: bool = False) -> dict:
        """Rows this runtime's transport moves in one layer exchange (see
        :meth:`repro.dist.ExchangePlan.transport_rows`)."""
        return self.xplan.transport_rows(self.transport, refresh,
                                         padded=padded)

    def set_plan(self, xplan: ExchangePlan) -> None:
        """Install a re-ranked plan (slot-stable capacity-padded layout:
        no retrace).  Cache content still follows the old tiering — the
        next step must refresh, or come from :meth:`step_transition`.
        Host mode additionally flushes the staging ring (unaccounted) and
        restages the layer-0 local tier for the new plan."""
        self.xplan = xplan
        hook = (self._state or {}).get("_set_plan")
        if hook is not None:
            hook(xplan)
        else:
            self._state["xarr"] = spmd_exchange_arrays(
                xplan, p2p=self.transport == "p2p")

    def step_transition(self, params, opt_state, caches,
                        new_xplan: ExchangePlan):
        """Pipelined plan switch: stale consumption + uncached exchange
        run on the installed plan while the refresh rings prefetch the
        **new** plan's tier rows; the emitted caches are laid out for
        ``new_xplan``, which becomes the installed plan.  Host-mode
        semantics mirror :meth:`repro.dist.SimRuntime.step_transition`."""
        hook = (self._state or {}).get("_transition")
        if hook is not None:
            out = hook(params, opt_state, caches, new_xplan)
        else:
            xe = spmd_exchange_arrays(new_xplan, p2p=self.transport == "p2p")
            out = self.jit_steps["pipelined"](params, opt_state, caches,
                                              self._state["xarr"], xe)
            self._state["xarr"] = xe
        self.xplan = new_xplan
        return out

    def lower_step(self, name: str, params, opt_state, caches):
        """Lower one jitted step flavour (``"refresh" | "cached" |
        "pipelined"``) with the installed plan's exchange arrays — for HLO
        inspection/cost tooling."""
        xa = self._state["xarr"]
        if self.features == "host":
            hd = self._state["_dummy_hostd"](name)
            return self.jit_steps[name].lower(params, opt_state, caches,
                                              hd, self._state["l0loc"],
                                              xa, xa)
        return self.jit_steps[name].lower(params, opt_state, caches, xa, xa)


def make_spmd_runtime(cfg: GNNConfig, sp: StackedParts, xplan: ExchangePlan,
                      opt: Optimizer, mesh, axis: str | Sequence[str] = "data",
                      exchange_layer0: bool = True, backend: str = "edges",
                      interpret: bool = True, transport: str = "allgather",
                      halo_dtype=None, donate: bool = True,
                      pallas_pack: bool = False, features: str = "device",
                      host_store: HostFeatureStore | None = None,
                      prefetch_depth: int = 2,
                      spec: TrainSpec | None = None) -> SpmdRuntime:
    """``backend`` mirrors :func:`make_sim_runtime`: the per-device local
    aggregation runs through the edge-list segment-sum, the Pallas
    blocked-ELL kernel, or the hybrid ELL+COO pack — the exchange
    collectives and byte accounting are identical across backends.

    ``transport`` picks the halo exchange lowering (see module docstring);
    ``"p2p"`` vs ``"allgather"`` logits and gradients agree to ~1e-5
    (asserted by ``tests/test_transport.py``).  ``halo_dtype="bf16"``
    casts every payload before the wire and dequantises on scatter.
    ``donate=True`` donates ``(params, opt_state, caches)`` into the
    jitted steps — re-use the returned state, not the arguments.
    ``pallas_pack=True`` routes the per-peer payload pack through the
    Pallas :func:`~repro.kernels.ops.gather_rows` kernel (TPU path).

    ``features="host"`` mirrors :func:`make_sim_runtime`'s out-of-core
    mode on the mesh: the halo table never ships to the devices — the
    layer-0 local tier is staged once per plan (sharded over the
    partition axis), the uncached+global layer-0 rows ride the store's
    double-buffered staging ring (the next step's ``device_put`` is in
    flight while the current step runs), and the per-layer global
    buffers are host-resident between steps (d2h writeback on refresh,
    replicated h2d stage for the stale reads).

    ``spec`` (a :class:`repro.dist.TrainSpec`) is the configuration
    surface; when passed it overrides every loose configuration kwarg
    (the deprecated shim forwards them into a synthesised spec with one
    ``DeprecationWarning`` — see the README migration note).  ``mesh``,
    ``axis`` and ``host_store`` stay real arguments: resources, not
    choices.
    """
    if spec is None:
        warn_loose_kwargs("make_spmd_runtime")
        spec = TrainSpec(strategy="halo_1d", backend=backend,
                         transport=transport, features=features,
                         halo_dtype=halo_dtype_name(halo_dtype),
                         exchange_layer0=exchange_layer0, donate=donate,
                         interpret=interpret, pallas_pack=pallas_pack,
                         prefetch_depth=prefetch_depth)
    exchange_layer0 = spec.exchange_layer0
    backend = spec.backend
    interpret = spec.interpret
    transport = spec.transport
    halo_dtype = spec.halo_dtype
    donate = spec.donate
    pallas_pack = spec.pallas_pack
    features = spec.features
    prefetch_depth = spec.prefetch_depth
    if transport not in TRANSPORTS:
        raise ValueError(f"unknown transport {transport!r}; "
                         f"expected one of {TRANSPORTS}")
    if features not in RUNTIME_FEATURES:
        raise ValueError(f"unknown features mode {features!r}; "
                         f"expected one of {RUNTIME_FEATURES}")
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    mesh_size = int(np.prod([mesh.shape[n] for n in names]))
    p, ni, nh = sp.num_parts, sp.n_inner_max, sp.n_halo_max
    if mesh_size != p:
        raise ValueError(f"mesh axes {names} have {mesh_size} devices but "
                         f"the plan has {p} partitions")
    layers = cfg.num_layers
    total_train = float(np.maximum(sp.train_mask.sum(), 1.0))
    adj_leaves, build_adj = make_adj_builder(sp, backend, interpret)
    hdt, hd_bytes = halo_dtype_info(halo_dtype)
    p2p = transport == "p2p"
    host_mode = features == "host"
    if host_mode:
        store = host_store if host_store is not None else HostFeatureStore(
            sp.halo_feats, halo_dtype=halo_dtype,
            prefetch_depth=prefetch_depth)
    else:
        store = None

    # Sharded batch: leading dim = partition.  The exchange index arrays
    # are NOT baked here — they travel as step arguments (xr/xe pytrees
    # from spmd_exchange_arrays) so online re-planning swaps them without
    # retracing.  In host mode the halo feature table stays host-side.
    data_sh = {
        "feats": sp.feats,
        "labels": sp.labels.astype(np.int32),
        "train_mask": sp.train_mask, "val_mask": sp.val_mask,
        "test_mask": sp.test_mask,
        "adj": adj_leaves,
    }
    if not host_mode:
        data_sh["halo_feats"] = sp.halo_feats
    data_sh = jax.tree.map(jnp.asarray, data_sh)

    caches_spec = {"local": P(names), "global": P()}
    xarr_spec = {"sh": P(names), "rep": P()}

    def _quant(x):
        return x.astype(hdt) if hdt is not None else x

    def _device_forward(params, caches, dsh, xr, xe, use_stale: bool,
                        defer_refresh: bool = False, hostd=None, l0loc=None):
        """Per-device forward. ``dsh``/``x*["sh"]`` leaves carry a leading
        dim of 1.

        ``xr`` is the installed (read) plan — stale cache consumption and
        the per-step uncached exchange run on it; ``xe`` is the emit plan
        whose tier rows the refresh pulls fetch.  They are the same arrays
        except on a plan-transition step, where the refresh
        prefetches the *next* plan's rows.

        ``defer_refresh`` (pipelined step, p2p transport): the local/global
        refresh pulls are issued as advance-able rings at their layer
        boundary, rotated once per layer while the SpMM computes, and
        finalised after the last layer — the layer math itself consumes
        the stale caches, so the rings never block it.

        In host mode the layer-0 halo is scattered from the staged
        payloads (``l0loc`` + ``hostd["l0"]``, sharded like the tiers)
        and stale global reads come from ``hostd["gl"]`` (replicated
        stage of the host-resident buffers) — mirroring the oracle.
        """
        feats = dsh["feats"][0]                       # [NI, F]
        halo0 = None if host_mode else dsh["halo_feats"][0]   # [NH, F]
        adj = build_adj({k: v[0] for k, v in dsh["adj"].items()})
        i_dev = jax.lax.axis_index(names) if p2p else None

        def peer_ring(tier, h):
            payload = pack_rows(h, tier["peer_send_row"][0],
                                use_pallas=pallas_pack,
                                interpret=interpret)             # [P, B, d]
            payload = jnp.where(tier["peer_send_valid"][0][..., None],
                                payload, 0.0)
            return _PeerRing(_quant(payload), i_dev, p, names)

        def peer_collect(tier, blocks, dtype):
            rows = blocks[tier["recv_src_part"][0],
                          tier["recv_peer_slot"][0]].astype(dtype)
            return jnp.where(tier["recv_valid"][0][..., None], rows, 0.0)

        def pull(tier, h):
            """Fresh tier rows [R, d], transport run to completion."""
            if p2p:
                return peer_collect(tier, peer_ring(tier, h).finish(),
                                    h.dtype)
            payload = _quant(h[tier["send_row"][0]])              # [S, d]
            gathered = jax.lax.all_gather(payload, names)         # [P, S, d]
            rows = gathered[tier["recv_src_part"][0],
                            tier["recv_src_slot"][0]].astype(h.dtype)
            return jnp.where(tier["recv_valid"][0][..., None], rows, 0.0)

        def buf_ring(xa, h):
            return _BufRing(_quant(h[xa["sh"]["gl"]["send_row"][0]]), i_dev,
                            p, names)

        def buf_collect(xa, acc, dtype):
            rows = acc[xa["rep"]["g_src_part"],
                       xa["rep"]["g_src_slot"]].astype(dtype)
            return jnp.where(xa["rep"]["g_buf_valid"][:, None], rows, 0.0)

        def build_global(xa, h):
            if p2p:
                return buf_collect(xa, buf_ring(xa, h).finish(), h.dtype)
            payload = _quant(h[xa["sh"]["gl"]["send_row"][0]])    # [SG, d]
            gathered = jax.lax.all_gather(payload, names)         # [P, SG, d]
            return buf_collect(xa, gathered, h.dtype)

        def scatter(halo, pos, rows, valid):
            pos_eff = jnp.where(valid, pos, nh)
            return halo.at[pos_eff].set(rows, mode="drop")

        def read_global(gl, buf, halo):
            return scatter(halo, gl["read_pos"][0],
                           buf[gl["read_buf_idx"][0]], gl["read_valid"][0])

        h = feats
        fresh = {"local": [], "global": []}
        pending = []   # (dtype, local _PeerRing, global _BufRing)
        for li, lp in enumerate(params):
            if li == 0:
                if host_mode:
                    halo = jnp.zeros((nh, feats.shape[-1]), feats.dtype)
                    loc_t = xr["sh"]["loc"]
                    halo = scatter(halo, loc_t["recv_halo_pos"][0],
                                   l0loc[0].astype(feats.dtype),
                                   loc_t["recv_valid"][0])
                    ht = xr["sh"]["host"]
                    halo = scatter(halo, ht["feat_pos"][0],
                                   hostd["l0"][0].astype(feats.dtype),
                                   ht["feat_valid"][0])
                else:
                    halo = halo0
            else:
                d = h.shape[-1]
                halo = jnp.zeros((nh, d), h.dtype)
                un = xr["sh"]["un"]
                with device_scope("tier_pull_uncached"):
                    halo = scatter(halo, un["recv_halo_pos"][0], pull(un, h),
                                   un["recv_valid"][0])
                stale_gl = (hostd["gl"][li - 1].astype(h.dtype) if host_mode
                            else caches["global"][li - 1]) if use_stale else None
                if defer_refresh and p2p:
                    # issue this boundary's refresh rings on the EMIT plan;
                    # consume stale through the READ plan
                    with device_scope("refresh_ring_issue"):
                        pending.append((h.dtype,
                                        peer_ring(xe["sh"]["loc"], h),
                                        buf_ring(xe, h)))
                    loc_use, loc_t = caches["local"][li - 1][0], xr["sh"]["loc"]
                    buf_use, gl_t = stale_gl, xr["sh"]["gl"]
                else:
                    with device_scope("tier_pull_refresh"):
                        loc_fresh = pull(xe["sh"]["loc"], h)
                        buf_fresh = build_global(xe, h)
                    if use_stale:
                        loc_use, loc_t = (caches["local"][li - 1][0],
                                          xr["sh"]["loc"])
                        buf_use, gl_t = stale_gl, xr["sh"]["gl"]
                    else:
                        loc_use, loc_t = loc_fresh, xe["sh"]["loc"]
                        buf_use, gl_t = buf_fresh, xe["sh"]["gl"]
                    fresh["local"].append(loc_fresh[None])
                    fresh["global"].append(buf_fresh)
                halo = scatter(halo, loc_t["recv_halo_pos"][0], loc_use,
                               loc_t["recv_valid"][0])
                halo = read_global(gl_t, buf_use, halo)
            h_local = jnp.concatenate([h, halo], axis=0)
            with device_scope(f"layer{li}/spmm"):
                h = _layer_apply(cfg, lp, adj, h_local, ni,
                                 is_last=(li == layers - 1))
            # one ring rotation per in-flight refresh, placed right after
            # the layer's SpMM in program order so XLA's latency-hiding
            # scheduler can run the sends under the compute
            with device_scope("refresh_ring_advance"):
                for _, lring, bring in pending:
                    lring.advance()
                    bring.advance()
        with device_scope("refresh_ring_finish"):
            for dtype, lring, bring in pending:
                fresh["local"].append(
                    peer_collect(xe["sh"]["loc"], lring.finish(),
                                 dtype)[None])
                fresh["global"].append(buf_collect(xe, bring.finish(),
                                                   dtype))
        return h, fresh

    def _device_loss(params, caches, dsh, xr, xe, use_stale: bool,
                     defer_refresh: bool, hostd=None, l0loc=None):
        """This device's share of the global mean loss.  The cross-device
        ``psum`` stays OUTSIDE the differentiated function: under
        ``shard_map`` the transpose of an in-loss ``psum`` is another
        ``psum``, so differentiating the summed loss and then psumming the
        grads double-counts by a factor P (the oracle-parity suite pins
        this with an sgd step, where adam's scale-invariant first step
        cannot mask it)."""
        logits, fresh = _device_forward(params, caches, dsh, xr, xe,
                                        use_stale, defer_refresh,
                                        hostd, l0loc)
        labels = dsh["labels"][0]
        mask = dsh["train_mask"][0]
        logp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
        return jnp.sum(nll * mask) / total_train, (logits, fresh)

    def _make_step(use_stale: bool, emit_fresh: bool,
                   defer_refresh: bool = False):
        def device_step(params, opt_state, caches, dsh, xr, xe,
                        hostd=None, l0loc=None):
            (loss, (logits, fresh)), grads = jax.value_and_grad(
                _device_loss, has_aux=True)(params, caches, dsh, xr, xe,
                                            use_stale, defer_refresh,
                                            hostd, l0loc)
            loss = jax.lax.psum(loss, names)
            grads = jax.lax.psum(grads, names)
            new_params, new_state = opt.update(grads, opt_state, params)
            labels = dsh["labels"][0]
            mask = dsh["train_mask"][0]
            correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
            acc = jax.lax.psum(jnp.sum(correct * mask), names) / total_train
            metrics = {"loss": loss, "acc": acc}
            # host refresh has no staged stale global to drift against —
            # the keys are not emitted there (mirrors the oracle runtime)
            if emit_fresh and (use_stale or not host_mode):
                stale_gl = ([g.astype(jnp.float32) for g in hostd["gl"]]
                            if host_mode else caches["global"])
                pairs = list(zip(fresh["local"] + fresh["global"],
                                 caches["local"] + stale_gl))
                drifts = [jnp.max(jnp.abs(a - b)) for a, b in pairs
                          if a.size]
                local_max = (jnp.max(jnp.stack(drifts)) if drifts
                             else jnp.zeros(()))
                metrics["drift"] = jax.lax.pmax(local_max, names)
                n_ex = len(fresh["local"])
                if n_ex:
                    # per-row drift stats for the drift-aware planner
                    metrics["drift_local_rows"] = jnp.max(jnp.stack(
                        [jnp.max(jnp.abs(a - b), axis=-1)
                         for a, b in pairs[:n_ex]]), axis=0)   # [1, Rloc]
                    metrics["drift_global_rows"] = jax.lax.pmax(
                        jnp.max(jnp.stack(
                            [jnp.max(jnp.abs(a - b), axis=-1)
                             for a, b in pairs[n_ex:]]), axis=0), names)
            if host_mode:
                out_caches = {"local": (fresh["local"] if emit_fresh
                                        else caches["local"]),
                              "global": []}
            else:
                out_caches = fresh if emit_fresh else caches
            if host_mode and emit_fresh:
                # fresh global buffers return to the host store (d2h by
                # the wrapper), not into replicated device caches
                return (new_params, new_state, out_caches,
                        fresh["global"], metrics)
            return new_params, new_state, out_caches, metrics

        mspec = {"loss": P(), "acc": P()}
        emit_drift = emit_fresh and (use_stale or not host_mode)
        if emit_drift and layers > 1:
            mspec.update(drift=P(), drift_local_rows=P(names),
                         drift_global_rows=P())
        elif emit_drift:
            mspec["drift"] = P()
        host_caches_spec = {"local": P(names), "global": P()}
        if host_mode:
            hostd_spec = ({"l0": P(names), "gl": P()} if use_stale
                          else {"l0": P(names)})
            out_specs = (P(), P(), host_caches_spec, mspec)
            if emit_fresh:
                out_specs = (P(), P(), host_caches_spec, P(), mspec)
            sm = shard_map(
                device_step, mesh=mesh,
                in_specs=(P(), P(), caches_spec, P(names), xarr_spec,
                          xarr_spec, hostd_spec, P(names)),
                out_specs=out_specs, check_rep=False)

            def step(params, opt_state, caches, hostd, l0loc, xr, xe):
                return sm(params, opt_state, caches, data_sh, xr, xe,
                          hostd, l0loc)
            # the staged hostd payloads are single-use but never match an
            # output shape, so they are not donated (mirrors the oracle)
            return jax.jit(step, donate_argnums=(0, 1, 2) if donate else ())

        sm = shard_map(
            device_step, mesh=mesh,
            in_specs=(P(), P(), caches_spec, P(names), xarr_spec, xarr_spec),
            out_specs=(P(), P(), caches_spec, mspec),
            check_rep=False)

        def step(params, opt_state, caches, xr, xe):
            return sm(params, opt_state, caches, data_sh, xr, xe)
        # steady-state steps rewrite (params, opt_state, caches) in place;
        # the exchange arrays (xr, xe) are reused across steps, not donated
        return jax.jit(step, donate_argnums=(0, 1, 2) if donate else ())

    if host_mode:
        def _device_fwd_fresh(params, caches, dsh, xr, hostd, l0loc):
            logits, _ = _device_forward(params, caches, dsh, xr, xr, False,
                                        hostd=hostd, l0loc=l0loc)
            return logits[None]

        sm_fwd = shard_map(_device_fwd_fresh, mesh=mesh,
                           in_specs=(P(), caches_spec, P(names), xarr_spec,
                                     {"l0": P(names)}, P(names)),
                           out_specs=P(names), check_rep=False)
    else:
        def _device_fwd_fresh(params, caches, dsh, xr):
            logits, _ = _device_forward(params, caches, dsh, xr, xr, False)
            return logits[None]

        sm_fwd = shard_map(_device_fwd_fresh, mesh=mesh,
                           in_specs=(P(), caches_spec, P(names), xarr_spec),
                           out_specs=P(names), check_rep=False)
    caches0 = init_caches(cfg, xplan, p, features=features)

    jit_steps = {"refresh": _make_step(False, True),
                 "cached": _make_step(True, False),
                 "pipelined": _make_step(True, True, defer_refresh=p2p)}
    if host_mode:
        jit_steps["forward"] = jax.jit(
            lambda params, hd, l0loc, xa: sm_fwd(params, caches0, data_sh,
                                                 xa, hd, l0loc))
    else:
        jit_steps["forward"] = jax.jit(
            lambda params, xa: sm_fwd(params, caches0, data_sh, xa))
    state = {"xarr": spmd_exchange_arrays(xplan, p2p=p2p,
                                          include_host=host_mode),
             "tracer": NULL_TRACER}

    def wrap(name):
        ann = f"capgnn/step_{name}"

        def stepper(params, opt_state, caches):
            xa = state["xarr"]
            with host_annotation(ann):
                return jit_steps[name](params, opt_state, caches, xa, xa)
        return stepper

    if host_mode:
        n_ex = layers - 1
        ex_dims = list(cfg.feat_dims[1:layers])
        parts_idx = np.arange(p)[:, None]
        staged_dtype = hdt if hdt is not None else jnp.float32
        shard_parts = NamedSharding(mesh, P(names))
        shard_rep = NamedSharding(mesh, P())

        def _host_np(xp: ExchangePlan) -> dict:
            return {"feat_pos": np.asarray(xp.host.feat_pos, np.int64),
                    "feat_valid": np.asarray(xp.host.feat_valid, bool),
                    "loc_pos": np.asarray(xp.local.recv_halo_pos, np.int64),
                    "loc_valid": np.asarray(xp.local.recv_valid, bool),
                    "gl_rows": int(xp.glob.n_unique)}

        def _stage_l0loc():
            hn = state["hostnp"]

            def stage():
                return store.stage_rows((parts_idx, hn["loc_pos"]),
                                        valid=hn["loc_valid"],
                                        device=shard_parts)
            g = state.get("fetch_guard")
            if g is None:
                sf = stage()
                store.account_fetch(sf)
                state["l0loc"] = sf.array
            else:
                state["l0loc"] = g.fetch_sync(stage, store, "l0loc")

        def _stage_l0():
            hn = state["hostnp"]
            return store.stage_rows((parts_idx, hn["feat_pos"]),
                                    valid=hn["feat_valid"],
                                    device=shard_parts)

        def _take_l0():
            # fault-guard semantics mirror the sim runtime's _take_l0
            ring = state["l0_ring"]
            g = state.get("fetch_guard")
            if g is None:
                sf = ring.popleft() if ring else _stage_l0()
                store.account_fetch(sf)
                return sf.array
            if ring:
                return g.consume(ring.popleft(), store, "l0")
            return g.fetch_sync(_stage_l0, store, "l0")

        def _prefetch_l0():
            ring = state["l0_ring"]
            g = state.get("fetch_guard")
            if g is not None and not g.prefetch_ok():
                return
            while len(ring) < max(1, store.prefetch_depth - 1):
                if g is None:
                    ring.append(_stage_l0())
                else:
                    sf = g.try_stage(_stage_l0)
                    if sf is None:
                        return
                    ring.append(sf)

        def _take_gl():
            g = state.get("fetch_guard")
            out = []
            for li in range(n_ex):
                if g is None:
                    sf = store.stage_buf(li, device=shard_rep)
                    store.account_fetch(sf)
                    out.append(sf.array)
                else:
                    out.append(g.fetch_sync(
                        lambda li=li: store.stage_buf(li, device=shard_rep),
                        store, f"gl{li}"))
            return out

        def _writeback(host_out):
            for li, buf in enumerate(host_out):
                store.write_buf(li, buf, state["hostnp"]["gl_rows"])

        state["hostnp"] = _host_np(xplan)
        state["l0_ring"] = deque()
        _stage_l0loc()
        for li, d in enumerate(ex_dims):
            store.init_buf(li, (xplan.glob.buf_size, d),
                           xplan.glob.n_unique)

        def wrap_host(name):
            use_gl = name in ("cached", "pipelined")
            emit = name in ("refresh", "pipelined")
            ann = f"capgnn/step_{name}"

            def stepper(params, opt_state, caches):
                tr = state["tracer"]
                with tr.span("l0_stage"):
                    hostd = {"l0": _take_l0()}
                    if use_gl:
                        hostd["gl"] = _take_gl()
                xa = state["xarr"]
                with host_annotation(ann):
                    out = jit_steps[name](params, opt_state, caches, hostd,
                                          state["l0loc"], xa, xa)
                if emit:
                    new_p, new_s, out_caches, host_out, metrics = out
                    with tr.span("writeback"):
                        _writeback(host_out)
                    out = (new_p, new_s, out_caches, metrics)
                with tr.span("h2d_prefetch"):
                    _prefetch_l0()
                return out
            return stepper

        def _set_plan(xp: ExchangePlan):
            tr = state["tracer"]
            state["xarr"] = spmd_exchange_arrays(xp, p2p=p2p,
                                                 include_host=True)
            state["hostnp"] = _host_np(xp)
            state["l0_ring"].clear()     # flushed, never accounted
            with tr.span("l0_stage"):
                _stage_l0loc()
            with tr.span("h2d_prefetch"):
                _prefetch_l0()
        state["_set_plan"] = _set_plan

        def _transition(params, opt_state, caches, new_xp: ExchangePlan):
            tr = state["tracer"]
            with tr.span("l0_stage"):
                hostd = {"l0": _take_l0(), "gl": _take_gl()}
            xr = state["xarr"]
            xe = spmd_exchange_arrays(new_xp, p2p=p2p, include_host=True)
            with host_annotation("capgnn/step_transition"):
                new_p, new_s, out_caches, host_out, metrics = (
                    jit_steps["pipelined"](params, opt_state, caches, hostd,
                                           state["l0loc"], xr, xe))
            state["xarr"] = xe
            state["hostnp"] = _host_np(new_xp)
            with tr.span("writeback"):
                _writeback(host_out)     # new plan's membership
            state["l0_ring"].clear()
            with tr.span("l0_stage"):
                _stage_l0loc()
            with tr.span("h2d_prefetch"):
                _prefetch_l0()
            return new_p, new_s, out_caches, metrics
        state["_transition"] = _transition

        def _dummy_hostd(name: str) -> dict:
            w = state["hostnp"]["feat_pos"].shape[1]
            hd = {"l0": jnp.zeros((p, w, cfg.feat_dims[0]), staged_dtype)}
            if name in ("cached", "pipelined"):
                hd["gl"] = [jnp.zeros((xplan.glob.buf_size, d),
                                      staged_dtype) for d in ex_dims]
            return hd
        state["_dummy_hostd"] = _dummy_hostd

        def forward_fresh(params):
            sf = _stage_l0()
            store.account_fetch(sf)
            return jit_steps["forward"](params, {"l0": sf.array},
                                        state["l0loc"], state["xarr"])

        step_wrap = wrap_host
        _prefetch_l0()
    else:
        def forward_fresh(params):
            return jit_steps["forward"](params, state["xarr"])

        step_wrap = wrap

    labels_flat = jnp.asarray(sp.labels.astype(np.int32)).reshape(-1)
    masks_flat = {"train": jnp.asarray(sp.train_mask).reshape(-1),
                  "val": jnp.asarray(sp.val_mask).reshape(-1),
                  "test": jnp.asarray(sp.test_mask).reshape(-1)}

    def evaluate(params, split: str = "val"):
        flat = forward_fresh(params).reshape(-1, cfg.out_dim)
        m = masks_flat[split]
        return (float(cross_entropy_loss(flat, labels_flat, m)),
                float(accuracy(flat, labels_flat, m)))

    comm_dims = list(cfg.feat_dims[:layers])
    if not exchange_layer0 or host_mode:
        # host mode: layer-0 rows arrive over PCIe from the host store
        # (accounted by the store), not over the inter-worker wire
        comm_dims = comm_dims[1:]

    return SpmdRuntime(cfg=cfg, xplan=xplan, mesh=mesh, axis_names=names,
                       comm_dims=comm_dims, forward_fresh=forward_fresh,
                       step_refresh=step_wrap("refresh"),
                       step_cached=step_wrap("cached"),
                       step_pipelined=step_wrap("pipelined"),
                       evaluate=evaluate, caches0=caches0, backend=backend,
                       transport=transport, halo_dtype_bytes=hd_bytes,
                       features=features, host_store=store,
                       jit_steps=jit_steps, _state=state, stacked=sp,
                       spec=spec)
