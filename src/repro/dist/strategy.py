"""Pluggable distribution strategies.

A :class:`DistStrategy` owns the three things that define "how the graph
is distributed":

1. **Layout construction** — turning a ``PartitionSet`` + task into the
   stacked device arrays and collective index programs of one model
   (``halo_1d``: ``stack_partitions`` + ``build_exchange_plan``;
   ``spmm_15d``: block-row stacking + per-replica edge chunks).
2. **Per-layer collective steps** — the runtime whose jitted steps run
   that model's exchange (halo tier pulls vs permute/gather/allreduce).
3. **The byte-accounting contract** — modeled == plan-counted ==
   HLO-measured bytes, so strategies are benchmarked head-to-head in
   ``benchmarks/comm_volume.py`` on equal footing.

Strategies declare *capabilities* (:class:`StrategyCaps`): the JACA
cache tiers, bounded staleness, pipelined refresh and the host feature
store are ``halo_1d`` machinery; ``spmm_15d`` runs refresh-equivalent
exact steps with its own exact byte model.  ``TrainSpec`` validation
routes through :meth:`DistStrategy.validate_spec`, so an unsupported
combination fails at spec-build time with a message naming the strategy.

Registry::

    get_strategy("halo_1d")   # -> Halo1DStrategy
    get_strategy("spmm_15d")  # -> Spmm15DStrategy (strategy_15d.py)
    get_strategy("2d")        # -> ValueError naming valid options
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

__all__ = ["StrategyCaps", "DistStrategy", "Halo1DStrategy",
           "StrategyCapabilityError", "STRATEGY_NAMES", "get_strategy"]

STRATEGY_NAMES = ("halo_1d", "spmm_15d")


class StrategyCapabilityError(ValueError):
    """A TrainSpec/operation asks for a feature the selected distribution
    strategy does not implement (e.g. host features under spmm_15d)."""


@dataclasses.dataclass(frozen=True)
class StrategyCaps:
    """What a distribution strategy supports — the capability matrix the
    README documents and ``TrainSpec`` validates against."""
    jaca_tiers: bool            # local/global cache tiers + staleness
    pipeline: bool              # overlapped refresh (step_pipelined)
    host_features: bool         # out-of-core host feature store
    adaptive_cache: bool        # AdaptivePlanner live re-planning
    fault_guard: bool           # repro.faults injection + defenses
    sim_runtime: bool           # single-device stacked oracle available
    transports: tuple           # SPMD wire lowerings
    backends: tuple             # local aggregation operators
    models: tuple               # GNN kinds the step functions implement
    replicated: bool            # uses a replication factor c > 1


@runtime_checkable
class DistStrategy(Protocol):
    """The distribution-model interface.  ``build_layout`` compiles the
    static index programs, ``make_*_runtime`` builds the jitted steps
    over them, ``train`` runs the strategy's loop, and the ``*_bytes``
    methods are the modeled side of the byte-accounting contract."""
    name: str
    caps: StrategyCaps

    def validate_spec(self, spec) -> None: ...
    def build_layout(self, ps, task, spec, **kw): ...
    def make_sim_runtime(self, cfg, layout, opt, spec, **kw): ...
    def make_spmd_runtime(self, cfg, layout, opt, spec, mesh, **kw): ...
    def train(self, cfg, runtime, layout, opt, spec, epochs, **kw): ...
    def step_bytes(self, layout, cfg, spec) -> int: ...
    def forward_collective_bytes(self, layout, cfg, spec, mesh_size) -> int: ...


@dataclasses.dataclass(frozen=True)
class HaloLayout:
    """halo_1d static layout: the padded ``[P, ...]`` task stacking plus
    the compiled exchange plan (tier gather/scatter index sets)."""
    sp: object                  # StackedParts
    xplan: object               # ExchangePlan

    @property
    def num_parts(self) -> int:
        return self.sp.num_parts


class Halo1DStrategy:
    """The paper's model: 1D vertex partitioning + per-layer halo
    exchange, with the JACA cache tiers, bounded staleness, pipelined
    refresh, adaptive re-planning, host feature store and both wire
    transports.  This class is a thin front door over the pre-existing
    machinery — building through it is bit-identical to calling
    ``stack_partitions``/``build_exchange_plan``/``make_*_runtime``
    directly (asserted by ``tests/test_strategy.py``)."""
    name = "halo_1d"
    caps = StrategyCaps(jaca_tiers=True, pipeline=True, host_features=True,
                        adaptive_cache=True, fault_guard=True,
                        sim_runtime=True,
                        transports=("allgather", "p2p"),
                        backends=("edges", "ell", "hybrid"),
                        models=("gcn", "sage", "gat", "gin"),
                        replicated=False)

    def validate_spec(self, spec) -> None:
        if spec.replication != 1:
            raise StrategyCapabilityError(
                "halo_1d has no replication axis: replication must be 1 "
                f"(got {spec.replication}); row replication is the "
                "spmm_15d strategy")

    def build_layout(self, ps, task, spec, *, plan, pad_to=None,
                     stack_pad_to=None) -> HaloLayout:
        """``plan`` is the JACA :class:`~repro.core.jaca.CachePlan`;
        ``pad_to``/``stack_pad_to`` are the slot-stable capacity paddings
        (see ``build_exchange_plan`` / ``stack_partitions``)."""
        from .exchange import build_exchange_plan, stack_partitions
        sp = stack_partitions(ps, task, backend=spec.backend,
                              pad_to=stack_pad_to)
        xplan = build_exchange_plan(ps, plan, pad_to=pad_to)
        return HaloLayout(sp=sp, xplan=xplan)

    def make_sim_runtime(self, cfg, layout, opt, spec, **kw):
        from .capgnn_sim import make_sim_runtime
        return make_sim_runtime(cfg, layout.sp, layout.xplan, opt,
                                spec=spec, **kw)

    def make_spmd_runtime(self, cfg, layout, opt, spec, mesh, **kw):
        from .capgnn_spmd import make_spmd_runtime
        return make_spmd_runtime(cfg, layout.sp, layout.xplan, opt, mesh,
                                 spec=spec, **kw)

    def train(self, cfg, runtime, layout, opt, spec, epochs, **kw):
        from .capgnn_sim import train_capgnn
        return train_capgnn(cfg, runtime, layout.xplan, layout.num_parts,
                            opt, epochs=epochs, spec=spec, **kw)

    def step_bytes(self, layout, cfg, spec) -> int:
        """Modeled p2p wire bytes of one *refresh* step (the paper's
        point-to-point accounting; cached steps move the uncached tier
        only — see ``ExchangePlan.bytes_per_step`` for the schedule)."""
        dtype_bytes = 2 if spec.halo_dtype == "bf16" else 4
        layers = cfg.num_layers
        dims = list(cfg.feat_dims[:layers])
        if not spec.exchange_layer0 or spec.features == "host":
            dims = dims[1:]
        return sum(layout.xplan.bytes_per_step(d, refresh=True,
                                               dtype_bytes=dtype_bytes)
                   for d in dims)

    def forward_collective_bytes(self, layout, cfg, spec,
                                 mesh_size) -> int:
        """halo_1d's HLO-measured side lives in the transport sweep of
        ``benchmarks/comm_volume.py`` (per-transport lowerings differ);
        the modeled equivalent here is the p2p per-device refresh
        figure."""
        return self.step_bytes(layout, cfg, spec) // max(1, mesh_size)


def get_strategy(name: str) -> DistStrategy:
    """Resolve a strategy by registry name; unknown names fail with the
    valid options spelled out."""
    if name == "halo_1d":
        return _HALO_1D
    if name == "spmm_15d":
        from .strategy_15d import SPMM_15D
        return SPMM_15D
    raise ValueError(f"unknown distribution strategy {name!r}; "
                     f"valid strategies: {', '.join(STRATEGY_NAMES)}")


_HALO_1D = Halo1DStrategy()
