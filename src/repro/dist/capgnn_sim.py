"""Single-device stacked oracle for the CaPGNN partition-parallel runtime.

Every partition's state lives in one padded ``[P, ...]`` array and the
per-worker computation is a ``vmap`` over the leading axis; the inter-worker
exchange is ordinary gather/scatter index arithmetic over the stacked inner
matrix.  Because the arithmetic is identical to what `capgnn_spmd` lowers
through ``shard_map`` collectives, this runtime doubles as the numerical
oracle for the SPMD parity tests — and, with ``refresh_every=1``, as an
exact reimplementation of single-worker full-graph training (the tier-1
correctness anchor).

Three step flavours (paper §4.2/§4.3):

- ``step_refresh``   — all three tiers pulled fresh; caches rewritten.
- ``step_cached``    — local/global tiers read stale from the caches; only
  the uncached tier is exchanged.  Caches unchanged.
- ``step_pipelined`` — same numerics as ``step_cached`` (consumes the same
  stale tiers) but *additionally* emits this step's fresh cache rows, the
  way the pipeline overlaps the refresh transfer with compute.  On the
  single-device oracle that is a numerics statement only; the SPMD
  runtime's ``transport="p2p"`` implements the overlap for real
  (double-buffered ``ppermute`` rings interleaved with the layer loop —
  see :mod:`repro.dist.capgnn_spmd`).

The jitted steps take the exchange index arrays as traced *arguments*
(a read plan and an emit plan — identical except on a plan-transition
step), so online cache adaptation (``SimRuntime.set_plan`` /
``step_transition`` with a capacity-padded slot-stable layout) swaps a
re-ranked plan into a running step without retracing.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.staleness import StalenessController
from repro.faults.guard import GuardConfig, TrainGuard
from repro.faults.plan import NULL_FAULTS
from repro.models.gnn import (EdgeListAdj, EllAdj, GNNConfig, HybridAdj,
                              _layer_apply, accuracy, cross_entropy_loss,
                              init_gnn)
from repro.obs.annotations import device_scope, host_annotation
from repro.obs.tracer import NULL_TRACER, StepCounters, device_peak_bytes
from repro.optim import Optimizer

from .exchange import ExchangePlan, ExchangeTier, GlobalTier, StackedParts
# halo_dtype_info moved to host_store (the staged h2d path casts with the
# same rules as the wire); re-exported here for backward compatibility
from .host_store import HostFeatureStore, halo_dtype_info
from .spec import TrainSpec, halo_dtype_name, warn_loose_kwargs

__all__ = ["make_sim_runtime", "SimRuntime", "init_caches", "train_capgnn",
           "TrainReport", "RUNTIME_BACKENDS", "check_backend",
           "make_adj_builder", "halo_dtype_info", "exchange_arrays",
           "RUNTIME_FEATURES"]

# where the input features live: stacked on device, or host-resident with
# per-step staged fetch of the non-locally-cached halo rows (out-of-core)
RUNTIME_FEATURES = ("device", "host")


# ---------------------------------------------------------------------------
# Tier primitives (shared by the property tests and both runtimes)
# ---------------------------------------------------------------------------

def _tier_dict(t: ExchangeTier) -> dict:
    return {
        "send_row": jnp.asarray(t.send_row, jnp.int32),
        "recv_src_part": jnp.asarray(t.recv_src_part, jnp.int32),
        "recv_src_slot": jnp.asarray(t.recv_src_slot, jnp.int32),
        "recv_halo_pos": jnp.asarray(t.recv_halo_pos, jnp.int32),
        "recv_valid": jnp.asarray(t.recv_valid),
    }


def _glob_dict(g: GlobalTier) -> dict:
    return {
        "send_row": jnp.asarray(g.send_row, jnp.int32),
        "src_part": jnp.asarray(g.src_part, jnp.int32),
        "src_slot": jnp.asarray(g.src_slot, jnp.int32),
        "read_pos": jnp.asarray(g.read_pos, jnp.int32),
        "read_buf_idx": jnp.asarray(g.read_buf_idx, jnp.int32),
        "read_valid": jnp.asarray(g.read_valid),
        "buf_valid": jnp.asarray(g.buf_valid),
    }


def exchange_arrays(xplan: ExchangePlan, include_host: bool = False) -> dict:
    """Device pytree of one plan's tier index arrays + valid masks.

    The jitted steps take this pytree as a *traced argument* (not a baked
    constant), so swapping in another plan's arrays — same shapes under a
    capacity-padded layout — re-plans the running step without retracing.
    ``include_host`` adds the layer-0 host-tier scatter program consumed
    by the ``features="host"`` runtimes.
    """
    out = {"un": _tier_dict(xplan.uncached),
           "loc": _tier_dict(xplan.local),
           "gl": _glob_dict(xplan.glob)}
    if include_host:
        if xplan.host is None:
            raise ValueError("features='host' needs a plan with a host "
                             "tier (rebuild via build_exchange_plan)")
        out["host"] = {"feat_pos": jnp.asarray(xplan.host.feat_pos,
                                               jnp.int32),
                       "feat_valid": jnp.asarray(xplan.host.feat_valid)}
    return out


def _pull(td: dict, h: jnp.ndarray, halo_dtype=None) -> jnp.ndarray:
    """Gather one tier's rows from the stacked inner matrix ``h [P,NI,d]``.

    Owners pack their send buffers, consumers address the payload by
    (src_part, src_slot).  Invalid (padding) rows are zeroed so they can be
    cached or compared without carrying garbage.  ``halo_dtype`` casts the
    packed payload before "transport" and dequantises the addressed rows
    back to ``h.dtype`` (the compressed-wire numerics the SPMD runtime
    applies for real).  Returns ``[P, R, d]``.
    """
    p = h.shape[0]
    payload = h[jnp.arange(p)[:, None], td["send_row"]]          # [P, S, d]
    if halo_dtype is not None:
        payload = payload.astype(halo_dtype)
    rows = payload[td["recv_src_part"], td["recv_src_slot"]]     # [P, R, d]
    rows = rows.astype(h.dtype)
    return jnp.where(td["recv_valid"][..., None], rows, 0.0)


def _scatter(halo: jnp.ndarray, pos: jnp.ndarray, rows: jnp.ndarray,
             valid: jnp.ndarray) -> jnp.ndarray:
    """Scatter tier rows into the halo buffer ``[P, NH, d]`` at ``pos``;
    invalid entries are routed out of bounds and dropped."""
    nh = halo.shape[1]
    pos_eff = jnp.where(valid, pos, nh)
    pidx = jnp.arange(halo.shape[0])[:, None]
    return halo.at[pidx, pos_eff].set(rows, mode="drop")


def _build_global(gd: dict, h: jnp.ndarray, halo_dtype=None) -> jnp.ndarray:
    """Fill the deduplicated global buffer ``[G, d]`` from owners' rows.
    The buffer is stored dequantised (compute dtype); with ``halo_dtype``
    the owners' payload is cast before transport, so the buffer carries
    exactly the rows a compressed wire delivers.  Capacity-padding slots
    (``buf_valid`` false) are zeroed so caches/drift stats never carry
    garbage."""
    p = h.shape[0]
    payload = h[jnp.arange(p)[:, None], gd["send_row"]]          # [P, S, d]
    if halo_dtype is not None:
        payload = payload.astype(halo_dtype)
    rows = payload[gd["src_part"], gd["src_slot"]].astype(h.dtype)  # [G, d]
    if "buf_valid" in gd:
        rows = jnp.where(gd["buf_valid"][:, None], rows, 0.0)
    return rows


def _read_global(gd: dict, buf: jnp.ndarray, halo: jnp.ndarray) -> jnp.ndarray:
    """Serve each worker's global-tier halo positions from the buffer."""
    rows = buf[gd["read_buf_idx"]]                               # [P, RG, d]
    return _scatter(halo, gd["read_pos"], rows, gd["read_valid"])


# ---------------------------------------------------------------------------
# Aggregation backends (shared with the SPMD runtime)
# ---------------------------------------------------------------------------

RUNTIME_BACKENDS = ("edges", "ell", "hybrid")


def check_backend(sp: StackedParts, backend: str) -> None:
    """Validate a runtime backend choice against the stacked layout."""
    if backend not in RUNTIME_BACKENDS:
        raise ValueError(f"unknown aggregation backend {backend!r}; "
                         f"expected one of {RUNTIME_BACKENDS}")
    if backend != "edges" and (sp.ell is None or sp.ell.backend != backend):
        have = sp.ell.backend if sp.ell is not None else None
        raise ValueError(
            f"backend={backend!r} needs a matching stacked aggregation pack "
            f"(found {have!r}); rebuild the stacked layout with "
            f"stack_partitions(ps, task, backend={backend!r})")


def make_adj_builder(sp: StackedParts, backend: str, interpret: bool = True):
    """Return ``(pack_leaves, build)``: ``pack_leaves`` is a dict of
    per-partition ``[P, ...]`` arrays to map over (vmap in the oracle, shard
    in SPMD), and ``build(leaves)`` constructs one partition's
    :class:`~repro.models.gnn.Adjacency` from the corresponding slices.

    Every backend aggregates over the identical edge set (the packs are
    built from the same remapped edge lists at stack time), so swapping the
    backend changes kernel shape only — logits, gradients, and the exchange
    byte accounting are backend-invariant.
    """
    check_backend(sp, backend)
    ni, nh = sp.n_inner_max, sp.n_halo_max
    if backend == "edges":
        leaves = {"src": jnp.asarray(sp.e_src), "dst": jnp.asarray(sp.e_dst),
                  "w": jnp.asarray(sp.e_w)}

        def build(lv):
            return EdgeListAdj(lv["src"], lv["dst"], lv["w"], ni, ni + nh)
    elif backend == "ell":
        leaves = {"cols": jnp.asarray(sp.ell.cols),
                  "vals": jnp.asarray(sp.ell.vals)}

        def build(lv):
            return EllAdj(lv["cols"], lv["vals"], ni + nh,
                          interpret=interpret)
    else:  # hybrid
        leaves = {"cols": jnp.asarray(sp.ell.cols),
                  "vals": jnp.asarray(sp.ell.vals),
                  "tail_src": jnp.asarray(sp.ell.tail_src),
                  "tail_dst": jnp.asarray(sp.ell.tail_dst),
                  "tail_w": jnp.asarray(sp.ell.tail_w)}

        def build(lv):
            return HybridAdj(lv["cols"], lv["vals"], lv["tail_src"],
                             lv["tail_dst"], lv["tail_w"], ni + nh,
                             interpret=interpret)
    return leaves, build


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_caches(cfg: GNNConfig, xplan: ExchangePlan, num_parts: int,
                features: str = "device") -> dict:
    """Zero-filled stale tiers, one entry per cached exchange layer.

    Entry ``l-1`` holds the halo inputs of layer ``l`` (layers ``1..L-1``);
    layer 0 consumes the static input features, which never go stale.

    With ``features="host"`` the global tier is *host-resident* (it lives
    in the runtime's :class:`~repro.dist.host_store.HostFeatureStore` and
    is staged per step), so the device cache pytree carries only the
    local tier.
    """
    dims = cfg.feat_dims[1: cfg.num_layers]
    r_local = int(np.asarray(xplan.local.recv_halo_pos).shape[1])
    g = xplan.glob.buf_size
    return {
        "local": [jnp.zeros((num_parts, r_local, d), jnp.float32)
                  for d in dims],
        "global": ([] if features == "host" else
                   [jnp.zeros((g, d), jnp.float32) for d in dims]),
    }


# ---------------------------------------------------------------------------
# Runtime
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimRuntime:
    cfg: GNNConfig
    xplan: ExchangePlan
    comm_dims: list        # per-exchange-layer feature dims (byte accounting)
    forward_fresh: Callable
    step_refresh: Callable
    step_cached: Callable
    step_pipelined: Callable
    evaluate: Callable
    caches0: dict
    backend: str = "edges"
    halo_dtype_bytes: int = 4   # actual wire width per halo payload entry
    # feature residency: "device" (stacked on device) or "host"
    # (out-of-core: host store + per-step staged fetch)
    features: str = "device"
    host_store: HostFeatureStore | None = dataclasses.field(default=None,
                                                            repr=False)
    # online adaptation plumbing: the jitted step impls take the exchange
    # arrays of the (read, emit) plans as traced arguments; `_state` holds
    # the currently-installed plan's arrays.
    jit_steps: dict | None = dataclasses.field(default=None, repr=False)
    _state: dict | None = dataclasses.field(default=None, repr=False)
    # the stacked layout this runtime was built over — kept for padded-row
    # accounting under uneven (resource-aware) partitions
    stacked: StackedParts | None = dataclasses.field(default=None, repr=False)
    # the TrainSpec this runtime was configured from (always set — the
    # loose-kwarg shim synthesises one), recorded into TrainReport.spec
    spec: TrainSpec | None = dataclasses.field(default=None, repr=False)

    def padding_stats(self) -> dict:
        """Valid vs padded stacked-row counts (see
        :meth:`repro.dist.StackedParts.padding_stats`)."""
        return self.stacked.padding_stats() if self.stacked else {}

    def set_tracer(self, tracer) -> None:
        """Attach a :class:`repro.obs.Tracer`: the plain-Python stepper
        wrappers record their staging sub-spans (``l0_stage``,
        ``h2d_prefetch``, ``writeback``) on it and the host store its
        ``h2d_put`` dispatches.  Default is the shared no-op tracer —
        detaching is ``set_tracer(NULL_TRACER)``."""
        if self._state is not None:
            self._state["tracer"] = tracer
        if self.host_store is not None:
            self.host_store.set_tracer(tracer)

    def set_fault_guard(self, guard) -> None:
        """Attach a :class:`repro.faults.FetchGuard`: the host-mode
        staging wrappers route through its retry/degrade/stale-reuse
        paths.  ``None`` (the default) keeps the original unguarded
        staging code byte-for-byte.  No-op in device-feature mode."""
        if self._state is not None:
            self._state["fetch_guard"] = guard
            if guard is not None and "l0loc" in self._state:
                # the resident layer-0 local rows are the natural stale
                # fallback for a failed re-stage at the next plan install
                guard.last_good.setdefault("l0loc", self._state["l0loc"])

    def set_plan(self, xplan: ExchangePlan) -> None:
        """Install a re-ranked plan.  Under a capacity-padded (slot-stable)
        layout the jitted steps keep their compiled executables — only the
        index data changes.  The caches' *content* still reflects the old
        tiering, so the next step must be a refresh (or have been emitted
        by :meth:`step_transition`).  In ``features="host"`` mode this
        also flushes the staged-fetch ring and restages the layer-0 local
        cache for the new plan."""
        self.xplan = xplan
        hook = (self._state or {}).get("_set_plan")
        if hook is not None:
            hook(xplan)
        else:
            self._state["xarr"] = exchange_arrays(xplan)

    def step_transition(self, params, opt_state, caches,
                        new_xplan: ExchangePlan):
        """Pipelined plan switch: consume the *current* plan's stale tiers
        (and its uncached exchange) while prefetching the **new** plan's
        tier rows in the refresh windows; the emitted caches are laid out
        for ``new_xplan``, which becomes the installed plan.  In host
        mode the stale global tier is staged on the *old* plan's layout,
        the emitted buffers are written back under the new plan's
        membership, and the layer-0 staging ring is flushed (its
        prefetches carry old-plan rows — they are discarded unaccounted,
        never served)."""
        hook = (self._state or {}).get("_transition")
        if hook is not None:
            out = hook(params, opt_state, caches, new_xplan)
        else:
            xe = exchange_arrays(new_xplan)
            out = self.jit_steps["pipelined"](params, opt_state, caches,
                                              self._state["xarr"], xe)
            self._state["xarr"] = xe
        self.xplan = new_xplan
        return out

    def lower_step(self, name: str, params, opt_state, caches):
        """Lower one jitted step flavour (``"refresh" | "cached" |
        "pipelined"``) with the installed plan's exchange arrays — for HLO
        inspection/cost tooling."""
        xa = self._state["xarr"]
        if self.features == "host":
            hd = self._state["_dummy_hostd"](name)
            return self.jit_steps[name].lower(params, opt_state, caches,
                                              hd, self._state["l0loc"],
                                              xa, xa)
        return self.jit_steps[name].lower(params, opt_state, caches, xa, xa)


def make_sim_runtime(cfg: GNNConfig, sp: StackedParts, xplan: ExchangePlan,
                     opt: Optimizer, exchange_layer0: bool = True,
                     backend: str = "edges", interpret: bool = True,
                     halo_dtype=None, donate: bool = True,
                     features: str = "device",
                     host_store: HostFeatureStore | None = None,
                     prefetch_depth: int = 2,
                     spec: TrainSpec | None = None) -> SimRuntime:
    """Build the jitted stacked-oracle runtime.

    ``spec`` (a :class:`repro.dist.TrainSpec`) is the configuration
    surface; when passed it overrides every loose configuration kwarg
    below.  The loose kwargs remain as a deprecated shim that forwards
    into a synthesised spec (one ``DeprecationWarning`` per call — see
    the README migration note).  ``host_store`` stays a real argument
    either way: it is a resource, not a choice.

    ``exchange_layer0=False`` models pre-replicated input features (they are
    static, so a deployment ships them once): layer 0 drops out of the byte
    accounting, while the numerics are unchanged.

    ``backend`` picks the per-partition aggregation operator: ``"edges"``
    (segment-sum reference), ``"ell"`` (Pallas blocked-ELL SpMM) or
    ``"hybrid"`` (Pallas ELL + COO overflow tail).  The non-edge backends
    need the stacked pack from ``stack_partitions(..., backend=...)``; the
    exchange plan, caches and byte accounting are backend-invariant.

    ``halo_dtype="bf16"`` casts every tier's payload before the exchange
    and dequantises on scatter, halving the accounted wire bytes
    (``halo_dtype_bytes`` is threaded into ``train_capgnn``'s accounting).
    In host mode the same cast compresses the PCIe staging payloads.

    ``donate=True`` (default) donates ``(params, opt_state, caches)`` into
    the jitted steps, so the optimizer and cache buffers are updated
    in place in steady state instead of being copied.  Callers must then
    treat the arguments of a step call as consumed — re-use the *returned*
    state (pass ``donate=False`` for branch-and-compare experiments that
    deliberately re-run a step from the same state).

    ``features="host"`` is the out-of-core mode: the halo feature table
    never lives on device.  Layer 0's local-tier rows are staged once per
    plan (``l0loc``, the genuinely device-cached JACA local tier); the
    uncached+global layer-0 rows ride a double-buffered
    :class:`~repro.dist.host_store.HostFeatureStore` staging ring whose
    next fetch is ``device_put``-in-flight while the current step runs;
    the per-exchange-layer global buffers live host-side between steps
    (written back on refresh, staged h2d for the stale reads).  The plan
    must carry a host tier (``build_exchange_plan`` always emits one).
    ``host_store`` injects a pre-built store (shared with a serve engine);
    by default one is built over ``sp.halo_feats``.
    """
    if spec is None:
        warn_loose_kwargs("make_sim_runtime")
        spec = TrainSpec(strategy="halo_1d", backend=backend,
                         features=features,
                         halo_dtype=halo_dtype_name(halo_dtype),
                         exchange_layer0=exchange_layer0, donate=donate,
                         interpret=interpret,
                         prefetch_depth=prefetch_depth)
    # the spec is authoritative from here on — identical construction for
    # both entry paths (the shim-equivalence tests pin this)
    exchange_layer0 = spec.exchange_layer0
    backend = spec.backend
    interpret = spec.interpret
    halo_dtype = spec.halo_dtype
    donate = spec.donate
    features = spec.features
    prefetch_depth = spec.prefetch_depth
    p, ni, nh = sp.num_parts, sp.n_inner_max, sp.n_halo_max
    hdt, hd_bytes = halo_dtype_info(halo_dtype)
    layers = cfg.num_layers
    if features not in RUNTIME_FEATURES:
        raise ValueError(f"unknown features mode {features!r}; "
                         f"expected one of {RUNTIME_FEATURES}")
    host_mode = features == "host"

    feats = jnp.asarray(sp.feats)
    if host_mode:
        store = host_store if host_store is not None else HostFeatureStore(
            sp.halo_feats, halo_dtype=halo_dtype,
            prefetch_depth=prefetch_depth)
        halo_feats = None      # the halo table never touches device memory
    else:
        store = None
        halo_feats = jnp.asarray(sp.halo_feats)
    labels = jnp.asarray(sp.labels).reshape(-1)
    masks = {k: jnp.asarray(m).reshape(-1)
             for k, m in (("train", sp.train_mask), ("val", sp.val_mask),
                          ("test", sp.test_mask))}
    adj_leaves, build_adj = make_adj_builder(sp, backend, interpret)

    def layer_all(lp, h, halo, is_last):
        def one(lv, hi, hhi):
            adj = build_adj(lv)
            h_local = jnp.concatenate([hi, hhi], axis=0)
            with device_scope("spmm_layer"):
                return _layer_apply(cfg, lp, adj, h_local, ni, is_last)
        return jax.vmap(one)(adj_leaves, h, halo)

    def forward(params, caches, xr, xe, use_stale: bool,
                hostd=None, l0loc=None):
        """``xr`` is the installed (read) plan: stale caches are scattered
        at its positions and its uncached tier is exchanged.  ``xe`` is the
        emit plan whose tier rows are pulled fresh — identical to ``xr``
        except on a plan-transition step, where the fresh pulls prefetch
        the *next* plan's rows.

        In host mode the layer-0 halo is assembled on device from two
        staged payloads instead of a resident table: ``l0loc`` (the
        per-plan device-cached local tier) scattered at the local tier's
        positions, and ``hostd["l0"]`` (this step's double-buffered host
        fetch) scattered at the host tier's positions (uncached ∪ global
        membership).  Stale global reads come from ``hostd["gl"]`` — the
        staged host-resident buffers — rather than a device cache."""
        h = feats
        fresh = {"local": [], "global": []}
        for li, lp in enumerate(params):
            if li == 0:
                if host_mode:
                    halo = jnp.zeros((p, nh, h.shape[-1]), h.dtype)
                    halo = _scatter(halo, xr["loc"]["recv_halo_pos"],
                                    l0loc.astype(h.dtype),
                                    xr["loc"]["recv_valid"])
                    halo = _scatter(halo, xr["host"]["feat_pos"],
                                    hostd["l0"].astype(h.dtype),
                                    xr["host"]["feat_valid"])
                else:
                    halo = halo_feats
            else:
                d = h.shape[-1]
                halo = jnp.zeros((p, nh, d), h.dtype)
                with device_scope("tier_pull_uncached"):
                    halo = _scatter(halo, xr["un"]["recv_halo_pos"],
                                    _pull(xr["un"], h, hdt),
                                    xr["un"]["recv_valid"])
                with device_scope("tier_pull_refresh"):
                    loc_fresh = _pull(xe["loc"], h, hdt)
                    buf_fresh = _build_global(xe["gl"], h, hdt)
                if use_stale:
                    loc_use, loc_t = caches["local"][li - 1], xr["loc"]
                    if host_mode:
                        buf_use = hostd["gl"][li - 1].astype(h.dtype)
                    else:
                        buf_use = caches["global"][li - 1]
                    gl_t = xr["gl"]
                else:
                    loc_use, loc_t = loc_fresh, xe["loc"]
                    buf_use, gl_t = buf_fresh, xe["gl"]
                halo = _scatter(halo, loc_t["recv_halo_pos"], loc_use,
                                loc_t["recv_valid"])
                halo = _read_global(gl_t, buf_use, halo)
                fresh["local"].append(loc_fresh)
                fresh["global"].append(buf_fresh)
            with device_scope(f"layer{li}"):
                h = layer_all(lp, h, halo, is_last=(li == layers - 1))
        return h, fresh

    def loss_fn(params, caches, xr, xe, use_stale: bool,
                hostd=None, l0loc=None):
        logits, fresh = forward(params, caches, xr, xe, use_stale,
                                hostd, l0loc)
        flat = logits.reshape(-1, logits.shape[-1])
        loss = cross_entropy_loss(flat, labels, masks["train"])
        return loss, (flat, fresh)

    def _metrics_and_caches(loss, flat, fresh, caches, stale_gl,
                            use_stale: bool, emit_fresh: bool):
        metrics = {"loss": loss,
                   "acc": accuracy(flat, labels, masks["train"])}
        # Drift compares fresh rows against the stale source of this step.
        # In host mode that source is the staged host buffer (``stale_gl``
        # from hostd) — on a host *refresh* there is no staged stale
        # global at all, so the drift keys are simply not emitted.
        if emit_fresh and (use_stale or not host_mode):
            pairs = list(zip(fresh["local"] + fresh["global"],
                             caches["local"] + stale_gl))
            drifts = [jnp.max(jnp.abs(a - b)) for a, b in pairs
                      if a.size]
            metrics["drift"] = (jnp.max(jnp.stack(drifts)) if drifts
                                else jnp.zeros(()))
            # per-row drift stats for the drift-aware planner policy
            # (max over layers and feature dim; meaningful when xr == xe)
            n_ex = len(fresh["local"])
            if n_ex:
                loc_rows = [jnp.max(jnp.abs(a - b), axis=-1)
                            for a, b in pairs[:n_ex]]
                gl_rows = [jnp.max(jnp.abs(a - b), axis=-1)
                           for a, b in pairs[n_ex:]]
                metrics["drift_local_rows"] = jnp.max(
                    jnp.stack(loc_rows), axis=0)          # [P, Rloc]
                metrics["drift_global_rows"] = jnp.max(
                    jnp.stack(gl_rows), axis=0)           # [G]
        if host_mode:
            out_caches = {"local": (fresh["local"] if emit_fresh
                                    else caches["local"]),
                          "global": []}
        else:
            out_caches = fresh if emit_fresh else caches
        return metrics, out_caches

    def make_step(use_stale: bool, emit_fresh: bool):
        if host_mode:
            def step(params, opt_state, caches, hostd, l0loc, xr, xe):
                (loss, (flat, fresh)), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, caches, xr, xe,
                                           use_stale, hostd, l0loc)
                new_params, new_state = opt.update(grads, opt_state, params)
                stale_gl = ([g.astype(jnp.float32) for g in hostd["gl"]]
                            if use_stale else [])
                metrics, out_caches = _metrics_and_caches(
                    loss, flat, fresh, caches, stale_gl,
                    use_stale, emit_fresh)
                if emit_fresh:
                    # emitted global buffers go back to the host store
                    # (d2h writeback by the caller), not into device caches
                    return (new_params, new_state, out_caches,
                            fresh["global"], metrics)
                return new_params, new_state, out_caches, metrics
            # the staged hostd payloads are single-use but their shapes
            # never match a step output, so donating them would only warn;
            # their buffers free when the wrapper drops the last reference
            return jax.jit(step,
                           donate_argnums=(0, 1, 2) if donate else ())

        def step(params, opt_state, caches, xr, xe):
            (loss, (flat, fresh)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, caches, xr, xe, use_stale)
            new_params, new_state = opt.update(grads, opt_state, params)
            metrics, out_caches = _metrics_and_caches(
                loss, flat, fresh, caches, caches["global"],
                use_stale, emit_fresh)
            return new_params, new_state, out_caches, metrics
        # steady-state steps rewrite (params, opt_state, caches) in place;
        # the exchange arrays (xr, xe) are NOT donated — they are reused
        # across steps and swapped wholesale by set_plan/step_transition
        return jax.jit(step, donate_argnums=(0, 1, 2) if donate else ())

    caches0 = init_caches(cfg, xplan, p, features=features)

    if host_mode:
        def _fwd_fresh(params, hostd, l0loc, xr):
            logits, _ = forward(params, caches0, xr, xr, False,
                                hostd, l0loc)
            return logits
    else:
        def _fwd_fresh(params, xr):
            logits, _ = forward(params, caches0, xr, xr, False)
            return logits

    jit_steps = {"refresh": make_step(False, True),
                 "cached": make_step(True, False),
                 "pipelined": make_step(True, True),
                 "forward": jax.jit(_fwd_fresh)}
    state = {"xarr": exchange_arrays(xplan, include_host=host_mode),
             "tracer": NULL_TRACER}

    def wrap(name):
        ann = f"capgnn/step_{name}"

        def stepper(params, opt_state, caches):
            xa = state["xarr"]
            with host_annotation(ann):
                return jit_steps[name](params, opt_state, caches, xa, xa)
        return stepper

    if host_mode:
        n_ex = layers - 1
        ex_dims = list(cfg.feat_dims[1:layers])
        parts_idx = np.arange(p)[:, None]
        staged_dtype = hdt if hdt is not None else jnp.float32

        def _host_np(xp: ExchangePlan) -> dict:
            """Host-side gather programs of one plan (plain numpy — these
            index the host table, they never ride into the jitted step)."""
            return {"feat_pos": np.asarray(xp.host.feat_pos, np.int64),
                    "feat_valid": np.asarray(xp.host.feat_valid, bool),
                    "loc_pos": np.asarray(xp.local.recv_halo_pos, np.int64),
                    "loc_valid": np.asarray(xp.local.recv_valid, bool),
                    "gl_rows": int(xp.glob.n_unique)}

        def _stage_l0loc():
            """(Re)stage the layer-0 local-tier rows — the device-cached
            slice of the host table.  One accounted fetch per plan install,
            then resident until the next re-plan."""
            hn = state["hostnp"]

            def stage():
                return store.stage_rows((parts_idx, hn["loc_pos"]),
                                        valid=hn["loc_valid"])
            g = state.get("fetch_guard")
            if g is None:
                sf = stage()
                store.account_fetch(sf)
                state["l0loc"] = sf.array
            else:
                state["l0loc"] = g.fetch_sync(stage, store, "l0loc")

        def _stage_l0():
            hn = state["hostnp"]
            return store.stage_rows((parts_idx, hn["feat_pos"]),
                                    valid=hn["feat_valid"])

        def _take_l0():
            """Pop the oldest in-flight layer-0 fetch (or stage one cold)
            and account it — accounting happens at consumption, so flushed
            prefetches never count.  With a fault guard attached the cold
            path retries with backoff and past the budget serves the
            previous step's rows (stale reuse)."""
            ring = state["l0_ring"]
            g = state.get("fetch_guard")
            if g is None:
                sf = ring.popleft() if ring else _stage_l0()
                store.account_fetch(sf)
                return sf.array
            if ring:
                return g.consume(ring.popleft(), store, "l0")
            return g.fetch_sync(_stage_l0, store, "l0")

        def _prefetch_l0():
            """Refill the double buffer: keep the *next* step's host rows
            ``device_put``-in-flight while the current step computes.
            Under an active fault guard a failed or slow fetch suspends
            the refill — consumption degrades to synchronous staging."""
            ring = state["l0_ring"]
            g = state.get("fetch_guard")
            if g is not None and not g.prefetch_ok():
                return
            while len(ring) < max(1, store.prefetch_depth - 1):
                if g is None:
                    ring.append(_stage_l0())
                else:
                    sf = g.try_stage(_stage_l0)
                    if sf is None:
                        return
                    ring.append(sf)

        def _take_gl():
            g = state.get("fetch_guard")
            out = []
            for li in range(n_ex):
                if g is None:
                    sf = store.stage_buf(li)
                    store.account_fetch(sf)
                    out.append(sf.array)
                else:
                    out.append(g.fetch_sync(
                        lambda li=li: store.stage_buf(li), store, f"gl{li}"))
            return out

        def _writeback(host_out):
            for li, buf in enumerate(host_out):
                store.write_buf(li, buf, state["hostnp"]["gl_rows"])

        state["hostnp"] = _host_np(xplan)
        state["l0_ring"] = deque()
        _stage_l0loc()
        for li, d in enumerate(ex_dims):
            store.init_buf(li, (xplan.glob.buf_size, d),
                           xplan.glob.n_unique)

        def wrap_host(name):
            use_gl = name in ("cached", "pipelined")
            emit = name in ("refresh", "pipelined")
            ann = f"capgnn/step_{name}"

            def stepper(params, opt_state, caches):
                tr = state["tracer"]
                with tr.span("l0_stage"):
                    hostd = {"l0": _take_l0()}
                    if use_gl:
                        hostd["gl"] = _take_gl()
                xa = state["xarr"]
                with host_annotation(ann):
                    out = jit_steps[name](params, opt_state, caches, hostd,
                                          state["l0loc"], xa, xa)
                if emit:
                    new_p, new_s, out_caches, host_out, metrics = out
                    with tr.span("writeback"):
                        _writeback(host_out)
                    out = (new_p, new_s, out_caches, metrics)
                with tr.span("h2d_prefetch"):
                    _prefetch_l0()
                return out
            return stepper

        def _set_plan(xp: ExchangePlan):
            tr = state["tracer"]
            state["xarr"] = exchange_arrays(xp, include_host=True)
            state["hostnp"] = _host_np(xp)
            # old-plan prefetches are flushed *unaccounted* — they were
            # never consumed, so staged == consumed stays exact
            state["l0_ring"].clear()
            with tr.span("l0_stage"):
                _stage_l0loc()
            with tr.span("h2d_prefetch"):
                _prefetch_l0()
            # the host-resident global buffers keep their (old-tiering)
            # content; shapes are plan-invariant under the capacity-padded
            # layout and the next step after set_plan must be a refresh
        state["_set_plan"] = _set_plan

        def _transition(params, opt_state, caches, new_xp: ExchangePlan):
            tr = state["tracer"]
            # old plan's stale tiers are staged on the OLD layout...
            with tr.span("l0_stage"):
                hostd = {"l0": _take_l0(), "gl": _take_gl()}
            xr = state["xarr"]
            xe = exchange_arrays(new_xp, include_host=True)
            with host_annotation("capgnn/step_transition"):
                new_p, new_s, out_caches, host_out, metrics = (
                    jit_steps["pipelined"](params, opt_state, caches, hostd,
                                           state["l0loc"], xr, xe))
            state["xarr"] = xe
            state["hostnp"] = _host_np(new_xp)
            # ...while the emitted buffers carry the NEW plan's membership
            with tr.span("writeback"):
                _writeback(host_out)
            state["l0_ring"].clear()
            with tr.span("l0_stage"):
                _stage_l0loc()
            with tr.span("h2d_prefetch"):
                _prefetch_l0()
            return new_p, new_s, out_caches, metrics
        state["_transition"] = _transition

        def _dummy_hostd(name: str) -> dict:
            """Zero payloads with the staged shapes/dtypes — for
            ``lower_step`` HLO inspection only."""
            w = state["hostnp"]["feat_pos"].shape[1]
            hd = {"l0": jnp.zeros((p, w, cfg.feat_dims[0]), staged_dtype)}
            if name in ("cached", "pipelined"):
                hd["gl"] = [jnp.zeros((xplan.glob.buf_size, d),
                                      staged_dtype) for d in ex_dims]
            return hd
        state["_dummy_hostd"] = _dummy_hostd

        def forward_fresh(params):
            sf = _stage_l0()
            store.account_fetch(sf)
            return jit_steps["forward"](params, {"l0": sf.array},
                                        state["l0loc"], state["xarr"])

        step_wrap = wrap_host
        _prefetch_l0()
    else:
        def forward_fresh(params):
            return jit_steps["forward"](params, state["xarr"])

        step_wrap = wrap

    def evaluate(params, split: str = "val"):
        flat = forward_fresh(params).reshape(-1, cfg.out_dim)
        m = masks[split]
        return (float(cross_entropy_loss(flat, labels, m)),
                float(accuracy(flat, labels, m)))

    comm_dims = list(cfg.feat_dims[:layers])
    if not exchange_layer0 or host_mode:
        # host mode: layer-0 rows arrive over PCIe from the host store
        # (accounted by the store), not over the inter-worker wire
        comm_dims = comm_dims[1:]

    return SimRuntime(cfg=cfg, xplan=xplan, comm_dims=comm_dims,
                      forward_fresh=forward_fresh,
                      step_refresh=step_wrap("refresh"),
                      step_cached=step_wrap("cached"),
                      step_pipelined=step_wrap("pipelined"),
                      evaluate=evaluate,
                      caches0=caches0, backend=backend,
                      halo_dtype_bytes=hd_bytes,
                      features=features, host_store=store,
                      jit_steps=jit_steps, _state=state, stacked=sp,
                      spec=spec)


# ---------------------------------------------------------------------------
# Training loop with exact byte accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainReport:
    losses: list
    val_acc: list
    comm_bytes: int
    comm_bytes_vanilla: int
    comm_reduction: float
    refresh_steps: int
    cached_steps: int
    wall_time_s: float
    replan_events: int = 0
    hit_rate: float | None = None    # planner-observed (adaptive runs only)
    final_opt_state: object = None   # for checkpoint/resume (launch.train)
    # out-of-core (features="host") traffic over the training loop, from
    # the store's consumption-driven counters; zero in device mode
    host_fetch_rows: int = 0
    host_fetch_bytes: int = 0
    host_writeback_bytes: int = 0
    # step 0 wall time (dominated by jit trace+compile), fenced separately
    # so ``wall_time_s`` above is steady-state only
    compile_s: float = 0.0
    # per step-kind {count, p50_ms, p99_ms, total_s} from the tracer's
    # depth-0 spans; None on untraced runs (timing them would add syncs)
    phase_stats: dict | None = None
    # fault-injection accounting (repro.faults): per-kind injected event
    # counts and the run's DefenseEvents totals; None on clean runs.
    # The fault-tolerance suite asserts the matched pairs are EQUAL —
    # fetch_drop==fetch_errors, fetch_delay==slow_fetches,
    # halo_corrupt==corruptions_detected, grad_nan==rollbacks,
    # mem_pressure==mem_backoffs.
    faults_injected: dict | None = None
    fault_events: dict | None = None
    # the serialised TrainSpec (spec.to_dict()) this run was configured
    # from, so every experiments/*.json records its exact configuration
    spec: dict | None = None


def _step_rows(x_read: ExchangePlan, x_emit: ExchangePlan,
               refresh: bool) -> int:
    """Exact per-layer wire rows of one step: the *read* plan's uncached
    tier moves every step; on a refresh the *emit* plan's cached tiers are
    (pre)fetched.  ``x_read is x_emit`` except on a plan-transition step."""
    n = x_read.uncached.n_rows
    if refresh:
        n += x_emit.local.n_rows + x_emit.glob.n_unique
    return n


def train_capgnn(cfg: GNNConfig, runtime, xplan: ExchangePlan,
                 num_parts: int, opt: Optimizer, epochs: int = 100,
                 eval_every: int = 0, controller: StalenessController | None = None,
                 pipeline: bool = False, seed: int = 0,
                 params0=None, opt_state0=None, planner=None,
                 tracer=None, faults=None, guard=None,
                 spec: TrainSpec | None = None) -> tuple[list, TrainReport]:
    """Full-batch CaPGNN training under the staleness schedule.

    ``spec`` (a :class:`repro.dist.TrainSpec`) supplies ``pipeline`` and
    ``seed`` and is recorded (serialised) into ``report.spec``; the loose
    ``pipeline``/``seed`` kwargs remain as a deprecated shim that forwards
    into a spec derived from the runtime's (one ``DeprecationWarning``).
    Object-valued collaborators (controller, planner, tracer, faults,
    guard, resume state) are resources, not spec fields — they stay
    explicit arguments on both paths.

    One step per epoch (full batch).  Per-step bytes are the plan's exact
    figures: a vanilla runtime would move every halo row at every layer of
    every step; CaPGNN moves only the uncached tier on cached steps and a
    deduplicated refresh on refresh steps.  With ``pipeline=True`` the
    scheduled refreshes (after warm-up) run as ``step_pipelined`` — the
    refresh payload rides along with the compute instead of a synchronous
    exchange phase; bytes are identical, latency is hidden.

    ``tracer`` (a :class:`repro.obs.Tracer`) records one depth-0 span per
    step — kind ``refresh``/``cached``/``pipelined``/``transition``, with
    the ``replan``/``l0_stage``/``writeback``/``h2d_prefetch``/``eval``
    sub-phases nested inside — plus one typed
    :class:`repro.obs.StepCounters` record per step whose totals equal
    this report's ``comm_bytes`` / ``host_fetch_*`` figures exactly (the
    per-step stream is the same accounting, before summation).  Traced
    steps are fenced (``block_until_ready``) so span durations measure
    completed device work; without a tracer no fence is added.

    Timing: step 0 is fenced separately — ``report.compile_s`` is the
    first step's wall time (dominated by jit trace+compile) and
    ``wall_time_s`` covers the remaining steady-state steps only, so
    throughput figures no longer conflate compilation with step time.

    ``planner`` (a :class:`repro.core.jaca.AdaptivePlanner`) switches on
    online cache adaptation: at the controller's re-plan boundaries
    (refresh steps, thinned by ``controller.replan_every``) the planner's
    live eviction state is materialised into a new plan and swapped into
    the runtime — via :meth:`~SimRuntime.step_transition` when pipelining
    (the transition step prefetches the *new* plan's rows inside the old
    plan's refresh windows) or ``set_plan`` + a plain refresh otherwise.
    The runtime must have been built against the planner's capacity-padded
    exchange layout so the swap never retraces; byte accounting follows
    the *active* plan(s) per step and stays exact across re-plan events.

    ``params0``/``opt_state0`` resume from checkpointed state instead of a
    fresh init (the staleness schedule restarts, whose first step is a
    refresh — required anyway since the caches start zero-filled).

    ``faults`` (a :class:`repro.faults.FaultPlan`) arms deterministic
    fault injection; ``guard`` (a :class:`repro.faults.GuardConfig`)
    configures the defenses — fetch retry/stale-reuse (via the runtime's
    ``set_fault_guard``), the divergence guard (per-step loss finiteness
    plus a fenced parameter sweep + snapshot every ``guard_every`` steps,
    rolling back and forcing a plain refresh on divergence), opt-in
    per-tier payload checksums (corruption forces a refresh of the
    affected tier), and memory-pressure capacity backoff (requires
    ``planner``).  With the default disabled plan and no guard, this loop
    is byte-for-byte the pre-faults code path: no extra sync points, no
    behavior change.  Guard-forced refreshes replace pipelined/transition
    steps with *plain* refreshes — a poisoned stale tier must never be
    consumed.  Injected and defended event counts land in the report
    (``faults_injected`` / ``fault_events``) and as per-step
    :class:`~repro.obs.StepCounters` fields.
    """
    if spec is None:
        warn_loose_kwargs("train_capgnn")
        base = getattr(runtime, "spec", None)
        spec = (base.replace(pipeline=pipeline, seed=seed)
                if base is not None
                else TrainSpec(pipeline=pipeline, seed=seed))
    else:
        pipeline = spec.pipeline
        seed = spec.seed
    if controller is None:
        controller = StalenessController(refresh_every=xplan.refresh_every)
    params = params0 if params0 is not None else init_gnn(
        jax.random.PRNGKey(seed), cfg)
    opt_state = opt_state0 if opt_state0 is not None else opt.init(params)
    caches = init_caches(cfg, xplan, num_parts,
                         features=getattr(runtime, "features", "device"))
    store = getattr(runtime, "host_store", None)
    store_snap = store.snapshot() if store is not None else None
    dims = getattr(runtime, "comm_dims", list(cfg.feat_dims[:cfg.num_layers]))
    # actual wire width of one halo payload entry (2 under halo_dtype=bf16);
    # the vanilla baseline ships the same payload dtype, so the reduction
    # isolates the caching effect.
    dtype_bytes = getattr(runtime, "halo_dtype_bytes", 4)

    tr = tracer if tracer is not None else NULL_TRACER
    if tr.enabled and hasattr(runtime, "set_tracer"):
        runtime.set_tracer(tr)

    fa = faults if faults is not None else NULL_FAULTS
    if fa.enabled and fa.has("mem_pressure") and planner is None:
        raise ValueError(
            "mem_pressure faults need an AdaptivePlanner: the backoff "
            "defense shrinks capacity and replans through it")
    gd = None
    ev_snap = inj_snap = None
    if fa.enabled or guard is not None:
        gd = TrainGuard(guard if guard is not None else GuardConfig(),
                        store=store)
        if hasattr(runtime, "set_fault_guard"):
            runtime.set_fault_guard(gd.fetch_guard)
        if fa.enabled and store is not None:
            store.set_faults(fa)
        if gd.cfg.guard_every > 0:
            gd.snapshot(-1, params, opt_state)   # rollback floor
        gd.seal(caches)                          # checksum baseline
        ev_snap = gd.events.as_dict()
        inj_snap = fa.total_injected()

    losses: list[float] = []
    val_acc: list[float] = []
    comm = 0
    vanilla = 0
    refresh_steps = 0
    replan_events = 0
    x_active = xplan
    dim_bytes = sum(d * dtype_bytes for d in dims)
    rows_by_worker = None   # per-worker uncached recv rows (traced runs)
    step_snap = (store.snapshot()
                 if store is not None and tr.enabled else None)
    compile_s = 0.0
    pending_refresh = False   # guard-forced refresh for the NEXT step
    t0 = time.perf_counter()
    for e in range(epochs):
        force_refresh, pending_refresh = pending_refresh, False
        mem = False
        if fa.enabled:
            fa.begin_step(e)
            params = fa.corrupt_params(params)
            caches, _ = fa.corrupt_caches(caches, store)
            mem = fa.mem_pressure()
        if gd is not None and gd.cfg.checksums:
            with tr.span("integrity", step=e):
                corrupted = gd.verify(caches)
            if corrupted:
                force_refresh = True
        refresh = controller.should_refresh()
        replan = planner is not None and controller.should_replan()
        if mem:
            # memory-pressure backoff: shrink the cache capacity and
            # replan through the slot-stable machinery this very step
            with tr.span("mem_backoff", step=e):
                planner.shrink_capacity(gd.cfg.mem_backoff_factor)
            gd.events.mem_backoffs += 1
            replan = True
        if force_refresh:
            gd.events.forced_refreshes += 1
        refresh = refresh or force_refresh or mem
        # a guard-forced refresh must be a PLAIN refresh: pipelined /
        # transition flavours consume the stale tiers being quarantined
        if replan:
            kind = ("transition" if pipeline and not force_refresh
                    else "refresh")
        elif refresh and pipeline and controller.step > 0 and not force_refresh:
            kind = "pipelined"
        elif refresh:
            kind = "refresh"
        else:
            kind = "cached"
        with tr.step_span(kind, e):
            if replan:
                with tr.span("replan", step=e):
                    x_next = planner.exchange_plan(planner.replan())
                if pipeline and not force_refresh:
                    # transition step: consume/exchange on the old plan,
                    # prefetch the new plan's tier rows in the ring windows
                    params, opt_state, caches, m = runtime.step_transition(
                        params, opt_state, caches, x_next)
                    x_read, x_emit = x_active, x_next
                else:
                    runtime.set_plan(x_next)
                    params, opt_state, caches, m = runtime.step_refresh(
                        params, opt_state, caches)
                    x_read = x_emit = x_next
                refreshed_tiers = True
                x_active = x_next
                replan_events += 1
            else:
                if (refresh and pipeline and controller.step > 0
                        and not force_refresh):
                    step_fn = runtime.step_pipelined
                elif refresh:
                    step_fn = runtime.step_refresh
                else:
                    step_fn = runtime.step_cached
                params, opt_state, caches, m = step_fn(params, opt_state,
                                                       caches)
                x_read = x_emit = x_active
                refreshed_tiers = refresh
            step_rows = _step_rows(x_read, x_emit, refresh=refreshed_tiers)
            tr.fence(m["loss"])
        losses.append(float(m["loss"]))
        if e == 0:
            # fence step 0 separately: its wall time is dominated by jit
            # trace+compile and must not pollute the steady-state figure
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
        comm += step_rows * dim_bytes
        vanilla += xplan.total_halo * dim_bytes
        refresh_steps += int(refresh)
        # divergence guard: the loss is already a host float (free check
        # every step); the fenced parameter sweep + snapshot run on the
        # guard_every cadence.  Divergence rolls back to the last good
        # snapshot and forces the next step to be a plain refresh.
        diverged = False
        if gd is not None and gd.cfg.guard_every > 0:
            diverged = not np.isfinite(losses[-1])
            if not diverged and (e + 1) % gd.cfg.guard_every == 0:
                with tr.span("divergence_check", step=e):
                    diverged = not gd.params_finite(params)
                if not diverged:
                    gd.snapshot(e, params, opt_state)
            if diverged:
                with tr.span("rollback", step=e):
                    params, opt_state = gd.rollback(params, opt_state)
                pending_refresh = True
        # On a transition step the fresh rows are laid out for the NEW plan
        # while the compared caches hold the OLD plan's rows, so the drift
        # metrics compare different vertices — skip them entirely there
        # (and on diverged steps, whose drift is non-finite).
        drift = (float(m["drift"])
                 if "drift" in m and not replan and not diverged else None)
        if planner is not None:
            planner.observe_step(layers=max(1, len(dims)))
            if "drift_local_rows" in m and not replan and not diverged:
                planner.observe_drift(np.asarray(m["drift_local_rows"]),
                                      np.asarray(m["drift_global_rows"]))
        controller.observe(drift, refreshed=refresh)
        if eval_every and (e + 1) % eval_every == 0:
            with tr.span("eval", step=e):
                val_acc.append(runtime.evaluate(params, "val")[1])
        if tr.enabled:
            # counters are recorded at iteration end so the store deltas
            # (step + any eval fetches) attribute to this step exactly —
            # the per-step stream sums to the report totals
            sd = {}
            if store is not None:
                sd = store.delta(step_snap)
                step_snap = store.snapshot()
            if refreshed_tiers or rows_by_worker is None:
                rows_by_worker = [int(n) for n in np.asarray(
                    x_read.uncached.recv_valid).sum(axis=1)]
            extra = {}
            if gd is not None:
                # per-step defense/injection deltas: the stream sums to
                # the report's fault_events / faults_injected exactly
                extra = gd.events.delta(ev_snap)
                ev_snap = gd.events.as_dict()
                extra["faults_injected"] = fa.total_injected() - inj_snap
                inj_snap = fa.total_injected()
            tr.count(StepCounters(
                step=e, kind=kind,
                wire_rows_uncached=x_read.uncached.n_rows,
                wire_rows_local=(x_emit.local.n_rows
                                 if refreshed_tiers else 0),
                wire_rows_global=(x_emit.glob.n_unique
                                  if refreshed_tiers else 0),
                wire_bytes=step_rows * dim_bytes,
                wire_bytes_vanilla=xplan.total_halo * dim_bytes,
                cache_hit_rate=(None if refreshed_tiers else
                                1.0 - x_read.uncached.n_rows
                                / max(1, x_read.total_halo)),
                planner_hit_rate=(planner.hit_rate()
                                  if planner is not None else None),
                drift=drift,
                host_fetch_rows=int(sd.get("fetch_rows", 0)),
                host_fetch_bytes=int(sd.get("fetch_bytes", 0)),
                host_writeback_rows=int(sd.get("writeback_rows", 0)),
                host_writeback_bytes=int(sd.get("writeback_bytes", 0)),
                device_peak_bytes=device_peak_bytes(),
                wire_rows_by_worker=rows_by_worker, **extra))
        if gd is not None and gd.cfg.checksums:
            # seal the post-step tier payloads: the digests the next
            # consuming step must still observe
            with tr.span("integrity", step=e):
                gd.seal(caches)
    wall = time.perf_counter() - t0
    fa.end_run()

    # note: eval_every runs also consume accounted host fetches, so pin
    # eval_every=0 when asserting the plan-rows == staged-rows identity
    hostd = store.delta(store_snap) if store is not None else {}
    report = TrainReport(
        losses=losses, val_acc=val_acc, comm_bytes=comm,
        comm_bytes_vanilla=vanilla,
        comm_reduction=1.0 - comm / max(vanilla, 1),
        refresh_steps=refresh_steps, cached_steps=epochs - refresh_steps,
        wall_time_s=wall, replan_events=replan_events,
        hit_rate=planner.hit_rate() if planner is not None else None,
        final_opt_state=opt_state,
        host_fetch_rows=int(hostd.get("fetch_rows", 0)),
        host_fetch_bytes=int(hostd.get("fetch_bytes", 0)),
        host_writeback_bytes=int(hostd.get("writeback_bytes", 0)),
        compile_s=compile_s,
        faults_injected=dict(fa.injected) if fa.enabled else None,
        fault_events=gd.events.as_dict() if gd is not None else None,
        phase_stats=tr.phase_stats() if tr.enabled else None,
        spec=spec.to_dict())
    return params, report
