"""``TrainSpec`` — the one configuration surface of the distributed
runtimes.

Nine PRs of accreted keyword arguments left ``make_sim_runtime`` /
``make_spmd_runtime`` / ``train_capgnn`` each taking 10+ loose
parameters, mirrored as ~20 ``launch.train`` flags — too brittle a
surface to absorb a second distribution model.  ``TrainSpec`` is the
consolidation: a frozen, validated, JSON-serialisable dataclass that the
CLI, the benchmarks and the parity scripts all build runtimes through.

- Construction: directly, or :meth:`TrainSpec.from_cli_args` (accepts
  any object with the ``launch.train gnn`` attribute names — an
  ``argparse.Namespace`` or a plain namespace in tests/benchmarks).
- Validation happens in ``__post_init__`` — including the capability
  checks of the selected distribution strategy (``repro.dist.strategy``):
  e.g. ``features="host"`` or ``pipeline=True`` under ``spmm_15d`` is a
  ``ValueError`` at spec-build time, not a crash mid-train.
- ``to_dict``/``from_dict`` round-trip: every ``TrainReport`` carries
  ``spec=spec.to_dict()`` so each experiments/*.json records the exact
  configuration that produced it.

The loose kwargs on the three constructors remain as deprecated shims
that forward into a spec (one ``DeprecationWarning`` per call); see the
README migration note for the removal plan.
"""
from __future__ import annotations

import dataclasses
import warnings

__all__ = ["TrainSpec", "BACKENDS", "TRANSPORTS", "FEATURES",
           "HALO_DTYPES", "CACHE_POLICIES", "warn_loose_kwargs",
           "halo_dtype_name"]

BACKENDS = ("edges", "ell", "hybrid")
TRANSPORTS = ("allgather", "p2p")
FEATURES = ("device", "host")
HALO_DTYPES = ("f32", "bf16")
CACHE_POLICIES = ("static", "overlap", "lru", "fifo", "drift")


def warn_loose_kwargs(fn_name: str) -> None:
    """The deprecation notice the runtime-constructor shims emit when
    configured through loose keyword arguments instead of ``spec=``."""
    warnings.warn(
        f"{fn_name}: configuring the runtime through loose keyword "
        "arguments is deprecated; build a repro.dist.TrainSpec and pass "
        "spec= (see the README migration note — the loose kwargs will be "
        "removed once downstream callers have migrated)",
        DeprecationWarning, stacklevel=3)


def halo_dtype_name(halo_dtype) -> str:
    """Normalise a loose ``halo_dtype`` kwarg value (None / strings /
    jnp dtypes) to the spec's canonical ``"f32" | "bf16"``."""
    if halo_dtype in (None, "f32", "fp32", "float32"):
        return "f32"
    if halo_dtype in ("bf16", "bfloat16"):
        return "bf16"
    name = getattr(halo_dtype, "__name__", str(halo_dtype))
    return "bf16" if "bfloat16" in name else "f32"


def _check(value, name: str, allowed) -> None:
    if value not in allowed:
        raise ValueError(f"unknown {name} {value!r}; expected one of "
                         f"{tuple(allowed)}")


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """Validated, serialisable configuration of one distributed training
    run.  Object-valued collaborators (host store, mesh, planner, tracer)
    are *not* spec fields — they stay explicit runtime arguments; the
    spec holds everything that is a choice, not a resource.
    """
    # distribution model (repro.dist.strategy registry)
    strategy: str = "halo_1d"
    replication: int = 1            # 1.5D row-replication factor c
    # runtime construction
    backend: str = "edges"          # local aggregation operator
    transport: str = "allgather"    # SPMD halo transport (halo_1d)
    features: str = "device"        # feature residency: device | host
    halo_dtype: str = "f32"         # wire payload dtype (f32 | bf16)
    exchange_layer0: bool = True
    donate: bool = True
    interpret: bool = True          # Pallas interpret mode (CPU CI)
    pallas_pack: bool = False
    prefetch_depth: int = 2         # host-store double-buffer depth
    # staleness / caching schedule (halo_1d)
    pipeline: bool = False
    refresh_every: int = 1
    cache_policy: str = "static"
    replan_every: int = 1
    cpu_cache_gib: float = 4.0
    # fault injection + defenses (repro.faults)
    faults: str = ""                # FaultPlan.parse spec string
    guard_every: int = 0
    fetch_retries: int | None = None
    checksums: bool = False
    seed: int = 0

    def __post_init__(self):
        _check(self.backend, "backend", BACKENDS)
        _check(self.transport, "transport", TRANSPORTS)
        _check(self.features, "features mode", FEATURES)
        _check(self.halo_dtype, "halo dtype", HALO_DTYPES)
        _check(self.cache_policy, "cache policy", CACHE_POLICIES)
        if self.replication < 1:
            raise ValueError(f"replication must be >= 1, got "
                             f"{self.replication}")
        if self.refresh_every < 1:
            raise ValueError(f"refresh_every must be >= 1, got "
                             f"{self.refresh_every}")
        if self.prefetch_depth < 1:
            raise ValueError(f"prefetch_depth must be >= 1, got "
                             f"{self.prefetch_depth}")
        # strategy-capability validation (late import: strategy.py type-
        # checks against specs, keeping this module import-cycle-free)
        from repro.dist.strategy import get_strategy
        strat = get_strategy(self.strategy)
        strat.validate_spec(self)

    # ------------------------------------------------------------- I/O
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TrainSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown TrainSpec fields {sorted(unknown)}")
        return cls(**d)

    def replace(self, **kw) -> "TrainSpec":
        return dataclasses.replace(self, **kw)

    @classmethod
    def from_cli_args(cls, args) -> "TrainSpec":
        """Build a spec from ``launch.train gnn``-style flags.  ``args``
        is any object carrying the flag attributes (missing attributes
        fall back to the CLI defaults), so benchmarks can pass a plain
        namespace instead of re-running the parser."""
        def get(name, default):
            return getattr(args, name, default)

        strategy = get("strategy", "halo_1d")
        spec = dict(
            strategy=strategy,
            replication=int(get("replication", 1)),
            backend=get("backend", "edges"),
            transport=get("transport", "allgather"),
            features=get("features", "device"),
            halo_dtype=get("halo_dtype", "f32"),
            exchange_layer0=not get("jaca", True),
            donate=get("donate", True),
            interpret=get("interpret", True),
            pallas_pack=get("pallas_pack", False),
            prefetch_depth=int(get("prefetch_depth", 2)),
            pipeline=bool(get("pipeline", False)),
            refresh_every=int(get("refresh_every", 1)),
            cache_policy=get("cache_policy", "static"),
            replan_every=int(get("replan_every", 1)),
            cpu_cache_gib=float(get("cpu_cache_gib", 4.0)),
            faults=get("faults", ""),
            guard_every=int(get("guard_every", 0) or 0),
            fetch_retries=get("fetch_retries", None),
            checksums=bool(get("checksums", False)),
            seed=int(get("seed", 0)),
        )
        if strategy == "spmm_15d":
            # spmm_15d runs refresh-equivalent exact steps: staleness /
            # caching / pipelining knobs are halo_1d machinery, so the
            # CLI's halo-oriented defaults are normalised away rather
            # than tripping the capability validation
            spec.update(pipeline=False, refresh_every=1,
                        cache_policy="static", replan_every=1)
        return cls(**spec)
