"""Host-resident feature/embedding store with async staged host→device
fetch — the out-of-core tier behind the runtimes' ``features="host"`` mode
and the serve engine's host tier.

The paper's JACA plans a *shared CPU cache* (C_CPU) next to the per-worker
GPU caches; until this module the runtimes emulated it as a replicated
**device** buffer, so every "host-cached" row still spent HBM.  The store
makes the CPU tier real:

- **feature table** — the stacked halo input features stay host numpy;
  each step stages exactly the plan's host-fetched rows
  (:class:`~repro.dist.exchange.HostTier`) to the device via
  ``jax.device_put`` — the transfer is *async*, so a staged buffer issued
  before a jitted step dispatch rides under that step's compute (BGL's
  pipelined-fetch observation; the double-buffer ring below keeps up to
  ``prefetch_depth`` fetches in flight).
- **global-tier buffers** — the per-exchange-layer deduplicated global
  cache ``[G, d]`` lives here between steps: refresh steps build it
  on-wire and write it *back* (d2h), cached steps stage it h2d for the
  stale reads.  Capacity is charged against measured host RAM
  (:func:`repro.core.device_profile.detect_host_mem_gib`), not the device.

``halo_dtype`` mirrors the wire compression: staged payloads are cast on
the **host** side (PCIe moves the narrow dtype) and dequantised back to
the compute dtype on device — same numerics as the compressed halo
transport.

Byte accounting is exact and *consumption*-driven: a staged fetch is a
:class:`StagedFetch` carrying its valid-row/byte counts, and the runtimes
account it via :meth:`HostFeatureStore.account_fetch` when the step that
consumes it is dispatched — prefetched-then-flushed buffers (plan change)
never pollute the counters, so plan-counted host-fetch rows == accounted
staged valid rows == the ``bytes_per_step`` deltas the forced-multi-device
harness asserts.

CPU-backend caveat: ``jax.device_put`` of a numpy array may alias the host
buffer (zero-copy).  Every ``stage_*`` therefore device_puts a *fresh*
gather result (numpy fancy indexing allocates) and never mutates a buffer
after staging it — the ring only retains handles to bound in-flight
transfers, it does not recycle storage.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.faults.plan import NULL_FAULTS
from repro.obs.tracer import NULL_TRACER

__all__ = ["HostFeatureStore", "StagedFetch", "halo_dtype_info",
           "suggest_prefetch_depth"]


def halo_dtype_info(halo_dtype) -> tuple:
    """Normalise the halo payload dtype knob -> ``(cast dtype | None, bytes)``.

    ``None``/f32 ships halo rows at full width; ``"bf16"`` casts the
    payload before transport (wire or PCIe) and dequantises back to the
    compute dtype on the consuming side — halving every tier's bytes
    (threaded through :meth:`~repro.dist.ExchangePlan.bytes_per_step` and
    the host-fetch accounting via ``dtype_bytes``).
    """
    import jax.numpy as jnp
    if halo_dtype in (None, "f32", "fp32", "float32", jnp.float32):
        return None, 4
    if halo_dtype in ("bf16", "bfloat16", jnp.bfloat16):
        return jnp.bfloat16, 2
    raise ValueError(f"unknown halo_dtype {halo_dtype!r}; "
                     "expected None, 'f32' or 'bf16'")


@dataclasses.dataclass(frozen=True)
class StagedFetch:
    """One in-flight host→device fetch: the device handle plus the exact
    valid-row/byte counts the consuming step must account."""
    array: object      # jax.Array, transfer possibly still in flight
    rows: int          # valid rows gathered (padding rows excluded)
    nbytes: int        # rows x feat_dim x staged dtype width
    gather_s: float    # host-side gather+cast seconds (excludes transfer)


def suggest_prefetch_depth(fetch_bytes_per_step: int, step_s: float,
                           h2d_gib_s: float, max_depth: int = 8) -> int:
    """Prefetch depth from measured H2D bandwidth: enough in-flight
    fetches to cover one step's host bytes within one step time,
    ``max(1, ceil(transfer_s / step_s))``, clamped to ``max_depth``.

    ``h2d_gib_s`` comes from a measured profile's ``h2d`` time (see
    :func:`repro.core.device_profile.measure_profile`, which also reports
    ``host_mem_gib`` for capacity sizing).
    """
    if fetch_bytes_per_step <= 0 or step_s <= 0 or h2d_gib_s <= 0:
        return 2
    transfer_s = fetch_bytes_per_step / (h2d_gib_s * 1024.0 ** 3)
    return int(np.clip(np.ceil(transfer_s / step_s), 1, max_depth))


class HostFeatureStore:
    """Host tables + staged-fetch machinery (see module docstring).

    ``feat`` is any host array whose leading index tuple selects rows:
    the training runtimes pass the stacked halo features ``[P, NH, F]``
    and gather by ``(part, halo_pos)``; the serve engine passes the
    precomputed logits table ``[N, C]`` and gathers by node id.
    """

    def __init__(self, feat: np.ndarray, halo_dtype=None,
                 prefetch_depth: int = 2):
        self.feat = np.ascontiguousarray(feat, dtype=np.float32)
        self.cast_dtype, self.dtype_bytes = halo_dtype_info(halo_dtype)
        self.prefetch_depth = max(1, int(prefetch_depth))
        # per-exchange-layer global-tier buffers: layer -> (table, n_valid)
        self._bufs: dict[int, tuple[np.ndarray, int]] = {}
        self._inflight: deque = deque()
        self.stats = {"fetches": 0, "fetch_rows": 0, "fetch_bytes": 0,
                      "writebacks": 0, "writeback_rows": 0,
                      "writeback_bytes": 0, "gather_s": 0.0}
        self.tracer = NULL_TRACER
        self.faults = NULL_FAULTS

    def set_tracer(self, tracer) -> None:
        """Attach a :class:`repro.obs.Tracer`: every h2d dispatch records
        an ``h2d_put`` sub-span (nested inside whatever staging span the
        caller holds open).  Default is the shared no-op tracer."""
        self.tracer = tracer

    def set_faults(self, faults) -> None:
        """Attach a :class:`repro.faults.FaultPlan`: every stage op
        consults it once (``on_fetch`` — injected drops raise
        :class:`repro.faults.FetchError`, injected delays stall the host
        gather).  Default is the shared disabled plan, whose consult is a
        single attribute check."""
        self.faults = faults

    # -- staging -----------------------------------------------------------

    def _cast(self, rows: np.ndarray) -> np.ndarray:
        if self.cast_dtype is not None:
            return rows.astype(self.cast_dtype)
        return rows

    def _put(self, rows: np.ndarray, device) -> object:
        import jax
        with self.tracer.span("h2d_put", nbytes=int(rows.nbytes)):
            handle = (jax.device_put(rows, device) if device is not None
                      else jax.device_put(rows))
            self._inflight.append(handle)
            while len(self._inflight) > self.prefetch_depth:
                # bound in-flight transfers: block on the oldest fetch only
                # once `prefetch_depth` newer ones are behind it (consumed
                # handles may already be donated into a step — skip those)
                old = self._inflight.popleft()
                if not getattr(old, "is_deleted", lambda: False)():
                    jax.block_until_ready(old)
        return handle

    def stage_rows(self, idx, valid: np.ndarray | None = None,
                   device=None) -> StagedFetch:
        """Stage ``feat[idx]`` to the device: host gather, zero invalid
        (padding) rows, ``halo_dtype`` cast, async ``device_put``.

        ``idx`` is any numpy fancy index into ``feat``'s leading dims
        (e.g. ``(part[:, None], halo_pos)`` for the stacked layout);
        ``valid`` masks real rows.  Returns a :class:`StagedFetch` the
        caller accounts via :meth:`account_fetch` when consumed.
        """
        t0 = time.perf_counter()
        # injected delays land inside the timed gather window, so the
        # slow-fetch defense observes them through ``gather_s`` like any
        # genuinely slow host gather would
        if self.faults.enabled:
            self.faults.on_fetch()
        rows = self.feat[idx]
        if valid is not None:
            rows = np.where(np.asarray(valid)[..., None], rows, 0.0)
            n = int(np.asarray(valid).sum())
        else:
            n = int(np.prod(rows.shape[:-1]))
        rows = self._cast(np.ascontiguousarray(rows, np.float32))
        gather_s = time.perf_counter() - t0
        return StagedFetch(array=self._put(rows, device), rows=n,
                           nbytes=n * rows.shape[-1] * self.dtype_bytes,
                           gather_s=gather_s)

    def fetch_rows(self, idx, device=None) -> np.ndarray:
        """Synchronous staged fetch for the serve path: same gather/stage
        machinery, accounted immediately, materialised back to numpy."""
        import jax
        staged = self.stage_rows(idx, device=device)
        self.account_fetch(staged)
        return np.asarray(jax.block_until_ready(staged.array),
                          dtype=np.float32)

    def account_fetch(self, staged: StagedFetch) -> None:
        """Record one *consumed* staged fetch.  Prefetches flushed by a
        plan change are never accounted — staged == consumed stays exact."""
        self.stats["fetches"] += 1
        self.stats["fetch_rows"] += staged.rows
        self.stats["fetch_bytes"] += staged.nbytes
        self.stats["gather_s"] += staged.gather_s

    # -- global-tier buffers ----------------------------------------------

    def write_buf(self, layer: int, device_buf, n_valid: int) -> None:
        """d2h writeback of one exchange layer's freshly built global
        buffer ``[G, d]`` (f32, dequantised — matching the device-mode
        cache content).  ``n_valid`` is the plan's ``glob.n_unique``."""
        rows = np.asarray(device_buf, dtype=np.float32)
        self._bufs[layer] = (rows, int(n_valid))
        self.stats["writebacks"] += 1
        self.stats["writeback_rows"] += int(n_valid)
        self.stats["writeback_bytes"] += int(n_valid) * rows.shape[-1] * 4

    def stage_buf(self, layer: int, device=None) -> StagedFetch:
        """Stage one exchange layer's host-resident global buffer to the
        device (h2d, ``halo_dtype``-cast like every staged payload)."""
        if layer not in self._bufs:
            raise KeyError(f"global buffer for exchange layer {layer} was "
                           "never written back; run a refresh step first")
        rows, n_valid = self._bufs[layer]
        t0 = time.perf_counter()
        if self.faults.enabled:
            self.faults.on_fetch()
        payload = self._cast(rows)
        gather_s = time.perf_counter() - t0
        return StagedFetch(array=self._put(payload, device), rows=n_valid,
                           nbytes=n_valid * rows.shape[-1] * self.dtype_bytes,
                           gather_s=gather_s)

    def init_buf(self, layer: int, shape: tuple, n_valid: int) -> None:
        """Zero-fill one layer's buffer (cold caches; matches the
        zero-initialised device caches of ``init_caches``)."""
        self._bufs[layer] = (np.zeros(shape, np.float32), int(n_valid))

    def has_buf(self, layer: int) -> bool:
        return layer in self._bufs

    def buf_layers(self) -> list[int]:
        """Exchange layers with a host-resident global buffer — the
        host-side tier set the integrity checksums cover."""
        return sorted(self._bufs)

    def buf_table(self, layer: int) -> np.ndarray:
        """Read-only view of one layer's host buffer (integrity digests
        and fault injection; do **not** mutate — staged payloads may
        alias it, see the module docstring's zero-copy caveat)."""
        return self._bufs[layer][0]

    def set_buf(self, layer: int, rows: np.ndarray) -> None:
        """Replace one layer's buffer *storage* keeping its valid count —
        the corruption injector swaps in a modified copy instead of
        mutating in place (staged payloads may alias the old storage)."""
        _, n_valid = self._bufs[layer]
        self._bufs[layer] = (np.ascontiguousarray(rows, np.float32), n_valid)

    # -- accounting --------------------------------------------------------

    def snapshot(self) -> dict:
        return dict(self.stats)

    def delta(self, before: dict) -> dict:
        return {k: self.stats[k] - before.get(k, 0) for k in self.stats}

    def resident_bytes(self) -> int:
        """Host bytes the store holds (feature table + buffers) — what the
        out-of-core benchmark charges against host RAM instead of HBM."""
        return int(self.feat.nbytes
                   + sum(b.nbytes for b, _ in self._bufs.values()))
