"""``spmm_15d``: communication-avoiding 1.5D replicated-row block SpMM.

The halo model's wire volume tracks the partition cut, which grows with P
until nearly every boundary vertex is consumed remotely.  Tripathy,
Yelick & Buluç ("Reducing Communication in Graph Neural Network
Training", PAPERS.md) avoid that wall by trading memory for bandwidth:
replicate block rows of H over a replication axis of size ``c`` and
aggregate partial SpMM products with an allreduce, cutting the gathered
volume by ``c`` at the cost of an ``[NI, d]`` allreduce per layer.

Layout.  The graph is split into ``pr = P / c`` block rows (the ordinary
1D partitioner — RAPA/METIS reuse).  The ``P``-device mesh is the paper's
2D ``(P/c, c)`` grid with the block-row axis factored into two named
axes, ``("grp", "sub")`` of sizes ``(c, g = pr/c)`` (hence the classic
``P % c**2 == 0`` constraint), plus the replication axis ``("repl", c)``.
Device ``(a, s, j)`` holds block row ``i = a*g + s`` of H (replicated
over ``j``) and the edges of block row ``i`` whose *source* block belongs
to group ``j`` (blocks ``j*g .. j*g+g-1``), with source indices remapped
to ``(k % g) * NI + owner_row`` — positions in the gathered group buffer.

Per layer, each device:

1. ``ppermute`` over ``("grp", "repl")`` — the involution ``(a, j) ->
   (j, a)`` — after which device ``(a, s, j)`` holds block ``j*g + s``
   (skipped when ``c == 1``: the permutation is the identity);
2. ``all_gather`` over ``"sub"`` — now it holds all ``g`` blocks of
   group ``j``, exactly the rows its edge chunk reads (skipped when
   ``g == 1``);
3. local partial SpMM of its chunk (segment-sum, zero-weight padding);
4. ``psum`` over ``"repl"`` sums the ``c`` partial aggregations into the
   exact neighborhood sum for block row ``i`` (skipped when ``c == 1``),
   after which the (replicated) layer transform applies.

``c == 1`` degenerates to the dense 1D baseline (full-H ``all_gather``);
``c > 1`` gathers ``1/c`` of H per device.  Every step is
refresh-equivalent and exact — the JACA tiers, staleness and the host
store are ``halo_1d`` capabilities (see ``StrategyCaps``).

Gradients.  The loss contribution of each block row is computed on all
``c`` replicas, so the final-loss cotangent enters the last layer's
``psum`` *replicated* — under ``shard_map`` the transpose of ``psum`` is
another ``psum``, which over-counts that (and only that) boundary by
``c``; deeper psums receive per-replica *partial* cotangent shares, for
which the summing transpose is exactly right.  Net effect: every
parameter's all-device grad psum carries one uniform factor ``c`` — so
the step divides the psummed loss and grads by ``c`` and lands on the
oracle's exact mean-loss gradient (pinned to 1e-5 by
``tests/spmm15d_parity_script.py``).

Byte accounting.  ``forward_collective_bytes_per_device`` models the
result-shape bytes of exactly the collectives above, matching
:func:`repro.launch.dryrun.collective_bytes` over the lowered forward
HLO op-for-op (gated in ``benchmarks/comm_volume.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from .strategy import StrategyCaps, StrategyCapabilityError

__all__ = ["Spmm15dLayout", "Spmm15dRuntime", "Spmm15DStrategy",
           "build_spmm15d_layout", "make_spmm15d_mesh",
           "make_spmm15d_runtime", "train_spmm15d",
           "forward_collective_bytes_per_device", "SPMM_15D"]

AXES_15D = ("grp", "sub", "repl")


@dataclasses.dataclass(frozen=True)
class Spmm15dLayout:
    """Static 1.5D layout: the ``pr``-block stacking (reused from
    ``stack_partitions``) plus per-device edge chunks with gathered-buffer
    source indices.  Flat device order is row-major over
    ``(grp, sub, repl)`` — device ``i*c + j`` serves block row ``i``,
    replica ``j``."""
    c: int                      # replication factor
    g: int                      # blocks per group (= pr / c)
    pr: int                     # block rows (= P / c)
    ni: int                     # padded rows per block (sp.n_inner_max)
    sp: object                  # StackedParts over the pr block rows
    chunk_src: np.ndarray       # [P, ME] int32 into [0, g*ni)
    chunk_dst: np.ndarray       # [P, ME] int32 into [0, ni]; ni = padding
    chunk_w: np.ndarray         # [P, ME] float32; 0 at padding
    n_edges_dev: np.ndarray     # [P] real edges per device chunk

    @property
    def n_devices(self) -> int:
        return self.pr * self.c

    @property
    def block_of_dev(self) -> np.ndarray:
        return np.repeat(np.arange(self.pr), self.c)

    @property
    def edges_total(self) -> int:
        return int(self.n_edges_dev.sum())


def build_spmm15d_layout(ps, task, spec) -> Spmm15dLayout:
    """Compile the 1.5D layout from an ordinary ``pr``-way partition.

    ``ps.num_parts`` is the block-row count ``pr``; the run needs
    ``pr * c`` devices and ``pr % c == 0`` (i.e. ``P % c**2 == 0``)."""
    from .exchange import stack_partitions

    c = spec.replication
    pr = ps.num_parts
    if pr % c:
        raise StrategyCapabilityError(
            f"spmm_15d with replication c={c} needs the block-row count "
            f"divisible by c (P % c**2 == 0); got pr={pr} block rows — "
            f"use {pr * c} devices with pr a multiple of {c}")
    g = pr // c
    sp = stack_partitions(ps, task, backend="edges")
    ni = sp.n_inner_max

    n = ps.graph.num_nodes
    owner_row = np.full(n, -1, np.int64)
    for part in ps.parts:
        owner_row[part.inner_nodes] = np.arange(part.n_inner)
    owner_part = ps.assign.astype(np.int64)

    chunks: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    for pt in ps.parts:
        src, dst = pt.local_graph.edges()
        keep = dst < pt.n_inner
        src, dst = src[keep], dst[keep]
        w = (pt.local_graph.edge_weight[keep]
             if pt.local_graph.edge_weight is not None
             else np.ones(src.shape[0], np.float32))
        gid = np.empty(src.shape[0], np.int64)
        inner = src < pt.n_inner
        gid[inner] = pt.inner_nodes[src[inner]]
        gid[~inner] = pt.halo_nodes[src[~inner] - pt.n_inner]
        k = owner_part[gid]
        src15 = ((k % g) * ni + owner_row[gid]).astype(np.int32)
        grp = k // g
        for j in range(c):
            sel = grp == j
            chunks.append((src15[sel], dst[sel].astype(np.int32),
                           w[sel].astype(np.float32)))

    p_dev = pr * c
    me = max(1, max(s.shape[0] for s, _, _ in chunks))
    chunk_src = np.zeros((p_dev, me), np.int32)
    chunk_dst = np.full((p_dev, me), ni, np.int32)   # ni row => dropped
    chunk_w = np.zeros((p_dev, me), np.float32)
    for d, (s, t, w) in enumerate(chunks):
        m = s.shape[0]
        chunk_src[d, :m] = s
        chunk_dst[d, :m] = t
        chunk_w[d, :m] = w
    n_edges_dev = np.array([s.shape[0] for s, _, _ in chunks], np.int64)
    return Spmm15dLayout(c=c, g=g, pr=pr, ni=ni, sp=sp,
                         chunk_src=chunk_src, chunk_dst=chunk_dst,
                         chunk_w=chunk_w, n_edges_dev=n_edges_dev)


def forward_collective_bytes_per_device(layout: Spmm15dLayout, cfg,
                                        spec) -> int:
    """Modeled per-device result-shape bytes of the forward collectives —
    the quantity :func:`repro.launch.dryrun.collective_bytes` measures on
    the lowered forward HLO: per layer one ``collective-permute``
    (``[ni, d]``, wire dtype; c > 1), one ``all-gather`` (``[g*ni, d]``,
    wire dtype; g > 1) and one ``all-reduce`` (``[ni, d]``, f32; c > 1).
    With ``exchange_layer0=False`` layer 0's permute/gather drop out (the
    gathered input features are pre-replicated at build time) while its
    partial-aggregation psum remains."""
    wire = 2 if spec.halo_dtype == "bf16" else 4
    c, g, ni = layout.c, layout.g, layout.ni
    total = 0
    for li, d in enumerate(cfg.feat_dims[:cfg.num_layers]):
        ship = spec.exchange_layer0 or li > 0
        if c > 1 and ship:
            total += ni * d * wire              # ppermute(grp<->repl)
        if g > 1 and ship:
            total += g * ni * d * wire          # all_gather(sub)
        if c > 1:
            total += ni * d * 4                 # psum(repl), f32
    return total


def step_bytes_total(layout: Spmm15dLayout, cfg, spec) -> int:
    """Modeled all-device wire bytes of one (refresh-equivalent) step —
    the 1.5D side of the head-to-head accounting in
    ``benchmarks/comm_volume.py``."""
    return layout.n_devices * forward_collective_bytes_per_device(
        layout, cfg, spec)


def vanilla_bytes_total(layout: Spmm15dLayout, cfg, spec) -> int:
    """The dense 1D baseline on the same block partitioning: every device
    all-gathers every block of H each layer (CAGNET 1D; what ``c == 1``
    costs).  The report's ``comm_reduction`` therefore isolates the
    replication benefit."""
    wire = 2 if spec.halo_dtype == "bf16" else 4
    dims = [d for li, d in enumerate(cfg.feat_dims[:cfg.num_layers])
            if spec.exchange_layer0 or li > 0]
    per_dev = sum(layout.pr * layout.ni * d * wire for d in dims)
    return layout.n_devices * per_dev


def make_spmm15d_mesh(c: int, g: int):
    """The ``(grp, sub, repl)`` = ``(c, g, c)`` device mesh (row-major —
    the order :class:`Spmm15dLayout`'s flat device index assumes)."""
    import jax
    return jax.make_mesh((c, g, c), AXES_15D)


@dataclasses.dataclass
class Spmm15dRuntime:
    """Jitted 1.5D runtime.  All step flavours are the same exact step
    (no staleness axis); the names exist so generic tooling can poke it
    like the halo runtimes."""
    cfg: object
    layout: Spmm15dLayout
    mesh: object
    spec: object
    step: Callable                  # (params, opt_state) -> (p, s, metrics)
    forward_fresh: Callable         # params -> [P, NI, out] logits
    evaluate: Callable              # (params, split) -> (loss, acc)
    lower_step: Callable            # (params, opt_state) -> Lowered
    lower_forward: Callable         # params -> Lowered
    step_bytes: int                 # modeled all-device bytes per step
    vanilla_bytes: int              # dense-1D baseline bytes per step
    forward_bytes_per_device: int   # modeled forward HLO collective bytes

    # step-flavour aliases: every 1.5D step is exact
    @property
    def step_refresh(self):
        return self.step

    @property
    def step_cached(self):
        return self.step

    @property
    def step_pipelined(self):
        return self.step


def make_spmm15d_runtime(cfg, layout: Spmm15dLayout, opt, spec,
                         mesh=None) -> Spmm15dRuntime:
    """Build the jitted 1.5D step over ``mesh`` (built from the layout's
    ``(c, g, c)`` shape when omitted).  Requires ``layout.n_devices``
    visible devices; params/opt state are replicated and donated
    (``spec.donate``) so steady-state steps update in place."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    if hasattr(jax, "shard_map"):
        shard_map = jax.shard_map
    else:                              # pre-jax.shard_map releases
        from jax.experimental.shard_map import shard_map

    from repro.models.gnn import accuracy, cross_entropy_loss
    from .capgnn_sim import halo_dtype_info

    if cfg.model not in Spmm15DStrategy.caps.models:
        raise StrategyCapabilityError(
            f"spmm_15d implements models {Spmm15DStrategy.caps.models}, "
            f"not {cfg.model!r}; use strategy='halo_1d' for the others")
    c, g, pr, ni = layout.c, layout.g, layout.pr, layout.ni
    p_dev = layout.n_devices
    if mesh is None:
        if len(jax.devices()) < p_dev:
            raise StrategyCapabilityError(
                f"spmm_15d with pr={pr}, c={c} needs {p_dev} devices "
                f"({len(jax.devices())} visible) — force host devices "
                "via XLA_FLAGS=--xla_force_host_platform_device_count")
        mesh = make_spmm15d_mesh(c, g)
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    if (tuple(mesh.axis_names) != AXES_15D
            or (shape["grp"], shape["sub"], shape["repl"]) != (c, g, c)):
        raise ValueError(f"spmm_15d needs a {AXES_15D} = ({c}, {g}, {c}) "
                         f"mesh, got axes {mesh.axis_names} of shape "
                         f"{mesh.devices.shape}")
    hdt, _ = halo_dtype_info(spec.halo_dtype)
    layers = cfg.num_layers
    sp = layout.sp
    rep = lambda x: np.repeat(np.asarray(x), c, axis=0)   # noqa: E731

    data = {"feats": rep(sp.feats),
            "labels": rep(sp.labels.astype(np.int32)),
            "train_mask": rep(sp.train_mask), "val_mask": rep(sp.val_mask),
            "test_mask": rep(sp.test_mask),
            "src": layout.chunk_src, "dst": layout.chunk_dst,
            "w": layout.chunk_w}
    if not spec.exchange_layer0:
        # pre-replicated inputs: each device ships with its group's
        # gathered layer-0 block instead of exchanging it per step
        f = sp.feats.shape[-1]
        hg0 = np.zeros((p_dev, g * ni, f), np.float32)
        for i in range(pr):
            for j in range(c):
                blocks = sp.feats[j * g:(j + 1) * g].reshape(g * ni, f)
                hg0[i * c + j] = blocks
        data["hg0"] = hg0
    data = jax.tree.map(jnp.asarray, data)

    total_train = float(np.maximum(sp.train_mask.sum(), 1.0))
    swap = [(a * c + j, j * c + a) for a in range(c) for j in range(c)]

    def _gather_group(h):
        """permute(grp<->repl) + all_gather(sub): [ni, d] -> [g*ni, d]
        holding every block of this device's source group."""
        hw = h.astype(hdt) if hdt is not None else h
        if c > 1:
            hw = jax.lax.ppermute(hw, ("grp", "repl"), swap)
        if g > 1:
            hw = jax.lax.all_gather(hw, "sub", tiled=True)
        return hw.astype(h.dtype)

    def _device_forward(params, dsh):
        src, dst, w = dsh["src"][0], dsh["dst"][0], dsh["w"][0]
        h = dsh["feats"][0]                                    # [ni, d]
        for li, lp in enumerate(params):
            if li == 0 and not spec.exchange_layer0:
                hg = dsh["hg0"][0]
            else:
                hg = _gather_group(h)
            msgs = hg[src] * w[:, None]
            agg = jax.ops.segment_sum(msgs, dst, num_segments=ni + 1)[:ni]
            if c > 1:
                agg = jax.lax.psum(agg, "repl")
            if cfg.model == "gcn":
                z = agg @ lp["w"] + lp["b"]
            else:                                              # gin
                z = (1.0 + lp["eps"]) * h + agg
                z = jax.nn.relu(z @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]
            h = z if li == layers - 1 else jax.nn.relu(z)
        return h

    def _device_loss(params, dsh):
        """This device's share of the (c-fold replicated) loss sum.  The
        psum stays OUTSIDE the differentiated function — see the module
        docstring for why the all-axis grad psum carries one uniform
        factor c that the step divides back out."""
        logits = _device_forward(params, dsh)
        labels = dsh["labels"][0]
        mask = dsh["train_mask"][0]
        logp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
        return jnp.sum(nll * mask) / total_train, logits

    def _device_step(params, opt_state, dsh):
        (loss, logits), grads = jax.value_and_grad(
            _device_loss, has_aux=True)(params, dsh)
        loss = jax.lax.psum(loss, AXES_15D) / c
        grads = jax.tree.map(lambda gr: jax.lax.psum(gr, AXES_15D) / c,
                             grads)
        new_params, new_state = opt.update(grads, opt_state, params)
        labels = dsh["labels"][0]
        mask = dsh["train_mask"][0]
        correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
        acc = jax.lax.psum(jnp.sum(correct * mask),
                           AXES_15D) / (c * total_train)
        return new_params, new_state, {"loss": loss, "acc": acc}

    names3 = AXES_15D
    sm_step = shard_map(_device_step, mesh=mesh,
                        in_specs=(P(), P(), P(names3)),
                        out_specs=(P(), P(), {"loss": P(), "acc": P()}),
                        check_rep=False)
    sm_fwd = shard_map(lambda params, dsh: _device_forward(params, dsh)[None],
                       mesh=mesh, in_specs=(P(), P(names3)),
                       out_specs=P(names3), check_rep=False)
    jit_step = jax.jit(lambda params, opt_state, dsh:
                       sm_step(params, opt_state, dsh),
                       donate_argnums=(0, 1) if spec.donate else ())
    jit_fwd = jax.jit(sm_fwd)

    def step(params, opt_state):
        return jit_step(params, opt_state, data)

    def forward_fresh(params):
        return jit_fwd(params, data)

    labels_flat = jnp.asarray(rep(sp.labels.astype(np.int32))).reshape(-1)
    masks_flat = {k: jnp.asarray(rep(m)).reshape(-1)
                  for k, m in (("train", sp.train_mask),
                               ("val", sp.val_mask),
                               ("test", sp.test_mask))}

    def evaluate(params, split: str = "val"):
        # rows are c-fold replicated; the masked means are unaffected
        flat = forward_fresh(params).reshape(-1, cfg.out_dim)
        m = masks_flat[split]
        return (float(cross_entropy_loss(flat, labels_flat, m)),
                float(accuracy(flat, labels_flat, m)))

    return Spmm15dRuntime(
        cfg=cfg, layout=layout, mesh=mesh, spec=spec, step=step,
        forward_fresh=forward_fresh, evaluate=evaluate,
        lower_step=lambda params, opt_state:
            jit_step.lower(params, opt_state, data),
        lower_forward=lambda params: jit_fwd.lower(params, data),
        step_bytes=step_bytes_total(layout, cfg, spec),
        vanilla_bytes=vanilla_bytes_total(layout, cfg, spec),
        forward_bytes_per_device=forward_collective_bytes_per_device(
            layout, cfg, spec))


def train_spmm15d(cfg, runtime: Spmm15dRuntime, opt, spec, epochs: int,
                  eval_every: int = 0, seed: int = 0, params0=None,
                  opt_state0=None):
    """The 1.5D training loop: every step is an exact refresh-equivalent
    step; byte accounting is the modeled figure (== HLO-measured, gated
    by the comm_volume suite).  Returns the same
    :class:`~repro.dist.capgnn_sim.TrainReport` shape as ``train_capgnn``
    (``comm_bytes_vanilla`` is the dense-1D baseline on the same
    blocks)."""
    import jax
    from repro.models.gnn import init_gnn
    from .capgnn_sim import TrainReport

    params = params0 if params0 is not None else init_gnn(
        jax.random.PRNGKey(seed), cfg)
    opt_state = opt_state0 if opt_state0 is not None else opt.init(params)
    losses: list[float] = []
    val_acc: list[float] = []
    compile_s = 0.0
    t0 = time.perf_counter()
    for e in range(epochs):
        params, opt_state, m = runtime.step(params, opt_state)
        losses.append(float(m["loss"]))
        if e == 0:
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
        if eval_every and (e + 1) % eval_every == 0:
            val_acc.append(runtime.evaluate(params, "val")[1])
    wall = time.perf_counter() - t0
    comm = runtime.step_bytes * epochs
    vanilla = runtime.vanilla_bytes * epochs
    report = TrainReport(
        losses=losses, val_acc=val_acc, comm_bytes=comm,
        comm_bytes_vanilla=vanilla,
        comm_reduction=1.0 - comm / max(vanilla, 1),
        refresh_steps=epochs, cached_steps=0, wall_time_s=wall,
        final_opt_state=opt_state, compile_s=compile_s,
        spec=spec.to_dict() if spec is not None else None)
    return params, report


class Spmm15DStrategy:
    """Registry entry for the 1.5D replicated-row SpMM model."""
    name = "spmm_15d"
    caps = StrategyCaps(jaca_tiers=False, pipeline=False,
                        host_features=False, adaptive_cache=False,
                        fault_guard=False, sim_runtime=False,
                        transports=("mesh_collectives",),
                        backends=("edges",),
                        models=("gcn", "gin"),
                        replicated=True)

    def validate_spec(self, spec) -> None:
        def deny(cond: bool, what: str):
            if cond:
                raise StrategyCapabilityError(
                    f"spmm_15d does not support {what} — that is halo_1d "
                    "machinery (see the strategy capability matrix in the "
                    "README); every spmm_15d step is refresh-equivalent "
                    "and exact")
        deny(spec.features != "device", f"features={spec.features!r}")
        deny(spec.pipeline, "pipeline=True (overlapped refresh)")
        deny(spec.cache_policy != "static",
             f"cache_policy={spec.cache_policy!r} (adaptive caching)")
        deny(spec.refresh_every != 1,
             f"refresh_every={spec.refresh_every} (bounded staleness)")
        deny(spec.backend != "edges", f"backend={spec.backend!r}")
        deny(bool(spec.faults) or spec.guard_every > 0 or spec.checksums
             or spec.fetch_retries is not None,
             "fault injection / guard defenses")
        deny(spec.pallas_pack, "pallas_pack (p2p peer packing)")

    def build_layout(self, ps, task, spec, **kw) -> Spmm15dLayout:
        return build_spmm15d_layout(ps, task, spec)

    def make_sim_runtime(self, cfg, layout, opt, spec, **kw):
        raise StrategyCapabilityError(
            "spmm_15d has no single-device sim runtime; parity checks "
            "run against the halo_1d sim oracle at refresh_every=1 "
            "(see tests/spmm15d_parity_script.py)")

    def make_spmd_runtime(self, cfg, layout, opt, spec, mesh=None, **kw):
        return make_spmm15d_runtime(cfg, layout, opt, spec, mesh=mesh)

    def train(self, cfg, runtime, layout, opt, spec, epochs, **kw):
        return train_spmm15d(cfg, runtime, opt, spec, epochs, **kw)

    def step_bytes(self, layout, cfg, spec) -> int:
        return step_bytes_total(layout, cfg, spec)

    def forward_collective_bytes(self, layout, cfg, spec,
                                 mesh_size=None) -> int:
        return forward_collective_bytes_per_device(layout, cfg, spec)


SPMM_15D = Spmm15DStrategy()
