"""Compile a JACA :class:`~repro.core.jaca.CachePlan` into static exchange
index sets, and stack per-partition task data into the padded ``[P, ...]``
layout the partition-parallel runtimes consume.

The exchange plan turns the plan's three halo tiers into gather/scatter
programs that are pure index arithmetic — no dynamic shapes, so the same
arrays drive both the single-device stacked oracle (`capgnn_sim`, a vmap
over the partition axis) and the collectives runtime (`capgnn_spmd`, a
`shard_map` over a device mesh):

- **uncached** tier: exchanged every step (the only per-step traffic on a
  cached step);
- **local** tier: each worker's HBM-resident cache rows, refreshed every
  ``refresh_every`` steps;
- **global** tier: the shared (CPU in the paper) cache — one buffer row per
  *unique* vertex, so a vertex consumed by k workers moves once per refresh
  instead of k times.  This dedup is where the global tier's savings come
  from (paper §4.2).

Transport layouts: every tier carries **two** send layouts compiled from
the same index sets —

- a *broadcast* layout (``send_row``): each owner packs the rows any
  consumer needs into one deduplicated dense buffer; consumers address
  rows by ``(src_part, src_slot)``.  The SPMD runtime's
  ``transport="allgather"`` ships this buffer to every device with a
  single ``all_gather`` (wire volume ~P x the paper's point-to-point
  model — replicas land on devices that never read them);
- a *per-peer packed* layout (``peer_send_row``): for each (owner, peer)
  pair, exactly the rows that peer consumes, padded to the fleet-wide
  maximum peer block.  ``transport="p2p"`` ships block (i -> j) directly
  with ``ppermute`` rotations, so each row crosses the wire once per
  consumer — exactly the row counts :meth:`ExchangePlan.bytes_per_step`
  and :func:`repro.core.jaca.comm_bytes_per_step` account for.

The global tier stays a deduplicated broadcast in both transports (it
emulates the paper's CPU-shared cache: each unique row is *originated*
once by its owner and circulated on the ring).

**Slot stability** (online cache adaptation): by default every tier array
is padded to the *current plan's* per-partition maxima, so re-ranking the
tiers produces arrays of different shapes and the jitted runtimes would
retrace.  Passing ``pad_to=exchange_capacity(ps, capacity)`` instead pads
every tier to a *capacity* width that upper-bounds ANY plan the
partitioning + cache capacity admits — tier membership then lives purely
in the index data + valid masks, and a re-ranked plan (same ``ps``, same
``CacheCapacity``) drops into an already-compiled step function without
retracing.  That is the contract the adaptive runtimes
(``SimRuntime.set_plan`` / ``step_transition``) rely on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.jaca import CachePlan
from repro.data.gnn_data import FullBatchTask
from repro.graph.partition import PartitionSet

__all__ = ["ExchangeTier", "GlobalTier", "HostTier", "ExchangePlan",
           "StackedParts", "StackedEllPack", "ExchangeCapacity",
           "exchange_capacity", "build_exchange_plan", "stack_partitions"]


@dataclasses.dataclass(frozen=True)
class ExchangeCapacity:
    """Fixed per-tier padded widths that upper-bound any cache plan over a
    given (partitioning, CacheCapacity) pair.

    Padding a compiled :class:`ExchangePlan` to these widths makes its
    array *shapes* a function of the capacities only — tier membership
    becomes data (indices + valid masks), so online re-planning never
    changes shapes and never retraces a jitted step.

    The scalar widths are the fleet maxima (rectangular arrays force a
    single shape); the ``*_w`` vectors record each worker's *tight* bound,
    so uneven (resource-aware) partitions keep exact per-worker accounting
    — the gap between ``P * scalar`` and ``sum(vector)`` is the padded-row
    waste the static shapes carry (see :meth:`padding_waste`).
    """
    un_recv: int     # uncached recv rows per consumer (<= its halo size)
    loc_recv: int    # local-tier recv rows per consumer (<= min(c_gpu, halo))
    glob_read: int   # global-tier reads per consumer (<= min(halo, c_cpu))
    send: int        # dedup send rows per owner, uncached/local tiers
    glob_send: int   # dedup send rows per owner into the global buffer
    peer: int        # per-(owner, peer) packed block width
    glob_buf: int    # unique rows resident in the global buffer (<= c_cpu)
    # per-worker tight widths (accounting; shapes always use the scalars)
    un_recv_w: np.ndarray | None = None    # [P]
    loc_recv_w: np.ndarray | None = None   # [P]
    glob_read_w: np.ndarray | None = None  # [P]
    send_w: np.ndarray | None = None       # [P]

    def __post_init__(self):
        # fleet-uniform fallback: every worker bounded by the scalar width
        def default(field, scalar):
            if getattr(self, field) is None:
                object.__setattr__(self, field,
                                   np.full(1, scalar, np.int64))
        default("un_recv_w", self.un_recv)
        default("loc_recv_w", self.loc_recv)
        default("glob_read_w", self.glob_read)
        default("send_w", self.send)

    def padding_waste(self) -> dict:
        """Padded-minus-valid row counts of the slot-stable layout, per
        tier, plus the aggregate waste fraction over all recv/send slots."""
        p = int(np.asarray(self.un_recv_w).shape[0])
        out = {}
        valid = padded = 0
        for field, scalar in (("un_recv", self.un_recv),
                              ("loc_recv", self.loc_recv),
                              ("glob_read", self.glob_read),
                              ("send", self.send)):
            v = int(np.asarray(getattr(self, field + "_w")).sum())
            tot = p * int(scalar)
            out[f"{field}_padded_rows"] = tot - v
            valid += v
            padded += tot
        out["waste_frac"] = float((padded - valid) / max(padded, 1))
        return out


def exchange_capacity(ps: PartitionSet, capacity) -> ExchangeCapacity:
    """Worst-case tier widths over ANY plan ``build_cache_plan``-shaped
    tiering can produce for ``ps`` under ``capacity``
    (:class:`repro.core.jaca.CacheCapacity`).

    - a consumer's local tier holds at most ``min(c_gpu, n_halo)`` rows,
      its global tier at most ``min(n_halo, c_cpu)``, its uncached tier at
      most ``n_halo`` (empty caches);
    - an owner's deduplicated send buffer holds at most the number of its
      inner vertices that appear in *any* partition's halo;
    - block (owner -> peer) holds at most ``|halo(peer) ∩ inner(owner)|``
      rows — a plan property of the partitioning, not of the tiering.
    """
    p = ps.num_parts
    h_sizes = np.array([pt.n_halo for pt in ps.parts], np.int64)
    union = ps.halo_union()
    owner = ps.assign
    exportable = np.bincount(owner[union], minlength=p).astype(np.int64) \
        if union.size else np.zeros(p, np.int64)
    c_cpu = int(min(capacity.c_cpu, union.size))
    peer = 0
    for pt in ps.parts:
        if pt.n_halo:
            peer = max(peer, int(np.bincount(owner[pt.halo_nodes],
                                             minlength=p).max()))
    un_recv_w = h_sizes
    loc_recv_w = np.minimum(np.asarray(capacity.c_gpu, np.int64)[:p],
                            h_sizes)
    glob_read_w = np.minimum(h_sizes, c_cpu)
    return ExchangeCapacity(
        un_recv=int(un_recv_w.max(initial=0)),
        loc_recv=int(loc_recv_w.max(initial=0)),
        glob_read=int(glob_read_w.max(initial=0)),
        send=int(exportable.max(initial=0)),
        glob_send=int(min(int(exportable.max(initial=0)), c_cpu)),
        peer=peer,
        glob_buf=c_cpu,
        un_recv_w=un_recv_w, loc_recv_w=loc_recv_w,
        glob_read_w=glob_read_w, send_w=exportable)


@dataclasses.dataclass(frozen=True)
class ExchangeTier:
    """One tier's gather/scatter program (uncached or local).

    All arrays are padded to the per-partition maximum; ``*_valid`` masks
    mark real entries.  ``send_row`` holds *deduplicated* inner rows per
    owner (a row consumed by several partitions occupies one send slot) —
    the broadcast/all-gather layout.  ``peer_send_row`` holds the same
    rows re-packed per destination (a row consumed by k peers occupies
    one slot in each of the k peer blocks) — the point-to-point layout;
    consumers address block rows by ``(src_part, peer_slot)``.
    """
    name: str
    send_row: np.ndarray        # [P, S] inner row each owner contributes
    send_valid: np.ndarray      # [P, S] bool
    recv_src_part: np.ndarray   # [P, R] owning partition per received row
    recv_src_slot: np.ndarray   # [P, R] slot in the owner's send buffer
    recv_halo_pos: np.ndarray   # [P, R] halo position to scatter into
    recv_valid: np.ndarray      # [P, R] bool
    peer_send_row: np.ndarray   # [P, P, B] inner rows owner i ships to peer j
    peer_send_valid: np.ndarray  # [P, P, B] bool
    recv_peer_slot: np.ndarray  # [P, R] slot in the (owner -> me) peer block

    @property
    def n_rows(self) -> int:
        """Total un-padded received rows (one per (vertex, consumer))."""
        return int(self.recv_valid.sum())

    @property
    def n_send_rows(self) -> int:
        """Total un-padded send rows (deduplicated per owner)."""
        return int(self.send_valid.sum())

    @property
    def n_peer_rows(self) -> int:
        """Total un-padded rows across all per-peer blocks.  Equals
        ``n_rows`` — each (vertex, consumer) pair occupies exactly one
        slot of exactly one peer block (asserted by the tier-1 suite)."""
        return int(self.peer_send_valid.sum())

    @property
    def peer_block(self) -> int:
        """Padded width of one (owner, peer) block."""
        return int(self.peer_send_row.shape[2])


@dataclasses.dataclass(frozen=True)
class GlobalTier:
    """The shared global cache: one buffer row per unique consumed vertex.

    Under a capacity-padded plan the buffer itself is padded too:
    ``buf_valid`` marks the real rows (always the leading slots — buffer
    rows are sorted by gid), so ``buf_size`` (array shape) is
    plan-invariant while ``n_unique`` (accounting) tracks the membership.
    """
    send_row: np.ndarray       # [P, S] inner rows owners contribute
    send_valid: np.ndarray     # [P, S] bool
    src_part: np.ndarray       # [G] owner partition per buffer row
    src_slot: np.ndarray       # [G] slot in owner's send buffer
    read_pos: np.ndarray       # [P, RG] halo positions served from the buffer
    read_buf_idx: np.ndarray   # [P, RG] buffer row per read
    read_valid: np.ndarray     # [P, RG] bool
    buf_valid: np.ndarray | None = None   # [G] bool (None => all real)

    def __post_init__(self):
        if self.buf_valid is None:
            object.__setattr__(self, "buf_valid",
                               np.ones(self.src_part.shape[0], bool))

    @property
    def n_unique(self) -> int:
        """Unique vertices resident in (and read from) the global buffer."""
        return int(self.buf_valid.sum())

    @property
    def buf_size(self) -> int:
        """Padded buffer row count (the runtime cache allocation)."""
        return int(self.src_part.shape[0])


@dataclasses.dataclass(frozen=True)
class HostTier:
    """The out-of-core layer-0 fetch program of the ``features="host"``
    runtimes: per worker, the halo positions whose *input features* are
    fetched from the host store every step instead of living stacked on
    device.

    Membership = the uncached tier ∪ the global-tier reads (the rows not
    held in the worker's device-resident local cache; the local tier's
    layer-0 rows stay device-cached — ``cal_capacity`` already charges
    every cached vertex for the input dim).  Same valid-mask/padding
    contract as the wire tiers: under a capacity-padded plan the width is
    ``un_recv + glob_read``, so re-plans swap membership as data without
    changing shapes.
    """
    feat_pos: np.ndarray     # [P, W] halo positions staged from host
    feat_valid: np.ndarray   # [P, W] bool

    @property
    def n_fetch_rows(self) -> int:
        """Rows staged host→device per step (one per (vertex, consumer) —
        the PCIe fetch is per worker, like the uncached wire tier)."""
        return int(self.feat_valid.sum())

    @property
    def width(self) -> int:
        return int(self.feat_pos.shape[1])


@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """Compiled communication program for one CachePlan."""
    num_parts: int
    uncached: ExchangeTier
    local: ExchangeTier
    glob: GlobalTier
    refresh_every: int
    total_halo: int
    host: HostTier | None = None   # layer-0 out-of-core fetch program

    def bytes_per_step(self, feat_dim: int, refresh: bool,
                       dtype_bytes: int = 4) -> int:
        """Bytes of one layer exchange of width ``feat_dim`` under the
        paper's point-to-point transport model: one row per (vertex,
        consumer) for the uncached/local tiers, one row per unique vertex
        for the global tier.  The plan's index sets count these rows
        exactly; matches :func:`repro.core.jaca.comm_bytes_per_step`
        (asserted by the tier-1 suite).  The ``capgnn_spmd`` runtime's
        ``transport="p2p"`` ships exactly these rows (per-peer packed
        ``ppermute`` blocks — each tier row originates once per consumer,
        each global row once total), so these figures ARE its wire
        accounting; ``transport="allgather"`` replicates every send
        buffer to all P devices and moves ~P x more.  ``dtype_bytes``
        must be the actual halo payload width (4 for f32, 2 for the
        ``halo_dtype="bf16"`` compressed transport).
        """
        row = feat_dim * dtype_bytes
        n = self.uncached.n_rows
        if refresh:
            n += self.local.n_rows + self.glob.n_unique
        return n * row

    def transport_rows(self, transport: str, refresh: bool,
                       padded: bool = False) -> dict:
        """Rows crossing the wire in one layer exchange under a transport.

        ``padded=False`` counts real (valid) rows *originated* into the
        transport — for ``"p2p"`` this equals the paper accounting of
        :meth:`bytes_per_step` exactly; for ``"allgather"`` every owner's
        send buffer lands on all P devices, hence the ~P x blow-up.
        ``padded=True`` additionally counts the static-shape padding the
        collectives actually carry (what HLO wire counters see).
        """
        if transport not in ("p2p", "allgather"):
            raise ValueError(f"unknown transport {transport!r}; "
                             "expected 'p2p' or 'allgather'")
        p = self.num_parts

        def tier_rows(t: ExchangeTier) -> int:
            if transport == "p2p":
                # one ppermute per (owner, peer != owner) block
                return (p * (p - 1) * t.peer_block if padded
                        else t.n_peer_rows)
            # all_gather: every owner's padded buffer to all P devices
            width = t.send_row.shape[1]
            return p * p * width if padded else p * t.n_send_rows

        def glob_rows() -> int:
            if transport == "p2p":
                # ring broadcast: each unique row originates once, then
                # circulates; padding rides every one of the P-1 rotations
                width = self.glob.send_row.shape[1]
                return p * (p - 1) * width if padded else self.glob.n_unique
            width = self.glob.send_row.shape[1]
            return (p * p * width if padded
                    else p * int(self.glob.send_valid.sum()))

        out = {"uncached": tier_rows(self.uncached)}
        out["local"] = tier_rows(self.local) if refresh else 0
        out["global"] = glob_rows() if refresh else 0
        out["total"] = out["uncached"] + out["local"] + out["global"]
        return out

    def host_fetch_rows(self, consume_stale: bool, stale_layers: int) -> dict:
        """Rows a ``features="host"`` step stages host→device (PCIe):
        the layer-0 host tier every step, plus — on stale-consuming
        (cached/pipelined) steps — each exchange layer's deduplicated
        global buffer.  Exact counts; the staged buffers' valid rows and
        the host store's accounted fetches must equal these (asserted by
        the out-of-core harness)."""
        if self.host is None:
            raise ValueError("plan has no host tier (built by an older "
                             "build_exchange_plan?)")
        l0 = self.host.n_fetch_rows
        gl = self.glob.n_unique * max(0, stale_layers) if consume_stale else 0
        return {"l0": l0, "global": gl, "total": l0 + gl}

    def host_bytes_per_step(self, feat_dim: int, dims,
                            consume_stale: bool,
                            dtype_bytes: int = 4) -> int:
        """Host→device bytes of one ``features="host"`` step:
        ``feat_dim``-wide layer-0 rows every step plus the staged global
        buffers (``dims`` = the stale exchange-layer widths) on
        stale-consuming steps, at the staged payload width
        (``dtype_bytes``: 2 under ``halo_dtype="bf16"``)."""
        if self.host is None:
            raise ValueError("plan has no host tier")
        n = self.host.n_fetch_rows * feat_dim
        if consume_stale:
            n += sum(self.glob.n_unique * int(d) for d in dims)
        return n * dtype_bytes

    def host_writeback_bytes(self, dims) -> int:
        """Device→host bytes of one emit (refresh/pipelined/transition)
        step: each exchange layer's freshly built global buffer is written
        back dequantised (f32), matching the device-mode cache content."""
        return sum(self.glob.n_unique * int(d) * 4 for d in dims)


def _pad2(rows: list[np.ndarray], fill: int, dtype=np.int32,
          width: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Stack ragged int rows into [P, width] + validity mask (``width``
    defaults to the ragged maximum; an explicit capacity must cover it)."""
    p = len(rows)
    natural = max((r.shape[0] for r in rows), default=0)
    if width is None:
        width = natural
    elif width < natural:
        raise ValueError(f"pad width {width} < ragged maximum {natural}")
    out = np.full((p, width), fill, dtype=dtype)
    valid = np.zeros((p, width), dtype=bool)
    for i, r in enumerate(rows):
        out[i, : r.shape[0]] = r
        valid[i, : r.shape[0]] = True
    return out, valid


def _owner_slots(op_all: np.ndarray, orow_all: np.ndarray, num_parts: int
                 ) -> tuple[list[np.ndarray], np.ndarray]:
    """Deduplicated per-owner send-slot allocation, vectorized.

    For ``(owner, row)`` request pairs, returns the unique rows each owner
    must send (sorted by row) and, per input pair, the slot of its row in
    the owner's send buffer.  O(N log N) in numpy — plan compilation stays
    cheap at million-halo scale.
    """
    if op_all.size == 0:
        return ([np.zeros(0, np.int64) for _ in range(num_parts)],
                np.zeros(0, np.int64))
    base = int(orow_all.max()) + 1
    key = op_all.astype(np.int64) * base + orow_all.astype(np.int64)
    uniq_key, inverse = np.unique(key, return_inverse=True)
    u_op = uniq_key // base
    u_row = uniq_key % base
    first = np.searchsorted(u_op, np.arange(num_parts))
    slot_of_uniq = np.arange(uniq_key.size) - first[u_op]
    send_rows = [u_row[u_op == q] for q in range(num_parts)]
    return send_rows, slot_of_uniq[inverse]


def _peer_blocks(gids_per_part: list[np.ndarray], owner_part: np.ndarray,
                 owner_row: np.ndarray, num_parts: int,
                 width: int | None = None
                 ) -> tuple[np.ndarray, np.ndarray, list[np.ndarray]]:
    """Per-destination packed send blocks, vectorized.

    For each (owner i, consumer j) pair, the inner rows i must ship to j
    (sorted by row), padded to the fleet-wide max block; plus, per
    consumer, the slot of each of its tier gids inside its (owner -> me)
    block.  A gid consumed by k partitions occupies one slot in each of
    its k destination blocks — no cross-peer dedup, that is the
    point-to-point transport's one-row-per-(vertex, consumer) contract.
    """
    p = num_parts
    counts = [g.size for g in gids_per_part]
    total = sum(counts)
    if total == 0:
        w0 = width or 0
        return (np.zeros((p, p, w0), np.int32), np.zeros((p, p, w0), bool),
                [np.zeros(0, np.int64) for _ in range(p)])
    gids_all = np.concatenate(gids_per_part)
    cons_all = np.repeat(np.arange(p), counts)
    op_all = owner_part[gids_all]
    orow_all = owner_row[gids_all]
    base = int(orow_all.max()) + 1
    pair = op_all * p + cons_all                     # block id in [0, p*p)
    order = np.argsort(pair * base + orow_all, kind="stable")
    pair_s = pair[order]
    first = np.searchsorted(pair_s, np.arange(p * p))
    slot_s = np.arange(total) - first[pair_s]        # slot within block
    slot = np.empty(total, np.int64)
    slot[order] = slot_s
    natural = int(np.bincount(pair, minlength=p * p).max())
    if width is None:
        width = natural
    elif width < natural:
        raise ValueError(f"peer pad width {width} < block maximum {natural}")
    peer_row = np.zeros((p * p, width), np.int32)
    peer_valid = np.zeros((p * p, width), dtype=bool)
    peer_row[pair_s, slot_s] = orow_all[order]
    peer_valid[pair_s, slot_s] = True
    offsets = np.cumsum([0] + counts)
    slots_per_part = [slot[offsets[i]: offsets[i + 1]] for i in range(p)]
    return (peer_row.reshape(p, p, width), peer_valid.reshape(p, p, width),
            slots_per_part)


def build_exchange_plan(ps: PartitionSet, plan: CachePlan,
                        pad_to: ExchangeCapacity | None = None
                        ) -> ExchangePlan:
    """Compile ``plan``'s tiering into static gather/scatter index sets.

    ``pad_to`` (from :func:`exchange_capacity`) pads every tier array to
    capacity widths instead of this plan's maxima — any two plans compiled
    with the same ``pad_to`` have byte-identical shapes (the slot-stable
    layout online re-planning needs to avoid retracing jitted steps).
    """
    p = ps.num_parts
    n = ps.graph.num_nodes
    owner_row = np.full(n, -1, np.int64)
    for part in ps.parts:
        owner_row[part.inner_nodes] = np.arange(part.n_inner)
    owner_part = ps.assign.astype(np.int64)

    def build_tier(name: str, gids_per_part: list[np.ndarray],
                   pos_per_part: list[np.ndarray],
                   recv_w: int | None, send_w: int | None,
                   peer_w: int | None) -> ExchangeTier:
        counts = [g.size for g in gids_per_part]
        gids_all = (np.concatenate(gids_per_part) if sum(counts)
                    else np.zeros(0, np.int64))
        send_rows, slots_all = _owner_slots(owner_part[gids_all],
                                            owner_row[gids_all], p)
        offsets = np.cumsum([0] + counts)
        src_parts = [owner_part[g].astype(np.int32) for g in gids_per_part]
        src_slots = [slots_all[offsets[i]: offsets[i + 1]].astype(np.int32)
                     for i in range(p)]
        send_row, send_valid = _pad2([r.astype(np.int32)
                                      for r in send_rows], fill=0,
                                     width=send_w)
        recv_src_part, recv_valid = _pad2(src_parts, fill=0, width=recv_w)
        recv_src_slot, _ = _pad2(src_slots, fill=0, width=recv_w)
        recv_halo_pos, _ = _pad2([np.asarray(q, np.int32)
                                  for q in pos_per_part], fill=0,
                                 width=recv_w)
        peer_row, peer_valid, peer_slots = _peer_blocks(
            gids_per_part, owner_part, owner_row, p, width=peer_w)
        recv_peer_slot, _ = _pad2([s.astype(np.int32)
                                   for s in peer_slots], fill=0,
                                  width=recv_w)
        return ExchangeTier(name=name, send_row=send_row,
                            send_valid=send_valid,
                            recv_src_part=recv_src_part,
                            recv_src_slot=recv_src_slot,
                            recv_halo_pos=recv_halo_pos,
                            recv_valid=recv_valid,
                            peer_send_row=peer_row,
                            peer_send_valid=peer_valid,
                            recv_peer_slot=recv_peer_slot)

    pt = pad_to
    uncached = build_tier("uncached",
                          [w.uncached_gids for w in plan.workers],
                          [w.uncached_pos for w in plan.workers],
                          recv_w=pt.un_recv if pt else None,
                          send_w=pt.send if pt else None,
                          peer_w=pt.peer if pt else None)
    local = build_tier("local",
                       [w.local_gids for w in plan.workers],
                       [w.local_pos for w in plan.workers],
                       recv_w=pt.loc_recv if pt else None,
                       send_w=pt.send if pt else None,
                       peer_w=pt.peer if pt else None)

    # Global tier: unique over the gids any worker actually reads (resident
    # rows no one consumes are never refreshed, so they cost nothing).
    read_gids = [w.global_gids for w in plan.workers]
    if any(g.size for g in read_gids):
        used = np.unique(np.concatenate([g for g in read_gids if g.size]))
    else:
        used = np.zeros(0, np.int64)
    g_send_rows, g_slots = _owner_slots(owner_part[used], owner_row[used], p)
    g_src_part = owner_part[used].astype(np.int32)
    g_src_slot = g_slots.astype(np.int32)
    g_send_row, g_send_valid = _pad2([r.astype(np.int32)
                                      for r in g_send_rows], fill=0,
                                     width=pt.glob_send if pt else None)
    # pad the buffer itself: real rows occupy the leading slots
    buf = pt.glob_buf if pt else used.size
    if buf < used.size:
        raise ValueError(f"global buffer capacity {buf} < plan's "
                         f"{used.size} unique consumed vertices")
    buf_valid = np.zeros(buf, bool)
    buf_valid[: used.size] = True
    g_src_part = np.concatenate(
        [g_src_part, np.zeros(buf - used.size, np.int32)])
    g_src_slot = np.concatenate(
        [g_src_slot, np.zeros(buf - used.size, np.int32)])
    # `used` is sorted, so buffer indices come straight from searchsorted
    read_buf_idx, read_valid = _pad2(
        [np.searchsorted(used, w.global_gids).astype(np.int32)
         for w in plan.workers], fill=0,
        width=pt.glob_read if pt else None)
    read_pos, _ = _pad2([w.global_pos.astype(np.int32)
                         for w in plan.workers], fill=0,
                        width=pt.glob_read if pt else None)
    glob = GlobalTier(send_row=g_send_row, send_valid=g_send_valid,
                      src_part=g_src_part, src_slot=g_src_slot,
                      read_pos=read_pos, read_buf_idx=read_buf_idx,
                      read_valid=read_valid, buf_valid=buf_valid)

    # Host tier (out-of-core layer 0): every halo position NOT in the
    # worker's device-resident local cache — uncached ∪ global reads —
    # fetched from the host feature store each step.  Capacity width is
    # the sum of the two member tiers' widths, so it is slot-stable
    # whenever they are.
    host_pos = [np.concatenate([np.asarray(w.uncached_pos, np.int64),
                                np.asarray(w.global_pos, np.int64)])
                for w in plan.workers]
    host_w = (pt.un_recv + pt.glob_read) if pt else None
    feat_pos, feat_valid = _pad2([q.astype(np.int32) for q in host_pos],
                                 fill=0, width=host_w)
    host = HostTier(feat_pos=feat_pos, feat_valid=feat_valid)

    return ExchangePlan(num_parts=p, uncached=uncached, local=local,
                        glob=glob, refresh_every=plan.refresh_every,
                        total_halo=ps.total_halo(), host=host)


# ---------------------------------------------------------------------------
# Stacked partition layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StackedEllPack:
    """Stacked blocked-ELL (+ optional COO tail) aggregation pack.

    Built from the same remapped edge lists as ``StackedParts.e_*``, so
    ``ell_spmm(cols[i], vals[i], concat([h_inner, h_halo]))`` equals the
    segment-sum over that partition's edges bit-for-bit (up to summation
    order).  ELL padding slots carry col 0 / val 0; the per-partition packs
    are padded to the fleet-wide ``max_deg`` and tail width.  For the pure
    ``"ell"`` backend the tail arrays have zero width.
    """
    backend: str               # "ell" | "hybrid"
    cols: np.ndarray           # [P, NI, K] int32 in [0, NI+NH)
    vals: np.ndarray           # [P, NI, K] float32 (0 at padding)
    tail_src: np.ndarray       # [P, MT] int32 in [0, NI+NH)
    tail_dst: np.ndarray       # [P, MT] int32 in [0, NI] (NI = padding)
    tail_w: np.ndarray         # [P, MT] float32 (0 at padding)

    @property
    def max_deg(self) -> int:
        return int(self.cols.shape[2])

    @property
    def tail_width(self) -> int:
        return int(self.tail_src.shape[1])


@dataclasses.dataclass(frozen=True)
class StackedParts:
    """Padded ``[P, ...]`` stacking of every partition's task slice.

    Local edge src ids are remapped so halo position ``q`` becomes column
    ``n_inner_max + q`` — the runtimes concatenate ``[h_inner, h_halo]``
    along rows, so the remap must target the *padded* inner width.  Padding
    edges carry ``dst = n_inner_max`` (dropped by segment ops) and zero
    weight; padded label/mask rows are zeroed so they never touch the loss.

    ``ell`` optionally carries the stacked blocked-ELL/hybrid aggregation
    pack (``stack_partitions(..., backend="ell" | "hybrid")``) consumed by
    the Pallas SpMM backends of the runtimes; the edge-list arrays are
    always present (GAT and the reference backend need them).

    With resource-aware *uneven* partitions the per-part widths are
    ragged; ``inner_valid``/``halo_valid`` mark the real rows of each
    stacked slot (padding rows carry zero features/labels/masks and never
    touch loss or accuracy) and :meth:`padding_stats` quantifies the
    padded-row waste the rectangular layout carries.
    """
    num_parts: int
    n_inner_max: int
    n_halo_max: int
    n_inner: np.ndarray        # [P]
    n_halo: np.ndarray         # [P]
    feats: np.ndarray          # [P, NI, F] inner input features
    halo_feats: np.ndarray     # [P, NH, F] halo input features (static)
    labels: np.ndarray         # [P, NI] int32
    train_mask: np.ndarray     # [P, NI] float32
    val_mask: np.ndarray       # [P, NI] float32
    test_mask: np.ndarray      # [P, NI] float32
    e_src: np.ndarray          # [P, ME] int32 in [0, NI+NH)
    e_dst: np.ndarray          # [P, ME] int32 in [0, NI] (NI = padding)
    e_w: np.ndarray            # [P, ME] float32 (0 at padding)
    ell: StackedEllPack | None = None
    inner_valid: np.ndarray | None = None   # [P, NI] bool
    halo_valid: np.ndarray | None = None    # [P, NH] bool

    def __post_init__(self):
        if self.inner_valid is None:
            iv = (np.arange(self.n_inner_max)[None, :]
                  < np.asarray(self.n_inner)[:, None])
            object.__setattr__(self, "inner_valid", iv)
        if self.halo_valid is None:
            hv = (np.arange(self.n_halo_max)[None, :]
                  < np.asarray(self.n_halo)[:, None])
            object.__setattr__(self, "halo_valid", hv)

    @property
    def n_edges(self) -> np.ndarray:
        """Real (un-padded) edge count per part; padding slots carry
        ``dst == n_inner_max``."""
        return (self.e_dst < self.n_inner_max).sum(axis=1).astype(np.int64)

    def padding_stats(self) -> dict:
        """Valid vs padded slot counts of the rectangular stacked layout —
        the waste uneven partitioning is judged on in
        ``benchmarks/heterogeneous.py``."""
        p = self.num_parts
        rows = {
            "inner": (int(self.inner_valid.sum()), p * self.n_inner_max),
            "halo": (int(self.halo_valid.sum()), p * self.n_halo_max),
            "edges": (int(self.n_edges.sum()), p * int(self.e_src.shape[1])),
        }
        out = {}
        valid = total = 0
        for name, (v, t) in rows.items():
            out[f"{name}_valid_rows"] = v
            out[f"{name}_padded_rows"] = t - v
            valid += v
            total += t
        out["waste_frac"] = float((total - valid) / max(total, 1))
        return out


def _stack_ell(edge_lists: list[tuple[np.ndarray, np.ndarray, np.ndarray]],
               n_inner_max: int, backend: str, quantile: float
               ) -> StackedEllPack:
    """Pack every partition's (remapped) edges to ELL/hybrid and pad the
    packs to a common ``[P, NI, K]`` (+ ``[P, MT]`` tail) layout."""
    from repro.kernels.ops import ell_pack, ell_pack_hybrid

    packs = []
    for src, dst, w in edge_lists:
        if backend == "hybrid":
            packs.append(ell_pack_hybrid(src, dst, w, n_inner_max,
                                         quantile=quantile))
        else:
            c, v = ell_pack(src, dst, w, n_inner_max)
            empty = np.zeros(0, np.int32)
            packs.append((c, v, empty, empty.copy(),
                          np.zeros(0, np.float32)))

    p = len(packs)
    k = max(c.shape[1] for c, *_ in packs)
    mt = max(ts.shape[0] for _, _, ts, _, _ in packs)
    cols = np.zeros((p, n_inner_max, k), np.int32)
    vals = np.zeros((p, n_inner_max, k), np.float32)
    tail_src = np.zeros((p, mt), np.int32)
    tail_dst = np.full((p, mt), n_inner_max, np.int32)  # NI row => dropped
    tail_w = np.zeros((p, mt), np.float32)
    for i, (c, v, ts, td, tw) in enumerate(packs):
        cols[i, :, : c.shape[1]] = c
        vals[i, :, : v.shape[1]] = v
        tail_src[i, : ts.shape[0]] = ts
        tail_dst[i, : td.shape[0]] = td
        tail_w[i, : tw.shape[0]] = tw
    return StackedEllPack(backend=backend, cols=cols, vals=vals,
                          tail_src=tail_src, tail_dst=tail_dst, tail_w=tail_w)


def stack_partitions(ps: PartitionSet, task: FullBatchTask,
                     backend: str = "edges",
                     ell_quantile: float = 0.95,
                     pad_to: tuple[int, int] | None = None) -> StackedParts:
    """Stack per-partition task slices; ``backend="ell" | "hybrid"`` also
    builds the stacked Pallas aggregation pack (``StackedEllPack``) the
    runtimes' non-edge-list backends consume.

    ``pad_to=(ni, nh)`` overrides the inner/halo padding widths (must
    cover the ragged maxima) — two partitionings stacked to the same
    widths produce shape-identical layouts, the stacking analogue of the
    exchange plan's slot-stable capacity padding.
    """
    if backend not in ("edges", "ell", "hybrid"):
        raise ValueError(f"unknown stacking backend {backend!r}; "
                         "expected 'edges', 'ell' or 'hybrid'")
    p = ps.num_parts
    ni = max(1, max(pt.n_inner for pt in ps.parts))
    nh = max(1, max(pt.n_halo for pt in ps.parts))
    if pad_to is not None:
        if pad_to[0] < ni or pad_to[1] < nh:
            raise ValueError(f"pad_to {pad_to} < ragged maxima ({ni}, {nh})")
        ni, nh = int(pad_to[0]), int(pad_to[1])
    f = task.features.shape[1]

    feats = np.zeros((p, ni, f), np.float32)
    halo_feats = np.zeros((p, nh, f), np.float32)
    labels = np.zeros((p, ni), np.int32)
    masks = {k: np.zeros((p, ni), np.float32)
             for k in ("train", "val", "test")}

    edge_lists = []
    for i, pt in enumerate(ps.parts):
        feats[i, : pt.n_inner] = task.features[pt.inner_nodes]
        halo_feats[i, : pt.n_halo] = task.features[pt.halo_nodes]
        labels[i, : pt.n_inner] = task.labels[pt.inner_nodes]
        masks["train"][i, : pt.n_inner] = task.train_mask[pt.inner_nodes]
        masks["val"][i, : pt.n_inner] = task.val_mask[pt.inner_nodes]
        masks["test"][i, : pt.n_inner] = task.test_mask[pt.inner_nodes]
        src, dst = pt.local_graph.edges()
        keep = dst < pt.n_inner
        src, dst = src[keep], dst[keep]
        w = (pt.local_graph.edge_weight[keep]
             if pt.local_graph.edge_weight is not None
             else np.ones(src.shape[0], np.float32))
        src = np.where(src < pt.n_inner, src, ni + (src - pt.n_inner))
        edge_lists.append((src.astype(np.int32), dst.astype(np.int32),
                           w.astype(np.float32)))

    me = max(1, max(s.shape[0] for s, _, _ in edge_lists))
    e_src = np.zeros((p, me), np.int32)
    e_dst = np.full((p, me), ni, np.int32)   # NI row => dropped by segments
    e_w = np.zeros((p, me), np.float32)
    for i, (src, dst, w) in enumerate(edge_lists):
        m = src.shape[0]
        e_src[i, :m] = src
        e_dst[i, :m] = dst
        e_w[i, :m] = w

    ell = (_stack_ell(edge_lists, ni, backend, ell_quantile)
           if backend in ("ell", "hybrid") else None)

    return StackedParts(
        num_parts=p, n_inner_max=ni, n_halo_max=nh,
        n_inner=np.array([pt.n_inner for pt in ps.parts], np.int32),
        n_halo=np.array([pt.n_halo for pt in ps.parts], np.int32),
        feats=feats, halo_feats=halo_feats, labels=labels,
        train_mask=masks["train"], val_mask=masks["val"],
        test_mask=masks["test"], e_src=e_src, e_dst=e_dst, e_w=e_w,
        ell=ell)
