"""Hymba-1.5B — hybrid heads: parallel attention + Mamba in every block.
[arXiv:2411.13676]

head_dim = 64 (25 heads x 64 = 1600); sliding-window attention (the
published model mixes SWA + 3 global-attention layers; we use SWA
throughout — DESIGN.md notes the simplification); vocab 32001 padded to
32256 for 16-way sharding.
"""
import dataclasses
from repro.models.transformer.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", arch_type="hybrid",
    num_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, ssm_expand=2, ssm_conv=4,
    attn_window=1024, norm="rmsnorm", ffn_act="swiglu",
    source="arXiv:2411.13676",
)

REDUCED = dataclasses.replace(
    CONFIG, name="hymba-1.5b-reduced", num_layers=2, d_model=160, n_heads=5,
    n_kv_heads=1, head_dim=32, d_ff=320, ssm_state=8, attn_window=32,
    vocab_size=512)
