"""MusicGen-large — decoder-only LM over EnCodec tokens.  [arXiv:2306.05284]

Frontend carve-out: the EnCodec conv codec is a stub — input_specs()
provides token ids in the 2048-entry codec vocabulary (delay-pattern
interleave applied upstream).  LayerNorm+GELU per the original; RoPE
substitutes the learned positional embedding (TPU adaptation note in
DESIGN.md).
"""
import dataclasses
from repro.models.transformer.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", arch_type="audio",
    num_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    norm="layernorm", ffn_act="gelu", audio_frontend=True, remat=True,
    source="arXiv:2306.05284",
)

REDUCED = dataclasses.replace(
    CONFIG, name="musicgen-large-reduced", num_layers=2, d_model=256,
    n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=512, remat=False)
