"""Mixtral-8x7B — sparse MoE (8 experts, top-2) with sliding-window
attention.  [arXiv:2401.04088]"""
import dataclasses
from repro.models.transformer.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", arch_type="moe",
    num_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    n_experts=8, top_k=2, moe_d_ff=14336,
    attn_window=4096, rope_theta=1e6, norm="rmsnorm", ffn_act="swiglu",
    remat=True, source="arXiv:2401.04088",
)

REDUCED = dataclasses.replace(
    CONFIG, name="mixtral-8x7b-reduced", num_layers=2, d_model=256,
    n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512, moe_d_ff=512,
    n_experts=4, top_k=2, attn_window=64, vocab_size=512, remat=False)
