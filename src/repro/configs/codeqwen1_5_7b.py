"""CodeQwen1.5-7B — dense MHA (kv=32) decoder, Qwen1.5 arch (QKV bias).
[hf:Qwen/CodeQwen1.5-7B]"""
import dataclasses
from repro.models.transformer.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", arch_type="dense",
    num_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size=92416,
    qkv_bias=True, rope_theta=1e6, norm="rmsnorm", ffn_act="swiglu",
    remat=True, source="hf:Qwen/CodeQwen1.5-7B",
)

REDUCED = dataclasses.replace(
    CONFIG, name="codeqwen1.5-7b-reduced", num_layers=2, d_model=256,
    n_heads=4, n_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512,
    remat=False)
