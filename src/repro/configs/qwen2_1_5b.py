"""Qwen2-1.5B — dense GQA decoder with QKV bias.  [arXiv:2407.10671]"""
import dataclasses
from repro.models.transformer.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", arch_type="dense",
    num_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, rope_theta=1e6, norm="rmsnorm", ffn_act="swiglu",
    tie_embeddings=True, source="arXiv:2407.10671",
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen2-1.5b-reduced", num_layers=2, d_model=192, n_heads=3,
    n_kv_heads=1, head_dim=64, d_ff=384, vocab_size=512)
