"""Phi-3-vision-4.2B — phi3-mini decoder + CLIP frontend (stub).
[hf:microsoft/Phi-3-vision-128k-instruct]

Frontend carve-out: the CLIP ViT + projector is a stub — input_specs()
provides 256 pre-computed patch embeddings of width d_model, prepended to
the text sequence; loss is computed on text positions only.
"""
import dataclasses
from repro.models.transformer.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", arch_type="vlm",
    num_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    norm="rmsnorm", ffn_act="swiglu", vision_tokens=256, remat=True,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

REDUCED = dataclasses.replace(
    CONFIG, name="phi-3-vision-4.2b-reduced", num_layers=2, d_model=256,
    n_heads=4, n_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512,
    vision_tokens=16, remat=False)
