"""xLSTM-350M — sLSTM + mLSTM blocks (xLSTM[7:1]).  [arXiv:2405.04517]

d_ff=0: xLSTM blocks carry their own up/down projections (expand=2);
every 8th block is sLSTM (scalar memory, sequential), rest mLSTM (matrix
memory, chunkwise-parallel).
"""
import dataclasses
from repro.models.transformer.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", arch_type="ssm",
    num_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    ssm_expand=2, slstm_every=8, norm="layernorm",
    source="arXiv:2405.04517",
)

REDUCED = dataclasses.replace(
    CONFIG, name="xlstm-350m-reduced", num_layers=2, d_model=128, n_heads=2,
    n_kv_heads=2, vocab_size=512, slstm_every=2)
