"""Qwen3-14B — dense GQA decoder with qk-norm.  [hf:Qwen/Qwen3-8B]"""
import dataclasses
from repro.models.transformer.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", arch_type="dense",
    num_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, norm="rmsnorm", ffn_act="swiglu",
    remat=True, source="hf:Qwen/Qwen3-8B (14B sibling config)",
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen3-14b-reduced", num_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512, remat=False)
