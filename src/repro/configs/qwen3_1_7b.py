"""Qwen3-1.7B — dense GQA decoder with qk-norm.  [hf:Qwen/Qwen3-8B]"""
import dataclasses
from repro.models.transformer.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", arch_type="dense",
    num_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, norm="rmsnorm", ffn_act="swiglu",
    tie_embeddings=True, source="hf:Qwen/Qwen3-8B (1.7B sibling config)",
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen3-1.7b-reduced", num_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512)
