"""DeepSeek-V3-671B — MLA + fine-grained MoE (1 shared + 256 routed,
top-8).  [arXiv:2412.19437]

Brief's d_ff=2048 is the per-expert (routed) width; the 3 leading dense
layers use the report's 18432.  MTP (multi-token prediction) is a training
objective add-on and is not reproduced (DESIGN.md §Arch-applicability).
"""
import dataclasses
from repro.models.transformer.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", arch_type="moe",
    num_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab_size=129280,
    n_experts=256, top_k=8, n_shared_experts=1, moe_d_ff=2048,
    n_dense_layers=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    rope_theta=1e4, norm="rmsnorm", ffn_act="swiglu", remat=True,
    source="arXiv:2412.19437",
)

REDUCED = dataclasses.replace(
    CONFIG, name="deepseek-v3-671b-reduced", num_layers=2, d_model=256,
    n_heads=4, n_kv_heads=4, d_ff=512, moe_d_ff=128, n_experts=4, top_k=2,
    n_dense_layers=1, q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
    qk_rope_dim=16, v_head_dim=32, vocab_size=512, remat=False)
