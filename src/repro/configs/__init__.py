"""Architecture config registry + input-shape suite.

``get_config(name)`` returns the full published config;
``get_reduced(name)`` returns the family-preserving smoke variant
(<=2 layers, d_model<=512, <=4 experts) used by CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.transformer.config import ModelConfig

ARCH_IDS = [
    "qwen3_14b", "qwen2_1_5b", "xlstm_350m", "musicgen_large", "qwen3_1_7b",
    "phi3_vision_4_2b", "mixtral_8x7b", "deepseek_v3_671b", "hymba_1_5b",
    "codeqwen1_5_7b",
]

_ALIASES = {
    "qwen3-14b": "qwen3_14b", "qwen2-1.5b": "qwen2_1_5b",
    "xlstm-350m": "xlstm_350m", "musicgen-large": "musicgen_large",
    "qwen3-1.7b": "qwen3_1_7b", "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "mixtral-8x7b": "mixtral_8x7b", "deepseek-v3-671b": "deepseek_v3_671b",
    "hymba-1.5b": "hymba_1_5b", "codeqwen1.5-7b": "codeqwen1_5_7b",
}

# (seq_len, global_batch, kind)
INPUT_SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.REDUCED


def list_configs() -> list[str]:
    return list(ARCH_IDS)


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Sub-quadratic variant for long_500k: dense archs get a 4096 sliding
    window (`+swa`); SSM/hybrid archs are already sub-quadratic."""
    if cfg.arch_type in ("ssm", "hybrid") or cfg.attn_window:
        return cfg
    return dataclasses.replace(cfg, attn_window=4096,
                               name=cfg.name + "+swa")
