from .checkpoint import (CheckpointCorruptError, latest_step,
                         load_checkpoint, save_checkpoint,
                         verify_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "verify_checkpoint", "CheckpointCorruptError"]
