"""Sharding-aware pytree checkpointing (npz payload + json treedef).

Writes are atomic (tmp + rename).  Sharded arrays are gathered to host
before save; on restore the caller re-shards via its own NamedSharding (we
store only the logical arrays, which is the portable choice when restore
topology differs from save topology — e.g. single-pod -> multi-pod).

Integrity: the sidecar meta records a CRC32 of the npz payload bytes.
``load_checkpoint`` validates it (raising :class:`CheckpointCorruptError`
on mismatch / truncation), and ``latest_step`` skips corrupt or partial
checkpoints, falling back to the newest valid one — so a crash mid-write
or a damaged file degrades to "resume from the previous step" instead of
a mid-restore explosion.
"""
from __future__ import annotations

import json
import os
import re
import zlib

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "verify_checkpoint", "CheckpointCorruptError"]

_LEAF_KEY = "leaf_{:05d}"

# npz only understands built-in numpy dtypes; ml_dtypes leaves (bfloat16,
# fp8, ...) are stored as a same-width uint view + a dtype-name record.
_UINT_OF_WIDTH = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


class CheckpointCorruptError(RuntimeError):
    """Checkpoint payload failed integrity validation (bad checksum,
    truncated file, or missing/corrupt sidecar meta)."""


def _is_native_dtype(dt: np.dtype) -> bool:
    try:
        return np.dtype(dt.name) == dt
    except TypeError:
        return False


def _encode(leaf: np.ndarray) -> tuple[np.ndarray, str]:
    dt = leaf.dtype
    if _is_native_dtype(dt):
        return leaf, dt.name
    return leaf.view(_UINT_OF_WIDTH[dt.itemsize]), dt.name


def _decode(raw: np.ndarray, dtype_name: str) -> np.ndarray:
    if _is_native_dtype(raw.dtype) and raw.dtype.name == dtype_name:
        return raw
    import jax.numpy as jnp
    return raw.view(np.dtype(getattr(jnp, dtype_name)))


def _file_crc32(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        while chunk := f.read(1 << 20):
            crc = zlib.crc32(chunk, crc)
    return crc


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    encoded = [_encode(leaf) for leaf in host_leaves]
    payload = {_LEAF_KEY.format(i): raw for i, (raw, _) in enumerate(encoded)}
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
    crc = _file_crc32(tmp)
    nbytes = os.path.getsize(tmp)
    os.replace(tmp, path)
    meta = {"step": step, "num_leaves": len(host_leaves),
            "dtypes": [name for _, name in encoded],
            "treedef": str(treedef),
            "payload_crc32": crc, "payload_bytes": nbytes}
    meta_path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.json")
    with open(meta_path + ".tmp", "w") as f:
        json.dump(meta, f)
    os.replace(meta_path + ".tmp", meta_path)
    return path


def verify_checkpoint(ckpt_dir: str, step: int) -> dict:
    """Validate one checkpoint's payload against its sidecar meta; returns
    the meta dict on success, raises :class:`CheckpointCorruptError` on
    a missing file, truncation, or checksum mismatch.  Metas written
    before checksums existed (no ``payload_crc32`` key) pass unchecked —
    old checkpoints stay loadable."""
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    meta_path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.json")
    if not os.path.isfile(path):
        raise CheckpointCorruptError(f"missing payload: {path}")
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"missing/corrupt sidecar meta: {meta_path}: {e}") from e
    want_crc = meta.get("payload_crc32")
    if want_crc is None:
        return meta
    nbytes = os.path.getsize(path)
    if nbytes != meta.get("payload_bytes", nbytes):
        raise CheckpointCorruptError(
            f"{path}: truncated ({nbytes} bytes, "
            f"expected {meta['payload_bytes']})")
    got = _file_crc32(path)
    if got != want_crc:
        raise CheckpointCorruptError(
            f"{path}: checksum mismatch "
            f"(crc32 {got:#010x}, expected {want_crc:#010x})")
    return meta


def load_checkpoint(ckpt_dir: str, step: int, like):
    """Restore into the structure of ``like`` (validates leaf count/shapes
    and the payload checksum recorded at save time)."""
    meta = verify_checkpoint(ckpt_dir, step)
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    leaves, treedef = jax.tree.flatten(like)
    restored = [_decode(data[_LEAF_KEY.format(i)], meta["dtypes"][i])
                for i in range(len(leaves))]
    for i, (r, l) in enumerate(zip(restored, leaves)):
        if hasattr(l, "shape") and tuple(r.shape) != tuple(np.shape(l)):
            raise ValueError(f"leaf {i}: shape {r.shape} != expected {np.shape(l)}")
    return jax.tree.unflatten(treedef, restored)


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step with a *valid* checkpoint.  Corrupt or partial entries
    (truncated payload, bad checksum, missing meta) are skipped with a
    warning, falling back to the next-newest valid one — leftover
    ``.tmp`` files from a crashed save never match at all."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted((int(m.group(1)) for f in os.listdir(ckpt_dir)
                    if (m := re.match(r"ckpt_(\d+)\.npz$", f))),
                   reverse=True)
    for step in steps:
        try:
            verify_checkpoint(ckpt_dir, step)
        except CheckpointCorruptError as e:
            import warnings
            warnings.warn(f"skipping corrupt checkpoint at step {step}: {e}",
                          RuntimeWarning, stacklevel=2)
            continue
        return step
    return None
