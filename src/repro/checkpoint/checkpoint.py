"""Sharding-aware pytree checkpointing (npz payload + json treedef).

Writes are atomic (tmp + rename).  Sharded arrays are gathered to host
before save; on restore the caller re-shards via its own NamedSharding (we
store only the logical arrays, which is the portable choice when restore
topology differs from save topology — e.g. single-pod -> multi-pod).
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]

_LEAF_KEY = "leaf_{:05d}"

# npz only understands built-in numpy dtypes; ml_dtypes leaves (bfloat16,
# fp8, ...) are stored as a same-width uint view + a dtype-name record.
_UINT_OF_WIDTH = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _is_native_dtype(dt: np.dtype) -> bool:
    try:
        return np.dtype(dt.name) == dt
    except TypeError:
        return False


def _encode(leaf: np.ndarray) -> tuple[np.ndarray, str]:
    dt = leaf.dtype
    if _is_native_dtype(dt):
        return leaf, dt.name
    return leaf.view(_UINT_OF_WIDTH[dt.itemsize]), dt.name


def _decode(raw: np.ndarray, dtype_name: str) -> np.ndarray:
    if _is_native_dtype(raw.dtype) and raw.dtype.name == dtype_name:
        return raw
    import jax.numpy as jnp
    return raw.view(np.dtype(getattr(jnp, dtype_name)))


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    encoded = [_encode(leaf) for leaf in host_leaves]
    payload = {_LEAF_KEY.format(i): raw for i, (raw, _) in enumerate(encoded)}
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
    os.replace(tmp, path)
    meta = {"step": step, "num_leaves": len(host_leaves),
            "dtypes": [name for _, name in encoded],
            "treedef": str(treedef)}
    meta_path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.json")
    with open(meta_path + ".tmp", "w") as f:
        json.dump(meta, f)
    os.replace(meta_path + ".tmp", meta_path)
    return path


def load_checkpoint(ckpt_dir: str, step: int, like):
    """Restore into the structure of ``like`` (validates leaf count/shapes)."""
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    with open(os.path.join(ckpt_dir, f"ckpt_{step:08d}.json")) as f:
        meta = json.load(f)
    leaves, treedef = jax.tree.flatten(like)
    restored = [_decode(data[_LEAF_KEY.format(i)], meta["dtypes"][i])
                for i in range(len(leaves))]
    for i, (r, l) in enumerate(zip(restored, leaves)):
        if hasattr(l, "shape") and tuple(r.shape) != tuple(np.shape(l)):
            raise ValueError(f"leaf {i}: shape {r.shape} != expected {np.shape(l)}")
    return jax.tree.unflatten(treedef, restored)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None
