"""Serving walkthrough: precompute -> two-tier cache engine -> query stream.

Builds a small partitioned task, precomputes per-layer embeddings through
the CaPGNN exchange machinery, then serves a zipf query stream from the
two-tier cache — and finally pushes a feature update through the fresh=k
recompute path.  A thin, commented wrapper over ``repro.serve``; the CLI
equivalent is ``python -m repro.launch.serve gnn``.

    PYTHONPATH=src python examples/serve_gnn.py
"""
import argparse
import json

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="flickr")
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--queries", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    from repro.core import PROFILES, build_cache_plan, cal_capacity
    from repro.data import make_task
    from repro.dist import build_exchange_plan, stack_partitions
    from repro.graph import build_partition, metis_partition
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.serve import (BatchConfig, GNNServeEngine, precompute_embeddings,
                             rank_hot_nodes, serve_stream, zipf_stream)

    # 1. the usual CaPGNN setup: task, partitions, JACA plan, exchange plan
    task = make_task(args.dataset, scale=args.scale, feat_dim=32,
                     seed=args.seed)
    g = task.graph
    ps = build_partition(g, metis_partition(g, args.parts, seed=args.seed),
                         hops=1)
    cfg = GNNConfig(model="gcn", in_dim=32, hidden_dim=64,
                    out_dim=task.num_classes, num_layers=3)
    params = init_gnn(jax.random.PRNGKey(args.seed), cfg)
    cap = cal_capacity(ps, cfg.feat_dims, [PROFILES["rtx3090"]] * args.parts)
    xplan = build_exchange_plan(ps, build_cache_plan(ps, cap))
    sp = stack_partitions(ps, task)

    # 2. offline: one partitioned layer-wise inference pass over the graph
    store = precompute_embeddings(cfg, ps, sp, xplan, params)
    print(f"precomputed {len(store.tables)} layer tables over "
          f"{store.num_nodes} nodes")

    # 3. online: degree-ranked hot tier + micro-batched query engine
    hot = rank_hot_nodes(g, g.num_nodes // 10, ps=ps, policy="degree")
    engine = GNNServeEngine(store, params, g, hot, features=task.features)
    by_degree = rank_hot_nodes(g, g.num_nodes, policy="degree")
    stream = zipf_stream(g.num_nodes, args.queries, qps=500.0, alpha=1.1,
                         seed=args.seed, rank_to_node=by_degree)
    report = serve_stream(engine, stream,
                          BatchConfig(max_batch=64, deadline_ms=2.0))
    print(json.dumps({k: round(v, 4) if isinstance(v, float) else v
                      for k, v in report.items()}, indent=1))

    # 4. freshness: update some features, serve again — stale nodes take the
    #    k-hop recompute path, clean ones still hit the cache tiers
    rng = np.random.default_rng(args.seed)
    upd = rng.choice(g.num_nodes, max(1, g.num_nodes // 200), replace=False)
    engine.update_features(upd, task.features[upd] + 0.5)
    report = serve_stream(engine, stream,
                          BatchConfig(max_batch=64, deadline_ms=2.0))
    print(f"after updating {upd.size} nodes ({int(engine.stale.sum())} stale):"
          f" fresh_rate {report['fresh_rate']:.2%}, "
          f"hot {report['hot_hit_rate']:.2%}, qps {report['qps']:.0f}")


if __name__ == "__main__":
    main()
