"""Serve demo: batched greedy decode for any of the 10 assigned archs.

Runs the reduced config of each requested architecture through the serve
path (one-token steps against a KV/SSM cache) and prints throughput —
a thin example wrapper over ``repro.launch.serve``.

    PYTHONPATH=src python examples/serve_transformer.py --arch hymba-1.5b
    PYTHONPATH=src python examples/serve_transformer.py --all
"""
import argparse
import sys

from repro.configs import ARCH_IDS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--all", action="store_true",
                    help="serve every assigned architecture (reduced)")
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    from repro.launch import serve
    archs = ARCH_IDS if args.all else [args.arch]
    for arch in archs:
        sys.argv = ["serve", "lm", "--arch", arch, "--steps", str(args.steps)]
        serve.main()


if __name__ == "__main__":
    main()
