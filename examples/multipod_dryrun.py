"""Multi-pod dry-run demo: lower + compile one (arch x shape) pair on the
production meshes and print its roofline terms — the smallest end-to-end
path through mesh.py / sharding.py / dryrun.py / hlo_cost.py.

    PYTHONPATH=src python examples/multipod_dryrun.py --arch hymba-1.5b \
        --shape train_4k
"""
import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import run_one   # sets XLA_FLAGS before jax init
    res = run_one(args.arch, args.shape, multi_pod=args.multi_pod)
    print(json.dumps(res, indent=1))

    peak, hbm, ici = 197e12, 819e9, 50e9
    t_c = res["hlo_flops_per_device"] / peak
    t_m = res["hlo_bytes_per_device"] / hbm
    t_x = res["collective_bytes_per_device"] / ici
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    print(f"\nroofline terms: compute {t_c:.3e}s  memory {t_m:.3e}s  "
          f"collective {t_x:.3e}s  -> {dom[0]}-bound")


if __name__ == "__main__":
    main()
